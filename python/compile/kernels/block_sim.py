"""Layer-1 Pallas kernel: tiled dense block cosine-similarity matrix.

The compute hot-spot of the dense cross-check path is ``S = X @ M^T`` for
a block of (already unit-norm) object rows against the mean rows. The
kernel expresses the HBM->VMEM schedule with BlockSpecs: the grid walks
(B/tb, K/tk) output tiles; each program instance loads one (tb, D) object
tile and one (tk, D) mean tile into VMEM and contracts them on the MXU
(``dot_general`` with the D axis contracted, f32 accumulation).

TPU sizing rationale (DESIGN.md §Hardware-Adaptation): with the default
tiles (64, 32) x D=256 the VMEM working set is
  tb*D + tk*D + tb*tk floats = (64 + 32)*256 + 64*32 ≈ 0.11 MB « 16 MB,
leaving room to scale D or double-buffer; tile edges are multiples of the
8x128 vector-register lanes when tb, tk >= 8 and D is a multiple of 128.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO so
the AOT artifact executes anywhere (correctness is validated against the
pure-jnp oracle in ``ref.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sim_kernel(x_ref, m_ref, o_ref):
    """One (tb, tk) output tile: contract the shared D axis on the MXU."""
    o_ref[...] = jax.lax.dot_general(
        x_ref[...],
        m_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("tb", "tk"))
def block_sim(x, m, *, tb=None, tk=None):
    """Similarity matrix ``S[b, k] = <x_b, m_k>`` via the Pallas kernel.

    Args:
      x: (B, D) f32 object block.
      m: (K, D) f32 mean block.
      tb, tk: tile sizes (default: whole B / whole K when they are small,
        else 64/32). Must divide B and K.

    Returns:
      (B, K) f32 similarity matrix.
    """
    b, d = x.shape
    k, d2 = m.shape
    assert d == d2, f"D mismatch: {d} vs {d2}"
    tb = tb or min(b, 64)
    tk = tk or min(k, 32)
    assert b % tb == 0, f"tile tb={tb} must divide B={b}"
    assert k % tk == 0, f"tile tk={tk} must divide K={k}"

    grid = (b // tb, k // tk)
    return pl.pallas_call(
        _sim_kernel,
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tk), lambda i, j: (i, j)),
        interpret=True,  # CPU-PJRT cannot execute Mosaic custom-calls
    )(x, m)
