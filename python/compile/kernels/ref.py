"""Pure-jnp oracles for the Pallas kernel and the dense model step.

These are the correctness references: ``pytest python/tests`` asserts the
Pallas kernel (interpret mode) and the lowered model agree with these to
float tolerance. No Pallas, no tiling — just the textbook math.
"""

import jax.numpy as jnp


def block_sim_ref(x, m):
    """S[b, k] = <x_b, m_k> — plain matmul reference."""
    return (x @ m.T).astype(jnp.float32)


def _one_hot(idx, k):
    return (idx[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)


def assign_ref(x, m):
    """Spherical assignment: argmax similarity (ties -> lowest id)."""
    sims = block_sim_ref(x, m)
    best = jnp.argmax(sims, axis=1).astype(jnp.int32)
    best_sim = jnp.max(sims, axis=1)
    return best, best_sim


def kmeans_step_ref(x, m):
    """One dense spherical k-means step.

    Returns (assignments, new unit-norm means, objective). Empty clusters
    keep their previous mean (matching the Rust update step's policy).
    """
    best, best_sim = assign_ref(x, m)
    k = m.shape[0]
    onehot = _one_hot(best, k)
    sums = onehot.T @ x  # (K, D)
    counts = onehot.sum(axis=0)  # (K,)
    norms = jnp.linalg.norm(sums, axis=1, keepdims=True)
    safe = jnp.where(norms > 0.0, norms, 1.0)
    fresh = sums / safe
    keep_old = (counts == 0.0) | (norms[:, 0] == 0.0)
    new_m = jnp.where(keep_old[:, None], m, fresh)
    objective = jnp.sum(best_sim)
    return best, new_m.astype(jnp.float32), objective.astype(jnp.float32)
