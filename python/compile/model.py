"""Layer-2 JAX model: the dense-block spherical k-means step.

This is the dense cross-check oracle the Rust coordinator executes
through PJRT (DESIGN.md §2): the similarity hot-spot goes through the
Layer-1 Pallas kernel (``kernels.block_sim``), the surrounding argmax /
one-hot update / renormalization is plain jnp so XLA fuses it into a
single executable. ``aot.py`` lowers both entry points at fixed block
shapes to HLO text.
"""

import jax
import jax.numpy as jnp

from compile.kernels.block_sim import block_sim


def assign_block(x, m):
    """Dense spherical assignment of a block.

    Args:
      x: (B, D) f32 unit-norm object rows.
      m: (K, D) f32 unit-norm mean rows.

    Returns:
      tuple of ((B,) int32 argmax ids, (B,) f32 best similarities).
    """
    sims = block_sim(x, m)  # Layer-1 Pallas kernel
    best = jnp.argmax(sims, axis=1).astype(jnp.int32)
    best_sim = jnp.max(sims, axis=1)
    return best, best_sim


def kmeans_step(x, m):
    """One full dense spherical k-means step (assign + update).

    Empty clusters keep their previous mean, matching the Rust update
    step, so iterating this function from the same seeds reproduces the
    sparse engine's trajectory on dense data.

    Returns:
      tuple of ((B,) int32 assignments, (K, D) f32 new unit-norm means,
      () f32 objective = sum of best similarities).
    """
    sims = block_sim(x, m)  # Layer-1 Pallas kernel
    best = jnp.argmax(sims, axis=1).astype(jnp.int32)
    best_sim = jnp.max(sims, axis=1)
    k = m.shape[0]
    onehot = (best[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    sums = onehot.T @ x
    counts = onehot.sum(axis=0)
    norms = jnp.linalg.norm(sums, axis=1, keepdims=True)
    safe = jnp.where(norms > 0.0, norms, 1.0)
    fresh = sums / safe
    keep_old = (counts == 0.0) | (norms[:, 0] == 0.0)
    new_m = jnp.where(keep_old[:, None], m, fresh).astype(jnp.float32)
    objective = jnp.sum(best_sim).astype(jnp.float32)
    return best, new_m, objective


assign_block_jit = jax.jit(assign_block)
kmeans_step_jit = jax.jit(kmeans_step)
