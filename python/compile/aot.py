"""AOT lowering: JAX/Pallas model -> HLO text artifacts for the Rust
runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which this image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
so the Rust side unwraps one tuple (see rust/src/runtime/).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Block shapes compiled into the artifacts. Must match the constants in
# rust/src/runtime/mod.rs (BLOCK_B, BLOCK_K, BLOCK_D).
BLOCK_B = 64
BLOCK_K = 32
BLOCK_D = 256


def to_hlo_text(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    x_spec = jax.ShapeDtypeStruct((BLOCK_B, BLOCK_D), jnp.float32)
    m_spec = jax.ShapeDtypeStruct((BLOCK_K, BLOCK_D), jnp.float32)

    artifacts = {
        "assign_block": (model.assign_block, (x_spec, m_spec)),
        "kmeans_step": (model.kmeans_step, (x_spec, m_spec)),
    }
    meta = {"block_b": BLOCK_B, "block_k": BLOCK_K, "block_d": BLOCK_D, "files": {}}
    for name, (fn, specs) in artifacts.items():
        text = to_hlo_text(fn, *specs)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["files"][name] = {"path": path, "chars": len(text)}
        print(f"wrote {len(text):>9} chars  {path}")

    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'meta.json')}")


if __name__ == "__main__":
    main()
