"""Layer-2 correctness: the dense model (which routes its hot-spot
through the Pallas kernel) vs the pure-jnp oracle, plus AOT lowering
round-trip checks on the HLO text itself."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels.ref import assign_ref, kmeans_step_ref


def _unit_rows(shape, seed):
    x = np.abs(np.random.default_rng(seed).normal(size=shape)) + 1e-3
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return jnp.asarray(x, dtype=jnp.float32)


class TestAssignBlock:
    def test_matches_oracle(self):
        x = _unit_rows((aot.BLOCK_B, aot.BLOCK_D), 0)
        m = _unit_rows((aot.BLOCK_K, aot.BLOCK_D), 1)
        best, best_sim = model.assign_block_jit(x, m)
        rbest, rsim = assign_ref(x, m)
        np.testing.assert_array_equal(np.asarray(best), np.asarray(rbest))
        np.testing.assert_allclose(np.asarray(best_sim), np.asarray(rsim), rtol=1e-5)
        assert best.dtype == jnp.int32

    def test_self_assignment(self):
        m = _unit_rows((aot.BLOCK_K, aot.BLOCK_D), 2)
        x = jnp.tile(m, (aot.BLOCK_B // aot.BLOCK_K, 1))
        best, best_sim = model.assign_block_jit(x, m)
        want = np.tile(np.arange(aot.BLOCK_K), aot.BLOCK_B // aot.BLOCK_K)
        np.testing.assert_array_equal(np.asarray(best), want)
        np.testing.assert_allclose(np.asarray(best_sim), 1.0, atol=1e-5)


class TestKmeansStep:
    def test_matches_oracle(self):
        x = _unit_rows((aot.BLOCK_B, aot.BLOCK_D), 3)
        m = _unit_rows((aot.BLOCK_K, aot.BLOCK_D), 4)
        best, new_m, obj = model.kmeans_step_jit(x, m)
        rbest, rm, robj = kmeans_step_ref(x, m)
        np.testing.assert_array_equal(np.asarray(best), np.asarray(rbest))
        np.testing.assert_allclose(np.asarray(new_m), np.asarray(rm), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(obj), float(robj), rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_objective_nondecreasing_property(self, seed):
        x = _unit_rows((aot.BLOCK_B, aot.BLOCK_D), seed)
        m = _unit_rows((aot.BLOCK_K, aot.BLOCK_D), seed ^ 0xFFFF)
        prev = -np.inf
        for _ in range(5):
            _, m, obj = model.kmeans_step_jit(x, m)
            assert float(obj) >= prev - 1e-3
            prev = float(obj)


class TestAotLowering:
    def test_hlo_text_nonempty_and_tupled(self):
        x_spec = jax.ShapeDtypeStruct((aot.BLOCK_B, aot.BLOCK_D), jnp.float32)
        m_spec = jax.ShapeDtypeStruct((aot.BLOCK_K, aot.BLOCK_D), jnp.float32)
        text = aot.to_hlo_text(model.assign_block, x_spec, m_spec)
        assert "HloModule" in text
        # return_tuple=True → root is a tuple of the two outputs.
        assert "ROOT" in text and "tuple(" in text.replace(") ", "(")
        # fixed shapes baked in
        assert f"f32[{aot.BLOCK_B},{aot.BLOCK_D}]" in text

    def test_kmeans_step_lowering_has_three_outputs(self):
        x_spec = jax.ShapeDtypeStruct((aot.BLOCK_B, aot.BLOCK_D), jnp.float32)
        m_spec = jax.ShapeDtypeStruct((aot.BLOCK_K, aot.BLOCK_D), jnp.float32)
        text = aot.to_hlo_text(model.kmeans_step, x_spec, m_spec)
        assert "HloModule" in text
        assert f"f32[{aot.BLOCK_K},{aot.BLOCK_D}]" in text

    def test_block_constants_match_rust(self):
        """Guard: the Rust runtime hard-codes the same block shapes."""
        import pathlib
        import re

        src = pathlib.Path(__file__).resolve().parents[2] / "rust/src/runtime/mod.rs"
        text = src.read_text()
        for name, value in [
            ("BLOCK_B", aot.BLOCK_B),
            ("BLOCK_K", aot.BLOCK_K),
            ("BLOCK_D", aot.BLOCK_D),
        ]:
            m = re.search(rf"pub const {name}: usize = (\d+);", text)
            assert m, f"{name} not found in rust runtime"
            assert int(m.group(1)) == value, f"{name} mismatch rust={m.group(1)} py={value}"
