"""Layer-1 correctness: the Pallas block-similarity kernel vs the
pure-jnp oracle, swept over shapes and value ranges with hypothesis.

This is the CORE kernel correctness signal: the same code path is what
the AOT artifacts embed, so agreement here + the Rust runtime test
closes the three-layer loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.block_sim import block_sim
from compile.kernels.ref import assign_ref, block_sim_ref, kmeans_step_ref


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), dtype=jnp.float32)


def _unit_rows(shape, seed):
    x = np.abs(np.random.default_rng(seed).normal(size=shape)) + 1e-3
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return jnp.asarray(x, dtype=jnp.float32)


class TestBlockSimBasic:
    def test_identity_match(self):
        # object r equals mean r: similarity matrix is identity-like.
        m = _unit_rows((8, 32), 0)
        s = block_sim(m, m)
        np.testing.assert_allclose(np.diag(np.asarray(s)), 1.0, atol=1e-6)

    def test_matches_ref_default_tiles(self):
        x = _rand((64, 256), 1)
        m = _rand((32, 256), 2)
        got = block_sim(x, m)
        want = block_sim_ref(x, m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("tb,tk", [(8, 8), (16, 32), (64, 32), (32, 16)])
    def test_tile_shapes_agree(self, tb, tk):
        x = _rand((64, 128), 3)
        m = _rand((32, 128), 4)
        got = block_sim(x, m, tb=tb, tk=tk)
        want = block_sim_ref(x, m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_indivisible_tile_rejected(self):
        x = _rand((10, 16), 5)
        m = _rand((4, 16), 6)
        with pytest.raises(AssertionError):
            block_sim(x, m, tb=3, tk=2)

    def test_zero_inputs(self):
        x = jnp.zeros((8, 16), jnp.float32)
        m = jnp.zeros((4, 16), jnp.float32)
        s = block_sim(x, m)
        assert np.all(np.asarray(s) == 0.0)


@settings(max_examples=25, deadline=None)
@given(
    bt=st.sampled_from([1, 2, 4]),
    kt=st.sampled_from([1, 2, 3]),
    d=st.sampled_from([8, 64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_kernel_matches_ref_hypothesis(bt, kt, d, seed, scale):
    """Property: kernel == oracle across shapes, seeds and value scales."""
    tb, tk = 8, 8
    x = _rand((bt * tb, d), seed, scale)
    m = _rand((kt * tk, d), seed + 1, scale)
    got = block_sim(x, m, tb=tb, tk=tk)
    want = block_sim_ref(x, m)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4 * scale * scale * d
    )


class TestRefOracles:
    """The oracles themselves must satisfy the spherical-k-means
    invariants (they are the ground truth for two layers)."""

    def test_assign_picks_true_argmax(self):
        x = _unit_rows((16, 32), 7)
        m = _unit_rows((5, 32), 8)
        best, best_sim = assign_ref(x, m)
        sims = np.asarray(x) @ np.asarray(m).T
        np.testing.assert_array_equal(np.asarray(best), sims.argmax(axis=1))
        np.testing.assert_allclose(np.asarray(best_sim), sims.max(axis=1), rtol=1e-6)

    def test_kmeans_step_means_unit_norm(self):
        x = _unit_rows((32, 16), 9)
        m = _unit_rows((4, 16), 10)
        _, new_m, _ = kmeans_step_ref(x, m)
        norms = np.linalg.norm(np.asarray(new_m), axis=1)
        np.testing.assert_allclose(norms, 1.0, atol=1e-6)

    def test_kmeans_step_objective_monotone(self):
        x = _unit_rows((64, 16), 11)
        m = _unit_rows((6, 16), 12)
        objs = []
        for _ in range(8):
            _, m, obj = kmeans_step_ref(x, m)
            objs.append(float(obj))
        assert all(b >= a - 1e-4 for a, b in zip(objs, objs[1:])), objs

    def test_empty_cluster_keeps_mean(self):
        # All objects identical -> only one cluster wins; others keep
        # their previous means.
        x = jnp.tile(_unit_rows((1, 8), 13), (10, 1))
        m = _unit_rows((3, 8), 14)
        best, new_m, _ = kmeans_step_ref(x, m)
        winner = int(np.asarray(best)[0])
        for j in range(3):
            if j != winner:
                np.testing.assert_allclose(
                    np.asarray(new_m)[j], np.asarray(m)[j], atol=1e-7
                )
