//! Hybrid dense pipeline: iterate the **AOT-compiled JAX+Pallas
//! `kmeans_step`** from Rust via PJRT until convergence on a dense block,
//! and verify the trajectory matches a pure-Rust dense reference step by
//! step — the strongest cross-layer correctness signal (Layer 3 drives
//! Layers 2+1 with no Python in the loop).
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example hybrid_dense`

use skm::runtime::{PjrtRuntime, BLOCK_B, BLOCK_D, BLOCK_K};
use skm::util::rng::Pcg32;

/// Pure-Rust dense spherical k-means step mirroring
/// `python/compile/model.py::kmeans_step` (and its jnp oracle).
fn rust_kmeans_step(x: &[f32], m: &[f32]) -> (Vec<u32>, Vec<f32>, f32) {
    let mut assign = vec![0u32; BLOCK_B];
    let mut obj = 0.0f32;
    for r in 0..BLOCK_B {
        let xr = &x[r * BLOCK_D..(r + 1) * BLOCK_D];
        let (mut best, mut bestv) = (0usize, f32::NEG_INFINITY);
        for j in 0..BLOCK_K {
            let mr = &m[j * BLOCK_D..(j + 1) * BLOCK_D];
            let s: f32 = xr.iter().zip(mr).map(|(a, b)| a * b).sum();
            if s > bestv {
                bestv = s;
                best = j;
            }
        }
        assign[r] = best as u32;
        obj += bestv;
    }
    let mut sums = vec![0.0f32; BLOCK_K * BLOCK_D];
    let mut counts = vec![0u32; BLOCK_K];
    for r in 0..BLOCK_B {
        let j = assign[r] as usize;
        counts[j] += 1;
        for t in 0..BLOCK_D {
            sums[j * BLOCK_D + t] += x[r * BLOCK_D + t];
        }
    }
    let mut new_m = m.to_vec();
    for j in 0..BLOCK_K {
        let row = &sums[j * BLOCK_D..(j + 1) * BLOCK_D];
        let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
        if counts[j] > 0 && norm > 0.0 {
            for t in 0..BLOCK_D {
                new_m[j * BLOCK_D + t] = row[t] / norm;
            }
        }
    }
    (assign, new_m, obj)
}

fn unit_rows(rows: usize, cols: usize, rng: &mut Pcg32) -> Vec<f32> {
    let mut x = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let mut norm = 0.0f32;
        for t in 0..cols {
            let v = (rng.next_f64().abs() as f32).max(1e-3);
            x[r * cols + t] = v;
            norm += v * v;
        }
        let norm = norm.sqrt();
        for t in 0..cols {
            x[r * cols + t] /= norm;
        }
    }
    x
}

fn main() {
    let dir = PjrtRuntime::default_dir();
    if !dir.join("kmeans_step.hlo.txt").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut rt = match PjrtRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("runtime unavailable: {e:#}");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.platform());

    let mut rng = Pcg32::new(2024);
    let x = unit_rows(BLOCK_B, BLOCK_D, &mut rng);
    let mut m_pjrt = unit_rows(BLOCK_K, BLOCK_D, &mut rng);
    let mut m_rust = m_pjrt.clone();

    println!("iter  objective(PJRT)  objective(Rust)  assign-agreement");
    let mut prev_obj = f32::NEG_INFINITY;
    for it in 1..=12 {
        let (a_pjrt, new_m, obj) = rt.kmeans_step(&x, &m_pjrt).expect("kmeans_step");
        let (a_rust, new_m_rust, obj_rust) = rust_kmeans_step(&x, &m_rust);

        let agree = a_pjrt
            .iter()
            .zip(&a_rust)
            .filter(|(a, b)| a == b)
            .count();
        println!(
            "{:>4}  {:<15.5} {:<16.5} {agree}/{BLOCK_B}",
            it, obj, obj_rust
        );
        assert!(
            (obj - obj_rust).abs() < 1e-2 * obj.abs().max(1.0),
            "objective diverged: {obj} vs {obj_rust}"
        );
        assert!(agree >= BLOCK_B - 2, "assignments diverged: {agree}/{BLOCK_B}");
        assert!(obj >= prev_obj - 1e-3, "objective decreased");
        prev_obj = obj;
        m_pjrt = new_m;
        m_rust = new_m_rust;
    }
    println!(
        "\n12 dense k-means steps executed through the runtime executor ({}) ✓",
        rt.platform()
    );
    println!("Rust reference and runtime trajectory agree ✓");
    println!(
        "note: on the native-cpu fallback this cross-checks the runtime executor, \
         not the HLO artifact itself — relink the XLA backend for the full \
         three-layer signal"
    );
}
