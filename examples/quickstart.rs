//! Quickstart: generate a small Zipf-topic corpus, cluster it with
//! ES-ICP, and inspect the result — cluster sizes, the dominant terms of
//! the largest clusters (the feature-value-concentration phenomenon
//! means one or two terms annotate each cluster), and the speedup over
//! the MIVI baseline.
//!
//! Run: `cargo run --release --example quickstart`

use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::corpus::{generate, pubmed_like};
use skm::index::update_means;
use skm::sparse::build_dataset;

fn main() {
    // ~4100 documents with PubMed-like statistics.
    let spec = pubmed_like(5e-4, 42);
    let corpus = generate(&spec);
    let ds = build_dataset(&corpus.name, corpus.n_terms, &corpus.docs);
    let k = (ds.n() / 100).max(8);
    println!(
        "corpus {}: N={} D={} avg distinct terms/doc={:.1}",
        ds.name,
        ds.n(),
        ds.d(),
        ds.avg_terms()
    );

    let cfg = ClusterConfig {
        k,
        seed: 42,
        ..Default::default()
    };

    // The proposed algorithm ...
    let es = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    // ... and the baseline for reference.
    let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);

    assert_eq!(es.assign, base.assign, "acceleration must be exact");
    println!(
        "\nES-ICP: {} iterations, objective J = {:.3}",
        es.iterations(),
        es.objective
    );
    println!(
        "assignment-step speedup vs MIVI: {:.1}x  (multiplications: {:.1}x fewer)",
        base.total_assign_secs() / es.total_assign_secs().max(1e-9),
        base.total_mult() as f64 / es.total_mult().max(1) as f64
    );

    // Top terms of the 5 largest clusters.
    let upd = update_means(&ds, &es.assign, k, None, None);
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by_key(|&j| std::cmp::Reverse(upd.means.sizes[j]));
    println!("\nlargest clusters (dominant feature values — note the concentration):");
    for &j in order.iter().take(5) {
        let (ts, vs) = upd.means.m.row(j);
        let mut top: Vec<(u32, f64)> = ts.iter().cloned().zip(vs.iter().cloned()).collect();
        top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let desc: Vec<String> = top
            .iter()
            .take(3)
            .map(|&(t, v)| format!("term{}:{:.2}", upd.means.m.n_cols() as u32 - t, v))
            .collect();
        println!(
            "  cluster {:>3}: {:>5} docs, top features [{}]",
            j,
            upd.means.sizes[j],
            desc.join(", ")
        );
    }
}
