//! EstParams walkthrough (Section V / Appendix C): estimate the
//! structural parameters on a PubMed-like workload, show the per-v_h
//! curve (Fig. 13's estimated series), and validate the estimate by
//! measuring the *actual* multiplication count of the resulting filter
//! against neighboring parameter choices.
//!
//! Run: `cargo run --release --example estparams_demo`

use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::coordinator::preset;
use skm::estparams::{actual_mult_count, estimate, EstConfig};
use skm::index::{update_means, ObjInvIndex};
use skm::util::cli::Args;
use skm::util::io::fmt_sig;

fn main() {
    let args = Args::parse();
    let p = preset(
        args.get_or("preset", "pubmed-like"),
        7,
        args.get("scale").map(|s| s.parse().expect("--scale")),
    )
    .unwrap();
    let ds = p.dataset();
    let cfg = p.config(42);
    println!("N={} D={} K={}", ds.n(), ds.d(), cfg.k);

    // Warm up with two MIVI iterations (the state EstParams sees inside
    // ES-ICP at its second estimation).
    let warm = ClusterConfig {
        max_iters: 2,
        ..cfg.clone()
    };
    let out = run_clustering(AlgoKind::Mivi, &ds, &warm);
    let upd = update_means(&ds, &out.assign, cfg.k, None, None);

    let s_min = (ds.d() as f64 * cfg.s_min_frac) as usize;
    let xp = ObjInvIndex::build(&ds.x, s_min);
    let (est, secs) = skm::util::timer::time_once(|| {
        estimate(
            &ds,
            &upd.means,
            &upd.rho,
            &xp,
            &EstConfig {
                s_min,
                n_candidates: cfg.n_vth_candidates,
                fixed_t: None,
                fixed_v: None,
                max_sample_objects: 10_000,
            },
        )
    });
    println!(
        "\nestimated in {:.3}s:  t_th={} ({:.3}*D)   v_th={:.4}   approx J={}",
        secs,
        est.t_th,
        est.t_th as f64 / ds.d() as f64,
        est.v_th,
        fmt_sig(est.j_value)
    );

    // Fig. 13: approximate J vs actual mult along the candidate curve.
    println!("\n   v_h      t_h(v_h)   approx J       actual Mult   (Fig. 13 series)");
    let step = (est.curve.len() / 12).max(1);
    for pnt in est.curve.iter().step_by(step) {
        let actual = actual_mult_count(&ds, &upd.means, &upd.rho, pnt.t_th, pnt.v_th);
        println!(
            "  {:<8.4} {:<10} {:<14} {}",
            pnt.v_th,
            pnt.t_th,
            fmt_sig(pnt.j_value),
            fmt_sig(actual as f64)
        );
    }

    // Sanity: the chosen parameters beat naive extremes on actual mults.
    let chosen = actual_mult_count(&ds, &upd.means, &upd.rho, est.t_th, est.v_th);
    let mivi = actual_mult_count(&ds, &upd.means, &upd.rho, ds.d(), 1.0);
    println!(
        "\nactual Mult: chosen params {} vs exhaustive (MIVI) {}  → {:.1}x reduction",
        fmt_sig(chosen as f64),
        fmt_sig(mivi as f64),
        mivi as f64 / chosen.max(1) as f64
    );
}
