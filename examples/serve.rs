//! Online serving demo: cluster a PubMed-like corpus, freeze it into a
//! `serve::ClusteredCorpus`, build the pruned query router over the
//! structured mean index, and answer a few queries — corpus documents,
//! a raw bag-of-words query embedded through the frozen tf-idf space,
//! and an out-of-vocabulary query.
//!
//! Run: `cargo run --release --example serve`

use skm::algo::{run_clustering, AlgoKind, ClusterConfig, ParConfig};
use skm::corpus::{generate, pubmed_like};
use skm::serve::{serve_batch, ClusteredCorpus, Query, Router, RouterParams, ServeDefaults};
use skm::sparse::build_dataset;
use std::time::Instant;

fn main() {
    // ~4100 documents with PubMed-like statistics.
    let spec = pubmed_like(5e-4, 42);
    let corpus = generate(&spec);
    let ds = build_dataset(&corpus.name, corpus.n_terms, &corpus.docs);
    let k = (ds.n() / 100).max(8);
    let cfg = ClusterConfig {
        k,
        seed: 42,
        ..Default::default()
    };
    println!("corpus {}: N={} D={} K={k}", ds.name, ds.n(), ds.d());

    // Cluster and freeze.
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    println!(
        "clustered: {} iterations, J={:.4}",
        out.iterations(),
        out.objective
    );
    let snap = ClusteredCorpus::from_output(ds, &out, k);

    // The router reuses the paper's machinery on the query side: the
    // Section-V estimator picks (t_th, v_th) over the frozen means, and
    // every query runs the ES-pruned gather + exact verification.
    let router =
        Router::new(&snap, RouterParams::estimate_for(&snap, &cfg)).expect("router build");
    let sd = ServeDefaults::default_for(k);
    println!(
        "router: t_th={} ({:.3}·D), v_th={:.4} — serving top-{} clusters / top-{} docs",
        router.t_th(),
        router.t_th() as f64 / snap.ds.d() as f64,
        router.v_th(),
        sd.top_p,
        sd.top_k
    );

    // Query 1–3: corpus documents as queries (batch-served, 2 threads).
    let doc_ids = [7usize, 191, 1033];
    let queries: Vec<Query> = doc_ids
        .iter()
        .map(|&i| Query::from_row(&snap.ds, i))
        .collect();
    let t0 = Instant::now();
    let (results, counters) = serve_batch(
        &router,
        &queries,
        sd.top_p,
        sd.top_k,
        &ParConfig::with_threads(2),
    );
    println!(
        "\nserved {} doc-queries in {:.2} ms (avg {:.1} candidate centroids of K={k})",
        results.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        counters.candidates as f64 / results.len() as f64
    );
    for ((&i, q), slot) in doc_ids.iter().zip(&queries).zip(&results) {
        let r = slot.as_ref().expect("doc query");
        let (c0, s0) = r.centroids[0];
        println!(
            "doc {i} (cluster {}): routed to cluster {c0} (cos {s0:.4}); best hits {:?}",
            snap.assign[i],
            r.hits
                .iter()
                .take(3)
                .map(|&(d, s)| format!("{d}@{s:.3}"))
                .collect::<Vec<_>>()
        );
        // A document whose own cluster is scanned can never be beaten
        // below its self-similarity.
        if r.centroids.iter().any(|&(c, _)| c == snap.assign[i]) {
            let self_score: f64 = q.vals().iter().map(|v| v * v).sum();
            assert!(
                r.hits[0].1 >= self_score - 1e-12,
                "doc {i}: best hit below self-similarity"
            );
        }
    }

    // Query 4: a raw bag-of-words query in the ORIGINAL vocabulary,
    // embedded through the frozen tf-idf space (the `skm serve
    // --queries file.txt` path). Reuse a corpus document's raw counts.
    let raw = &corpus.docs[500];
    let embedded = snap.embed_bow(raw).expect("embed raw counts");
    let r = router.retrieve(&embedded, sd.top_p, 3).expect("retrieve");
    println!(
        "\nembedded bag-of-words query ({} raw terms -> {} features): top hit doc {} at cos {:.4} (source doc 500)",
        raw.len(),
        embedded.nnz(),
        r.hits[0].0,
        r.hits[0].1
    );

    // Query 5: out-of-vocabulary terms only — embeds to the zero
    // vector and routes deterministically with zero scores.
    let oov = Query::from_pairs(snap.ds.d(), &[(snap.ds.d() as u32 + 9, 3.0)]).expect("oov query");
    assert!(oov.is_zero());
    let (routed, _) = router.route(&oov, 2).expect("route oov");
    println!(
        "OOV-only query: zero vector, deterministically routed to clusters {:?} with zero scores",
        routed.iter().map(|&(c, _)| c).collect::<Vec<_>>()
    );
    assert!(routed.iter().all(|&(_, s)| s == 0.0));
}
