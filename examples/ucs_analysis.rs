//! Universal-characteristics analysis (Section III) on both synthetic
//! corpora — the data behind Figs. 2, 3, 4, 9, 11, 21, 22 — with CSV
//! output under `target/experiments/ucs/` for plotting.
//!
//! Run: `cargo run --release --example ucs_analysis [-- --preset pubmed-like]`

use skm::algo::{run_clustering, AlgoKind};
use skm::coordinator::preset;
use skm::index::update_means;
use skm::ucs;
use skm::util::cli::Args;
use skm::util::io::{fmt_sig, Table};

fn main() {
    let args = Args::parse();
    let names: Vec<&str> = match args.get("preset") {
        Some(p) => vec![p],
        None => vec!["pubmed-like", "nyt-like"],
    };
    for name in names {
        analyze(name, args.get("scale").map(|s| s.parse().expect("--scale")));
    }
}

fn analyze(name: &str, scale: Option<f64>) {
    let p = preset(name, 7, scale).unwrap();
    let ds = p.dataset();
    let cfg = p.config(42);
    println!("\n==== {} (N={} D={} K={}) ====", name, ds.n(), ds.d(), cfg.k);

    // Fig 2(a): Zipf on tf and df.
    let tf = ds.x.column_sum();
    let df: Vec<f64> = ds.df.iter().map(|&x| x as f64).collect();
    let rf_tf = ucs::rank_frequency(&tf);
    let rf_df = ucs::rank_frequency(&df);
    let (a_tf, r_tf) = ucs::zipf_exponent(&rf_tf, 100);
    let (a_df, r_df) = ucs::zipf_exponent(&rf_df, 100);
    println!("[Fig 2a] Zipf: tf alpha={a_tf:.3} (r2={r_tf:.2}), df alpha={a_df:.3} (r2={r_df:.2})");
    write_series(name, "fig2a_df_rank_freq", &rf_df);

    // Cluster to get a mean set.
    eprintln!("clustering with ES-ICP ...");
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    let upd = update_means(&ds, &out.assign, cfg.k, None, None);

    // Fig 2(b): bounded Zipf on mf for several K values.
    let mut t = Table::new(vec!["K", "alpha_mf", "max_mf", "bounded_by_K"]);
    for kf in [cfg.k / 8, cfg.k / 4, cfg.k / 2, cfg.k] {
        let kf = kf.max(2);
        let c2 = skm::algo::ClusterConfig {
            k: kf,
            max_iters: 6,
            ..cfg.clone()
        };
        let o2 = run_clustering(AlgoKind::EsIcp, &ds, &c2);
        let m2 = update_means(&ds, &o2.assign, kf, None, None);
        let mf: Vec<f64> = m2.means.m.column_df().iter().map(|&x| x as f64).collect();
        let rf = ucs::rank_frequency(&mf);
        let (a, _) = ucs::zipf_exponent(&rf, 60);
        t.row(vec![
            kf.to_string(),
            format!("{a:.3}"),
            format!("{}", rf[0].1),
            (rf[0].1 <= kf as f64).to_string(),
        ]);
    }
    println!("[Fig 2b] bounded Zipf on mean frequency:\n{}", t.render());

    // Fig 3: df–mf correlation + multiplication volume.
    let prof = ucs::df_mf_profile(&ds, &upd.means);
    write_series(name, "fig3a_df_mf", &prof);
    let (total, topfrac) = ucs::mult_volume(&ds, &upd.means);
    println!(
        "[Fig 3] df–mf profile written; MIVI mult volume = {} with {:.1}% in the top-10% term ids",
        fmt_sig(total),
        topfrac * 100.0
    );

    // Fig 4(a)/11(a): feature-value skew.
    let skew = ucs::value_skew(&upd.means, 500);
    write_series(name, "fig4a_value_skew", &skew);
    println!(
        "[Fig 4a] feature-value skew written; {} components > 1/sqrt(2) across K={} centroids",
        ucs::concentration_count(&upd.means),
        cfg.k
    );

    // Fig 9/11(b): order-value CDFs.
    let t_th = out.t_th.unwrap_or(ds.d() * 9 / 10);
    let cdfs = ucs::order_value_cdf(&upd.means, t_th, &[1, 2, 3, 10, 100]);
    for (q, samples) in &cdfs {
        if samples.is_empty() {
            continue;
        }
        let med = samples[samples.len() / 2];
        println!(
            "[Fig 9] order {:>3}: {} arrays, median value {:.4}",
            q,
            samples.len(),
            med
        );
    }
    let (maxlen, avglen) = ucs::array_length_stats(&upd.means, t_th);
    println!("[Fig 9] array lengths in s >= t_th: max={maxlen} avg={avglen:.1}");

    // Fig 4(b)/21/22: CPS curve.
    let curve = ucs::cps_curve(&ds, &upd.means, &out.assign, 100);
    let series: Vec<(f64, f64)> = curve.nr.iter().cloned().zip(curve.mean.iter().cloned()).collect();
    write_series(name, "fig4b_cps", &series);
    println!(
        "[Fig 4b] CPS(0.1)={:.3}  CPS(0.2)={:.3}  CPS(0.5)={:.3}   (paper PubMed: CPS(0.1)=0.92)",
        curve.value_at(0.1),
        curve.value_at(0.2),
        curve.value_at(0.5)
    );
}

fn write_series(preset: &str, fname: &str, series: &[(f64, f64)]) {
    let mut t = Table::new(vec!["x", "y"]);
    for &(x, y) in series {
        t.row(vec![format!("{x}"), format!("{y}")]);
    }
    let path = format!("target/experiments/ucs/{preset}_{fname}.csv");
    t.write_csv(&path).expect("write csv");
    eprintln!("  wrote {path}");
}
