//! NYT-like workload (Section VII-C: ES-ICP as a *general* algorithm):
//! longer documents (avg ≈ 226 distinct terms), larger vocabulary,
//! K ≈ N/128. Runs the §VI-D suite and reports the Table-VI-style rates,
//! plus the Appendix-F observation that on NYT the ES-ICP assignment
//! step can drop *below* the update step.
//!
//! Run: `cargo run --release --example nyt_like [-- --scale 0.5 --seed 1]`

use skm::algo::AlgoKind;
use skm::coordinator::compare::absolute_table;
use skm::coordinator::{comparison_rate_table, preset, run_and_summarize};
use skm::util::cli::Args;

fn main() {
    let args = Args::parse();
    let scale = args.get("scale").map(|s| s.parse().expect("--scale"));
    let seed = args.get_parsed::<u64>("seed", 1);
    let p = preset("nyt-like", 11, scale).unwrap();
    let ds = p.dataset();
    let cfg = p.config(seed);
    println!(
        "== NYT-like ==\nN={} D={} avg-terms={:.1} sparsity={:.2e} K={}",
        ds.n(),
        ds.d(),
        ds.avg_terms(),
        ds.sparsity_indicator(),
        cfg.k
    );

    let suite = [
        AlgoKind::Mivi,
        AlgoKind::Icp,
        AlgoKind::TaIcp,
        AlgoKind::CsIcp,
        AlgoKind::EsIcp,
    ];
    let mut summaries = Vec::new();
    let mut baseline_assign: Option<Vec<u32>> = None;
    for kind in suite {
        eprint!("running {:>7} ... ", kind.name());
        let (out, s) = run_and_summarize(kind, &ds, &cfg);
        eprintln!("{} iters, {:.2}s/iter avg", s.iterations, s.avg_secs);
        match &baseline_assign {
            None => baseline_assign = Some(out.assign),
            Some(base) => assert_eq!(&out.assign, base, "{} diverged", kind.name()),
        }
        summaries.push(s);
    }
    println!("\nexactness: all algorithms agree ✓");
    println!("\nAbsolute (per iteration):\n{}", absolute_table(&summaries).render());
    println!(
        "Rates relative to ES-ICP (paper Table VI):\n{}",
        comparison_rate_table(&summaries, "ES-ICP").render()
    );

    let es = &summaries[4];
    println!(
        "ES-ICP assignment {:.3}s/iter vs update {:.3}s/iter — the paper's NYT observation is \
         that assignment can drop below update (Table XVII)",
        es.avg_assign_secs, es.avg_update_secs
    );
    let mivi = &summaries[0];
    println!(
        "HEADLINE: ES-ICP {:.1}x faster than MIVI overall, {:.1}x on the assignment step",
        mivi.avg_secs / es.avg_secs,
        mivi.avg_assign_secs / es.avg_assign_secs
    );
}
