//! End-to-end driver (DESIGN.md end-to-end validation): the full system
//! on a PubMed-like workload.
//!
//! 1. Generates the scaled PubMed-like corpus (Zipf topic model) and
//!    builds tf-idf features.
//! 2. Runs the paper's §VI-D algorithm suite — MIVI, ICP, TA-ICP,
//!    CS-ICP, ES-ICP — from one seeding, checking they agree.
//! 3. Reports the headline metric (ES-ICP speedup over MIVI and over
//!    the next-best comparator) plus the paper-style rate table.
//! 4. Closes the three-layer loop: a sampled block of the converged
//!    solution is re-verified through the AOT-compiled JAX+Pallas dense
//!    kernel via PJRT (Layer 1+2 executed from Rust, no Python).
//!
//! Run: `cargo run --release --example pubmed_like [-- --scale 0.5 --seed 42]`

use skm::algo::AlgoKind;
use skm::coordinator::compare::absolute_table;
use skm::coordinator::{comparison_rate_table, preset, run_and_summarize};
use skm::index::update_means;
use skm::runtime::{densify_top_terms, PjrtRuntime, BLOCK_B, BLOCK_D, BLOCK_K};
use skm::util::cli::Args;
use skm::util::rng::Pcg32;

fn main() {
    let args = Args::parse();
    let scale = args.get("scale").map(|s| s.parse().expect("--scale"));
    let seed = args.get_parsed::<u64>("seed", 42);
    let p = preset("pubmed-like", 7, scale).unwrap();
    let ds = p.dataset();
    let cfg = p.config(seed);
    println!(
        "== PubMed-like end-to-end ==\nN={} D={} avg-terms={:.1} sparsity={:.2e} K={}",
        ds.n(),
        ds.d(),
        ds.avg_terms(),
        ds.sparsity_indicator(),
        cfg.k
    );

    // ---- the §VI-D suite ------------------------------------------------
    let suite = [
        AlgoKind::Mivi,
        AlgoKind::Icp,
        AlgoKind::TaIcp,
        AlgoKind::CsIcp,
        AlgoKind::EsIcp,
    ];
    let mut outs = Vec::new();
    let mut summaries = Vec::new();
    for kind in suite {
        eprint!("running {:>7} ... ", kind.name());
        let (out, s) = run_and_summarize(kind, &ds, &cfg);
        eprintln!(
            "{} iters, {:.2}s total ({:.2}s assign)",
            s.iterations,
            s.avg_secs * s.iterations as f64,
            s.avg_assign_secs * s.iterations as f64
        );
        outs.push(out);
        summaries.push(s);
    }
    // All accelerations agree with MIVI.
    for o in &outs[1..] {
        assert_eq!(
            o.assign, outs[0].assign,
            "{:?} diverged from MIVI",
            o.algo
        );
    }
    println!("\nexactness: all {} algorithms returned identical assignments ✓", suite.len());

    println!("\nAbsolute (per iteration):\n{}", absolute_table(&summaries).render());
    println!(
        "Rates relative to ES-ICP (paper Table IV):\n{}",
        comparison_rate_table(&summaries, "ES-ICP").render()
    );

    let mivi_t = summaries[0].avg_secs;
    let es_t = summaries[4].avg_secs;
    let next_best = summaries[1..4]
        .iter()
        .map(|s| s.avg_secs)
        .fold(f64::INFINITY, f64::min);
    println!(
        "HEADLINE: ES-ICP is {:.1}x faster than MIVI and {:.1}x faster than the next-best comparator",
        mivi_t / es_t,
        next_best / es_t
    );
    println!(
        "          assignment-step speedup vs MIVI: {:.1}x (paper: >15x at 8.2M docs)",
        summaries[0].avg_assign_secs / summaries[4].avg_assign_secs
    );

    // ---- three-layer cross-check via PJRT --------------------------------
    let dir = PjrtRuntime::default_dir();
    if !dir.join("assign_block.hlo.txt").exists() {
        println!("\n[skip] PJRT cross-check: artifacts not built (run `make artifacts`)");
        return;
    }
    println!("\n== PJRT dense cross-check (Layer 1+2 from Rust) ==");
    let mut rt = match PjrtRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("[skip] runtime unavailable: {e:#}");
            return;
        }
    };
    println!("platform: {}", rt.platform());

    // Sample BLOCK_B objects and BLOCK_K centroids; project both onto the
    // BLOCK_D highest-df terms; compare the kernel's argmax against the
    // same dense argmax computed in Rust.
    let final_means = update_means(&ds, &outs[4].assign, cfg.k, None, None).means;
    let mut rng = Pcg32::new(seed ^ 0xb10c);
    let rows: Vec<usize> = rng.sample_distinct(ds.n(), BLOCK_B);
    let cents: Vec<usize> = rng.sample_distinct(cfg.k.min(final_means.k()), BLOCK_K);
    let x_dense = densify_top_terms(&ds.x, &rows, BLOCK_D);
    let m_csr = final_means.m.to_csr();
    let m_dense = densify_top_terms(&m_csr, &cents, BLOCK_D);

    let (ids, sims) = rt.assign_block(&x_dense, &m_dense).expect("assign_block");

    // Rust-side reference argmax over the same projected data.
    let mut agree = 0;
    for r in 0..BLOCK_B {
        let xr = &x_dense[r * BLOCK_D..(r + 1) * BLOCK_D];
        let (mut best, mut bestv) = (0u32, f32::NEG_INFINITY);
        for (jj, _) in cents.iter().enumerate() {
            let mr = &m_dense[jj * BLOCK_D..(jj + 1) * BLOCK_D];
            let s: f32 = xr.iter().zip(mr).map(|(a, b)| a * b).sum();
            if s > bestv {
                bestv = s;
                best = jj as u32;
            }
        }
        assert!(
            (bestv - sims[r]).abs() < 1e-4,
            "row {r}: kernel sim {} vs rust {}",
            sims[r],
            bestv
        );
        if ids[r] == best {
            agree += 1;
        }
    }
    println!(
        "kernel argmax agreement: {agree}/{BLOCK_B} rows; max-sim values match to 1e-4 ✓"
    );
    assert!(agree >= BLOCK_B - 1, "dense cross-check failed"); // ties may differ
    println!("three-layer composition verified: Rust → PJRT → (JAX model → Pallas kernel) ✓");
}
