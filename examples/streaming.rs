//! Streaming / mini-batch spherical k-means demo: consume a PubMed-like
//! corpus in sequential batches through `coordinator::minibatch`,
//! watching the running objective climb epoch over epoch, then compare
//! against full-batch Lloyd — including the driver's bit-exactness
//! contract in the degenerate configuration (`batch == n`, `decay == 0`).
//!
//! Run: `cargo run --release --example streaming`

use skm::algo::{run_clustering, AlgoKind, ClusterConfig, ParConfig};
use skm::coordinator::minibatch::{run_minibatch, BatchSchedule, MiniBatchConfig};
use skm::corpus::{generate, pubmed_like};
use skm::metrics::nmi;
use skm::sparse::build_dataset;

fn main() {
    // ~4100 documents with PubMed-like statistics, treated as a stream.
    let spec = pubmed_like(5e-4, 42);
    let corpus = generate(&spec);
    let ds = build_dataset(&corpus.name, corpus.n_terms, &corpus.docs);
    let k = (ds.n() / 100).max(8);
    let cfg = ClusterConfig {
        k,
        seed: 42,
        ..Default::default()
    };
    println!(
        "stream {}: N={} D={} K={k}",
        ds.name,
        ds.n(),
        ds.d()
    );

    // Full-batch Lloyd for reference.
    let full = run_clustering(AlgoKind::EsIcp, &ds, &cfg);

    // Streaming run: sequential windows, classic count decay.
    let batch = (ds.n() / 12).max(128);
    let rpe = (ds.n() + batch - 1) / batch;
    let mb = MiniBatchConfig {
        batch,
        schedule: BatchSchedule::Sequential,
        decay: 1.0,
        max_rounds: 30 * rpe,
        sample_seed: 7,
    };
    let out = run_minibatch(AlgoKind::EsIcp, &ds, &cfg, &mb, &ParConfig::serial());
    println!(
        "\nmini-batch ES-ICP: batch {batch} ({rpe} rounds/epoch), {} rounds, {}",
        out.n_rounds(),
        if out.converged {
            "quiet epoch reached"
        } else {
            "round cap reached"
        }
    );
    println!("epoch  objective (running)");
    for (e, chunk) in out.rounds.chunks(rpe).enumerate() {
        let last = chunk.last().unwrap();
        println!("{:>5}  {:.4}", e + 1, last.objective);
    }
    println!(
        "\nfull-batch J = {:.4}, streaming J = {:.4} ({:.2}% of Lloyd)",
        full.objective,
        out.objective,
        100.0 * out.objective / full.objective
    );
    println!(
        "agreement with the full-batch solution: NMI = {:.4}",
        nmi(&out.assign, &full.assign)
    );
    println!(
        "agreement with the planted topics:     NMI = {:.4}",
        nmi(&out.assign, &corpus.labels)
    );

    // The contract the test suite pins: batch == n with decay == 0 IS
    // full-batch Lloyd, bit for bit.
    let exact = run_minibatch(
        AlgoKind::EsIcp,
        &ds,
        &cfg,
        &MiniBatchConfig {
            batch: ds.n(),
            schedule: BatchSchedule::Sequential,
            decay: 0.0,
            max_rounds: cfg.max_iters,
            sample_seed: 7,
        },
        &ParConfig::serial(),
    );
    assert_eq!(exact.assign, full.assign, "degenerate mode must be Lloyd");
    assert_eq!(exact.objective.to_bits(), full.objective.to_bits());
    println!("\nbatch == n, decay == 0: bit-exact full-batch Lloyd — verified");
}
