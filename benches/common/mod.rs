//! Shared plumbing for the experiment benches (`cargo bench`): preset
//! resolution with env overrides, output directory handling, and the
//! standard header each harness prints.
//!
//! Environment knobs (all optional):
//!   SKM_SCALE   — multiply the preset's corpus size (default 1.0)
//!   SKM_SEED    — clustering seed (default 42)
//!   SKM_OUT     — output dir (default target/experiments)
//!   SKM_THREADS — sharded-engine worker threads (default 1 = serial)
//!   SKM_SHARD   — objects per shard (default 0 = one shard per thread)
//!
//! `SKM_THREADS`/`SKM_SHARD` flow into every harness through
//! `coordinator::run_and_summarize` (harnesses driving
//! `run_clustering_with` directly can use `ParConfig::from_env`); the
//! sharded engine is bit-identical to the serial path, so the knobs
//! change elapsed time only.

use skm::algo::ParConfig;
use skm::coordinator::{preset, Preset};
use skm::sparse::Dataset;
use skm::util::io::Table;
use std::path::PathBuf;

#[allow(dead_code)]
pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[allow(dead_code)]
pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[allow(dead_code)]
pub fn out_dir() -> PathBuf {
    std::env::var("SKM_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/experiments"))
}

/// Resolve a preset with the SKM_SCALE override applied.
pub fn bench_preset(name: &str) -> (Preset, Dataset, u64) {
    let scale = env_f64("SKM_SCALE", 1.0);
    let seed = env_u64("SKM_SEED", 42);
    let p = preset(name, 7, Some(scale)).unwrap_or_else(|| panic!("preset {name}"));
    let ds = p.dataset();
    (p, ds, seed)
}

pub fn header(exp: &str, what: &str, ds: &Dataset, k: usize) {
    println!("==================================================================");
    println!("{exp}: {what}");
    println!(
        "workload {}: N={} D={} avg-terms={:.1} K={k}",
        ds.name,
        ds.n(),
        ds.d(),
        ds.avg_terms()
    );
    let par = ParConfig::from_env();
    if par.is_parallel() {
        println!(
            "sharded engine: {} threads, shard size {} (bit-identical to serial)",
            par.threads,
            par.shard_size(ds.n())
        );
    }
    println!("==================================================================");
}

#[allow(dead_code)]
pub fn save(exp: &str, name: &str, t: &Table) {
    let path = out_dir().join(exp).join(format!("{name}.csv"));
    t.write_csv(&path).expect("write csv");
    println!("[saved {path:?}]");
}
