//! Experiment §VII-C — Table VI (and Appendix F Tables XVII–XVIII): the
//! main comparison on the NYT-like workload, demonstrating ES-ICP as a
//! *general* algorithm across corpora with different statistics
//! (longer documents, larger vocabulary, K ≈ N/128).
//!
//! Expected shape: same orderings as the PubMed tables; additionally the
//! paper observes that ES-ICP's assignment time can drop *below* its
//! update time on NYT (Table XVII).

mod common;

use common::{bench_preset, header, save};
use skm::algo::AlgoKind;
use skm::coordinator::compare::absolute_table;
use skm::coordinator::{comparison_rate_table, run_and_summarize};

fn main() {
    let (p, ds, seed) = bench_preset("nyt-like");
    let cfg = p.config(seed);
    header(
        "exp_main_nyt",
        "main comparison on NYT-like (Tables VI, XVII, XVIII)",
        &ds,
        cfg.k,
    );

    let suite = [
        AlgoKind::Mivi,
        AlgoKind::Icp,
        AlgoKind::CsIcp,
        AlgoKind::TaIcp,
        AlgoKind::EsIcp,
    ];
    let mut outs = Vec::new();
    let mut summaries = Vec::new();
    for kind in suite {
        eprintln!("running {} ...", kind.name());
        let (out, s) = run_and_summarize(kind, &ds, &cfg);
        outs.push(out);
        summaries.push(s);
    }
    for o in &outs[1..] {
        assert_eq!(o.assign, outs[0].assign, "{:?} diverged from MIVI", o.algo);
    }

    println!("\n[Table XVII analog] absolute values:");
    println!("{}", absolute_table(&summaries).render());
    println!("[Table VI analog] rates relative to ES-ICP:");
    let rates = comparison_rate_table(&summaries, "ES-ICP");
    println!("{}", rates.render());
    save("exp_main_nyt", "table6_rates", &rates);

    let (mivi, icp, cs, ta, es) = (
        &summaries[0],
        &summaries[1],
        &summaries[2],
        &summaries[3],
        &summaries[4],
    );
    let ok = |b: bool| if b { "OK" } else { "MISMATCH" };
    println!("shape checks (paper Table VI):");
    println!(
        "  ES-ICP fastest on the assignment step: {} (MIVI {:.1}x, ICP {:.1}x, CS {:.1}x, TA {:.1}x)",
        ok(es.avg_assign_secs
            < mivi
                .avg_assign_secs
                .min(icp.avg_assign_secs)
                .min(cs.avg_assign_secs)
                .min(ta.avg_assign_secs)),
        mivi.avg_assign_secs / es.avg_assign_secs,
        icp.avg_assign_secs / es.avg_assign_secs,
        cs.avg_assign_secs / es.avg_assign_secs,
        ta.avg_assign_secs / es.avg_assign_secs
    );
    let best_other = mivi.avg_secs.min(icp.avg_secs).min(cs.avg_secs).min(ta.avg_secs);
    println!(
        "  ES-ICP overall: {:.2}x the best comparator ({:.1}x faster than MIVI) — at K=80 the          estimation+index overhead is not amortized; the paper's margin needs K=10 000          (EXPERIMENTS.md n.3). informational: {}",
        es.avg_secs / best_other,
        mivi.avg_secs / es.avg_secs,
        if es.avg_secs < best_other * 1.5 { "within 1.5x band OK" } else { "MISMATCH" }
    );
    println!(
        "  CS-ICP lowest-or-tied Mult: {} ({:.3}x of ES)",
        ok(cs.avg_mult < es.avg_mult * 1.1),
        cs.avg_mult / es.avg_mult
    );
    println!(
        "  TA-ICP worst branch proxy: {}",
        ok(ta.sw_irregular_branches > es.sw_irregular_branches.max(icp.sw_irregular_branches))
    );
    println!(
        "  ES-ICP assign vs update per iter: {:.3}s vs {:.3}s (paper NYT: assign < update)",
        es.avg_assign_secs, es.avg_update_secs
    );
}
