//! Hot-path microbenchmarks — the §Perf instrumentation (not a paper
//! experiment). Times the building blocks of the assignment step in
//! isolation so optimization work can attribute gains:
//!
//!   * plain TAAT accumulation over the mean-inverted index (MIVI core)
//!   * ES gathering (Region 1+2, two-block arrays) + filter + verify
//!   * mean-set construction (update step)
//!   * EsIndex / InvIndex build
//!   * EstParams sweep

mod common;

use common::{bench_preset, header};
use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::estparams::{estimate, EstConfig};
use skm::index::{update_means, EsIndex, InvIndex, ObjInvIndex};
use skm::util::timer::bench;

fn main() {
    let (p, ds, seed) = bench_preset("pubmed-like");
    let cfg = p.config(seed);
    header("hot_path", "assignment-step microbenchmarks (§Perf)", &ds, cfg.k);
    let k = cfg.k;

    // Converged state for realistic index shapes.
    let warm = ClusterConfig {
        max_iters: 4,
        ..cfg.clone()
    };
    let out = run_clustering(AlgoKind::Mivi, &ds, &warm);
    let upd = update_means(&ds, &out.assign, k, None, None);

    // --- index builds ---------------------------------------------------
    let s = bench(1, 10, 2.0, || {
        let idx = InvIndex::build(&upd.means, ds.d());
        std::hint::black_box(idx.nnz());
    });
    println!("{}", s.summary("InvIndex::build (full)"));

    let t_th = ds.d() * 8 / 10;
    let s = bench(1, 10, 2.0, || {
        let idx = EsIndex::build(&upd.means, t_th, 0.02);
        std::hint::black_box(idx.mem_bytes());
    });
    println!("{}", s.summary("EsIndex::build (t_th=0.8D)"));

    // --- update step ------------------------------------------------------
    let changed = vec![true; k];
    let s = bench(1, 10, 3.0, || {
        let u = update_means(&ds, &out.assign, k, Some(&upd.means), Some(&changed));
        std::hint::black_box(u.objective);
    });
    println!("{}", s.summary("update_means (all clusters moving)"));
    let unchanged = vec![false; k];
    let s = bench(1, 10, 3.0, || {
        let u = update_means(&ds, &out.assign, k, Some(&upd.means), Some(&unchanged));
        std::hint::black_box(u.objective);
    });
    println!("{}", s.summary("update_means (all clusters invariant)"));

    // --- TAAT accumulation core (MIVI inner loops) -----------------------
    let idx = InvIndex::build(&upd.means, ds.d());
    let mut rho = vec![0.0f64; k];
    let s = bench(1, 5, 3.0, || {
        let mut acc = 0.0f64;
        for i in 0..ds.n().min(2000) {
            let (ts, vs) = ds.x.row(i);
            rho.iter_mut().for_each(|r| *r = 0.0);
            for (&t, &u) in ts.iter().zip(vs) {
                let (ids, vals) = idx.postings(t as usize);
                for (&c, &v) in ids.iter().zip(vals) {
                    rho[c as usize] += u * v;
                }
            }
            acc += rho[0];
        }
        std::hint::black_box(acc);
    });
    println!("{}", s.summary("TAAT accumulate (2000 objects)"));

    // --- ES gathering + verification -------------------------------------
    let es_idx = EsIndex::build(&upd.means, t_th, 0.02);
    let s = bench(1, 5, 3.0, || {
        let mut acc = 0usize;
        for i in 0..ds.n().min(2000) {
            let (ts, vs) = ds.x.row(i);
            let p0 = ts.partition_point(|&t| (t as usize) < t_th);
            let mut y_base = 0.0;
            for &u in &vs[p0..] {
                y_base += u * 0.02;
            }
            // Folded accumulator: rho[j] is the upper bound directly.
            rho.iter_mut().for_each(|r| *r = y_base);
            for (&t, &u) in ts[..p0].iter().zip(&vs[..p0]) {
                let (ids, vals) = es_idx.r1.postings(t as usize);
                let us = u * 0.02;
                for (&c, &v) in ids.iter().zip(vals) {
                    rho[c as usize] += us * v;
                }
            }
            for (&t, &u) in ts[p0..].iter().zip(&vs[p0..]) {
                let (ids, vals) = es_idx.r2.postings(t as usize);
                let us = u * 0.02;
                for (&c, &v) in ids.iter().zip(vals) {
                    rho[c as usize] += us * v;
                }
            }
            let rho_max = upd.rho[i];
            let mut z = 0usize;
            for &r in rho.iter() {
                if r > rho_max {
                    z += 1;
                }
            }
            acc += z;
        }
        std::hint::black_box(acc);
    });
    println!("{}", s.summary("ES gather+filter (2000 objects)"));

    // --- EstParams --------------------------------------------------------
    let s_min = ds.d() * 8 / 10;
    let xp = ObjInvIndex::build(&ds.x, s_min);
    let s = bench(0, 3, 10.0, || {
        let est = estimate(
            &ds,
            &upd.means,
            &upd.rho,
            &xp,
            &EstConfig {
                s_min,
                n_candidates: 21,
                ..Default::default()
            },
        );
        std::hint::black_box(est.t_th);
    });
    println!("{}", s.summary("EstParams (21 candidates)"));
}
