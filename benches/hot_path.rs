//! Hot-path microbenchmarks — the §Perf instrumentation (not a paper
//! experiment). Times the building blocks of the assignment/update loop
//! in isolation so optimization work can attribute gains:
//!
//!   * plain TAAT accumulation over the mean-inverted index (MIVI core)
//!   * **the gather micro-kernel** (`algo::kernel`): naive scalar
//!     scatter-add vs the unrolled/unchecked/dense-tail kernel, with
//!     ns/posting and effective GB/s, bitwise-verified first
//!   * **the SIMD dispatch sweep**: the same gather forced onto every
//!     backend the host supports (scalar/AVX2/AVX-512/NEON), each
//!     bitwise-verified against the scalar oracle, with per-ISA
//!     ns/posting, GB/s, and the reported (not gated) speedup
//!   * ES gathering (Region 1+2, two-block arrays) + filter + verify
//!   * mean-set construction (update step)
//!   * EsIndex / InvIndex from-scratch builds
//!   * **incremental splice vs from-scratch rebuild** at late
//!     iterations (moving fraction < 30%) for all four structured
//!     index kinds, with a bitwise equality check
//!   * the ES-ICP phase-level breakdown (gather / verify / update /
//!     rebuild)
//!   * **the mini-batch update floor**: the in-place splice update plus
//!     the incremental maintainer per round at batch sizes
//!     {n/64, n/8, n}, bitwise parity-checked against the from-scratch
//!     oracle before timing (the small/full cost ratio is reported by
//!     bench-smoke, not gated)
//!   * EstParams sweep
//!
//! Emits a machine-readable baseline to `$SKM_BENCH_JSON` (default
//! `BENCH_hot_path.json`). No baseline JSON is committed — CI's
//! bench-smoke job regenerates it every run, validates the schema and
//! the hard correctness/speedup gates, and uploads it as an artifact;
//! real reference numbers come from those artifacts, never from a
//! hand-authored file.

mod common;

use common::{bench_preset, header};
use skm::algo::kernel;
use skm::algo::{
    make_assigner, run_clustering, seed_means, AlgoKind, Assigner, ClusterConfig, IterState,
    ParConfig,
};
use skm::coordinator::minibatch::{run_minibatch, BatchSchedule, MiniBatchConfig};
use skm::estparams::{estimate, EstConfig};
use skm::index::{
    membership_changes, update_means, update_means_minibatch, update_means_minibatch_inplace,
    update_means_with_rho, CsIndex, CsMaintainer, EsIndex, EsMaintainer, InvIndex, InvMaintainer,
    MbUpdateScratch, MeanSet, ObjInvIndex, TaIndex, TaMaintainer,
};
use skm::sparse::Dataset;
use skm::util::json::Json;
use skm::util::timer::{bench, BenchStats};
use std::time::Instant;

/// Drive a plain MIVI Lloyd loop, collecting the mean set after every
/// update step (the realistic moved-flag trajectory the incremental
/// maintainers see in production).
fn mivi_trajectory(ds: &Dataset, cfg: &ClusterConfig, max_iters: usize) -> Vec<MeanSet> {
    let n = ds.n();
    let mut st = IterState {
        k: cfg.k,
        assign: vec![0; n],
        rho: vec![-1.0; n],
        xstate: vec![false; n],
        means: seed_means(ds, cfg.k, cfg.seed),
        iter: 1,
    };
    let mut assigner = make_assigner(AlgoKind::Mivi, ds, cfg);
    assigner.rebuild(ds, &st, cfg);
    let mut seq = vec![st.means.clone()];
    for r in 1..=max_iters {
        st.iter = r;
        let prev = st.assign.clone();
        let (_, changes) = assigner.assign(ds, &mut st);
        if changes == 0 && r > 1 {
            break;
        }
        let changed = membership_changes(&prev, &st.assign, cfg.k);
        let upd = update_means_with_rho(
            ds,
            &st.assign,
            cfg.k,
            Some(&st.means),
            Some(&changed),
            Some(&st.rho),
        );
        st.means = upd.means;
        st.rho = upd.rho;
        st.iter = r + 1;
        assigner.rebuild(ds, &st, cfg);
        seq.push(st.means.clone());
    }
    seq
}

/// The late-iteration window: starting one mean set before the first
/// iteration whose moving fraction drops under `frac` (the maintainer
/// needs a predecessor to prime on), through the end of the run.
fn late_window(seq: &[MeanSet], frac: f64) -> &[MeanSet] {
    let k = seq[0].k().max(1) as f64;
    let start = seq
        .iter()
        .position(|m| (m.n_moving() as f64) / k < frac)
        .unwrap_or(seq.len().saturating_sub(6).max(1))
        .max(1);
    &seq[start - 1..]
}

fn best_of<F: FnMut() -> f64>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        best = best.min(f());
    }
    best
}

/// Shared measurement protocol for one index kind: best-of-`reps`
/// from-scratch passes over the window vs best-of-`reps` incremental
/// passes where each rep gets a fresh maintainer, primed (untimed) on
/// the window's first mean set. Keeping the protocol in one place keeps
/// all four index kinds' numbers comparable by construction.
fn time_rebuild_cmp(
    name: &'static str,
    reps: usize,
    window: &[MeanSet],
    scratch_build: impl Fn(&MeanSet),
    mut make_updater: impl FnMut() -> Box<dyn FnMut(&MeanSet)>,
) -> RebuildCmp {
    let steps = (window.len() - 1).max(1) as f64;
    let scratch = best_of(reps, || {
        let t0 = Instant::now();
        for m in &window[1..] {
            scratch_build(m);
        }
        t0.elapsed().as_secs_f64()
    });
    let incremental = best_of(reps, || {
        let mut update = make_updater();
        update(&window[0]); // prime: the first build is always full
        let t0 = Instant::now();
        for m in &window[1..] {
            update(m);
        }
        t0.elapsed().as_secs_f64()
    });
    RebuildCmp {
        name,
        scratch_ms_per_iter: scratch * 1e3 / steps,
        incremental_ms_per_iter: incremental * 1e3 / steps,
    }
}

fn assert_bits_eq(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (q, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: value {q}");
    }
}

fn assert_inv_eq(a: &InvIndex, b: &InvIndex, tag: &str) {
    let (ao, ai, av, am) = a.raw_parts();
    let (bo, bi, bv, bm) = b.raw_parts();
    assert_eq!(ao, bo, "{tag}: offsets");
    assert_eq!(ai, bi, "{tag}: ids");
    assert_eq!(am, bm, "{tag}: mfm");
    assert_bits_eq(av, bv, tag);
    assert_eq!(a.moving_ids, b.moving_ids, "{tag}: moving_ids");
}

/// Per-index-kind scratch-vs-incremental comparison over the window.
struct RebuildCmp {
    name: &'static str,
    scratch_ms_per_iter: f64,
    incremental_ms_per_iter: f64,
}

impl RebuildCmp {
    fn json(&self) -> (&str, Json) {
        (
            self.name,
            Json::obj(vec![
                ("scratch_ms_per_iter", Json::Num(self.scratch_ms_per_iter)),
                (
                    "incremental_ms_per_iter",
                    Json::Num(self.incremental_ms_per_iter),
                ),
                (
                    "speedup",
                    Json::Num(self.scratch_ms_per_iter / self.incremental_ms_per_iter.max(1e-12)),
                ),
            ]),
        )
    }

    fn print(&self) {
        println!(
            "rebuild {}: scratch {:.3} ms/iter  incremental {:.3} ms/iter  ({:.2}x)",
            self.name,
            self.scratch_ms_per_iter,
            self.incremental_ms_per_iter,
            self.scratch_ms_per_iter / self.incremental_ms_per_iter.max(1e-12)
        );
    }
}

fn main() {
    let (p, ds, seed) = bench_preset("nyt-like");
    let cfg = p.config(seed);
    header(
        "hot_path",
        "assignment/update hot-path microbenchmarks (§Perf)",
        &ds,
        cfg.k,
    );
    let k = cfg.k;
    let reps = 3usize;
    let mut micro: Vec<(String, BenchStats)> = Vec::new();

    // Converged-ish state for realistic index shapes.
    let warm = ClusterConfig {
        max_iters: 4,
        ..cfg.clone()
    };
    let out = run_clustering(AlgoKind::Mivi, &ds, &warm);
    let upd = update_means(&ds, &out.assign, k, None, None);

    // --- index builds (from scratch) -------------------------------------
    let s = bench(1, 10, 2.0, || {
        let idx = InvIndex::build(&upd.means, ds.d());
        std::hint::black_box(idx.nnz());
    });
    println!("{}", s.summary("InvIndex::build (full)"));
    micro.push(("invindex_build_full".into(), s));

    let t_th = ds.d() * 8 / 10;
    let s = bench(1, 10, 2.0, || {
        let idx = EsIndex::build(&upd.means, t_th, 0.02);
        std::hint::black_box(idx.mem_bytes());
    });
    println!("{}", s.summary("EsIndex::build (t_th=0.8D)"));
    micro.push(("esindex_build".into(), s));

    // --- update step ------------------------------------------------------
    let changed = vec![true; k];
    let s = bench(1, 10, 3.0, || {
        let u = update_means(&ds, &out.assign, k, Some(&upd.means), Some(&changed));
        std::hint::black_box(u.objective);
    });
    println!("{}", s.summary("update_means (all clusters moving)"));
    micro.push(("update_means_all_moving".into(), s));
    let unchanged = vec![false; k];
    let s = bench(1, 10, 3.0, || {
        let u = update_means(&ds, &out.assign, k, Some(&upd.means), Some(&unchanged));
        std::hint::black_box(u.objective);
    });
    println!("{}", s.summary("update_means (all clusters invariant)"));
    micro.push(("update_means_all_invariant".into(), s));

    // --- TAAT accumulation core (MIVI inner loops) -----------------------
    let idx = InvIndex::build(&upd.means, ds.d());
    let mut rho = vec![0.0f64; k];
    let s = bench(1, 5, 3.0, || {
        let mut acc = 0.0f64;
        for i in 0..ds.n().min(2000) {
            let (ts, vs) = ds.x.row(i);
            rho.iter_mut().for_each(|r| *r = 0.0);
            for (&t, &u) in ts.iter().zip(vs) {
                let (ids, vals) = idx.postings(t as usize);
                for (&c, &v) in ids.iter().zip(vals) {
                    rho[c as usize] += u * v;
                }
            }
            acc += rho[0];
        }
        std::hint::black_box(acc);
    });
    println!("{}", s.summary("TAAT accumulate (2000 objects)"));
    micro.push(("taat_accumulate_2000".into(), s));

    // --- gather micro-kernel: scalar baseline vs kernel routing ----------
    // Same index and object window as the TAAT section. The scalar pass
    // is the pre-kernel inner loop verbatim (bounds-checked indexing);
    // the kernel pass times the REAL production dispatch
    // (`InvIndex::gather_term`: unrolled unchecked scatter-add plus the
    // dense Region-1 tail rows) — not a copy of it. Bitwise equality is
    // asserted before anything is timed.
    let n_obj = ds.n().min(2000);
    let mut postings_total = 0u64;
    let mut dense_postings = 0u64;
    for i in 0..n_obj {
        let (ts, _) = ds.x.row(i);
        for &t in ts {
            let t = t as usize;
            let m = idx.mf(t) as u64;
            postings_total += m;
            if idx.dense_row(t).is_some() {
                dense_postings += m;
            }
        }
    }
    let (dense_lo, _) = idx.dense_parts();
    {
        let mut a = vec![0.0f64; k];
        let mut b = vec![0.0f64; k];
        for i in 0..n_obj {
            let (ts, vs) = ds.x.row(i);
            a.iter_mut().for_each(|r| *r = 0.0);
            b.iter_mut().for_each(|r| *r = 0.0);
            for (&t, &u) in ts.iter().zip(vs) {
                let (ids, vals) = idx.postings(t as usize);
                kernel::scatter_add_scalar(&mut a, ids, vals, u);
            }
            for (&t, &u) in ts.iter().zip(vs) {
                idx.gather_term(t as usize, u, &mut b, false);
            }
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "gather kernel diverged at object {i}");
            }
        }
    }
    let mut rho_g = vec![0.0f64; k];
    let scalar = bench(1, 7, 3.0, || {
        let mut acc = 0.0f64;
        for i in 0..n_obj {
            let (ts, vs) = ds.x.row(i);
            rho_g.iter_mut().for_each(|r| *r = 0.0);
            for (&t, &u) in ts.iter().zip(vs) {
                let (ids, vals) = idx.postings(t as usize);
                kernel::scatter_add_scalar(&mut rho_g, ids, vals, u);
            }
            acc += rho_g[0];
        }
        std::hint::black_box(acc);
    });
    println!("{}", scalar.summary("gather scalar baseline (2000 objects)"));
    let tuned = bench(1, 7, 3.0, || {
        let mut acc = 0.0f64;
        for i in 0..n_obj {
            let (ts, vs) = ds.x.row(i);
            rho_g.iter_mut().for_each(|r| *r = 0.0);
            for (&t, &u) in ts.iter().zip(vs) {
                idx.gather_term(t as usize, u, &mut rho_g, false);
            }
            acc += rho_g[0];
        }
        std::hint::black_box(acc);
    });
    println!("{}", tuned.summary("gather kernel (2000 objects)"));
    // Effective traffic per posting: 4 B id + 8 B value streamed, plus
    // one 8 B accumulator store (loads mostly hit cache; this is the
    // conventional streamed-bytes accounting, stated so the number is
    // comparable run to run, not an absolute bandwidth claim).
    const BYTES_PER_POSTING: f64 = 20.0;
    let pp = postings_total.max(1) as f64;
    let scalar_ns = scalar.min_s * 1e9 / pp;
    let kernel_ns = tuned.min_s * 1e9 / pp;
    let gather_speedup = scalar.min_s / tuned.min_s.max(1e-12);
    println!(
        "gather kernel: {:.3} -> {:.3} ns/posting ({:.2}x), {:.2} -> {:.2} GB/s effective, dense share {:.1}% ({} dense terms)",
        scalar_ns,
        kernel_ns,
        gather_speedup,
        BYTES_PER_POSTING / scalar_ns.max(1e-12),
        BYTES_PER_POSTING / kernel_ns.max(1e-12),
        100.0 * dense_postings as f64 / pp,
        ds.d() - dense_lo
    );
    micro.push(("gather_scalar_2000".into(), scalar.clone()));
    micro.push(("gather_kernel_2000".into(), tuned.clone()));

    // --- SIMD backend sweep: the dispatched gather per detected ISA ------
    // Same workload as the gather section, with the kernel dispatch
    // table forced to each backend this host supports (scalar always
    // included, so the sweep runs even on bare hosts). Bitwise equality
    // against the scalar oracle is asserted per backend before anything
    // is timed; the scalar-vs-SIMD ratio is *reported*, never gated —
    // CI hosts differ too much for a speedup threshold.
    let auto_backend = kernel::Backend::detect();
    println!(
        "simd dispatch: auto-detected backend {} (available: {})",
        auto_backend.name(),
        kernel::Backend::available()
            .iter()
            .map(|b| b.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    let mut simd_rows: Vec<(String, Json)> = Vec::new();
    {
        let mut scalar_forced_ns = None;
        for b in kernel::Backend::available() {
            kernel::force_backend(b).expect("available backend must force");
            // Bit-equality vs the scalar oracle over the full window.
            let mut a = vec![0.0f64; k];
            let mut bb = vec![0.0f64; k];
            for i in 0..n_obj {
                let (ts, vs) = ds.x.row(i);
                a.iter_mut().for_each(|r| *r = 0.0);
                bb.iter_mut().for_each(|r| *r = 0.0);
                for (&t, &u) in ts.iter().zip(vs) {
                    let (ids, vals) = idx.postings(t as usize);
                    kernel::scatter_add_scalar(&mut a, ids, vals, u);
                }
                for (&t, &u) in ts.iter().zip(vs) {
                    idx.gather_term(t as usize, u, &mut bb, false);
                }
                for (x, y) in a.iter().zip(&bb) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{} gather diverged from scalar at object {i}",
                        b.name()
                    );
                }
            }
            let s = bench(1, 7, 3.0, || {
                let mut acc = 0.0f64;
                for i in 0..n_obj {
                    let (ts, vs) = ds.x.row(i);
                    rho_g.iter_mut().for_each(|r| *r = 0.0);
                    for (&t, &u) in ts.iter().zip(vs) {
                        idx.gather_term(t as usize, u, &mut rho_g, false);
                    }
                    acc += rho_g[0];
                }
                std::hint::black_box(acc);
            });
            let ns = s.min_s * 1e9 / pp;
            let base = *scalar_forced_ns.get_or_insert(ns);
            println!(
                "{}",
                s.summary(&format!("gather dispatched [{}] (2000 objects)", b.name()))
            );
            println!(
                "simd [{}]: {:.3} ns/posting, {:.2} GB/s effective, {:.2}x vs forced scalar",
                b.name(),
                ns,
                BYTES_PER_POSTING / ns.max(1e-12),
                base / ns.max(1e-12)
            );
            simd_rows.push((
                b.name().to_string(),
                Json::obj(vec![
                    ("ns_per_posting", Json::Num(ns)),
                    ("gbps", Json::Num(BYTES_PER_POSTING / ns.max(1e-12))),
                    ("speedup_vs_scalar", Json::Num(base / ns.max(1e-12))),
                ]),
            ));
            micro.push((format!("gather_{}_2000", b.name()), s));
        }
        kernel::reset_backend();
    }

    // --- incremental splice vs from-scratch rebuild ----------------------
    // Realistic late-iteration trajectory: few centroids move, which is
    // exactly the regime the incremental maintainers target.
    let seq = mivi_trajectory(&ds, &cfg, 40);
    let window = late_window(&seq, 0.30);
    let steps = (window.len() - 1).max(1) as f64;
    let kf = window[0].k() as f64;
    let moving_frac: f64 = window[1..]
        .iter()
        .map(|m| m.n_moving() as f64 / kf)
        .sum::<f64>()
        / steps;
    let dirty_frac: f64 = window
        .windows(2)
        .map(|w| w[1].dirty_against(&w[0].moved) as f64 / kf)
        .sum::<f64>()
        / steps;
    println!(
        "late window: {} transitions, avg moving fraction {:.3}, avg dirty fraction {:.3}",
        window.len() - 1,
        moving_frac,
        dirty_frac
    );
    let d = ds.d();
    let (v_th, ta_t) = (0.02f64, (d as f64 * 0.9) as usize);

    let cmps: Vec<RebuildCmp> = vec![
        time_rebuild_cmp(
            "inv",
            reps,
            window,
            |m| {
                std::hint::black_box(InvIndex::build(m, d).nnz());
            },
            || {
                let mut maint = InvMaintainer::new();
                maint.max_dirty_frac = 1.0;
                Box::new(move |m: &MeanSet| {
                    std::hint::black_box(maint.update(m, d, 1.0).nnz());
                })
            },
        ),
        time_rebuild_cmp(
            "es",
            reps,
            window,
            |m| {
                std::hint::black_box(EsIndex::build(m, t_th, v_th).mem_bytes());
            },
            || {
                let mut maint = EsMaintainer::new();
                maint.max_dirty_frac = 1.0;
                Box::new(move |m: &MeanSet| {
                    std::hint::black_box(maint.update(m, t_th, v_th).mem_bytes());
                })
            },
        ),
        time_rebuild_cmp(
            "ta",
            reps,
            window,
            |m| {
                std::hint::black_box(TaIndex::build(m, ta_t).mem_bytes());
            },
            || {
                let mut maint = TaMaintainer::new();
                maint.max_dirty_frac = 1.0;
                Box::new(move |m: &MeanSet| {
                    std::hint::black_box(maint.update(m, ta_t).mem_bytes());
                })
            },
        ),
        time_rebuild_cmp(
            "cs",
            reps,
            window,
            |m| {
                std::hint::black_box(CsIndex::build(m, ta_t).mem_bytes());
            },
            || {
                let mut maint = CsMaintainer::new();
                maint.max_dirty_frac = 1.0;
                Box::new(move |m: &MeanSet| {
                    std::hint::black_box(maint.update(m, ta_t).mem_bytes());
                })
            },
        ),
    ];
    for c in &cmps {
        c.print();
    }

    // Bitwise equality of the final spliced index vs a scratch build —
    // the per-kind assertions differ because the region structures do.
    {
        let mut maint = InvMaintainer::new();
        maint.max_dirty_frac = 1.0;
        for m in window {
            maint.update(m, d, 1.0);
        }
        assert!(maint.incremental_rebuilds > 0);
        assert_inv_eq(
            maint.index().unwrap(),
            &InvIndex::build(window.last().unwrap(), d),
            "inv splice",
        );
    }
    {
        let mut maint = EsMaintainer::new();
        maint.max_dirty_frac = 1.0;
        for m in window {
            maint.update(m, t_th, v_th);
        }
        let got = maint.index().unwrap();
        let want = EsIndex::build(window.last().unwrap(), t_th, v_th);
        assert_inv_eq(&got.r1, &want.r1, "es splice r1");
        assert_eq!(got.r2.raw_parts().0, want.r2.raw_parts().0, "es r2 offsets");
        assert_eq!(got.r2.raw_parts().1, want.r2.raw_parts().1, "es r2 ids");
        assert_eq!(got.r2.raw_parts().3, want.r2.raw_parts().3, "es r2 mfm");
        assert_bits_eq(got.r2.raw_parts().2, want.r2.raw_parts().2, "es r2 vals");
        assert_bits_eq(got.partial.values(), want.partial.values(), "es partial");
    }
    {
        let mut maint = TaMaintainer::new();
        maint.max_dirty_frac = 1.0;
        for m in window {
            maint.update(m, ta_t);
        }
        let got = maint.index().unwrap();
        let want = TaIndex::build(window.last().unwrap(), ta_t);
        assert_inv_eq(&got.r1, &want.r1, "ta splice r1");
        assert_eq!(got.r2_all.raw_parts().0, want.r2_all.raw_parts().0);
        assert_eq!(got.r2_all.raw_parts().1, want.r2_all.raw_parts().1);
        assert_bits_eq(got.r2_all.raw_parts().2, want.r2_all.raw_parts().2, "ta all");
        assert_eq!(got.r2_moving.raw_parts().0, want.r2_moving.raw_parts().0);
        assert_eq!(got.r2_moving.raw_parts().1, want.r2_moving.raw_parts().1);
        assert_bits_eq(
            got.r2_moving.raw_parts().2,
            want.r2_moving.raw_parts().2,
            "ta moving",
        );
        assert_bits_eq(got.partial.values(), want.partial.values(), "ta partial");
    }
    {
        let mut maint = CsMaintainer::new();
        maint.max_dirty_frac = 1.0;
        for m in window {
            maint.update(m, ta_t);
        }
        let got = maint.index().unwrap();
        let want = CsIndex::build(window.last().unwrap(), ta_t);
        assert_inv_eq(&got.r1, &want.r1, "cs splice r1");
        assert_eq!(got.r2_sq.raw_parts().0, want.r2_sq.raw_parts().0);
        assert_eq!(got.r2_sq.raw_parts().1, want.r2_sq.raw_parts().1);
        assert_eq!(got.r2_sq.raw_parts().3, want.r2_sq.raw_parts().3);
        assert_bits_eq(got.r2_sq.raw_parts().2, want.r2_sq.raw_parts().2, "cs sq");
        assert_bits_eq(got.partial.values(), want.partial.values(), "cs partial");
    }

    // --- ES-ICP phase breakdown (full run) -------------------------------
    let es_out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    println!(
        "ES-ICP phases over {} iters: assign {:.3}s (gather {:.3}s / verify {:.3}s), update {:.3}s, rebuild {:.3}s",
        es_out.iterations(),
        es_out.total_assign_secs(),
        es_out.total_gather_secs(),
        es_out.total_verify_secs(),
        es_out.total_update_secs() - es_out.total_rebuild_secs(),
        es_out.total_rebuild_secs()
    );

    // --- mini-batch / streaming driver ------------------------------------
    // One ES-ICP streaming run (sequential batches, classic count decay)
    // against the full-batch run above: per-round phase costs, rounds to
    // the quiet-epoch exit, and the achieved objective relative to Lloyd.
    let mb_batch = (ds.n() / 8).max(256).min(ds.n());
    let mb_rpe = (ds.n() + mb_batch - 1) / mb_batch;
    let mb_cfg = MiniBatchConfig {
        batch: mb_batch,
        schedule: BatchSchedule::Sequential,
        decay: 1.0,
        max_rounds: 24 * mb_rpe,
        sample_seed: seed,
    };
    let mb_t0 = Instant::now();
    let mb_out = run_minibatch(AlgoKind::EsIcp, &ds, &cfg, &mb_cfg, &ParConfig::serial());
    let mb_wall = mb_t0.elapsed().as_secs_f64();
    let mb_rounds = mb_out.n_rounds().max(1) as f64;
    let mb_obj_ratio = mb_out.objective / es_out.objective;
    println!(
        "minibatch ES-ICP: batch {} ({} rounds, {} epochs-equivalent), {:.3} ms/round \
         [assign {:.3} / update {:.3} / rebuild {:.3}], objective ratio vs full batch {:.4}",
        mb_batch,
        mb_out.n_rounds(),
        mb_out.objects_processed() / ds.n().max(1),
        mb_wall * 1e3 / mb_rounds,
        mb_out.total_assign_secs() * 1e3 / mb_rounds,
        (mb_out.total_update_secs() - mb_out.total_rebuild_secs()) * 1e3 / mb_rounds,
        mb_out.total_rebuild_secs() * 1e3 / mb_rounds,
        mb_obj_ratio
    );

    // --- mini-batch update floor ------------------------------------------
    // Direct per-round cost of the in-place splice update plus the
    // incremental maintainer at batch sizes {n/64, n/8, n}. The claim
    // under test is the cost model: a round costs O(batch + nnz of
    // touched rows), so shrinking the batch must shrink the update cost
    // instead of being swamped by an O(n) ρ copy or an O(nnz(M))
    // rebuild. Bitwise parity of the in-place path against the
    // from-scratch oracle is hard-asserted at every size before
    // anything is timed; bench-smoke *reports* (never gates) the
    // small/full-batch cost ratio.
    let floor_sizes = [
        (ds.n() / 64).max(64).min(ds.n()),
        (ds.n() / 8).max(64).min(ds.n()),
        ds.n(),
    ];
    let floor_decay = 1.0f64;
    let floor_changed = vec![true; k];
    let mut floor_sizes_counts = vec![0u32; k];
    for &a in &out.assign {
        floor_sizes_counts[a as usize] += 1;
    }
    let wrap_runs = |cursor: &mut usize, b: usize, runs: &mut Vec<(usize, usize)>| {
        runs.clear();
        let lo = *cursor;
        let n = ds.n();
        if lo + b <= n {
            runs.push((lo, lo + b));
            *cursor = if lo + b == n { 0 } else { lo + b };
        } else {
            let rem = lo + b - n;
            runs.push((0, rem));
            runs.push((lo, n));
            *cursor = rem;
        }
    };
    let mut floor_rows: Vec<Json> = Vec::new();
    for &bsz in &floor_sizes {
        let rpe = (ds.n() + bsz - 1) / bsz;
        let mut runs: Vec<(usize, usize)> = Vec::with_capacity(2);

        // Parity: one epoch of rounds (capped at 8) where the spliced
        // state must bit-match the oracle's from-scratch rebuild.
        {
            let mut i_means = upd.means.clone();
            let mut i_rho = upd.rho.clone();
            let mut i_counts = vec![0.0f64; k];
            let mut o_means = upd.means.clone();
            let mut o_rho = upd.rho.clone();
            let mut o_counts = vec![0.0f64; k];
            let mut scratch = MbUpdateScratch::new();
            let mut cursor = 0usize;
            for round in 0..rpe.min(8) {
                wrap_runs(&mut cursor, bsz, &mut runs);
                let o = update_means_minibatch(
                    &ds, &out.assign, &runs, k, &o_means, &floor_changed, &o_rho,
                    &floor_sizes_counts, &mut o_counts, floor_decay,
                );
                o_means = o.means;
                o_rho = o.rho;
                let _ = update_means_minibatch_inplace(
                    &ds, &out.assign, &runs, &mut i_means, &mut i_rho, &floor_changed,
                    &floor_sizes_counts, &mut i_counts, floor_decay, &mut scratch,
                    &ParConfig::serial(),
                );
                let tag = format!("mb floor parity batch={bsz} round={round}");
                assert_eq!(i_means.moved, o_means.moved, "{tag}: moved");
                for j in 0..k {
                    let (ai, av) = i_means.m.row(j);
                    let (bi, bv) = o_means.m.row(j);
                    assert_eq!(ai, bi, "{tag}: row {j} ids");
                    assert_bits_eq(av, bv, &format!("{tag}: row {j}"));
                }
                assert_bits_eq(&i_rho, &o_rho, &format!("{tag}: rho"));
                assert_bits_eq(&i_counts, &o_counts, &format!("{tag}: counts"));
            }
        }

        // Timing: prime one epoch (scratch/slab/maintainer plateau),
        // then best-of-reps over one epoch of update + maintain.
        let mut f_means = upd.means.clone();
        let mut f_rho = upd.rho.clone();
        let mut f_counts = vec![0.0f64; k];
        let mut scratch = MbUpdateScratch::new();
        let mut maint = InvMaintainer::new();
        maint.max_dirty_frac = 1.0;
        let mut cursor = 0usize;
        for _ in 0..rpe {
            wrap_runs(&mut cursor, bsz, &mut runs);
            let _ = update_means_minibatch_inplace(
                &ds, &out.assign, &runs, &mut f_means, &mut f_rho, &floor_changed,
                &floor_sizes_counts, &mut f_counts, floor_decay, &mut scratch,
                &ParConfig::serial(),
            );
            std::hint::black_box(maint.update(&f_means, d, 1.0).nnz());
        }
        let mut upd_s = f64::INFINITY;
        let mut mnt_s = f64::INFINITY;
        for _ in 0..reps {
            let mut u_acc = 0.0f64;
            let mut m_acc = 0.0f64;
            for _ in 0..rpe {
                wrap_runs(&mut cursor, bsz, &mut runs);
                let t0 = Instant::now();
                let delta = update_means_minibatch_inplace(
                    &ds, &out.assign, &runs, &mut f_means, &mut f_rho, &floor_changed,
                    &floor_sizes_counts, &mut f_counts, floor_decay, &mut scratch,
                    &ParConfig::serial(),
                );
                u_acc += t0.elapsed().as_secs_f64();
                std::hint::black_box(delta);
                let t1 = Instant::now();
                std::hint::black_box(maint.update(&f_means, d, 1.0).nnz());
                m_acc += t1.elapsed().as_secs_f64();
            }
            upd_s = upd_s.min(u_acc / rpe as f64);
            mnt_s = mnt_s.min(m_acc / rpe as f64);
        }
        let (u_ms, m_ms) = (upd_s * 1e3, mnt_s * 1e3);
        println!(
            "minibatch update floor: batch {:>7} ({} rounds/epoch)  update {:.4} ms/round  maintain {:.4} ms/round  total {:.4} ms/round",
            bsz, rpe, u_ms, m_ms, u_ms + m_ms
        );
        floor_rows.push(Json::obj(vec![
            ("batch", Json::UInt(bsz as u64)),
            ("rounds_per_epoch", Json::UInt(rpe as u64)),
            ("update_ms_per_round", Json::Num(u_ms)),
            ("maintain_ms_per_round", Json::Num(m_ms)),
            ("total_ms_per_round", Json::Num(u_ms + m_ms)),
        ]));
    }

    // --- EstParams --------------------------------------------------------
    let s_min = ds.d() * 8 / 10;
    let xp = ObjInvIndex::build(&ds.x, s_min);
    let s = bench(0, 3, 10.0, || {
        let est = estimate(
            &ds,
            &upd.means,
            &upd.rho,
            &xp,
            &EstConfig {
                s_min,
                n_candidates: 21,
                ..Default::default()
            },
        );
        std::hint::black_box(est.t_th);
    });
    println!("{}", s.summary("EstParams (21 candidates)"));
    micro.push(("estparams_21".into(), s));

    // --- machine-readable baseline ---------------------------------------
    let json = Json::obj(vec![
        ("bench", Json::str("hot_path")),
        (
            "note",
            Json::str("regenerate with: cargo bench --bench hot_path"),
        ),
        (
            "dataset",
            Json::obj(vec![
                ("preset", Json::str("nyt-like")),
                ("name", Json::str(ds.name.clone())),
                ("n", Json::UInt(ds.n() as u64)),
                ("d", Json::UInt(ds.d() as u64)),
                ("k", Json::UInt(k as u64)),
                ("seed", Json::UInt(seed)),
            ]),
        ),
        (
            "incremental_rebuild",
            Json::obj(vec![
                ("window_transitions", Json::UInt((window.len() - 1) as u64)),
                ("avg_moving_fraction", Json::Num(moving_frac)),
                ("avg_dirty_fraction", Json::Num(dirty_frac)),
                (
                    "indexes",
                    Json::Obj(
                        cmps.iter()
                            .map(|c| {
                                let (name, j) = c.json();
                                (name.to_string(), j)
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "gather_kernel",
            Json::obj(vec![
                ("objects_per_pass", Json::UInt(n_obj as u64)),
                ("postings_per_pass", Json::UInt(postings_total)),
                ("dense_terms", Json::UInt((ds.d() - dense_lo) as u64)),
                (
                    "dense_posting_share",
                    Json::Num(dense_postings as f64 / pp),
                ),
                ("scalar_ms", Json::Num(scalar.min_s * 1e3)),
                ("kernel_ms", Json::Num(tuned.min_s * 1e3)),
                ("scalar_ns_per_posting", Json::Num(scalar_ns)),
                ("kernel_ns_per_posting", Json::Num(kernel_ns)),
                (
                    "scalar_gbps",
                    Json::Num(BYTES_PER_POSTING / scalar_ns.max(1e-12)),
                ),
                (
                    "kernel_gbps",
                    Json::Num(BYTES_PER_POSTING / kernel_ns.max(1e-12)),
                ),
                ("speedup", Json::Num(gather_speedup)),
            ]),
        ),
        (
            "simd",
            Json::obj(vec![
                ("active", Json::str(auto_backend.name())),
                ("backends", Json::Obj(simd_rows)),
            ]),
        ),
        (
            "es_icp_run",
            Json::obj(vec![
                ("iterations", Json::UInt(es_out.iterations() as u64)),
                (
                    "phase_secs",
                    Json::obj(vec![
                        ("assign", Json::Num(es_out.total_assign_secs())),
                        ("gather", Json::Num(es_out.total_gather_secs())),
                        ("verify", Json::Num(es_out.total_verify_secs())),
                        (
                            "update",
                            Json::Num(
                                es_out.total_update_secs() - es_out.total_rebuild_secs(),
                            ),
                        ),
                        ("rebuild", Json::Num(es_out.total_rebuild_secs())),
                    ]),
                ),
            ]),
        ),
        (
            "minibatch",
            Json::obj(vec![
                ("algo", Json::str("ES-ICP")),
                ("batch", Json::UInt(mb_batch as u64)),
                ("schedule", Json::str(mb_cfg.schedule.name())),
                ("decay", Json::Num(mb_cfg.decay)),
                ("rounds", Json::UInt(mb_out.n_rounds() as u64)),
                ("converged", Json::Bool(mb_out.converged)),
                (
                    "objects_processed",
                    Json::UInt(mb_out.objects_processed() as u64),
                ),
                ("wall_ms_per_round", Json::Num(mb_wall * 1e3 / mb_rounds)),
                (
                    "assign_ms_per_round",
                    Json::Num(mb_out.total_assign_secs() * 1e3 / mb_rounds),
                ),
                (
                    "update_ms_per_round",
                    Json::Num(
                        (mb_out.total_update_secs() - mb_out.total_rebuild_secs()) * 1e3
                            / mb_rounds,
                    ),
                ),
                (
                    "rebuild_ms_per_round",
                    Json::Num(mb_out.total_rebuild_secs() * 1e3 / mb_rounds),
                ),
                ("objective_ratio_vs_full", Json::Num(mb_obj_ratio)),
            ]),
        ),
        (
            "minibatch_update_floor",
            Json::obj(vec![
                ("decay", Json::Num(floor_decay)),
                ("schedule", Json::str("sequential-wrap")),
                ("sizes", Json::Arr(floor_rows)),
            ]),
        ),
        (
            "microbench",
            Json::Arr(
                micro
                    .iter()
                    .map(|(name, s)| {
                        Json::obj(vec![
                            ("name", Json::str(name.clone())),
                            ("mean_ms", Json::Num(s.mean_s * 1e3)),
                            ("min_ms", Json::Num(s.min_s * 1e3)),
                            ("max_ms", Json::Num(s.max_s * 1e3)),
                            ("iters", Json::UInt(s.iters as u64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path =
        std::env::var("SKM_BENCH_JSON").unwrap_or_else(|_| "BENCH_hot_path.json".to_string());
    std::fs::write(&path, json.render_pretty()).expect("write bench json");
    println!("[wrote {path}]");
}
