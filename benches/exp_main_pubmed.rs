//! Experiment §VI-D — Figs. 7(a,b), 8 and Tables IV, XV, XVI: the main
//! comparison (MIVI, ICP, TA-ICP, CS-ICP, ES-ICP) on the PubMed-like
//! workload.
//!
//! Expected shape (paper): ES-ICP fastest through *all* iterations
//! (>15× MIVI, ≥3.5× the others); CS-ICP lowest Mult but slower than
//! ICP; TA-ICP worst branch behavior; ES-ICP/CS-ICP/TA-ICP ≈2× MIVI's
//! memory (the partial mean-inverted indexes).

mod common;

use common::{bench_preset, header, save};
use skm::algo::AlgoKind;
use skm::coordinator::compare::absolute_table;
use skm::coordinator::{comparison_rate_table, run_and_summarize};
use skm::util::io::Table;

fn main() {
    let (p, ds, seed) = bench_preset("pubmed-like");
    let cfg = p.config(seed);
    header(
        "exp_main_pubmed",
        "main comparison (Figs 7-8, Tables IV, XV, XVI)",
        &ds,
        cfg.k,
    );

    let suite = [
        AlgoKind::Mivi,
        AlgoKind::Icp,
        AlgoKind::CsIcp,
        AlgoKind::TaIcp,
        AlgoKind::EsIcp,
    ];
    let mut outs = Vec::new();
    let mut summaries = Vec::new();
    for kind in suite {
        eprintln!("running {} ...", kind.name());
        let (out, s) = run_and_summarize(kind, &ds, &cfg);
        outs.push(out);
        summaries.push(s);
    }
    for o in &outs[1..] {
        assert_eq!(o.assign, outs[0].assign, "{:?} diverged from MIVI", o.algo);
    }

    // Figs 7(a) Mult, 7(b) CPR, 8 elapsed time — per-iteration series.
    let mut t = Table::new(vec![
        "iter", "mult_MIVI", "mult_ICP", "mult_CS", "mult_TA", "mult_ES", "cpr_ICP", "cpr_CS",
        "cpr_TA", "cpr_ES", "t_MIVI", "t_ICP", "t_CS", "t_TA", "t_ES",
    ]);
    let iters = outs.iter().map(|o| o.logs.len()).min().unwrap();
    for i in 0..iters {
        t.row(vec![
            (i + 1).to_string(),
            outs[0].logs[i].counters.mult.to_string(),
            outs[1].logs[i].counters.mult.to_string(),
            outs[2].logs[i].counters.mult.to_string(),
            outs[3].logs[i].counters.mult.to_string(),
            outs[4].logs[i].counters.mult.to_string(),
            format!("{:.6}", outs[1].logs[i].cpr),
            format!("{:.6}", outs[2].logs[i].cpr),
            format!("{:.6}", outs[3].logs[i].cpr),
            format!("{:.6}", outs[4].logs[i].cpr),
            format!("{:.4}", outs[0].logs[i].assign_secs),
            format!("{:.4}", outs[1].logs[i].assign_secs),
            format!("{:.4}", outs[2].logs[i].assign_secs),
            format!("{:.4}", outs[3].logs[i].assign_secs),
            format!("{:.4}", outs[4].logs[i].assign_secs),
        ]);
    }
    save("exp_main_pubmed", "figs7_8_per_iteration", &t);

    println!("\n[Table XV analog] absolute values:");
    println!("{}", absolute_table(&summaries).render());
    println!("[Table IV analog] rates relative to ES-ICP:");
    let rates = comparison_rate_table(&summaries, "ES-ICP");
    println!("{}", rates.render());
    save("exp_main_pubmed", "table4_rates", &rates);

    // Shape assertions.
    let (mivi, icp, cs, ta, es) = (
        &summaries[0],
        &summaries[1],
        &summaries[2],
        &summaries[3],
        &summaries[4],
    );
    let ok = |b: bool| if b { "OK" } else { "MISMATCH" };
    println!("shape checks (paper Table IV):");
    let best_other = mivi.avg_secs.min(icp.avg_secs).min(cs.avg_secs).min(ta.avg_secs);
    println!(
        "  ES-ICP fastest overall (within 15% at laptop scale; margins grow with K — EXPERIMENTS.md n.3): {} (MIVI {:.1}x, ICP {:.1}x, CS {:.1}x, TA {:.1}x)",
        ok(es.avg_secs < best_other * 1.15),
        mivi.avg_secs / es.avg_secs,
        icp.avg_secs / es.avg_secs,
        cs.avg_secs / es.avg_secs,
        ta.avg_secs / es.avg_secs
    );
    println!(
        "  ES-ICP fastest on the assignment step: {} (MIVI {:.1}x; paper >15x)",
        ok(es.avg_assign_secs
            < mivi
                .avg_assign_secs
                .min(icp.avg_assign_secs)
                .min(cs.avg_assign_secs)
                .min(ta.avg_assign_secs)),
        mivi.avg_assign_secs / es.avg_assign_secs
    );
    println!(
        "  CS-ICP lowest-or-tied Mult: {} (CS {:.3}x of ES, {:.3}x of ICP)",
        ok(cs.avg_mult < es.avg_mult * 1.1 && cs.avg_mult < icp.avg_mult),
        cs.avg_mult / es.avg_mult,
        cs.avg_mult / icp.avg_mult
    );
    println!(
        "  TA-ICP worst branch proxy: {}",
        ok(ta.sw_irregular_branches > es.sw_irregular_branches.max(icp.sw_irregular_branches))
    );
    println!(
        "  partial indexes cost memory (ES/CS/TA > MIVI): {} ({:.2}x)",
        ok(es.max_mem_gb > mivi.max_mem_gb),
        es.max_mem_gb / mivi.max_mem_gb
    );
    println!(
        "  final CPR: ICP {:.3}  CS {:.4}  TA {:.4}  ES {:.4} (MIVI = 1)",
        icp.final_cpr, cs.final_cpr, ta.final_cpr, es.final_cpr
    );
}
