//! Experiment Appendix C — Figs. 13 and 14: validation of the EstParams
//! estimator.
//!
//! * Fig 13: the *approximate* multiplication count J(t_h, v_h) along
//!   the v_h candidates (with the per-v_h optimal t_h) vs the *actual*
//!   multiplication count of the resulting filter — the two series
//!   should agree and share their minimum.
//! * Fig 14: actual multiplications along v_th for several *fixed* t_th
//!   values — the Fig-13 approximate curve should be their lower
//!   envelope.

mod common;

use common::{bench_preset, header, save};
use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::estparams::{actual_mult_count, estimate, EstConfig};
use skm::index::{update_means, ObjInvIndex};
use skm::util::io::Table;

fn main() {
    let (p, ds, seed) = bench_preset("pubmed-like");
    let cfg = p.config(seed);
    header("exp_estparams", "EstParams validation (Figs 13-14)", &ds, cfg.k);

    // Second-iteration state, as in the paper's Appendix-C experiment.
    let warm = ClusterConfig {
        max_iters: 2,
        ..cfg.clone()
    };
    let out = run_clustering(AlgoKind::Mivi, &ds, &warm);
    let upd = update_means(&ds, &out.assign, cfg.k, None, None);

    let s_min = (ds.d() as f64 * cfg.s_min_frac) as usize;
    let xp = ObjInvIndex::build(&ds.x, s_min);
    let est = estimate(
        &ds,
        &upd.means,
        &upd.rho,
        &xp,
        &EstConfig {
            s_min,
            n_candidates: 25,
            fixed_t: None,
            fixed_v: None,
            max_sample_objects: 10_000,
        },
    );
    println!(
        "estimated: t_th={} ({:.3}D), v_th={:.4}",
        est.t_th,
        est.t_th as f64 / ds.d() as f64,
        est.v_th
    );

    // ---- Fig 13: approximate vs actual along v_h ----------------------
    let mut t13 = Table::new(vec!["v_h", "t_h", "approx_J(M)", "actual_Mult(M)"]);
    let mut approx_min = (f64::INFINITY, 0.0);
    let mut actual_min = (u64::MAX, 0.0);
    for pnt in &est.curve {
        let actual = actual_mult_count(&ds, &upd.means, &upd.rho, pnt.t_th, pnt.v_th);
        if pnt.j_value < approx_min.0 {
            approx_min = (pnt.j_value, pnt.v_th);
        }
        if actual < actual_min.0 {
            actual_min = (actual, pnt.v_th);
        }
        t13.row(vec![
            format!("{:.4}", pnt.v_th),
            pnt.t_th.to_string(),
            format!("{:.3}", pnt.j_value / 1e6),
            format!("{:.3}", actual as f64 / 1e6),
        ]);
    }
    println!("[Fig 13] approximate vs actual multiplications:\n{}", t13.render());
    save("exp_estparams", "fig13_approx_vs_actual", &t13);
    println!(
        "minima: approx at v_h={:.4}, actual at v_h={:.4} ({})",
        approx_min.1,
        actual_min.1,
        if (approx_min.1 - actual_min.1).abs() <= est.v_th * 0.5 {
            "OK — minima agree (paper: both at 0.038)"
        } else {
            "MISMATCH"
        }
    );

    // ---- Fig 14: actual Mult for fixed t_th values --------------------
    let d = ds.d();
    let fixed_ts: Vec<usize> = [0.86, 0.88, 0.90, 0.92, 0.94]
        .iter()
        .map(|f| (d as f64 * f) as usize)
        .collect();
    let vs: Vec<f64> = est.curve.iter().map(|p| p.v_th).collect();
    let mut t14 = Table::new(vec!["v_th", "t0.86D", "t0.88D", "t0.90D", "t0.92D", "t0.94D", "envelope"]);
    for (i, &v) in vs.iter().enumerate() {
        let mut row = vec![format!("{v:.4}")];
        let mut lowest = u64::MAX;
        for &t in &fixed_ts {
            let a = actual_mult_count(&ds, &upd.means, &upd.rho, t, v);
            lowest = lowest.min(a);
            row.push(format!("{:.3}", a as f64 / 1e6));
        }
        row.push(format!("{:.3}", est.curve[i].j_value / 1e6));
        t14.row(row);
    }
    println!("[Fig 14] actual Mult at fixed t_th (M) vs the approximate envelope:\n{}", t14.render());
    save("exp_estparams", "fig14_fixed_tth", &t14);
}
