//! Experiment §III / Appendix I — Figs. 2(a,b), 3(a,b), 4(a,b), 11(a),
//! 21, 22: the universal characteristics of both corpora and their
//! clustering results.
//!
//! Expected shape: power-law df/tf rank-frequency; mf bounded by K but
//! otherwise Zipf-like; positive df–mf correlation; multiplication
//! volume concentrated in high-df term ids; strongly concave CPS curve
//! (paper: CPS(0.1) = 0.92 on PubMed, 0.90 on NYT).

mod common;

use common::{bench_preset, header, save};
use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::index::update_means;
use skm::ucs;
use skm::util::io::Table;

fn main() {
    for preset_name in ["pubmed-like", "nyt-like"] {
        run_one(preset_name);
    }
}

fn run_one(preset_name: &str) {
    let (p, ds, seed) = bench_preset(preset_name);
    let cfg = p.config(seed);
    header("exp_ucs", "universal characteristics (Figs 2-4, 21-22)", &ds, cfg.k);

    // Fig 2(a): Zipf on tf / df.
    let tf = ds.x.column_sum();
    let df: Vec<f64> = ds.df.iter().map(|&x| x as f64).collect();
    let rf_tf = ucs::rank_frequency(&tf);
    let rf_df = ucs::rank_frequency(&df);
    let (a_tf, r2_tf) = ucs::zipf_exponent(&rf_tf, 100);
    let (a_df, r2_df) = ucs::zipf_exponent(&rf_df, 100);
    println!("[Fig 2a] tf: alpha={a_tf:.3} r2={r2_tf:.3}   df: alpha={a_df:.3} r2={r2_df:.3}");
    let mut t2a = Table::new(vec!["rank", "tf", "df"]);
    for i in (0..rf_df.len().min(rf_tf.len())).step_by((rf_df.len() / 400).max(1)) {
        t2a.row(vec![
            format!("{}", rf_df[i].0),
            format!("{}", rf_tf[i].1),
            format!("{}", rf_df[i].1),
        ]);
    }
    save("exp_ucs", &format!("{preset_name}_fig2a"), &t2a);

    // Fig 2(b): bounded Zipf on mf at 4 K values.
    let mut t2b = Table::new(vec!["K", "alpha_mf", "max_mf"]);
    for kdiv in [8usize, 4, 2, 1] {
        let k = (cfg.k / kdiv).max(2);
        let c = ClusterConfig {
            k,
            max_iters: 6,
            ..cfg.clone()
        };
        let o = run_clustering(AlgoKind::EsIcp, &ds, &c);
        let m = update_means(&ds, &o.assign, k, None, None).means;
        let mf: Vec<f64> = m.m.column_df().iter().map(|&x| x as f64).collect();
        let rf = ucs::rank_frequency(&mf);
        let (a, _) = ucs::zipf_exponent(&rf, 60);
        assert!(rf[0].1 <= k as f64, "mf exceeded K");
        t2b.row(vec![k.to_string(), format!("{a:.3}"), format!("{}", rf[0].1)]);
    }
    println!("[Fig 2b] bounded Zipf on mf:\n{}", t2b.render());
    save("exp_ucs", &format!("{preset_name}_fig2b"), &t2b);

    // Full clustering for the remaining panels.
    eprintln!("clustering with ES-ICP for the mean-set panels ...");
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    let upd = update_means(&ds, &out.assign, cfg.k, None, None);

    // Fig 3(a): df–mf trend.
    let prof = ucs::df_mf_profile(&ds, &upd.means);
    let mut t3a = Table::new(vec!["df", "avg_mf"]);
    for (df, mf) in prof.iter().step_by((prof.len() / 300).max(1)) {
        t3a.row(vec![format!("{df}"), format!("{mf:.3}")]);
    }
    save("exp_ucs", &format!("{preset_name}_fig3a"), &t3a);

    // Fig 3(b): multiplication volume concentration.
    let (total, top_frac) = ucs::mult_volume(&ds, &upd.means);
    println!(
        "[Fig 3b] Σ df·mf = {:.3e}; share in the top-10% term ids = {:.1}% (uneven by design)",
        total,
        top_frac * 100.0
    );
    assert!(top_frac > 0.3, "no high-df concentration");

    // Fig 4(a)/11(a): feature-value skew.
    let skew = ucs::value_skew(&upd.means, 400);
    let mut t4a = Table::new(vec!["rank_over_K", "value"]);
    for (r, v) in &skew {
        t4a.row(vec![format!("{r:.4}"), format!("{v:.5}")]);
    }
    save("exp_ucs", &format!("{preset_name}_fig4a"), &t4a);
    println!(
        "[Fig 4a] {} mean components above 1/sqrt(2) across K={} centroids",
        ucs::concentration_count(&upd.means),
        cfg.k
    );

    // Fig 4(b)/21/22: CPS with STD.
    let curve = ucs::cps_curve(&ds, &upd.means, &out.assign, 100);
    let mut t4b = Table::new(vec!["NR", "CPS_mean", "CPS_std"]);
    for i in 0..curve.nr.len() {
        t4b.row(vec![
            format!("{:.2}", curve.nr[i]),
            format!("{:.5}", curve.mean[i]),
            format!("{:.5}", curve.std[i]),
        ]);
    }
    save("exp_ucs", &format!("{preset_name}_fig4b_cps"), &t4b);
    println!(
        "[Fig 4b/21/22] CPS(0.1)={:.3} CPS(0.2)={:.3} CPS(0.5)={:.3}  (paper: 0.92/0.90 at 0.1)",
        curve.value_at(0.1),
        curve.value_at(0.2),
        curve.value_at(0.5)
    );
    assert!(curve.value_at(0.5) > 0.7, "CPS not Pareto-like");
    println!();
}
