//! Experiment §VII-B — Figs. 9, 10 (PubMed-like) and 11(b), 12
//! (NYT-like): how the ES filter exploits the feature-value
//! concentration phenomenon.
//!
//! * Fig 9/11(b): P(q-th largest value in a mean-inverted array ≤ v) for
//!   orders 1, 2, 3, 10, 100 — very few entries are large.
//! * Fig 10/12: multiplications (a) spent *before* filtering (building
//!   exact Region-1/2 partial sims) and (b) for centroids *passing* the
//!   filter, as v_th sweeps, with t_th = 0 to isolate the value
//!   threshold (the paper's setting for this figure). The estimated
//!   v_th (dashed line in the paper) should sit where both curves are
//!   low.

mod common;

use common::{bench_preset, header, save};
use skm::algo::{run_clustering, AlgoKind};
use skm::index::{update_means, EsIndex};
use skm::ucs;
use skm::util::io::Table;

fn main() {
    for preset_name in ["pubmed-like", "nyt-like"] {
        run_one(preset_name);
    }
}

fn run_one(preset_name: &str) {
    let (p, ds, seed) = bench_preset(preset_name);
    let cfg = p.config(seed);
    header("exp_filter", "ES filter analysis (Figs 9-12)", &ds, cfg.k);

    // Cluster, then analyze the converged mean set (as the paper does).
    eprintln!("clustering with ES-ICP ...");
    let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
    let upd = update_means(&ds, &out.assign, cfg.k, None, None);
    let t_th_est = out.t_th.unwrap();
    let v_th_est = out.v_th.unwrap();
    println!("estimated parameters: t_th={t_th_est} v_th={v_th_est:.4}");

    // ---- Fig 9 / 11(b): order-value CDFs over s >= t_th --------------
    let orders = [1usize, 2, 3, 10, 100];
    let cdfs = ucs::order_value_cdf(&upd.means, t_th_est, &orders);
    let mut t9 = Table::new(vec!["order", "n_arrays", "p10", "median", "p90", "max"]);
    for (q, samples) in &cdfs {
        if samples.is_empty() {
            t9.row(vec![q.to_string(), "0".into(), "-".into(), "-".into(), "-".into(), "-".into()]);
            continue;
        }
        let pick = |f: f64| samples[((samples.len() - 1) as f64 * f) as usize];
        t9.row(vec![
            q.to_string(),
            samples.len().to_string(),
            format!("{:.4}", pick(0.1)),
            format!("{:.4}", pick(0.5)),
            format!("{:.4}", pick(0.9)),
            format!("{:.4}", samples[samples.len() - 1]),
        ]);
    }
    println!("[Fig 9/11b] per-order value distribution in sorted arrays:\n{}", t9.render());
    save("exp_filter", &format!("{preset_name}_fig9_orders"), &t9);
    let (maxlen, avglen) = ucs::array_length_stats(&upd.means, t_th_est);
    println!("array lengths (s >= t_th): max={maxlen} avg={avglen:.1}");

    // ---- Fig 10 / 12: Mult before/after filtering vs v_th -------------
    // t_th = 0 isolates the value threshold, as in the paper.
    let mut t10 = Table::new(vec!["v_th", "mult_before(M)", "mult_passing(M)"]);
    let sweep: Vec<f64> = (1..=14).map(|i| v_th_est * i as f64 / 6.0).collect();
    for &v in &sweep {
        let (before, passing) = filter_cost_split(&ds, &upd, v);
        t10.row(vec![
            format!("{v:.4}"),
            format!("{:.3}", before as f64 / 1e6),
            format!("{:.3}", passing as f64 / 1e6),
        ]);
    }
    println!(
        "[Fig 10/12] Mult before filter / for passing centroids along v_th (estimated v_th={v_th_est:.4}):\n{}",
        t10.render()
    );
    save("exp_filter", &format!("{preset_name}_fig10_sweep"), &t10);

    // The estimator's choice should be near the joint minimum.
    let (b_est, p_est) = filter_cost_split(&ds, &upd, v_th_est);
    let total_est = b_est + p_est;
    let best_total = sweep
        .iter()
        .map(|&v| {
            let (b, p) = filter_cost_split(&ds, &upd, v);
            b + p
        })
        .min()
        .unwrap();
    // NOTE the sweep isolates v_th with t_th = 0 (the paper's Fig-10
    // setting, chosen "to be independent from our t_th"), while the
    // estimator optimized v_th jointly WITH t_th — so compare shapes, and
    // check the estimate against the joint-cost sweep at its own t_th.
    let (b2, p2) = {
        let mut best = u64::MAX;
        for &v in &sweep {
            let b = skm::estparams::actual_mult_count(&ds, &upd.means, &upd.rho, out.t_th.unwrap(), v);
            best = best.min(b);
        }
        let est_cost =
            skm::estparams::actual_mult_count(&ds, &upd.means, &upd.rho, out.t_th.unwrap(), v_th_est);
        (est_cost, best)
    };
    println!(
        "Fig-10 sweep (t_th=0): estimated v_th costs {:.3}M vs sweep minimum {:.3}M (informational)",
        total_est as f64 / 1e6,
        best_total as f64 / 1e6
    );
    println!(
        "at the estimator's own t_th: estimated v_th {:.3}M vs v-sweep minimum {:.3}M ({})",
        b2 as f64 / 1e6,
        p2 as f64 / 1e6,
        if b2 <= p2 + p2 / 2 {
            "OK — near the optimum"
        } else {
            "MISMATCH"
        }
    );
    println!();
}

/// Multiplications (before-filter exact part, passing-centroid
/// verification part) for one assignment pass with t_th = 0 and the
/// given v_th — the two panels of Fig. 10.
fn filter_cost_split(
    ds: &skm::sparse::Dataset,
    upd: &skm::index::UpdateOutput,
    v_th: f64,
) -> (u64, u64) {
    let k = upd.means.k();
    let idx = EsIndex::build(&upd.means, 0, v_th);
    let mut rho = vec![0.0f64; k];
    let (mut before, mut passing) = (0u64, 0u64);
    for i in 0..ds.n() {
        let (ts, vs) = ds.x.row(i);
        let mut y_base = 0.0;
        for &u in vs {
            y_base += u * v_th;
        }
        // Folded accumulator: rho[j] is the upper bound after gathering.
        rho.iter_mut().for_each(|r| *r = y_base);
        for (&t, &u) in ts.iter().zip(vs) {
            let (ids, vals) = idx.r2.postings(t as usize);
            before += ids.len() as u64;
            let us = u * v_th;
            for (&c, &v) in ids.iter().zip(vals) {
                rho[c as usize] += us * v;
            }
        }
        let rho_max = upd.rho[i];
        let mut z = 0u64;
        for &r in rho.iter() {
            if r > rho_max {
                z += 1;
            }
        }
        passing += z * ts.len() as u64;
    }
    (before, passing)
}
