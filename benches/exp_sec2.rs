//! Experiment §II — Fig. 1, Table II (and Appendix E Tables XIII–XIV):
//! MIVI vs DIVI vs Ding+ on the PubMed-like workload.
//!
//! Expected shape (paper, 8.2M PubMed, K=80 000):
//!   * MIVI and DIVI: identical multiplication counts;
//!     DIVI ~10× slower in elapsed time.
//!   * Ding+: ~4× fewer multiplications than MIVI, yet ~3× slower,
//!     with orders-of-magnitude more branch misses / LLC misses.

mod common;

use common::{bench_preset, header, save};
use skm::algo::AlgoKind;
use skm::coordinator::compare::absolute_table;
use skm::coordinator::{comparison_rate_table, run_and_summarize};
use skm::util::io::{fmt_sig, Table};

fn main() {
    let (p, ds, seed) = bench_preset("pubmed-like");
    let cfg = p.config(seed);
    header("exp_sec2", "MIVI vs DIVI vs Ding+ (Fig 1, Tab II, XIII-XIV)", &ds, cfg.k);

    let mut outs = Vec::new();
    let mut summaries = Vec::new();
    for kind in [AlgoKind::Mivi, AlgoKind::Divi, AlgoKind::Ding] {
        eprintln!("running {} ...", kind.name());
        let (out, s) = run_and_summarize(kind, &ds, &cfg);
        outs.push(out);
        summaries.push(s);
    }
    for o in &outs[1..] {
        assert_eq!(o.assign, outs[0].assign, "{:?} diverged", o.algo);
    }

    // Fig 1: per-iteration Mult and elapsed time.
    let mut fig1 = Table::new(vec!["iter", "mult_MIVI", "mult_DIVI", "mult_Ding", "t_MIVI", "t_DIVI", "t_Ding"]);
    let iters = outs.iter().map(|o| o.logs.len()).min().unwrap();
    for i in 0..iters {
        fig1.row(vec![
            (i + 1).to_string(),
            outs[0].logs[i].counters.mult.to_string(),
            outs[1].logs[i].counters.mult.to_string(),
            outs[2].logs[i].counters.mult.to_string(),
            format!("{:.4}", outs[0].logs[i].assign_secs),
            format!("{:.4}", outs[1].logs[i].assign_secs),
            format!("{:.4}", outs[2].logs[i].assign_secs),
        ]);
    }
    save("exp_sec2", "fig1_per_iteration", &fig1);

    // Table XIII: absolute values.
    println!("\n[Table XIII analog] absolute values:");
    println!("{}", absolute_table(&summaries).render());

    // Table II: rates relative to MIVI.
    println!("[Table II analog] rates relative to MIVI:");
    let rates = comparison_rate_table(&summaries, "MIVI");
    println!("{}", rates.render());
    save("exp_sec2", "table2_rates", &rates);

    // Shape assertions (the paper's qualitative claims).
    let (mivi, divi, ding) = (&summaries[0], &summaries[1], &summaries[2]);
    println!("shape checks:");
    let mult_eq = (mivi.avg_mult - divi.avg_mult).abs() / mivi.avg_mult < 1e-9;
    println!("  MIVI == DIVI multiplications: {}", ok(mult_eq));
    println!(
        "  DIVI slower than MIVI: {} ({:.1}x; paper ~10x)",
        ok(divi.avg_secs > mivi.avg_secs),
        divi.avg_secs / mivi.avg_secs
    );
    println!(
        "  Ding+ fewer mult than MIVI: {} ({} vs {})",
        ok(ding.avg_mult < mivi.avg_mult),
        fmt_sig(ding.avg_mult),
        fmt_sig(mivi.avg_mult)
    );
    // The paper's 2.9x slowdown is a cache-capacity effect (90 GB dense
    // mean set at K=80 000); at laptop scale the dense set fits the LLC,
    // so we check the quantity that explodes at paper scale instead.
    println!(
        "  Ding+ wall-clock vs MIVI: {:.2}x here (paper ~2.9x; cache-capacity effect, see EXPERIMENTS.md n.1)",
        ding.avg_secs / mivi.avg_secs
    );
    println!(
        "  Ding+ dominant cold-touch (LLCM) proxy: {} ({} vs MIVI {})",
        ok(ding.sw_cold_touches > 10 * mivi.sw_cold_touches.max(1)),
        ding.sw_cold_touches,
        mivi.sw_cold_touches
    );
    println!(
        "  Ding+ worst irregular-branch proxy: {} ({} vs MIVI {})",
        ok(ding.sw_irregular_branches > mivi.sw_irregular_branches),
        ding.sw_irregular_branches,
        mivi.sw_irregular_branches
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "MISMATCH"
    }
}
