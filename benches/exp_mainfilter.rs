//! Experiment Appendix G — Tables XIX–XXII: main filters *without* the
//! auxiliary ICP filter (ES-MIVI ≡ ES, TA-MIVI, CS-MIVI vs MIVI), on
//! both corpora.
//!
//! Expected shape (paper): no algorithm improves by dropping ICP;
//! ES-MIVI is the best of the filter-only variants regardless of
//! data set; CS-MIVI/TA-MIVI remain slower than MIVI-with-ICP-style
//! algorithms despite fewer multiplications.

mod common;

use common::{bench_preset, header, save};
use skm::algo::AlgoKind;
use skm::coordinator::compare::absolute_table;
use skm::coordinator::{comparison_rate_table, run_and_summarize};

fn main() {
    for preset_name in ["pubmed-like", "nyt-like"] {
        run_one(preset_name);
    }
}

fn run_one(preset_name: &str) {
    let (p, ds, seed) = bench_preset(preset_name);
    let cfg = p.config(seed);
    header(
        "exp_mainfilter",
        "main filters without ICP (Tables XIX-XXII)",
        &ds,
        cfg.k,
    );

    let suite = [
        AlgoKind::Mivi,
        AlgoKind::Es,     // ES-MIVI
        AlgoKind::CsMivi,
        AlgoKind::TaMivi,
        // with-ICP counterparts for the "no variant improves without
        // ICP" comparison:
        AlgoKind::EsIcp,
        AlgoKind::CsIcp,
        AlgoKind::TaIcp,
    ];
    let mut outs = Vec::new();
    let mut summaries = Vec::new();
    for kind in suite {
        eprintln!("running {} ...", kind.name());
        let (out, s) = run_and_summarize(kind, &ds, &cfg);
        outs.push(out);
        summaries.push(s);
    }
    for o in &outs[1..] {
        assert_eq!(o.assign, outs[0].assign, "{:?} diverged from MIVI", o.algo);
    }

    println!("\n[Tables XIX/XXI analog] absolute values:");
    println!("{}", absolute_table(&summaries).render());
    println!("[Table XX/XXII analog] rates relative to MIVI:");
    let rates = comparison_rate_table(&summaries, "MIVI");
    println!("{}", rates.render());
    save("exp_mainfilter", &format!("{preset_name}_rates"), &rates);

    let by = |n: &str| summaries.iter().find(|s| s.name == n).unwrap();
    let ok = |b: bool| if b { "OK" } else { "MISMATCH" };
    let (es, cs, ta) = (by("ES"), by("CS-MIVI"), by("TA-MIVI"));
    let (esicp, csicp, taicp) = (by("ES-ICP"), by("CS-ICP"), by("TA-ICP"));
    println!("shape checks (Appendix G):");
    println!(
        "  ES-MIVI best-or-tied filter-only variant: {} (ES {:.3}s, CS {:.3}s, TA {:.3}s per iter)",
        ok(es.avg_secs < cs.avg_secs && es.avg_secs < ta.avg_secs * 1.15),
        es.avg_secs,
        cs.avg_secs,
        ta.avg_secs
    );
    println!(
        "  adding ICP never hurts: ES {} CS {} TA {}",
        ok(esicp.avg_secs <= es.avg_secs * 1.1),
        ok(csicp.avg_secs <= cs.avg_secs * 1.1),
        ok(taicp.avg_secs <= ta.avg_secs * 1.1)
    );
    println!();
}
