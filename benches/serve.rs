//! Serving-layer benchmark: QPS and latency percentiles of the online
//! query router (`serve::Router`) over a clustered corpus.
//!
//! Sections:
//!   * **correctness gates** (before anything is timed): the pruned
//!     router's top-p equals the brute-force dense scan (ids + score
//!     bits) on a query subsample, and the sharded `serve_batch` output
//!     is bitwise-equal to the serial loop on the full load.
//!   * **routing**: pruned routing vs brute-force all-means scan,
//!     queries/second.
//!   * **serving (route + retrieve)**: single-thread QPS with latency
//!     percentiles, then batch-sharded QPS across worker threads.
//!
//! Emits a machine-readable baseline to `$SKM_BENCH_JSON` (default
//! `BENCH_serve.json`). CI's bench-smoke job regenerates and validates
//! it; the batch-vs-serial speedup is reported (with a warning when a
//! noisy runner fails to beat 1x) — bitwise equality is the hard gate.

mod common;

use common::{bench_preset, header};
use skm::algo::{run_clustering_with, AlgoKind, ParConfig};
use skm::serve::{
    latency_stats, push_top, serve_batch, ClusteredCorpus, Query, Router, RouterParams,
};
use skm::util::json::Json;
use skm::util::rng::Pcg32;
use std::time::Instant;

fn main() {
    let (p, ds, seed) = bench_preset("pubmed-like");
    let cfg = p.config(seed);
    header(
        "serve",
        "online nearest-centroid query serving (QPS / latency)",
        &ds,
        cfg.k,
    );
    let k = cfg.k;
    let par_env = ParConfig::from_env();

    // --- cluster + freeze -------------------------------------------------
    let t0 = Instant::now();
    let out = run_clustering_with(AlgoKind::EsIcp, &ds, &cfg, &par_env);
    let cluster_secs = t0.elapsed().as_secs_f64();
    println!(
        "clustered: {} iterations in {cluster_secs:.2}s (J={:.4})",
        out.iterations(),
        out.objective
    );
    let snap = ClusteredCorpus::from_output(ds, &out, k);
    let params = RouterParams::estimate_for(&snap, &cfg);
    let router = Router::new(&snap, params).expect("router build");
    println!(
        "router: t_th={} ({:.3}·D), v_th={:.4}, index {:.2} MB over snapshot {:.2} MB",
        router.t_th(),
        router.t_th() as f64 / snap.ds.d() as f64,
        router.v_th(),
        router.mem_bytes() as f64 / 1e6,
        snap.mem_bytes() as f64 / 1e6
    );

    // --- query load: sampled corpus docs + random sparse queries ----------
    let n_queries = std::env::var("SKM_QUERIES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(512usize)
        .min(snap.ds.n());
    let mut rng = Pcg32::new(seed ^ 0x5e4e);
    let mut queries: Vec<Query> = rng
        .sample_distinct(snap.ds.n(), n_queries * 3 / 4)
        .into_iter()
        .map(|i| Query::from_row(&snap.ds, i))
        .collect();
    let d = snap.ds.d();
    while queries.len() < n_queries {
        let nnz = 4 + rng.gen_range(24) as usize;
        let pairs: Vec<(u32, f64)> = rng
            .sample_distinct(d, nnz.min(d))
            .into_iter()
            .map(|t| (t as u32, 0.05 + rng.next_f64()))
            .collect();
        queries.push(Query::from_pairs(d, &pairs).expect("valid query weights"));
    }
    let sd = p.serve_defaults();
    let (top_p, top_k) = (sd.top_p, sd.top_k);
    println!(
        "query load: {} queries, top-p {top_p}, top-k {top_k}",
        queries.len()
    );

    // --- correctness gate 1: pruned routing == brute force ----------------
    let brute_route = |q: &Query, pp: usize| -> Vec<(u32, f64)> {
        let mut top: Vec<(f64, u32)> = Vec::new();
        for j in 0..snap.k {
            let (mts, mvs) = snap.means.m.row(j);
            let sc = skm::sparse::dot_sorted(q.ids(), q.vals(), mts, mvs);
            push_top(&mut top, pp, sc, j as u32);
        }
        top.into_iter().map(|(s, j)| (j, s)).collect()
    };
    for q in queries.iter().take(64) {
        let (got, _) = router.route(q, top_p).expect("route");
        let want = brute_route(q, top_p);
        assert_eq!(got.len(), want.len(), "routing soundness: length");
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.0, b.0, "routing soundness: centroid id");
            assert_eq!(
                a.1.to_bits(),
                b.1.to_bits(),
                "routing soundness: score bits"
            );
        }
    }
    println!("correctness: pruned routing bit-matches brute force (64 queries)");

    // --- correctness gate 2: sharded batch == serial, bit for bit ---------
    let batch_threads = if par_env.is_parallel() {
        par_env.threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(2, 8)
    };
    let (serial_results, serial_counters) =
        serve_batch(&router, &queries, top_p, top_k, &ParConfig::serial());
    let (batch_results, batch_counters) = serve_batch(
        &router,
        &queries,
        top_p,
        top_k,
        &ParConfig::with_threads(batch_threads),
    );
    assert_eq!(serial_counters, batch_counters, "batch merged counters");
    for (ra, rb) in serial_results.iter().zip(&batch_results) {
        let a = ra.as_ref().expect("serial slot");
        let b = rb.as_ref().expect("batch slot");
        assert_eq!(a.centroids.len(), b.centroids.len());
        for (x, y) in a.centroids.iter().zip(&b.centroids) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "batch centroid score bits");
        }
        for (x, y) in a.hits.iter().zip(&b.hits) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "batch hit score bits");
        }
    }
    let bitwise_equal = true; // reaching here means every assert held
    println!("correctness: {batch_threads}-thread serve_batch bit-matches serial");
    let avg_candidates = serial_counters.candidates as f64 / queries.len().max(1) as f64;
    println!(
        "pruning: avg candidates/query {avg_candidates:.1} of K={k} (CPR {:.4})",
        avg_candidates / k as f64
    );

    // --- routing throughput: pruned vs brute force ------------------------
    // (A generic fn, not a `Box<dyn FnMut>`-taking closure: the boxed
    // trait object would demand 'static captures, and every timed body
    // borrows the local queries/router.)
    fn best_of(reps: usize, mut f: impl FnMut() -> f64) -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            best = best.min(f());
        }
        best
    }
    let reps = 3usize;
    let routed_secs = best_of(reps, || {
        let t = Instant::now();
        let mut acc = 0u32;
        for q in &queries {
            let (r, _) = router.route(q, top_p).expect("route");
            acc ^= r[0].0;
        }
        std::hint::black_box(acc);
        t.elapsed().as_secs_f64()
    });
    let brute_secs = best_of(reps, || {
        let t = Instant::now();
        let mut acc = 0u32;
        for q in &queries {
            let r = brute_route(q, top_p);
            acc ^= r[0].0;
        }
        std::hint::black_box(acc);
        t.elapsed().as_secs_f64()
    });
    let route_qps = queries.len() as f64 / routed_secs;
    let brute_qps = queries.len() as f64 / brute_secs;
    println!(
        "routing: pruned {route_qps:.0} QPS vs brute-force {brute_qps:.0} QPS ({:.2}x)",
        route_qps / brute_qps.max(1e-12)
    );

    // --- serving latency (route + retrieve), single thread ----------------
    let mut lat = vec![0.0f64; queries.len()];
    let serial_secs = best_of(reps, || {
        let t = Instant::now();
        for (q, slot) in queries.iter().zip(lat.iter_mut()) {
            let tq = Instant::now();
            std::hint::black_box(router.retrieve(q, top_p, top_k).expect("retrieve").hits.len());
            *slot = tq.elapsed().as_secs_f64();
        }
        t.elapsed().as_secs_f64()
    });
    let stats = latency_stats(&lat);
    let serial_qps = queries.len() as f64 / serial_secs;
    println!(
        "serving (1 thread): {serial_qps:.0} QPS — latency mean {:.1} us, p50 {:.1}, p90 {:.1}, p99 {:.1}, max {:.1}",
        stats.mean_s * 1e6,
        stats.p50_s * 1e6,
        stats.p90_s * 1e6,
        stats.p99_s * 1e6,
        stats.max_s * 1e6
    );

    // --- batch-sharded serving --------------------------------------------
    let batch_secs = best_of(reps, || {
        let t = Instant::now();
        let (r, _) = serve_batch(
            &router,
            &queries,
            top_p,
            top_k,
            &ParConfig::with_threads(batch_threads),
        );
        std::hint::black_box(r.len());
        t.elapsed().as_secs_f64()
    });
    let batch_qps = queries.len() as f64 / batch_secs;
    let speedup = batch_qps / serial_qps.max(1e-12);
    println!(
        "serving ({batch_threads} threads): {batch_qps:.0} QPS ({speedup:.2}x vs 1 thread, results bitwise-equal)"
    );
    if speedup < 1.0 {
        println!(
            "WARNING: batch-sharded QPS fell below single-thread on this runner ({speedup:.2}x)"
        );
    }

    // --- persistence: snapshot save / warm-restart cost -------------------
    // How expensive is publishing the serving state, and how fast is a
    // warm restart (load + router build + first answered query) compared
    // with re-clustering from scratch?
    let snap_path =
        std::env::temp_dir().join(format!("skm_bench_serve_{}.skm", std::process::id()));
    let save_secs = best_of(reps, || {
        let t = Instant::now();
        skm::persist::save_snapshot(&snap_path, &snap, &params).expect("save snapshot");
        t.elapsed().as_secs_f64()
    });
    let snapshot_bytes = std::fs::metadata(&snap_path).expect("snapshot stat").len();
    let warm_secs = best_of(reps, || {
        let t = Instant::now();
        let (s, p2) = skm::persist::load_snapshot(&snap_path).expect("load snapshot");
        let r = Router::new(&s, p2).expect("router from snapshot");
        std::hint::black_box(
            r.retrieve(&queries[0], top_p, top_k)
                .expect("first query")
                .hits
                .len(),
        );
        t.elapsed().as_secs_f64()
    });
    // Correctness gate: the loaded snapshot answers bit-identically.
    {
        let (s, p2) = skm::persist::load_snapshot(&snap_path).expect("load snapshot");
        let r = Router::new(&s, p2).expect("router from snapshot");
        for q in queries.iter().take(64) {
            let a = router.retrieve(q, top_p, top_k).expect("hot");
            let b = r.retrieve(q, top_p, top_k).expect("warm");
            assert_eq!(a.hits.len(), b.hits.len(), "warm-restart soundness");
            for (x, y) in a.hits.iter().zip(&b.hits) {
                assert_eq!(x.0, y.0, "warm-restart hit id");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "warm-restart score bits");
            }
        }
    }
    let _ = std::fs::remove_file(&snap_path);
    println!(
        "persist: snapshot {:.2} MB, save {:.1} ms, warm restart (load+router+first query) {:.1} ms vs {:.2}s re-cluster ({:.0}x faster)",
        snapshot_bytes as f64 / 1e6,
        save_secs * 1e3,
        warm_secs * 1e3,
        cluster_secs,
        cluster_secs / warm_secs.max(1e-9)
    );

    // --- compressed snapshot + mmap-served queries ------------------------
    // Format v2 chunk-encodes the posting ids (delta+varint); the mmap
    // reader then serves corpus rows through an LRU block cache instead
    // of materializing the CSR. Bit-equality is gated before any timing.
    let v2_path =
        std::env::temp_dir().join(format!("skm_bench_serve_v2_{}.skm", std::process::id()));
    let v2_save_secs = best_of(reps, || {
        let t = Instant::now();
        skm::persist::save_snapshot_with(&v2_path, &snap, &params, true)
            .expect("save compressed snapshot");
        t.elapsed().as_secs_f64()
    });
    let v2_bytes = std::fs::metadata(&v2_path).expect("compressed stat").len();
    let compression_ratio = v2_bytes as f64 / snapshot_bytes.max(1) as f64;
    let cache_mb = skm::persist::mmap::DEFAULT_CACHE_MB;
    let cache_blocks = (cache_mb << 20) / skm::persist::format::BLOCK_CAP;
    let mmap_load_secs = best_of(reps, || {
        let t = Instant::now();
        let (s, p2) =
            skm::persist::load_snapshot_mmap(&v2_path, cache_blocks).expect("mmap load");
        let r = Router::new(&s, p2).expect("router over mmap");
        std::hint::black_box(
            r.retrieve(&queries[0], top_p, top_k)
                .expect("first mmap query")
                .hits
                .len(),
        );
        t.elapsed().as_secs_f64()
    });
    let (disk_snap, disk_params) =
        skm::persist::load_snapshot_mmap(&v2_path, cache_blocks).expect("mmap load");
    assert!(disk_snap.is_disk_backed(), "v2 snapshot must serve via mmap");
    let disk_router = Router::new(&disk_snap, disk_params).expect("router over mmap");
    // Correctness gate: mmap-served answers bit-match the in-RAM router.
    for q in queries.iter().take(64) {
        let a = router.retrieve(q, top_p, top_k).expect("ram");
        let b = disk_router.retrieve(q, top_p, top_k).expect("mmap");
        assert_eq!(a.hits.len(), b.hits.len(), "mmap soundness");
        for (x, y) in a.hits.iter().zip(&b.hits) {
            assert_eq!(x.0, y.0, "mmap hit id");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "mmap score bits");
        }
    }
    let mmap_secs = best_of(reps, || {
        let t = Instant::now();
        let (r, _) = serve_batch(
            &disk_router,
            &queries,
            top_p,
            top_k,
            &ParConfig::with_threads(batch_threads),
        );
        std::hint::black_box(r.len());
        t.elapsed().as_secs_f64()
    });
    let mmap_qps = queries.len() as f64 / mmap_secs;
    let (cache_hits, cache_misses) = disk_snap.disk_cache_counters();
    let hit_rate = cache_hits as f64 / (cache_hits + cache_misses).max(1) as f64;
    drop(disk_router);
    drop(disk_snap);
    let _ = std::fs::remove_file(&v2_path);
    println!(
        "compressed: {:.2} MB ({:.3}x of v1), save {:.1} ms; mmap warm restart {:.1} ms, \
         {batch_threads}-thread serving {mmap_qps:.0} QPS ({:.2}x of in-RAM, bit-equal), \
         block cache {cache_mb} MB hit rate {:.3}",
        v2_bytes as f64 / 1e6,
        compression_ratio,
        v2_save_secs * 1e3,
        mmap_load_secs * 1e3,
        mmap_qps / batch_qps.max(1e-12),
        hit_rate
    );
    if compression_ratio >= 1.0 {
        println!(
            "WARNING: compressed snapshot not smaller than uncompressed ({compression_ratio:.3}x) — \
             block padding dominates at this corpus size"
        );
    }

    // --- machine-readable baseline ----------------------------------------
    let json = Json::obj(vec![
        ("bench", Json::str("serve")),
        (
            "note",
            Json::str("regenerate with: cargo bench --bench serve"),
        ),
        (
            "dataset",
            Json::obj(vec![
                ("preset", Json::str("pubmed-like")),
                ("name", Json::str(snap.ds.name.clone())),
                ("n", Json::UInt(snap.ds.n() as u64)),
                ("d", Json::UInt(snap.ds.d() as u64)),
                ("k", Json::UInt(k as u64)),
                ("seed", Json::UInt(seed)),
            ]),
        ),
        (
            "router",
            Json::obj(vec![
                ("t_th", Json::UInt(router.t_th() as u64)),
                ("v_th", Json::Num(router.v_th())),
                ("top_p", Json::UInt(top_p as u64)),
                ("top_k", Json::UInt(top_k as u64)),
                ("index_mem_bytes", Json::UInt(router.mem_bytes() as u64)),
            ]),
        ),
        (
            "pruning",
            Json::obj(vec![
                ("avg_candidates_per_query", Json::Num(avg_candidates)),
                ("candidate_fraction", Json::Num(avg_candidates / k as f64)),
            ]),
        ),
        (
            "routing",
            Json::obj(vec![
                ("pruned_qps", Json::Num(route_qps)),
                ("brute_force_qps", Json::Num(brute_qps)),
                ("speedup", Json::Num(route_qps / brute_qps.max(1e-12))),
            ]),
        ),
        (
            "serial",
            Json::obj(vec![
                ("queries", Json::UInt(queries.len() as u64)),
                ("qps", Json::Num(serial_qps)),
                (
                    "latency_us",
                    Json::obj(vec![
                        ("mean", Json::Num(stats.mean_s * 1e6)),
                        ("p50", Json::Num(stats.p50_s * 1e6)),
                        ("p90", Json::Num(stats.p90_s * 1e6)),
                        ("p99", Json::Num(stats.p99_s * 1e6)),
                        ("max", Json::Num(stats.max_s * 1e6)),
                    ]),
                ),
            ]),
        ),
        (
            "batch",
            Json::obj(vec![
                ("threads", Json::UInt(batch_threads as u64)),
                ("qps", Json::Num(batch_qps)),
                ("speedup_vs_serial", Json::Num(speedup)),
                ("bitwise_equal", Json::Bool(bitwise_equal)),
            ]),
        ),
        (
            "persist",
            Json::obj(vec![
                ("snapshot_bytes", Json::UInt(snapshot_bytes)),
                ("save_ms", Json::Num(save_secs * 1e3)),
                ("warm_restart_ms", Json::Num(warm_secs * 1e3)),
                ("cluster_secs", Json::Num(cluster_secs)),
                (
                    "warm_vs_recluster_speedup",
                    Json::Num(cluster_secs / warm_secs.max(1e-9)),
                ),
                ("compressed_snapshot_bytes", Json::UInt(v2_bytes)),
                ("compressed_save_ms", Json::Num(v2_save_secs * 1e3)),
                ("compression_ratio", Json::Num(compression_ratio)),
            ]),
        ),
        (
            "mmap",
            Json::obj(vec![
                ("cache_mb", Json::UInt(cache_mb as u64)),
                ("warm_restart_ms", Json::Num(mmap_load_secs * 1e3)),
                ("qps", Json::Num(mmap_qps)),
                ("qps_vs_in_ram", Json::Num(mmap_qps / batch_qps.max(1e-12))),
                ("bitwise_equal", Json::Bool(true)),
                ("cache_hits", Json::UInt(cache_hits)),
                ("cache_misses", Json::UInt(cache_misses)),
                ("cache_hit_rate", Json::Num(hit_rate)),
            ]),
        ),
    ]);
    let path = std::env::var("SKM_BENCH_JSON").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    std::fs::write(&path, json.render_pretty()).expect("write bench json");
    println!("[wrote {path}]");
}
