//! Experiment Appendix H — Figs. 17–20: initial-state independence.
//!
//! For several K values, run ES-ICP from several random seedings and
//! measure (a) the pairwise NMI between the resulting clusterings
//! (Eqs. 49–50) and (b) the coefficient of variation of the objective J
//! and of the NMI (Eq. 51).
//!
//! Expected shape (paper Figs. 17–20): NMI rises toward ~0.9 and both
//! CVs fall toward 0 as K grows — seeding does not matter at large K.

mod common;

use common::{bench_preset, env_u64, header, save};
use skm::algo::{run_clustering, AlgoKind, ClusterConfig};
use skm::metrics::pairwise_nmi;
use skm::util::io::Table;
use skm::util::stats::coefficient_of_variation;

fn main() {
    for preset_name in ["pubmed-like", "nyt-like"] {
        run_one(preset_name);
    }
}

fn run_one(preset_name: &str) {
    let (p, ds, _) = bench_preset(preset_name);
    let n_seeds = env_u64("SKM_SEEDS", 5) as usize;
    header(
        "exp_seeding",
        "initial-state independence (Figs 17-20)",
        &ds,
        p.k,
    );

    let ks: Vec<usize> = [10usize, 40, 160, p.k.max(320)]
        .iter()
        .cloned()
        .filter(|&k| k <= ds.n() / 2)
        .collect();

    let mut t = Table::new(vec!["K", "NMI_mean", "NMI_std", "CV_J", "CV_NMI"]);
    let mut prev_nmi = 0.0;
    for &k in &ks {
        eprintln!("K={k}: {n_seeds} seeds ...");
        let mut labelings = Vec::new();
        let mut objectives = Vec::new();
        for s in 0..n_seeds {
            let cfg = ClusterConfig {
                k,
                seed: 1000 + s as u64,
                max_iters: 60,
                ..Default::default()
            };
            let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
            objectives.push(out.objective);
            labelings.push(out.assign);
        }
        let (nmi_mean, nmi_std) = pairwise_nmi(&labelings);
        let nmis: Vec<f64> = {
            let mut v = Vec::new();
            for i in 0..labelings.len() {
                for j in (i + 1)..labelings.len() {
                    v.push(skm::metrics::nmi(&labelings[i], &labelings[j]));
                }
            }
            v
        };
        let cv_j = coefficient_of_variation(&objectives);
        let cv_nmi = coefficient_of_variation(&nmis);
        println!(
            "K={k:<6} NMI={nmi_mean:.4} (+/-{nmi_std:.4})  CV(J)={cv_j:.5}  CV(NMI)={cv_nmi:.5}"
        );
        t.row(vec![
            k.to_string(),
            format!("{nmi_mean:.4}"),
            format!("{nmi_std:.4}"),
            format!("{cv_j:.5}"),
            format!("{cv_nmi:.5}"),
        ]);
        prev_nmi = nmi_mean;
    }
    let _ = prev_nmi;
    save("exp_seeding", &format!("{preset_name}_figs17_20"), &t);

    // Shape: NMI at the largest K exceeds NMI at the smallest; CV(J)
    // shrinks.
    let first = &t.rows[0];
    let last = &t.rows[t.rows.len() - 1];
    let nmi_first: f64 = first[1].parse().unwrap();
    let nmi_last: f64 = last[1].parse().unwrap();
    let cvj_first: f64 = first[3].parse().unwrap();
    let cvj_last: f64 = last[3].parse().unwrap();
    println!(
        "shape checks: NMI grows with K: {} ({nmi_first:.3} -> {nmi_last:.3}); CV(J) shrinks: {} ({cvj_first:.4} -> {cvj_last:.4})\n",
        if nmi_last > nmi_first { "OK" } else { "MISMATCH" },
        if cvj_last < cvj_first { "OK" } else { "MISMATCH" },
    );
}
