//! Experiment Appendix D — Figs. 15–16 and Tables VIII–XII: ablation of
//! the ES filter's two structural parameters.
//!
//! Variants: ES (both parameters), ThV (v_th only, t_th = 0), ThT
//! (t_th only, v_th = 1), vs MIVI and the full ES-ICP.
//!
//! Expected shape (paper): ES ≈ ThV in Mult/CPR/time (v_th does the
//! pruning); ThV needs ~6× the memory (its partial index spans all of
//! D); ThT prunes barely at all (≈ MIVI) but keeps memory low —
//! i.e. v_th buys pruning, t_th buys memory.

mod common;

use common::{bench_preset, header, save};
use skm::algo::AlgoKind;
use skm::coordinator::compare::absolute_table;
use skm::coordinator::{comparison_rate_table, run_and_summarize};
use skm::util::io::Table;

fn main() {
    for preset_name in ["pubmed-like", "nyt-like"] {
        run_one(preset_name);
    }
}

fn run_one(preset_name: &str) {
    let (p, ds, seed) = bench_preset(preset_name);
    let cfg = p.config(seed);
    header("exp_ablation", "ES ablation (Figs 15-16, Tables VIII-XII)", &ds, cfg.k);

    let suite = [
        AlgoKind::Mivi,
        AlgoKind::Es,
        AlgoKind::ThV,
        AlgoKind::ThT,
        AlgoKind::EsIcp,
    ];
    let mut outs = Vec::new();
    let mut summaries = Vec::new();
    for kind in suite {
        eprintln!("running {} ...", kind.name());
        let (out, s) = run_and_summarize(kind, &ds, &cfg);
        outs.push(out);
        summaries.push(s);
    }
    for o in &outs[1..] {
        assert_eq!(o.assign, outs[0].assign, "{:?} diverged from MIVI", o.algo);
    }

    // Figs 15(a,b) & 16: per-iteration Mult / CPR / time.
    let mut fig = Table::new(vec![
        "iter", "mult_MIVI", "mult_ES", "mult_ThV", "mult_ThT", "cpr_ES", "cpr_ThV", "cpr_ThT",
        "t_MIVI", "t_ES", "t_ThV", "t_ThT",
    ]);
    let iters = outs.iter().map(|o| o.logs.len()).min().unwrap();
    for i in 0..iters {
        fig.row(vec![
            (i + 1).to_string(),
            outs[0].logs[i].counters.mult.to_string(),
            outs[1].logs[i].counters.mult.to_string(),
            outs[2].logs[i].counters.mult.to_string(),
            outs[3].logs[i].counters.mult.to_string(),
            format!("{:.6}", outs[1].logs[i].cpr),
            format!("{:.6}", outs[2].logs[i].cpr),
            format!("{:.6}", outs[3].logs[i].cpr),
            format!("{:.4}", outs[0].logs[i].assign_secs),
            format!("{:.4}", outs[1].logs[i].assign_secs),
            format!("{:.4}", outs[2].logs[i].assign_secs),
            format!("{:.4}", outs[3].logs[i].assign_secs),
        ]);
    }
    save("exp_ablation", &format!("{preset_name}_figs15_16"), &fig);

    println!("\n[Tables IX/XI analog] absolute values:");
    println!("{}", absolute_table(&summaries).render());
    println!("[Table VIII analog] rates relative to ES-ICP:");
    let rates = comparison_rate_table(&summaries, "ES-ICP");
    println!("{}", rates.render());
    save("exp_ablation", &format!("{preset_name}_table8_rates"), &rates);

    let (mivi, es, thv, tht) = (&summaries[0], &summaries[1], &summaries[2], &summaries[3]);
    let ok = |b: bool| if b { "OK" } else { "MISMATCH" };
    println!("shape checks (Appendix D):");
    println!(
        "  v_th does the pruning — ES and ThV both ≪ MIVI mult: {} (ES {:.3}, ThV {:.3} of MIVI)",
        ok(es.avg_mult < 0.5 * mivi.avg_mult && thv.avg_mult < 0.5 * mivi.avg_mult),
        es.avg_mult / mivi.avg_mult,
        thv.avg_mult / mivi.avg_mult
    );
    println!(
        "  ThT prunes far less than the v_th variants: {} (ThT {:.3} of MIVI vs ES {:.3}; paper 0.85 vs 0.027)",
        ok(tht.avg_mult > 2.0 * es.avg_mult),
        tht.avg_mult / mivi.avg_mult,
        es.avg_mult / mivi.avg_mult
    );
    println!(
        "  t_th buys memory — ThV ≫ ES memory: {} (ThV {:.2}x ES; paper ~5.8x)",
        ok(thv.max_mem_gb > 1.5 * es.max_mem_gb),
        thv.max_mem_gb / es.max_mem_gb
    );
    println!(
        "  ThT memory lowest of the ES family: {} ({:.2}x ES)",
        ok(tht.max_mem_gb < es.max_mem_gb),
        tht.max_mem_gb / es.max_mem_gb
    );
    println!();
}
