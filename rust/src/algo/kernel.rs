//! The shared gather micro-kernels (§Perf tentpole) — the innermost
//! loops of every assignment step, extracted into one place so all six
//! assigners (`mivi`, `esicp`, `ta`, `cs`, `divi`, `ding`) run the
//! *same* tuned code instead of six hand-rolled copies.
//!
//! ## Why this module exists (the AFM argument)
//!
//! The paper's §III–IV analysis attributes MIVI-family speed to three
//! architecture-friendly properties of the gathering phase:
//!
//! 1. **Multiplication volume concentrates** on a few high-df terms
//!    against high mean-feature values (UC3), so the bytes that matter
//!    fit in cache *if the layout lets them stay there*;
//! 2. the two-block postings layout makes the moving-only scan
//!    **branch-free** (no per-entry `if moving` test);
//! 3. the scatter-add `ρ[c] += u·v` itself is a pure data-flow loop —
//!    every iteration is independent (distinct accumulator slots), so
//!    the only obstacles to peak throughput are *bounds checks*, *loop
//!    overhead*, and *cache misses on ρ / the postings stream*.
//!
//! The kernels here attack exactly those three: fixed-order 4-way
//! unrolling (less loop overhead, wider instruction window),
//! `get_unchecked` indexing guarded by debug assertions (no release-
//! mode bounds checks in the hottest loop of the codebase), and
//! software prefetch of upcoming ρ cache lines on x86_64 (the postings
//! stream is sequential and prefetches itself; the ρ scatter targets do
//! not). The companion memory-layout work lives in
//! [`crate::index::inverted`]: `u32` posting offsets (half the index
//! metadata traffic) and the dense Region-1 tail block whose gather is
//! [`dense_axpy`] — a contiguous FMA loop with zero indirection, the
//! paper's "frequently used data kept in cache" region made literal.
//!
//! ## Bit-exactness contract
//!
//! Every kernel performs **the same floating-point operations in the
//! same left-to-right order** as the naive scalar loop it replaces
//! (unrolling is purely mechanical: four sequential statements per
//! iteration, one accumulator, no reassociation). Results are therefore
//! bit-identical to the pre-kernel code — enforced against in-crate
//! scalar references by `rust/tests/kernel.rs` (random lengths,
//! remainders 1–3, empty slices, duplicate ids) and end-to-end by the
//! `parallel` / `incremental` equivalence suites.
//!
//! The dense path is the one deliberate re-ordering: a dense row adds
//! `u·w[j]` for *every* `j`, padding the absent entries with `w[j] = 0`.
//! Within one term each centroid appears at most once, so the adds land
//! in **distinct** accumulator slots and per-term ordering is
//! irrelevant; the padded adds contribute `u·0.0 = ±0.0`, and
//! `x + (±0.0)` is a bitwise no-op for every `x` except `x = -0.0`
//! (where `-0.0 + 0.0 = +0.0`). An accumulator that starts at `+0.0`
//! can never *become* `-0.0` under IEEE-754 addition (a sum is `-0.0`
//! only when both addends are `-0.0`), so the dense gather is bit-
//! identical to the sparse scatter for any accumulator initialized at
//! `+0.0` or above — which all assigners do (`0.0` or the nonnegative
//! `y_base`). `rust/tests/kernel.rs` checks this equivalence with
//! adversarial (negative / underflowing) values.
//!
//! ## Safety
//!
//! The posting-rate kernels ([`scatter_add`], [`scatter_add_unit`],
//! [`sparse_dot_dense`], [`scatter_add_versioned`]) are **`unsafe
//! fn`**: they index with `get_unchecked` and require every id to fall
//! inside the accumulator slice. The safe boundary sits where that
//! invariant is actually enforced — the [`crate::index`] builders
//! produce ids `< K` by construction and the assigners size their
//! scratch to `K` — so call sites carry one `SAFETY:` comment citing
//! exactly that. The invariant is additionally re-checked per call in
//! debug builds (full-slice scan); CI runs the suite optimized with
//! debug assertions enabled, and the kernel tests run under Miri. The
//! per-candidate scans ([`argmax_ids`], [`collect_above_ids`],
//! [`verify_axpy_ids`]) run once per survivor, not once per posting,
//! so they keep ordinary bounds-checked indexing and stay safe.

/// How many entries ahead of the current position the ρ prefetch runs.
/// Far enough to cover DRAM latency at ~4 entries/cycle, near enough
/// that the line is still resident when the store arrives.
const PREFETCH_AHEAD: usize = 16;

/// Prefetch the accumulator cache line targeted by `ids[at]` (x86_64
/// only; a no-op elsewhere — the scalar fallback the portability story
/// requires). Reads `ids` in bounds-checked fashion: `at` may run past
/// the end near the tail, where the prefetch simply stops.
#[inline(always)]
fn prefetch_acc(acc: &[f64], ids: &[u32], at: usize) {
    // Skipped under Miri: a prefetch has no observable semantics, and
    // the interpreter need not model the intrinsic.
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if let Some(&c) = ids.get(at) {
            let c = c as usize;
            if c < acc.len() {
                // SAFETY: `c < acc.len()` just checked; prefetch has no
                // architectural effect beyond the cache.
                unsafe {
                    core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(
                        acc.as_ptr().add(c) as *const i8,
                    );
                }
            }
        }
    }
    #[cfg(any(not(target_arch = "x86_64"), miri))]
    {
        let _ = (acc, ids, at);
    }
}

/// Debug-only validation of the unchecked-kernel invariant: parallel
/// slices, every id inside the accumulator.
#[inline(always)]
fn debug_check(acc: &[f64], ids: &[u32], vals: &[f64]) {
    debug_assert_eq!(ids.len(), vals.len(), "postings arrays must be parallel");
    debug_assert!(
        ids.iter().all(|&c| (c as usize) < acc.len()),
        "posting id out of accumulator range"
    );
}

/// Branch-free scatter-add over a postings slice:
/// `acc[ids[q]] += u * vals[q]` for `q` in order.
///
/// Fixed-order 4-way unrolled with `get_unchecked` indexing and ρ-line
/// prefetch; bit-identical to [`scatter_add_scalar`] (same operations,
/// same order — see the module docs). Duplicate ids are fine: the
/// strictly sequential order makes their accumulation well-defined.
///
/// # Safety
///
/// `ids.len() == vals.len()` and every id must be `< acc.len()`. Both
/// are debug-asserted per call; in-crate callers get them from the
/// [`crate::index`] builders (ids `< K`) with `K`-length accumulators.
#[inline]
pub unsafe fn scatter_add(acc: &mut [f64], ids: &[u32], vals: &[f64], u: f64) {
    debug_check(acc, ids, vals);
    let n = ids.len().min(vals.len());
    let mut q = 0usize;
    while q + 4 <= n {
        // Cover all four scatter targets of the block PREFETCH_AHEAD
        // entries out — the targets are effectively random lines, so
        // each needs its own prefetch.
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 1);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 2);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 3);
        // SAFETY: q+3 < n ≤ ids.len() == vals.len(); ids < acc.len() is
        // this function's contract, checked above in debug builds.
        unsafe {
            let c0 = *ids.get_unchecked(q) as usize;
            *acc.get_unchecked_mut(c0) += u * *vals.get_unchecked(q);
            let c1 = *ids.get_unchecked(q + 1) as usize;
            *acc.get_unchecked_mut(c1) += u * *vals.get_unchecked(q + 1);
            let c2 = *ids.get_unchecked(q + 2) as usize;
            *acc.get_unchecked_mut(c2) += u * *vals.get_unchecked(q + 2);
            let c3 = *ids.get_unchecked(q + 3) as usize;
            *acc.get_unchecked_mut(c3) += u * *vals.get_unchecked(q + 3);
        }
        q += 4;
    }
    while q < n {
        // SAFETY: q < n; same contract as above.
        unsafe {
            let c = *ids.get_unchecked(q) as usize;
            *acc.get_unchecked_mut(c) += u * *vals.get_unchecked(q);
        }
        q += 1;
    }
}

/// [`scatter_add`] without the weight: `acc[ids[q]] += vals[q]` (the CS
/// filter's squared-norm accumulation, which stores pre-squared values
/// and needs no per-object multiply).
///
/// # Safety
///
/// Same contract as [`scatter_add`]: parallel slices, every id
/// `< acc.len()` (debug-asserted).
#[inline]
pub unsafe fn scatter_add_unit(acc: &mut [f64], ids: &[u32], vals: &[f64]) {
    debug_check(acc, ids, vals);
    let n = ids.len().min(vals.len());
    let mut q = 0usize;
    while q + 4 <= n {
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 1);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 2);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 3);
        // SAFETY: as in `scatter_add`.
        unsafe {
            let c0 = *ids.get_unchecked(q) as usize;
            *acc.get_unchecked_mut(c0) += *vals.get_unchecked(q);
            let c1 = *ids.get_unchecked(q + 1) as usize;
            *acc.get_unchecked_mut(c1) += *vals.get_unchecked(q + 1);
            let c2 = *ids.get_unchecked(q + 2) as usize;
            *acc.get_unchecked_mut(c2) += *vals.get_unchecked(q + 2);
            let c3 = *ids.get_unchecked(q + 3) as usize;
            *acc.get_unchecked_mut(c3) += *vals.get_unchecked(q + 3);
        }
        q += 4;
    }
    while q < n {
        // SAFETY: as in `scatter_add`.
        unsafe {
            let c = *ids.get_unchecked(q) as usize;
            *acc.get_unchecked_mut(c) += *vals.get_unchecked(q);
        }
        q += 1;
    }
}

/// Naive bounds-checked scatter-add — the pre-kernel reference loop.
/// Kept for the bit-identity tests (`rust/tests/kernel.rs`) and the
/// scalar baseline of the gather-kernel bench section.
#[inline]
pub fn scatter_add_scalar(acc: &mut [f64], ids: &[u32], vals: &[f64], u: f64) {
    for (&c, &v) in ids.iter().zip(vals) {
        acc[c as usize] += u * v;
    }
}

/// Naive bounds-checked unit scatter-add (reference for
/// [`scatter_add_unit`]).
#[inline]
pub fn scatter_add_unit_scalar(acc: &mut [f64], ids: &[u32], vals: &[f64]) {
    for (&c, &v) in ids.iter().zip(vals) {
        acc[c as usize] += v;
    }
}

/// Dense gather over a Region-1 tail row: `acc[j] += u * row[j]` for
/// every `j` — contiguous streaming FMA, zero indirection, no scatter.
/// Used for terms inside the dense block of
/// [`crate::index::InvIndex`]; bit-identical to scatter-adding the
/// term's sparse postings under the `+0.0`-padding argument in the
/// module docs.
#[inline]
pub fn dense_axpy(acc: &mut [f64], row: &[f64], u: f64) {
    debug_assert_eq!(acc.len(), row.len(), "dense row must span the accumulator");
    let n = acc.len().min(row.len());
    let mut j = 0usize;
    while j + 4 <= n {
        // SAFETY: j+3 < n ≤ both lengths.
        unsafe {
            *acc.get_unchecked_mut(j) += u * *row.get_unchecked(j);
            *acc.get_unchecked_mut(j + 1) += u * *row.get_unchecked(j + 1);
            *acc.get_unchecked_mut(j + 2) += u * *row.get_unchecked(j + 2);
            *acc.get_unchecked_mut(j + 3) += u * *row.get_unchecked(j + 3);
        }
        j += 4;
    }
    while j < n {
        // SAFETY: j < n.
        unsafe {
            *acc.get_unchecked_mut(j) += u * *row.get_unchecked(j);
        }
        j += 1;
    }
}

/// The ρ-argmax scan over the whole accumulator, with the shared
/// tie-break semantics every assigner uses: keep `(amax, rmax)` unless
/// **strictly** better, lowest index first. Previously six hand-rolled
/// copies (`rho[j] > rmax` loops) drifting apart; now one.
#[inline]
pub fn argmax_scan(acc: &[f64], mut rmax: f64, mut amax: u32) -> (u32, f64) {
    for (j, &r) in acc.iter().enumerate() {
        if r > rmax {
            rmax = r;
            amax = j as u32;
        }
    }
    (amax, rmax)
}

/// [`argmax_scan`] restricted to a candidate id list (the survivor set
/// `Z`, or the moving-centroid list under ICP). Runs once per
/// candidate, not per posting, so ordinary bounds-checked indexing is
/// kept and the function stays safe (panics on an out-of-range id).
#[inline]
pub fn argmax_ids(acc: &[f64], ids: &[u32], mut rmax: f64, mut amax: u32) -> (u32, f64) {
    for &j in ids {
        let r = acc[j as usize];
        if r > rmax {
            rmax = r;
            amax = j;
        }
    }
    (amax, rmax)
}

/// The ES main filter over the whole accumulator: collect every index
/// whose (folded upper-bound) value strictly beats the threshold.
/// `z` is cleared first; callers pre-reserve it to K so pushes never
/// allocate (the §Perf allocation-free contract).
#[inline]
pub fn collect_above(acc: &[f64], thresh: f64, z: &mut Vec<u32>) {
    z.clear();
    for (j, &r) in acc.iter().enumerate() {
        if r > thresh {
            z.push(j as u32);
        }
    }
}

/// [`collect_above`] restricted to a candidate id list (the ICP
/// moving-centroid scan). Safe bounds-checked indexing, like
/// [`argmax_ids`].
#[inline]
pub fn collect_above_ids(acc: &[f64], ids: &[u32], thresh: f64, z: &mut Vec<u32>) {
    z.clear();
    for &j in ids {
        if acc[j as usize] > thresh {
            z.push(j);
        }
    }
}

/// Verification-phase update over the survivor list against one dense
/// partial-index row: `acc[j] += sign · u · row[j]` for `j ∈ z`.
/// ES retires deficits with `sign = -1`; CS adds exact Region-3
/// contributions with `sign = +1`. Runs once per survivor (the filters
/// already pruned the candidate set), so safe bounds-checked indexing
/// is kept.
#[inline]
pub fn verify_axpy_ids(acc: &mut [f64], z: &[u32], row: &[f64], u: f64, sign: f64) {
    let su = sign * u;
    for &j in z {
        let j = j as usize;
        acc[j] += su * row[j];
    }
}

/// Sparse·dense dot product in strict left-to-right term order —
/// Ding+'s exact similarity through the dense mean row (object term id
/// as direct key). One sequential accumulator, so the sum order (and
/// hence every bit) matches the naive loop; the win is the removed
/// bounds checks and unrolled loop control.
///
/// # Safety
///
/// `ts.len() == us.len()` and every term id must be `< row.len()`
/// (debug-asserted). In-crate callers pass CSR rows whose term ids are
/// `< D` with `D`-length dense mean rows.
#[inline]
pub unsafe fn sparse_dot_dense(ts: &[u32], us: &[f64], row: &[f64]) -> f64 {
    debug_assert_eq!(ts.len(), us.len());
    debug_assert!(ts.iter().all(|&t| (t as usize) < row.len()));
    let n = ts.len().min(us.len());
    let mut s = 0.0f64;
    let mut q = 0usize;
    while q + 4 <= n {
        // SAFETY: q+3 < n; term ids in range is the caller invariant,
        // checked above in debug builds.
        unsafe {
            s += *us.get_unchecked(q) * *row.get_unchecked(*ts.get_unchecked(q) as usize);
            s += *us.get_unchecked(q + 1)
                * *row.get_unchecked(*ts.get_unchecked(q + 1) as usize);
            s += *us.get_unchecked(q + 2)
                * *row.get_unchecked(*ts.get_unchecked(q + 2) as usize);
            s += *us.get_unchecked(q + 3)
                * *row.get_unchecked(*ts.get_unchecked(q + 3) as usize);
        }
        q += 4;
    }
    while q < n {
        // SAFETY: as above.
        unsafe {
            s += *us.get_unchecked(q) * *row.get_unchecked(*ts.get_unchecked(q) as usize);
        }
        q += 1;
    }
    s
}

/// DIVI's epoch-versioned scatter-add (the deliberately cache-hostile
/// strawman loop, kept faithful): `score[i − lo] += u·v` with lazy
/// per-epoch reset and a touched list. Returns nothing; the caller
/// accounts `ids.len()` multiplications and irregular branches.
///
/// # Safety
///
/// Ids must be global object ids in `[lo, lo + score.len())` and
/// `version.len() >= score.len()` (debug-asserted). In-crate callers
/// pass posting slices already restricted to the shard's id range.
#[inline]
#[allow(clippy::too_many_arguments)]
pub unsafe fn scatter_add_versioned(
    score: &mut [f64],
    version: &mut [u32],
    touched: &mut Vec<u32>,
    epoch: u32,
    ids: &[u32],
    vals: &[f64],
    u: f64,
    lo: usize,
) {
    debug_assert_eq!(ids.len(), vals.len());
    debug_assert!(version.len() >= score.len());
    debug_assert!(ids
        .iter()
        .all(|&i| (i as usize) >= lo && (i as usize) - lo < score.len()));
    for (&i, &v) in ids.iter().zip(vals) {
        let li = i as usize - lo;
        // SAFETY: caller invariant, checked above in debug builds.
        unsafe {
            if *version.get_unchecked(li) != epoch {
                *version.get_unchecked_mut(li) = epoch;
                *score.get_unchecked_mut(li) = 0.0;
                touched.push(li as u32);
            }
            *score.get_unchecked_mut(li) += u * v;
        }
    }
}
