//! Sharded multi-threaded execution of the assignment step (§Perf).
//!
//! The assignment step of every algorithm in this crate is
//! *embarrassingly parallel over objects*: the new assignment of object
//! `i` depends only on the read-only per-iteration structures (the mean
//! set / structured index built by `rebuild`) and on object `i`'s own
//! previous state (`assign[i]`, `rho[i]`, `xstate[i]`). The engine here
//! exploits that by chunking the objects into contiguous **shards**,
//! processing shards on a [`std::thread::scope`] pool, and merging the
//! per-shard [`OpCounters`] / change counts in fixed shard order.
//!
//! **Determinism contract.** Because every object's computation performs
//! exactly the same floating-point operations in exactly the same order
//! as the serial path (each shard runs the serial per-object routine),
//! and the counter merge is integer addition, the parallel engine is
//! **bit-identical** to the serial path — same assignments, same
//! objective trajectory, same counters — for any `threads`/`shard`
//! combination. `rust/tests/parallel.rs` enforces this for all
//! [`super::AlgoKind`]s.
//!
//! The update step is parallelized over *clusters* with the same
//! guarantee (each cluster's mean is computed by the serial per-cluster
//! routine); see [`crate::index::update_means_with_rho_par`].
//!
//! **Fault containment (§Robustness).** A panicking shard must not take
//! down the others, and must not poison the shared state. The engine
//! guarantees:
//!
//! * every queue/pool lock uses [`lock_unpoisoned`], so an unwind while
//!   holding a lock never wedges the remaining workers (the protected
//!   values — a work list, a scratch vec, integer phase times — are
//!   valid after any partial mutation);
//! * each shard executes under [`std::panic::catch_unwind`]; a panic is
//!   recorded per shard while every other shard (including later shards
//!   pulled by the same worker thread) runs to completion, bit-identical
//!   to a fault-free run;
//! * after the scope joins, a recorded fault is re-raised as a single
//!   structured [`SkmError::WorkerPanic`] panic payload naming the first
//!   failing shard — so `run_sharded` keeps its infallible signature for
//!   the bit-pinned callers, while [`crate::error::contain`] boundaries
//!   ([`crate::algo::try_run_clustering_with`]) receive a typed error
//!   instead of a scope abort. `rust/tests/faults.rs` proves all three.

use crate::error::SkmError;
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::PhaseTimes;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, tolerating poison: if a previous holder panicked, take
/// the guard anyway. Sound for every mutex in this crate's engines —
/// they protect structurally-simple values (work queues, scratch pools,
/// additive counters) that are valid after any interrupted mutation;
/// result correctness never depends on lock-protected state because
/// result slots are owned exclusively per shard/query.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A pool of per-worker scratch objects (§Perf: the allocation-free
/// iteration loop). Assignment-step scratch — ρ accumulators, survivor
/// lists, bound arrays — used to be allocated on every `assign_range`
/// call; pooling hoists it into persistent storage reused across
/// iterations, so the steady-state assignment loop performs **zero**
/// heap allocations (enforced by `rust/tests/alloc_free.rs`).
///
/// The pooled accumulators are exactly the scatter targets of the
/// [`crate::algo::kernel`] micro-kernels: one K-length ρ array per
/// worker stays hot in that worker's private cache across every object
/// of its shard — the cache-residency half of the AFM argument, while
/// the kernels supply the branch-free instruction stream half. The
/// kernels' safety contract (ids `< K`) is guaranteed here by
/// construction: scratch is sized to `K` on checkout and the shared
/// index is read-only for the whole assignment step.
///
/// Workers `checkout` a scratch at shard start and `checkin` at shard
/// end, folding their locally accumulated [`PhaseTimes`] into the pool;
/// the coordinator drains the merged phases once per iteration. Scratch
/// contents are fully reset per object, so *which* pooled instance a
/// worker gets never affects results — the engine stays bit-identical
/// to the serial path.
pub struct ScratchPool<T> {
    items: Mutex<Vec<T>>,
    phases: Mutex<PhaseTimes>,
}

impl<T> Default for ScratchPool<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ScratchPool<T> {
    pub fn new() -> Self {
        Self {
            items: Mutex::new(Vec::new()),
            phases: Mutex::new(PhaseTimes::default()),
        }
    }

    /// Pop a pooled scratch, or create one with `make` (first use only).
    pub fn checkout(&self, make: impl FnOnce() -> T) -> T {
        let pooled = lock_unpoisoned(&self.items).pop();
        pooled.unwrap_or_else(make)
    }

    /// Return a scratch to the pool and fold in the shard's phase times.
    pub fn checkin(&self, item: T, phases: PhaseTimes) {
        lock_unpoisoned(&self.phases).add(&phases);
        lock_unpoisoned(&self.items).push(item);
    }

    /// Take (and reset) the phase times accumulated since the last drain.
    pub fn drain_phases(&self) -> PhaseTimes {
        std::mem::take(&mut *lock_unpoisoned(&self.phases))
    }

    /// Bytes held by all pooled scratch objects, as reported by `f`
    /// (Max-MEM accounting of the persistent scratch).
    pub fn mem_bytes(&self, f: impl Fn(&T) -> usize) -> usize {
        lock_unpoisoned(&self.items).iter().map(f).sum()
    }
}

/// Configuration of the sharded execution engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParConfig {
    /// Worker threads for the assignment and update steps. `0` and `1`
    /// both mean serial execution on the calling thread.
    pub threads: usize,
    /// Objects per shard. `0` selects one contiguous shard per thread
    /// (`ceil(N / threads)`), which minimizes scratch allocations; small
    /// explicit shards trade that for better load balance.
    pub shard: usize,
}

impl Default for ParConfig {
    fn default() -> Self {
        Self::serial()
    }
}

impl ParConfig {
    /// Serial execution (the reference path).
    pub fn serial() -> Self {
        Self {
            threads: 1,
            shard: 0,
        }
    }

    /// `threads` workers with auto shard size.
    pub fn with_threads(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
            shard: 0,
        }
    }

    /// Read `SKM_THREADS` / `SKM_SHARD` (both optional; defaults are
    /// serial). This is how the bench harnesses and
    /// `coordinator::run_and_summarize` pick up parallelism without
    /// signature churn.
    pub fn from_env() -> Self {
        let get = |name: &str| {
            std::env::var(name)
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
        };
        Self {
            threads: get("SKM_THREADS").unwrap_or(1).max(1),
            shard: get("SKM_SHARD").unwrap_or(0),
        }
    }

    pub fn is_parallel(&self) -> bool {
        self.threads > 1
    }

    /// Effective shard size for `n` objects (always ≥ 1).
    pub fn shard_size(&self, n: usize) -> usize {
        let auto = {
            let t = self.threads.max(1);
            (n + t - 1) / t.max(1)
        };
        let s = if self.shard > 0 { self.shard } else { auto };
        s.max(1)
    }
}

/// Re-raise faults recorded by the sharded drivers as one structured
/// panic payload ([`SkmError::WorkerPanic`]) naming the first failing
/// shard — callers keep the infallible bit-pinned signature, while a
/// [`crate::error::contain`] boundary up-stack receives the typed error
/// unchanged (see [`SkmError::from_panic`]'s pass-through).
fn raise_shard_faults(site: &str, n_shards: usize, faults: Vec<(usize, String)>) {
    if faults.is_empty() {
        return;
    }
    let mut faults = faults;
    faults.sort_by_key(|&(lo, _)| lo);
    let (lo, ref msg) = faults[0];
    std::panic::panic_any(SkmError::WorkerPanic {
        site: site.to_string(),
        detail: format!(
            "{} of {} shards panicked; first: shard at object {} ({})",
            faults.len(),
            n_shards,
            lo,
            msg
        ),
    });
}

/// Run `f` over contiguous shards of `assign`, in parallel when
/// `par.is_parallel()`, and merge the per-shard results in fixed shard
/// order. `f(lo, chunk)` receives the global index of the first object
/// in the shard and the shard's mutable slice of the assignment vector
/// (holding the *previous* assignments on entry; `f` writes the new
/// ones in place, exactly like the serial per-object loops do).
///
/// A panic inside `f` is contained to its shard: every other shard
/// still completes bit-identically, and the fault is re-raised after
/// the join as a structured [`SkmError::WorkerPanic`] payload (see the
/// module docs). Catch it with [`crate::algo::try_run_clustering_with`]
/// or [`crate::error::contain`].
pub fn run_sharded<F>(par: &ParConfig, assign: &mut [u32], f: F) -> (OpCounters, usize)
where
    F: Fn(usize, &mut [u32]) -> (OpCounters, usize) + Sync,
{
    let n = assign.len();
    if !par.is_parallel() || n == 0 {
        crate::failpoint!("algo.assign_shard", 0u64);
        return f(0, assign);
    }
    let shard = par.shard_size(n);
    let n_shards = (n + shard - 1) / shard;
    let threads = par.threads.min(n_shards).max(1);
    let mut results: Vec<(OpCounters, usize)> = vec![(OpCounters::new(), 0); n_shards];
    let faults: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());

    {
        // Shared work queue: workers pull shards as they finish, so
        // many small shards genuinely load-balance uneven objects.
        // Which worker runs which shard varies run to run, but results
        // are merged by shard index below, so the output is
        // deterministic regardless.
        let work: Vec<(usize, &mut [u32], &mut (OpCounters, usize))> = assign
            .chunks_mut(shard)
            .zip(results.iter_mut())
            .enumerate()
            .map(|(si, (chunk, slot))| (si * shard, chunk, slot))
            .collect();
        let queue = std::sync::Mutex::new(work);
        let queue = &queue;
        let f = &f;
        let faults = &faults;
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(move || loop {
                    let item = lock_unpoisoned(queue).pop();
                    match item {
                        Some((lo, chunk, slot)) => {
                            // Contain a panicking shard: the worker
                            // records it and moves on to the next
                            // shard, so unaffected shards stay
                            // bit-identical to a fault-free run.
                            let r = catch_unwind(AssertUnwindSafe(|| {
                                crate::failpoint!("algo.assign_shard", lo);
                                f(lo, chunk)
                            }));
                            match r {
                                Ok(out) => *slot = out,
                                Err(payload) => lock_unpoisoned(faults)
                                    .push((lo, crate::error::panic_message(payload.as_ref()))),
                            }
                        }
                        None => break,
                    }
                });
            }
        });
    }

    raise_shard_faults("algo.assign_shard", n_shards, faults.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner));

    let mut counters = OpCounters::new();
    let mut changes = 0usize;
    for &(c, ch) in &results {
        counters.add(&c);
        changes += ch;
    }
    (counters, changes)
}

/// [`run_sharded`] with an additional per-object mutable state array
/// (`per_obj` entries per object, e.g. Ding+'s group-bound matrix),
/// split along the same shard boundaries so each worker owns its
/// objects' state exclusively. Also reused by the mini-batch update
/// step ([`crate::index::update_means_minibatch_inplace`]) to shard
/// per-cluster staging over cluster ranges: there the "objects" are
/// touched cluster ids and `extra` holds one staged-result slot per
/// cluster, so the fixed-order merge/apply recipe carries over
/// unchanged.
pub fn run_sharded_with<T, F>(
    par: &ParConfig,
    assign: &mut [u32],
    extra: &mut [T],
    per_obj: usize,
    f: F,
) -> (OpCounters, usize)
where
    T: Send,
    F: Fn(usize, &mut [u32], &mut [T]) -> (OpCounters, usize) + Sync,
{
    let n = assign.len();
    assert_eq!(extra.len(), n * per_obj, "per-object state size mismatch");
    if !par.is_parallel() || n == 0 {
        crate::failpoint!("algo.assign_shard", 0u64);
        return f(0, assign, extra);
    }
    let shard = par.shard_size(n);
    let n_shards = (n + shard - 1) / shard;
    let threads = par.threads.min(n_shards).max(1);
    let mut results: Vec<(OpCounters, usize)> = vec![(OpCounters::new(), 0); n_shards];
    let faults: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());

    {
        // Shared work queue, exactly as in [`run_sharded`].
        let work: Vec<(usize, &mut [u32], &mut [T], &mut (OpCounters, usize))> = assign
            .chunks_mut(shard)
            .zip(extra.chunks_mut(shard * per_obj))
            .zip(results.iter_mut())
            .enumerate()
            .map(|(si, ((chunk, ext), slot))| (si * shard, chunk, ext, slot))
            .collect();
        let queue = std::sync::Mutex::new(work);
        let queue = &queue;
        let f = &f;
        let faults = &faults;
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(move || loop {
                    let item = lock_unpoisoned(queue).pop();
                    match item {
                        Some((lo, chunk, ext, slot)) => {
                            // Same per-shard containment as run_sharded.
                            let r = catch_unwind(AssertUnwindSafe(|| {
                                crate::failpoint!("algo.assign_shard", lo);
                                f(lo, chunk, ext)
                            }));
                            match r {
                                Ok(out) => *slot = out,
                                Err(payload) => lock_unpoisoned(faults)
                                    .push((lo, crate::error::panic_message(payload.as_ref()))),
                            }
                        }
                        None => break,
                    }
                });
            }
        });
    }

    raise_shard_faults("algo.assign_shard", n_shards, faults.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner));

    let mut counters = OpCounters::new();
    let mut changes = 0usize;
    for &(c, ch) in &results {
        counters.add(&c);
        changes += ch;
    }
    (counters, changes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_pool_reuses_and_merges_phases() {
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        let mut a = pool.checkout(|| Vec::with_capacity(16));
        a.push(1);
        pool.checkin(
            a,
            PhaseTimes {
                gather: 1.0,
                ..Default::default()
            },
        );
        let b = pool.checkout(Vec::new);
        assert!(b.capacity() >= 16, "pooled scratch was not reused");
        pool.checkin(
            b,
            PhaseTimes {
                verify: 2.0,
                ..Default::default()
            },
        );
        let ph = pool.drain_phases();
        assert_eq!(ph.gather, 1.0);
        assert_eq!(ph.verify, 2.0);
        assert_eq!(pool.drain_phases().total(), 0.0);
        assert!(pool.mem_bytes(|v| v.capacity()) >= 16);
    }

    #[test]
    fn shard_size_auto_and_explicit() {
        let p = ParConfig::with_threads(4);
        assert_eq!(p.shard_size(100), 25);
        assert_eq!(p.shard_size(101), 26);
        assert_eq!(p.shard_size(3), 1);
        let q = ParConfig { threads: 4, shard: 7 };
        assert_eq!(q.shard_size(100), 7);
        assert_eq!(ParConfig::serial().shard_size(10), 10);
        assert_eq!(ParConfig::serial().shard_size(0), 1);
    }

    /// The sharded driver must agree with the serial closure application
    /// for every threads/shard combination, including counter merging.
    #[test]
    fn sharded_matches_serial_closure() {
        let n = 103;
        let step = |lo: usize, chunk: &mut [u32]| {
            let mut c = OpCounters::new();
            let mut changes = 0;
            for (off, slot) in chunk.iter_mut().enumerate() {
                let i = (lo + off) as u32;
                let next = (*slot).wrapping_mul(31).wrapping_add(i) % 17;
                c.mult += u64::from(next) + 1;
                c.candidates += 1;
                if next != *slot {
                    *slot = next;
                    changes += 1;
                }
            }
            (c, changes)
        };

        let mut base: Vec<u32> = (0..n as u32).map(|i| i % 5).collect();
        let (bc, bch) = run_sharded(&ParConfig::serial(), &mut base, step);

        for threads in [2usize, 4, 7] {
            for shard in [0usize, 1, 13, 64] {
                let mut v: Vec<u32> = (0..n as u32).map(|i| i % 5).collect();
                let par = ParConfig { threads, shard };
                let (c, ch) = run_sharded(&par, &mut v, step);
                assert_eq!(v, base, "threads={threads} shard={shard}");
                assert_eq!(c, bc, "threads={threads} shard={shard}");
                assert_eq!(ch, bch, "threads={threads} shard={shard}");
            }
        }
    }

    /// A panicking shard is contained: every other shard's writes land
    /// exactly as in a fault-free run, the shared queue survives, and
    /// the fault resurfaces as a typed `WorkerPanic` (via `contain`).
    #[test]
    fn sharded_contains_a_panicking_shard() {
        let n = 64usize;
        let poison_lo = 16usize; // start of the shard we kill
        let step = |lo: usize, chunk: &mut [u32]| {
            if lo == poison_lo {
                panic!("shard {lo} exploded");
            }
            for (off, slot) in chunk.iter_mut().enumerate() {
                *slot = (lo + off) as u32 + 1000;
            }
            (OpCounters::new(), chunk.len())
        };
        let mut v = vec![0u32; n];
        let par = ParConfig { threads: 4, shard: 16 };
        let err = crate::error::contain("algo.run", || run_sharded(&par, &mut v, step))
            .unwrap_err();
        match err {
            SkmError::WorkerPanic { site, detail } => {
                assert_eq!(site, "algo.assign_shard");
                assert!(detail.contains("1 of 4 shards"), "{detail}");
                assert!(detail.contains("object 16"), "{detail}");
                assert!(detail.contains("shard 16 exploded"), "{detail}");
            }
            other => panic!("wrong variant: {other:?}"),
        }
        for i in 0..n {
            if (poison_lo..poison_lo + 16).contains(&i) {
                assert_eq!(v[i], 0, "killed shard must be untouched");
            } else {
                assert_eq!(v[i], i as u32 + 1000, "unaffected shard diverged");
            }
        }
    }

    /// The scratch pool must keep working after a panic unwound through
    /// a checkout/checkin sequence (poison tolerance).
    #[test]
    fn scratch_pool_survives_a_panicking_holder() {
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        pool.checkin(vec![7u8; 4], PhaseTimes::default());
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _guard = lock_unpoisoned(&pool.items);
            panic!("holder dies with the lock");
        }));
        assert!(r.is_err());
        let got = pool.checkout(Vec::new);
        assert_eq!(got, vec![7u8; 4], "pool unusable after poison");
        pool.checkin(got, PhaseTimes::default());
        assert!(pool.mem_bytes(|v| v.capacity()) >= 4);
    }

    #[test]
    fn sharded_with_extra_state_partitions_cleanly() {
        let n = 50;
        let per = 3;
        let step = |lo: usize, chunk: &mut [u32], ext: &mut [f64]| {
            assert_eq!(ext.len(), chunk.len() * per);
            for (off, slot) in chunk.iter_mut().enumerate() {
                let i = lo + off;
                for g in 0..per {
                    ext[off * per + g] += (i * per + g) as f64;
                }
                *slot = i as u32;
            }
            (OpCounters::new(), chunk.len())
        };
        for par in [ParConfig::serial(), ParConfig { threads: 3, shard: 8 }] {
            let mut assign = vec![0u32; n];
            let mut extra = vec![0.0f64; n * per];
            let (_, ch) = run_sharded_with(&par, &mut assign, &mut extra, per, step);
            assert_eq!(ch, n);
            for i in 0..n {
                assert_eq!(assign[i], i as u32);
                for g in 0..per {
                    assert_eq!(extra[i * per + g], (i * per + g) as f64);
                }
            }
        }
    }
}
