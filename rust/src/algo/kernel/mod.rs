//! The shared gather micro-kernels (§Perf tentpole) — the innermost
//! loops of every assignment step, extracted into one place so all six
//! assigners (`mivi`, `esicp`, `ta`, `cs`, `divi`, `ding`) and the
//! serving router run the *same* tuned code instead of seven
//! hand-rolled copies.
//!
//! ## Why this module exists (the AFM argument)
//!
//! The paper's §III–IV analysis attributes MIVI-family speed to three
//! architecture-friendly properties of the gathering phase:
//!
//! 1. **Multiplication volume concentrates** on a few high-df terms
//!    against high mean-feature values (UC3), so the bytes that matter
//!    fit in cache *if the layout lets them stay there*;
//! 2. the two-block postings layout makes the moving-only scan
//!    **branch-free** (no per-entry `if moving` test);
//! 3. the scatter-add `ρ[c] += u·v` itself is a pure data-flow loop —
//!    every iteration is independent (distinct accumulator slots), so
//!    the only obstacles to peak throughput are *bounds checks*, *loop
//!    overhead*, *cache misses on ρ / the postings stream*, and — once
//!    those are gone — the **scalar multiply width** itself.
//!
//! PR 3 attacked the first three (unrolling, `get_unchecked`, prefetch,
//! `u32` offsets, the dense Region-1 tail). This module now also
//! recovers the multiply width: explicit SIMD paths (AVX2, AVX-512F,
//! NEON) selected **once at startup** into a [`KernelTable`] of
//! function pointers shared by every worker — the paper's "share the
//! structure with all objects" move applied to ISA selection, so the
//! per-call dispatch is a perfectly predicted indirect branch.
//!
//! ## Runtime dispatch
//!
//! * The active backend resolves once from `SKM_KERNEL`
//!   (`scalar|avx2|avx512|neon|auto`, default `auto` = best ISA the
//!   host supports, detected via `is_x86_feature_detected!` /
//!   `cfg(target_arch = "aarch64")`). Requesting an ISA the host lacks
//!   is a **hard error** (panic with a clear message), never UB:
//!   [`resolve_backend`] refuses before any `#[target_feature]` code
//!   can run.
//! * Under Miri the scalar table is used unconditionally — the
//!   interpreter validates the `get_unchecked` arithmetic, not vendor
//!   intrinsics.
//! * [`force_backend`] / [`reset_backend`] swap the active table for
//!   tests and benches ([`Backend::available`] enumerates what the
//!   host can run). Production code never calls them.
//!
//! ## Bit-exactness contract (per kernel)
//!
//! Every dispatched path is **bit-identical** to the scalar oracle; the
//! per-kernel arguments, each enforced by fuzz in `rust/tests/kernel.rs`
//! and `rust/tests/simd.rs`:
//!
//! * [`dense_axpy`]: vector lanes compute `mul` then `add` as two
//!   separately-rounded IEEE-754 ops — exactly the scalar
//!   `acc[j] += u * row[j]` sequence. **No FMA contraction** on either
//!   side: rustc never enables floating-point contraction (only an
//!   explicit `f64::mul_add` or FMA intrinsic fuses, and none appears
//!   on the bit-exact paths), so the "provably absent" claim reduces to
//!   the absence of those tokens — grep-checkable.
//! * [`scatter_add`] / [`scatter_add_unit`]: within one posting list a
//!   centroid id appears **at most once** (the index builders emit one
//!   posting per (term, centroid) — the same distinct-slot argument the
//!   dense-tail docs make), so the lanes of a gather→mul→add→store
//!   block touch pairwise-distinct accumulator slots and per-block
//!   reordering cannot change any slot's operation sequence. Distinct
//!   ids are therefore part of these kernels' `unsafe` contract
//!   (debug-asserted per call).
//! * [`argmax_scan`]: the SIMD scan keeps a per-lane running (value,
//!   index) pair updated on **strictly-greater** compares, then reduces
//!   lanes with an explicit lowest-index-wins tie-break and finally
//!   applies one strictly-greater compare against the caller's initial
//!   `(amax, rmax)` — reproducing the scalar scan's first-occurrence
//!   semantics bit for bit, signed zeros included (a later `+0.0` never
//!   displaces an earlier `-0.0`, exactly like the scalar `>`).
//! * [`collect_above`]: compare-mask + movemask, emitting indices in
//!   ascending order via trailing-zeros iteration — same output order,
//!   same strict `>` threshold.
//! * [`verify_axpy_ids`] stays a *safe* fn: the SIMD path first checks
//!   the survivor list is strictly ascending and in bounds (true for
//!   every in-crate caller — `collect_above*` output is ascending) and
//!   otherwise falls back to the scalar loop, preserving exact
//!   semantics (including panic behavior) for all safe inputs.
//! * [`sparse_dot_dense`] keeps its **sequential scalar accumulator**
//!   under every backend: a lane-parallel dot product reassociates the
//!   sum and breaks bits. The opt-in `relaxed-simd` cargo feature
//!   (documented, off by default, excluded from the golden/equivalence
//!   suites) replaces it with a 4/8-lane accumulator on x86 — still
//!   deterministic for a fixed backend, but **not** bit-identical to
//!   scalar.
//! * [`scatter_add_versioned`] (DIVI's deliberately cache-hostile
//!   strawman) and the per-candidate scans ([`argmax_ids`],
//!   [`collect_above_ids`]) stay scalar on every backend: the former is
//!   kept faithful to the baseline being measured, the latter run once
//!   per survivor, not once per posting.
//!
//! The dense path is the one deliberate re-ordering: a dense row adds
//! `u·w[j]` for *every* `j`, padding the absent entries with `w[j] = 0`.
//! Within one term each centroid appears at most once, so the adds land
//! in **distinct** accumulator slots and per-term ordering is
//! irrelevant; the padded adds contribute `u·0.0 = ±0.0`, and
//! `x + (±0.0)` is a bitwise no-op for every `x` except `x = -0.0`
//! (where `-0.0 + 0.0 = +0.0`). An accumulator that starts at `+0.0`
//! can never *become* `-0.0` under IEEE-754 addition (a sum is `-0.0`
//! only when both addends are `-0.0`), so the dense gather is bit-
//! identical to the sparse scatter for any accumulator initialized at
//! `+0.0` or above — which all assigners do (`0.0` or the nonnegative
//! `y_base`). `rust/tests/kernel.rs` checks this equivalence with
//! adversarial (negative / underflowing) values.
//!
//! ## Safety
//!
//! The posting-rate kernels ([`scatter_add`], [`scatter_add_unit`],
//! [`sparse_dot_dense`], [`scatter_add_versioned`]) are **`unsafe
//! fn`**: they index with `get_unchecked` (or vector gathers) and
//! require every id to fall inside the accumulator slice —
//! [`scatter_add`] / [`scatter_add_unit`] additionally require the ids
//! to be pairwise distinct (see above). The safe boundary sits where
//! those invariants are actually enforced — the [`crate::index`]
//! builders produce ids `< K`, one posting per (term, centroid), and
//! the assigners size their scratch to `K` — so call sites carry one
//! `SAFETY:` comment citing exactly that. The invariants are re-checked
//! per call in debug builds (full-slice scan plus a distinctness
//! bitmap); CI runs the suite optimized with debug assertions enabled,
//! and the kernel tests run under Miri on the scalar table.
//! Mismatched `ids`/`vals` lengths are a **hard error** in every build
//! profile (release included): a malformed postings slice must fail
//! loudly, not silently truncate the gather.

use std::sync::atomic::AtomicPtr;
#[cfg(not(miri))]
use std::sync::atomic::Ordering;

#[cfg(all(target_arch = "x86_64", not(miri)))]
pub(crate) mod simd_x86;

#[cfg(all(target_arch = "aarch64", not(miri)))]
pub(crate) mod simd_neon;

/// Environment variable that selects the kernel backend at startup:
/// `scalar|avx2|avx512|neon|auto` (empty / unset = `auto`).
pub const KERNEL_ENV: &str = "SKM_KERNEL";

/// How many entries ahead of the current position the ρ prefetch runs.
/// Far enough to cover DRAM latency at ~4 entries/cycle, near enough
/// that the line is still resident when the store arrives.
pub(crate) const PREFETCH_AHEAD: usize = 16;

/// Prefetch the accumulator cache line targeted by `ids[at]` (x86_64
/// only; a no-op elsewhere — the scalar fallback the portability story
/// requires). Reads `ids` in bounds-checked fashion: `at` may run past
/// the end near the tail, where the prefetch simply stops.
#[inline(always)]
pub(crate) fn prefetch_acc(acc: &[f64], ids: &[u32], at: usize) {
    // Skipped under Miri: a prefetch has no observable semantics, and
    // the interpreter need not model the intrinsic.
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if let Some(&c) = ids.get(at) {
            let c = c as usize;
            if c < acc.len() {
                // SAFETY: `c < acc.len()` just checked; prefetch has no
                // architectural effect beyond the cache.
                unsafe {
                    core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(
                        acc.as_ptr().add(c) as *const i8,
                    );
                }
            }
        }
    }
    #[cfg(any(not(target_arch = "x86_64"), miri))]
    {
        let _ = (acc, ids, at);
    }
}

/// Debug-only validation of the unchecked-kernel invariant: every id
/// inside the accumulator.
#[inline(always)]
fn debug_check(acc: &[f64], ids: &[u32], vals: &[f64]) {
    debug_assert_eq!(ids.len(), vals.len(), "postings arrays must be parallel");
    debug_assert!(
        ids.iter().all(|&c| (c as usize) < acc.len()),
        "posting id out of accumulator range"
    );
}

/// Debug-only validation of the distinct-ids contract the SIMD
/// gather/scatter blocks rely on (one posting per (term, centroid) —
/// guaranteed by every index builder/splicer in this crate).
#[inline(always)]
fn debug_check_distinct(acc_len: usize, ids: &[u32]) {
    #[cfg(debug_assertions)]
    {
        let mut seen = vec![false; acc_len];
        for &c in ids {
            let c = c as usize;
            assert!(
                c < acc_len && !std::mem::replace(&mut seen[c], true),
                "duplicate or out-of-range posting id {c} violates the scatter_add contract"
            );
        }
    }
    #[cfg(not(debug_assertions))]
    {
        let _ = (acc_len, ids);
    }
}

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// A kernel instruction-set backend. `Scalar` is the oracle every other
/// backend must bit-match; it is also the Miri target and the fallback
/// on hosts without SIMD.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
    Avx512,
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Avx512 => "avx512",
            Backend::Neon => "neon",
        }
    }

    /// Whether this host can run the backend. Feature detection is the
    /// *only* gate in front of `#[target_feature]` code — an
    /// unsupported backend is unreachable by construction.
    pub fn is_supported(self) -> bool {
        match self {
            Backend::Scalar => true,
            Backend::Avx2 => {
                #[cfg(all(target_arch = "x86_64", not(miri)))]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(all(target_arch = "x86_64", not(miri))))]
                {
                    false
                }
            }
            Backend::Avx512 => {
                #[cfg(all(target_arch = "x86_64", not(miri)))]
                {
                    std::arch::is_x86_feature_detected!("avx512f")
                }
                #[cfg(not(all(target_arch = "x86_64", not(miri))))]
                {
                    false
                }
            }
            // NEON is baseline on aarch64 — present whenever the arch is.
            Backend::Neon => cfg!(all(target_arch = "aarch64", not(miri))),
        }
    }

    /// Best supported backend on this host (the `auto` resolution).
    pub fn detect() -> Backend {
        for b in [Backend::Avx512, Backend::Avx2, Backend::Neon] {
            if b.is_supported() {
                return b;
            }
        }
        Backend::Scalar
    }

    /// Every backend this host can run, scalar first — the sweep order
    /// used by the per-backend equivalence tests and the bench.
    pub fn available() -> Vec<Backend> {
        [Backend::Scalar, Backend::Avx2, Backend::Avx512, Backend::Neon]
            .into_iter()
            .filter(|b| b.is_supported())
            .collect()
    }
}

/// Resolve a backend request (the `SKM_KERNEL` value, or `None` when
/// unset) to a backend the host supports. Explicitly requesting an
/// unsupported ISA is an error — never silently downgraded, never UB.
pub fn resolve_backend(req: Option<&str>) -> Result<Backend, String> {
    let b = match req.map(|s| s.trim().to_ascii_lowercase()) {
        None => return Ok(Backend::detect()),
        Some(s) => match s.as_str() {
            "" | "auto" => return Ok(Backend::detect()),
            "scalar" => Backend::Scalar,
            "avx2" => Backend::Avx2,
            "avx512" | "avx512f" => Backend::Avx512,
            "neon" => Backend::Neon,
            other => {
                return Err(format!(
                    "unknown kernel backend {other:?} (expected scalar|avx2|avx512|neon|auto)"
                ))
            }
        },
    };
    if b.is_supported() {
        Ok(b)
    } else {
        Err(format!(
            "kernel backend {:?} was requested but this host does not support it",
            b.name()
        ))
    }
}

/// The runtime dispatch table: one function pointer per vectorizable
/// kernel, resolved once and shared by all workers. Entries are
/// `unsafe fn` uniformly (some kernels have safe semantics, but
/// `#[target_feature]` implementations coerce only to `unsafe fn`
/// pointers); the public wrappers re-establish the safe API.
struct KernelTable {
    backend: Backend,
    scatter_add: unsafe fn(&mut [f64], &[u32], &[f64], f64),
    scatter_add_unit: unsafe fn(&mut [f64], &[u32], &[f64]),
    dense_axpy: unsafe fn(&mut [f64], &[f64], f64),
    argmax_scan: unsafe fn(&[f64], f64, u32) -> (u32, f64),
    collect_above: unsafe fn(&[f64], f64, &mut Vec<u32>),
    verify_axpy_ids: unsafe fn(&mut [f64], &[u32], &[f64], f64, f64),
    sparse_dot_dense: unsafe fn(&[u32], &[f64], &[f64]) -> f64,
}

static SCALAR_TABLE: KernelTable = KernelTable {
    backend: Backend::Scalar,
    scatter_add: scatter_add_unrolled,
    scatter_add_unit: scatter_add_unit_unrolled,
    dense_axpy: dense_axpy_unrolled,
    argmax_scan: argmax_scan_fallback,
    collect_above: collect_above_fallback,
    verify_axpy_ids: verify_axpy_ids_fallback,
    sparse_dot_dense: sparse_dot_dense_unrolled,
};

#[cfg(all(target_arch = "x86_64", not(miri)))]
static AVX2_TABLE: KernelTable = KernelTable {
    backend: Backend::Avx2,
    scatter_add: simd_x86::avx2::scatter_add,
    scatter_add_unit: simd_x86::avx2::scatter_add_unit,
    dense_axpy: simd_x86::avx2::dense_axpy,
    argmax_scan: simd_x86::avx2::argmax_scan,
    collect_above: simd_x86::avx2::collect_above,
    verify_axpy_ids: simd_x86::avx2::verify_axpy_ids,
    #[cfg(not(feature = "relaxed-simd"))]
    sparse_dot_dense: sparse_dot_dense_unrolled,
    #[cfg(feature = "relaxed-simd")]
    sparse_dot_dense: simd_x86::avx2::sparse_dot_dense_relaxed,
};

#[cfg(all(target_arch = "x86_64", not(miri)))]
static AVX512_TABLE: KernelTable = KernelTable {
    backend: Backend::Avx512,
    scatter_add: simd_x86::avx512::scatter_add,
    scatter_add_unit: simd_x86::avx512::scatter_add_unit,
    dense_axpy: simd_x86::avx512::dense_axpy,
    argmax_scan: simd_x86::avx512::argmax_scan,
    collect_above: simd_x86::avx512::collect_above,
    verify_axpy_ids: simd_x86::avx512::verify_axpy_ids,
    #[cfg(not(feature = "relaxed-simd"))]
    sparse_dot_dense: sparse_dot_dense_unrolled,
    #[cfg(feature = "relaxed-simd")]
    sparse_dot_dense: simd_x86::avx512::sparse_dot_dense_relaxed,
};

#[cfg(all(target_arch = "aarch64", not(miri)))]
static NEON_TABLE: KernelTable = KernelTable {
    backend: Backend::Neon,
    scatter_add: simd_neon::scatter_add,
    scatter_add_unit: simd_neon::scatter_add_unit,
    dense_axpy: simd_neon::dense_axpy,
    // NEON has no f64 gather/scatter or movemask; the scan kernels keep
    // the unrolled scalar path (still bit-exact by construction).
    argmax_scan: argmax_scan_fallback,
    collect_above: collect_above_fallback,
    verify_axpy_ids: verify_axpy_ids_fallback,
    sparse_dot_dense: sparse_dot_dense_unrolled,
};

/// Pointer to the active table. Null until first use; written once at
/// startup (or by `force_backend`/`reset_backend` in tests/benches).
/// An `AtomicPtr` rather than a `OnceLock` precisely so tests can swap
/// backends; every stored pointer refers to one of the `'static`
/// tables above, so loads are always valid.
#[cfg_attr(miri, allow(dead_code))] // Miri pins the scalar table and never reads this.
static ACTIVE: AtomicPtr<KernelTable> = AtomicPtr::new(std::ptr::null_mut());

#[cfg(not(miri))]
fn table_for(b: Backend) -> &'static KernelTable {
    match b {
        Backend::Scalar => &SCALAR_TABLE,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => &AVX2_TABLE,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx512 => &AVX512_TABLE,
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => &NEON_TABLE,
        // Backends not compiled for this arch are rejected by
        // `resolve_backend`/`force_backend` before reaching here.
        #[allow(unreachable_patterns)]
        _ => &SCALAR_TABLE,
    }
}

#[cfg(not(miri))]
#[cold]
fn init_active() -> &'static KernelTable {
    let req = std::env::var(KERNEL_ENV).ok();
    let b = resolve_backend(req.as_deref()).unwrap_or_else(|e| panic!("{KERNEL_ENV}: {e}"));
    let t = table_for(b);
    ACTIVE.store(
        t as *const KernelTable as *mut KernelTable,
        Ordering::Release,
    );
    t
}

#[inline]
fn table() -> &'static KernelTable {
    // Miri always interprets the scalar oracle: vendor intrinsics are
    // outside its model, and the unsafe indexing is what it validates.
    #[cfg(miri)]
    return &SCALAR_TABLE;
    #[cfg(not(miri))]
    {
        let p = ACTIVE.load(Ordering::Acquire);
        if p.is_null() {
            init_active()
        } else {
            // SAFETY: ACTIVE only ever holds pointers to the 'static
            // tables above.
            unsafe { &*p }
        }
    }
}

/// The backend currently answering kernel calls.
pub fn active_backend() -> Backend {
    table().backend
}

/// Swap the active table (tests/benches only — production code resolves
/// once at startup). Errors on a backend this host cannot run; the
/// unsupported path is an error, never UB.
pub fn force_backend(b: Backend) -> Result<(), String> {
    if !b.is_supported() {
        return Err(format!(
            "kernel backend {:?} is not supported on this host",
            b.name()
        ));
    }
    #[cfg(not(miri))]
    ACTIVE.store(
        table_for(b) as *const KernelTable as *mut KernelTable,
        Ordering::Release,
    );
    Ok(())
}

/// Re-resolve the backend from `SKM_KERNEL` / auto-detection (undoes a
/// `force_backend` in tests/benches).
pub fn reset_backend() {
    #[cfg(not(miri))]
    {
        let _ = init_active();
    }
}

// ---------------------------------------------------------------------------
// Public dispatched API (signatures unchanged from the scalar-only era)
// ---------------------------------------------------------------------------

/// Branch-free scatter-add over a postings slice:
/// `acc[ids[q]] += u * vals[q]` for `q` in order.
///
/// Dispatched (scalar unrolled / AVX2 gather / AVX-512 gather+scatter /
/// NEON); every backend is bit-identical to [`scatter_add_scalar`]
/// under this function's contract — see the module docs. Mismatched
/// slice lengths are a hard error in every build profile.
///
/// # Safety
///
/// Every id must be `< acc.len()` **and the ids must be pairwise
/// distinct** (the SIMD gather/scatter blocks reorder within a lane
/// block, which is only sound on distinct slots). Both are
/// debug-asserted per call; in-crate callers get them from the
/// [`crate::index`] builders (one posting per (term, centroid), ids
/// `< K`) with `K`-length accumulators.
#[inline]
pub unsafe fn scatter_add(acc: &mut [f64], ids: &[u32], vals: &[f64], u: f64) {
    assert_eq!(ids.len(), vals.len(), "postings arrays must be parallel");
    debug_check(acc, ids, vals);
    debug_check_distinct(acc.len(), ids);
    // SAFETY: caller contract (in-range, distinct ids); the table only
    // ever holds backends this host supports.
    unsafe { (table().scatter_add)(acc, ids, vals, u) }
}

/// [`scatter_add`] without the weight: `acc[ids[q]] += vals[q]` (the CS
/// filter's squared-norm accumulation, which stores pre-squared values
/// and needs no per-object multiply).
///
/// # Safety
///
/// Same contract as [`scatter_add`]: every id `< acc.len()`, ids
/// pairwise distinct (both debug-asserted). Mismatched lengths are a
/// hard error.
#[inline]
pub unsafe fn scatter_add_unit(acc: &mut [f64], ids: &[u32], vals: &[f64]) {
    assert_eq!(ids.len(), vals.len(), "postings arrays must be parallel");
    debug_check(acc, ids, vals);
    debug_check_distinct(acc.len(), ids);
    // SAFETY: as in `scatter_add`.
    unsafe { (table().scatter_add_unit)(acc, ids, vals) }
}

/// Dense gather over a Region-1 tail row: `acc[j] += u * row[j]` for
/// every `j` of the row — contiguous streaming mul+add, zero
/// indirection, no scatter. Used for terms inside the dense block of
/// [`crate::index::InvIndex`]; bit-identical to scatter-adding the
/// term's sparse postings under the `+0.0`-padding argument in the
/// module docs. The accumulator must cover the row (hard error
/// otherwise); rows are 64-byte aligned by the index, but the kernels
/// use unaligned loads so correctness never depends on that.
#[inline]
pub fn dense_axpy(acc: &mut [f64], row: &[f64], u: f64) {
    assert!(
        acc.len() >= row.len(),
        "dense row must fit inside the accumulator"
    );
    // SAFETY: row fits in acc (checked above); every backend's impl
    // touches exactly acc[..row.len()].
    unsafe { (table().dense_axpy)(acc, row, u) }
}

/// The ρ-argmax scan over the whole accumulator, with the shared
/// tie-break semantics every assigner uses: keep `(amax, rmax)` unless
/// **strictly** better, lowest index first. Previously six hand-rolled
/// copies (`rho[j] > rmax` loops) drifting apart; now one, dispatched.
#[inline]
pub fn argmax_scan(acc: &[f64], rmax: f64, amax: u32) -> (u32, f64) {
    // SAFETY: every backend's impl only reads `acc` in bounds; the
    // semantics are safe.
    unsafe { (table().argmax_scan)(acc, rmax, amax) }
}

/// [`argmax_scan`] restricted to a candidate id list (the survivor set
/// `Z`, or the moving-centroid list under ICP). Runs once per
/// candidate, not per posting, so ordinary bounds-checked indexing is
/// kept and the function stays safe and scalar on every backend
/// (panics on an out-of-range id).
#[inline]
pub fn argmax_ids(acc: &[f64], ids: &[u32], mut rmax: f64, mut amax: u32) -> (u32, f64) {
    for &j in ids {
        let r = acc[j as usize];
        if r > rmax {
            rmax = r;
            amax = j;
        }
    }
    (amax, rmax)
}

/// The ES main filter over the whole accumulator: collect every index
/// whose (folded upper-bound) value strictly beats the threshold.
/// `z` is cleared first; callers pre-reserve it to K so pushes never
/// allocate (the §Perf allocation-free contract). Dispatched
/// (movemask-based on x86); output order is ascending on every backend.
#[inline]
pub fn collect_above(acc: &[f64], thresh: f64, z: &mut Vec<u32>) {
    // SAFETY: every backend's impl only reads `acc` in bounds and
    // pushes into `z`; the semantics are safe.
    unsafe { (table().collect_above)(acc, thresh, z) }
}

/// [`collect_above`] restricted to a candidate id list (the ICP
/// moving-centroid scan). Safe bounds-checked indexing, like
/// [`argmax_ids`]; scalar on every backend.
#[inline]
pub fn collect_above_ids(acc: &[f64], ids: &[u32], thresh: f64, z: &mut Vec<u32>) {
    z.clear();
    for &j in ids {
        if acc[j as usize] > thresh {
            z.push(j);
        }
    }
}

/// Verification-phase update over the survivor list against one dense
/// partial-index row: `acc[j] += sign · u · row[j]` for `j ∈ z`.
/// ES retires deficits with `sign = -1`; CS adds exact Region-3
/// contributions with `sign = +1`.
///
/// Stays a **safe** fn: the SIMD backends pre-validate that `z` is
/// strictly ascending and in bounds (always true for the
/// `collect_above*` output the assigners pass) and gather through
/// `row`; any other input falls back to the scalar loop, so arbitrary
/// safe inputs keep exact scalar semantics, panics included.
#[inline]
pub fn verify_axpy_ids(acc: &mut [f64], z: &[u32], row: &[f64], u: f64, sign: f64) {
    // SAFETY: every backend's impl validates `z` before any unchecked
    // access and otherwise runs the bounds-checked scalar loop.
    unsafe { (table().verify_axpy_ids)(acc, z, row, u, sign) }
}

/// Sparse·dense dot product in strict left-to-right term order —
/// Ding+'s exact similarity through the dense mean row (object term id
/// as direct key). One sequential accumulator, so the sum order (and
/// hence every bit) matches the naive loop; the win is the removed
/// bounds checks and unrolled loop control. Scalar under every backend
/// unless the `relaxed-simd` feature opts into a lane-parallel
/// (reassociated, documented-inexact) x86 path. Mismatched lengths are
/// a hard error.
///
/// # Safety
///
/// Every term id must be `< row.len()` (debug-asserted). In-crate
/// callers pass CSR rows whose term ids are `< D` with `D`-length dense
/// mean rows.
#[inline]
pub unsafe fn sparse_dot_dense(ts: &[u32], us: &[f64], row: &[f64]) -> f64 {
    assert_eq!(ts.len(), us.len(), "term/value arrays must be parallel");
    debug_assert!(ts.iter().all(|&t| (t as usize) < row.len()));
    // SAFETY: caller contract (ids in range, parallel slices).
    unsafe { (table().sparse_dot_dense)(ts, us, row) }
}

// ---------------------------------------------------------------------------
// Scalar oracles (naive, bounds-checked — the reference for every test)
// ---------------------------------------------------------------------------

/// Naive bounds-checked scatter-add — the pre-kernel reference loop.
/// Kept for the bit-identity tests (`rust/tests/kernel.rs`,
/// `rust/tests/simd.rs`) and the scalar baseline of the gather-kernel
/// bench section. Unlike the dispatched [`scatter_add`], duplicate ids
/// are fine here (strictly sequential order).
#[inline]
pub fn scatter_add_scalar(acc: &mut [f64], ids: &[u32], vals: &[f64], u: f64) {
    assert_eq!(ids.len(), vals.len(), "postings arrays must be parallel");
    for (&c, &v) in ids.iter().zip(vals) {
        acc[c as usize] += u * v;
    }
}

/// Naive bounds-checked unit scatter-add (reference for
/// [`scatter_add_unit`]); duplicate-tolerant like
/// [`scatter_add_scalar`].
#[inline]
pub fn scatter_add_unit_scalar(acc: &mut [f64], ids: &[u32], vals: &[f64]) {
    assert_eq!(ids.len(), vals.len(), "postings arrays must be parallel");
    for (&c, &v) in ids.iter().zip(vals) {
        acc[c as usize] += v;
    }
}

// ---------------------------------------------------------------------------
// Scalar backend implementations (the unrolled/unchecked paths that were
// this module's whole body before runtime dispatch existed)
// ---------------------------------------------------------------------------

/// Fixed-order 4-way unrolled scatter-add with `get_unchecked` indexing
/// and ρ-line prefetch — the scalar backend's entry.
///
/// # Safety
///
/// Wrapper contract: parallel slices (already hard-checked), every id
/// `< acc.len()`.
pub(crate) unsafe fn scatter_add_unrolled(acc: &mut [f64], ids: &[u32], vals: &[f64], u: f64) {
    let n = ids.len();
    let mut q = 0usize;
    while q + 4 <= n {
        // Cover all four scatter targets of the block PREFETCH_AHEAD
        // entries out — the targets are effectively random lines, so
        // each needs its own prefetch.
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 1);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 2);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 3);
        // SAFETY: q+3 < n == ids.len() == vals.len(); ids < acc.len()
        // is the wrapper's contract, checked there in debug builds.
        unsafe {
            let c0 = *ids.get_unchecked(q) as usize;
            *acc.get_unchecked_mut(c0) += u * *vals.get_unchecked(q);
            let c1 = *ids.get_unchecked(q + 1) as usize;
            *acc.get_unchecked_mut(c1) += u * *vals.get_unchecked(q + 1);
            let c2 = *ids.get_unchecked(q + 2) as usize;
            *acc.get_unchecked_mut(c2) += u * *vals.get_unchecked(q + 2);
            let c3 = *ids.get_unchecked(q + 3) as usize;
            *acc.get_unchecked_mut(c3) += u * *vals.get_unchecked(q + 3);
        }
        q += 4;
    }
    while q < n {
        // SAFETY: q < n; same contract as above.
        unsafe {
            let c = *ids.get_unchecked(q) as usize;
            *acc.get_unchecked_mut(c) += u * *vals.get_unchecked(q);
        }
        q += 1;
    }
}

/// Unit-weight variant of [`scatter_add_unrolled`].
///
/// # Safety
///
/// As [`scatter_add_unrolled`].
pub(crate) unsafe fn scatter_add_unit_unrolled(acc: &mut [f64], ids: &[u32], vals: &[f64]) {
    let n = ids.len();
    let mut q = 0usize;
    while q + 4 <= n {
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 1);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 2);
        prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 3);
        // SAFETY: as in `scatter_add_unrolled`.
        unsafe {
            let c0 = *ids.get_unchecked(q) as usize;
            *acc.get_unchecked_mut(c0) += *vals.get_unchecked(q);
            let c1 = *ids.get_unchecked(q + 1) as usize;
            *acc.get_unchecked_mut(c1) += *vals.get_unchecked(q + 1);
            let c2 = *ids.get_unchecked(q + 2) as usize;
            *acc.get_unchecked_mut(c2) += *vals.get_unchecked(q + 2);
            let c3 = *ids.get_unchecked(q + 3) as usize;
            *acc.get_unchecked_mut(c3) += *vals.get_unchecked(q + 3);
        }
        q += 4;
    }
    while q < n {
        // SAFETY: as in `scatter_add_unrolled`.
        unsafe {
            let c = *ids.get_unchecked(q) as usize;
            *acc.get_unchecked_mut(c) += *vals.get_unchecked(q);
        }
        q += 1;
    }
}

/// 4-way unrolled dense axpy over `acc[..row.len()]`.
///
/// # Safety
///
/// Wrapper contract: `acc.len() >= row.len()` (already hard-checked).
pub(crate) unsafe fn dense_axpy_unrolled(acc: &mut [f64], row: &[f64], u: f64) {
    let n = row.len();
    let mut j = 0usize;
    while j + 4 <= n {
        // SAFETY: j+3 < n <= acc.len().
        unsafe {
            *acc.get_unchecked_mut(j) += u * *row.get_unchecked(j);
            *acc.get_unchecked_mut(j + 1) += u * *row.get_unchecked(j + 1);
            *acc.get_unchecked_mut(j + 2) += u * *row.get_unchecked(j + 2);
            *acc.get_unchecked_mut(j + 3) += u * *row.get_unchecked(j + 3);
        }
        j += 4;
    }
    while j < n {
        // SAFETY: j < n.
        unsafe {
            *acc.get_unchecked_mut(j) += u * *row.get_unchecked(j);
        }
        j += 1;
    }
}

/// Scalar argmax scan — the oracle semantics every SIMD backend must
/// reproduce (strict `>`, lowest index wins, signed-zero ties keep the
/// incumbent).
///
/// # Safety
///
/// Safe semantics (only reads `acc` in bounds); `unsafe fn` purely for
/// the uniform table type.
pub(crate) unsafe fn argmax_scan_fallback(acc: &[f64], mut rmax: f64, mut amax: u32) -> (u32, f64) {
    for (j, &r) in acc.iter().enumerate() {
        if r > rmax {
            rmax = r;
            amax = j as u32;
        }
    }
    (amax, rmax)
}

/// Scalar threshold filter — ascending push order.
///
/// # Safety
///
/// Safe semantics; `unsafe fn` purely for the uniform table type.
pub(crate) unsafe fn collect_above_fallback(acc: &[f64], thresh: f64, z: &mut Vec<u32>) {
    z.clear();
    for (j, &r) in acc.iter().enumerate() {
        if r > thresh {
            z.push(j as u32);
        }
    }
}

/// Scalar survivor-list axpy — bounds-checked, panics on out-of-range
/// ids exactly like direct indexing.
///
/// # Safety
///
/// Safe semantics; `unsafe fn` purely for the uniform table type.
pub(crate) unsafe fn verify_axpy_ids_fallback(
    acc: &mut [f64],
    z: &[u32],
    row: &[f64],
    u: f64,
    sign: f64,
) {
    let su = sign * u;
    for &j in z {
        let j = j as usize;
        acc[j] += su * row[j];
    }
}

/// Sequential-accumulator sparse·dense dot product, 4-way unrolled.
///
/// # Safety
///
/// Wrapper contract: parallel slices (hard-checked), every term id
/// `< row.len()`.
pub(crate) unsafe fn sparse_dot_dense_unrolled(ts: &[u32], us: &[f64], row: &[f64]) -> f64 {
    let n = ts.len();
    let mut s = 0.0f64;
    let mut q = 0usize;
    while q + 4 <= n {
        // SAFETY: q+3 < n; term ids in range is the wrapper's contract.
        unsafe {
            s += *us.get_unchecked(q) * *row.get_unchecked(*ts.get_unchecked(q) as usize);
            s += *us.get_unchecked(q + 1)
                * *row.get_unchecked(*ts.get_unchecked(q + 1) as usize);
            s += *us.get_unchecked(q + 2)
                * *row.get_unchecked(*ts.get_unchecked(q + 2) as usize);
            s += *us.get_unchecked(q + 3)
                * *row.get_unchecked(*ts.get_unchecked(q + 3) as usize);
        }
        q += 4;
    }
    while q < n {
        // SAFETY: as above.
        unsafe {
            s += *us.get_unchecked(q) * *row.get_unchecked(*ts.get_unchecked(q) as usize);
        }
        q += 1;
    }
    s
}

/// DIVI's epoch-versioned scatter-add (the deliberately cache-hostile
/// strawman loop, kept faithful and **scalar on every backend** — it is
/// the baseline being measured): `score[i − lo] += u·v` with lazy
/// per-epoch reset and a touched list. Returns nothing; the caller
/// accounts `ids.len()` multiplications and irregular branches.
/// Mismatched lengths are a hard error.
///
/// # Safety
///
/// Ids must be global object ids in `[lo, lo + score.len())` and
/// `version.len() >= score.len()` (debug-asserted). In-crate callers
/// pass posting slices already restricted to the shard's id range.
#[inline]
#[allow(clippy::too_many_arguments)]
pub unsafe fn scatter_add_versioned(
    score: &mut [f64],
    version: &mut [u32],
    touched: &mut Vec<u32>,
    epoch: u32,
    ids: &[u32],
    vals: &[f64],
    u: f64,
    lo: usize,
) {
    assert_eq!(ids.len(), vals.len(), "postings arrays must be parallel");
    debug_assert!(version.len() >= score.len());
    debug_assert!(ids
        .iter()
        .all(|&i| (i as usize) >= lo && (i as usize) - lo < score.len()));
    for (&i, &v) in ids.iter().zip(vals) {
        let li = i as usize - lo;
        // SAFETY: caller invariant, checked above in debug builds.
        unsafe {
            if *version.get_unchecked(li) != epoch {
                *version.get_unchecked_mut(li) = epoch;
                *score.get_unchecked_mut(li) = 0.0;
                touched.push(li as u32);
            }
            *score.get_unchecked_mut(li) += u * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_rejects_unknown_names() {
        assert!(resolve_backend(Some("scalar")).unwrap() == Backend::Scalar);
        assert!(resolve_backend(Some("SCALAR")).unwrap() == Backend::Scalar);
        assert!(resolve_backend(Some("  auto ")).is_ok());
        assert!(resolve_backend(Some("")).is_ok());
        assert!(resolve_backend(None).is_ok());
        assert!(resolve_backend(Some("sse9")).is_err());
    }

    #[test]
    fn detect_is_always_supported() {
        assert!(Backend::detect().is_supported());
        let avail = Backend::available();
        assert_eq!(avail[0], Backend::Scalar);
        assert!(avail.iter().all(|b| b.is_supported()));
    }
}
