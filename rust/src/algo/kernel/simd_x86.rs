//! x86-64 SIMD backends (AVX2, AVX-512F) for the gather micro-kernels.
//!
//! Every function here is reached **only** through the dispatch table
//! in the parent module, which is populated after
//! `is_x86_feature_detected!` has confirmed the ISA — the
//! `#[target_feature]` code is unreachable on hosts that lack it.
//!
//! Bit-exactness rests on the arguments documented per kernel in the
//! parent module: separate `mul`/`add` intrinsics (never FMA — rustc
//! performs no floating-point contraction, and no `mul_add`/`fmadd`
//! token appears in this file), distinct posting ids per block for the
//! gather→add→store / gather+scatter sequences, strictly-greater
//! compare-masks with lowest-index-wins reductions for the scans, and a
//! scalar fallback whenever a precondition the SIMD form needs (i32
//! index range, ascending survivor list, minimum length) does not hold.
//!
//! The vector gathers index with **signed 32-bit** lane offsets, so any
//! slice longer than `i32::MAX` elements falls back to the scalar
//! path — unreachable for real accumulators (length K) and mean rows
//! (length D), but checked rather than assumed.

#![allow(clippy::missing_safety_doc)] // every fn: wrapper-enforced contract, documented in mod.rs

pub(crate) mod avx2 {
    use core::arch::x86_64::*;

    use crate::algo::kernel::{
        self, prefetch_acc, scatter_add_unit_unrolled, scatter_add_unrolled, PREFETCH_AHEAD,
    };

    /// AVX2 scatter-add: gather four accumulator slots, `mul`+`add`,
    /// store the four lanes back scalarly (AVX2 has no scatter).
    /// Distinct ids per the kernel contract make the per-block
    /// reordering sound; each slot still sees exactly one
    /// `+= u * v` with scalar-identical rounding.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn scatter_add(acc: &mut [f64], ids: &[u32], vals: &[f64], u: f64) {
        if acc.len() > i32::MAX as usize {
            // SAFETY: same contract.
            return unsafe { scatter_add_unrolled(acc, ids, vals, u) };
        }
        let n = ids.len();
        let base = acc.as_mut_ptr();
        let uu = _mm256_set1_pd(u);
        let mut buf = [0.0f64; 4];
        let mut q = 0usize;
        while q + 4 <= n {
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 1);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 2);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 3);
            // SAFETY: q+3 < n; ids in-range/distinct is the kernel
            // contract (debug-checked by the wrapper); ids fit i32
            // (acc.len() <= i32::MAX checked above).
            unsafe {
                let idx = _mm_loadu_si128(ids.as_ptr().add(q) as *const __m128i);
                let a = _mm256_i32gather_pd::<8>(base as *const f64, idx);
                let v = _mm256_loadu_pd(vals.as_ptr().add(q));
                let r = _mm256_add_pd(a, _mm256_mul_pd(uu, v));
                _mm256_storeu_pd(buf.as_mut_ptr(), r);
                *base.add(*ids.get_unchecked(q) as usize) = buf[0];
                *base.add(*ids.get_unchecked(q + 1) as usize) = buf[1];
                *base.add(*ids.get_unchecked(q + 2) as usize) = buf[2];
                *base.add(*ids.get_unchecked(q + 3) as usize) = buf[3];
            }
            q += 4;
        }
        while q < n {
            // SAFETY: q < n; same contract.
            unsafe {
                let c = *ids.get_unchecked(q) as usize;
                *base.add(c) += u * *vals.get_unchecked(q);
            }
            q += 1;
        }
    }

    /// Unit-weight AVX2 scatter-add (no multiply at all — pure
    /// gather/add/store, same distinct-ids argument).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn scatter_add_unit(acc: &mut [f64], ids: &[u32], vals: &[f64]) {
        if acc.len() > i32::MAX as usize {
            // SAFETY: same contract.
            return unsafe { scatter_add_unit_unrolled(acc, ids, vals) };
        }
        let n = ids.len();
        let base = acc.as_mut_ptr();
        let mut buf = [0.0f64; 4];
        let mut q = 0usize;
        while q + 4 <= n {
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 1);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 2);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 3);
            // SAFETY: as in `scatter_add`.
            unsafe {
                let idx = _mm_loadu_si128(ids.as_ptr().add(q) as *const __m128i);
                let a = _mm256_i32gather_pd::<8>(base as *const f64, idx);
                let v = _mm256_loadu_pd(vals.as_ptr().add(q));
                let r = _mm256_add_pd(a, v);
                _mm256_storeu_pd(buf.as_mut_ptr(), r);
                *base.add(*ids.get_unchecked(q) as usize) = buf[0];
                *base.add(*ids.get_unchecked(q + 1) as usize) = buf[1];
                *base.add(*ids.get_unchecked(q + 2) as usize) = buf[2];
                *base.add(*ids.get_unchecked(q + 3) as usize) = buf[3];
            }
            q += 4;
        }
        while q < n {
            // SAFETY: q < n; same contract.
            unsafe {
                let c = *ids.get_unchecked(q) as usize;
                *base.add(c) += *vals.get_unchecked(q);
            }
            q += 1;
        }
    }

    /// AVX2 dense axpy: contiguous 4-lane `mul`+`add` over the row.
    /// Unaligned loads (the index 64-byte-aligns rows so these never
    /// split a cache line, but correctness does not depend on it).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dense_axpy(acc: &mut [f64], row: &[f64], u: f64) {
        let n = row.len();
        let a = acc.as_mut_ptr();
        let r = row.as_ptr();
        let uu = _mm256_set1_pd(u);
        let mut j = 0usize;
        while j + 4 <= n {
            // SAFETY: j+3 < n <= acc.len() (wrapper contract).
            unsafe {
                let av = _mm256_loadu_pd(a.add(j));
                let rv = _mm256_loadu_pd(r.add(j));
                _mm256_storeu_pd(a.add(j), _mm256_add_pd(av, _mm256_mul_pd(uu, rv)));
            }
            j += 4;
        }
        while j < n {
            // SAFETY: j < n.
            unsafe {
                *a.add(j) += u * *r.add(j);
            }
            j += 1;
        }
    }

    /// AVX2 argmax: per-lane running (value, index-as-f64) pairs
    /// updated on strictly-greater compares, reduced with an explicit
    /// lowest-index-wins tie-break (numeric equality, so ±0.0 ties
    /// resolve to the earlier element's bits — scalar semantics), then
    /// one final strict compare against the caller's `(amax0, rmax0)`.
    /// Indices as f64 lanes are exact (slice lengths < 2^53).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn argmax_scan(acc: &[f64], rmax0: f64, amax0: u32) -> (u32, f64) {
        let n = acc.len();
        if n < 8 {
            // SAFETY: safe semantics.
            return unsafe { kernel::argmax_scan_fallback(acc, rmax0, amax0) };
        }
        let p = acc.as_ptr();
        // SAFETY: n >= 8; all block loads below stay < n.
        unsafe {
            // Lanes start at -inf so elements only *enter* the running
            // max through the strict-GT blend. A NaN element therefore
            // never occupies a lane (GT_OQ is false on unordered), so
            // it cannot shadow later values in that lane — exactly the
            // scalar semantics, where NaN loses every comparison and
            // the scan moves on.
            let mut vmax = _mm256_set1_pd(f64::NEG_INFINITY);
            let mut vidx = _mm256_setzero_pd();
            let step = _mm256_set1_pd(4.0);
            let mut cur = _mm256_set_pd(3.0, 2.0, 1.0, 0.0);
            let mut j = 0usize;
            while j + 4 <= n {
                let v = _mm256_loadu_pd(p.add(j));
                let gt = _mm256_cmp_pd::<_CMP_GT_OQ>(v, vmax);
                vmax = _mm256_blendv_pd(vmax, v, gt);
                vidx = _mm256_blendv_pd(vidx, cur, gt);
                cur = _mm256_add_pd(cur, step);
                j += 4;
            }
            let mut mv = [0.0f64; 4];
            let mut mi = [0.0f64; 4];
            _mm256_storeu_pd(mv.as_mut_ptr(), vmax);
            _mm256_storeu_pd(mi.as_mut_ptr(), vidx);
            // NEG_INFINITY start keeps NaN lanes unselected, matching
            // the scalar scan (NaN never wins a strict `>`).
            let mut best_v = f64::NEG_INFINITY;
            let mut best_i = usize::MAX;
            for l in 0..4 {
                let (v, i) = (mv[l], mi[l] as usize);
                if v > best_v || (v == best_v && i < best_i) {
                    best_v = v;
                    best_i = i;
                }
            }
            while j < n {
                let v = *p.add(j);
                if v > best_v {
                    best_v = v;
                    best_i = j;
                }
                j += 1;
            }
            if best_v > rmax0 {
                (best_i as u32, best_v)
            } else {
                (amax0, rmax0)
            }
        }
    }

    /// AVX2 threshold filter: strict-greater compare-mask + movemask,
    /// indices emitted in ascending order via trailing-zeros iteration.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn collect_above(acc: &[f64], thresh: f64, z: &mut Vec<u32>) {
        z.clear();
        let n = acc.len();
        let p = acc.as_ptr();
        let tv = _mm256_set1_pd(thresh);
        let mut j = 0usize;
        while j + 4 <= n {
            // SAFETY: j+3 < n.
            let mut m = unsafe {
                let v = _mm256_loadu_pd(p.add(j));
                _mm256_movemask_pd(_mm256_cmp_pd::<_CMP_GT_OQ>(v, tv)) as u32
            };
            while m != 0 {
                z.push(j as u32 + m.trailing_zeros());
                m &= m - 1;
            }
            j += 4;
        }
        while j < n {
            // SAFETY: j < n.
            if unsafe { *p.add(j) } > thresh {
                z.push(j as u32);
            }
            j += 1;
        }
    }

    /// AVX2 survivor-list axpy: gather `row[j]`, multiply by the
    /// pre-folded `sign·u` (one scalar mul, as in the scalar loop),
    /// store lanes back scalarly. Requires a strictly ascending
    /// in-bounds survivor list (what `collect_above*` produces); any
    /// other input — duplicates, unsorted, out of range — takes the
    /// scalar fallback, preserving exact safe-fn semantics.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn verify_axpy_ids(
        acc: &mut [f64],
        z: &[u32],
        row: &[f64],
        u: f64,
        sign: f64,
    ) {
        let lim = acc.len().min(row.len());
        let simd_ok = row.len() <= i32::MAX as usize
            && z.windows(2).all(|w| w[0] < w[1])
            && z.last().map_or(true, |&j| (j as usize) < lim);
        if !simd_ok {
            // SAFETY: safe semantics (bounds-checked fallback).
            return unsafe { kernel::verify_axpy_ids_fallback(acc, z, row, u, sign) };
        }
        let su = sign * u;
        let vsu = _mm256_set1_pd(su);
        let rp = row.as_ptr();
        let ap = acc.as_mut_ptr();
        let n = z.len();
        let mut buf = [0.0f64; 4];
        let mut q = 0usize;
        while q + 4 <= n {
            // SAFETY: q+3 < n; every id < lim (validated above).
            unsafe {
                let idx = _mm_loadu_si128(z.as_ptr().add(q) as *const __m128i);
                let rv = _mm256_i32gather_pd::<8>(rp, idx);
                _mm256_storeu_pd(buf.as_mut_ptr(), _mm256_mul_pd(vsu, rv));
                *ap.add(*z.get_unchecked(q) as usize) += buf[0];
                *ap.add(*z.get_unchecked(q + 1) as usize) += buf[1];
                *ap.add(*z.get_unchecked(q + 2) as usize) += buf[2];
                *ap.add(*z.get_unchecked(q + 3) as usize) += buf[3];
            }
            q += 4;
        }
        while q < n {
            // SAFETY: q < n; id < lim.
            unsafe {
                let j = *z.get_unchecked(q) as usize;
                *ap.add(j) += su * *rp.add(j);
            }
            q += 1;
        }
    }

    /// Lane-parallel sparse·dense dot product — `relaxed-simd` only:
    /// four independent partial sums reassociate the reduction, so this
    /// is deterministic for a fixed backend but **not** bit-identical
    /// to the scalar sequential accumulator. Reduction order is fixed:
    /// `((l0+l1)+(l2+l3))`, then the scalar tail in sequence.
    #[cfg(feature = "relaxed-simd")]
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn sparse_dot_dense_relaxed(ts: &[u32], us: &[f64], row: &[f64]) -> f64 {
        if row.len() > i32::MAX as usize {
            // SAFETY: same contract.
            return unsafe { kernel::sparse_dot_dense_unrolled(ts, us, row) };
        }
        let n = ts.len();
        let rp = row.as_ptr();
        let mut sv = _mm256_setzero_pd();
        let mut q = 0usize;
        while q + 4 <= n {
            // SAFETY: q+3 < n; term ids < row.len() is the kernel
            // contract.
            unsafe {
                let idx = _mm_loadu_si128(ts.as_ptr().add(q) as *const __m128i);
                let rv = _mm256_i32gather_pd::<8>(rp, idx);
                let uv = _mm256_loadu_pd(us.as_ptr().add(q));
                sv = _mm256_add_pd(sv, _mm256_mul_pd(uv, rv));
            }
            q += 4;
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), sv);
        let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        while q < n {
            // SAFETY: as above.
            unsafe {
                s += *us.get_unchecked(q) * *rp.add(*ts.get_unchecked(q) as usize);
            }
            q += 1;
        }
        s
    }
}

pub(crate) mod avx512 {
    use core::arch::x86_64::*;

    use crate::algo::kernel::{
        self, prefetch_acc, scatter_add_unit_unrolled, scatter_add_unrolled, PREFETCH_AHEAD,
    };

    /// AVX-512F scatter-add: true gather + `mul`+`add` + scatter over
    /// eight lanes. Sound under the kernel's distinct-ids contract
    /// (`vscatter` with duplicate indices would keep only the highest
    /// lane — exactly the case the contract excludes).
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn scatter_add(acc: &mut [f64], ids: &[u32], vals: &[f64], u: f64) {
        if acc.len() > i32::MAX as usize {
            // SAFETY: same contract.
            return unsafe { scatter_add_unrolled(acc, ids, vals, u) };
        }
        let n = ids.len();
        let base = acc.as_mut_ptr();
        let uu = _mm512_set1_pd(u);
        let mut q = 0usize;
        while q + 8 <= n {
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 2);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 4);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 6);
            // SAFETY: q+7 < n; ids in-range/distinct is the kernel
            // contract; ids fit i32 (checked above).
            unsafe {
                let idx = _mm256_loadu_si256(ids.as_ptr().add(q) as *const __m256i);
                let a = _mm512_i32gather_pd::<8>(idx, base as *const u8);
                let v = _mm512_loadu_pd(vals.as_ptr().add(q));
                let r = _mm512_add_pd(a, _mm512_mul_pd(uu, v));
                _mm512_i32scatter_pd::<8>(base as *mut u8, idx, r);
            }
            q += 8;
        }
        while q < n {
            // SAFETY: q < n; same contract.
            unsafe {
                let c = *ids.get_unchecked(q) as usize;
                *base.add(c) += u * *vals.get_unchecked(q);
            }
            q += 1;
        }
    }

    /// Unit-weight AVX-512F scatter-add.
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn scatter_add_unit(acc: &mut [f64], ids: &[u32], vals: &[f64]) {
        if acc.len() > i32::MAX as usize {
            // SAFETY: same contract.
            return unsafe { scatter_add_unit_unrolled(acc, ids, vals) };
        }
        let n = ids.len();
        let base = acc.as_mut_ptr();
        let mut q = 0usize;
        while q + 8 <= n {
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 2);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 4);
            prefetch_acc(acc, ids, q + PREFETCH_AHEAD + 6);
            // SAFETY: as in `scatter_add`.
            unsafe {
                let idx = _mm256_loadu_si256(ids.as_ptr().add(q) as *const __m256i);
                let a = _mm512_i32gather_pd::<8>(idx, base as *const u8);
                let v = _mm512_loadu_pd(vals.as_ptr().add(q));
                _mm512_i32scatter_pd::<8>(base as *mut u8, idx, _mm512_add_pd(a, v));
            }
            q += 8;
        }
        while q < n {
            // SAFETY: q < n; same contract.
            unsafe {
                let c = *ids.get_unchecked(q) as usize;
                *base.add(c) += *vals.get_unchecked(q);
            }
            q += 1;
        }
    }

    /// AVX-512F dense axpy: contiguous 8-lane `mul`+`add`.
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn dense_axpy(acc: &mut [f64], row: &[f64], u: f64) {
        let n = row.len();
        let a = acc.as_mut_ptr();
        let r = row.as_ptr();
        let uu = _mm512_set1_pd(u);
        let mut j = 0usize;
        while j + 8 <= n {
            // SAFETY: j+7 < n <= acc.len() (wrapper contract).
            unsafe {
                let av = _mm512_loadu_pd(a.add(j));
                let rv = _mm512_loadu_pd(r.add(j));
                _mm512_storeu_pd(a.add(j), _mm512_add_pd(av, _mm512_mul_pd(uu, rv)));
            }
            j += 8;
        }
        while j < n {
            // SAFETY: j < n.
            unsafe {
                *a.add(j) += u * *r.add(j);
            }
            j += 1;
        }
    }

    /// AVX-512F argmax — same lane-tracking scheme as the AVX2 version
    /// (see there for the tie-break/NaN analysis), eight lanes wide.
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn argmax_scan(acc: &[f64], rmax0: f64, amax0: u32) -> (u32, f64) {
        let n = acc.len();
        if n < 16 {
            // SAFETY: safe semantics.
            return unsafe { kernel::argmax_scan_fallback(acc, rmax0, amax0) };
        }
        let p = acc.as_ptr();
        // SAFETY: n >= 16; all block loads below stay < n.
        unsafe {
            // -inf lane init: see the AVX2 variant — NaN can never
            // enter the running max, so it cannot shadow its lane.
            let mut vmax = _mm512_set1_pd(f64::NEG_INFINITY);
            let mut vidx = _mm512_setzero_pd();
            let step = _mm512_set1_pd(8.0);
            let mut cur = _mm512_set_pd(7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0, 0.0);
            let mut j = 0usize;
            while j + 8 <= n {
                let v = _mm512_loadu_pd(p.add(j));
                let gt = _mm512_cmp_pd_mask::<_CMP_GT_OQ>(v, vmax);
                vmax = _mm512_mask_blend_pd(gt, vmax, v);
                vidx = _mm512_mask_blend_pd(gt, vidx, cur);
                cur = _mm512_add_pd(cur, step);
                j += 8;
            }
            let mut mv = [0.0f64; 8];
            let mut mi = [0.0f64; 8];
            _mm512_storeu_pd(mv.as_mut_ptr(), vmax);
            _mm512_storeu_pd(mi.as_mut_ptr(), vidx);
            let mut best_v = f64::NEG_INFINITY;
            let mut best_i = usize::MAX;
            for l in 0..8 {
                let (v, i) = (mv[l], mi[l] as usize);
                if v > best_v || (v == best_v && i < best_i) {
                    best_v = v;
                    best_i = i;
                }
            }
            while j < n {
                let v = *p.add(j);
                if v > best_v {
                    best_v = v;
                    best_i = j;
                }
                j += 1;
            }
            if best_v > rmax0 {
                (best_i as u32, best_v)
            } else {
                (amax0, rmax0)
            }
        }
    }

    /// AVX-512F threshold filter: the compare yields an `__mmask8`
    /// directly (no movemask needed); ascending emit order preserved.
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn collect_above(acc: &[f64], thresh: f64, z: &mut Vec<u32>) {
        z.clear();
        let n = acc.len();
        let p = acc.as_ptr();
        let tv = _mm512_set1_pd(thresh);
        let mut j = 0usize;
        while j + 8 <= n {
            // SAFETY: j+7 < n.
            let mut m = unsafe {
                let v = _mm512_loadu_pd(p.add(j));
                _mm512_cmp_pd_mask::<_CMP_GT_OQ>(v, tv) as u32
            };
            while m != 0 {
                z.push(j as u32 + m.trailing_zeros());
                m &= m - 1;
            }
            j += 8;
        }
        while j < n {
            // SAFETY: j < n.
            if unsafe { *p.add(j) } > thresh {
                z.push(j as u32);
            }
            j += 1;
        }
    }

    /// AVX-512F survivor-list axpy — same validation/fallback scheme as
    /// the AVX2 version, eight lanes wide.
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn verify_axpy_ids(
        acc: &mut [f64],
        z: &[u32],
        row: &[f64],
        u: f64,
        sign: f64,
    ) {
        let lim = acc.len().min(row.len());
        let simd_ok = row.len() <= i32::MAX as usize
            && z.windows(2).all(|w| w[0] < w[1])
            && z.last().map_or(true, |&j| (j as usize) < lim);
        if !simd_ok {
            // SAFETY: safe semantics (bounds-checked fallback).
            return unsafe { kernel::verify_axpy_ids_fallback(acc, z, row, u, sign) };
        }
        let su = sign * u;
        let vsu = _mm512_set1_pd(su);
        let rp = row.as_ptr();
        let ap = acc.as_mut_ptr();
        let n = z.len();
        let mut buf = [0.0f64; 8];
        let mut q = 0usize;
        while q + 8 <= n {
            // SAFETY: q+7 < n; every id < lim (validated above).
            unsafe {
                let idx = _mm256_loadu_si256(z.as_ptr().add(q) as *const __m256i);
                let rv = _mm512_i32gather_pd::<8>(idx, rp as *const u8);
                _mm512_storeu_pd(buf.as_mut_ptr(), _mm512_mul_pd(vsu, rv));
                *ap.add(*z.get_unchecked(q) as usize) += buf[0];
                *ap.add(*z.get_unchecked(q + 1) as usize) += buf[1];
                *ap.add(*z.get_unchecked(q + 2) as usize) += buf[2];
                *ap.add(*z.get_unchecked(q + 3) as usize) += buf[3];
                *ap.add(*z.get_unchecked(q + 4) as usize) += buf[4];
                *ap.add(*z.get_unchecked(q + 5) as usize) += buf[5];
                *ap.add(*z.get_unchecked(q + 6) as usize) += buf[6];
                *ap.add(*z.get_unchecked(q + 7) as usize) += buf[7];
            }
            q += 8;
        }
        while q < n {
            // SAFETY: q < n; id < lim.
            unsafe {
                let j = *z.get_unchecked(q) as usize;
                *ap.add(j) += su * *rp.add(j);
            }
            q += 1;
        }
    }

    /// Eight-lane relaxed dot product (`relaxed-simd` only; documented
    /// reassociation — see the AVX2 variant). Reduction order fixed:
    /// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`, then the scalar tail.
    #[cfg(feature = "relaxed-simd")]
    #[target_feature(enable = "avx512f")]
    pub(crate) unsafe fn sparse_dot_dense_relaxed(ts: &[u32], us: &[f64], row: &[f64]) -> f64 {
        if row.len() > i32::MAX as usize {
            // SAFETY: same contract.
            return unsafe { kernel::sparse_dot_dense_unrolled(ts, us, row) };
        }
        let n = ts.len();
        let rp = row.as_ptr();
        let mut sv = _mm512_setzero_pd();
        let mut q = 0usize;
        while q + 8 <= n {
            // SAFETY: q+7 < n; term ids < row.len() is the kernel
            // contract.
            unsafe {
                let idx = _mm256_loadu_si256(ts.as_ptr().add(q) as *const __m256i);
                let rv = _mm512_i32gather_pd::<8>(idx, rp as *const u8);
                let uv = _mm512_loadu_pd(us.as_ptr().add(q));
                sv = _mm512_add_pd(sv, _mm512_mul_pd(uv, rv));
            }
            q += 8;
        }
        let mut lanes = [0.0f64; 8];
        _mm512_storeu_pd(lanes.as_mut_ptr(), sv);
        let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        while q < n {
            // SAFETY: as above.
            unsafe {
                s += *us.get_unchecked(q) * *rp.add(*ts.get_unchecked(q) as usize);
            }
            q += 1;
        }
        s
    }
}
