//! AArch64 NEON backend for the gather micro-kernels.
//!
//! NEON is a baseline feature of AArch64, so these functions are always
//! callable on that architecture; the dispatch table still routes
//! through `Backend::Neon` so `SKM_KERNEL=scalar` keeps working and the
//! fuzz suite can compare both paths on ARM CI hosts.
//!
//! Only the multiply-heavy kernels are vectorized. NEON has no
//! gather/scatter, so the posting kernels vectorize the `u * v`
//! multiply into a stack buffer with `vmulq_f64` (two separately
//! rounded IEEE lanes — `vfmaq_f64` is never used, so no contraction)
//! and then perform the indexed `+=` adds scalarly in posting order.
//! That add order is *identical* to the scalar loop, which makes these
//! two kernels bit-exact even for duplicate ids — stricter than the
//! x86 versions need. The scan kernels (`argmax_scan`,
//! `collect_above`) and `verify_axpy_ids` stay on the scalar fallbacks
//! in `NEON_TABLE`; 2-wide compares gain little over the unrolled
//! scalar form and the scalar path keeps the oracle argument trivial.

#![allow(clippy::missing_safety_doc)] // wrapper-enforced contract, documented in mod.rs

use core::arch::aarch64::*;

/// NEON scatter-add: vectorized multiply, scalar in-order adds.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn scatter_add(acc: &mut [f64], ids: &[u32], vals: &[f64], u: f64) {
    let n = ids.len();
    let base = acc.as_mut_ptr();
    let uu = vdupq_n_f64(u);
    let mut buf = [0.0f64; 2];
    let mut q = 0usize;
    while q + 2 <= n {
        // SAFETY: q+1 < n; ids in-range is the kernel contract
        // (debug-checked by the wrapper).
        unsafe {
            let v = vld1q_f64(vals.as_ptr().add(q));
            vst1q_f64(buf.as_mut_ptr(), vmulq_f64(uu, v));
            *base.add(*ids.get_unchecked(q) as usize) += buf[0];
            *base.add(*ids.get_unchecked(q + 1) as usize) += buf[1];
        }
        q += 2;
    }
    if q < n {
        // SAFETY: q < n; same contract.
        unsafe {
            let c = *ids.get_unchecked(q) as usize;
            *base.add(c) += u * *vals.get_unchecked(q);
        }
    }
}

/// Unit-weight NEON scatter-add. No multiply at all, so this is the
/// scalar add sequence verbatim; it exists so `Backend::Neon` owns a
/// complete posting-kernel set.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn scatter_add_unit(acc: &mut [f64], ids: &[u32], vals: &[f64]) {
    let n = ids.len();
    let base = acc.as_mut_ptr();
    let mut q = 0usize;
    while q < n {
        // SAFETY: q < n; ids in-range is the kernel contract.
        unsafe {
            let c = *ids.get_unchecked(q) as usize;
            *base.add(c) += *vals.get_unchecked(q);
        }
        q += 1;
    }
}

/// NEON dense axpy: contiguous 2-lane `vmulq`+`vaddq` (never `vfmaq`).
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dense_axpy(acc: &mut [f64], row: &[f64], u: f64) {
    let n = row.len();
    let a = acc.as_mut_ptr();
    let r = row.as_ptr();
    let uu = vdupq_n_f64(u);
    let mut j = 0usize;
    while j + 2 <= n {
        // SAFETY: j+1 < n <= acc.len() (wrapper contract).
        unsafe {
            let av = vld1q_f64(a.add(j));
            let rv = vld1q_f64(r.add(j));
            vst1q_f64(a.add(j), vaddq_f64(av, vmulq_f64(uu, rv)));
        }
        j += 2;
    }
    if j < n {
        // SAFETY: j < n.
        unsafe {
            *a.add(j) += u * *r.add(j);
        }
    }
}
