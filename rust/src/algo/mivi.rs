//! MIVI — the mean-inverted-index baseline (Algorithm 1) — and ICP, its
//! extension with the invariant-centroid pruning filter (Section IV-B
//! auxiliary filter used standalone, as in the paper's §VI-C "ICP").
//!
//! MIVI: term-at-a-time accumulation of all K similarities through the
//! mean-inverted index, then a full argmax. No pruning: CPR = 1.
//!
//! ICP: identical, except that for objects satisfying Eq. (5) the
//! accumulation runs only over the *moving block* of each postings array
//! and the argmax only over moving centroids — invariant centroids
//! provably cannot win (their similarity is unchanged, and it already
//! lost at the previous assignment).
//!
//! The per-object routine lives in [`MiviAssigner::assign_range`] and is
//! shared verbatim by the serial path and the sharded parallel path, so
//! the two are bit-identical by construction (see `algo::par`). The
//! inner loops route through the shared gather micro-kernels
//! ([`crate::algo::kernel`]): unrolled unchecked scatter-add, the dense
//! Region-1 tail gather, and the deduplicated ρ-argmax scans.

use crate::algo::kernel;
use crate::algo::par::ScratchPool;
use crate::algo::{par, Assigner, ClusterConfig, IterState, ParConfig};
use crate::index::InvMaintainer;
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::PhaseTimes;
use crate::sparse::Dataset;
use std::mem::size_of;
use std::time::Instant;

/// Pooled per-worker scratch: the K-length similarity accumulator.
#[derive(Default)]
struct MiviScratch {
    rho: Vec<f64>,
}

impl MiviScratch {
    fn mem_bytes(&self) -> usize {
        self.rho.capacity() * size_of::<f64>()
    }
}

pub struct MiviAssigner {
    use_icp: bool,
    /// Persistent index + incremental splice state (§Perf).
    maint: InvMaintainer,
    scratch: ScratchPool<MiviScratch>,
}

impl MiviAssigner {
    pub fn new(_ds: &Dataset, use_icp: bool) -> Self {
        Self {
            use_icp,
            maint: InvMaintainer::new(),
            scratch: ScratchPool::new(),
        }
    }

    /// Assignment of objects `[lo, lo + out.len())`. `out` holds the
    /// previous assignments on entry and the new ones on exit.
    fn assign_range(
        &self,
        ds: &Dataset,
        k: usize,
        rho_prev: &[f64],
        xstate: &[bool],
        lo: usize,
        out: &mut [u32],
    ) -> (OpCounters, usize) {
        let idx = self.maint.index().expect("rebuild not called");
        let mut counters = OpCounters::new();
        let mut changes = 0usize;
        // Pooled shard scratch — no per-call allocations (§Perf).
        let mut s = self.scratch.checkout(MiviScratch::default);
        if s.rho.len() != k {
            s.rho.clear();
            s.rho.resize(k, 0.0);
        }
        let rho = &mut s.rho;
        let t0 = Instant::now();

        for (off, slot) in out.iter_mut().enumerate() {
            let i = lo + off;
            let (ts, vs) = ds.x.row(i);
            let icp_active = self.use_icp && xstate[i];

            rho.iter_mut().for_each(|r| *r = 0.0);
            let mut mult = 0u64;

            // Moving blocks only under ICP; the full pass (Algorithm 1)
            // gathers dense-tail terms through contiguous FMA rows —
            // one shared dispatch (`InvIndex::gather_term`), identical
            // mult accounting either way.
            for (&t, &u) in ts.iter().zip(vs) {
                mult += idx.gather_term(t as usize, u, rho, icp_active);
            }
            let (amax, _) = if icp_active {
                kernel::argmax_ids(rho, &idx.moving_ids, rho_prev[i], *slot)
            } else {
                kernel::argmax_scan(rho, rho_prev[i], *slot)
            };
            let scanned = if icp_active {
                idx.moving_ids.len() as u64
            } else {
                k as u64
            };
            counters.mult += mult;
            counters.candidates += scanned;
            counters.exact_sims += scanned;
            if amax != *slot {
                *slot = amax;
                changes += 1;
            }
        }
        // MIVI/ICP have no separate verification phase: the whole
        // term-at-a-time pass (accumulation + argmax) is gathering.
        let ph = PhaseTimes {
            gather: t0.elapsed().as_secs_f64(),
            ..Default::default()
        };
        self.scratch.checkin(s, ph);
        (counters, changes)
    }
}

impl Assigner for MiviAssigner {
    fn rebuild(&mut self, ds: &Dataset, st: &IterState, _cfg: &ClusterConfig) {
        self.maint.update(&st.means, ds.d(), 1.0);
    }

    fn assign(&mut self, ds: &Dataset, st: &mut IterState) -> (OpCounters, usize) {
        let IterState {
            assign,
            rho,
            xstate,
            k,
            ..
        } = st;
        self.assign_range(ds, *k, rho, xstate, 0, assign)
    }

    fn assign_par(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let n = st.assign.len();
        self.assign_span(ds, st, 0, n, cfg)
    }

    fn assign_span(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        lo: usize,
        hi: usize,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let this = &*self;
        let IterState {
            assign,
            rho,
            xstate,
            k,
            ..
        } = st;
        let (k, rho, xstate) = (*k, &rho[..], &xstate[..]);
        par::run_sharded(cfg, &mut assign[lo..hi], |rel, chunk| {
            this.assign_range(ds, k, rho, xstate, lo + rel, chunk)
        })
    }

    fn mem_bytes(&self) -> usize {
        self.maint.mem_bytes() + self.scratch.mem_bytes(MiviScratch::mem_bytes)
    }

    fn take_phases(&mut self) -> PhaseTimes {
        self.scratch.drain_phases()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{run_clustering, run_clustering_with, AlgoKind, ClusterConfig};
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;

    fn toy() -> Dataset {
        let c = generate(&tiny(21));
        build_dataset("t", c.n_terms, &c.docs)
    }

    /// Brute-force reference assignment: exact argmax with the same
    /// tie-break (keep current unless strictly better, lowest id first).
    pub(crate) fn brute_force_step(
        ds: &Dataset,
        means: &crate::index::MeanSet,
        assign: &[u32],
        rho_prev: &[f64],
    ) -> Vec<u32> {
        let k = means.k();
        (0..ds.n())
            .map(|i| {
                let mut amax = assign[i];
                let mut rmax = rho_prev[i];
                for j in 0..k {
                    let dense = means.m.row_dense(j);
                    let s = ds.x.row_dot_dense(i, &dense);
                    if s > rmax {
                        rmax = s;
                        amax = j as u32;
                    }
                }
                amax
            })
            .collect()
    }

    #[test]
    fn mivi_single_step_matches_brute_force() {
        let ds = toy();
        let k = 8;
        let means = crate::algo::seed_means(&ds, k, 5);
        let mut st = IterState {
            k,
            assign: vec![0; ds.n()],
            rho: vec![-1.0; ds.n()],
            xstate: vec![false; ds.n()],
            means,
            iter: 1,
        };
        let cfg = ClusterConfig::default();
        let mut a = MiviAssigner::new(&ds, false);
        a.rebuild(&ds, &st, &cfg);
        let expect = brute_force_step(&ds, &st.means, &st.assign, &st.rho);
        let (c, _) = a.assign(&ds, &mut st);
        assert_eq!(st.assign, expect);
        assert!(c.mult > 0);
        assert_eq!(c.cpr(ds.n(), k), 1.0); // MIVI never prunes
    }

    #[test]
    fn mivi_converges_and_objective_monotone() {
        let ds = toy();
        let cfg = ClusterConfig {
            k: 10,
            seed: 3,
            ..Default::default()
        };
        let out = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        assert!(out.converged, "did not converge");
        // Lloyd objective (sum of similarities) is non-decreasing.
        let objs: Vec<f64> = out.logs.iter().map(|l| l.objective).collect();
        for w in objs.windows(2) {
            assert!(
                w[1] >= w[0] - 1e-9,
                "objective decreased: {} -> {}",
                w[0],
                w[1]
            );
        }
        // changes hit 0 at the end
        assert_eq!(out.logs.last().unwrap().changes, 0);
    }

    #[test]
    fn icp_matches_mivi_assignments() {
        let ds = toy();
        let cfg = ClusterConfig {
            k: 12,
            seed: 9,
            ..Default::default()
        };
        let a = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let b = run_clustering(AlgoKind::Icp, &ds, &cfg);
        assert_eq!(a.assign, b.assign, "ICP diverged from MIVI");
        assert_eq!(a.iterations(), b.iterations());
        // ICP must not do more multiplications than MIVI.
        assert!(b.total_mult() <= a.total_mult());
    }

    #[test]
    fn sharded_mivi_bit_identical() {
        let ds = toy();
        let cfg = ClusterConfig {
            k: 10,
            seed: 6,
            ..Default::default()
        };
        let serial = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let par = run_clustering_with(AlgoKind::Mivi, &ds, &cfg, &ParConfig::with_threads(4));
        assert_eq!(serial.assign, par.assign);
        assert_eq!(serial.iterations(), par.iterations());
        assert_eq!(serial.objective.to_bits(), par.objective.to_bits());
    }
}
