//! Ding+ — the Yinyang-k-means-style comparator of Section II, modified
//! for the spherical setting exactly as the paper describes: sparse
//! objects against **full-expression** (dense) mean vectors, no inverted
//! index, group-wise pruning bounds derived from centroid drift.
//!
//! Cosine analog of the Yinyang bounds: for unit-norm `x`,
//! `|ρ(x, μ') − ρ(x, μ)| ≤ ‖μ' − μ‖₂` (Cauchy–Schwarz), so a per-group
//! upper bound on the best similarity inside group `g` can be carried
//! across iterations by adding the group's maximum drift. Groups whose
//! bound cannot beat the object's exact own-centroid similarity are
//! pruned; otherwise every member is evaluated exactly through direct
//! indexing into the dense mean array — the cache-hostile access pattern
//! (plus the per-group irregular branches) that makes Ding+ slower than
//! MIVI despite ~4× fewer multiplications (Table II).
//!
//! Sharding: the per-object bound matrix `gub` (N × (G + 1), row-major
//! per object: G group bounds plus a last-tightened round stamp) is
//! split along the same object-shard boundaries as the assignment
//! vector (`par::run_sharded_with`), so each worker owns its objects'
//! bounds exclusively and the sharded path is bit-identical to the
//! serial one. The stamp keeps the mini-batch path sound: bounds are
//! drift-corrected only one round at a time, so rows whose object
//! skipped rounds take the exact first-pass evaluation (all centroids,
//! own included) rather than an under-corrected pruning pass.

use crate::algo::kernel;
use crate::algo::{par, Assigner, ClusterConfig, IterState, ParConfig};
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::PhaseTimes;
use crate::sparse::Dataset;
use std::mem::size_of;
use std::time::Instant;

pub struct DingAssigner {
    /// Dense K × D mean matrix (full expression, Section II).
    dense: Vec<f64>,
    prev_dense: Vec<f64>,
    d: usize,
    k: usize,
    /// Number of groups (Yinyang uses K/10).
    n_groups: usize,
    /// Group of each centroid (contiguous blocks).
    group_of: Vec<u32>,
    group_start: Vec<usize>,
    /// Max drift per group at this iteration.
    group_drift: Vec<f64>,
    /// Per-object pruning state, `stride = n_groups + 1` slots per
    /// object: `n_groups` per-group similarity upper bounds followed by
    /// one **round stamp** (the round the row was last tightened, as an
    /// exact small-integer f64). Persistent across iterations; the
    /// stamp exists for the mini-batch path — the one-round drift
    /// correction in the assignment loop is only valid for objects
    /// visited on the immediately preceding round, so a stale or
    /// never-stamped row is routed through the exact first-pass body
    /// (all centroids evaluated, own included, bounds re-initialized)
    /// instead of silently under-correcting or excluding a
    /// possibly-moved own centroid. Full-batch runs visit every object
    /// every round, so the stamp check never fires there and behavior
    /// is bit-identical to the pre-stamp code.
    gub: Vec<f64>,
    /// Rebuild counter == the 1-based round whose assignment comes next
    /// (`rebuild` is called exactly once before every assignment round
    /// in both the full-batch and mini-batch drivers).
    round: u32,
    first_pass_done: bool,
    /// Assignment-step phase seconds since the last `take_phases` drain.
    phases: PhaseTimes,
}

impl DingAssigner {
    pub fn new(ds: &Dataset, cfg: &ClusterConfig) -> Self {
        let k = cfg.k;
        let n_groups = (k / 10).clamp(1, k);
        let group_of: Vec<u32> = (0..k).map(|j| ((j * n_groups) / k) as u32).collect();
        let mut group_start = vec![0usize; n_groups + 1];
        for &g in &group_of {
            group_start[g as usize + 1] += 1;
        }
        for g in 0..n_groups {
            group_start[g + 1] += group_start[g];
        }
        let stride = n_groups + 1;
        let mut gub = vec![f64::INFINITY; ds.n() * stride];
        for i in 0..ds.n() {
            gub[i * stride + n_groups] = 0.0; // round stamp: never visited
        }
        Self {
            dense: vec![0.0; k * ds.d()],
            prev_dense: vec![0.0; k * ds.d()],
            d: ds.d(),
            k,
            n_groups,
            group_of,
            group_start,
            group_drift: vec![0.0; n_groups],
            gub,
            round: 0,
            first_pass_done: false,
            phases: PhaseTimes::default(),
        }
    }

    #[inline]
    fn mean_row(&self, j: usize) -> &[f64] {
        &self.dense[j * self.d..(j + 1) * self.d]
    }

    /// Exact similarity of object `i` to centroid `j` by direct indexing
    /// into the dense mean (the paper's "simply and quickly access a
    /// mean-feature value by using a data-object term ID as a key").
    /// Routed through the shared micro-kernel: strict left-to-right
    /// accumulation, so the sum is bit-identical to the naive loop.
    #[inline]
    fn exact_sim(&self, ds: &Dataset, i: usize, j: usize) -> f64 {
        let (ts, us) = ds.x.row(i);
        // SAFETY: CSR term ids are < D == mean_row(j).len() by
        // construction; ts/us are one row's parallel slices.
        unsafe { kernel::sparse_dot_dense(ts, us, self.mean_row(j)) }
    }

    /// Assignment of objects `[lo, lo + out.len())`; `gub` is the bound
    /// sub-matrix for exactly those objects
    /// (`out.len() × (n_groups + 1)`, bounds + round stamp per row).
    fn assign_range(
        &self,
        ds: &Dataset,
        first_pass: bool,
        rho_prev: &[f64],
        lo: usize,
        out: &mut [u32],
        gub: &mut [f64],
    ) -> (OpCounters, usize) {
        let ng = self.n_groups;
        let stride = ng + 1;
        let round_f = self.round as f64;
        let mut counters = OpCounters::new();
        let mut changes = 0usize;

        for (off, slot) in out.iter_mut().enumerate() {
            let i = lo + off;
            let base = off * stride;
            let (ts, _) = ds.x.row(i);
            let nt = ts.len() as u64;

            // First-pass evaluation — globally on iteration 1, and
            // per-object for (a) anyone the mini-batch schedule has
            // never visited (their ρ still carries the −1.0 init
            // sentinel, so there is no exact own similarity to
            // exclude-and-reuse) and (b) anyone whose bound row was not
            // tightened on the immediately preceding round (the
            // one-round drift correction below would under-correct, and
            // the own centroid may have moved since the stale ρ, so the
            // exclude-a0 path would be unsound — every centroid gets
            // evaluated here instead, a0 included): exact full
            // evaluation, recording per-group maxima to initialize the
            // bounds. The group that ends up holding the assigned
            // centroid gets an infinite bound: all other groups' bounds
            // are valid for "best excluding the assigned centroid"
            // because the assigned centroid is not in them (the Yinyang
            // own-group refinement). Full-batch runs tighten every row
            // every round, so the stamp clause never fires there.
            if first_pass || rho_prev[i] < 0.0 || gub[base + ng] + 1.0 != round_f {
                let mut amax = *slot;
                let mut rmax = rho_prev[i];
                for g in 0..ng {
                    let mut gmax = f64::NEG_INFINITY;
                    for j in self.group_start[g]..self.group_start[g + 1] {
                        let s = self.exact_sim(ds, i, j);
                        counters.mult += nt;
                        counters.cold_touches += nt;
                        if s > gmax {
                            gmax = s;
                        }
                        if s > rmax {
                            rmax = s;
                            amax = j as u32;
                        }
                    }
                    gub[base + g] = gmax;
                }
                gub[base + self.group_of[amax as usize] as usize] = f64::INFINITY;
                gub[base + ng] = round_f;
                counters.candidates += self.k as u64;
                counters.exact_sims += self.k as u64;
                if amax != *slot {
                    *slot = amax;
                    changes += 1;
                }
                continue;
            }

            // The exact own similarity is ρ from the update step; bounds
            // are for "best in group excluding the assigned centroid".
            let a0 = *slot;
            let own = rho_prev[i];
            let mut amax = a0;
            let mut rmax = own;
            for g in 0..ng {
                // Carry the bound across the mean update.
                gub[base + g] += self.group_drift[g];
                counters.irregular_branches += 1;
                if gub[base + g] <= rmax {
                    continue; // group pruned
                }
                // Evaluate the group exactly and tighten its bound
                // (excluding the assigned centroid, whose similarity is
                // already known exactly).
                let mut gmax = f64::NEG_INFINITY;
                for j in self.group_start[g]..self.group_start[g + 1] {
                    if j as u32 == a0 {
                        continue;
                    }
                    let s = self.exact_sim(ds, i, j);
                    counters.mult += nt;
                    counters.cold_touches += nt;
                    counters.exact_sims += 1;
                    counters.candidates += 1;
                    if s > gmax {
                        gmax = s;
                    }
                    if s > rmax {
                        rmax = s;
                        amax = j as u32;
                    }
                }
                gub[base + g] = gmax;
            }
            gub[base + ng] = round_f;
            if amax != a0 {
                // The old centroid is no longer excluded from its group's
                // bound; invalidate so the next iteration re-evaluates.
                gub[base + self.group_of[a0 as usize] as usize] = f64::INFINITY;
                *slot = amax;
                changes += 1;
            }
        }
        (counters, changes)
    }

    /// Shared serial/parallel/span driver: slices the per-object bound
    /// matrix `gub` along the same `[lo, hi)` object span as the
    /// assignment slice and runs [`DingAssigner::assign_range`] per
    /// shard. A full span is the classic assignment step; partial spans
    /// serve the mini-batch driver (each object's bound row stays owned
    /// by exactly one worker either way).
    fn assign_with(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        lo: usize,
        hi: usize,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let first_pass = !self.first_pass_done;
        let stride = self.n_groups + 1;
        let t0 = Instant::now();
        let mut gub = std::mem::take(&mut self.gub);
        let result = {
            let this = &*self;
            let IterState { assign, rho, .. } = st;
            let rho = &rho[..];
            par::run_sharded_with(
                cfg,
                &mut assign[lo..hi],
                &mut gub[lo * stride..hi * stride],
                stride,
                |rel, chunk, g| this.assign_range(ds, first_pass, rho, lo + rel, chunk, g),
            )
        };
        self.gub = gub;
        self.first_pass_done = true;
        // Ding+ has no verification phase: bounds + exact evaluation are
        // one interleaved gathering pass.
        self.phases.gather += t0.elapsed().as_secs_f64();
        result
    }
}

impl Assigner for DingAssigner {
    fn rebuild(&mut self, _ds: &Dataset, st: &IterState, _cfg: &ClusterConfig) {
        // One rebuild precedes every assignment round in both drivers;
        // the counter stamps bound rows with their tightening round.
        self.round += 1;
        // Densify the new means and compute per-group max drift.
        std::mem::swap(&mut self.dense, &mut self.prev_dense);
        self.dense.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..self.k {
            let (ts, vs) = st.means.m.row(j);
            let row = &mut self.dense[j * self.d..(j + 1) * self.d];
            for (&t, &v) in ts.iter().zip(vs) {
                row[t as usize] = v;
            }
        }
        if self.first_pass_done {
            for g in 0..self.n_groups {
                self.group_drift[g] = 0.0;
            }
            for j in 0..self.k {
                let a = &self.dense[j * self.d..(j + 1) * self.d];
                let b = &self.prev_dense[j * self.d..(j + 1) * self.d];
                let drift: f64 = if st.means.moved[j] {
                    a.iter()
                        .zip(b)
                        .map(|(x, y)| (x - y) * (x - y))
                        .sum::<f64>()
                        .sqrt()
                } else {
                    0.0
                };
                let g = self.group_of[j] as usize;
                if drift > self.group_drift[g] {
                    self.group_drift[g] = drift;
                }
            }
        }
    }

    fn assign(&mut self, ds: &Dataset, st: &mut IterState) -> (OpCounters, usize) {
        let n = st.assign.len();
        self.assign_with(ds, st, 0, n, &ParConfig::serial())
    }

    fn assign_par(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let n = st.assign.len();
        self.assign_with(ds, st, 0, n, cfg)
    }

    fn assign_span(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        lo: usize,
        hi: usize,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        self.assign_with(ds, st, lo, hi, cfg)
    }

    fn mem_bytes(&self) -> usize {
        (self.dense.len() + self.prev_dense.len() + self.gub.len() + self.group_drift.len())
            * size_of::<f64>()
            + self.group_of.len() * size_of::<u32>()
            + self.group_start.len() * size_of::<usize>()
    }

    fn take_phases(&mut self) -> PhaseTimes {
        std::mem::take(&mut self.phases)
    }
}

#[cfg(test)]
mod tests {
    use crate::algo::{run_clustering, run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
    use crate::corpus::{generate, tiny, CorpusSpec};
    use crate::sparse::build_dataset;

    #[test]
    fn ding_matches_mivi() {
        let c = generate(&CorpusSpec {
            n_docs: 500,
            ..tiny(99)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 12,
            seed: 5,
            ..Default::default()
        };
        let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let ding = run_clustering(AlgoKind::Ding, &ds, &cfg);
        assert_eq!(ding.assign, base.assign, "Ding+ diverged from MIVI");
        assert_eq!(ding.iterations(), base.iterations());
    }

    #[test]
    fn ding_prunes_multiplications() {
        // Needs enough clusters for group granularity (K/10 groups).
        let c = generate(&CorpusSpec {
            n_docs: 900,
            n_topics: 36,
            ..tiny(100)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 40,
            seed: 15,
            ..Default::default()
        };
        let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let ding = run_clustering(AlgoKind::Ding, &ds, &cfg);
        // The Section-II shape: Ding+'s drift bounds prune progressively
        // — late iterations need far fewer multiplications than the full
        // first pass (at paper scale this nets ~4× fewer than MIVI; at
        // unit-test scale we assert the pruning trend itself).
        let first = ding.logs.first().unwrap().counters.mult;
        let late = ding.logs[ding.logs.len() - 2].counters.mult;
        assert!(
            late * 2 < first,
            "drift bounds never pruned: first={first} late={late}"
        );
        // ... and Ding+ pays in cold-array touches (dense mean accesses).
        let dc: u64 = ding.logs.iter().map(|l| l.counters.cold_touches).sum();
        let bc: u64 = base.logs.iter().map(|l| l.counters.cold_touches).sum();
        assert!(dc > bc);
    }

    #[test]
    fn sharded_ding_bit_identical() {
        // Ding carries per-object bound state across iterations — the
        // sharded path must preserve it exactly.
        let c = generate(&CorpusSpec {
            n_docs: 600,
            n_topics: 24,
            ..tiny(101)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 24,
            seed: 8,
            ..Default::default()
        };
        let serial = run_clustering(AlgoKind::Ding, &ds, &cfg);
        for par in [
            ParConfig::with_threads(4),
            ParConfig {
                threads: 2,
                shard: 41,
            },
        ] {
            let out = run_clustering_with(AlgoKind::Ding, &ds, &cfg, &par);
            assert_eq!(serial.assign, out.assign, "{par:?}");
            assert_eq!(serial.objective.to_bits(), out.objective.to_bits());
            assert_eq!(serial.total_mult(), out.total_mult());
        }
    }
}
