//! CS-ICP / CS-MIVI — the Cauchy–Schwarz comparator (Appendix F-B,
//! Algorithms 10–11), as in Bottesch+ / Knittel+.
//!
//! The upper bound on the `s ≥ t_th` part of the similarity is
//! `‖x^p‖₂ · ‖μ^p_(j;i)‖₂` where both norms are restricted to the
//! *object's* inherent dimensions (Eqs. 19–21). The object norm is
//! precomputed; the mean norm is accumulated on the fly from a partial
//! squared-mean-inverted index — a second K-length accumulator array
//! whose traffic is the cache-miss source the paper measures — and needs
//! one square root per scanned centroid.
//!
//! The per-object routine lives in [`CsAssigner::assign_range`] and is
//! shared verbatim by the serial and sharded parallel paths (see
//! `algo::par`).

use crate::algo::kernel;
use crate::algo::par::ScratchPool;
use crate::algo::{par, Assigner, ClusterConfig, IterState, ParConfig};
use crate::index::CsMaintainer;
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::{phase_timing_enabled, PhaseTimes};
use crate::sparse::Dataset;
use std::mem::size_of;
use std::time::Instant;

/// Pooled per-worker scratch: ρ and squared-norm accumulators plus the
/// survivor list.
#[derive(Default)]
struct CsScratch {
    rho: Vec<f64>,
    normsq: Vec<f64>,
    z: Vec<u32>,
}

impl CsScratch {
    fn mem_bytes(&self) -> usize {
        (self.rho.capacity() + self.normsq.capacity()) * size_of::<f64>()
            + self.z.capacity() * size_of::<u32>()
    }
}

pub struct CsAssigner {
    use_icp: bool,
    t_th: usize,
    /// Persistent squared-postings index + incremental splice state.
    maint: CsMaintainer,
    /// ‖x_i^p‖₂ over terms ≥ t_th (Eq. 20), precomputed per object when
    /// the preset t_th activates.
    xp_norm: Vec<f64>,
    scratch: ScratchPool<CsScratch>,
    /// Per-object gather/verify probes (`SKM_PHASE_TIMING`, default on).
    phase_timing: bool,
}

impl CsAssigner {
    pub fn new(ds: &Dataset, use_icp: bool) -> Self {
        Self {
            use_icp,
            t_th: ds.d(),
            maint: CsMaintainer::new(),
            xp_norm: vec![0.0; ds.n()],
            scratch: ScratchPool::new(),
            phase_timing: phase_timing_enabled(),
        }
    }

    fn compute_xp_norms(&mut self, ds: &Dataset) {
        for i in 0..ds.n() {
            let (_, (_, hvs)) = ds.x.row_split(i, self.t_th);
            self.xp_norm[i] = hvs.iter().map(|v| v * v).sum::<f64>().sqrt();
        }
    }

    /// Assignment of objects `[lo, lo + out.len())`. `out` holds the
    /// previous assignments on entry and the new ones on exit.
    fn assign_range(
        &self,
        ds: &Dataset,
        k: usize,
        rho_prev: &[f64],
        xstate: &[bool],
        lo: usize,
        out: &mut [u32],
    ) -> (OpCounters, usize) {
        let idx = self.maint.index().expect("rebuild not called");
        let t_th = self.t_th;
        let mut counters = OpCounters::new();
        let mut changes = 0usize;
        // Pooled shard scratch — no per-call allocations (§Perf).
        let s = self.scratch.checkout(CsScratch::default);
        let CsScratch {
            mut rho,
            mut normsq,
            mut z,
        } = s;
        if rho.len() != k {
            rho.clear();
            rho.resize(k, 0.0);
            normsq.clear();
            normsq.resize(k, 0.0);
        }
        // Clear before reserving: `reserve` is relative to len, so this
        // guarantees capacity ≥ K once and pushes never reallocate.
        z.clear();
        if z.capacity() < k {
            z.reserve(k);
        }
        let mut ph = PhaseTimes::default();
        // Per-object probes cost two Instant::now() calls per object;
        // SKM_PHASE_TIMING=0 turns them off (phases then read 0).
        let timing = self.phase_timing;
        let mut t0 = Instant::now();

        for (off, slot) in out.iter_mut().enumerate() {
            let i = lo + off;
            let ((lts, lus), (hts, hus)) = ds.x.row_split(i, t_th);

            rho.iter_mut().for_each(|r| *r = 0.0);
            normsq.iter_mut().for_each(|v| *v = 0.0);
            z.clear();
            let rho_max0 = rho_prev[i];
            let mut mult = 0u64;

            let icp_active = self.use_icp && xstate[i];

            // Region 1 exact (Algorithm 11 lines 2–4) through the
            // shared dispatch (moving prefix under ICP, dense tail rows
            // on the full scan).
            for (&t, &u) in lts.iter().zip(lus) {
                mult += idx.r1.gather_term(t as usize, u, &mut rho, icp_active);
            }
            // Squared mean norms in the object subspace (lines 5–7):
            // additions of pre-squared values, but through a *second*
            // K-length accumulator (the LLCM source). Unit scatter —
            // the values are pre-squared, no per-object multiply.
            for &t in hts {
                let (ids, sq) = if icp_active {
                    idx.r2_sq.postings_moving(t as usize)
                } else {
                    idx.r2_sq.postings(t as usize)
                };
                counters.cold_touches += ids.len() as u64;
                // SAFETY: squared-postings ids are centroid ids < k ==
                // normsq.len() by index construction, with at most one
                // posting per centroid in a term's list — pairwise
                // distinct, as the SIMD backends require.
                unsafe { kernel::scatter_add_unit(&mut normsq, ids, sq) };
            }
            // UBP filter (lines 8–12): ρ_j + ‖x^p‖·√(‖μ^p_j‖²) — one
            // multiplication and one square root per scanned centroid.
            let xp = self.xp_norm[i];
            if icp_active {
                for &j in &idx.moving_ids {
                    let j = j as usize;
                    mult += 1;
                    counters.sqrts += 1;
                    if rho[j] + xp * normsq[j].sqrt() > rho_max0 {
                        z.push(j as u32);
                    }
                }
            } else {
                for j in 0..k {
                    mult += 1;
                    counters.sqrts += 1;
                    if rho[j] + xp * normsq[j].sqrt() > rho_max0 {
                        z.push(j as u32);
                    }
                }
            }

            let t1 = if timing {
                let t1 = Instant::now();
                ph.gather += (t1 - t0).as_secs_f64();
                t1
            } else {
                t0
            };

            // Verification: exact `s ≥ t_th` contribution via the full
            // partial index (same structure as Algorithm 4's phase).
            let nth = hts.len() as u64;
            mult += z.len() as u64 * nth;
            counters.cold_touches += z.len() as u64 * nth;
            for (&t, &u) in hts.iter().zip(hus) {
                let row = idx.partial.row(t as usize);
                kernel::verify_axpy_ids(&mut rho, &z, row, u, 1.0);
            }

            let (amax, _) = kernel::argmax_ids(&rho, &z, rho_max0, *slot);

            counters.mult += mult;
            counters.candidates += z.len() as u64;
            counters.exact_sims += z.len() as u64;
            if amax != *slot {
                *slot = amax;
                changes += 1;
            }
            if timing {
                let t2 = Instant::now();
                ph.verify += (t2 - t1).as_secs_f64();
                t0 = t2;
            }
        }
        self.scratch.checkin(CsScratch { rho, normsq, z }, ph);
        (counters, changes)
    }
}

impl Assigner for CsAssigner {
    fn rebuild(&mut self, ds: &Dataset, st: &IterState, cfg: &ClusterConfig) {
        if st.iter >= 2 {
            let new_t = ((ds.d() as f64 * cfg.t_th_frac) as usize).min(ds.d());
            if new_t != self.t_th {
                self.t_th = new_t;
                self.compute_xp_norms(ds);
            }
        }
        // Incremental splice when t_th is unchanged and few centroids
        // moved; full rebuild otherwise (first pass, preset switch).
        self.maint.update(&st.means, self.t_th);
    }

    fn assign(&mut self, ds: &Dataset, st: &mut IterState) -> (OpCounters, usize) {
        let IterState {
            assign,
            rho,
            xstate,
            k,
            ..
        } = st;
        self.assign_range(ds, *k, rho, xstate, 0, assign)
    }

    fn assign_par(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let n = st.assign.len();
        self.assign_span(ds, st, 0, n, cfg)
    }

    fn assign_span(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        lo: usize,
        hi: usize,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let this = &*self;
        let IterState {
            assign,
            rho,
            xstate,
            k,
            ..
        } = st;
        let (k, rho, xstate) = (*k, &rho[..], &xstate[..]);
        par::run_sharded(cfg, &mut assign[lo..hi], |rel, chunk| {
            this.assign_range(ds, k, rho, xstate, lo + rel, chunk)
        })
    }

    fn mem_bytes(&self) -> usize {
        self.maint.mem_bytes()
            + self.xp_norm.len() * size_of::<f64>()
            + self.scratch.mem_bytes(CsScratch::mem_bytes)
    }

    fn take_phases(&mut self) -> PhaseTimes {
        self.scratch.drain_phases()
    }

    fn params(&self) -> (Option<usize>, Option<f64>) {
        (Some(self.t_th), None)
    }
}

#[cfg(test)]
mod tests {
    use crate::algo::{run_clustering, run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
    use crate::corpus::{generate, tiny, CorpusSpec};
    use crate::sparse::build_dataset;

    #[test]
    fn cs_matches_mivi() {
        let c = generate(&CorpusSpec {
            n_docs: 600,
            ..tiny(88)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 15,
            seed: 11,
            ..Default::default()
        };
        let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        for kind in [AlgoKind::CsIcp, AlgoKind::CsMivi] {
            let out = run_clustering(kind, &ds, &cfg);
            assert_eq!(out.assign, base.assign, "{} diverged", kind.name());
            assert_eq!(out.iterations(), base.iterations());
        }
    }

    #[test]
    fn cs_has_low_mult_but_pays_sqrts() {
        let c = generate(&CorpusSpec {
            n_docs: 800,
            ..tiny(89)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 16,
            seed: 12,
            ..Default::default()
        };
        let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let cs = run_clustering(AlgoKind::CsIcp, &ds, &cfg);
        assert!(cs.total_mult() < base.total_mult());
        let sq: u64 = cs.logs.iter().map(|l| l.counters.sqrts).sum();
        assert!(sq > 0);
    }

    #[test]
    fn sharded_cs_bit_identical() {
        let c = generate(&CorpusSpec {
            n_docs: 500,
            ..tiny(90)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 12,
            seed: 2,
            ..Default::default()
        };
        let serial = run_clustering(AlgoKind::CsIcp, &ds, &cfg);
        let par = run_clustering_with(AlgoKind::CsIcp, &ds, &cfg, &ParConfig::with_threads(5));
        assert_eq!(serial.assign, par.assign);
        assert_eq!(serial.objective.to_bits(), par.objective.to_bits());
    }
}
