//! TA-ICP / TA-MIVI — the threshold-algorithm comparator (Appendix F-A,
//! Algorithms 8–9), inspired by Fagin+ and Li+'s cosine-threshold
//! algorithm.
//!
//! Unlike ES, the value threshold is *individual per object*:
//! `v_ta = ρ_max / ‖x‖₁` (Eq. 16). The `s ≥ t_th` postings are sorted in
//! descending feature value, and the gathering phase walks each list from
//! the top until the value drops below `v_ta` — an irregular,
//! data-dependent break that the paper blames for TA-ICP's branch
//! mispredictions; the verification phase must re-check every value
//! against `v_ta` to skip the already-consumed prefix (more irregular
//! branches). Both effects are counted in `OpCounters` and visible to
//! the hardware PMU counters.
//!
//! The per-object routine lives in [`TaAssigner::assign_range`] and is
//! shared verbatim by the serial and sharded parallel paths (see
//! `algo::par`).

use crate::algo::kernel;
use crate::algo::par::ScratchPool;
use crate::algo::{par, Assigner, ClusterConfig, IterState, ParConfig};
use crate::index::TaMaintainer;
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::{phase_timing_enabled, PhaseTimes};
use crate::sparse::Dataset;
use std::mem::size_of;
use std::time::Instant;

/// Pooled per-worker scratch: ρ and remaining-mass accumulators plus
/// the survivor list.
#[derive(Default)]
struct TaScratch {
    rho: Vec<f64>,
    y: Vec<f64>,
    z: Vec<u32>,
}

impl TaScratch {
    fn mem_bytes(&self) -> usize {
        (self.rho.capacity() + self.y.capacity()) * size_of::<f64>()
            + self.z.capacity() * size_of::<u32>()
    }
}

pub struct TaAssigner {
    use_icp: bool,
    /// Preset `t_th` (paper §VI-C: 0.9·D); `D` before iteration 2 so the
    /// first pass degenerates to plain MIVI.
    t_th: usize,
    /// Persistent sorted-postings index + incremental splice state.
    maint: TaMaintainer,
    /// ‖x_i‖₁ per object (Eq. 16 denominator), precomputed once.
    l1: Vec<f64>,
    scratch: ScratchPool<TaScratch>,
    /// Per-object gather/verify probes (`SKM_PHASE_TIMING`, default on).
    phase_timing: bool,
}

impl TaAssigner {
    pub fn new(ds: &Dataset, use_icp: bool) -> Self {
        let l1 = (0..ds.n()).map(|i| ds.x.row_l1(i)).collect();
        Self {
            use_icp,
            t_th: ds.d(),
            maint: TaMaintainer::new(),
            l1,
            scratch: ScratchPool::new(),
            phase_timing: phase_timing_enabled(),
        }
    }

    /// Assignment of objects `[lo, lo + out.len())`. `out` holds the
    /// previous assignments on entry and the new ones on exit.
    fn assign_range(
        &self,
        ds: &Dataset,
        k: usize,
        rho_prev: &[f64],
        xstate: &[bool],
        lo: usize,
        out: &mut [u32],
    ) -> (OpCounters, usize) {
        let idx = self.maint.index().expect("rebuild not called");
        let t_th = self.t_th;
        let mut counters = OpCounters::new();
        let mut changes = 0usize;
        // Pooled shard scratch — no per-call allocations (§Perf).
        let s = self.scratch.checkout(TaScratch::default);
        let TaScratch {
            mut rho,
            mut y,
            mut z,
        } = s;
        if rho.len() != k {
            rho.clear();
            rho.resize(k, 0.0);
            y.clear();
            y.resize(k, 0.0);
        }
        // Clear before reserving: `reserve` is relative to len, so this
        // guarantees capacity ≥ K once and pushes never reallocate.
        z.clear();
        if z.capacity() < k {
            z.reserve(k);
        }
        let mut ph = PhaseTimes::default();
        // Per-object probes cost two Instant::now() calls per object;
        // SKM_PHASE_TIMING=0 turns them off (phases then read 0).
        let timing = self.phase_timing;
        let mut t0 = Instant::now();

        for (off, slot) in out.iter_mut().enumerate() {
            let i = lo + off;
            let ((lts, lus), (hts, hus)) = ds.x.row_split(i, t_th);
            let mut y_base = 0.0;
            for &u in hus {
                y_base += u;
            }

            rho.iter_mut().for_each(|r| *r = 0.0);
            y.iter_mut().for_each(|v| *v = y_base);
            z.clear();
            let rho_max0 = rho_prev[i];
            // Individual threshold (Eq. 16). ρ_max < 0 only before the
            // first update; v_ta ≤ 0 then disables the region-2 break.
            let v_ta = rho_max0 / self.l1[i].max(f64::MIN_POSITIVE);
            let mut mult = 0u64;

            let icp_active = self.use_icp && xstate[i];

            // Region 1 exact partial similarities through the shared
            // dispatch (moving prefix under ICP, dense tail rows on the
            // full scan).
            for (&t, &u) in lts.iter().zip(lus) {
                mult += idx.r1.gather_term(t as usize, u, &mut rho, icp_active);
            }
            // Region 2: walk the sorted list until v < v_ta (the TA
            // stopping rule — one irregular branch per visited entry;
            // the data-dependent break keeps this loop out of the
            // branch-free kernels by design — it IS the comparator's
            // measured weakness).
            for (&t, &u) in hts.iter().zip(hus) {
                let (ids, vals) = if icp_active {
                    idx.r2_moving.postings(t as usize)
                } else {
                    idx.r2_all.postings(t as usize)
                };
                for (&c, &v) in ids.iter().zip(vals) {
                    counters.irregular_branches += 1;
                    if v < v_ta {
                        break;
                    }
                    mult += 1;
                    rho[c as usize] += u * v;
                    y[c as usize] -= u;
                }
            }
            // UBP filter (Algorithm 9 lines 9–12): skip ρ_j = 0, then
            // ρ_j + v_ta · y_(i,j)  >  ρ_max keeps j. One multiplication
            // per unpruned-by-zero candidate (no scaling possible with an
            // individual threshold — paper footnote 8).
            if icp_active {
                for &j in &idx.moving_ids {
                    let j = j as usize;
                    counters.irregular_branches += 1;
                    if rho[j] == 0.0 {
                        continue;
                    }
                    mult += 1;
                    if rho[j] + v_ta * y[j] > rho_max0 {
                        z.push(j as u32);
                    }
                }
            } else {
                for j in 0..k {
                    counters.irregular_branches += 1;
                    if rho[j] == 0.0 {
                        continue;
                    }
                    mult += 1;
                    if rho[j] + v_ta * y[j] > rho_max0 {
                        z.push(j as u32);
                    }
                }
            }

            let t1 = if timing {
                let t1 = Instant::now();
                ph.gather += (t1 - t0).as_secs_f64();
                t1
            } else {
                t0
            };

            // Verification: add the not-yet-consumed region-2/3 values
            // (those `< v_ta`), skipping consumed ones with the
            // conditional the paper calls out (Algorithm 8 lines 12–15).
            for (&t, &u) in hts.iter().zip(hus) {
                let row = idx.partial.row(t as usize);
                for &j in &z {
                    let w = row[j as usize];
                    counters.irregular_branches += 1;
                    counters.cold_touches += 1;
                    if w < v_ta {
                        mult += 1;
                        rho[j as usize] += u * w;
                    }
                }
            }

            let (amax, _) = kernel::argmax_ids(&rho, &z, rho_max0, *slot);

            counters.mult += mult;
            counters.candidates += z.len() as u64;
            counters.exact_sims += z.len() as u64;
            if amax != *slot {
                *slot = amax;
                changes += 1;
            }
            if timing {
                let t2 = Instant::now();
                ph.verify += (t2 - t1).as_secs_f64();
                t0 = t2;
            }
        }
        self.scratch.checkin(TaScratch { rho, y, z }, ph);
        (counters, changes)
    }
}

impl Assigner for TaAssigner {
    fn rebuild(&mut self, ds: &Dataset, st: &IterState, cfg: &ClusterConfig) {
        // Switch to the preset t_th once a real threshold ρ_max exists
        // (after the first update step). The maintainer detects the
        // parameter change and falls back to a full build, then splices
        // incrementally for the rest of the run.
        if st.iter >= 2 {
            self.t_th = ((ds.d() as f64 * cfg.t_th_frac) as usize).min(ds.d());
        }
        self.maint.update(&st.means, self.t_th);
    }

    fn assign(&mut self, ds: &Dataset, st: &mut IterState) -> (OpCounters, usize) {
        let IterState {
            assign,
            rho,
            xstate,
            k,
            ..
        } = st;
        self.assign_range(ds, *k, rho, xstate, 0, assign)
    }

    fn assign_par(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let n = st.assign.len();
        self.assign_span(ds, st, 0, n, cfg)
    }

    fn assign_span(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        lo: usize,
        hi: usize,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let this = &*self;
        let IterState {
            assign,
            rho,
            xstate,
            k,
            ..
        } = st;
        let (k, rho, xstate) = (*k, &rho[..], &xstate[..]);
        par::run_sharded(cfg, &mut assign[lo..hi], |rel, chunk| {
            this.assign_range(ds, k, rho, xstate, lo + rel, chunk)
        })
    }

    fn mem_bytes(&self) -> usize {
        self.maint.mem_bytes()
            + self.l1.len() * size_of::<f64>()
            + self.scratch.mem_bytes(TaScratch::mem_bytes)
    }

    fn take_phases(&mut self) -> PhaseTimes {
        self.scratch.drain_phases()
    }

    fn params(&self) -> (Option<usize>, Option<f64>) {
        (Some(self.t_th), None)
    }
}

#[cfg(test)]
mod tests {
    use crate::algo::{run_clustering, run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
    use crate::corpus::{generate, tiny, CorpusSpec};
    use crate::sparse::build_dataset;

    #[test]
    fn ta_matches_mivi() {
        let c = generate(&CorpusSpec {
            n_docs: 600,
            ..tiny(77)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 15,
            seed: 6,
            ..Default::default()
        };
        let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        for kind in [AlgoKind::TaIcp, AlgoKind::TaMivi] {
            let out = run_clustering(kind, &ds, &cfg);
            assert_eq!(out.assign, base.assign, "{} diverged", kind.name());
            assert_eq!(out.iterations(), base.iterations());
        }
    }

    #[test]
    fn ta_reduces_mult_but_pays_in_branches() {
        let c = generate(&CorpusSpec {
            n_docs: 800,
            ..tiny(78)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 16,
            seed: 9,
            ..Default::default()
        };
        let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let ta = run_clustering(AlgoKind::TaIcp, &ds, &cfg);
        assert!(ta.total_mult() < base.total_mult());
        let tb: u64 = ta.logs.iter().map(|l| l.counters.irregular_branches).sum();
        let bb: u64 = base.logs.iter().map(|l| l.counters.irregular_branches).sum();
        assert!(tb > bb, "TA should show the irregular-branch penalty");
    }

    #[test]
    fn sharded_ta_bit_identical() {
        let c = generate(&CorpusSpec {
            n_docs: 500,
            ..tiny(79)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 12,
            seed: 3,
            ..Default::default()
        };
        let serial = run_clustering(AlgoKind::TaIcp, &ds, &cfg);
        let par = run_clustering_with(AlgoKind::TaIcp, &ds, &cfg, &ParConfig::with_threads(3));
        assert_eq!(serial.assign, par.assign);
        assert_eq!(serial.objective.to_bits(), par.objective.to_bits());
    }
}
