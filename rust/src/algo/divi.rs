//! DIVI — the data-(object-)inverted-index strawman of Section II.
//!
//! Same multiplication count as MIVI, but the loop nest is inverted:
//! outer loop over means, middle loop over the mean's terms, inner loop
//! over *object* postings, scattering partial similarities into an
//! N-length accumulator. This destroys the temporal/spatial locality MIVI
//! enjoys (the paper measured ~10× the elapsed time at identical Mult,
//! Table II) — DIVI exists to demonstrate that instruction counts alone
//! do not determine speed.

use crate::algo::{Assigner, ClusterConfig, IterState};
use crate::index::ObjInvIndex;
use crate::metrics::counters::OpCounters;
use crate::sparse::Dataset;

pub struct DiviAssigner {
    /// Object-inverted index (built once; objects never change).
    obj_idx: ObjInvIndex,
    /// Mean rows (kept as the means CSR via IterState).
    /// Per-object accumulator for the current mean.
    score: Vec<f64>,
    /// Epoch tags: `version[i] == cur_epoch` ⇔ `score[i]` is live. This
    /// per-entry check is exactly the kind of irregular conditional the
    /// AFM analysis blames for DIVI's branch behavior.
    version: Vec<u32>,
    touched: Vec<u32>,
    epoch: u32,
    /// Best similarity / argmax per object for the current iteration.
    best: Vec<f64>,
    besta: Vec<u32>,
}

impl DiviAssigner {
    pub fn new(ds: &Dataset) -> Self {
        Self {
            obj_idx: ObjInvIndex::build(&ds.x, 0),
            score: vec![0.0; ds.n()],
            version: vec![u32::MAX; ds.n()],
            touched: Vec::new(),
            epoch: 0,
            best: vec![0.0; ds.n()],
            besta: vec![0; ds.n()],
        }
    }
}

impl Assigner for DiviAssigner {
    fn rebuild(&mut self, _ds: &Dataset, _st: &IterState, _cfg: &ClusterConfig) {
        // The object index never changes; means are read from `st`.
    }

    fn assign(&mut self, ds: &Dataset, st: &mut IterState) -> (OpCounters, usize) {
        let n = ds.n();
        let k = st.k;
        let mut counters = OpCounters::new();

        // Initialize the running best with the previous-iteration
        // thresholds (same tie-break semantics as MIVI's ρ_max).
        self.best.copy_from_slice(&st.rho);
        self.besta.copy_from_slice(&st.assign);

        for j in 0..k {
            self.epoch = self.epoch.wrapping_add(1);
            self.touched.clear();
            let (mts, mvs) = st.means.m.row(j);
            let mut mult = 0u64;
            for (&t, &v) in mts.iter().zip(mvs) {
                let (oids, ovals) = self.obj_idx.postings(t as usize);
                mult += oids.len() as u64;
                // Scattered writes into the N-length accumulator: the
                // cache-hostile inner loop.
                counters.cold_touches += oids.len() as u64;
                for (&i, &u) in oids.iter().zip(ovals) {
                    let i = i as usize;
                    if self.version[i] != self.epoch {
                        self.version[i] = self.epoch;
                        self.score[i] = 0.0;
                        self.touched.push(i as u32);
                    }
                    counters.irregular_branches += 1;
                    self.score[i] += u * v;
                }
            }
            counters.mult += mult;
            for &i in &self.touched {
                let i = i as usize;
                if self.score[i] > self.best[i] {
                    self.best[i] = self.score[i];
                    self.besta[i] = j as u32;
                }
            }
        }
        counters.candidates += (n * k) as u64;
        counters.exact_sims += (n * k) as u64;

        let mut changes = 0;
        for i in 0..n {
            if self.besta[i] != st.assign[i] {
                st.assign[i] = self.besta[i];
                changes += 1;
            }
        }
        (counters, changes)
    }

    fn mem_bytes(&self) -> usize {
        self.obj_idx.nnz() * 12 + self.score.len() * 17 // score+version+best+besta
    }
}

#[cfg(test)]
mod tests {
    use crate::algo::{run_clustering, AlgoKind, ClusterConfig};
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;

    #[test]
    fn divi_matches_mivi_exactly() {
        let c = generate(&tiny(31));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 10,
            seed: 4,
            ..Default::default()
        };
        let a = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let b = run_clustering(AlgoKind::Divi, &ds, &cfg);
        assert_eq!(a.assign, b.assign, "DIVI diverged from MIVI");
        assert_eq!(a.iterations(), b.iterations());
        // Identical multiplication counts — the Section-II observation.
        assert_eq!(a.total_mult(), b.total_mult());
        // ... but DIVI's irregularity proxies are strictly worse.
        let ta: u64 = a.logs.iter().map(|l| l.counters.irregular_branches).sum();
        let tb: u64 = b.logs.iter().map(|l| l.counters.irregular_branches).sum();
        assert!(tb > ta);
    }
}
