//! DIVI — the data-(object-)inverted-index strawman of Section II.
//!
//! Same multiplication count as MIVI, but the loop nest is inverted:
//! outer loop over means, middle loop over the mean's terms, inner loop
//! over *object* postings, scattering partial similarities into an
//! N-length accumulator. This destroys the temporal/spatial locality MIVI
//! enjoys (the paper measured ~10× the elapsed time at identical Mult,
//! Table II) — DIVI exists to demonstrate that instruction counts alone
//! do not determine speed.
//!
//! Sharding: object postings are stored ascending, so a shard restricts
//! every posting list to its `[lo, hi)` sub-range with two binary
//! searches and scatters into a shard-local accumulator. Each object's
//! partial-similarity additions happen in exactly the serial order, so
//! the sharded path is bit-identical to the serial one (see `algo::par`).

use crate::algo::kernel;
use crate::algo::par::ScratchPool;
use crate::algo::{par, Assigner, ClusterConfig, IterState, ParConfig};
use crate::index::{MeanSet, ObjInvIndex};
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::PhaseTimes;
use crate::sparse::Dataset;
use std::mem::size_of;
use std::time::Instant;

/// Pooled per-worker scratch: the shard-length partial-sum arrays and
/// running-best state. `version[li] == epoch` marks a live `score[li]`;
/// `0` marks never-touched, and the epoch counter persists across
/// iterations (recycled before it could wrap into live values).
#[derive(Default)]
struct DiviScratch {
    score: Vec<f64>,
    version: Vec<u32>,
    touched: Vec<u32>,
    best: Vec<f64>,
    besta: Vec<u32>,
    epoch: u32,
}

impl DiviScratch {
    fn mem_bytes(&self) -> usize {
        (self.score.capacity() + self.best.capacity()) * size_of::<f64>()
            + (self.version.capacity() + self.touched.capacity() + self.besta.capacity())
                * size_of::<u32>()
    }
}

pub struct DiviAssigner {
    /// Object-inverted index (built once; objects never change).
    obj_idx: ObjInvIndex,
    /// Number of objects (serial shard covers everything).
    n: usize,
    scratch: ScratchPool<DiviScratch>,
}

impl DiviAssigner {
    pub fn new(ds: &Dataset) -> Self {
        Self {
            obj_idx: ObjInvIndex::build(&ds.x, 0),
            n: ds.n(),
            scratch: ScratchPool::new(),
        }
    }

    /// Assignment of objects `[lo, lo + out.len())`: the mean-major DIVI
    /// loop nest over the shard's slice of every posting list.
    fn assign_range(
        &self,
        k: usize,
        means: &MeanSet,
        rho_prev: &[f64],
        lo: usize,
        out: &mut [u32],
    ) -> (OpCounters, usize) {
        let len = out.len();
        let hi = lo + len;
        // Serial path (or a shard covering everything): skip the
        // per-posting-list binary searches — DIVI's reference timings
        // are the point of this algorithm, so the full-range hot loop
        // must stay identical to the classic loop nest.
        let full_range = lo == 0 && hi >= self.n;
        let mut counters = OpCounters::new();

        // Pooled shard-local state, indexed by `i - lo` (§Perf: no
        // per-call allocations once the pool is warm).
        //
        // `version[li] == epoch` ⇔ `score[li]` is live for the current
        // mean. This per-entry check is exactly the kind of irregular
        // conditional the AFM analysis blames for DIVI's branch behavior.
        let s = self.scratch.checkout(DiviScratch::default);
        let DiviScratch {
            mut score,
            mut version,
            mut touched,
            mut best,
            mut besta,
            mut epoch,
        } = s;
        if score.len() < len {
            score.resize(len, 0.0);
            version.resize(len, 0);
        }
        // Clear before reserving: `reserve` is relative to len, so this
        // guarantees capacity ≥ shard length once and pushes never
        // reallocate (the checked-in scratch arrives non-empty).
        touched.clear();
        if touched.capacity() < len {
            touched.reserve(len);
        }
        // Epoch-space guard: `0` marks never-touched entries; recycle
        // before the per-mean increments could wrap into live values.
        if epoch > u32::MAX - k as u32 - 1 {
            version.iter_mut().for_each(|v| *v = 0);
            epoch = 0;
        }
        // Running best initialized with the previous-iteration thresholds
        // (same tie-break semantics as MIVI's ρ_max).
        best.clear();
        best.extend_from_slice(&rho_prev[lo..hi]);
        besta.clear();
        besta.extend_from_slice(out);
        let t0 = Instant::now();

        for j in 0..k {
            epoch += 1;
            touched.clear();
            let (mts, mvs) = means.m.row(j);
            let mut mult = 0u64;
            for (&t, &v) in mts.iter().zip(mvs) {
                let (oids, ovals) = self.obj_idx.postings(t as usize);
                // Posting ids ascend: restrict to this shard's objects.
                let (oids, ovals) = if full_range {
                    (oids, ovals)
                } else {
                    let a = oids.partition_point(|&i| (i as usize) < lo);
                    let b = oids.partition_point(|&i| (i as usize) < hi);
                    (&oids[a..b], &ovals[a..b])
                };
                mult += oids.len() as u64;
                // Scattered writes into the accumulator: the
                // cache-hostile inner loop (kernel-routed, but the
                // per-entry epoch conditional is intrinsic to DIVI —
                // it is exactly the irregular branch being counted).
                counters.cold_touches += oids.len() as u64;
                counters.irregular_branches += oids.len() as u64;
                // SAFETY: the posting slice was restricted to this
                // shard's object range [lo, hi) above (or covers the
                // full range with lo == 0), and score/version span the
                // shard (len >= hi - lo).
                unsafe {
                    kernel::scatter_add_versioned(
                        &mut score,
                        &mut version,
                        &mut touched,
                        epoch,
                        oids,
                        ovals,
                        v,
                        lo,
                    )
                };
            }
            counters.mult += mult;
            for &li in &touched {
                let li = li as usize;
                if score[li] > best[li] {
                    best[li] = score[li];
                    besta[li] = j as u32;
                }
            }
        }
        counters.candidates += (len * k) as u64;
        counters.exact_sims += (len * k) as u64;

        let mut changes = 0;
        for (slot, &b) in out.iter_mut().zip(&besta) {
            if b != *slot {
                *slot = b;
                changes += 1;
            }
        }
        // DIVI has no verification phase: the mean-major scatter pass is
        // all gathering.
        let ph = PhaseTimes {
            gather: t0.elapsed().as_secs_f64(),
            ..Default::default()
        };
        self.scratch.checkin(
            DiviScratch {
                score,
                version,
                touched,
                best,
                besta,
                epoch,
            },
            ph,
        );
        (counters, changes)
    }
}

impl Assigner for DiviAssigner {
    fn rebuild(&mut self, _ds: &Dataset, _st: &IterState, _cfg: &ClusterConfig) {
        // The object index never changes; means are read from `st`.
    }

    fn assign(&mut self, _ds: &Dataset, st: &mut IterState) -> (OpCounters, usize) {
        let IterState {
            assign,
            rho,
            means,
            k,
            ..
        } = st;
        self.assign_range(*k, means, rho, 0, assign)
    }

    fn assign_par(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let n = st.assign.len();
        self.assign_span(ds, st, 0, n, cfg)
    }

    fn assign_span(
        &mut self,
        _ds: &Dataset,
        st: &mut IterState,
        lo: usize,
        hi: usize,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let this = &*self;
        let IterState {
            assign,
            rho,
            means,
            k,
            ..
        } = st;
        let (k, rho, means) = (*k, &rho[..], &*means);
        par::run_sharded(cfg, &mut assign[lo..hi], |rel, chunk| {
            this.assign_range(k, means, rho, lo + rel, chunk)
        })
    }

    fn mem_bytes(&self) -> usize {
        self.obj_idx.mem_bytes() + self.scratch.mem_bytes(DiviScratch::mem_bytes)
    }

    fn take_phases(&mut self) -> PhaseTimes {
        self.scratch.drain_phases()
    }
}

#[cfg(test)]
mod tests {
    use crate::algo::{run_clustering, run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;

    #[test]
    fn divi_matches_mivi_exactly() {
        let c = generate(&tiny(31));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 10,
            seed: 4,
            ..Default::default()
        };
        let a = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let b = run_clustering(AlgoKind::Divi, &ds, &cfg);
        assert_eq!(a.assign, b.assign, "DIVI diverged from MIVI");
        assert_eq!(a.iterations(), b.iterations());
        // Identical multiplication counts — the Section-II observation.
        assert_eq!(a.total_mult(), b.total_mult());
        // ... but DIVI's irregularity proxies are strictly worse.
        let ta: u64 = a.logs.iter().map(|l| l.counters.irregular_branches).sum();
        let tb: u64 = b.logs.iter().map(|l| l.counters.irregular_branches).sum();
        assert!(tb > ta);
    }

    #[test]
    fn sharded_divi_bit_identical() {
        let c = generate(&tiny(32));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 9,
            seed: 5,
            ..Default::default()
        };
        let serial = run_clustering(AlgoKind::Divi, &ds, &cfg);
        for par in [
            ParConfig::with_threads(4),
            ParConfig {
                threads: 3,
                shard: 17,
            },
        ] {
            let out = run_clustering_with(AlgoKind::Divi, &ds, &cfg, &par);
            assert_eq!(serial.assign, out.assign, "{par:?}");
            assert_eq!(serial.objective.to_bits(), out.objective.to_bits());
            assert_eq!(serial.total_mult(), out.total_mult());
        }
    }
}
