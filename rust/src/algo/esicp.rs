//! ES-ICP — the paper's proposed algorithm (Section IV, Algorithms 2–6)
//! — plus its ablations ES (no ICP), ThV (value threshold only) and ThT
//! (term threshold only) from Appendix D.
//!
//! Assignment of one object (Algorithm 4):
//!
//! 1. **Gathering** (`G_1` for ICP-eligible objects, else `G_0`,
//!    Algorithm 5): accumulate exact partial similarities over Region 1
//!    (`s < t_th`) and Region 2 (`s ≥ t_th`, `v ≥ v_th`), decrementing
//!    the remaining L1 mass `y_(i,j)`; then the ES filter keeps centroid
//!    `j` iff `ρ_j + y_(i,j) > ρ_max` — thanks to the Appendix-A scaling
//!    (object values × v_th, mean values ÷ v_th) the Region-3 upper
//!    bound is that pure *addition*.
//! 2. **Verification**: for survivors only, add the exact Region-3
//!    partial similarity through the full-expression partial index `M^p`
//!    and take the argmax.
//!
//! The structural parameters are estimated by `estparams` at the first
//! and second update steps (Algorithm 6 lines 17–19).
//!
//! The per-object routine lives in [`EsAssigner::assign_range`] and is
//! shared verbatim by the serial and sharded parallel paths (bit-identical
//! by construction; see `algo::par`). Estimation and index construction
//! stay serial inside `rebuild` — the shared structured index is then
//! read-only for the whole assignment step.

use crate::algo::kernel;
use crate::algo::par::ScratchPool;
use crate::algo::{par, Assigner, ClusterConfig, IterState, ParConfig};
use crate::estparams::{estimate, EstConfig};
use crate::index::{EsMaintainer, ObjInvIndex};
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::{phase_timing_enabled, PhaseTimes};
use crate::sparse::{CsrMatrix, Dataset};
use std::mem::size_of;
use std::time::Instant;

/// Which variant of the ES family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EsMode {
    /// Both structural parameters estimated; `icp` toggles the auxiliary
    /// filter (ES-ICP vs ES / ES-MIVI).
    Full { icp: bool },
    /// ThV (Appendix D): `t_th` pinned to 0, only `v_th` estimated.
    ValueOnly,
    /// ThT (Appendix D): `v_th` pinned to 1.0, only `t_th` estimated.
    TermOnly,
}

/// Pooled per-worker scratch: the folded ρ accumulator and the
/// survivor list `Z`.
#[derive(Default)]
struct EsScratch {
    rho: Vec<f64>,
    z: Vec<u32>,
}

impl EsScratch {
    fn mem_bytes(&self) -> usize {
        self.rho.capacity() * size_of::<f64>() + self.z.capacity() * size_of::<u32>()
    }
}

pub struct EsAssigner {
    mode: EsMode,
    /// Current structural parameters. Before the first estimation this
    /// is `(D, 1.0)`: everything is Region 1 and the gathering phase
    /// degenerates to a full MIVI pass (so iteration 1 is exact without
    /// special-casing).
    t_th: usize,
    v_th: f64,
    /// Persistent structured index + incremental splice state (§Perf);
    /// falls back to a from-scratch build whenever EstParams changes
    /// `(t_th, v_th)`.
    maint: EsMaintainer,
    /// Object matrix with values scaled by `v_th` (Appendix A). Rebuilt
    /// only when `v_th` changes (estimations happen twice).
    xs: CsrMatrix,
    xs_scale: f64,
    /// Partial object inverted index for EstParams (built lazily).
    xp: Option<ObjInvIndex>,
    estimations_done: usize,
    /// One-shot guard set by [`Assigner::import_params_state`]: the
    /// initial rebuild of a resumed run re-creates an index rebuild the
    /// uninterrupted run already performed, so the estimation that may
    /// be due at that `st.iter` must not fire a second time (it belongs
    /// to the *next* rebuild, with the next round's state).
    skip_estimation_once: bool,
    scratch: ScratchPool<EsScratch>,
    /// Per-object gather/verify probes (`SKM_PHASE_TIMING`, default on).
    phase_timing: bool,
}

impl EsAssigner {
    pub fn new(ds: &Dataset, mode: EsMode) -> Self {
        Self {
            mode,
            t_th: ds.d(),
            v_th: 1.0,
            maint: EsMaintainer::new(),
            xs: ds.x.clone(),
            xs_scale: 1.0,
            xp: None,
            estimations_done: 0,
            skip_estimation_once: false,
            scratch: ScratchPool::new(),
            phase_timing: phase_timing_enabled(),
        }
    }

    fn use_icp(&self) -> bool {
        matches!(self.mode, EsMode::Full { icp: true })
    }

    fn est_config(&self, ds: &Dataset, cfg: &ClusterConfig) -> EstConfig {
        let d = ds.d();
        let s_min = ((d as f64 * cfg.s_min_frac) as usize).min(d.saturating_sub(1));
        match self.mode {
            EsMode::Full { .. } => EstConfig {
                s_min,
                n_candidates: cfg.n_vth_candidates,
                fixed_t: None,
                fixed_v: None,
                max_sample_objects: 4_000,
            },
            EsMode::ValueOnly => EstConfig {
                s_min: 0,
                n_candidates: cfg.n_vth_candidates,
                fixed_t: Some(0),
                fixed_v: None,
                max_sample_objects: 4_000,
            },
            EsMode::TermOnly => EstConfig {
                s_min,
                n_candidates: 1,
                fixed_t: None,
                fixed_v: Some(1.0),
                max_sample_objects: 4_000,
            },
        }
    }

    fn rescale_objects(&mut self, ds: &Dataset) {
        if (self.v_th - self.xs_scale).abs() < f64::EPSILON * self.v_th.abs() {
            return;
        }
        self.xs = ds.x.clone();
        if self.v_th != 1.0 {
            for i in 0..self.xs.n_rows() {
                let (_, vs) = self.xs.row_mut(i);
                for v in vs {
                    *v *= self.v_th;
                }
            }
        }
        self.xs_scale = self.v_th;
    }

    /// Assignment of objects `[lo, lo + out.len())` against the shared
    /// structured index. `out` holds the previous assignments on entry.
    fn assign_range(
        &self,
        k: usize,
        rho_prev: &[f64],
        xstate: &[bool],
        lo: usize,
        out: &mut [u32],
    ) -> (OpCounters, usize) {
        let idx = self.maint.index().expect("rebuild not called");
        let t_th = self.t_th;
        let use_icp = self.use_icp();
        let mut counters = OpCounters::new();
        let mut changes = 0usize;
        // Pooled shard scratch (folded ρ accumulator + survivor list):
        // no per-call allocations — `z` is pre-reserved to K so pushes
        // never grow it (§Perf).
        let s = self.scratch.checkout(EsScratch::default);
        let EsScratch { mut rho, mut z } = s;
        if rho.len() != k {
            rho.clear();
            rho.resize(k, 0.0);
        }
        // Clear before reserving: `reserve` is relative to len, so this
        // guarantees capacity ≥ K once and pushes never reallocate.
        z.clear();
        if z.capacity() < k {
            z.reserve(k);
        }
        let mut ph = PhaseTimes::default();
        // Per-object probes cost two Instant::now() calls per object;
        // SKM_PHASE_TIMING=0 turns them off (phases then read 0).
        let timing = self.phase_timing;
        let mut t0 = Instant::now();

        for (off, slot) in out.iter_mut().enumerate() {
            let i = lo + off;
            // Split the object's terms at t_th (terms are ascending).
            let ((lts, lus), (hts, hus)) = self.xs.row_split(i, t_th);
            let mut y_base = 0.0;
            for &u in hus {
                y_base += u;
            }

            // Folded accumulator (see EsIndex docs): start at the full
            // Region-3 upper-bound mass; Region-2 entries store v−1 so
            // one multiply-add accumulates and retires simultaneously.
            // After the gathering phase, rho[j] IS the upper bound.
            rho.iter_mut().for_each(|r| *r = y_base);
            let rho_max0 = rho_prev[i];
            let mut mult = 0u64;

            let icp_active = use_icp && xstate[i];
            // Region 1 through the shared dispatch (moving prefix under
            // ICP, dense tail rows on the full scan — Algorithm 5).
            for (&t, &u) in lts.iter().zip(lus) {
                mult += idx.r1.gather_term(t as usize, u, &mut rho, icp_active);
            }
            if icp_active {
                // G_1 over Region 2's moving blocks, then the ES filter
                // over moving centroids: a bare comparison.
                for (&t, &u) in hts.iter().zip(hus) {
                    let (ids, vals) = idx.r2.postings_moving(t as usize);
                    mult += ids.len() as u64;
                    // SAFETY: region-2 ids are centroid ids < k ==
                    // rho.len() by index construction, and each term's
                    // posting list holds at most one entry per centroid,
                    // so the ids are pairwise distinct as the SIMD
                    // gather/scatter backends require.
                    unsafe { kernel::scatter_add(&mut rho, ids, vals, u) };
                }
                kernel::collect_above_ids(&rho, &idx.moving_ids, rho_max0, &mut z);
            } else {
                // G_0 over the full Region-2 arrays.
                for (&t, &u) in hts.iter().zip(hus) {
                    let (ids, vals) = idx.r2.postings(t as usize);
                    mult += ids.len() as u64;
                    // SAFETY: as above (in-bounds and pairwise-distinct
                    // ids by index construction).
                    unsafe { kernel::scatter_add(&mut rho, ids, vals, u) };
                }
                kernel::collect_above(&rho, rho_max0, &mut z);
            }

            let t1 = if timing {
                let t1 = Instant::now();
                ph.gather += (t1 - t0).as_secs_f64();
                t1
            } else {
                t0
            };

            // Verification phase: retire the survivors' remaining bound
            // mass through the deficit index — rho lands exactly on the
            // similarity (Algorithm 4 l.12–13, folded).
            let nth = hts.len() as u64;
            mult += z.len() as u64 * nth;
            for (&t, &u) in hts.iter().zip(hus) {
                let row = idx.partial.row(t as usize);
                kernel::verify_axpy_ids(&mut rho, &z, row, u, -1.0);
            }

            let (amax, _) = kernel::argmax_ids(&rho, &z, rho_max0, *slot);

            counters.mult += mult;
            counters.candidates += z.len() as u64;
            counters.exact_sims += z.len() as u64;
            if amax != *slot {
                *slot = amax;
                changes += 1;
            }
            if timing {
                let t2 = Instant::now();
                ph.verify += (t2 - t1).as_secs_f64();
                t0 = t2;
            }
        }
        self.scratch.checkin(EsScratch { rho, z }, ph);
        (counters, changes)
    }
}

impl Assigner for EsAssigner {
    fn rebuild(&mut self, ds: &Dataset, st: &IterState, cfg: &ClusterConfig) {
        // EstParams at the first and second update steps (st.iter is the
        // iteration of the NEXT assignment, so 2 and 3).
        // The probability model behind EstParams assumes K > e (Eq. 28
        // divides the tail mass 1/K; ln(K/e) must be positive). For very
        // small K the filter cannot pay off anyway — keep the degenerate
        // (D, 1.0) parameters, i.e. exact MIVI behavior.
        let skip_once = std::mem::take(&mut self.skip_estimation_once);
        if !skip_once && st.k >= 4 && (st.iter == 2 || st.iter == 3) && self.estimations_done < 2 {
            let mut ec = self.est_config(ds, cfg);
            if self.estimations_done == 0 {
                // The first estimation exists only to cheapen iteration
                // 2 (Appendix A): a coarse grid over a small object
                // sample is enough. The second estimation (authoritative,
                // used for the rest of the run) gets the full budget.
                ec.n_candidates = (ec.n_candidates / 3).max(5);
                ec.max_sample_objects = ec.max_sample_objects.min(1_500);
            }
            if self
                .xp
                .as_ref()
                .map(|x| x.s_lo > ec.s_min.min(ec.fixed_t.unwrap_or(usize::MAX)))
                .unwrap_or(true)
            {
                let lo = ec.fixed_t.map(|t| t.min(ec.s_min)).unwrap_or(ec.s_min);
                self.xp = Some(ObjInvIndex::build(&ds.x, lo));
            }
            let est = estimate(ds, &st.means, &st.rho, self.xp.as_ref().unwrap(), &ec);
            self.t_th = est.t_th;
            self.v_th = est.v_th;
            self.estimations_done += 1;
            self.rescale_objects(ds);
            if self.estimations_done == 2 {
                // X^p is only needed by EstParams; release it for the
                // long steady-state phase (its transient footprint is
                // merged into the estimation cost, like the paper's
                // elapsed-time accounting in footnote 7).
                self.xp = None;
            }
        }
        // Incremental maintenance: splice the persistent index when the
        // parameters are unchanged and few centroids moved; full rebuild
        // otherwise (in particular right after the estimations above).
        self.maint.update(&st.means, self.t_th, self.v_th);
    }

    fn assign(&mut self, _ds: &Dataset, st: &mut IterState) -> (OpCounters, usize) {
        let IterState {
            assign,
            rho,
            xstate,
            k,
            ..
        } = st;
        self.assign_range(*k, rho, xstate, 0, assign)
    }

    fn assign_par(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let n = st.assign.len();
        self.assign_span(ds, st, 0, n, cfg)
    }

    fn assign_span(
        &mut self,
        _ds: &Dataset,
        st: &mut IterState,
        lo: usize,
        hi: usize,
        cfg: &ParConfig,
    ) -> (OpCounters, usize) {
        let this = &*self;
        let IterState {
            assign,
            rho,
            xstate,
            k,
            ..
        } = st;
        let (k, rho, xstate) = (*k, &rho[..], &xstate[..]);
        par::run_sharded(cfg, &mut assign[lo..hi], |rel, chunk| {
            this.assign_range(k, rho, xstate, lo + rel, chunk)
        })
    }

    fn mem_bytes(&self) -> usize {
        // The scaled object copy substitutes for the input matrix (the
        // paper scales in place, Algorithm 4 lines 1-2), and X^p lives
        // only through the two estimations, so neither is counted here —
        // this matches the paper's Max MEM accounting where the partial
        // mean-inverted index is the differentiating term (§VI-D). The
        // maintainer's persistent splice state and the pooled scratch
        // ARE counted (they live for the whole run).
        self.maint.mem_bytes() + self.scratch.mem_bytes(EsScratch::mem_bytes)
    }

    fn take_phases(&mut self) -> PhaseTimes {
        self.scratch.drain_phases()
    }

    fn params(&self) -> (Option<usize>, Option<f64>) {
        (Some(self.t_th), Some(self.v_th))
    }

    fn export_params_state(&self) -> crate::algo::ParamsState {
        crate::algo::ParamsState {
            t_th: Some(self.t_th),
            v_th: Some(self.v_th),
            estimations_done: self.estimations_done,
        }
    }

    fn import_params_state(&mut self, ds: &Dataset, ps: &crate::algo::ParamsState) {
        if let Some(t) = ps.t_th {
            self.t_th = t;
        }
        if let Some(v) = ps.v_th {
            self.v_th = v;
        }
        self.estimations_done = ps.estimations_done;
        // Re-derive the v_th-scaled object copy the checkpointed run was
        // using (Appendix A scaling); no-op while v_th is still 1.0.
        self.rescale_objects(ds);
        self.skip_estimation_once = true;
    }
}

#[cfg(test)]
mod tests {
    use crate::algo::{run_clustering, run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
    use crate::corpus::{generate, tiny, CorpusSpec};
    use crate::sparse::build_dataset;

    fn dataset(seed: u64) -> crate::sparse::Dataset {
        let c = generate(&CorpusSpec {
            n_docs: 600,
            ..tiny(seed)
        });
        build_dataset("t", c.n_terms, &c.docs)
    }

    /// The central exactness property: every ES-family variant follows
    /// MIVI's trajectory (same assignments, same iteration count).
    #[test]
    fn es_family_matches_mivi() {
        let ds = dataset(41);
        let cfg = ClusterConfig {
            k: 15,
            seed: 2,
            ..Default::default()
        };
        let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        for kind in [AlgoKind::EsIcp, AlgoKind::Es, AlgoKind::ThV, AlgoKind::ThT] {
            let out = run_clustering(kind, &ds, &cfg);
            assert_eq!(
                out.assign,
                base.assign,
                "{} diverged from MIVI",
                kind.name()
            );
            assert_eq!(out.iterations(), base.iterations(), "{}", kind.name());
            assert!(
                (out.objective - base.objective).abs() < 1e-6,
                "{} objective {} vs {}",
                kind.name(),
                out.objective,
                base.objective
            );
        }
    }

    #[test]
    fn es_icp_prunes() {
        let ds = dataset(43);
        let cfg = ClusterConfig {
            k: 15,
            seed: 7,
            ..Default::default()
        };
        let base = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let es = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        assert!(
            es.total_mult() < base.total_mult(),
            "ES-ICP did not reduce multiplications: {} vs {}",
            es.total_mult(),
            base.total_mult()
        );
        // After the parameters kick in (iteration ≥ 2) the CPR must drop
        // below 1; MIVI's is identically 1.
        let late_cpr = es.logs[es.logs.len() / 2].cpr;
        assert!(late_cpr < 1.0, "CPR never dropped: {late_cpr}");
        // Structural parameters were estimated.
        assert!(es.t_th.unwrap() <= ds.d());
        assert!(es.v_th.unwrap() > 0.0 && es.v_th.unwrap() < 1.0);
    }

    #[test]
    fn tht_uses_pinned_v() {
        let ds = dataset(44);
        let cfg = ClusterConfig {
            k: 10,
            seed: 3,
            ..Default::default()
        };
        let out = run_clustering(AlgoKind::ThT, &ds, &cfg);
        assert_eq!(out.v_th, Some(1.0));
    }

    #[test]
    fn thv_uses_pinned_t() {
        let ds = dataset(45);
        let cfg = ClusterConfig {
            k: 10,
            seed: 3,
            ..Default::default()
        };
        let out = run_clustering(AlgoKind::ThV, &ds, &cfg);
        assert_eq!(out.t_th, Some(0));
        // ThV's partial index spans all of D: its memory must exceed
        // ES-ICP's (the Appendix-D Max MEM observation).
        let es = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        assert!(out.max_mem_bytes > es.max_mem_bytes);
    }

    #[test]
    fn sharded_es_icp_bit_identical() {
        let ds = dataset(46);
        let cfg = ClusterConfig {
            k: 12,
            seed: 4,
            ..Default::default()
        };
        let serial = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        for threads in [2usize, 7] {
            let par =
                run_clustering_with(AlgoKind::EsIcp, &ds, &cfg, &ParConfig::with_threads(threads));
            assert_eq!(serial.assign, par.assign, "threads={threads}");
            assert_eq!(serial.objective.to_bits(), par.objective.to_bits());
            assert_eq!(serial.t_th, par.t_th);
            assert_eq!(serial.v_th, par.v_th);
        }
    }
}
