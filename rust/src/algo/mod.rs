//! The clustering algorithms: the proposed **ES-ICP** and every
//! comparator the paper evaluates (Sections II, VI; Appendices D–G).
//!
//! All algorithms are *accelerations* in the paper's sense: started from
//! the same seeding they compute the same Lloyd fixed-point trajectory as
//! the baseline MIVI (up to floating-point tie-breaks; see
//! `coordinator::audit`). They differ only in the data structures and
//! pruning filters used at the assignment step.
//!
//! | kind        | main filter | aux filter | index |
//! |-------------|-------------|------------|-------|
//! | `Mivi`      | –           | –          | plain mean-inverted |
//! | `Divi`      | –           | –          | object-inverted (strawman, §II) |
//! | `Ding`      | group drift bounds | –   | dense means (Yinyang-for-cosine analog, §II) |
//! | `Icp`       | –           | ICP        | two-block mean-inverted |
//! | `EsIcp`     | ES          | ICP        | three-region structured |
//! | `Es`        | ES          | –          | three-region structured |
//! | `ThV`       | ES (t_th=0) | –          | value-threshold only (App. D) |
//! | `ThT`       | ES (v_th=1) | –          | term-threshold only (App. D) |
//! | `TaIcp`     | TA          | ICP        | sorted postings (App. F) |
//! | `TaMivi`    | TA          | –          | sorted postings |
//! | `CsIcp`     | CS          | ICP        | squared postings (App. F) |
//! | `CsMivi`    | CS          | –          | squared postings |

pub mod cs;
pub mod ding;
pub mod divi;
pub mod esicp;
pub mod kernel;
pub mod mivi;
pub mod par;
pub mod ta;

pub use par::ParConfig;

use crate::index::{membership_changes, update_means_with_rho_par, MeanSet};
use crate::metrics::counters::OpCounters;
use crate::persist::checkpoint::{CheckpointSpec, CheckpointState, RunFingerprint};
use crate::metrics::perf::PhaseTimes;
use crate::sparse::Dataset;
use crate::util::rng::Pcg32;
use crate::util::timer::Stopwatch;

/// Which algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    Mivi,
    Divi,
    Ding,
    Icp,
    EsIcp,
    Es,
    ThV,
    ThT,
    TaIcp,
    TaMivi,
    CsIcp,
    CsMivi,
}

impl AlgoKind {
    pub fn name(self) -> &'static str {
        match self {
            AlgoKind::Mivi => "MIVI",
            AlgoKind::Divi => "DIVI",
            AlgoKind::Ding => "Ding+",
            AlgoKind::Icp => "ICP",
            AlgoKind::EsIcp => "ES-ICP",
            AlgoKind::Es => "ES",
            AlgoKind::ThV => "ThV",
            AlgoKind::ThT => "ThT",
            AlgoKind::TaIcp => "TA-ICP",
            AlgoKind::TaMivi => "TA-MIVI",
            AlgoKind::CsIcp => "CS-ICP",
            AlgoKind::CsMivi => "CS-MIVI",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "mivi" => AlgoKind::Mivi,
            "divi" => AlgoKind::Divi,
            "ding" | "ding+" => AlgoKind::Ding,
            "icp" => AlgoKind::Icp,
            "es-icp" | "esicp" => AlgoKind::EsIcp,
            "es" | "es-mivi" => AlgoKind::Es,
            "thv" => AlgoKind::ThV,
            "tht" => AlgoKind::ThT,
            "ta-icp" | "taicp" => AlgoKind::TaIcp,
            "ta-mivi" | "tamivi" => AlgoKind::TaMivi,
            "cs-icp" | "csicp" => AlgoKind::CsIcp,
            "cs-mivi" | "csmivi" => AlgoKind::CsMivi,
            _ => return None,
        })
    }

    /// All kinds, in the paper's presentation order.
    pub fn all() -> &'static [AlgoKind] {
        &[
            AlgoKind::Mivi,
            AlgoKind::Divi,
            AlgoKind::Ding,
            AlgoKind::Icp,
            AlgoKind::EsIcp,
            AlgoKind::Es,
            AlgoKind::ThV,
            AlgoKind::ThT,
            AlgoKind::TaIcp,
            AlgoKind::TaMivi,
            AlgoKind::CsIcp,
            AlgoKind::CsMivi,
        ]
    }
}

/// Run configuration shared by all algorithms.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of clusters K.
    pub k: usize,
    /// Seeding RNG seed; identical seeds give identical initial states
    /// across algorithms (the exactness audits rely on this).
    pub seed: u64,
    /// Iteration cap (the paper's runs converge in 64–81 iterations).
    pub max_iters: usize,
    /// Preset `t_th` as a fraction of D for TA-ICP / CS-ICP
    /// (paper §VI-C: 0.9·D).
    pub t_th_frac: f64,
    /// EstParams: minimum `s'` candidate as a fraction of D
    /// (paper App. C used s_min ≈ 0.865·D).
    pub s_min_frac: f64,
    /// EstParams: number of `v_th` candidates.
    pub n_vth_candidates: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            k: 16,
            seed: 42,
            max_iters: 200,
            t_th_frac: 0.9,
            s_min_frac: 0.8,
            n_vth_candidates: 25,
        }
    }
}

/// Estimator / structural-parameter state persisted in a checkpoint
/// ([`crate::persist::checkpoint`]) so a resumed run re-enters the
/// bit-exact trajectory of the uninterrupted one. Stateless assigners
/// (MIVI, DIVI, Ding, TA, CS — their thresholds are pure functions of
/// config and iteration) export the default; the ES family carries its
/// estimated `t_th` / `v_th` and how many EstParams passes have run.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ParamsState {
    pub t_th: Option<usize>,
    pub v_th: Option<f64>,
    pub estimations_done: usize,
}

/// Mutable state shared between the driver and an assigner.
pub struct IterState {
    pub k: usize,
    /// Current assignment a(i).
    pub assign: Vec<u32>,
    /// ρ_{a(i)}^{[r-1]}: similarity of each object to its centroid as of
    /// the previous update step (-1.0 before the first assignment).
    pub rho: Vec<f64>,
    /// ICP eligibility (Eq. 5): similarity did not decrease and the
    /// assignment did not change at the previous step.
    pub xstate: Vec<bool>,
    /// Current mean set M^{[r-1]} (with moved flags).
    pub means: MeanSet,
    /// 1-based iteration of the *next* assignment step.
    pub iter: usize,
}

/// Per-iteration record (feeds Figs. 1, 7, 8, 15, 16 and all tables).
#[derive(Debug, Clone)]
pub struct IterLog {
    pub iter: usize,
    pub counters: OpCounters,
    pub assign_secs: f64,
    /// Mean-construction time (update step proper: centroid sums,
    /// normalization, ρ, ICP bookkeeping).
    pub update_secs: f64,
    /// Index-maintenance time (incremental splice or from-scratch
    /// rebuild, plus EstParams where applicable) performed during this
    /// iteration's update window — i.e. over the mean set whose
    /// `n_moving` is logged in the same record. Record 1 additionally
    /// carries the initial seed-index build. Together with
    /// `update_secs` this is the paper's footnote-7 "update step".
    pub rebuild_secs: f64,
    /// Assignment gathering-phase seconds (region accumulation +
    /// pruning filters), summed across shard workers — CPU-seconds
    /// under `--threads N`, wall time in serial runs.
    pub gather_secs: f64,
    /// Assignment verification-phase seconds (partial-index exact pass
    /// + argmax), same units caveat as `gather_secs`.
    pub verify_secs: f64,
    pub changes: usize,
    pub cpr: f64,
    pub mem_bytes: usize,
    pub n_moving: usize,
    pub objective: f64,
}

/// Result of a complete clustering run.
pub struct ClusterOutput {
    pub algo: AlgoKind,
    pub assign: Vec<u32>,
    pub objective: f64,
    pub logs: Vec<IterLog>,
    pub converged: bool,
    /// Maximum resident structure size over the run (paper's Max MEM).
    pub max_mem_bytes: usize,
    /// Final structural parameters, if the algorithm uses them.
    pub t_th: Option<usize>,
    pub v_th: Option<f64>,
}

impl ClusterOutput {
    pub fn iterations(&self) -> usize {
        self.logs.len()
    }

    pub fn total_mult(&self) -> u64 {
        self.logs.iter().map(|l| l.counters.mult).sum()
    }

    pub fn avg_mult(&self) -> f64 {
        self.total_mult() as f64 / self.logs.len().max(1) as f64
    }

    pub fn total_assign_secs(&self) -> f64 {
        self.logs.iter().map(|l| l.assign_secs).sum()
    }

    /// Total update-step seconds in the paper's footnote-7 sense: mean
    /// construction **plus** index maintenance / EstParams.
    pub fn total_update_secs(&self) -> f64 {
        self.logs.iter().map(|l| l.update_secs + l.rebuild_secs).sum()
    }

    /// Index-maintenance (rebuild-phase) seconds alone.
    pub fn total_rebuild_secs(&self) -> f64 {
        self.logs.iter().map(|l| l.rebuild_secs).sum()
    }

    pub fn total_gather_secs(&self) -> f64 {
        self.logs.iter().map(|l| l.gather_secs).sum()
    }

    pub fn total_verify_secs(&self) -> f64 {
        self.logs.iter().map(|l| l.verify_secs).sum()
    }

    /// Operation counters summed over the whole run.
    pub fn total_counters(&self) -> OpCounters {
        let mut c = OpCounters::new();
        for l in &self.logs {
            c.add(&l.counters);
        }
        c
    }

    pub fn total_secs(&self) -> f64 {
        self.total_assign_secs() + self.total_update_secs()
    }

    pub fn avg_iter_secs(&self) -> f64 {
        self.total_secs() / self.logs.len().max(1) as f64
    }
}

/// The assignment-step strategy implemented by each algorithm.
///
/// `Sync` is a supertrait so a shared `&dyn Assigner` can be handed to
/// the scoped worker threads of the sharded engine ([`par`]); every
/// assigner's per-iteration structures are plain read-only data during
/// the assignment step.
pub trait Assigner: Sync {
    /// Rebuild per-iteration structures after an update step (or from the
    /// seed means before iteration 1). `st.iter` is the iteration whose
    /// assignment comes next.
    fn rebuild(&mut self, ds: &Dataset, st: &IterState, cfg: &ClusterConfig);

    /// Run one assignment step: update `st.assign` in place, return the
    /// cost counters and the number of changed assignments.
    fn assign(&mut self, ds: &Dataset, st: &mut IterState) -> (OpCounters, usize);

    /// Sharded multi-threaded assignment step. Implementations run the
    /// *same* per-object routine as [`Assigner::assign`] over contiguous
    /// object shards (see [`par::run_sharded`]) so the result — new
    /// assignments, counters, change count — is bit-identical to the
    /// serial path. The default falls back to serial execution.
    fn assign_par(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        par: &ParConfig,
    ) -> (OpCounters, usize) {
        let _ = par;
        self.assign(ds, st)
    }

    /// Assignment step restricted to the contiguous object span
    /// `[lo, hi)` — the mini-batch / streaming entry point
    /// ([`crate::coordinator::minibatch`]). Implementations run the
    /// same per-object routine as [`Assigner::assign`] over the span
    /// (sharded when `par.is_parallel()`), so a span covering every
    /// object is bit-identical to [`Assigner::assign_par`], and a
    /// partial span updates only `st.assign[lo..hi]` (counters cover
    /// exactly those objects). All six built-in assigners override
    /// this; the default supports only the full span.
    fn assign_span(
        &mut self,
        ds: &Dataset,
        st: &mut IterState,
        lo: usize,
        hi: usize,
        par: &ParConfig,
    ) -> (OpCounters, usize) {
        assert!(
            lo == 0 && hi == st.assign.len(),
            "this assigner does not support partial-span (mini-batch) assignment"
        );
        self.assign_par(ds, st, par)
    }

    /// Bytes held by the algorithm-specific structures right now
    /// (indexes, persistent maintainer state, pooled scratch).
    fn mem_bytes(&self) -> usize;

    /// Drain the gather/verify phase seconds accumulated since the last
    /// call (the coordinator calls this once per assignment step). The
    /// six built-in assigners all override this: ES/TA/CS split
    /// gather/verify per object, MIVI/DIVI/Ding report their whole pass
    /// as gather. The default reports no breakdown (all-zero) — an
    /// assigner that does not override it logs zero phase times.
    /// Summed across shard workers, so parallel runs report
    /// CPU-seconds, not wall time (see [`PhaseTimes`]).
    fn take_phases(&mut self) -> PhaseTimes {
        PhaseTimes::default()
    }

    /// Current structural parameters, if applicable.
    fn params(&self) -> (Option<usize>, Option<f64>) {
        (None, None)
    }

    /// Export the estimator state a checkpoint must carry (see
    /// [`ParamsState`]). The default — no state — is correct for every
    /// assigner whose thresholds are pure functions of config and
    /// iteration number.
    fn export_params_state(&self) -> ParamsState {
        ParamsState::default()
    }

    /// Restore state from [`Assigner::export_params_state`] on a
    /// resumed run, *before* the initial rebuild. Implementations must
    /// leave the assigner on the bit-exact trajectory of the
    /// uninterrupted run (`tests/persist.rs` enforces this).
    fn import_params_state(&mut self, ds: &Dataset, ps: &ParamsState) {
        let _ = (ds, ps);
    }
}

/// Construct the assigner for an algorithm kind.
pub fn make_assigner(kind: AlgoKind, ds: &Dataset, cfg: &ClusterConfig) -> Box<dyn Assigner> {
    match kind {
        AlgoKind::Mivi => Box::new(mivi::MiviAssigner::new(ds, /*icp=*/ false)),
        AlgoKind::Icp => Box::new(mivi::MiviAssigner::new(ds, /*icp=*/ true)),
        AlgoKind::Divi => Box::new(divi::DiviAssigner::new(ds)),
        AlgoKind::Ding => Box::new(ding::DingAssigner::new(ds, cfg)),
        AlgoKind::EsIcp => Box::new(esicp::EsAssigner::new(ds, esicp::EsMode::Full { icp: true })),
        AlgoKind::Es => Box::new(esicp::EsAssigner::new(ds, esicp::EsMode::Full { icp: false })),
        AlgoKind::ThV => Box::new(esicp::EsAssigner::new(ds, esicp::EsMode::ValueOnly)),
        AlgoKind::ThT => Box::new(esicp::EsAssigner::new(ds, esicp::EsMode::TermOnly)),
        AlgoKind::TaIcp => Box::new(ta::TaAssigner::new(ds, true)),
        AlgoKind::TaMivi => Box::new(ta::TaAssigner::new(ds, false)),
        AlgoKind::CsIcp => Box::new(cs::CsAssigner::new(ds, true)),
        AlgoKind::CsMivi => Box::new(cs::CsAssigner::new(ds, false)),
    }
}

/// Deterministic seeding: K distinct objects as initial means (the
/// paper's random initial-state selection; Appendix H shows seeding does
/// not matter at large K, which `benches/exp_seeding` reproduces).
pub fn seed_means(ds: &Dataset, k: usize, seed: u64) -> MeanSet {
    assert!(k >= 1 && k <= ds.n(), "K={k} out of range (N={})", ds.n());
    let mut rng = Pcg32::new(seed ^ 0x5eed_5eed);
    let picks = rng.sample_distinct(ds.n(), k);
    let rows: Vec<Vec<(u32, f64)>> = picks
        .iter()
        .map(|&i| {
            let (ts, vs) = ds.x.row(i);
            ts.iter().cloned().zip(vs.iter().cloned()).collect()
        })
        .collect();
    MeanSet {
        m: crate::index::RowSlab::from_rows(ds.d(), &rows),
        moved: vec![true; k],
        sizes: vec![0; k],
    }
}

/// Run a complete clustering with the given algorithm on the serial
/// (reference) path. See module docs.
pub fn run_clustering(kind: AlgoKind, ds: &Dataset, cfg: &ClusterConfig) -> ClusterOutput {
    run_clustering_with(kind, ds, cfg, &ParConfig::serial())
}

/// Validate a [`ClusterConfig`] against a dataset, as a typed error
/// instead of the panics the bit-pinned internals keep using.
pub fn validate_cluster_config(
    cfg: &ClusterConfig,
    ds: &Dataset,
) -> crate::error::SkmResult<()> {
    use crate::error::SkmError;
    if cfg.k < 1 || cfg.k > ds.n() {
        return Err(SkmError::invalid_config(format!(
            "K={} out of range (need 1 <= K <= N={})",
            cfg.k,
            ds.n()
        )));
    }
    if cfg.max_iters < 1 {
        return Err(SkmError::invalid_config("max_iters must be >= 1"));
    }
    for (name, v) in [("t_th_frac", cfg.t_th_frac), ("s_min_frac", cfg.s_min_frac)] {
        if !v.is_finite() || !(0.0..=1.0).contains(&v) {
            return Err(SkmError::invalid_config(format!(
                "{name} must be finite in [0, 1] (got {v})"
            )));
        }
    }
    Ok(())
}

/// Fallible front door to [`run_clustering_with`]: validates the config
/// up front ([`crate::error::SkmError::InvalidConfig`]) and contains a
/// panicking run — including a [`par::run_sharded`] worker fault — as a
/// typed [`crate::error::SkmError::WorkerPanic`] instead of unwinding
/// into the caller. On success the output is bit-identical to
/// [`run_clustering_with`]; the infallible entry points stay available
/// for the determinism suites.
pub fn try_run_clustering_with(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    par: &ParConfig,
) -> crate::error::SkmResult<ClusterOutput> {
    validate_cluster_config(cfg, ds)?;
    crate::error::contain("algo.run", || run_clustering_with(kind, ds, cfg, par))
}

/// Run a complete clustering with the given algorithm under a sharded
/// execution configuration. With `par.threads > 1` the assignment step
/// runs over contiguous object shards and the update step over cluster
/// ranges on a [`std::thread::scope`] pool; results are **bit-identical**
/// to [`run_clustering`] (see [`par`] module docs for the argument).
pub fn run_clustering_with(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    par: &ParConfig,
) -> ClusterOutput {
    run_clustering_resumable(kind, ds, cfg, par, None, None)
        .expect("the driver is infallible without checkpointing")
}

/// [`run_clustering_with`] plus crash-safe persistence: an optional
/// periodic checkpoint ([`CheckpointSpec`]) and an optional `resume`
/// path produced by an earlier checkpointed run of the *same*
/// configuration (enforced via [`RunFingerprint`], including a content
/// digest of the corpus).
///
/// Determinism contract: a run resumed from the round-`c` checkpoint
/// computes rounds `c+1..` **bit-identically** to the uninterrupted
/// run — same assignment, objective bits, and structural parameters.
/// `IterLog`s cover only the resumed segment; `max_mem_bytes` is the
/// max over both segments. Checkpoints are written after the rebuild of
/// round `r` whenever `r % every == 0`, and once more at run completion
/// if the final round is not already on disk; each write atomically
/// replaces the previous checkpoint (never leaving a torn file — see
/// [`crate::persist`]).
pub fn run_clustering_resumable(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    par: &ParConfig,
    ckpt: Option<&CheckpointSpec>,
    resume: Option<&std::path::Path>,
) -> crate::error::SkmResult<ClusterOutput> {
    let n = ds.n();
    let mut st = IterState {
        k: cfg.k,
        assign: vec![0; n],
        rho: vec![-1.0; n],
        xstate: vec![false; n],
        means: seed_means(ds, cfg.k, cfg.seed),
        iter: 1,
    };
    let mut assigner = make_assigner(kind, ds, cfg);

    let mut logs: Vec<IterLog> = Vec::new();
    let mut max_mem = 0usize;
    let mut objective = f64::NAN;
    let mut converged = false;
    let mut start_round = 1usize;

    // Run identity, needed by both the save and the resume path.
    let fp = (ckpt.is_some() || resume.is_some())
        .then(|| RunFingerprint::compute(kind, ds, cfg, None));

    if let Some(path) = resume {
        let ck = crate::persist::checkpoint::load_cluster_checkpoint(
            path,
            fp.as_ref().expect("fingerprint exists when resuming"),
            n,
            ds.d(),
            cfg.k,
        )?;
        st.assign = ck.assign;
        st.rho = ck.rho;
        st.xstate = ck.xstate;
        st.means = ck.means;
        st.iter = ck.round + 1;
        objective = ck.objective;
        max_mem = ck.max_mem;
        assigner.import_params_state(ds, &ck.params);
        start_round = ck.round + 1;
    }

    // Initial structures — from the seed means on a fresh run, from the
    // restored post-update means on a resumed one; carried into the
    // first round's rebuild phase (see the attribution note at the log
    // push).
    let mut rb_sw = Stopwatch::new();
    rb_sw.start();
    assigner.rebuild(ds, &st, cfg);
    rb_sw.stop();
    let mut carry_rebuild_secs = rb_sw.secs();

    let every = ckpt.map_or(0, |s| s.every);
    // Highest round whose update+rebuild completed / is on disk.
    let mut completed = start_round - 1;
    let mut last_saved = start_round - 1;

    for r in start_round..=cfg.max_iters {
        st.iter = r;
        let prev_assign = st.assign.clone();

        let mut asg_sw = Stopwatch::new();
        asg_sw.start();
        let (counters, changes) = if par.is_parallel() {
            assigner.assign_par(ds, &mut st, par)
        } else {
            assigner.assign(ds, &mut st)
        };
        asg_sw.stop();
        let phases = assigner.take_phases();

        let mem = assigner.mem_bytes();
        max_mem = max_mem.max(mem);

        if changes == 0 && r > 1 {
            // Fixed point: the update step would reproduce the same
            // means. Log the final (pure-assignment) iteration.
            logs.push(IterLog {
                iter: r,
                counters,
                assign_secs: asg_sw.secs(),
                update_secs: 0.0,
                rebuild_secs: carry_rebuild_secs,
                gather_secs: phases.gather,
                verify_secs: phases.verify,
                changes,
                cpr: counters.cpr(n, cfg.k),
                mem_bytes: mem,
                n_moving: st.means.n_moving(),
                objective,
            });
            converged = true;
            break;
        }

        // Update step: mean construction + ρ / ICP bookkeeping …
        let changed = membership_changes(&prev_assign, &st.assign, cfg.k);
        let mut upd_sw = Stopwatch::new();
        upd_sw.start();
        let upd = update_means_with_rho_par(
            ds,
            &st.assign,
            cfg.k,
            Some(&st.means),
            Some(&changed),
            Some(&st.rho),
            par.threads,
        );
        // ICP eligibility for the next assignment (Eq. 5): similarity
        // non-decreasing w.r.t. the *same* centroid.
        for i in 0..n {
            st.xstate[i] = prev_assign[i] == st.assign[i] && upd.rho[i] >= st.rho[i];
        }
        objective = upd.objective;
        st.means = upd.means;
        st.rho = upd.rho;
        st.iter = r + 1;
        upd_sw.stop();

        // … and the rebuild phase: incremental index splice (or full
        // rebuild) + EstParams, timed separately for the breakdown.
        let mut rb_sw = Stopwatch::new();
        rb_sw.start();
        assigner.rebuild(ds, &st, cfg);
        rb_sw.stop();

        // Attribution convention: row r's `rebuild_secs` is the index
        // maintenance performed during r's update window — it rebuilds
        // over the post-update means, i.e. exactly the mean set whose
        // `n_moving` is logged in the same row, so rebuild cost and
        // mover count line up for the Fig-style plots and --bench-json.
        // Row 1 additionally carries the initial seed-index build.
        logs.push(IterLog {
            iter: r,
            counters,
            assign_secs: asg_sw.secs(),
            update_secs: upd_sw.secs(),
            rebuild_secs: carry_rebuild_secs + rb_sw.secs(),
            gather_secs: phases.gather,
            verify_secs: phases.verify,
            changes,
            cpr: counters.cpr(n, cfg.k),
            mem_bytes: assigner.mem_bytes(),
            n_moving: st.means.n_moving(),
            objective,
        });
        carry_rebuild_secs = 0.0;
        max_mem = max_mem.max(assigner.mem_bytes());
        completed = r;

        if let Some(spec) = ckpt {
            if every > 0 && r % every == 0 {
                let fp = fp.as_ref().unwrap();
                save_cluster_ckpt(spec, fp, r, objective, max_mem, &st, &*assigner)?;
                last_saved = r;
            }
        }
    }

    // Final checkpoint so `--resume` can extend a finished run.
    if let Some(spec) = ckpt {
        if completed > last_saved {
            let fp = fp.as_ref().unwrap();
            save_cluster_ckpt(spec, fp, completed, objective, max_mem, &st, &*assigner)?;
        }
    }

    let (t_th, v_th) = assigner.params();
    Ok(ClusterOutput {
        algo: kind,
        assign: st.assign,
        objective,
        logs,
        converged,
        max_mem_bytes: max_mem,
        t_th,
        v_th,
    })
}

fn save_cluster_ckpt(
    spec: &CheckpointSpec,
    fp: &RunFingerprint,
    round: usize,
    objective: f64,
    max_mem: usize,
    st: &IterState,
    assigner: &dyn Assigner,
) -> crate::error::SkmResult<()> {
    crate::persist::checkpoint::save_cluster_checkpoint(
        &spec.path,
        fp,
        &CheckpointState {
            round,
            objective,
            max_mem,
            params: assigner.export_params_state(),
            assign: &st.assign,
            rho: &st.rho,
            xstate: &st.xstate,
            means: &st.means,
        },
    )?;
    Ok(())
}

/// Fallible front door to [`run_clustering_resumable`]: config
/// validation up front, worker panics contained as typed errors, and
/// checkpoint/resume I/O surfaced as [`crate::error::SkmError`].
pub fn try_run_clustering_resumable(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    par: &ParConfig,
    ckpt: Option<&CheckpointSpec>,
    resume: Option<&std::path::Path>,
) -> crate::error::SkmResult<ClusterOutput> {
    validate_cluster_config(cfg, ds)?;
    crate::error::contain("algo.run", || {
        run_clustering_resumable(kind, ds, cfg, par, ckpt, resume)
    })
    .and_then(|r| r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;

    #[test]
    fn seeding_is_deterministic_and_distinct() {
        let c = generate(&tiny(3));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let a = seed_means(&ds, 10, 7);
        let b = seed_means(&ds, 10, 7);
        assert_eq!(a.m, b.m);
        let c2 = seed_means(&ds, 10, 8);
        assert_ne!(a.m, c2.m);
        assert_eq!(a.k(), 10);
        for j in 0..10 {
            assert!((a.m.row_norm(j) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn algo_kind_parse_roundtrip() {
        for &k in AlgoKind::all() {
            assert_eq!(AlgoKind::parse(k.name()), Some(k), "{:?}", k);
        }
        assert_eq!(AlgoKind::parse("nope"), None);
    }
}
