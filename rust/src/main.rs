//! `skm` — command-line driver for the spherical-k-means reproduction.
//!
//! Subcommands:
//!   cluster    run one algorithm on a preset or UCI corpus
//!   compare    run several algorithms and print the paper-style tables
//!   serve      cluster a corpus, then answer nearest-centroid queries
//!   audit      verify an algorithm reproduces MIVI's solution
//!   ucs        print the universal-characteristics report
//!   estparams  run the structural-parameter estimator and report (t_th, v_th)
//!   info       environment / artifacts status
//!
//! Examples:
//!   skm cluster --preset pubmed-like --algo es-icp --seed 42
//!   skm compare --preset nyt-like --algos mivi,icp,es-icp --seed 1
//!   skm serve --preset pubmed-like --top-p 4 --top-k 10 --threads 8
//!   skm serve --preset nyt-like --queries queries.docword.txt --bench-json out.json
//!   skm audit --preset tiny --algo all
//!   skm cluster --input docword.pubmed.txt --max-docs 100000 --algo es-icp
//!   skm cluster --preset nyt-like --algo es-icp --bench-json run.json
//!   skm cluster --preset pubmed-like --algo es-icp --minibatch --batch-size 2048 --decay 1
//!
//! `--minibatch` switches `cluster` to the streaming driver
//! (`coordinator::minibatch`): seeded-deterministic batches through the
//! same assigners and incremental index maintenance, with
//! `--batch-size`, `--schedule sequential|reservoir`, `--decay`,
//! `--rounds`, and `--sample-seed` knobs.
//!
//! `serve` clusters the corpus (any `--algo`, or `--minibatch` streaming),
//! freezes the result into a `serve::ClusteredCorpus`, builds the pruned
//! query router over the structured mean index, and serves a query batch:
//! `--queries <docword file>` embeds raw bag-of-words queries into the
//! frozen tf-idf space, otherwise `--n-queries` synthetic queries are
//! sampled from the corpus (`--query-seed`). `--top-p`/`--top-k` size the
//! answer, `--t-th`/`--v-th` override the estimated router parameters,
//! and `--threads` shards the batch (bit-identical to serial).
//!
//! `--bench-json <path>` (cluster, compare, and serve) dumps the
//! machine-readable report (phase timings / counters, or the per-query
//! serving answers with QPS) as JSON.
//!
//! ## Persistence (§Persist tentpole)
//!
//! `skm serve --save <path>` persists the frozen serving state
//! (checksummed block format, atomic publish — see `skm::persist`);
//! `skm serve --load <path>` warm-restarts from it, skipping dataset
//! building and clustering entirely, with bit-identical answers.
//! `skm cluster --save <path>` writes periodic run checkpoints
//! (`--checkpoint-every N`, default 10, plus a final checkpoint);
//! `skm cluster --resume <path>` continues such a run — the checkpoint
//! fingerprint must match the configuration and corpus, and the resumed
//! trajectory is bit-identical to the uninterrupted one. Both work with
//! `--minibatch` (the checkpoint also carries the sampling RNG state,
//! decay counts, and staleness clocks).
//!
//! ## Failure semantics (§Robustness)
//!
//! Every subcommand returns [`SkmResult`]; `main` prints one
//! `skm: <message>` line to stderr and exits with the error's
//! [`SkmError::exit_code`] — 2 for usage errors (bad flag values,
//! unknown presets/algorithms/schedules), 1 for runtime failures
//! (malformed corpora, I/O, worker panics). No user-facing error
//! carries a backtrace. Per-query serving failures are contained: the
//! batch completes, failed slots are reported in the log/JSON, and the
//! process still exits 0 (failure is per request, not per process).

use skm::algo::{
    try_run_clustering_resumable, try_run_clustering_with, AlgoKind, ClusterConfig, ParConfig,
};
use skm::coordinator::compare::absolute_table;
use skm::coordinator::{
    audit_equivalence_with, cluster_run_json, compare_runs_json, comparison_rate_table,
    minibatch_run_json, preset, try_run_minibatch, try_run_minibatch_resumable, BatchSchedule,
    MiniBatchConfig, run_and_summarize_with,
};
use skm::corpus::read_uci_bow_file;
use skm::error::{SkmError, SkmResult};
use skm::estparams::{estimate, EstConfig};
use skm::index::{update_means, ObjInvIndex};
use skm::persist::checkpoint::CheckpointSpec;
use skm::serve::{
    serve_batch, serve_run_json, ClusteredCorpus, Query, Router, RouterParams, ServeDefaults,
};
use skm::sparse::{build_dataset, Dataset};
use skm::ucs;
use skm::util::cli::Args;
use skm::util::io::fmt_sig;
use skm::util::rng::Pcg32;
use std::path::{Path, PathBuf};
use std::time::Instant;

fn load_dataset(args: &Args) -> SkmResult<Dataset> {
    if let Some(path) = args.get("input") {
        let max_docs = args.try_parsed::<usize>("max-docs")?;
        let corpus = read_uci_bow_file(path, max_docs)?;
        Ok(build_dataset("uci", corpus.n_terms, &corpus.docs))
    } else {
        let name = args.get_or("preset", "pubmed-like");
        let seed = args.try_parsed_or::<u64>("corpus-seed", 7)?;
        let scale = args.try_parsed::<f64>("scale")?;
        match preset(name, seed, scale) {
            Some(p) => Ok(p.dataset()),
            None => Err(SkmError::invalid_config(format!(
                "unknown preset {name:?} (expected pubmed-like, pubmed-like-large, nyt-like, nyt-like-large, or tiny)"
            ))),
        }
    }
}

fn config_for(args: &Args, ds: &Dataset) -> SkmResult<ClusterConfig> {
    let default_k = (ds.n() / 100).max(2);
    Ok(ClusterConfig {
        k: args.try_parsed_or("k", default_k)?,
        seed: args.try_parsed_or("seed", 42)?,
        max_iters: args.try_parsed_or("max-iters", 200)?,
        ..Default::default()
    })
}

/// Sharded-engine configuration from `--threads` / `--shard` (falling
/// back to the `SKM_THREADS` / `SKM_SHARD` environment knobs). The
/// engine is bit-identical to the serial path, so these flags change
/// wall-clock time only — never results.
fn par_for(args: &Args) -> SkmResult<ParConfig> {
    let env = ParConfig::from_env();
    Ok(ParConfig {
        threads: args.try_parsed_or("threads", env.threads)?.max(1),
        shard: args.try_parsed_or("shard", env.shard)?,
    })
}

/// `--save` / `--checkpoint-every` → the clustering drivers'
/// [`CheckpointSpec`]. `--save` alone checkpoints every 10 completed
/// rounds plus the final state; `--checkpoint-every 0` means
/// final-checkpoint only; `--checkpoint-every` without `--save` is a
/// usage error.
fn checkpoint_spec_for(args: &Args) -> SkmResult<Option<CheckpointSpec>> {
    let every = args.checkpoint_every()?;
    match (args.save_path(), every) {
        (Some(path), every) => Ok(Some(CheckpointSpec {
            every: every.unwrap_or(10),
            path: PathBuf::from(path),
        })),
        (None, Some(_)) => Err(SkmError::invalid_config(
            "--checkpoint-every requires --save <path>",
        )),
        (None, None) => Ok(None),
    }
}

fn parse_algo(s: &str) -> SkmResult<AlgoKind> {
    AlgoKind::parse(s).ok_or_else(|| {
        SkmError::invalid_config(format!(
            "unknown algo {s:?} (expected one of: {})",
            AlgoKind::all()
                .iter()
                .map(|k| k.name())
                .collect::<Vec<_>>()
                .join(", ")
        ))
    })
}

fn describe(ds: &Dataset, k: usize) {
    eprintln!(
        "dataset {}: N={} D={} avg-terms={:.1} (sparsity {:.2e}), K={}",
        ds.name,
        ds.n(),
        ds.d(),
        ds.avg_terms(),
        ds.sparsity_indicator(),
        k
    );
}

fn main() {
    let args = Args::parse();
    let result = match args.subcommand() {
        Some("cluster") => cmd_cluster(&args),
        Some("compare") => cmd_compare(&args),
        Some("serve") => cmd_serve(&args),
        Some("audit") => cmd_audit(&args),
        Some("ucs") => cmd_ucs(&args),
        Some("estparams") => cmd_estparams(&args),
        Some("info") => cmd_info(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            eprintln!(
                "usage: skm <cluster|compare|serve|audit|ucs|estparams|info> [--preset NAME] [--algo NAME] [--threads N] ..."
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("skm: {e}");
        std::process::exit(e.exit_code());
    }
}

fn cmd_cluster(args: &Args) -> SkmResult<()> {
    let ds = load_dataset(args)?;
    let cfg = config_for(args, &ds)?;
    let par = par_for(args)?;
    let kind = parse_algo(args.get_or("algo", "es-icp"))?;
    describe(&ds, cfg.k);
    if par.is_parallel() {
        eprintln!(
            "sharded engine: {} threads, shard {}",
            par.threads,
            par.shard_size(ds.n())
        );
    }
    let ckpt = checkpoint_spec_for(args)?;
    let resume = args.resume_path().map(Path::new);
    if let Some(spec) = &ckpt {
        match spec.every {
            0 => eprintln!("checkpointing to {} at completion", spec.path.display()),
            e => eprintln!("checkpointing to {} every {e} round(s)", spec.path.display()),
        }
    }
    if let Some(p) = resume {
        eprintln!("resuming from {}", p.display());
    }
    if args.minibatch() {
        return cmd_cluster_minibatch(args, &ds, &cfg, &par, kind, ckpt.as_ref(), resume);
    }
    let out = try_run_clustering_resumable(kind, &ds, &cfg, &par, ckpt.as_ref(), resume)?;
    println!(
        "{}: {} iterations ({}), J={:.4}, total {:.2}s (assign {:.2}s / update {:.2}s), avg mult/iter {}, max mem {:.3} GB",
        kind.name(),
        out.iterations(),
        if out.converged { "converged" } else { "iteration cap" },
        out.objective,
        out.total_secs(),
        out.total_assign_secs(),
        out.total_update_secs(),
        fmt_sig(out.avg_mult()),
        out.max_mem_bytes as f64 / 1e9
    );
    if let (Some(t), Some(v)) = (out.t_th, out.v_th) {
        println!(
            "structural parameters: t_th={t} ({:.3}·D), v_th={v:.4}",
            t as f64 / ds.d() as f64
        );
    }
    if args.flag("log") {
        println!(
            "iter  mult          CPR       assign(s)  update(s)  rebuild(s)  changes  moving"
        );
        for l in &out.logs {
            println!(
                "{:>4}  {:<12}  {:<8}  {:<9.4}  {:<9.4}  {:<10.4}  {:>7}  {:>6}",
                l.iter,
                fmt_sig(l.counters.mult as f64),
                fmt_sig(l.cpr),
                l.assign_secs,
                l.update_secs,
                l.rebuild_secs,
                l.changes,
                l.n_moving
            );
        }
    }
    write_bench_json(args, &cluster_run_json(&ds, &cfg, &out))
}

/// The one `--minibatch` knob semantics, shared by `cluster` and
/// `serve` (so the two subcommands cannot drift): `--batch-size`
/// defaults to the workload policy and clamps to N, `--schedule`
/// defaults to sequential, the epoch budget is rescaled to the
/// (possibly overridden) batch size unless `--rounds` pins it, and
/// `--sample-seed` falls back to the clustering seed.
fn minibatch_config_for(args: &Args, n: usize, cfg: &ClusterConfig) -> SkmResult<MiniBatchConfig> {
    // One default policy, shared with Preset::minibatch_config.
    let defaults = MiniBatchConfig::default_for(n);
    let batch = match args.try_parsed_or::<usize>("batch-size", 0)? {
        0 => defaults.batch,
        b => b.min(n),
    };
    let rounds_per_epoch = (n + batch - 1) / batch;
    let sched = args.get_or("schedule", "sequential");
    Ok(MiniBatchConfig {
        batch,
        schedule: BatchSchedule::parse(sched).ok_or_else(|| {
            SkmError::invalid_config(format!(
                "unknown schedule {sched:?} (expected sequential or reservoir)"
            ))
        })?,
        decay: args.try_parsed_or("decay", 1.0)?,
        max_rounds: args.try_parsed_or(
            "rounds",
            skm::coordinator::minibatch::DEFAULT_EPOCH_BUDGET * rounds_per_epoch,
        )?,
        sample_seed: args.try_parsed_or("sample-seed", cfg.seed)?,
    })
}

/// The `--minibatch` arm of `cluster`: batches through
/// `coordinator::minibatch` with `--batch-size` / `--schedule` /
/// `--decay` / `--rounds` / `--sample-seed` (defaults: 1/16 of the
/// corpus floored at 256, sequential, 1.0, 64 epochs, the clustering
/// seed). `--batch-size <n> --decay 0` is bit-exact full-batch Lloyd.
fn cmd_cluster_minibatch(
    args: &Args,
    ds: &Dataset,
    cfg: &ClusterConfig,
    par: &ParConfig,
    kind: AlgoKind,
    ckpt: Option<&CheckpointSpec>,
    resume: Option<&Path>,
) -> SkmResult<()> {
    let n = ds.n();
    let mb = minibatch_config_for(args, n, cfg)?;
    let rounds_per_epoch = (n + mb.batch - 1) / mb.batch;
    eprintln!(
        "mini-batch mode: batch {} ({} rounds/epoch), schedule {}, decay {}",
        mb.batch,
        rounds_per_epoch,
        mb.schedule.name(),
        mb.decay
    );
    let out = try_run_minibatch_resumable(kind, ds, cfg, &mb, par, ckpt, resume)?;
    println!(
        "{} (mini-batch): {} rounds ({}), J={:.4}, {} objects processed, total {:.2}s (assign {:.2}s / update {:.2}s), max mem {:.3} GB",
        kind.name(),
        out.n_rounds(),
        if out.converged { "quiet epoch" } else { "round cap" },
        out.objective,
        out.objects_processed(),
        out.total_assign_secs() + out.total_update_secs(),
        out.total_assign_secs(),
        out.total_update_secs(),
        out.max_mem_bytes as f64 / 1e9
    );
    if let (Some(t), Some(v)) = (out.t_th, out.v_th) {
        println!(
            "structural parameters: t_th={t} ({:.3}·D), v_th={v:.4}",
            t as f64 / ds.d() as f64
        );
    }
    if args.flag("log") {
        println!("round  batch  mult          assign(s)  update(s)  rebuild(s)  changes  moving");
        for l in &out.rounds {
            println!(
                "{:>5}  {:>5}  {:<12}  {:<9.4}  {:<9.4}  {:<10.4}  {:>7}  {:>6}",
                l.round,
                l.batch_len,
                fmt_sig(l.counters.mult as f64),
                l.assign_secs,
                l.update_secs,
                l.rebuild_secs,
                l.changes,
                l.n_moving
            );
        }
    }
    write_bench_json(args, &minibatch_run_json(ds, cfg, &mb, &out))
}

/// `--bench-json <path>`: dump the phase-level timing breakdown,
/// iteration count, and OpCounters of the run(s) as JSON.
fn write_bench_json(args: &Args, json: &skm::util::json::Json) -> SkmResult<()> {
    if let Some(path) = args.get("bench-json") {
        std::fs::write(path, json.render_pretty())
            .map_err(|e| SkmError::io(format!("write --bench-json {path}"), e))?;
        eprintln!("[wrote {path}]");
    }
    Ok(())
}

fn parse_algos(spec: &str) -> SkmResult<Vec<AlgoKind>> {
    if spec == "all" {
        return Ok(AlgoKind::all().to_vec());
    }
    spec.split(',').map(|s| parse_algo(s.trim())).collect()
}

fn cmd_compare(args: &Args) -> SkmResult<()> {
    let ds = load_dataset(args)?;
    let cfg = config_for(args, &ds)?;
    let par = par_for(args)?;
    let kinds = parse_algos(args.get_or("algos", "mivi,icp,ta-icp,cs-icp,es-icp"))?;
    skm::algo::validate_cluster_config(&cfg, &ds)?;
    describe(&ds, cfg.k);
    let mut summaries = Vec::new();
    let mut outs = Vec::new();
    for kind in kinds {
        eprintln!("running {} ...", kind.name());
        let (out, s) = run_and_summarize_with(kind, &ds, &cfg, &par);
        eprintln!(
            "  {} iters, avg {:.3}s/iter, avg mult {}",
            s.iterations,
            s.avg_secs,
            fmt_sig(s.avg_mult)
        );
        summaries.push(s);
        outs.push(out);
    }
    println!("\nAbsolute values (per iteration):");
    println!("{}", absolute_table(&summaries).render());
    let reference = args.get_or("reference", summaries.last().map(|s| s.name).unwrap_or("MIVI"));
    println!("Rates relative to {reference} (cf. paper Tables IV/VI):");
    println!("{}", comparison_rate_table(&summaries, reference).render());
    write_bench_json(args, &compare_runs_json(&ds, &cfg, &outs))
}

/// The `serve` subcommand: cluster the corpus, freeze it into a serving
/// snapshot, build the pruned query router, and answer a query batch.
/// Per-query failures are contained — the batch completes, failed slots
/// are reported (stderr count, `--log` lines, JSON `error` objects),
/// and the exit code stays 0.
fn cmd_serve(args: &Args) -> SkmResult<()> {
    let par = par_for(args)?;
    let t_ov = args.try_parsed::<usize>("t-th")?;
    let v_ov = args.try_parsed::<f64>("v-th")?;

    // 1. The serving state: either a warm restart from a persisted
    //    snapshot (`--load` — no dataset build, no clustering; answers
    //    are bit-identical to the run that saved it), or cluster the
    //    corpus and freeze the result.
    let (snap, params, query_seed_base) = if let Some(path) = args.load_path() {
        // `--mmap`: leave the (compressed v2) corpus sections on disk
        // behind an mmap + LRU block cache; `--cache-mb` sizes the
        // cache. v1 snapshots fall back to the full in-RAM load.
        let (snap, stored) = if args.mmap() {
            let cache_blocks =
                (args.cache_mb()? << 20) / skm::persist::format::BLOCK_CAP;
            skm::persist::load_snapshot_mmap(Path::new(path), cache_blocks)?
        } else {
            skm::persist::load_snapshot(Path::new(path))?
        };
        eprintln!(
            "loaded snapshot {path}{}: K={}, router (t_th={}, v_th={:.4})",
            if snap.is_disk_backed() {
                " (corpus on disk via mmap)"
            } else {
                ""
            },
            snap.k,
            stored.t_th,
            stored.v_th
        );
        describe(&snap.ds, snap.k);
        // --t-th / --v-th still override the stored parameters.
        let params = RouterParams {
            t_th: t_ov.unwrap_or(stored.t_th),
            v_th: v_ov.unwrap_or(stored.v_th),
        };
        let seed = args.try_parsed_or::<u64>("seed", 42)?;
        (snap, params, seed)
    } else {
        let ds = load_dataset(args)?;
        let cfg = config_for(args, &ds)?;
        let kind = parse_algo(args.get_or("algo", "es-icp"))?;
        let k = cfg.k;
        describe(&ds, k);

        // Cluster (full-batch Lloyd, or the streaming driver under
        // --minibatch) and freeze the result.
        eprintln!("clustering with {} ...", kind.name());
        let snap = if args.minibatch() {
            // Same knobs and defaults as `cluster --minibatch` — one
            // shared helper, so the two subcommands cannot drift.
            let mb = minibatch_config_for(args, ds.n(), &cfg)?;
            let out = try_run_minibatch(kind, &ds, &cfg, &mb, &par)?;
            eprintln!(
                "  {} rounds, J={:.4} (streaming)",
                out.n_rounds(),
                out.objective
            );
            ClusteredCorpus::from_minibatch(ds, &out, k)
        } else {
            let out = try_run_clustering_with(kind, &ds, &cfg, &par)?;
            eprintln!("  {} iterations, J={:.4}", out.iterations(), out.objective);
            ClusteredCorpus::from_output(ds, &out, k)
        };

        // The router: --t-th / --v-th each independently override the
        // Section-V estimator (estimation is skipped only when both are
        // given). A failed estimation degrades to exact routing
        // parameters inside estimate_for — never an exit.
        let params = match (t_ov, v_ov) {
            (Some(t_th), Some(v_th)) => RouterParams { t_th, v_th },
            (t, v) => {
                let est = RouterParams::estimate_for(&snap, &cfg);
                RouterParams {
                    t_th: t.unwrap_or(est.t_th),
                    v_th: v.unwrap_or(est.v_th),
                }
            }
        };
        (snap, params, cfg.seed)
    };
    let k = snap.k;

    let router = Router::new(&snap, params)?;

    // 2. `--save`: persist the frozen serving state (checksummed block
    //    format, atomic publish) with the *resolved* router parameters,
    //    so `--load` answers bit-identically without re-clustering or
    //    re-estimating.
    if let Some(path) = args.save_path() {
        let saved = RouterParams {
            t_th: router.t_th(),
            v_th: router.v_th(),
        };
        let bytes =
            skm::persist::save_snapshot_with(Path::new(path), &snap, &saved, args.compress())?;
        eprintln!(
            "[saved snapshot {path}: {bytes} bytes{}]",
            if args.compress() {
                " (compressed, format v2)"
            } else {
                ""
            }
        );
    }

    let defaults = ServeDefaults::default_for(k);
    let top_p = match args.try_parsed_or::<usize>("top-p", 0)? {
        0 => defaults.top_p,
        p => p,
    };
    let top_k = args.try_parsed_or::<usize>("top-k", 10)?;

    // 3. Queries: a raw bag-of-words file embedded into the frozen
    //    feature space, or synthetic queries sampled from the corpus.
    let queries: Vec<Query> = if let Some(path) = args.get("queries") {
        let qc = read_uci_bow_file(path, None)?;
        qc.docs
            .iter()
            .map(|doc| snap.embed_bow(doc))
            .collect::<SkmResult<Vec<_>>>()?
    } else {
        let nq = args
            .try_parsed_or::<usize>("n-queries", 64)?
            .clamp(1, snap.ds.n());
        let mut rng = Pcg32::new(args.try_parsed_or("query-seed", query_seed_base ^ 0x5e4e)?);
        rng.sample_distinct(snap.ds.n(), nq)
            .into_iter()
            // query_from_row works for both resident and disk-backed
            // corpora (Query::from_row would read the mmap stub).
            .map(|i| snap.query_from_row(i))
            .collect()
    };
    eprintln!(
        "serving {} queries: top-p {top_p}, top-k {top_k}, router (t_th={} = {:.3}·D, v_th={:.4})",
        queries.len(),
        router.t_th(),
        router.t_th() as f64 / snap.ds.d() as f64,
        router.v_th()
    );

    // 4. Serve the batch (sharded; bit-identical to serial). Failed
    //    queries occupy Err slots; successes are unaffected.
    let t0 = Instant::now();
    let (results, counters) = serve_batch(&router, &queries, top_p, top_k, &par);
    let wall = t0.elapsed().as_secs_f64();
    let nq = results.len().max(1) as f64;
    let n_err = results.iter().filter(|r| r.is_err()).count();
    println!(
        "served {} queries in {wall:.3}s — {} QPS ({} thread{}), avg candidates/query {:.1} of K={k} (CPR {:.4}), avg exact sims/query {:.1}",
        results.len(),
        fmt_sig(results.len() as f64 / wall.max(1e-12)),
        par.threads,
        if par.threads == 1 { "" } else { "s" },
        counters.candidates as f64 / nq,
        counters.candidates as f64 / (nq * k as f64),
        counters.exact_sims as f64 / nq
    );
    if n_err > 0 {
        eprintln!(
            "skm: {n_err} of {} queries failed (contained; see --log / --bench-json for details)",
            results.len()
        );
    }
    if router.fallback_count() > 0 {
        eprintln!(
            "skm: {} queries served by the exact-scan fallback",
            router.fallback_count()
        );
    }
    if args.flag("log") {
        for (qi, r) in results.iter().enumerate() {
            match r {
                Ok(r) => {
                    let cents: Vec<String> = r
                        .centroids
                        .iter()
                        .map(|&(c, s)| format!("{c}:{s:.4}"))
                        .collect();
                    let hits: Vec<String> = r
                        .hits
                        .iter()
                        .map(|&(i, s)| format!("{i}:{s:.4}"))
                        .collect();
                    println!(
                        "query {qi}: clusters [{}]  docs [{}]",
                        cents.join(" "),
                        hits.join(" ")
                    );
                }
                Err(e) => println!("query {qi}: ERROR {e}"),
            }
        }
    }
    write_bench_json(
        args,
        &serve_run_json(
            &snap,
            &router,
            top_p,
            top_k,
            par.threads,
            &results,
            wall,
            None,
        ),
    )
}

fn cmd_audit(args: &Args) -> SkmResult<()> {
    let ds = load_dataset(args)?;
    let cfg = config_for(args, &ds)?;
    let par = par_for(args)?;
    let kinds = parse_algos(args.get_or("algo", "all"))?;
    skm::algo::validate_cluster_config(&cfg, &ds)?;
    describe(&ds, cfg.k);
    let mut failures = 0;
    for kind in kinds {
        if kind == AlgoKind::Mivi {
            continue;
        }
        let rep = audit_equivalence_with(kind, &ds, &cfg, 1e-9, &par);
        println!(
            "{:<8} {}  exact={}  fp-ties={}  divergences={}  iters {}/{}",
            rep.algo,
            if rep.passed() { "PASS" } else { "FAIL" },
            rep.exact_matches,
            rep.tie_matches,
            rep.divergences,
            rep.algo_iterations,
            rep.mivi_iterations
        );
        if !rep.passed() {
            failures += 1;
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_ucs(args: &Args) -> SkmResult<()> {
    let ds = load_dataset(args)?;
    let cfg = config_for(args, &ds)?;
    describe(&ds, cfg.k);
    eprintln!("clustering with ES-ICP to obtain the mean set ...");
    let out = try_run_clustering_with(AlgoKind::EsIcp, &ds, &cfg, &par_for(args)?)?;
    let upd = update_means(&ds, &out.assign, cfg.k, None, None);

    let df: Vec<f64> = ds.df.iter().map(|&x| x as f64).collect();
    let rf_df = ucs::rank_frequency(&df);
    let (alpha_df, r2_df) = ucs::zipf_exponent(&rf_df, 100);
    let tf = ds.x.column_sum();
    let (alpha_tf, r2_tf) = ucs::zipf_exponent(&ucs::rank_frequency(&tf), 100);
    let mf: Vec<f64> = upd.means.m.column_df().iter().map(|&x| x as f64).collect();
    let rf_mf = ucs::rank_frequency(&mf);
    let (alpha_mf, r2_mf) = ucs::zipf_exponent(&rf_mf, 100);
    println!("UC1 Zipf:  df alpha={alpha_df:.3} (r2={r2_df:.3}), tf alpha={alpha_tf:.3} (r2={r2_tf:.3})");
    println!(
        "UC2 bounded Zipf on mf: alpha={alpha_mf:.3} (r2={r2_mf:.3}), max mf={} (K={})",
        rf_mf[0].1, cfg.k
    );
    let (total, topfrac) = ucs::mult_volume(&ds, &upd.means);
    println!(
        "UC3 df–mf concentration: total df·mf volume {} — top 10% of term ids carry {:.1}%",
        fmt_sig(total),
        topfrac * 100.0
    );
    println!(
        "UC3 feature-value concentration: {} mean components > 1/sqrt(2) across K={} centroids; mean nnz avg {:.1}",
        ucs::concentration_count(&upd.means),
        cfg.k,
        upd.means.avg_nnz()
    );
    let curve = ucs::cps_curve(&ds, &upd.means, &out.assign, 100);
    println!(
        "UC4 Pareto CPS: CPS(0.1)={:.3} CPS(0.2)={:.3} CPS(0.5)={:.3} (paper PubMed: 0.92 at 0.1)",
        curve.value_at(0.1),
        curve.value_at(0.2),
        curve.value_at(0.5)
    );
    Ok(())
}

fn cmd_estparams(args: &Args) -> SkmResult<()> {
    let ds = load_dataset(args)?;
    let cfg = config_for(args, &ds)?;
    describe(&ds, cfg.k);
    // Two MIVI iterations to get realistic means, as ES-ICP does.
    let warm = ClusterConfig {
        max_iters: 2,
        ..cfg.clone()
    };
    let out = try_run_clustering_with(AlgoKind::Mivi, &ds, &warm, &par_for(args)?)?;
    let upd = update_means(&ds, &out.assign, cfg.k, None, None);
    let s_min = (ds.d() as f64 * cfg.s_min_frac) as usize;
    let xp = ObjInvIndex::build(&ds.x, s_min);
    let est = estimate(
        &ds,
        &upd.means,
        &upd.rho,
        &xp,
        &EstConfig {
            s_min,
            n_candidates: cfg.n_vth_candidates,
            fixed_t: None,
            fixed_v: None,
            max_sample_objects: 10_000,
        },
    );
    println!(
        "estimated t_th={} ({:.3}·D)  v_th={:.4}  approx J={}",
        est.t_th,
        est.t_th as f64 / ds.d() as f64,
        est.v_th,
        fmt_sig(est.j_value)
    );
    println!("v_h        best t_h    J(t_h, v_h)");
    for p in &est.curve {
        println!("{:<9.4}  {:<9}  {}", p.v_th, p.t_th, fmt_sig(p.j_value));
    }
    Ok(())
}

fn cmd_info() -> SkmResult<()> {
    println!("skm — ES-ICP spherical k-means reproduction");
    println!("algorithms: {}", AlgoKind::all().iter().map(|k| k.name()).collect::<Vec<_>>().join(", "));
    let dir = skm::runtime::PjrtRuntime::default_dir();
    println!("artifacts dir: {dir:?}");
    for name in ["assign_block", "kmeans_step"] {
        let p = dir.join(format!("{name}.hlo.txt"));
        println!("  {name}: {}", if p.exists() { "present" } else { "MISSING (run `make artifacts`)" });
    }
    match skm::runtime::PjrtRuntime::new(&dir) {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    println!(
        "hardware PMU counters: {}",
        if skm::metrics::PerfGroup::try_new().is_some() {
            "available"
        } else {
            "unavailable (software cost model will be used)"
        }
    );
    Ok(())
}
