//! EstParams — estimation of the structural parameters `(t_th, v_th)`
//! (Section V, Appendices B–C, Algorithm 7).
//!
//! The estimator minimizes the *approximate number of multiplications*
//!
//! ```text
//! J(s', v_h) = φ1(s')            exact mults in Region 1
//!            + φ2(s', v_h)       exact mults in Region 2
//!            + φ̃3(s', v_h)       expected verification mults in Region 3
//! ```
//!
//! with (Eqs. 8, 9, 13):
//!
//! ```text
//! φ1(s')      = Σ_{s < s'}  df_s · mf_s
//! φ2(s', v_h) = Σ_{s ≥ s'}  df_s · mfH_(s, v_h)
//! φ̃3(s', v_h) = Σ_i ntH_(i,s') · (K/e)^{Δρ̄(i; s', h) / (ρ_a(i) − ρ̄_i)}
//! ```
//!
//! where `Δρ̄ = ρ̄^[ub] − ρ̄` is the mean upper-bound slack
//!
//! ```text
//! Δρ̄(i; s', h) = Σ_{p: t_(i,p) ≥ s'} u_(i,p) · Δv̄_h(t_(i,p))
//! Δv̄_h(s)     = (1/K)·[ Σ_{q: v < v_h} (v_h − v_c(s,q)) + (K − mf_s)·v_h ]
//! ```
//!
//! We sweep `s'` from D−1 down to `s_min` using the partial object
//! inverted index `X^p` exactly as Algorithm 7: only objects containing
//! term `s'` update their state, and a running total of `φ̃3` is
//! maintained incrementally. `(K/e)^x` is evaluated with
//! `util::stats::fast_exp` (the probability model is itself approximate;
//! see its docs).

use crate::index::{MeanSet, ObjInvIndex};
use crate::sparse::Dataset;
use crate::util::stats::fast_exp;

/// Configuration of one estimation call.
#[derive(Debug, Clone)]
pub struct EstConfig {
    /// Smallest `s'` candidate (Algorithm 7's `s_min`).
    pub s_min: usize,
    /// Number of `v_th` candidates (ignored when `fixed_v` is set).
    pub n_candidates: usize,
    /// Pin `t_th` (ThV ablation: `Some(0)`).
    pub fixed_t: Option<usize>,
    /// Pin `v_th` (ThT ablation: `Some(1.0)`).
    pub fixed_v: Option<f64>,
    /// Cap on the number of objects used for the φ̃3 expectation
    /// (Eq. 13 is a sum of i.i.d.-ish per-object terms, so a strided
    /// subsample scaled by the stride is an unbiased estimate; the
    /// paper parallelizes over 50 threads instead — DESIGN.md §3).
    /// `0` disables subsampling.
    pub max_sample_objects: usize,
}

impl Default for EstConfig {
    fn default() -> Self {
        Self {
            s_min: 0,
            n_candidates: 25,
            fixed_t: None,
            fixed_v: None,
            max_sample_objects: 10_000,
        }
    }
}

/// One evaluated candidate: the best `t_th` for a given `v_h` and the
/// objective there (the per-`v_h` minimum of Algorithm 7 line 16 — the
/// series plotted in Fig. 13).
#[derive(Debug, Clone, Copy)]
pub struct CandidatePoint {
    pub v_th: f64,
    pub t_th: usize,
    pub j_value: f64,
}

/// Estimation result.
#[derive(Debug, Clone)]
pub struct Estimate {
    pub t_th: usize,
    pub v_th: f64,
    pub j_value: f64,
    /// Per-candidate curve (for Fig. 13 / `benches/exp_estparams`).
    pub curve: Vec<CandidatePoint>,
}

/// Per-term value statistics over `s ∈ [s_lo, D)`: sorted values plus
/// prefix sums, so `mfH`, `cntLow`, and `sumLow` for any `v_h` are two
/// binary searches away.
struct TermStats {
    s_lo: usize,
    /// Sorted ascending values per term (flat).
    offsets: Vec<usize>,
    vals: Vec<f64>,
    /// Prefix sums of `vals` (prefix[i] = Σ vals[..i]) per term, flat and
    /// aligned with `vals` (+1 slot per term).
    prefix: Vec<f64>,
    mf: Vec<u32>,
}

impl TermStats {
    fn build(means: &MeanSet, s_lo: usize) -> Self {
        let d = means.m.n_cols();
        let width = d - s_lo;
        let mut per_term: Vec<Vec<f64>> = vec![Vec::new(); width];
        for j in 0..means.k() {
            let (ts, vs) = means.m.row(j);
            for (&t, &v) in ts.iter().zip(vs) {
                let t = t as usize;
                if t >= s_lo {
                    per_term[t - s_lo].push(v);
                }
            }
        }
        let mut offsets = vec![0usize; width + 1];
        for (i, l) in per_term.iter().enumerate() {
            offsets[i + 1] = offsets[i] + l.len();
        }
        let mut vals = Vec::with_capacity(offsets[width]);
        let mut prefix = Vec::with_capacity(offsets[width] + width);
        let mut mf = vec![0u32; width];
        for (i, mut l) in per_term.into_iter().enumerate() {
            l.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            mf[i] = l.len() as u32;
            let mut acc = 0.0;
            for &v in &l {
                vals.push(v);
                acc += v;
            }
            let _ = acc;
            // per-term prefix sums: rebuild with explicit base
            let base = vals.len() - l.len();
            let mut run = 0.0;
            prefix.push(0.0);
            for q in 0..l.len() {
                run += vals[base + q];
                prefix.push(run);
            }
        }
        Self {
            s_lo,
            offsets,
            vals,
            prefix,
            mf,
        }
    }

    /// For term `s` and threshold `v`: `(mfH, cnt_low, sum_low)` —
    /// entries ≥ v, entries < v, and the value-sum of the latter.
    fn split(&self, s: usize, v: f64) -> (u32, u32, f64) {
        let i = s - self.s_lo;
        let (a, b) = (self.offsets[i], self.offsets[i + 1]);
        let seg = &self.vals[a..b];
        let cnt_low = seg.partition_point(|&x| x < v);
        // prefix array has (len + 1) entries per term, offset by a + i.
        let pa = a + i;
        let sum_low = self.prefix[pa + cnt_low];
        let mfh = (seg.len() - cnt_low) as u32;
        (mfh, cnt_low as u32, sum_low)
    }
}

/// Estimate the structural parameters. `rho_assign` is the per-object
/// similarity to its assigned centroid (from the last update step).
pub fn estimate(
    ds: &Dataset,
    means: &MeanSet,
    rho_assign: &[f64],
    xp: &ObjInvIndex,
    cfg: &EstConfig,
) -> Estimate {
    let d = ds.d();
    let n = ds.n();
    let k = means.k();
    assert!(k >= 2, "EstParams needs K >= 2");
    let s_lo = cfg.fixed_t.unwrap_or(cfg.s_min).min(d);
    assert!(
        xp.s_lo <= s_lo,
        "partial object index starts at {} but estimation needs terms from {}",
        xp.s_lo,
        s_lo
    );
    let stats = TermStats::build(means, s_lo);

    // Column averages over the mean set: (1/K) Σ_q v_c(s,q), needed for
    // ρ̄_i (Eq. 32).
    let colavg = {
        let mut c = means.m.column_sum();
        for v in &mut c {
            *v /= k as f64;
        }
        c
    };

    // Strided object subsample for the φ̃3 expectation (see EstConfig).
    // The sweep's cost is driven by *postings* in the indexed range, not
    // objects (long NYT-like documents carry ~4x the postings per
    // object), so the stride also caps sampled postings at ~50 per
    // object of the object budget.
    let stride = if cfg.max_sample_objects == 0 {
        1
    } else {
        let by_objects = (n / cfg.max_sample_objects.max(1)).max(1);
        let posting_budget = cfg.max_sample_objects.saturating_mul(50).max(1);
        let by_postings = (xp.nnz() / posting_budget).max(1);
        by_objects.max(by_postings)
    };
    let scale3 = stride as f64;
    let in_sample = |i: usize| i % stride == 0;

    // ρ̄_i and the per-object exponent scale γ_i = ln(K/e)/(ρ_a − ρ̄).
    let ln_ke = (k as f64).ln() - 1.0;
    let mut gamma = vec![0.0f64; n];
    for i in (0..n).step_by(stride) {
        let (ts, vs) = ds.x.row(i);
        let mut rbar = 0.0;
        for (&t, &u) in ts.iter().zip(vs) {
            rbar += u * colavg[t as usize];
        }
        let denom = (rho_assign[i] - rbar).max(1e-9);
        gamma[i] = ln_ke / denom;
    }

    // v_th candidates: quantiles of the mean-feature values in the
    // high-df region (the skewed tail is where the threshold lives,
    // Section VII-B).
    let v_candidates: Vec<f64> = if let Some(v) = cfg.fixed_v {
        vec![v]
    } else {
        let mut vals: Vec<f64> = stats.vals.clone();
        if vals.is_empty() {
            vec![1.0]
        } else {
            vals.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            let m = cfg.n_candidates.max(2);
            (0..m)
                .map(|h| {
                    let q = 0.5 + 0.4999 * h as f64 / (m - 1) as f64;
                    crate::util::stats::quantile_sorted(&vals, q)
                })
                .filter(|&v| v > 0.0)
                .collect::<Vec<_>>()
                .into_iter()
                .fold(Vec::new(), |mut acc, v| {
                    // dedup near-identical candidates
                    if acc.last().map(|&l: &f64| (v - l).abs() > 1e-12).unwrap_or(true) {
                        acc.push(v);
                    }
                    acc
                })
        }
    };

    // φ1 over the full range (prefix of df·mf). mf for s < s_lo comes
    // from the mean set's column df.
    let mf_full: Vec<u32> = means.m.column_df();
    let mut phi1 = vec![0.0f64; d + 1]; // phi1[s'] = Σ_{s<s'} df·mf
    for s in 0..d {
        phi1[s + 1] = phi1[s] + ds.df[s] as f64 * mf_full[s] as f64;
    }

    let mut curve: Vec<CandidatePoint> = Vec::new();
    let mut best = Estimate {
        t_th: d,
        v_th: v_candidates.last().cloned().unwrap_or(1.0),
        j_value: f64::INFINITY,
        curve: Vec::new(),
    };

    // Buffers reused across candidates.
    let mut e_slack = vec![0.0f64; n]; // Δρ̄ numerator per object
    let mut nth = vec![0u32; n]; // ntH per object
    let mut contrib = vec![0.0f64; n]; // current φ̃3 contribution

    for &v_h in &v_candidates {
        // Per-term derived quantities over [s_lo, d).
        let width = d - s_lo;
        let mut dv = vec![0.0f64; width]; // Δv̄_h(s)
        let mut phi2_suffix = vec![0.0f64; width + 1];
        for s in (s_lo..d).rev() {
            let (mfh, cnt_low, sum_low) = stats.split(s, v_h);
            let mf_s = stats.mf[s - s_lo] as f64;
            dv[s - s_lo] =
                (cnt_low as f64 * v_h - sum_low + (k as f64 - mf_s) * v_h) / k as f64;
            phi2_suffix[s - s_lo] =
                phi2_suffix[s - s_lo + 1] + ds.df[s] as f64 * mfh as f64;
        }

        if let Some(t_fixed) = cfg.fixed_t {
            // Direct evaluation at the pinned t_th (ThV/ThT ablations):
            // one pass over the indexed postings.
            let mut phi3 = 0.0f64;
            for i in 0..n {
                e_slack[i] = 0.0;
                nth[i] = 0;
            }
            for s in t_fixed..d {
                let (oids, ovals) = xp.postings(s);
                for (&i, &u) in oids.iter().zip(ovals) {
                    let i = i as usize;
                    if !in_sample(i) {
                        continue;
                    }
                    e_slack[i] += u * dv[s - s_lo];
                    nth[i] += 1;
                }
            }
            for i in (0..n).step_by(stride) {
                if nth[i] > 0 {
                    let p = fast_exp(gamma[i] * e_slack[i]).min(k as f64);
                    phi3 += nth[i] as f64 * p;
                }
            }
            let j = phi1[t_fixed] + phi2_suffix[t_fixed.max(s_lo) - s_lo] + phi3 * scale3;
            curve.push(CandidatePoint {
                v_th: v_h,
                t_th: t_fixed,
                j_value: j,
            });
            if j < best.j_value {
                best.t_th = t_fixed;
                best.v_th = v_h;
                best.j_value = j;
            }
            continue;
        }

        // Descending sweep s' = d-1 .. s_min with incremental φ̃3
        // (Algorithm 7 lines 7–15).
        for i in 0..n {
            e_slack[i] = 0.0;
            nth[i] = 0;
            contrib[i] = 0.0;
        }
        let mut phi3_total = 0.0f64;
        let mut best_t = d;
        let mut best_j = phi1[d]; // s' = D: everything Region 1
        for s in (cfg.s_min..d).rev() {
            let (oids, ovals) = xp.postings(s);
            let dvs = dv[s - s_lo];
            for (&i, &u) in oids.iter().zip(ovals) {
                let i = i as usize;
                if !in_sample(i) {
                    continue;
                }
                phi3_total -= contrib[i];
                e_slack[i] += u * dvs;
                nth[i] += 1;
                let p = fast_exp(gamma[i] * e_slack[i]).min(k as f64);
                contrib[i] = nth[i] as f64 * p;
                phi3_total += contrib[i];
            }
            let j = phi1[s] + phi2_suffix[s - s_lo] + phi3_total * scale3;
            if j < best_j {
                best_j = j;
                best_t = s;
            }
        }
        curve.push(CandidatePoint {
            v_th: v_h,
            t_th: best_t,
            j_value: best_j,
        });
        if best_j < best.j_value {
            best.t_th = best_t;
            best.v_th = v_h;
            best.j_value = best_j;
        }
    }

    best.curve = curve;
    best
}

/// Exact multiplication-count predictor for given `(t_th, v_th)` using
/// the *actual* filter (no probability model): runs the gathering phase
/// accounting without performing the assignments. Used by
/// `benches/exp_estparams` to produce the "actual" series of Figs. 13–14.
pub fn actual_mult_count(
    ds: &Dataset,
    means: &MeanSet,
    rho_assign: &[f64],
    t_th: usize,
    v_th: f64,
) -> u64 {
    use crate::index::EsIndex;
    let idx = EsIndex::build(means, t_th, v_th);
    let k = means.k();
    let n = ds.n();
    let mut rho = vec![0.0f64; k];
    let mut total = 0u64;
    for i in 0..n {
        let (ts, vs) = ds.x.row(i);
        let p0 = ts.partition_point(|&t| (t as usize) < t_th);
        let mut y_base = 0.0;
        for &u in &vs[p0..] {
            y_base += u * v_th; // scaled object values
        }
        // Folded accumulator (see EsIndex docs): after the gathering
        // loops rho[j] is the upper bound directly.
        rho.iter_mut().for_each(|r| *r = y_base);
        let mut mult = 0u64;
        for (&t, &u) in ts[..p0].iter().zip(&vs[..p0]) {
            let (ids, vals) = idx.r1.postings(t as usize);
            mult += ids.len() as u64;
            let us = u * v_th;
            for (&c, &v) in ids.iter().zip(vals) {
                rho[c as usize] += us * v;
            }
        }
        for (&t, &u) in ts[p0..].iter().zip(&vs[p0..]) {
            let (ids, vals) = idx.r2.postings(t as usize);
            mult += ids.len() as u64;
            let us = u * v_th;
            for (&c, &v) in ids.iter().zip(vals) {
                rho[c as usize] += us * v;
            }
        }
        let rho_max = rho_assign[i];
        let mut z = 0u64;
        for &r in rho.iter() {
            if r > rho_max {
                z += 1;
            }
        }
        mult += z * (ts.len() - p0) as u64;
        total += mult;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{run_clustering, AlgoKind, ClusterConfig};
    use crate::corpus::{generate, tiny};
    use crate::index::update_means;
    use crate::sparse::build_dataset;

    fn setup() -> (Dataset, MeanSet, Vec<f64>) {
        let c = generate(&tiny(13));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 12,
            seed: 1,
            max_iters: 3,
            ..Default::default()
        };
        let out = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let upd = update_means(&ds, &out.assign, 12, None, None);
        (ds, upd.means, upd.rho)
    }

    #[test]
    fn term_stats_split_consistent() {
        let (_, means, _) = setup();
        let d = means.m.n_cols();
        let s_lo = d / 2;
        let stats = TermStats::build(&means, s_lo);
        for s in s_lo..d {
            let (mfh, cnt_low, sum_low) = stats.split(s, 0.1);
            assert_eq!(mfh + cnt_low, stats.mf[s - s_lo]);
            // brute force against the mean set
            let mut bf_cnt = 0u32;
            let mut bf_sum = 0.0;
            let mut bf_high = 0u32;
            for j in 0..means.k() {
                let dense = means.m.row_dense(j);
                let v = dense[s];
                if v != 0.0 {
                    if v < 0.1 {
                        bf_cnt += 1;
                        bf_sum += v;
                    } else {
                        bf_high += 1;
                    }
                }
            }
            assert_eq!(cnt_low, bf_cnt, "term {s}");
            assert_eq!(mfh, bf_high, "term {s}");
            assert!((sum_low - bf_sum).abs() < 1e-9, "term {s}");
        }
    }

    #[test]
    fn estimate_returns_sane_parameters() {
        let (ds, means, rho) = setup();
        let d = ds.d();
        let s_min = d * 6 / 10;
        let xp = ObjInvIndex::build(&ds.x, s_min);
        let est = estimate(
            &ds,
            &means,
            &rho,
            &xp,
            &EstConfig {
                s_min,
                n_candidates: 12,
                fixed_t: None,
                fixed_v: None,
                max_sample_objects: 0,
            },
        );
        assert!(est.t_th >= s_min && est.t_th <= d, "t_th={}", est.t_th);
        assert!(est.v_th > 0.0 && est.v_th <= 1.0, "v_th={}", est.v_th);
        assert!(est.j_value.is_finite());
        assert!(!est.curve.is_empty());
        // J at the chosen point is the minimum over the curve.
        for p in &est.curve {
            assert!(est.j_value <= p.j_value + 1e-9);
        }
    }

    #[test]
    fn estimate_beats_extreme_parameters() {
        // The estimated J must be no worse than both degenerate choices:
        // t_th = D (everything exact: J = Σ df·mf = MIVI cost).
        let (ds, means, rho) = setup();
        let d = ds.d();
        let s_min = d / 2;
        let xp = ObjInvIndex::build(&ds.x, s_min);
        let est = estimate(
            &ds,
            &means,
            &rho,
            &xp,
            &EstConfig {
                s_min,
                n_candidates: 16,
                fixed_t: None,
                fixed_v: None,
                max_sample_objects: 0,
            },
        );
        let mivi_cost: f64 = (0..d)
            .map(|s| ds.df[s] as f64 * means.m.column_df()[s] as f64)
            .sum();
        assert!(
            est.j_value <= mivi_cost + 1e-6,
            "estimated J {} worse than MIVI cost {}",
            est.j_value,
            mivi_cost
        );
    }

    #[test]
    fn fixed_t_mode_pins_t() {
        let (ds, means, rho) = setup();
        let xp = ObjInvIndex::build(&ds.x, 0);
        let est = estimate(
            &ds,
            &means,
            &rho,
            &xp,
            &EstConfig {
                s_min: 0,
                n_candidates: 8,
                fixed_t: Some(0),
                fixed_v: None,
                max_sample_objects: 0,
            },
        );
        assert_eq!(est.t_th, 0);
        assert!(est.curve.iter().all(|p| p.t_th == 0));
    }

    #[test]
    fn fixed_v_mode_pins_v() {
        let (ds, means, rho) = setup();
        let d = ds.d();
        let s_min = d / 2;
        let xp = ObjInvIndex::build(&ds.x, s_min);
        let est = estimate(
            &ds,
            &means,
            &rho,
            &xp,
            &EstConfig {
                s_min,
                n_candidates: 8,
                fixed_t: None,
                fixed_v: Some(1.0),
                max_sample_objects: 0,
            },
        );
        assert_eq!(est.v_th, 1.0);
    }

    #[test]
    fn actual_mult_decreases_from_mivi_at_good_params() {
        let (ds, means, rho) = setup();
        let d = ds.d();
        // Full-exact configuration ≙ MIVI cost.
        let mivi = actual_mult_count(&ds, &means, &rho, d, 1.0);
        // A reasonable filter configuration should not exceed it.
        let filt = actual_mult_count(&ds, &means, &rho, d * 7 / 10, 0.08);
        assert!(
            filt <= mivi,
            "filtered mult {filt} > MIVI {mivi} — filter made things worse"
        );
    }
}
