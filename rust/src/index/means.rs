//! The update step shared by every algorithm (Algorithm 6, steps 1–2):
//! build the cluster means (centroids) from the current assignment,
//! L2-normalize them, compute each object's similarity to its own centroid
//! (the `ρ_{a(i)}^{[r]}` threshold used by the next assignment step), and
//! track which centroids *moved* (for the ICP filter).

use crate::algo::par::{run_sharded_with, ParConfig, ScratchPool};
use crate::index::slab::RowSlab;
use crate::metrics::counters::OpCounters;
use crate::metrics::perf::PhaseTimes;
use crate::sparse::{CsrMatrix, Dataset};

/// The mean (centroid) set at one iteration.
#[derive(Debug, Clone)]
pub struct MeanSet {
    /// K × D sparse matrix of unit-norm mean-feature vectors, stored as
    /// a spliceable row slab so the mini-batch update can rewrite only
    /// the touched rows in place ([`RowSlab::set_row`]) instead of
    /// rebuilding the whole matrix per round.
    pub m: RowSlab,
    /// `moved[j]`: did cluster j's membership change in the assignment
    /// step that produced this mean set? Invariant (`!moved`) centroids
    /// are exactly equal to their previous-iteration values, which is
    /// what the ICP filter exploits (Section IV-B).
    pub moved: Vec<bool>,
    /// Number of members per cluster (empty clusters keep their previous
    /// mean and are never "moving").
    pub sizes: Vec<u32>,
}

impl MeanSet {
    pub fn k(&self) -> usize {
        self.m.n_rows()
    }

    /// Number of moving centroids — the paper's `(nMv)`.
    pub fn n_moving(&self) -> usize {
        self.moved.iter().filter(|&&m| m).count()
    }

    /// Average non-zeros per mean (compare paper §VI-A: 2094.94 for
    /// PubMed at K = 80 000).
    pub fn avg_nnz(&self) -> f64 {
        self.m.avg_row_nnz()
    }

    /// Mark every centroid invariant. Used by the serving layer
    /// ([`crate::serve`]) to freeze a finished clustering's means: a
    /// snapshot's centroids never move again, so the two-block index
    /// built over them has empty moving blocks and every query runs the
    /// full (branch-free) scan path.
    pub fn freeze(&mut self) {
        for m in &mut self.moved {
            *m = false;
        }
    }

    /// Number of centroids the incremental index maintainers must touch
    /// relative to a previous build's moved flags: moving now (values
    /// changed) or moving then (must relocate between the moving and
    /// invariant blocks). See [`crate::index::maintain`].
    pub fn dirty_against(&self, prev_moved: &[bool]) -> usize {
        debug_assert_eq!(prev_moved.len(), self.moved.len());
        prev_moved
            .iter()
            .zip(&self.moved)
            .filter(|&(&was, &now)| was || now)
            .count()
    }
}

/// Output of one update step.
#[derive(Debug, Clone)]
pub struct UpdateOutput {
    pub means: MeanSet,
    /// `rho[i]` = exact similarity of object i to its assigned centroid,
    /// used as the pruning threshold `ρ_(max)` at the next assignment.
    pub rho: Vec<f64>,
    /// Objective J = Σ_i ρ_{a(i)} (Eq. 47; larger is better).
    pub objective: f64,
}

/// Compute the update step (Algorithm 6 steps (1)–(2)).
///
/// * `assign[i]` — cluster of object i (current assignment).
/// * `prev` — previous mean set; clusters whose membership did not change
///   (and empty clusters) reuse the previous mean row verbatim, which is
///   both faster and makes the ICP invariance *exact* rather than
///   approximate.
/// * `changed[j]` — whether cluster j's membership changed; pass
///   `None` on the first call (everything is built fresh and marked
///   moving).
pub fn update_means(
    ds: &Dataset,
    assign: &[u32],
    k: usize,
    prev: Option<&MeanSet>,
    changed: Option<&[bool]>,
) -> UpdateOutput {
    update_means_with_rho(ds, assign, k, prev, changed, None)
}

/// [`update_means`] with the previous iteration's `ρ_{a(i)}` values:
/// members of an *unchanged* cluster keep both their mean and their
/// similarity, so ρ can be copied instead of recomputed — the dominant
/// cost of the update step once most centroids are invariant (§Perf).
///
/// **Sync contract:** the per-cluster body of this function is
/// duplicated verbatim inside [`update_means_with_rho_par`]'s workers;
/// any change here must be mirrored there (the parallel path is
/// required to be bit-identical).
pub fn update_means_with_rho(
    ds: &Dataset,
    assign: &[u32],
    k: usize,
    prev: Option<&MeanSet>,
    changed: Option<&[bool]>,
    prev_rho: Option<&[f64]>,
) -> UpdateOutput {
    let n = ds.n();
    let d = ds.d();
    assert_eq!(assign.len(), n);
    if let Some(p) = prev {
        assert_eq!(p.k(), k);
    }

    // Bucket members by cluster (counting sort: two passes, no per-cluster
    // Vec allocations).
    let mut sizes = vec![0u32; k];
    for &a in assign {
        sizes[a as usize] += 1;
    }
    let mut starts = vec![0usize; k + 1];
    for j in 0..k {
        starts[j + 1] = starts[j] + sizes[j] as usize;
    }
    let mut members = vec![0u32; n];
    let mut cursor = starts.clone();
    for (i, &a) in assign.iter().enumerate() {
        members[cursor[a as usize]] = i as u32;
        cursor[a as usize] += 1;
    }

    let mut rho = vec![0.0f64; n];
    let mut moved = vec![false; k];
    let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); k];

    // Dense scratch for the tentative mean λ plus a touched-term list so
    // resetting costs O(touched), not O(D).
    let mut lambda = vec![0.0f64; d];
    let mut touched: Vec<u32> = Vec::new();

    for j in 0..k {
        let mem = &members[starts[j]..starts[j + 1]];
        let membership_changed = changed.map(|c| c[j]).unwrap_or(true);
        if mem.is_empty() || (!membership_changed && prev.is_some()) {
            // Empty cluster: keep previous mean (invariant). Unchanged
            // cluster: reuse previous mean verbatim — identical values,
            // marked invariant.
            if let Some(p) = prev {
                let (ts, vs) = p.m.row(j);
                rows[j] = ts.iter().cloned().zip(vs.iter().cloned()).collect();
                // The mean is unchanged, so each member's similarity is
                // unchanged too: copy it when available (fast path),
                // else recompute via a sparse merge.
                if let Some(pr) = prev_rho {
                    for &i in mem {
                        rho[i as usize] = pr[i as usize];
                    }
                } else {
                    for &i in mem {
                        rho[i as usize] = dot_row_sparse(&ds.x, i as usize, &rows[j]);
                    }
                }
                moved[j] = false;
                continue;
            }
            // No previous means (first iteration) and empty cluster:
            // leave a zero mean; it can never win an argmax.
            moved[j] = false;
            continue;
        }

        // (1) Tentative mean λ = Σ members.
        touched.clear();
        for &i in mem {
            let (ts, vs) = ds.x.row(i as usize);
            for (&t, &v) in ts.iter().zip(vs) {
                if lambda[t as usize] == 0.0 {
                    touched.push(t);
                }
                lambda[t as usize] += v;
            }
        }
        // L2-normalize λ.
        let norm = touched
            .iter()
            .map(|&t| lambda[t as usize] * lambda[t as usize])
            .sum::<f64>()
            .sqrt();
        if norm > 0.0 {
            for &t in &touched {
                lambda[t as usize] /= norm;
            }
        }
        // (2) Similarities of members to their own centroid, while λ is
        // dense in scratch: O(nt_i) each (Algorithm 6 lines 6–7).
        for &i in mem {
            let (ts, vs) = ds.x.row(i as usize);
            let mut s = 0.0;
            for (&t, &v) in ts.iter().zip(vs) {
                s += v * lambda[t as usize];
            }
            rho[i as usize] = s;
        }
        // Extract the sparse row (term-sorted) and reset scratch.
        touched.sort_unstable();
        let row: Vec<(u32, f64)> = touched
            .iter()
            .map(|&t| (t, lambda[t as usize]))
            .filter(|&(_, v)| v != 0.0)
            .collect();
        for &t in &touched {
            lambda[t as usize] = 0.0;
        }
        rows[j] = row;
        moved[j] = true;
    }

    let m = RowSlab::from_rows(d, &rows);
    let objective = rho.iter().sum();
    UpdateOutput {
        means: MeanSet { m, moved, sizes },
        rho,
        objective,
    }
}

/// [`update_means_with_rho`] parallelized over **cluster ranges** on a
/// [`std::thread::scope`] pool (`threads ≤ 1` falls back to the serial
/// function). Each cluster's tentative mean, normalization, and member
/// similarities are computed by exactly one worker running the serial
/// per-cluster routine — accumulation in member order, norm over the
/// touched-term list in insertion order — and the per-thread partial
/// results (mean rows, moved flags, member ρ values) are merged in fixed
/// cluster order. The output is therefore **bit-identical** to the
/// serial path for any thread count: same mean values, same ρ, and the
/// objective is summed over the same index order.
///
/// **Sync contract:** the worker body below is the per-cluster routine
/// of [`update_means_with_rho`] verbatim (only the ρ writes go through
/// an `(object, value)` list instead of the shared vector). Any change
/// to either copy must be mirrored in the other — the determinism
/// suite (`rust/tests/parallel.rs` and `par_update_bit_identical_to_serial`
/// below) enforces the equivalence.
pub fn update_means_with_rho_par(
    ds: &Dataset,
    assign: &[u32],
    k: usize,
    prev: Option<&MeanSet>,
    changed: Option<&[bool]>,
    prev_rho: Option<&[f64]>,
    threads: usize,
) -> UpdateOutput {
    if threads <= 1 || k < 2 {
        return update_means_with_rho(ds, assign, k, prev, changed, prev_rho);
    }
    let n = ds.n();
    let d = ds.d();
    assert_eq!(assign.len(), n);
    if let Some(p) = prev {
        assert_eq!(p.k(), k);
    }

    // Bucket members by cluster (identical to the serial pass).
    let mut sizes = vec![0u32; k];
    for &a in assign {
        sizes[a as usize] += 1;
    }
    let mut starts = vec![0usize; k + 1];
    for j in 0..k {
        starts[j + 1] = starts[j] + sizes[j] as usize;
    }
    let mut members = vec![0u32; n];
    let mut cursor = starts.clone();
    for (i, &a) in assign.iter().enumerate() {
        members[cursor[a as usize]] = i as u32;
        cursor[a as usize] += 1;
    }

    /// Partial result for one contiguous cluster range `[j0, j0+len)`.
    struct ClusterRange {
        j0: usize,
        rows: Vec<Vec<(u32, f64)>>,
        moved: Vec<bool>,
        /// `(object id, ρ)` for every member of the range's clusters.
        rho: Vec<(u32, f64)>,
    }

    let workers = threads.min(k).max(1);
    let chunk = (k + workers - 1) / workers;
    let members_ref = &members;
    let starts_ref = &starts;

    let results: Vec<ClusterRange> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..workers {
            let j0 = t * chunk;
            let j1 = ((t + 1) * chunk).min(k);
            if j0 >= j1 {
                break;
            }
            handles.push(scope.spawn(move || {
                let mut out = ClusterRange {
                    j0,
                    rows: Vec::with_capacity(j1 - j0),
                    moved: Vec::with_capacity(j1 - j0),
                    rho: Vec::new(),
                };
                // Thread-local dense scratch, exactly like the serial path.
                let mut lambda = vec![0.0f64; d];
                let mut touched: Vec<u32> = Vec::new();
                for j in j0..j1 {
                    let mem = &members_ref[starts_ref[j]..starts_ref[j + 1]];
                    let membership_changed = changed.map(|c| c[j]).unwrap_or(true);
                    if mem.is_empty() || (!membership_changed && prev.is_some()) {
                        if let Some(p) = prev {
                            let (ts, vs) = p.m.row(j);
                            let row: Vec<(u32, f64)> =
                                ts.iter().cloned().zip(vs.iter().cloned()).collect();
                            if let Some(pr) = prev_rho {
                                for &i in mem {
                                    out.rho.push((i, pr[i as usize]));
                                }
                            } else {
                                for &i in mem {
                                    out.rho.push((i, dot_row_sparse(&ds.x, i as usize, &row)));
                                }
                            }
                            out.rows.push(row);
                            out.moved.push(false);
                            continue;
                        }
                        out.rows.push(Vec::new());
                        out.moved.push(false);
                        continue;
                    }

                    touched.clear();
                    for &i in mem {
                        let (ts, vs) = ds.x.row(i as usize);
                        for (&t, &v) in ts.iter().zip(vs) {
                            if lambda[t as usize] == 0.0 {
                                touched.push(t);
                            }
                            lambda[t as usize] += v;
                        }
                    }
                    let norm = touched
                        .iter()
                        .map(|&t| lambda[t as usize] * lambda[t as usize])
                        .sum::<f64>()
                        .sqrt();
                    if norm > 0.0 {
                        for &t in &touched {
                            lambda[t as usize] /= norm;
                        }
                    }
                    for &i in mem {
                        let (ts, vs) = ds.x.row(i as usize);
                        let mut s = 0.0;
                        for (&t, &v) in ts.iter().zip(vs) {
                            s += v * lambda[t as usize];
                        }
                        out.rho.push((i, s));
                    }
                    touched.sort_unstable();
                    let row: Vec<(u32, f64)> = touched
                        .iter()
                        .map(|&t| (t, lambda[t as usize]))
                        .filter(|&(_, v)| v != 0.0)
                        .collect();
                    for &t in &touched {
                        lambda[t as usize] = 0.0;
                    }
                    out.rows.push(row);
                    out.moved.push(true);
                }
                out
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("update-step worker panicked"))
            .collect()
    });

    // Merge the partial mean rows / moved flags / ρ in fixed cluster order.
    let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); k];
    let mut moved = vec![false; k];
    let mut rho = vec![0.0f64; n];
    for range in results {
        let j0 = range.j0;
        for (off, m) in range.moved.iter().enumerate() {
            moved[j0 + off] = *m;
        }
        for (off, row) in range.rows.into_iter().enumerate() {
            rows[j0 + off] = row;
        }
        for (i, r) in range.rho {
            rho[i as usize] = r;
        }
    }

    let m = RowSlab::from_rows(d, &rows);
    let objective = rho.iter().sum();
    UpdateOutput {
        means: MeanSet { m, moved, sizes },
        rho,
        objective,
    }
}

/// Mini-batch / streaming update step (§Stream): fold one batch of
/// objects into the mean set with per-centroid **count-decay** learning
/// rates, reusing the full-batch per-cluster routine so the degenerate
/// configuration is *bit-exact* Lloyd.
///
/// * `runs` — the batch as maximal contiguous object-id ranges
///   (ascending, disjoint; the driver's schedules produce these).
/// * `changed[j]` — whether cluster `j` is rebuilt this batch. The
///   driver sets it from batch membership changes (memoryless mode) or
///   for every cluster with batch members (streaming mode).
/// * `sizes` — full-assignment cluster sizes, maintained incrementally
///   by the driver (copied into the returned [`MeanSet`]).
/// * `counts[j]` — decayed batch mass `c_j`, updated in place:
///   `c_j ← decay·c_j + m_j` with `m_j` the cluster's batch-member
///   count; the learning rate is `η_j = m_j / c_j`. `decay = 1`
///   is classic count decay (Sculley-style mini-batch k-means),
///   `decay < 1` forgets old batches (drifting streams), and
///   `decay = 0` is memoryless: `η_j = 1` exactly, so the batch mean
///   replaces the centroid outright.
///
/// **Lloyd-parity contract.** When the batch covers every object and
/// `η_j == 1` (first touch of `j`, or `decay == 0`), each rebuilt
/// cluster runs the *same* floating-point operations in the same order
/// as [`update_means_with_rho`]'s moving branch (member-order λ
/// accumulation, touched-list norm, dense-scratch member ρ), reuse
/// clusters take the same verbatim-copy path, and ρ entries outside the
/// batch are carried from `prev_rho` — so the output (means, ρ,
/// objective) is **bit-identical** to the full-batch update.
/// `rust/tests/minibatch.rs` enforces this end to end. Any change to
/// the per-cluster body here must be mirrored in
/// [`update_means_with_rho`] / [`update_means_with_rho_par`] and vice
/// versa (the existing sync contract extends to this function).
///
/// With `η < 1` the tentative vector is the spherical blend
/// `(1−η)·μ_old + η·λ̂` (λ̂ the unit-normalized batch mean),
/// re-normalized — centroids move toward fresh batches at a rate that
/// decays as their accumulated mass grows.
///
/// **Cost floor — this is the reference oracle.** Per call this does
/// O(n) scalar work (the ρ carry and objective sum) plus O(nnz(M))
/// (untouched rows are cloned and the mean matrix is rebuilt) on top of
/// the O(batch-terms) accumulation. The steady-state driver no longer
/// pays that floor: it calls [`update_means_minibatch_inplace`], which
/// splices only the touched rows of the existing [`RowSlab`] and
/// mutates ρ with per-batch-member deltas. This function is kept
/// deliberately unchanged as the **from-scratch reference** the
/// splice-vs-scratch bit-equality suite (`rust/tests/minibatch_splice.rs`)
/// compares against every round.
#[allow(clippy::too_many_arguments)]
pub fn update_means_minibatch(
    ds: &Dataset,
    assign: &[u32],
    runs: &[(usize, usize)],
    k: usize,
    prev: &MeanSet,
    changed: &[bool],
    prev_rho: &[f64],
    sizes: &[u32],
    counts: &mut [f64],
    decay: f64,
) -> UpdateOutput {
    let n = ds.n();
    let d = ds.d();
    assert_eq!(assign.len(), n);
    assert_eq!(prev.k(), k);
    assert_eq!(counts.len(), k);
    assert_eq!(prev_rho.len(), n);
    debug_assert!(runs.windows(2).all(|w| w[0].1 <= w[1].0), "runs overlap");

    // Bucket the batch members by cluster (counting sort over the runs,
    // ascending object id — the member order the Lloyd-parity contract
    // relies on).
    let b: usize = runs.iter().map(|&(lo, hi)| hi - lo).sum();
    let mut bsizes = vec![0u32; k];
    for &(lo, hi) in runs {
        for &a in &assign[lo..hi] {
            bsizes[a as usize] += 1;
        }
    }
    let mut starts = vec![0usize; k + 1];
    for j in 0..k {
        starts[j + 1] = starts[j] + bsizes[j] as usize;
    }
    let mut members = vec![0u32; b];
    let mut cursor = starts.clone();
    for &(lo, hi) in runs {
        for i in lo..hi {
            let a = assign[i] as usize;
            members[cursor[a]] = i as u32;
            cursor[a] += 1;
        }
    }

    // ρ outside the batch is carried verbatim; batch members are
    // overwritten below (reuse clusters keep the carried value — the
    // same values the full-batch reuse path copies).
    let mut rho = prev_rho.to_vec();
    let mut moved = vec![false; k];
    let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); k];
    let mut lambda = vec![0.0f64; d];
    let mut touched: Vec<u32> = Vec::new();

    for j in 0..k {
        let mem = &members[starts[j]..starts[j + 1]];
        if mem.is_empty() || !changed[j] {
            // No batch members, or the driver ruled this cluster
            // untouched: previous mean reused verbatim, invariant. The
            // count-decay rule still applies with m_j = 0 — idle
            // clusters forget, so a drifting stream re-adopts them at
            // full learning rate instead of being damped by ancient
            // mass. (With decay = 0 this zeroes the count, which the
            // Lloyd-parity mode never reads.)
            counts[j] *= decay;
            let (ts, vs) = prev.m.row(j);
            rows[j] = ts.iter().cloned().zip(vs.iter().cloned()).collect();
            continue;
        }

        let m_j = mem.len() as f64;
        let carried = decay * counts[j];
        counts[j] = carried + m_j;
        let eta = m_j / counts[j];

        // Batch mean λ, accumulated in member order and normalized over
        // the touched list in insertion order (identical to the
        // full-batch routine).
        touched.clear();
        for &i in mem {
            let (ts, vs) = ds.x.row(i as usize);
            for (&t, &v) in ts.iter().zip(vs) {
                if lambda[t as usize] == 0.0 {
                    touched.push(t);
                }
                lambda[t as usize] += v;
            }
        }
        let norm = touched
            .iter()
            .map(|&t| lambda[t as usize] * lambda[t as usize])
            .sum::<f64>()
            .sqrt();
        if norm > 0.0 {
            for &t in &touched {
                lambda[t as usize] /= norm;
            }
        }
        if carried != 0.0 {
            // η < 1: spherical blend (1−η)·μ_old + η·λ̂, re-normalized.
            // (η == 1 skips this block entirely — the bit-exact
            // full-batch Lloyd path performs no extra operations.)
            for &t in &touched {
                lambda[t as usize] *= eta;
            }
            let (ots, ovs) = prev.m.row(j);
            for (&t, &v) in ots.iter().zip(ovs) {
                if lambda[t as usize] == 0.0 {
                    touched.push(t);
                }
                lambda[t as usize] += (1.0 - eta) * v;
            }
            let bnorm = touched
                .iter()
                .map(|&t| lambda[t as usize] * lambda[t as usize])
                .sum::<f64>()
                .sqrt();
            if bnorm > 0.0 {
                for &t in &touched {
                    lambda[t as usize] /= bnorm;
                }
            }
        }
        // Batch members' similarities to their (new) centroid while it
        // is dense in scratch.
        for &i in mem {
            let (ts, vs) = ds.x.row(i as usize);
            let mut s = 0.0;
            for (&t, &v) in ts.iter().zip(vs) {
                s += v * lambda[t as usize];
            }
            rho[i as usize] = s;
        }
        touched.sort_unstable();
        let row: Vec<(u32, f64)> = touched
            .iter()
            .map(|&t| (t, lambda[t as usize]))
            .filter(|&(_, v)| v != 0.0)
            .collect();
        for &t in &touched {
            lambda[t as usize] = 0.0;
        }
        rows[j] = row;
        moved[j] = true;
    }

    let m = RowSlab::from_rows(d, &rows);
    let objective = rho.iter().sum();
    UpdateOutput {
        means: MeanSet {
            m,
            moved,
            sizes: sizes.to_vec(),
        },
        rho,
        objective,
    }
}

/// One staged (not yet applied) touched cluster of a mini-batch round:
/// the new mean row, the batch members' new ρ values (in member order),
/// and the updated decay count. Staging and applying are separated so
/// the per-cluster float work can run on worker threads while every
/// mutation of the shared state happens serially in fixed cluster
/// order — the bit-identity-to-serial recipe the assignment engine uses.
#[derive(Debug, Default)]
struct StagedCluster {
    row_ids: Vec<u32>,
    row_vals: Vec<f64>,
    /// New ρ per batch member, ordered like `members[starts[j]..]`.
    mrho: Vec<f64>,
    /// `decay·counts[j] + m_j`, applied to `counts[j]` at apply time.
    count: f64,
}

/// Per-worker dense scratch for [`stage_cluster`] (the λ accumulator
/// plus its touched-term list), pooled so steady-state rounds allocate
/// nothing.
#[derive(Debug, Default)]
struct LambdaScratch {
    lambda: Vec<f64>,
    touched: Vec<u32>,
}

/// Reusable state of [`update_means_minibatch_inplace`]. Holding it in
/// the driver (instead of locals) is what makes the steady-state round
/// allocation-free: every vector is cleared and refilled within its
/// plateaued capacity (enforced by `rust/tests/alloc_free.rs`).
#[derive(Debug, Default)]
pub struct MbUpdateScratch {
    /// Batch members per cluster `m_j` (counting-sort histogram).
    bsizes: Vec<u32>,
    /// Cluster start offsets into `members` (`k + 1` entries).
    starts: Vec<usize>,
    /// Counting-sort write cursor.
    cursor: Vec<usize>,
    /// Batch member ids bucketed by cluster, ascending within a cluster.
    members: Vec<u32>,
    /// Touched cluster ids, ascending.
    touched_js: Vec<u32>,
    /// One staged result slot per touched cluster.
    staged: Vec<StagedCluster>,
    /// Pooled per-worker λ scratch.
    pool: ScratchPool<LambdaScratch>,
}

impl MbUpdateScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resident bytes of the persistent scratch (Max-MEM accounting).
    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        self.bsizes.capacity() * size_of::<u32>()
            + (self.starts.capacity() + self.cursor.capacity()) * size_of::<usize>()
            + (self.members.capacity() + self.touched_js.capacity()) * size_of::<u32>()
            + self
                .staged
                .iter()
                .map(|s| {
                    s.row_ids.capacity() * size_of::<u32>()
                        + (s.row_vals.capacity() + s.mrho.capacity()) * size_of::<f64>()
                })
                .sum::<usize>()
            + self.pool.mem_bytes(|ls| {
                ls.lambda.capacity() * size_of::<f64>() + ls.touched.capacity() * size_of::<u32>()
            })
    }
}

/// Stage one touched cluster: the **verbatim** per-cluster float
/// sequence of [`update_means_minibatch`]'s touched branch (member-order
/// λ accumulation, touched-list norm, optional spherical blend, member
/// ρ while λ is dense, sort, extract), writing into `out` instead of
/// the shared state. **Sync contract:** any change here must be mirrored
/// in the oracle's touched branch and vice versa — the splice-vs-scratch
/// suite enforces bit-equality of the two.
#[allow(clippy::too_many_arguments)]
fn stage_cluster(
    ds: &Dataset,
    m_ro: &RowSlab,
    counts_ro: &[f64],
    decay: f64,
    members: &[u32],
    starts: &[usize],
    j: usize,
    ls: &mut LambdaScratch,
    out: &mut StagedCluster,
) {
    let mem = &members[starts[j]..starts[j + 1]];
    let m_j = mem.len() as f64;
    let carried = decay * counts_ro[j];
    out.count = carried + m_j;
    let eta = m_j / out.count;

    let lambda = &mut ls.lambda;
    let touched = &mut ls.touched;
    touched.clear();
    for &i in mem {
        let (ts, vs) = ds.x.row(i as usize);
        for (&t, &v) in ts.iter().zip(vs) {
            if lambda[t as usize] == 0.0 {
                touched.push(t);
            }
            lambda[t as usize] += v;
        }
    }
    let norm = touched
        .iter()
        .map(|&t| lambda[t as usize] * lambda[t as usize])
        .sum::<f64>()
        .sqrt();
    if norm > 0.0 {
        for &t in touched.iter() {
            lambda[t as usize] /= norm;
        }
    }
    if carried != 0.0 {
        for &t in touched.iter() {
            lambda[t as usize] *= eta;
        }
        let (ots, ovs) = m_ro.row(j);
        for (&t, &v) in ots.iter().zip(ovs) {
            if lambda[t as usize] == 0.0 {
                touched.push(t);
            }
            lambda[t as usize] += (1.0 - eta) * v;
        }
        let bnorm = touched
            .iter()
            .map(|&t| lambda[t as usize] * lambda[t as usize])
            .sum::<f64>()
            .sqrt();
        if bnorm > 0.0 {
            for &t in touched.iter() {
                lambda[t as usize] /= bnorm;
            }
        }
    }
    out.mrho.clear();
    for &i in mem {
        let (ts, vs) = ds.x.row(i as usize);
        let mut s = 0.0;
        for (&t, &v) in ts.iter().zip(vs) {
            s += v * lambda[t as usize];
        }
        out.mrho.push(s);
    }
    touched.sort_unstable();
    out.row_ids.clear();
    out.row_vals.clear();
    for &t in touched.iter() {
        let v = lambda[t as usize];
        if v != 0.0 {
            out.row_ids.push(t);
            out.row_vals.push(v);
        }
    }
    for &t in touched.iter() {
        lambda[t as usize] = 0.0;
    }
}

/// In-place mini-batch update: the batch-scale replacement for
/// [`update_means_minibatch`]. Instead of cloning ρ, cloning untouched
/// rows, and rebuilding the mean matrix, it
///
/// * splices only the touched rows of `means.m` ([`RowSlab::set_row`]),
/// * rewrites `means.moved` / `means.sizes` / `counts` in place,
/// * overwrites `rho` only at batch-member positions, and
/// * returns the **objective delta** Σ (ρ_new − ρ_old) over batch
///   members, so the driver can maintain the objective incrementally.
///
/// Per-cluster staging (the count-decay update and the mean/ρ float
/// work) is sharded over cluster ranges through the same engine as the
/// assignment step when `par.is_parallel()` — each touched cluster runs
/// the serial float sequence on exactly one worker, results land in
/// per-cluster slots, and the apply pass mutates the shared state in
/// ascending cluster order — so the output is **bit-identical** to
/// serial for any thread count.
///
/// The per-cluster float sequence is [`update_means_minibatch`]'s
/// verbatim (see [`stage_cluster`]'s sync contract): for the same
/// inputs, the spliced `means.m`, ρ, `counts`, `moved`, and `sizes`
/// bit-match the oracle's freshly built ones, which keeps the
/// batch==n ∧ decay==0 path bit-exact full-batch Lloyd.
///
/// Cost: O(batch terms + nnz of touched mean rows) — no O(n) pass, no
/// O(nnz(M)) rebuild — and zero allocations at steady state (`scratch`
/// capacities plateau).
#[allow(clippy::too_many_arguments)]
pub fn update_means_minibatch_inplace(
    ds: &Dataset,
    assign: &[u32],
    runs: &[(usize, usize)],
    means: &mut MeanSet,
    rho: &mut [f64],
    changed: &[bool],
    sizes: &[u32],
    counts: &mut [f64],
    decay: f64,
    scratch: &mut MbUpdateScratch,
    par: &ParConfig,
) -> f64 {
    let n = ds.n();
    let d = ds.d();
    let k = means.k();
    assert_eq!(assign.len(), n);
    assert_eq!(counts.len(), k);
    assert_eq!(rho.len(), n);
    assert_eq!(changed.len(), k);
    assert_eq!(sizes.len(), k);
    debug_assert!(runs.windows(2).all(|w| w[0].1 <= w[1].0), "runs overlap");

    let sc = scratch;
    // Counting sort of the batch by cluster — same member order as the
    // oracle (ascending object id within a cluster), into reused
    // buffers.
    sc.bsizes.clear();
    sc.bsizes.resize(k, 0);
    for &(lo, hi) in runs {
        for &a in &assign[lo..hi] {
            sc.bsizes[a as usize] += 1;
        }
    }
    sc.starts.clear();
    sc.starts.resize(k + 1, 0);
    for j in 0..k {
        sc.starts[j + 1] = sc.starts[j] + sc.bsizes[j] as usize;
    }
    let b = sc.starts[k];
    sc.members.clear();
    sc.members.resize(b, 0);
    sc.cursor.clear();
    sc.cursor.extend_from_slice(&sc.starts);
    for &(lo, hi) in runs {
        for i in lo..hi {
            let a = assign[i] as usize;
            sc.members[sc.cursor[a]] = i as u32;
            sc.cursor[a] += 1;
        }
    }

    // Untouched clusters: count decay in place, row and ρ untouched
    // (they were already exactly the reused values the oracle clones).
    // Touched clusters are collected in ascending order for staging.
    sc.touched_js.clear();
    for j in 0..k {
        means.moved[j] = false;
        if sc.bsizes[j] == 0 || !changed[j] {
            counts[j] *= decay;
        } else {
            sc.touched_js.push(j as u32);
        }
    }
    means.sizes.copy_from_slice(sizes);

    let t = sc.touched_js.len();
    if sc.staged.len() < t {
        sc.staged.resize_with(t, StagedCluster::default);
    }

    // Stage every touched cluster (read-only over the shared state).
    {
        let m_ro: &RowSlab = &means.m;
        let counts_ro: &[f64] = counts;
        let members: &[u32] = &sc.members;
        let starts: &[usize] = &sc.starts;
        let pool = &sc.pool;
        let make = || LambdaScratch {
            lambda: vec![0.0f64; d],
            touched: Vec::new(),
        };
        // A pooled λ from an earlier dataset may have the wrong width.
        let fix = |ls: &mut LambdaScratch| {
            if ls.lambda.len() != d {
                ls.lambda.clear();
                ls.lambda.resize(d, 0.0);
                ls.touched.clear();
            }
        };
        if par.is_parallel() && t > 1 {
            run_sharded_with(
                par,
                &mut sc.touched_js[..],
                &mut sc.staged[..t],
                1,
                |_, js, slots| {
                    let mut ls = pool.checkout(make);
                    fix(&mut ls);
                    for (&jj, out) in js.iter().zip(slots.iter_mut()) {
                        stage_cluster(
                            ds, m_ro, counts_ro, decay, members, starts, jj as usize, &mut ls,
                            out,
                        );
                    }
                    pool.checkin(ls, PhaseTimes::default());
                    (OpCounters::new(), 0)
                },
            );
        } else {
            let mut ls = pool.checkout(make);
            fix(&mut ls);
            for (idx, &jj) in sc.touched_js.iter().enumerate() {
                stage_cluster(
                    ds,
                    m_ro,
                    counts_ro,
                    decay,
                    members,
                    starts,
                    jj as usize,
                    &mut ls,
                    &mut sc.staged[idx],
                );
            }
            pool.checkin(ls, PhaseTimes::default());
        }
    }

    // Apply serially in ascending cluster order: splice the row, commit
    // the count, flag the move, and fold the member ρ deltas into the
    // incremental objective.
    let mut obj_delta = 0.0f64;
    for (idx, &jj) in sc.touched_js.iter().enumerate() {
        let j = jj as usize;
        let slot = &sc.staged[idx];
        counts[j] = slot.count;
        means.m.set_row(j, &slot.row_ids, &slot.row_vals);
        means.moved[j] = true;
        let mem = &sc.members[sc.starts[j]..sc.starts[j + 1]];
        for (&i, &new) in mem.iter().zip(&slot.mrho) {
            obj_delta += new - rho[i as usize];
            rho[i as usize] = new;
        }
    }
    obj_delta
}

/// Dot of CSR row `i` with a term-sorted sparse tuple list.
fn dot_row_sparse(x: &CsrMatrix, i: usize, row: &[(u32, f64)]) -> f64 {
    let (ts, vs) = x.row(i);
    let (mut a, mut b, mut acc) = (0usize, 0usize, 0.0);
    while a < ts.len() && b < row.len() {
        match ts[a].cmp(&row[b].0) {
            std::cmp::Ordering::Less => a += 1,
            std::cmp::Ordering::Greater => b += 1,
            std::cmp::Ordering::Equal => {
                acc += vs[a] * row[b].1;
                a += 1;
                b += 1;
            }
        }
    }
    acc
}

/// Determine which clusters' membership changed between two assignments;
/// used to mark moving/invariant centroids for the ICP filter.
pub fn membership_changes(prev: &[u32], next: &[u32], k: usize) -> Vec<bool> {
    assert_eq!(prev.len(), next.len());
    let mut changed = vec![false; k];
    for (&p, &q) in prev.iter().zip(next) {
        if p != q {
            changed[p as usize] = true;
            changed[q as usize] = true;
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::build_dataset;

    fn toy_ds() -> Dataset {
        // 6 docs, clearly two groups sharing terms.
        let docs = vec![
            vec![(0, 3), (1, 1)],
            vec![(0, 2), (1, 2)],
            vec![(0, 4)],
            vec![(2, 3), (3, 1)],
            vec![(2, 2), (3, 2)],
            vec![(3, 4)],
        ];
        build_dataset("toy", 4, &docs)
    }

    #[test]
    fn means_are_unit_norm_and_rho_correct() {
        let ds = toy_ds();
        let assign = vec![0, 0, 0, 1, 1, 1];
        let out = update_means(&ds, &assign, 2, None, None);
        assert_eq!(out.means.k(), 2);
        for j in 0..2 {
            assert!((out.means.m.row_norm(j) - 1.0).abs() < 1e-12);
        }
        // rho[i] must equal dot(x_i, mean_{a(i)}) by definition.
        for i in 0..6 {
            let dense = out.means.m.row_dense(assign[i] as usize);
            let expect = ds.x.row_dot_dense(i, &dense);
            assert!((out.rho[i] - expect).abs() < 1e-12);
        }
        assert!((out.objective - out.rho.iter().sum::<f64>()).abs() < 1e-12);
        assert_eq!(out.means.sizes, vec![3, 3]);
        assert!(out.means.moved.iter().all(|&m| m));
    }

    #[test]
    fn unchanged_cluster_reuses_previous_mean_exactly() {
        let ds = toy_ds();
        let a0 = vec![0, 0, 0, 1, 1, 1];
        let first = update_means(&ds, &a0, 2, None, None);
        // Same assignment again: no cluster changed.
        let changed = membership_changes(&a0, &a0, 2);
        assert!(changed.iter().all(|&c| !c));
        let second = update_means(&ds, &a0, 2, Some(&first.means), Some(&changed));
        assert_eq!(second.means.m, first.means.m); // bitwise identical
        assert!(second.means.moved.iter().all(|&m| !m));
        for i in 0..6 {
            assert!((second.rho[i] - first.rho[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn membership_changes_marks_both_sides() {
        let prev = vec![0, 0, 1, 1];
        let next = vec![0, 1, 1, 1];
        let ch = membership_changes(&prev, &next, 3);
        assert_eq!(ch, vec![true, true, false]);
    }

    #[test]
    fn empty_cluster_keeps_previous_mean() {
        let ds = toy_ds();
        let a0 = vec![0, 0, 0, 1, 1, 1];
        let first = update_means(&ds, &a0, 2, None, None);
        // Everybody moves to cluster 0; cluster 1 becomes empty.
        let a1 = vec![0, 0, 0, 0, 0, 0];
        let changed = membership_changes(&a0, &a1, 2);
        let second = update_means(&ds, &a1, 2, Some(&first.means), Some(&changed));
        assert_eq!(second.means.sizes, vec![6, 0]);
        // Cluster 1 kept its old mean row and is marked invariant.
        assert_eq!(second.means.m.row(1), first.means.m.row(1));
        assert!(!second.means.moved[1]);
        assert!(second.means.moved[0]);
    }

    #[test]
    fn par_update_bit_identical_to_serial() {
        use crate::corpus::{generate, tiny};
        let c = generate(&tiny(71));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let k = 9usize;
        let assign: Vec<u32> = (0..ds.n() as u32).map(|i| i % k as u32).collect();
        let serial = update_means_with_rho(&ds, &assign, k, None, None, None);
        for threads in [2usize, 4, 7] {
            let par = update_means_with_rho_par(&ds, &assign, k, None, None, None, threads);
            assert_eq!(par.means.m, serial.means.m, "threads={threads}");
            assert_eq!(par.means.moved, serial.means.moved);
            assert_eq!(par.means.sizes, serial.means.sizes);
            assert_eq!(par.rho, serial.rho, "threads={threads}");
            assert_eq!(
                par.objective.to_bits(),
                serial.objective.to_bits(),
                "threads={threads}"
            );
        }
        // Second step with unchanged membership + previous means/ρ: the
        // reuse fast paths must stay bit-identical too.
        let changed = membership_changes(&assign, &assign, k);
        let s2 = update_means_with_rho(
            &ds,
            &assign,
            k,
            Some(&serial.means),
            Some(&changed),
            Some(&serial.rho),
        );
        let p2 = update_means_with_rho_par(
            &ds,
            &assign,
            k,
            Some(&serial.means),
            Some(&changed),
            Some(&serial.rho),
            4,
        );
        assert_eq!(p2.means.m, s2.means.m);
        assert_eq!(p2.rho, s2.rho);
    }

    #[test]
    fn minibatch_full_span_eta_one_is_bitwise_lloyd() {
        use crate::corpus::{generate, tiny};
        let c = generate(&tiny(91));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let k = 7usize;
        let a0: Vec<u32> = (0..ds.n() as u32).map(|i| i % k as u32).collect();
        let first = update_means(&ds, &a0, k, None, None);
        // Second assignment perturbs some memberships.
        let mut a1 = a0.clone();
        for i in (0..ds.n()).step_by(9) {
            a1[i] = (a1[i] + 1) % k as u32;
        }
        let changed = membership_changes(&a0, &a1, k);
        let full = update_means_with_rho(
            &ds,
            &a1,
            k,
            Some(&first.means),
            Some(&changed),
            Some(&first.rho),
        );
        // Mini-batch over the full span with zero carried mass: must be
        // bit-identical (means, ρ, objective) to the full-batch update.
        let mut sizes = vec![0u32; k];
        for &a in &a1 {
            sizes[a as usize] += 1;
        }
        let mut counts = vec![0.0f64; k];
        let mb = update_means_minibatch(
            &ds,
            &a1,
            &[(0, ds.n())],
            k,
            &first.means,
            &changed,
            &first.rho,
            &sizes,
            &mut counts,
            0.0,
        );
        assert_eq!(mb.means.m, full.means.m);
        assert_eq!(mb.means.moved, full.means.moved);
        assert_eq!(mb.means.sizes, full.means.sizes);
        for (a, b) in mb.rho.iter().zip(&full.rho) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(mb.objective.to_bits(), full.objective.to_bits());
        // Memoryless counts hold exactly the last batch's masses.
        for j in 0..k {
            let m_j = a1.iter().filter(|&&a| a as usize == j).count() as f64;
            if changed[j] && m_j > 0.0 {
                assert_eq!(counts[j], m_j);
            }
        }
    }

    #[test]
    fn minibatch_blend_keeps_unit_norms_and_counts_decay() {
        use crate::corpus::{generate, tiny};
        let c = generate(&tiny(92));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let k = 6usize;
        let assign: Vec<u32> = (0..ds.n() as u32).map(|i| (i * 7 % k as u32)).collect();
        let seed = update_means(&ds, &assign, k, None, None);
        let mut counts = vec![0.0f64; k];
        let mut sizes = vec![0u32; k];
        for &a in &assign {
            sizes[a as usize] += 1;
        }
        let changed = vec![true; k];
        // Two successive batches over different windows; decay 0.5.
        let mut prev = seed.means.clone();
        let mut rho = seed.rho.clone();
        for (lo, hi) in [(0usize, ds.n() / 2), (ds.n() / 4, ds.n())] {
            let out = update_means_minibatch(
                &ds,
                &assign,
                &[(lo, hi)],
                k,
                &prev,
                &changed,
                &rho,
                &sizes,
                &mut counts,
                0.5,
            );
            for j in 0..k {
                if out.means.m.row_nnz(j) > 0 {
                    let norm = out.means.m.row_norm(j);
                    assert!(
                        (norm - 1.0).abs() < 1e-9,
                        "cluster {j} not unit norm after blend: {norm}"
                    );
                }
            }
            prev = out.means;
            rho = out.rho;
        }
        // Counts carry decayed history: after two overlapping batches
        // every cluster with members in both windows holds
        // 0.5·m1 + m2, strictly more than its second-batch mass.
        for j in 0..k {
            let m2 = assign[ds.n() / 4..]
                .iter()
                .filter(|&&a| a as usize == j)
                .count() as f64;
            if m2 > 0.0 && counts[j] > 0.0 {
                assert!(counts[j] >= m2, "cluster {j}: count {} < {m2}", counts[j]);
            }
        }
    }

    #[test]
    fn minibatch_inplace_matches_oracle_and_parallel_is_bit_identical() {
        use crate::algo::par::ParConfig;
        use crate::corpus::{generate, tiny};
        let c = generate(&tiny(93));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let n = ds.n();
        let k = 6usize;
        let assign: Vec<u32> = (0..n as u32).map(|i| (i * 5) % k as u32).collect();
        let seed = update_means(&ds, &assign, k, None, None);
        let mut sizes = vec![0u32; k];
        for &a in &assign {
            sizes[a as usize] += 1;
        }
        let changed = vec![true; k];

        // Three lockstep streams: the from-scratch oracle, the in-place
        // serial path, and the in-place path with a varying thread count.
        let mut o_means = seed.means.clone();
        let mut o_rho = seed.rho.clone();
        let mut o_counts = vec![0.0f64; k];
        let mut s_means = seed.means.clone();
        let mut s_rho = seed.rho.clone();
        let mut s_counts = vec![0.0f64; k];
        let mut s_scr = MbUpdateScratch::new();
        let mut p_means = seed.means.clone();
        let mut p_rho = seed.rho.clone();
        let mut p_counts = vec![0.0f64; k];
        let mut p_scr = MbUpdateScratch::new();

        let serial = ParConfig::serial();
        let threads = [2usize, 4, 7];
        let b = n / 3;
        let mut lo = 0usize;
        for round in 0..12 {
            let runs = if lo + b <= n {
                vec![(lo, lo + b)]
            } else {
                vec![(0, lo + b - n), (lo, n)]
            };
            lo = (lo + b) % n;

            let out = update_means_minibatch(
                &ds, &assign, &runs, k, &o_means, &changed, &o_rho, &sizes, &mut o_counts,
                0.5,
            );
            o_means = out.means;
            o_rho = out.rho;

            let sd = update_means_minibatch_inplace(
                &ds, &assign, &runs, &mut s_means, &mut s_rho, &changed, &sizes,
                &mut s_counts, 0.5, &mut s_scr, &serial,
            );
            let par = ParConfig::with_threads(threads[round % threads.len()]);
            let pd = update_means_minibatch_inplace(
                &ds, &assign, &runs, &mut p_means, &mut p_rho, &changed, &sizes,
                &mut p_counts, 0.5, &mut p_scr, &par,
            );

            assert_eq!(s_means.m, o_means.m, "round {round}: spliced means diverged");
            assert_eq!(s_means.moved, o_means.moved, "round {round}");
            assert_eq!(s_means.sizes, o_means.sizes, "round {round}");
            for (a, b2) in s_rho.iter().zip(&o_rho) {
                assert_eq!(a.to_bits(), b2.to_bits(), "round {round}: rho bits");
            }
            for (a, b2) in s_counts.iter().zip(&o_counts) {
                assert_eq!(a.to_bits(), b2.to_bits(), "round {round}: counts bits");
            }
            assert_eq!(p_means.m, s_means.m, "round {round}: parallel means");
            assert_eq!(p_means.moved, s_means.moved, "round {round}: parallel moved");
            for (a, b2) in p_rho.iter().zip(&s_rho) {
                assert_eq!(a.to_bits(), b2.to_bits(), "round {round}: parallel rho");
            }
            for (a, b2) in p_counts.iter().zip(&s_counts) {
                assert_eq!(a.to_bits(), b2.to_bits(), "round {round}: parallel counts");
            }
            assert_eq!(sd.to_bits(), pd.to_bits(), "round {round}: objective delta");
        }
    }

    #[test]
    fn first_call_with_empty_cluster_yields_zero_mean() {
        let ds = toy_ds();
        // cluster 2 gets nobody
        let assign = vec![0, 0, 0, 1, 1, 1];
        let mut a = assign.clone();
        a[5] = 1;
        let prev: Option<&MeanSet> = None;
        let out = update_means(&ds, &a, 2, prev, None);
        assert_eq!(out.means.k(), 2);
        // force K=4 via a fake previous set is covered elsewhere; here we
        // simply check no panic and valid norms.
        for j in 0..out.means.k() {
            let nz = out.means.m.row_nnz(j);
            if nz > 0 {
                assert!((out.means.m.row_norm(j) - 1.0).abs() < 1e-12);
            }
        }
    }
}
