//! Incremental structured-index maintenance (§Perf).
//!
//! Every iteration the update step produces a new [`MeanSet`] in which —
//! late in a run — only a shrinking fraction of centroids actually
//! changed (`MeanSet::moved`, the same invariance the ICP filter
//! exploits). The from-scratch `build` constructors nevertheless pay
//! O(nnz(M)) tuple placement plus an O(K·(D−t_th)) dense partial-index
//! fill per iteration. The maintainers here persist each index across
//! iterations and *splice* instead:
//!
//! * **Two-block regions** (`InvIndex` / `Region2`): a centroid is
//!   *dirty* when it is moving now (values changed) **or** was moving at
//!   the previous build (it must relocate from the moving block to the
//!   invariant block). Per term, the new moving block is re-scattered
//!   from the moving rows, the invariant block is a two-way merge of the
//!   surviving old invariant entries with relocated entries, and maximal
//!   runs of untouched terms are block-copied. Cost: O(dirty nnz +
//!   touched postings), with untouched regions moving at `memcpy` speed.
//! * **Sorted regions** (TA): `r2_all` has no block structure, so only
//!   centroids moving *now* are dirty; their entries are removed from
//!   and re-merged into each touched term's descending-value order.
//!   `r2_moving` contains only moving centroids and is rebuilt from the
//!   moving rows alone.
//! * **Partial index** (`M^p`): only moved centroids' columns are
//!   rewritten (clear the old row's cells, write the new row's cells) —
//!   the dense O(K·(D−t_th)) fill disappears.
//!
//! The spliced index is **byte-identical** to a from-scratch build for
//! the same mean set (enforced by `rust/tests/incremental.rs` and the
//! hot-path bench). The from-scratch path remains as the fallback
//! whenever the structural parameters `(t_th, v_th)` change after an
//! EstParams run, on the first build, or when the dirty fraction exceeds
//! each maintainer's `max_dirty_frac` (splicing a mostly-dirty index
//! costs more than rebuilding it).
//!
//! All scratch (counts, cursors, spare flat arrays) is persistent and
//! reused across iterations, so steady-state maintenance performs no
//! per-iteration allocations beyond amortized high-water growth.

use crate::index::inverted::InvIndex;
use crate::index::means::MeanSet;
use crate::index::slab::RowSlab;
use crate::index::structured::{CsIndex, EsIndex, TaIndex};

/// Default dirty-fraction threshold above which maintainers fall back to
/// a from-scratch build. Overridable with the `SKM_SPLICE_FRAC`
/// environment knob (`0` disables splicing, `1` always splices);
/// results are identical either way — only elapsed time changes.
pub fn default_dirty_frac() -> f64 {
    std::env::var("SKM_SPLICE_FRAC")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.5)
}

/// Snapshot of the mean rows and moved flags as of the last index build.
/// Held as a [`RowSlab`] so the steady-state refresh is a **delta**:
/// [`Self::refresh_dirty`] rewrites only the rows the update step moved
/// (the unmoved ones are verbatim identical to the snapshot already —
/// the same invariance the splice itself relies on), O(moved nnz) per
/// round instead of a full O(nnz(M)) re-copy. The full [`Self::set_from`]
/// remains for the incompatible cases (first build, k/d/parameter
/// change) and reuses arena capacity, so neither path allocates in
/// steady state.
#[derive(Debug, Default)]
struct PrevMeans {
    rows: RowSlab,
    moved: Vec<bool>,
}

impl PrevMeans {
    fn set_from(&mut self, means: &MeanSet) {
        self.rows.set_from(&means.m);
        self.moved.clear();
        self.moved.extend_from_slice(&means.moved);
    }

    /// Delta refresh: rewrite only the rows `means.moved` flags as
    /// changed since the last sync. Valid whenever this snapshot was
    /// taken from the same `(k, d)` mean set lineage (the `compatible`
    /// gate of every maintainer) — rows with `moved[j] == false` are
    /// bit-identical to what the snapshot already holds.
    fn refresh_dirty(&mut self, means: &MeanSet) {
        debug_assert_eq!(self.k(), means.k());
        debug_assert_eq!(self.d(), means.m.n_cols());
        for j in 0..means.k() {
            if means.moved[j] {
                let (ts, vs) = means.m.row(j);
                self.rows.set_row(j, ts, vs);
            }
        }
        self.moved.clear();
        self.moved.extend_from_slice(&means.moved);
    }

    fn k(&self) -> usize {
        self.rows.n_rows()
    }

    fn d(&self) -> usize {
        self.rows.n_cols()
    }

    #[inline]
    fn row(&self, j: usize) -> (&[u32], &[f64]) {
        self.rows.row(j)
    }

    fn mem_bytes(&self) -> usize {
        self.rows.mem_bytes() + self.moved.capacity()
    }
}

/// Persistent scratch for the splice passes: per-term counts/cursors,
/// the insertion CSR, and the spare flat arrays the new layout is built
/// into (swapped with the live index afterwards, so the old arrays
/// become the next iteration's spares).
#[derive(Debug, Default)]
struct SpliceScratch {
    cnt_mov: Vec<u32>,
    cnt_inv: Vec<u32>,
    touched: Vec<bool>,
    ins_cnt: Vec<u32>,
    ins_off: Vec<usize>,
    ins_ids: Vec<u32>,
    ins_vals: Vec<f64>,
    cur: Vec<usize>,
    /// Spare offsets in the live indexes' compact `u32` layout (swapped
    /// in wholesale, so the element type must match).
    new_offsets: Vec<u32>,
    new_ids: Vec<u32>,
    new_vals: Vec<f64>,
    new_mfm: Vec<u32>,
    sort_buf: Vec<(u32, f64)>,
}

impl SpliceScratch {
    fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        (self.cnt_mov.capacity()
            + self.cnt_inv.capacity()
            + self.ins_cnt.capacity()
            + self.new_mfm.capacity()
            + self.ins_ids.capacity()
            + self.new_ids.capacity()
            + self.new_offsets.capacity())
            * size_of::<u32>()
            + (self.ins_off.capacity() + self.cur.capacity()) * size_of::<usize>()
            + (self.ins_vals.capacity() + self.new_vals.capacity()) * size_of::<f64>()
            + self.touched.capacity()
            + self.sort_buf.capacity() * size_of::<(u32, f64)>()
    }
}

/// Splice a two-block (moving | invariant) flat postings region over
/// terms `[t_lo, t_hi)` from the previous build's mean snapshot to the
/// new mean set. `map` is the value transform (returning `None` drops
/// the entry, e.g. the ES `v ≥ v_th` filter); it must be the same
/// transform the from-scratch builder applies, so spliced values are
/// bitwise identical to freshly built ones.
#[allow(clippy::too_many_arguments)]
fn splice_two_block<F>(
    t_lo: usize,
    t_hi: usize,
    offsets: &mut Vec<u32>,
    ids: &mut Vec<u32>,
    vals: &mut Vec<f64>,
    mfm: &mut Vec<u32>,
    prev: &PrevMeans,
    means: &MeanSet,
    map: F,
    sc: &mut SpliceScratch,
) where
    F: Fn(f64) -> Option<f64>,
{
    let k = means.k();
    let width = t_hi - t_lo;
    debug_assert_eq!(offsets.len(), width + 1);
    debug_assert_eq!(mfm.len(), width);
    debug_assert_eq!(prev.k(), k);

    // Per-term counts seeded from the current layout. Every old moving
    // id is dirty (it was moving), so the moving counts drain to exactly
    // the new moving insertions below.
    sc.cnt_mov.clear();
    sc.cnt_mov.extend_from_slice(mfm);
    sc.cnt_inv.clear();
    sc.cnt_inv.extend((0..width).map(|i| offsets[i + 1] - offsets[i] - mfm[i]));
    sc.touched.clear();
    sc.touched.resize(width, false);
    sc.ins_cnt.clear();
    sc.ins_cnt.resize(width, 0);

    for j in 0..k {
        let was = prev.moved[j];
        let now = means.moved[j];
        if !was && !now {
            continue; // clean: same values, same (invariant) block
        }
        // Remove the old contribution.
        let (ots, ovs) = prev.row(j);
        for (&t, &v) in ots.iter().zip(ovs) {
            let t = t as usize;
            if t >= t_lo && t < t_hi && map(v).is_some() {
                let i = t - t_lo;
                sc.touched[i] = true;
                if was {
                    sc.cnt_mov[i] -= 1;
                } else {
                    sc.cnt_inv[i] -= 1;
                }
            }
        }
        // Add the new contribution.
        let (nts, nvs) = means.m.row(j);
        for (&t, &v) in nts.iter().zip(nvs) {
            let t = t as usize;
            if t >= t_lo && t < t_hi && map(v).is_some() {
                let i = t - t_lo;
                sc.touched[i] = true;
                if now {
                    sc.cnt_mov[i] += 1;
                } else {
                    sc.cnt_inv[i] += 1;
                    sc.ins_cnt[i] += 1; // relocation into the invariant block
                }
            }
        }
    }

    // New offsets (compact u32 layout; accumulate wide, assert, store).
    sc.new_offsets.clear();
    sc.new_offsets.reserve(width + 1);
    sc.new_offsets.push(0);
    let mut off_acc = 0usize;
    for i in 0..width {
        off_acc += sc.cnt_mov[i] as usize + sc.cnt_inv[i] as usize;
        sc.new_offsets.push(off_acc as u32);
    }
    assert!(
        off_acc <= u32::MAX as usize,
        "spliced nnz {off_acc} overflows the u32 offset layout"
    );
    let nnz = off_acc;
    sc.new_ids.clear();
    sc.new_ids.resize(nnz, 0);
    sc.new_vals.clear();
    sc.new_vals.resize(nnz, 0.0);

    // Insertion CSR: entries of dirty centroids that are invariant NOW
    // (relocations out of the old moving block; their rows are verbatim
    // identical to the previous iteration, only the block changes).
    sc.ins_off.clear();
    sc.ins_off.reserve(width + 1);
    sc.ins_off.push(0);
    for i in 0..width {
        let last = *sc.ins_off.last().unwrap();
        sc.ins_off.push(last + sc.ins_cnt[i] as usize);
    }
    let ins_nnz = *sc.ins_off.last().unwrap();
    sc.ins_ids.clear();
    sc.ins_ids.resize(ins_nnz, 0);
    sc.ins_vals.clear();
    sc.ins_vals.resize(ins_nnz, 0.0);
    sc.cur.clear();
    sc.cur.extend_from_slice(&sc.ins_off[..width]);
    for j in 0..k {
        if !(prev.moved[j] && !means.moved[j]) {
            continue;
        }
        let (nts, nvs) = means.m.row(j);
        for (&t, &v) in nts.iter().zip(nvs) {
            let t = t as usize;
            if t >= t_lo && t < t_hi {
                if let Some(w) = map(v) {
                    let i = t - t_lo;
                    let slot = sc.cur[i];
                    sc.ins_ids[slot] = j as u32;
                    sc.ins_vals[slot] = w;
                    sc.cur[i] += 1;
                }
            }
        }
    }

    // Moving-block scatter: iterating j ascending keeps ids ascending
    // within each term's moving block, exactly like the scratch builder.
    sc.cur.clear();
    sc.cur.extend(sc.new_offsets[..width].iter().map(|&o| o as usize));
    for j in 0..k {
        if !means.moved[j] {
            continue;
        }
        let (nts, nvs) = means.m.row(j);
        for (&t, &v) in nts.iter().zip(nvs) {
            let t = t as usize;
            if t >= t_lo && t < t_hi {
                if let Some(w) = map(v) {
                    let i = t - t_lo;
                    let slot = sc.cur[i];
                    sc.new_ids[slot] = j as u32;
                    sc.new_vals[slot] = w;
                    sc.cur[i] += 1;
                }
            }
        }
    }

    // Invariant blocks: block-copy maximal untouched runs, merge touched
    // terms (old invariant survivors × relocations, both id-ascending).
    let mut i = 0usize;
    while i < width {
        if !sc.touched[i] {
            let run = i;
            while i < width && !sc.touched[i] {
                debug_assert_eq!(mfm[i], 0, "untouched term cannot hold moving entries");
                i += 1;
            }
            let (a, b) = (offsets[run] as usize, offsets[i] as usize);
            let dst = sc.new_offsets[run] as usize;
            sc.new_ids[dst..dst + (b - a)].copy_from_slice(&ids[a..b]);
            sc.new_vals[dst..dst + (b - a)].copy_from_slice(&vals[a..b]);
            continue;
        }
        let mut a = offsets[i] as usize + mfm[i] as usize;
        let a_end = offsets[i + 1] as usize;
        let mut b = sc.ins_off[i];
        let b_end = sc.ins_off[i + 1];
        let mut out = sc.new_offsets[i] as usize + sc.cnt_mov[i] as usize;
        while a < a_end {
            let ja = ids[a];
            if means.moved[ja as usize] {
                a += 1; // departed to the moving block
                continue;
            }
            while b < b_end && sc.ins_ids[b] < ja {
                sc.new_ids[out] = sc.ins_ids[b];
                sc.new_vals[out] = sc.ins_vals[b];
                out += 1;
                b += 1;
            }
            sc.new_ids[out] = ja;
            sc.new_vals[out] = vals[a];
            out += 1;
            a += 1;
        }
        while b < b_end {
            sc.new_ids[out] = sc.ins_ids[b];
            sc.new_vals[out] = sc.ins_vals[b];
            out += 1;
            b += 1;
        }
        debug_assert_eq!(out, sc.new_offsets[i + 1] as usize);
        i += 1;
    }

    sc.new_mfm.clear();
    sc.new_mfm.extend_from_slice(&sc.cnt_mov);

    // Install the new layout; the old arrays become next round's spares.
    std::mem::swap(offsets, &mut sc.new_offsets);
    std::mem::swap(ids, &mut sc.new_ids);
    std::mem::swap(vals, &mut sc.new_vals);
    std::mem::swap(mfm, &mut sc.new_mfm);
}

/// Splice a per-term descending-value sorted region (TA's `r2_all`)
/// over terms `[t_lo, t_hi)`. Only centroids moving *now* are dirty
/// (there is no block structure, so relocations keep their exact slot);
/// their old entries are filtered out and their new entries merged back
/// in `(value desc, id asc)` order — the same strict total order the
/// scratch builder sorts by, hence a unique, bitwise-identical layout.
#[allow(clippy::too_many_arguments)]
fn splice_sorted_desc(
    t_lo: usize,
    t_hi: usize,
    offsets: &mut Vec<u32>,
    ids: &mut Vec<u32>,
    vals: &mut Vec<f64>,
    prev: &PrevMeans,
    means: &MeanSet,
    sc: &mut SpliceScratch,
) {
    let k = means.k();
    let width = t_hi - t_lo;
    debug_assert_eq!(offsets.len(), width + 1);

    sc.cnt_inv.clear();
    sc.cnt_inv.extend((0..width).map(|i| offsets[i + 1] - offsets[i]));
    sc.touched.clear();
    sc.touched.resize(width, false);
    sc.ins_cnt.clear();
    sc.ins_cnt.resize(width, 0);

    for j in 0..k {
        if !means.moved[j] {
            continue;
        }
        let (ots, _) = prev.row(j);
        for &t in ots {
            let t = t as usize;
            if t >= t_lo && t < t_hi {
                sc.touched[t - t_lo] = true;
                sc.cnt_inv[t - t_lo] -= 1;
            }
        }
        let (nts, _) = means.m.row(j);
        for &t in nts {
            let t = t as usize;
            if t >= t_lo && t < t_hi {
                sc.touched[t - t_lo] = true;
                sc.cnt_inv[t - t_lo] += 1;
                sc.ins_cnt[t - t_lo] += 1;
            }
        }
    }

    sc.new_offsets.clear();
    sc.new_offsets.reserve(width + 1);
    sc.new_offsets.push(0);
    let mut off_acc = 0usize;
    for i in 0..width {
        off_acc += sc.cnt_inv[i] as usize;
        sc.new_offsets.push(off_acc as u32);
    }
    assert!(
        off_acc <= u32::MAX as usize,
        "spliced nnz {off_acc} overflows the u32 offset layout"
    );
    let nnz = off_acc;
    sc.new_ids.clear();
    sc.new_ids.resize(nnz, 0);
    sc.new_vals.clear();
    sc.new_vals.resize(nnz, 0.0);

    // Insertion CSR over the moving rows.
    sc.ins_off.clear();
    sc.ins_off.reserve(width + 1);
    sc.ins_off.push(0);
    for i in 0..width {
        let last = *sc.ins_off.last().unwrap();
        sc.ins_off.push(last + sc.ins_cnt[i] as usize);
    }
    let ins_nnz = *sc.ins_off.last().unwrap();
    sc.ins_ids.clear();
    sc.ins_ids.resize(ins_nnz, 0);
    sc.ins_vals.clear();
    sc.ins_vals.resize(ins_nnz, 0.0);
    sc.cur.clear();
    sc.cur.extend_from_slice(&sc.ins_off[..width]);
    for j in 0..k {
        if !means.moved[j] {
            continue;
        }
        let (nts, nvs) = means.m.row(j);
        for (&t, &v) in nts.iter().zip(nvs) {
            let t = t as usize;
            if t >= t_lo && t < t_hi {
                let i = t - t_lo;
                let slot = sc.cur[i];
                sc.ins_ids[slot] = j as u32;
                sc.ins_vals[slot] = v;
                sc.cur[i] += 1;
            }
        }
    }

    // `a` before `b` in TA order: value desc, id asc (strict total
    // order — ids are distinct within a term).
    #[inline]
    fn ta_before(va: f64, ia: u32, vb: f64, ib: u32) -> bool {
        va > vb || (va == vb && ia < ib)
    }

    let mut i = 0usize;
    while i < width {
        if !sc.touched[i] {
            let run = i;
            while i < width && !sc.touched[i] {
                i += 1;
            }
            let (a, b) = (offsets[run] as usize, offsets[i] as usize);
            let dst = sc.new_offsets[run] as usize;
            sc.new_ids[dst..dst + (b - a)].copy_from_slice(&ids[a..b]);
            sc.new_vals[dst..dst + (b - a)].copy_from_slice(&vals[a..b]);
            continue;
        }
        // Sort this term's insertions into TA order.
        sc.sort_buf.clear();
        for q in sc.ins_off[i]..sc.ins_off[i + 1] {
            sc.sort_buf.push((sc.ins_ids[q], sc.ins_vals[q]));
        }
        sc.sort_buf
            .sort_unstable_by(|x, y| y.1.partial_cmp(&x.1).unwrap().then(x.0.cmp(&y.0)));
        // Merge survivors (old order minus dirty ids) with insertions.
        let mut a = offsets[i] as usize;
        let a_end = offsets[i + 1] as usize;
        let mut b = 0usize;
        let b_end = sc.sort_buf.len();
        let mut out = sc.new_offsets[i] as usize;
        while a < a_end {
            let (ja, va) = (ids[a], vals[a]);
            if means.moved[ja as usize] {
                a += 1; // stale entry of a moved centroid
                continue;
            }
            while b < b_end && ta_before(sc.sort_buf[b].1, sc.sort_buf[b].0, va, ja) {
                sc.new_ids[out] = sc.sort_buf[b].0;
                sc.new_vals[out] = sc.sort_buf[b].1;
                out += 1;
                b += 1;
            }
            sc.new_ids[out] = ja;
            sc.new_vals[out] = va;
            out += 1;
            a += 1;
        }
        while b < b_end {
            sc.new_ids[out] = sc.sort_buf[b].0;
            sc.new_vals[out] = sc.sort_buf[b].1;
            out += 1;
            b += 1;
        }
        debug_assert_eq!(out, sc.new_offsets[i + 1] as usize);
        i += 1;
    }

    std::mem::swap(offsets, &mut sc.new_offsets);
    std::mem::swap(ids, &mut sc.new_ids);
    std::mem::swap(vals, &mut sc.new_vals);
}

/// Rebuild a per-term descending-value sorted region from the moving
/// rows only (TA's `r2_moving` holds nothing else, so "incremental" is
/// a from-moving-rows rebuild — cost proportional to the moving mass).
fn rebuild_moving_sorted(
    t_lo: usize,
    t_hi: usize,
    offsets: &mut Vec<u32>,
    ids: &mut Vec<u32>,
    vals: &mut Vec<f64>,
    means: &MeanSet,
    sc: &mut SpliceScratch,
) {
    let k = means.k();
    let width = t_hi - t_lo;

    sc.ins_cnt.clear();
    sc.ins_cnt.resize(width, 0);
    for j in 0..k {
        if !means.moved[j] {
            continue;
        }
        let (nts, _) = means.m.row(j);
        for &t in nts {
            let t = t as usize;
            if t >= t_lo && t < t_hi {
                sc.ins_cnt[t - t_lo] += 1;
            }
        }
    }
    sc.new_offsets.clear();
    sc.new_offsets.reserve(width + 1);
    sc.new_offsets.push(0);
    let mut off_acc = 0usize;
    for i in 0..width {
        off_acc += sc.ins_cnt[i] as usize;
        sc.new_offsets.push(off_acc as u32);
    }
    assert!(
        off_acc <= u32::MAX as usize,
        "spliced nnz {off_acc} overflows the u32 offset layout"
    );
    let nnz = off_acc;
    sc.new_ids.clear();
    sc.new_ids.resize(nnz, 0);
    sc.new_vals.clear();
    sc.new_vals.resize(nnz, 0.0);
    sc.cur.clear();
    sc.cur.extend(sc.new_offsets[..width].iter().map(|&o| o as usize));
    for j in 0..k {
        if !means.moved[j] {
            continue;
        }
        let (nts, nvs) = means.m.row(j);
        for (&t, &v) in nts.iter().zip(nvs) {
            let t = t as usize;
            if t >= t_lo && t < t_hi {
                let i = t - t_lo;
                let slot = sc.cur[i];
                sc.new_ids[slot] = j as u32;
                sc.new_vals[slot] = v;
                sc.cur[i] += 1;
            }
        }
    }
    for i in 0..width {
        let (a, b) = (sc.new_offsets[i] as usize, sc.new_offsets[i + 1] as usize);
        sc.sort_buf.clear();
        for q in a..b {
            sc.sort_buf.push((sc.new_ids[q], sc.new_vals[q]));
        }
        sc.sort_buf
            .sort_unstable_by(|x, y| y.1.partial_cmp(&x.1).unwrap().then(x.0.cmp(&y.0)));
        for (q, &(id, v)) in sc.sort_buf.iter().enumerate() {
            sc.new_ids[a + q] = id;
            sc.new_vals[a + q] = v;
        }
    }

    std::mem::swap(offsets, &mut sc.new_offsets);
    std::mem::swap(ids, &mut sc.new_ids);
    std::mem::swap(vals, &mut sc.new_vals);
}

/// Rewrite only the moved centroids' columns of a full-expression
/// partial index (`w` is row-major per term over `t_th ≤ s < D`).
/// Invariant centroids' columns are untouched — their rows are verbatim
/// identical to the previous iteration, so their cells already match a
/// from-scratch fill.
fn rewrite_partial_columns<G>(
    t_th: usize,
    k: usize,
    w: &mut [f64],
    default: f64,
    prev: &PrevMeans,
    means: &MeanSet,
    cell: G,
) where
    G: Fn(f64) -> f64,
{
    for j in 0..k {
        if !means.moved[j] {
            continue;
        }
        let (ots, _) = prev.row(j);
        for &t in ots {
            let t = t as usize;
            if t >= t_th {
                w[(t - t_th) * k + j] = default;
            }
        }
        let (nts, nvs) = means.m.row(j);
        for (&t, &v) in nts.iter().zip(nvs) {
            let t = t as usize;
            if t >= t_th {
                w[(t - t_th) * k + j] = cell(v);
            }
        }
    }
}

fn set_moving_ids(moving_ids: &mut Vec<u32>, means: &MeanSet) {
    moving_ids.clear();
    for j in 0..means.k() {
        if means.moved[j] {
            moving_ids.push(j as u32);
        }
    }
}

fn dirty_count(prev_moved: &[bool], means: &MeanSet) -> usize {
    means.dirty_against(prev_moved)
}

/// How the last `update` call rebuilt the index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebuildKind {
    /// Nothing built yet.
    None,
    /// From-scratch `build` (first build, parameter change, or dirty
    /// fraction above threshold).
    Full,
    /// In-place incremental splice.
    Incremental,
}

macro_rules! maintainer_common {
    ($index:ty) => {
        /// The maintained index, if `update` has run at least once.
        pub fn index(&self) -> Option<&$index> {
            self.idx.as_ref()
        }

        /// How the last `update` rebuilt the index (bench/test hook).
        pub fn last_rebuild(&self) -> RebuildKind {
            self.last_rebuild
        }

        /// Persistent-state bytes: the index itself plus the mean
        /// snapshot and splice scratch (counted toward Max MEM).
        pub fn mem_bytes(&self) -> usize {
            self.idx.as_ref().map(|i| i.mem_bytes()).unwrap_or(0)
                + self.prev.mem_bytes()
                + self.sc.mem_bytes()
        }
    };
}

/// Maintainer for the plain two-block [`InvIndex`] (MIVI / ICP, and the
/// Region-1 part when used standalone).
pub struct InvMaintainer {
    idx: Option<InvIndex>,
    prev: PrevMeans,
    t_lim: usize,
    scale: f64,
    sc: SpliceScratch,
    /// Dirty fraction above which `update` falls back to a full build.
    pub max_dirty_frac: f64,
    pub full_rebuilds: u64,
    pub incremental_rebuilds: u64,
    last_rebuild: RebuildKind,
}

impl Default for InvMaintainer {
    fn default() -> Self {
        Self::new()
    }
}

impl InvMaintainer {
    pub fn new() -> Self {
        Self {
            idx: None,
            prev: PrevMeans::default(),
            t_lim: usize::MAX,
            scale: 1.0,
            sc: SpliceScratch::default(),
            max_dirty_frac: default_dirty_frac(),
            full_rebuilds: 0,
            incremental_rebuilds: 0,
            last_rebuild: RebuildKind::None,
        }
    }

    maintainer_common!(InvIndex);

    /// Bring the index up to date with `means`; splices when the layout
    /// parameters are unchanged and the dirty fraction is low enough,
    /// else rebuilds from scratch. Byte-identical either way.
    pub fn update(&mut self, means: &MeanSet, t_lim: usize, scale: f64) -> &InvIndex {
        crate::failpoint!("maintain.inv", 0u64);
        let k = means.k();
        let d = means.m.n_cols();
        let t_lim = t_lim.min(d);
        let compatible = self.idx.is_some()
            && self.prev.k() == k
            && self.prev.d() == d
            && self.t_lim == t_lim
            && self.scale.to_bits() == scale.to_bits();
        let dirty = if compatible {
            dirty_count(&self.prev.moved, means)
        } else {
            k
        };
        if compatible && (dirty as f64) <= self.max_dirty_frac * k as f64 {
            let idx = self.idx.as_mut().unwrap();
            splice_two_block(
                0,
                t_lim,
                &mut idx.offsets,
                &mut idx.ids,
                &mut idx.vals,
                &mut idx.mfm,
                &self.prev,
                means,
                |v| Some(v * scale),
                &mut self.sc,
            );
            set_moving_ids(&mut idx.moving_ids, means);
            // Re-derive the dense Region-1 tail from the freshly
            // spliced sparse arrays (deterministic in them, so this
            // matches a from-scratch build bit-for-bit).
            idx.refresh_dense_tail();
            self.incremental_rebuilds += 1;
            self.last_rebuild = RebuildKind::Incremental;
        } else {
            self.idx = Some(InvIndex::build_scaled(means, t_lim, scale));
            self.full_rebuilds += 1;
            self.last_rebuild = RebuildKind::Full;
        }
        self.t_lim = t_lim;
        self.scale = scale;
        if compatible {
            self.prev.refresh_dirty(means);
        } else {
            self.prev.set_from(means);
        }
        self.idx.as_ref().unwrap()
    }
}

/// Maintainer for the ES three-region structured index.
pub struct EsMaintainer {
    idx: Option<EsIndex>,
    prev: PrevMeans,
    t_th: usize,
    v_th: f64,
    sc: SpliceScratch,
    pub max_dirty_frac: f64,
    pub full_rebuilds: u64,
    pub incremental_rebuilds: u64,
    last_rebuild: RebuildKind,
}

impl Default for EsMaintainer {
    fn default() -> Self {
        Self::new()
    }
}

impl EsMaintainer {
    pub fn new() -> Self {
        Self {
            idx: None,
            prev: PrevMeans::default(),
            t_th: usize::MAX,
            v_th: f64::NAN,
            sc: SpliceScratch::default(),
            max_dirty_frac: default_dirty_frac(),
            full_rebuilds: 0,
            incremental_rebuilds: 0,
            last_rebuild: RebuildKind::None,
        }
    }

    maintainer_common!(EsIndex);

    pub fn update(&mut self, means: &MeanSet, t_th: usize, v_th: f64) -> &EsIndex {
        crate::failpoint!("maintain.es", 0u64);
        let k = means.k();
        let d = means.m.n_cols();
        let t_th = t_th.min(d);
        assert!(v_th > 0.0, "v_th must be positive (got {v_th})");
        let compatible = self.idx.is_some()
            && self.prev.k() == k
            && self.prev.d() == d
            && self.t_th == t_th
            && self.v_th.to_bits() == v_th.to_bits();
        let dirty = if compatible {
            dirty_count(&self.prev.moved, means)
        } else {
            k
        };
        if compatible && (dirty as f64) <= self.max_dirty_frac * k as f64 {
            let inv_scale = 1.0 / v_th;
            let idx = self.idx.as_mut().unwrap();
            splice_two_block(
                0,
                t_th,
                &mut idx.r1.offsets,
                &mut idx.r1.ids,
                &mut idx.r1.vals,
                &mut idx.r1.mfm,
                &self.prev,
                means,
                |v| Some(v * inv_scale),
                &mut self.sc,
            );
            splice_two_block(
                t_th,
                d,
                &mut idx.r2.offsets,
                &mut idx.r2.ids,
                &mut idx.r2.vals,
                &mut idx.r2.mfm,
                &self.prev,
                means,
                |v| {
                    if v >= v_th {
                        Some(v * inv_scale - 1.0)
                    } else {
                        None
                    }
                },
                &mut self.sc,
            );
            rewrite_partial_columns(
                t_th,
                k,
                &mut idx.partial.w,
                1.0,
                &self.prev,
                means,
                |v| {
                    if v >= v_th {
                        0.0
                    } else {
                        1.0 - v * inv_scale
                    }
                },
            );
            set_moving_ids(&mut idx.r1.moving_ids, means);
            set_moving_ids(&mut idx.moving_ids, means);
            idx.r1.refresh_dense_tail();
            self.incremental_rebuilds += 1;
            self.last_rebuild = RebuildKind::Incremental;
        } else {
            self.idx = Some(EsIndex::build(means, t_th, v_th));
            self.full_rebuilds += 1;
            self.last_rebuild = RebuildKind::Full;
        }
        self.t_th = t_th;
        self.v_th = v_th;
        if compatible {
            self.prev.refresh_dirty(means);
        } else {
            self.prev.set_from(means);
        }
        self.idx.as_ref().unwrap()
    }
}

/// Maintainer for the TA sorted-postings structured index.
pub struct TaMaintainer {
    idx: Option<TaIndex>,
    prev: PrevMeans,
    t_th: usize,
    sc: SpliceScratch,
    pub max_dirty_frac: f64,
    pub full_rebuilds: u64,
    pub incremental_rebuilds: u64,
    last_rebuild: RebuildKind,
}

impl Default for TaMaintainer {
    fn default() -> Self {
        Self::new()
    }
}

impl TaMaintainer {
    pub fn new() -> Self {
        Self {
            idx: None,
            prev: PrevMeans::default(),
            t_th: usize::MAX,
            sc: SpliceScratch::default(),
            max_dirty_frac: default_dirty_frac(),
            full_rebuilds: 0,
            incremental_rebuilds: 0,
            last_rebuild: RebuildKind::None,
        }
    }

    maintainer_common!(TaIndex);

    pub fn update(&mut self, means: &MeanSet, t_th: usize) -> &TaIndex {
        crate::failpoint!("maintain.ta", 0u64);
        let k = means.k();
        let d = means.m.n_cols();
        let t_th = t_th.min(d);
        let compatible =
            self.idx.is_some() && self.prev.k() == k && self.prev.d() == d && self.t_th == t_th;
        let dirty = if compatible {
            dirty_count(&self.prev.moved, means)
        } else {
            k
        };
        if compatible && (dirty as f64) <= self.max_dirty_frac * k as f64 {
            let idx = self.idx.as_mut().unwrap();
            splice_two_block(
                0,
                t_th,
                &mut idx.r1.offsets,
                &mut idx.r1.ids,
                &mut idx.r1.vals,
                &mut idx.r1.mfm,
                &self.prev,
                means,
                Some,
                &mut self.sc,
            );
            splice_sorted_desc(
                t_th,
                d,
                &mut idx.r2_all.offsets,
                &mut idx.r2_all.ids,
                &mut idx.r2_all.vals,
                &self.prev,
                means,
                &mut self.sc,
            );
            rebuild_moving_sorted(
                t_th,
                d,
                &mut idx.r2_moving.offsets,
                &mut idx.r2_moving.ids,
                &mut idx.r2_moving.vals,
                means,
                &mut self.sc,
            );
            rewrite_partial_columns(t_th, k, &mut idx.partial.w, 0.0, &self.prev, means, |v| v);
            set_moving_ids(&mut idx.r1.moving_ids, means);
            set_moving_ids(&mut idx.moving_ids, means);
            idx.r1.refresh_dense_tail();
            self.incremental_rebuilds += 1;
            self.last_rebuild = RebuildKind::Incremental;
        } else {
            self.idx = Some(TaIndex::build(means, t_th));
            self.full_rebuilds += 1;
            self.last_rebuild = RebuildKind::Full;
        }
        self.t_th = t_th;
        if compatible {
            self.prev.refresh_dirty(means);
        } else {
            self.prev.set_from(means);
        }
        self.idx.as_ref().unwrap()
    }
}

/// Maintainer for the CS squared-postings structured index.
pub struct CsMaintainer {
    idx: Option<CsIndex>,
    prev: PrevMeans,
    t_th: usize,
    sc: SpliceScratch,
    pub max_dirty_frac: f64,
    pub full_rebuilds: u64,
    pub incremental_rebuilds: u64,
    last_rebuild: RebuildKind,
}

impl Default for CsMaintainer {
    fn default() -> Self {
        Self::new()
    }
}

impl CsMaintainer {
    pub fn new() -> Self {
        Self {
            idx: None,
            prev: PrevMeans::default(),
            t_th: usize::MAX,
            sc: SpliceScratch::default(),
            max_dirty_frac: default_dirty_frac(),
            full_rebuilds: 0,
            incremental_rebuilds: 0,
            last_rebuild: RebuildKind::None,
        }
    }

    maintainer_common!(CsIndex);

    pub fn update(&mut self, means: &MeanSet, t_th: usize) -> &CsIndex {
        crate::failpoint!("maintain.cs", 0u64);
        let k = means.k();
        let d = means.m.n_cols();
        let t_th = t_th.min(d);
        let compatible =
            self.idx.is_some() && self.prev.k() == k && self.prev.d() == d && self.t_th == t_th;
        let dirty = if compatible {
            dirty_count(&self.prev.moved, means)
        } else {
            k
        };
        if compatible && (dirty as f64) <= self.max_dirty_frac * k as f64 {
            let idx = self.idx.as_mut().unwrap();
            splice_two_block(
                0,
                t_th,
                &mut idx.r1.offsets,
                &mut idx.r1.ids,
                &mut idx.r1.vals,
                &mut idx.r1.mfm,
                &self.prev,
                means,
                Some,
                &mut self.sc,
            );
            splice_two_block(
                t_th,
                d,
                &mut idx.r2_sq.offsets,
                &mut idx.r2_sq.ids,
                &mut idx.r2_sq.vals,
                &mut idx.r2_sq.mfm,
                &self.prev,
                means,
                |v| Some(v * v),
                &mut self.sc,
            );
            rewrite_partial_columns(t_th, k, &mut idx.partial.w, 0.0, &self.prev, means, |v| v);
            set_moving_ids(&mut idx.r1.moving_ids, means);
            set_moving_ids(&mut idx.moving_ids, means);
            idx.r1.refresh_dense_tail();
            self.incremental_rebuilds += 1;
            self.last_rebuild = RebuildKind::Incremental;
        } else {
            self.idx = Some(CsIndex::build(means, t_th));
            self.full_rebuilds += 1;
            self.last_rebuild = RebuildKind::Full;
        }
        self.t_th = t_th;
        if compatible {
            self.prev.refresh_dirty(means);
        } else {
            self.prev.set_from(means);
        }
        self.idx.as_ref().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::means::update_means;
    use crate::sparse::build_dataset;

    fn means_seq() -> Vec<MeanSet> {
        // A tiny dataset with hand-driven assignment changes so the
        // moved flags cycle through all dirty transitions:
        // moving→moving, moving→invariant, invariant→moving, clean.
        let docs = vec![
            vec![(0, 3), (1, 1), (4, 2)],
            vec![(0, 2), (1, 2), (5, 1)],
            vec![(2, 3), (3, 1), (4, 1)],
            vec![(2, 2), (3, 2), (5, 2)],
            vec![(1, 1), (3, 1), (5, 3)],
            vec![(0, 1), (2, 1), (4, 4)],
            vec![(0, 1), (3, 2), (5, 1)],
            vec![(1, 2), (2, 2), (4, 1)],
        ];
        let ds = build_dataset("t", 6, &docs);
        let assigns: Vec<Vec<u32>> = vec![
            vec![0, 0, 1, 1, 2, 2, 3, 3],
            vec![0, 0, 1, 1, 2, 3, 3, 2], // clusters 2,3 change; 0,1 stay
            vec![0, 1, 1, 1, 2, 3, 3, 2], // clusters 0,1 change; 2,3 stay
            vec![0, 1, 1, 1, 2, 3, 3, 2], // nothing changes
            vec![0, 1, 1, 0, 2, 3, 3, 2], // clusters 0,1 change again
        ];
        let mut out = update_means(&ds, &assigns[0], 4, None, None);
        let mut seq = vec![out.means.clone()];
        for w in assigns.windows(2) {
            let changed = crate::index::means::membership_changes(&w[0], &w[1], 4);
            out = update_means(&ds, &w[1], 4, Some(&out.means), Some(&changed));
            seq.push(out.means.clone());
        }
        seq
    }

    fn assert_inv_eq(a: &InvIndex, b: &InvIndex, tag: &str) {
        let (ao, ai, av, am) = a.raw_parts();
        let (bo, bi, bv, bm) = b.raw_parts();
        assert_eq!(ao, bo, "{tag}: offsets");
        assert_eq!(ai, bi, "{tag}: ids");
        assert_eq!(am, bm, "{tag}: mfm");
        assert_eq!(av.len(), bv.len(), "{tag}: vals len");
        for (q, (x, y)) in av.iter().zip(bv).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: vals[{q}]");
        }
        assert_eq!(a.moving_ids, b.moving_ids, "{tag}: moving_ids");
    }

    #[test]
    fn inv_splice_matches_scratch_over_sequence() {
        let seq = means_seq();
        let d = seq[0].m.n_cols();
        let mut maint = InvMaintainer::new();
        maint.max_dirty_frac = 1.0; // always splice once primed
        for (r, means) in seq.iter().enumerate() {
            maint.update(means, d, 1.0);
            let scratch = InvIndex::build(means, d);
            assert_inv_eq(maint.index().unwrap(), &scratch, &format!("iter {r}"));
        }
        assert!(maint.incremental_rebuilds >= 3);
        assert_eq!(maint.full_rebuilds, 1);
    }

    #[test]
    fn es_splice_matches_scratch_including_partial() {
        let seq = means_seq();
        let d = seq[0].m.n_cols();
        let (t_th, v_th) = (d / 2, 0.2);
        let mut maint = EsMaintainer::new();
        maint.max_dirty_frac = 1.0;
        for (r, means) in seq.iter().enumerate() {
            maint.update(means, t_th, v_th);
            let scratch = EsIndex::build(means, t_th, v_th);
            let got = maint.index().unwrap();
            assert_inv_eq(&got.r1, &scratch.r1, &format!("iter {r} r1"));
            assert_eq!(got.r2.raw_parts().0, scratch.r2.raw_parts().0);
            assert_eq!(got.r2.raw_parts().1, scratch.r2.raw_parts().1);
            assert_eq!(got.r2.raw_parts().3, scratch.r2.raw_parts().3);
            for (x, y) in got.r2.raw_parts().2.iter().zip(scratch.r2.raw_parts().2) {
                assert_eq!(x.to_bits(), y.to_bits(), "iter {r} r2 vals");
            }
            for (x, y) in got.partial.values().iter().zip(scratch.partial.values()) {
                assert_eq!(x.to_bits(), y.to_bits(), "iter {r} partial");
            }
            assert_eq!(got.moving_ids, scratch.moving_ids);
        }
        assert!(maint.incremental_rebuilds >= 3);
    }

    #[test]
    fn param_change_falls_back_to_full_rebuild() {
        let seq = means_seq();
        let d = seq[0].m.n_cols();
        let mut maint = EsMaintainer::new();
        maint.max_dirty_frac = 1.0;
        maint.update(&seq[0], d / 2, 0.2);
        assert_eq!(maint.last_rebuild(), RebuildKind::Full);
        maint.update(&seq[1], d / 2, 0.2);
        assert_eq!(maint.last_rebuild(), RebuildKind::Incremental);
        // EstParams re-parameterization: t_th changes → full rebuild.
        maint.update(&seq[2], d / 3, 0.2);
        assert_eq!(maint.last_rebuild(), RebuildKind::Full);
        let scratch = EsIndex::build(&seq[2], d / 3, 0.2);
        assert_eq!(
            maint.index().unwrap().partial.values().len(),
            scratch.partial.values().len()
        );
        // … and v_th changes → full rebuild, then splicing resumes.
        maint.update(&seq[3], d / 3, 0.1);
        assert_eq!(maint.last_rebuild(), RebuildKind::Full);
        maint.update(&seq[4], d / 3, 0.1);
        assert_eq!(maint.last_rebuild(), RebuildKind::Incremental);
        let scratch = EsIndex::build(&seq[4], d / 3, 0.1);
        for (x, y) in maint
            .index()
            .unwrap()
            .partial
            .values()
            .iter()
            .zip(scratch.partial.values())
        {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// The delta snapshot refresh (only moved rows rewritten) must land
    /// on the same logical state as a full re-snapshot at every step of
    /// a moved-flag sequence covering all dirty transitions.
    #[test]
    fn delta_prev_refresh_matches_full_snapshot() {
        let seq = means_seq();
        let mut delta = PrevMeans::default();
        let mut full = PrevMeans::default();
        delta.set_from(&seq[0]);
        full.set_from(&seq[0]);
        for (r, means) in seq.iter().enumerate().skip(1) {
            delta.refresh_dirty(means);
            full.set_from(means);
            assert_eq!(delta.rows, full.rows, "iter {r}: rows");
            assert_eq!(delta.moved, full.moved, "iter {r}: moved");
        }
    }

    #[test]
    fn dirty_threshold_falls_back() {
        let seq = means_seq();
        let d = seq[0].m.n_cols();
        let mut maint = InvMaintainer::new();
        maint.max_dirty_frac = 0.0; // never splice
        for means in &seq {
            maint.update(means, d, 1.0);
            assert_eq!(maint.last_rebuild(), RebuildKind::Full);
        }
        assert_eq!(maint.incremental_rebuilds, 0);
    }
}
