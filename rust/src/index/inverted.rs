//! Inverted-index data structures over the *mean* set (Section II) and
//! over the object set (used by DIVI and by EstParams' partial object
//! index X^p, Appendix C).
//!
//! A mean-inverted index stores, for every term id `s`, the tuple array
//! `ξ_s = [(mean id c, feature value v)]` of centroids whose mean vector
//! is non-zero at `s` — `(mf)_s = |ξ_s|`. For the ICP filter the array is
//! arranged in two blocks, **moving centroids first** (Fig. 6), so the
//! moving-only scan is "iterate the first `(mfM)_s` entries": no
//! per-entry conditional branch, which is the AFM trick that keeps branch
//! mispredictions low.
//!
//! Storage is flat (CSC-like): one offsets array plus parallel `ids` /
//! `vals` arrays — no per-term `Vec` allocations on the hot path.
//!
//! ## Compact layout (§Perf tentpole)
//!
//! Posting **offsets are `u32`**, not `usize`: a mean-inverted index
//! holds at most `nnz(M) ≤ K·D̂` tuples (≈1.6·10⁸ at the paper's
//! largest PubMed configuration), far under `u32::MAX`, and the
//! narrower offsets halve the index-metadata traffic of every postings
//! lookup (the offsets array is touched once per object·term — the
//! second-hottest stream after the postings themselves). Construction
//! asserts the bound. The *object*-side [`ObjInvIndex`] keeps `usize`
//! offsets: object nnz grows with the corpus, not with K, and that
//! index sits outside the per-iteration gather loop.
//!
//! ## The dense Region-1 tail block
//!
//! Term ids are globally ordered by ascending df, so the **highest-df
//! terms sit at the top of Region 1** — and by UC3 those few terms
//! against high mean-feature values carry almost all multiplications.
//! Their tuple arrays are also the *fullest* (nearly every centroid has
//! a value at a stop-word-like term). For a short suffix of terms whose
//! arrays are ≥¾ full, the index additionally materializes a **dense
//! row-major block** (`K` doubles per term, capped to stay
//! cache-resident): the gathering phase then runs
//! [`crate::algo::kernel::dense_axpy`] — a contiguous mul/add loop with
//! zero indirection — instead of the id-indirected scatter. This is the
//! paper's "frequently used data kept in cache" region made literal.
//! The block is *derived* state, rebuilt deterministically from the
//! sparse arrays after every build or splice; bit-identity of the dense
//! gather rests on the `+0.0`-padding argument in
//! [`crate::algo::kernel`]'s docs. The moving-block (ICP) scans keep
//! using the sparse arrays — the two-block structure is untouched.
//!
//! Storage for the block is an [`AlignedF64Vec`] with the per-term row
//! stride rounded up to 8 doubles (`dense_stride`), so **every row
//! starts on a 64-byte boundary after every build and every splice**:
//! the SIMD `dense_axpy` backends then never split a cache line on
//! their row loads. The stride padding is pure layout — `dense_row`
//! still hands out exactly `k` values, and the padding doubles are
//! `+0.0` like every other absent entry.
//!
//! Indexes are *persistent* across iterations: instead of rebuilding
//! from scratch each update step, [`crate::index::maintain`] splices
//! only the postings of centroids that moved (and those that just
//! became invariant) into the two-block layout — byte-identical to a
//! from-scratch build, at a cost proportional to the moved mass.
//!
//! ## Building from persisted (possibly compressed) snapshots
//!
//! Every builder here consumes a [`CsrMatrix`] whose invariants the
//! persistence layer has already release-checked
//! (`persist::validated_csr`: monotone `indptr`, strictly ascending
//! ids `< D`, finite nonnegative values). Format-v2 snapshots store
//! postings delta+varint chunk-encoded (`persist::chunk`); the decoder
//! reproduces the original arrays **bit-exactly** before they reach
//! this module, so index construction — and therefore every downstream
//! score bit — is identical whether the matrix came from memory, a v1
//! file, or a compressed v2 file. The builders themselves never see
//! encoded bytes; `validated_csr` is the single enforcement point.
//! (The mmap serving path bypasses this module entirely for the corpus:
//! disk-resident rows are decoded per access in `persist::mmap`, and
//! the router's member scan uses `ClusteredCorpus::row_view`.)

use crate::index::means::MeanSet;
use crate::sparse::CsrMatrix;
use crate::util::aligned::AlignedF64Vec;

/// Minimum fill (numerator / denominator) for a term to join the dense
/// tail block: `mf(s) / k ≥ 3/4`.
const DENSE_MIN_FILL_NUM: usize = 3;
const DENSE_MIN_FILL_DEN: usize = 4;

/// Byte budget for the dense tail block (256 KiB — comfortably inside
/// L2, the "kept in cache" constraint).
const DENSE_MAX_BYTES: usize = 256 * 1024;

/// Floor on the dense-block term budget. At very large K a single row
/// exceeds [`DENSE_MAX_BYTES`] (K = 80 000 ⇒ 640 KB/row), but densifying
/// a ≥¾-full term still wins regardless of cache residency: the gather
/// drops the 4-byte id stream and the scatter indirection entirely and
/// streams 8 bytes/centroid sequentially. So the top few qualifying
/// terms are always mirrored, budget notwithstanding.
const DENSE_MIN_TERMS: usize = 4;

/// Mean-inverted index with the two-block (moving | invariant) layout.
///
/// Fields are `pub(crate)` so the incremental splice engine
/// ([`crate::index::maintain`]) can rebuild the flat arrays in place.
#[derive(Debug, Clone)]
pub struct InvIndex {
    pub d: usize,
    pub k: usize,
    pub(crate) offsets: Vec<u32>,
    pub(crate) ids: Vec<u32>,
    pub(crate) vals: Vec<f64>,
    /// `mfm[s]` — number of *moving* centroids in `ξ_s` (the first block).
    pub mfm: Vec<u32>,
    /// Moving centroid ids, ascending (the paper's j' → j map in G_1).
    pub moving_ids: Vec<u32>,
    /// First term of the dense tail block (`== t_lim` when the block is
    /// empty). Derived from the sparse arrays; see the module docs.
    pub(crate) dense_lo: usize,
    /// Row-major rows for terms `s ∈ [dense_lo, t_lim)` (zero-padded
    /// mirror of the sparse postings), `dense_stride` doubles apart so
    /// every row is 64-byte aligned.
    pub(crate) dense_w: AlignedF64Vec,
    /// Row stride of `dense_w` in doubles: `k` rounded up to a multiple
    /// of 8. Only the first `k` of each row are meaningful.
    pub(crate) dense_stride: usize,
}

impl InvIndex {
    /// Build from a mean set. Only terms `s < t_lim` are indexed (pass
    /// `d` for a full index; ES/TA/CS pass `t_th` and store the
    /// `s ≥ t_th` region in their own specialized structures).
    pub fn build(means: &MeanSet, t_lim: usize) -> Self {
        Self::build_scaled(means, t_lim, 1.0)
    }

    /// [`InvIndex::build`] with the Appendix-A value scaling folded into
    /// construction: every stored value is `v · scale`, written once
    /// (the ES family passes `1 / v_th`; there is no separate
    /// scale-in-place post-pass).
    pub fn build_scaled(means: &MeanSet, t_lim: usize, scale: f64) -> Self {
        let d = means.m.n_cols();
        let k = means.k();
        let t_lim = t_lim.min(d);

        // Pass 1: count entries per (term, block).
        let mut cnt_mov = vec![0u32; t_lim];
        let mut cnt_inv = vec![0u32; t_lim];
        for j in 0..k {
            let (ts, _) = means.m.row(j);
            let moving = means.moved[j];
            for &t in ts {
                let t = t as usize;
                if t < t_lim {
                    if moving {
                        cnt_mov[t] += 1;
                    } else {
                        cnt_inv[t] += 1;
                    }
                }
            }
        }
        let mut offsets = vec![0u32; t_lim + 1];
        let mut acc = 0usize;
        for s in 0..t_lim {
            acc += (cnt_mov[s] + cnt_inv[s]) as usize;
            offsets[s + 1] = acc as u32;
        }
        assert!(
            acc <= u32::MAX as usize,
            "mean-inverted index nnz {acc} overflows the u32 offset layout"
        );
        let nnz = acc;
        let mut ids = vec![0u32; nnz];
        let mut vals = vec![0.0f64; nnz];

        // Pass 2: fill. Iterating j ascending keeps ids ascending within
        // each block (deterministic layout).
        let mut cur_mov: Vec<usize> = (0..t_lim).map(|s| offsets[s] as usize).collect();
        let mut cur_inv: Vec<usize> = (0..t_lim)
            .map(|s| offsets[s] as usize + cnt_mov[s] as usize)
            .collect();
        for j in 0..k {
            let (ts, vs) = means.m.row(j);
            let moving = means.moved[j];
            for (&t, &v) in ts.iter().zip(vs) {
                let t = t as usize;
                if t < t_lim {
                    let slot = if moving {
                        let s = cur_mov[t];
                        cur_mov[t] += 1;
                        s
                    } else {
                        let s = cur_inv[t];
                        cur_inv[t] += 1;
                        s
                    };
                    ids[slot] = j as u32;
                    vals[slot] = v * scale;
                }
            }
        }

        let moving_ids: Vec<u32> = (0..k as u32).filter(|&j| means.moved[j as usize]).collect();
        let mut idx = Self {
            d,
            k,
            offsets,
            ids,
            vals,
            mfm: cnt_mov,
            moving_ids,
            dense_lo: t_lim,
            dense_w: AlignedF64Vec::new(),
            dense_stride: 0,
        };
        idx.refresh_dense_tail();
        idx
    }

    /// Rebuild the derived dense tail block from the sparse arrays.
    /// Deterministic in the sparse layout alone, so two byte-identical
    /// sparse indexes always carry byte-identical dense blocks; called
    /// after every from-scratch build and every incremental splice.
    pub(crate) fn refresh_dense_tail(&mut self) {
        let t_lim = self.offsets.len() - 1;
        let k = self.k;
        // Row stride: k rounded up to 8 doubles so every row starts on
        // a 64-byte boundary of the aligned buffer.
        let stride = if k == 0 { 0 } else { (k + 7) & !7 };
        let max_terms = if k == 0 {
            0
        } else {
            (DENSE_MAX_BYTES / (stride * std::mem::size_of::<f64>())).max(DENSE_MIN_TERMS)
        };
        let mut lo = t_lim;
        while lo > 0
            && t_lim - lo < max_terms
            && self.mf(lo - 1) * DENSE_MIN_FILL_DEN >= k * DENSE_MIN_FILL_NUM
        {
            lo -= 1;
        }
        self.dense_lo = lo;
        self.dense_stride = stride;
        self.dense_w.resize_zeroed((t_lim - lo) * stride);
        for s in lo..t_lim {
            let (a, b) = (self.offsets[s] as usize, self.offsets[s + 1] as usize);
            let base = (s - lo) * stride;
            let row = &mut self.dense_w.as_mut_slice()[base..base + k];
            for q in a..b {
                row[self.ids[q] as usize] = self.vals[q];
            }
        }
    }

    /// The dense tail row for term `s`, if `s` is inside the dense
    /// block: a `k`-length zero-padded value row addressed by centroid
    /// id, for [`crate::algo::kernel::dense_axpy`]. `None` ⇒ use the
    /// sparse postings. Multiplication accounting stays [`InvIndex::mf`]
    /// either way (padded zeros are layout, not work).
    #[inline]
    pub fn dense_row(&self, s: usize) -> Option<&[f64]> {
        if s >= self.dense_lo && s < self.offsets.len() - 1 {
            let i = (s - self.dense_lo) * self.dense_stride;
            Some(&self.dense_w.as_slice()[i..i + self.k])
        } else {
            None
        }
    }

    /// `(dense_lo, dense values)` — the derived dense tail block
    /// including the stride padding, for the equality suites and the
    /// bench reporters. Both sides of an equality comparison are built
    /// by [`InvIndex::refresh_dense_tail`] with the same `k`, so the
    /// padded buffers are comparable byte-for-byte.
    pub fn dense_parts(&self) -> (usize, &[f64]) {
        (self.dense_lo, self.dense_w.as_slice())
    }

    /// Gather one term into the accumulator and return the charged
    /// multiplication count — THE shared dispatch of every assigner's
    /// Region-1 scan (one place, not four drifting copies):
    ///
    /// * `moving_only` (ICP `G_1`): the moving-block prefix, always
    ///   sparse (a strict subset is never dense-mirrored);
    /// * full scan inside the dense tail: contiguous
    ///   [`crate::algo::kernel::dense_axpy`] row, still charging the
    ///   true `mf(s)`;
    /// * full scan elsewhere: unrolled unchecked scatter-add.
    /// This is the safe boundary over the unsafe scatter kernel: the
    /// builders/splicers only ever store centroid ids `< k`, **at most
    /// one posting per (term, centroid)** — so within any one term's
    /// tuple array the ids are pairwise distinct, and any accumulator
    /// of length ≥ `k` satisfies the kernel contract (in-range +
    /// distinct ids), including its SIMD gather/scatter forms.
    #[inline]
    pub fn gather_term(&self, s: usize, u: f64, acc: &mut [f64], moving_only: bool) -> u64 {
        assert!(acc.len() >= self.k, "accumulator shorter than K");
        if moving_only {
            let (ids, vals) = self.postings_moving(s);
            // SAFETY: ids are centroid ids < k ≤ acc.len() and pairwise
            // distinct by index construction (one posting per (term,
            // centroid)); ids/vals are parallel postings slices.
            unsafe { crate::algo::kernel::scatter_add(acc, ids, vals, u) };
            ids.len() as u64
        } else if let Some(row) = self.dense_row(s) {
            crate::algo::kernel::dense_axpy(acc, row, u);
            self.mf(s) as u64
        } else {
            let (ids, vals) = self.postings(s);
            // SAFETY: as above.
            unsafe { crate::algo::kernel::scatter_add(acc, ids, vals, u) };
            ids.len() as u64
        }
    }

    /// Number of indexed terms (`t_lim` at build time).
    pub fn t_lim(&self) -> usize {
        self.offsets.len() - 1
    }

    /// `(mf)_s` — full array length for term `s`.
    #[inline]
    pub fn mf(&self, s: usize) -> usize {
        (self.offsets[s + 1] - self.offsets[s]) as usize
    }

    /// Full tuple array `ξ_s` as `(ids, vals)` slices.
    #[inline]
    pub fn postings(&self, s: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.offsets[s] as usize, self.offsets[s + 1] as usize);
        (&self.ids[a..b], &self.vals[a..b])
    }

    /// Moving-block prefix of `ξ_s` (the first `(mfM)_s` entries).
    #[inline]
    pub fn postings_moving(&self, s: usize) -> (&[u32], &[f64]) {
        let a = self.offsets[s] as usize;
        let b = a + self.mfm[s] as usize;
        (&self.ids[a..b], &self.vals[a..b])
    }

    /// Total stored tuples Σ_s (mf)_s.
    pub fn nnz(&self) -> usize {
        self.ids.len()
    }

    /// Σ_s over a row's terms of (mf)_s — the MIVI multiplication count
    /// for one object (Fig. 3(b) integrand).
    pub fn mult_cost_for(&self, terms: &[u32]) -> u64 {
        terms
            .iter()
            .filter(|&&t| (t as usize) < self.t_lim())
            .map(|&t| self.mf(t as usize) as u64)
            .sum()
    }

    /// The flat storage `(offsets, ids, vals, mfm)` — exposed so the
    /// incremental-maintenance equality suite can compare indexes
    /// bitwise (offsets/ids/mfm with `==`, vals via `f64::to_bits`).
    pub fn raw_parts(&self) -> (&[u32], &[u32], &[f64], &[u32]) {
        (&self.offsets, &self.ids, &self.vals, &self.mfm)
    }

    /// Approximate resident bytes (paper's Max MEM accounting); counts
    /// the derived dense tail block too — it is resident state.
    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        self.offsets.len() * size_of::<u32>()
            + self.ids.len() * size_of::<u32>()
            + self.vals.len() * size_of::<f64>()
            + self.mfm.len() * size_of::<u32>()
            + self.moving_ids.len() * size_of::<u32>()
            + self.dense_w.mem_bytes()
    }
}

/// Object-inverted index: per term, the array `η_s = [(object id,
/// value)]`. Used by DIVI (Section II) over the whole vocabulary and by
/// EstParams as the partial index `X^p` over `s ≥ s_min` (Appendix C).
#[derive(Debug, Clone)]
pub struct ObjInvIndex {
    /// First indexed term id (0 for DIVI, `s_min` for X^p).
    pub s_lo: usize,
    pub d: usize,
    pub n: usize,
    offsets: Vec<usize>,
    ids: Vec<u32>,
    vals: Vec<f64>,
}

impl ObjInvIndex {
    pub fn build(x: &CsrMatrix, s_lo: usize) -> Self {
        let d = x.n_cols();
        let n = x.n_rows();
        assert!(s_lo <= d);
        let width = d - s_lo;
        let mut counts = vec![0u32; width];
        for (_, t, _) in x.iter() {
            let t = t as usize;
            if t >= s_lo {
                counts[t - s_lo] += 1;
            }
        }
        let mut offsets = vec![0usize; width + 1];
        for s in 0..width {
            offsets[s + 1] = offsets[s] + counts[s] as usize;
        }
        let nnz = offsets[width];
        let mut ids = vec![0u32; nnz];
        let mut vals = vec![0.0f64; nnz];
        let mut cur = offsets.clone();
        for (i, t, v) in x.iter() {
            let t = t as usize;
            if t >= s_lo {
                let slot = cur[t - s_lo];
                ids[slot] = i as u32;
                vals[slot] = v;
                cur[t - s_lo] += 1;
            }
        }
        Self {
            s_lo,
            d,
            n,
            offsets,
            ids,
            vals,
        }
    }

    /// Postings `(object ids, values)` for term `s` (`s ≥ s_lo`).
    #[inline]
    pub fn postings(&self, s: usize) -> (&[u32], &[f64]) {
        debug_assert!(s >= self.s_lo && s < self.d);
        let (a, b) = (self.offsets[s - self.s_lo], self.offsets[s - self.s_lo + 1]);
        (&self.ids[a..b], &self.vals[a..b])
    }

    /// Document frequency of term `s` within the indexed range.
    #[inline]
    pub fn df(&self, s: usize) -> usize {
        self.offsets[s - self.s_lo + 1] - self.offsets[s - self.s_lo]
    }

    pub fn nnz(&self) -> usize {
        self.ids.len()
    }

    /// Approximate resident bytes (Max MEM accounting).
    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        self.offsets.len() * size_of::<usize>()
            + self.ids.len() * size_of::<u32>()
            + self.vals.len() * size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::means::update_means;
    use crate::sparse::build_dataset;

    fn small_means() -> (crate::sparse::Dataset, MeanSet) {
        let docs = vec![
            vec![(0, 3), (1, 1)],
            vec![(0, 2), (1, 2)],
            vec![(2, 3), (3, 1)],
            vec![(2, 2), (3, 2)],
            vec![(1, 1), (3, 1)],
            vec![(0, 1), (2, 1)],
        ];
        let ds = build_dataset("t", 4, &docs);
        let assign = vec![0, 0, 1, 1, 2, 2];
        let out = update_means(&ds, &assign, 3, None, None);
        (ds, out.means)
    }

    #[test]
    fn index_matches_means() {
        let (_, mut means) = small_means();
        means.moved = vec![true, false, true];
        let idx = InvIndex::build(&means, means.m.n_cols());
        // Every mean entry must appear exactly once.
        let mut total = 0;
        for s in 0..idx.t_lim() {
            let (ids, vals) = idx.postings(s);
            total += ids.len();
            for (&j, &v) in ids.iter().zip(vals) {
                let dense = means.m.row_dense(j as usize);
                assert_eq!(dense[s], v, "mismatch at term {s} mean {j}");
            }
            // moving block first
            let mfm = idx.mfm[s] as usize;
            for (q, &j) in ids.iter().enumerate() {
                let is_moving = means.moved[j as usize];
                assert_eq!(q < mfm, is_moving, "block ordering broken at {s}");
            }
            // ascending ids within each block
            assert!(ids[..mfm].windows(2).all(|w| w[0] < w[1]));
            assert!(ids[mfm..].windows(2).all(|w| w[0] < w[1]));
        }
        assert_eq!(total, means.m.nnz());
        assert_eq!(idx.moving_ids, vec![0, 2]);
    }

    #[test]
    fn partial_index_range() {
        let (_, means) = small_means();
        let idx = InvIndex::build(&means, 2); // only terms 0..2
        assert_eq!(idx.t_lim(), 2);
        let kept: usize = (0..2).map(|s| idx.mf(s)).sum();
        assert_eq!(kept, idx.nnz());
        let full = InvIndex::build(&means, 4);
        assert_eq!(idx.mf(0), full.mf(0));
        assert_eq!(idx.mf(1), full.mf(1));
    }

    #[test]
    fn build_scaled_folds_scaling() {
        let (_, mut means) = small_means();
        means.moved = vec![true, false, true];
        let raw = InvIndex::build(&means, 4);
        let scaled = InvIndex::build_scaled(&means, 4, 0.5);
        let (ro, ri, rv, rm) = raw.raw_parts();
        let (so, si, sv, sm) = scaled.raw_parts();
        assert_eq!(ro, so);
        assert_eq!(ri, si);
        assert_eq!(rm, sm);
        for (a, b) in rv.iter().zip(sv) {
            assert_eq!((a * 0.5).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dense_tail_mirrors_sparse_postings() {
        // Every cluster touches term 3 (the highest-df id), so its
        // tuple array is 100% full and joins the dense tail; term 2
        // lives in one cluster only (fill 1/3 < 3/4) and stays sparse.
        let docs = vec![
            vec![(0, 2), (3, 1)],
            vec![(1, 1), (3, 2)],
            vec![(2, 3), (3, 1)],
            vec![(2, 1), (3, 1)],
            vec![(0, 1), (3, 2)],
            vec![(1, 2), (3, 1)],
        ];
        let ds = build_dataset("t", 4, &docs);
        let assign = vec![0, 0, 1, 1, 2, 2];
        let mut out = update_means(&ds, &assign, 3, None, None);
        out.means.moved = vec![true, false, true];
        let idx = InvIndex::build(&out.means, 4);
        let (dense_lo, dense_w) = idx.dense_parts();
        assert_eq!(dense_lo, 3, "only the full term should be dense");
        // One row of `dense_stride` doubles: k rounded up to 8, with
        // +0.0 stride padding past the k meaningful values.
        assert_eq!(idx.dense_stride, 8);
        assert_eq!(dense_w.len(), idx.dense_stride);
        assert!(dense_w[idx.k..].iter().all(|&x| x.to_bits() == 0));
        // The aligned buffer puts every row on a 64-byte boundary.
        assert_eq!(dense_w.as_ptr() as usize % 64, 0);
        assert!(idx.dense_row(2).is_none());
        let row = idx.dense_row(3).expect("term 3 is in the dense block");
        // The dense row is the zero-padded mirror of the postings, and
        // gathering through it is bit-identical to the sparse scatter.
        let (ids, vals) = idx.postings(3);
        let mut scattered = vec![0.0f64; idx.k];
        crate::algo::kernel::scatter_add_scalar(&mut scattered, ids, vals, 1.7);
        let mut dense = vec![0.0f64; idx.k];
        crate::algo::kernel::dense_axpy(&mut dense, row, 1.7);
        for (a, b) in scattered.iter().zip(&dense) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // mult accounting is unchanged by the dense mirror.
        assert_eq!(idx.mf(3), ids.len());
    }

    #[test]
    fn mult_cost_sums_mf() {
        let (_, means) = small_means();
        let idx = InvIndex::build(&means, 4);
        let cost = idx.mult_cost_for(&[0, 3]);
        assert_eq!(cost, (idx.mf(0) + idx.mf(3)) as u64);
    }

    #[test]
    fn obj_index_roundtrip() {
        let (ds, _) = small_means();
        let full = ObjInvIndex::build(&ds.x, 0);
        assert_eq!(full.nnz(), ds.x.nnz());
        for s in 0..ds.d() {
            let (ids, vals) = full.postings(s);
            assert_eq!(ids.len(), full.df(s));
            for (&i, &v) in ids.iter().zip(vals) {
                let (ts, vs) = ds.x.row(i as usize);
                let pos = ts.iter().position(|&t| t as usize == s).unwrap();
                assert_eq!(vs[pos], v);
            }
            // df consistency with the dataset
            assert_eq!(full.df(s) as u32, ds.df[s]);
        }
    }

    #[test]
    fn obj_index_partial_range() {
        let (ds, _) = small_means();
        let part = ObjInvIndex::build(&ds.x, 2);
        let full = ObjInvIndex::build(&ds.x, 0);
        for s in 2..ds.d() {
            assert_eq!(part.postings(s), full.postings(s));
        }
        assert!(part.nnz() <= full.nnz());
    }
}
