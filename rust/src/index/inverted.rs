//! Inverted-index data structures over the *mean* set (Section II) and
//! over the object set (used by DIVI and by EstParams' partial object
//! index X^p, Appendix C).
//!
//! A mean-inverted index stores, for every term id `s`, the tuple array
//! `ξ_s = [(mean id c, feature value v)]` of centroids whose mean vector
//! is non-zero at `s` — `(mf)_s = |ξ_s|`. For the ICP filter the array is
//! arranged in two blocks, **moving centroids first** (Fig. 6), so the
//! moving-only scan is "iterate the first `(mfM)_s` entries": no
//! per-entry conditional branch, which is the AFM trick that keeps branch
//! mispredictions low.
//!
//! Storage is flat (CSC-like): one offsets array plus parallel `ids` /
//! `vals` arrays — no per-term `Vec` allocations on the hot path.
//!
//! Indexes are *persistent* across iterations: instead of rebuilding
//! from scratch each update step, [`crate::index::maintain`] splices
//! only the postings of centroids that moved (and those that just
//! became invariant) into the two-block layout — byte-identical to a
//! from-scratch build, at a cost proportional to the moved mass.

use crate::index::means::MeanSet;
use crate::sparse::CsrMatrix;

/// Mean-inverted index with the two-block (moving | invariant) layout.
///
/// Fields are `pub(crate)` so the incremental splice engine
/// ([`crate::index::maintain`]) can rebuild the flat arrays in place.
#[derive(Debug, Clone)]
pub struct InvIndex {
    pub d: usize,
    pub k: usize,
    pub(crate) offsets: Vec<usize>,
    pub(crate) ids: Vec<u32>,
    pub(crate) vals: Vec<f64>,
    /// `mfm[s]` — number of *moving* centroids in `ξ_s` (the first block).
    pub mfm: Vec<u32>,
    /// Moving centroid ids, ascending (the paper's j' → j map in G_1).
    pub moving_ids: Vec<u32>,
}

impl InvIndex {
    /// Build from a mean set. Only terms `s < t_lim` are indexed (pass
    /// `d` for a full index; ES/TA/CS pass `t_th` and store the
    /// `s ≥ t_th` region in their own specialized structures).
    pub fn build(means: &MeanSet, t_lim: usize) -> Self {
        Self::build_scaled(means, t_lim, 1.0)
    }

    /// [`InvIndex::build`] with the Appendix-A value scaling folded into
    /// construction: every stored value is `v · scale`, written once
    /// (the ES family passes `1 / v_th`; there is no separate
    /// scale-in-place post-pass).
    pub fn build_scaled(means: &MeanSet, t_lim: usize, scale: f64) -> Self {
        let d = means.m.n_cols();
        let k = means.k();
        let t_lim = t_lim.min(d);

        // Pass 1: count entries per (term, block).
        let mut cnt_mov = vec![0u32; t_lim];
        let mut cnt_inv = vec![0u32; t_lim];
        for j in 0..k {
            let (ts, _) = means.m.row(j);
            let moving = means.moved[j];
            for &t in ts {
                let t = t as usize;
                if t < t_lim {
                    if moving {
                        cnt_mov[t] += 1;
                    } else {
                        cnt_inv[t] += 1;
                    }
                }
            }
        }
        let mut offsets = vec![0usize; t_lim + 1];
        for s in 0..t_lim {
            offsets[s + 1] = offsets[s] + (cnt_mov[s] + cnt_inv[s]) as usize;
        }
        let nnz = offsets[t_lim];
        let mut ids = vec![0u32; nnz];
        let mut vals = vec![0.0f64; nnz];

        // Pass 2: fill. Iterating j ascending keeps ids ascending within
        // each block (deterministic layout).
        let mut cur_mov: Vec<usize> = (0..t_lim).map(|s| offsets[s]).collect();
        let mut cur_inv: Vec<usize> = (0..t_lim)
            .map(|s| offsets[s] + cnt_mov[s] as usize)
            .collect();
        for j in 0..k {
            let (ts, vs) = means.m.row(j);
            let moving = means.moved[j];
            for (&t, &v) in ts.iter().zip(vs) {
                let t = t as usize;
                if t < t_lim {
                    let slot = if moving {
                        let s = cur_mov[t];
                        cur_mov[t] += 1;
                        s
                    } else {
                        let s = cur_inv[t];
                        cur_inv[t] += 1;
                        s
                    };
                    ids[slot] = j as u32;
                    vals[slot] = v * scale;
                }
            }
        }

        let moving_ids: Vec<u32> = (0..k as u32).filter(|&j| means.moved[j as usize]).collect();
        Self {
            d,
            k,
            offsets,
            ids,
            vals,
            mfm: cnt_mov,
            moving_ids,
        }
    }

    /// Number of indexed terms (`t_lim` at build time).
    pub fn t_lim(&self) -> usize {
        self.offsets.len() - 1
    }

    /// `(mf)_s` — full array length for term `s`.
    #[inline]
    pub fn mf(&self, s: usize) -> usize {
        self.offsets[s + 1] - self.offsets[s]
    }

    /// Full tuple array `ξ_s` as `(ids, vals)` slices.
    #[inline]
    pub fn postings(&self, s: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.offsets[s], self.offsets[s + 1]);
        (&self.ids[a..b], &self.vals[a..b])
    }

    /// Moving-block prefix of `ξ_s` (the first `(mfM)_s` entries).
    #[inline]
    pub fn postings_moving(&self, s: usize) -> (&[u32], &[f64]) {
        let a = self.offsets[s];
        let b = a + self.mfm[s] as usize;
        (&self.ids[a..b], &self.vals[a..b])
    }

    /// Total stored tuples Σ_s (mf)_s.
    pub fn nnz(&self) -> usize {
        self.ids.len()
    }

    /// Σ_s over a row's terms of (mf)_s — the MIVI multiplication count
    /// for one object (Fig. 3(b) integrand).
    pub fn mult_cost_for(&self, terms: &[u32]) -> u64 {
        terms
            .iter()
            .filter(|&&t| (t as usize) < self.t_lim())
            .map(|&t| self.mf(t as usize) as u64)
            .sum()
    }

    /// The flat storage `(offsets, ids, vals, mfm)` — exposed so the
    /// incremental-maintenance equality suite can compare indexes
    /// bitwise (offsets/ids/mfm with `==`, vals via `f64::to_bits`).
    pub fn raw_parts(&self) -> (&[usize], &[u32], &[f64], &[u32]) {
        (&self.offsets, &self.ids, &self.vals, &self.mfm)
    }

    /// Approximate resident bytes (paper's Max MEM accounting).
    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        self.offsets.len() * size_of::<usize>()
            + self.ids.len() * size_of::<u32>()
            + self.vals.len() * size_of::<f64>()
            + self.mfm.len() * size_of::<u32>()
            + self.moving_ids.len() * size_of::<u32>()
    }
}

/// Object-inverted index: per term, the array `η_s = [(object id,
/// value)]`. Used by DIVI (Section II) over the whole vocabulary and by
/// EstParams as the partial index `X^p` over `s ≥ s_min` (Appendix C).
#[derive(Debug, Clone)]
pub struct ObjInvIndex {
    /// First indexed term id (0 for DIVI, `s_min` for X^p).
    pub s_lo: usize,
    pub d: usize,
    pub n: usize,
    offsets: Vec<usize>,
    ids: Vec<u32>,
    vals: Vec<f64>,
}

impl ObjInvIndex {
    pub fn build(x: &CsrMatrix, s_lo: usize) -> Self {
        let d = x.n_cols();
        let n = x.n_rows();
        assert!(s_lo <= d);
        let width = d - s_lo;
        let mut counts = vec![0u32; width];
        for (_, t, _) in x.iter() {
            let t = t as usize;
            if t >= s_lo {
                counts[t - s_lo] += 1;
            }
        }
        let mut offsets = vec![0usize; width + 1];
        for s in 0..width {
            offsets[s + 1] = offsets[s] + counts[s] as usize;
        }
        let nnz = offsets[width];
        let mut ids = vec![0u32; nnz];
        let mut vals = vec![0.0f64; nnz];
        let mut cur = offsets.clone();
        for (i, t, v) in x.iter() {
            let t = t as usize;
            if t >= s_lo {
                let slot = cur[t - s_lo];
                ids[slot] = i as u32;
                vals[slot] = v;
                cur[t - s_lo] += 1;
            }
        }
        Self {
            s_lo,
            d,
            n,
            offsets,
            ids,
            vals,
        }
    }

    /// Postings `(object ids, values)` for term `s` (`s ≥ s_lo`).
    #[inline]
    pub fn postings(&self, s: usize) -> (&[u32], &[f64]) {
        debug_assert!(s >= self.s_lo && s < self.d);
        let (a, b) = (self.offsets[s - self.s_lo], self.offsets[s - self.s_lo + 1]);
        (&self.ids[a..b], &self.vals[a..b])
    }

    /// Document frequency of term `s` within the indexed range.
    #[inline]
    pub fn df(&self, s: usize) -> usize {
        self.offsets[s - self.s_lo + 1] - self.offsets[s - self.s_lo]
    }

    pub fn nnz(&self) -> usize {
        self.ids.len()
    }

    /// Approximate resident bytes (Max MEM accounting).
    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        self.offsets.len() * size_of::<usize>()
            + self.ids.len() * size_of::<u32>()
            + self.vals.len() * size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::means::update_means;
    use crate::sparse::build_dataset;

    fn small_means() -> (crate::sparse::Dataset, MeanSet) {
        let docs = vec![
            vec![(0, 3), (1, 1)],
            vec![(0, 2), (1, 2)],
            vec![(2, 3), (3, 1)],
            vec![(2, 2), (3, 2)],
            vec![(1, 1), (3, 1)],
            vec![(0, 1), (2, 1)],
        ];
        let ds = build_dataset("t", 4, &docs);
        let assign = vec![0, 0, 1, 1, 2, 2];
        let out = update_means(&ds, &assign, 3, None, None);
        (ds, out.means)
    }

    #[test]
    fn index_matches_means() {
        let (_, mut means) = small_means();
        means.moved = vec![true, false, true];
        let idx = InvIndex::build(&means, means.m.n_cols());
        // Every mean entry must appear exactly once.
        let mut total = 0;
        for s in 0..idx.t_lim() {
            let (ids, vals) = idx.postings(s);
            total += ids.len();
            for (&j, &v) in ids.iter().zip(vals) {
                let dense = means.m.row_dense(j as usize);
                assert_eq!(dense[s], v, "mismatch at term {s} mean {j}");
            }
            // moving block first
            let mfm = idx.mfm[s] as usize;
            for (q, &j) in ids.iter().enumerate() {
                let is_moving = means.moved[j as usize];
                assert_eq!(q < mfm, is_moving, "block ordering broken at {s}");
            }
            // ascending ids within each block
            assert!(ids[..mfm].windows(2).all(|w| w[0] < w[1]));
            assert!(ids[mfm..].windows(2).all(|w| w[0] < w[1]));
        }
        assert_eq!(total, means.m.nnz());
        assert_eq!(idx.moving_ids, vec![0, 2]);
    }

    #[test]
    fn partial_index_range() {
        let (_, means) = small_means();
        let idx = InvIndex::build(&means, 2); // only terms 0..2
        assert_eq!(idx.t_lim(), 2);
        let kept: usize = (0..2).map(|s| idx.mf(s)).sum();
        assert_eq!(kept, idx.nnz());
        let full = InvIndex::build(&means, 4);
        assert_eq!(idx.mf(0), full.mf(0));
        assert_eq!(idx.mf(1), full.mf(1));
    }

    #[test]
    fn build_scaled_folds_scaling() {
        let (_, mut means) = small_means();
        means.moved = vec![true, false, true];
        let raw = InvIndex::build(&means, 4);
        let scaled = InvIndex::build_scaled(&means, 4, 0.5);
        let (ro, ri, rv, rm) = raw.raw_parts();
        let (so, si, sv, sm) = scaled.raw_parts();
        assert_eq!(ro, so);
        assert_eq!(ri, si);
        assert_eq!(rm, sm);
        for (a, b) in rv.iter().zip(sv) {
            assert_eq!((a * 0.5).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn mult_cost_sums_mf() {
        let (_, means) = small_means();
        let idx = InvIndex::build(&means, 4);
        let cost = idx.mult_cost_for(&[0, 3]);
        assert_eq!(cost, (idx.mf(0) + idx.mf(3)) as u64);
    }

    #[test]
    fn obj_index_roundtrip() {
        let (ds, _) = small_means();
        let full = ObjInvIndex::build(&ds.x, 0);
        assert_eq!(full.nnz(), ds.x.nnz());
        for s in 0..ds.d() {
            let (ids, vals) = full.postings(s);
            assert_eq!(ids.len(), full.df(s));
            for (&i, &v) in ids.iter().zip(vals) {
                let (ts, vs) = ds.x.row(i as usize);
                let pos = ts.iter().position(|&t| t as usize == s).unwrap();
                assert_eq!(vs[pos], v);
            }
            // df consistency with the dataset
            assert_eq!(full.df(s) as u32, ds.df[s]);
        }
    }

    #[test]
    fn obj_index_partial_range() {
        let (ds, _) = small_means();
        let part = ObjInvIndex::build(&ds.x, 2);
        let full = ObjInvIndex::build(&ds.x, 0);
        for s in 2..ds.d() {
            assert_eq!(part.postings(s), full.postings(s));
        }
        assert!(part.nnz() <= full.nnz());
    }
}
