//! Index structures: the update step producing the mean set, the
//! two-block mean-inverted index, the object-inverted index, the
//! three-region structured indexes for the ES / TA / CS filters, and
//! the incremental maintainers that splice those indexes across
//! iterations instead of rebuilding them from scratch.

pub mod inverted;
pub mod maintain;
pub mod means;
pub mod slab;
pub mod structured;

pub use inverted::{InvIndex, ObjInvIndex};
pub use maintain::{CsMaintainer, EsMaintainer, InvMaintainer, RebuildKind, TaMaintainer};
pub use means::{
    membership_changes, update_means, update_means_minibatch, update_means_minibatch_inplace,
    update_means_with_rho, update_means_with_rho_par, MbUpdateScratch, MeanSet, UpdateOutput,
};
pub use slab::RowSlab;
pub use structured::{CsIndex, EsIndex, PartialIndex, Region2, TaIndex};
