//! A spliceable row-slab store for the mean set.
//!
//! [`RowSlab`] keeps K sparse rows in one pair of arenas (`ids`, `vals`)
//! with a per-row span `{start, len, cap}`. Unlike [`CsrMatrix`], a row
//! can be **rewritten in place** without touching its neighbours: when
//! the new row fits the span's capacity it is copied over the old one;
//! when it does not, the row relocates to the arena tail with 1.5×+8
//! headroom and the old span's capacity is accounted as dead space.
//! Once dead space exceeds half the arena it is compacted by a
//! ping-pong copy into a spare buffer pair, so the arenas never grow
//! unboundedly and — once per-row capacities plateau — a steady-state
//! `set_row` performs **zero allocations**. This is what makes a
//! mini-batch round's mean update cost O(nnz of touched rows) instead
//! of the O(nnz(M)) full rebuild that `CsrMatrix::from_rows` pays.
//!
//! Reads mirror the [`CsrMatrix`] accessors the rest of the crate uses
//! on the mean matrix (`row`, `row_norm`, `row_dense`, `column_df`, …)
//! with identical semantics, and every whole-matrix iteration walks
//! rows in ascending row order so float reductions over the matrix are
//! bit-stable regardless of where rows physically live in the arena.
//! Equality is logical (same rows, same bits), independent of physical
//! layout. The persistence layer keeps its on-disk CSR format via
//! [`RowSlab::to_csr`] / [`RowSlab::from_csr`], which round-trip
//! bit-exactly.

use crate::sparse::CsrMatrix;

/// Physical location of one row inside the arenas.
#[derive(Debug, Clone, Copy)]
struct RowSpan {
    /// Offset of the row's first element in `ids` / `vals`.
    start: usize,
    /// Live length (the row's nnz).
    len: u32,
    /// Reserved capacity; `len <= cap` always, and the `cap - len` tail
    /// slots hold zeros so a relocation can `copy_from_slice` blindly.
    cap: u32,
}

/// K sparse rows with in-place row rewrites. See the module docs.
#[derive(Debug, Clone, Default)]
pub struct RowSlab {
    n_cols: usize,
    ids: Vec<u32>,
    vals: Vec<f64>,
    spans: Vec<RowSpan>,
    /// Σ span.len — kept so `nnz()` is O(1).
    live_nnz: usize,
    /// Σ cap of abandoned (relocated-away-from) spans.
    dead: usize,
    /// Ping-pong partners for [`Self::compact`]; empty between compactions
    /// but their capacity is retained, so steady-state compaction does
    /// not allocate.
    spare_ids: Vec<u32>,
    spare_vals: Vec<f64>,
}

/// Growth policy for relocated rows: 1.5× + 8 headroom, so a row whose
/// support oscillates settles into a capacity it stops outgrowing.
#[inline]
fn cap_for(len: usize) -> usize {
    len + len / 2 + 8
}

impl RowSlab {
    /// Build from per-row tuple lists — delegates to
    /// [`CsrMatrix::from_rows`] so sorting and duplicate-summing follow
    /// the exact float sequence every existing producer used.
    pub fn from_rows(n_cols: usize, rows: &[Vec<(u32, f64)>]) -> Self {
        Self::from_csr(&CsrMatrix::from_rows(n_cols, rows))
    }

    /// Tight-pack a CSR matrix (every span `cap == len`).
    pub fn from_csr(m: &CsrMatrix) -> Self {
        let (n_cols, indptr, indices, values) = m.raw_parts();
        let mut spans = Vec::with_capacity(m.n_rows());
        for r in 0..m.n_rows() {
            let len = (indptr[r + 1] - indptr[r]) as u32;
            spans.push(RowSpan {
                start: indptr[r],
                len,
                cap: len,
            });
        }
        Self {
            n_cols,
            ids: indices.to_vec(),
            vals: values.to_vec(),
            spans,
            live_nnz: indices.len(),
            dead: 0,
            spare_ids: Vec::new(),
            spare_vals: Vec::new(),
        }
    }

    /// Materialize as a CSR matrix (rows in ascending order, bit-exact
    /// values) — the persistence layer's bridge to the unchanged
    /// on-disk format.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(self.n_rows() + 1);
        let mut indices = Vec::with_capacity(self.live_nnz);
        let mut values = Vec::with_capacity(self.live_nnz);
        indptr.push(0);
        for j in 0..self.n_rows() {
            let (ts, vs) = self.row(j);
            indices.extend_from_slice(ts);
            values.extend_from_slice(vs);
            indptr.push(indices.len());
        }
        CsrMatrix::from_raw(self.n_cols, indptr, indices, values)
    }

    /// Become a tight-packed copy of `other`, reusing this slab's arena
    /// capacity (the maintainers' `set_from` idiom: steady-state
    /// allocation-free once capacities have plateaued).
    pub fn set_from(&mut self, other: &RowSlab) {
        self.n_cols = other.n_cols;
        self.ids.clear();
        self.vals.clear();
        self.spans.clear();
        self.ids.reserve(other.live_nnz);
        self.vals.reserve(other.live_nnz);
        self.spans.reserve(other.spans.len());
        for j in 0..other.n_rows() {
            let (ts, vs) = other.row(j);
            let start = self.ids.len();
            self.ids.extend_from_slice(ts);
            self.vals.extend_from_slice(vs);
            self.spans.push(RowSpan {
                start,
                len: ts.len() as u32,
                cap: ts.len() as u32,
            });
        }
        self.live_nnz = self.ids.len();
        self.dead = 0;
    }

    /// Rewrite row `j` with sorted-unique `(ids, vals)`. In place when
    /// the new row fits the span's capacity; otherwise the row relocates
    /// to the arena tail (with headroom) and the arena is compacted
    /// once dead space dominates. Other rows' bits are never touched.
    pub fn set_row(&mut self, j: usize, ids: &[u32], vals: &[f64]) {
        debug_assert_eq!(ids.len(), vals.len());
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "row {j} not sorted");
        debug_assert!(ids.iter().all(|&t| (t as usize) < self.n_cols));
        let len = ids.len();
        let sp = self.spans[j];
        self.live_nnz = self.live_nnz - sp.len as usize + len;
        if len <= sp.cap as usize {
            let s = sp.start;
            self.ids[s..s + len].copy_from_slice(ids);
            self.vals[s..s + len].copy_from_slice(vals);
            // Zero the shrunk tail so a future relocation of this span
            // can be copied blindly and the arena holds no stale bits.
            for slot in &mut self.vals[s + len..s + sp.len as usize] {
                *slot = 0.0;
            }
            self.spans[j].len = len as u32;
            return;
        }
        self.dead += sp.cap as usize;
        let cap = cap_for(len);
        let start = self.ids.len();
        self.ids.extend_from_slice(ids);
        self.vals.extend_from_slice(vals);
        self.ids.resize(start + cap, 0);
        self.vals.resize(start + cap, 0.0);
        self.spans[j] = RowSpan {
            start,
            len: len as u32,
            cap: cap as u32,
        };
        // Compact only after the relocation so every span (including
        // row j's new one) is valid while copying.
        if self.dead > self.ids.len() / 2 && self.dead > 64 {
            self.compact();
        }
    }

    /// Squeeze dead space out by a ping-pong copy into the spare
    /// buffers, preserving each span's capacity (so the no-relocation
    /// steady state survives compaction).
    fn compact(&mut self) {
        let mut ids = std::mem::take(&mut self.spare_ids);
        let mut vals = std::mem::take(&mut self.spare_vals);
        ids.clear();
        vals.clear();
        let total: usize = self.spans.iter().map(|s| s.cap as usize).sum();
        ids.reserve(total);
        vals.reserve(total);
        for sp in &mut self.spans {
            let (s, len, cap) = (sp.start, sp.len as usize, sp.cap as usize);
            let start = ids.len();
            ids.extend_from_slice(&self.ids[s..s + len]);
            vals.extend_from_slice(&self.vals[s..s + len]);
            ids.resize(start + cap, 0);
            vals.resize(start + cap, 0.0);
            sp.start = start;
        }
        self.spare_ids = std::mem::replace(&mut self.ids, ids);
        self.spare_vals = std::mem::replace(&mut self.vals, vals);
        self.dead = 0;
    }

    pub fn n_rows(&self) -> usize {
        self.spans.len()
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Total live non-zeros (O(1): dead arena space is excluded).
    pub fn nnz(&self) -> usize {
        self.live_nnz
    }

    #[inline]
    pub fn row_nnz(&self, j: usize) -> usize {
        self.spans[j].len as usize
    }

    /// Row `j` as parallel slices `(term ids, values)`.
    #[inline]
    pub fn row(&self, j: usize) -> (&[u32], &[f64]) {
        let sp = self.spans[j];
        let (s, e) = (sp.start, sp.start + sp.len as usize);
        (&self.ids[s..e], &self.vals[s..e])
    }

    /// L2 norm of row `j`.
    pub fn row_norm(&self, j: usize) -> f64 {
        let (_, vs) = self.row(j);
        vs.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Densify row `j` (test/oracle helper, like [`CsrMatrix::row_dense`]).
    pub fn row_dense(&self, j: usize) -> Vec<f64> {
        let mut d = vec![0.0; self.n_cols];
        let (ts, vs) = self.row(j);
        for (&t, &v) in ts.iter().zip(vs) {
            d[t as usize] = v;
        }
        d
    }

    /// Average row nnz — the paper's `D̂` over the mean set.
    pub fn avg_row_nnz(&self) -> f64 {
        if self.n_rows() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_rows() as f64
        }
    }

    /// Rows containing each column — the mean frequency `(mf)_t`.
    /// Ascending row order, like the CSR version.
    pub fn column_df(&self) -> Vec<u32> {
        let mut df = vec![0u32; self.n_cols];
        for j in 0..self.n_rows() {
            let (ts, _) = self.row(j);
            for &t in ts {
                df[t as usize] += 1;
            }
        }
        df
    }

    /// Per-column value sums, accumulated in ascending row order so the
    /// float sequence is independent of physical arena layout.
    pub fn column_sum(&self) -> Vec<f64> {
        let mut s = vec![0.0; self.n_cols];
        for j in 0..self.n_rows() {
            let (ts, vs) = self.row(j);
            for (&t, &v) in ts.iter().zip(vs) {
                s[t as usize] += v;
            }
        }
        s
    }

    /// Resident bytes (arenas at capacity, spans, spare buffers).
    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        (self.ids.capacity() + self.spare_ids.capacity()) * size_of::<u32>()
            + (self.vals.capacity() + self.spare_vals.capacity()) * size_of::<f64>()
            + self.spans.capacity() * size_of::<RowSpan>()
    }
}

/// Logical equality: same shape and the same row bits, regardless of
/// where rows live in the arena — so a spliced slab compares equal to a
/// from-scratch rebuild with identical contents.
impl PartialEq for RowSlab {
    fn eq(&self, other: &Self) -> bool {
        self.n_cols == other.n_cols
            && self.spans.len() == other.spans.len()
            && (0..self.spans.len()).all(|j| {
                let (ta, va) = self.row(j);
                let (tb, vb) = other.row(j);
                ta == tb && va == vb
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RowSlab {
        RowSlab::from_rows(
            5,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![],
                vec![(2, 1.0), (4, 1.0), (0, 4.0)],
            ],
        )
    }

    #[test]
    fn mirrors_csr_reads() {
        let s = sample();
        let c = s.to_csr();
        assert_eq!(s.n_rows(), 4);
        assert_eq!(s.n_cols(), 5);
        assert_eq!(s.nnz(), 6);
        for j in 0..4 {
            assert_eq!(s.row(j), c.row(j));
            assert_eq!(s.row_nnz(j), c.row_nnz(j));
            assert_eq!(s.row_norm(j).to_bits(), c.row_norm(j).to_bits());
            assert_eq!(s.row_dense(j), c.row_dense(j));
        }
        assert_eq!(s.column_df(), c.column_df());
        assert_eq!(s.column_sum(), c.column_sum());
        assert_eq!(s.avg_row_nnz(), c.avg_row_nnz());
    }

    #[test]
    fn csr_round_trip_is_identity() {
        let s = sample();
        assert_eq!(RowSlab::from_csr(&s.to_csr()), s);
    }

    #[test]
    fn in_place_rewrite_keeps_other_rows() {
        let mut s = sample();
        let before3 = (s.row(3).0.to_vec(), s.row(3).1.to_vec());
        // Same length: fits the tight-packed span.
        s.set_row(0, &[1, 3], &[0.5, 0.5]);
        assert_eq!(s.row(0), (&[1u32, 3][..], &[0.5, 0.5][..]));
        // Shrink: also in place.
        s.set_row(0, &[4], &[1.0]);
        assert_eq!(s.row(0), (&[4u32][..], &[1.0][..]));
        assert_eq!(s.nnz(), 5);
        assert_eq!(s.row(3), (&before3.0[..], &before3.1[..]));
    }

    #[test]
    fn growth_relocates_and_compaction_preserves_rows() {
        let mut s = RowSlab::from_rows(64, &vec![vec![(0, 1.0)]; 8]);
        // Repeatedly grow/shrink every row well past the compaction
        // threshold; contents must always match a scratch rebuild.
        for round in 0..40usize {
            let mut rows: Vec<Vec<(u32, f64)>> = Vec::new();
            for j in 0..8usize {
                let len = 1 + (round * 7 + j * 3) % 13;
                let row: Vec<(u32, f64)> = (0..len)
                    .map(|t| ((t * 4 + j) as u32, (round + t + 1) as f64))
                    .collect();
                s.set_row(j, &row.iter().map(|p| p.0).collect::<Vec<_>>(),
                          &row.iter().map(|p| p.1).collect::<Vec<_>>());
                rows.push(row);
            }
            let want = RowSlab::from_rows(64, &rows);
            assert_eq!(s, want, "round {round}");
            assert_eq!(s.nnz(), want.nnz(), "round {round}");
        }
        // Dead space is bounded by the compaction policy.
        assert!(s.dead <= (s.ids.len() / 2).max(64));
    }

    #[test]
    fn steady_state_set_row_reuses_capacity() {
        let mut s = RowSlab::from_rows(32, &vec![vec![(0, 1.0), (1, 1.0)]; 4]);
        // Warm up: grow each row so capacities plateau.
        for j in 0..4 {
            s.set_row(j, &[0, 1, 2, 3], &[1.0; 4]);
        }
        let (ic, vc) = (s.ids.capacity(), s.vals.capacity());
        for round in 0..100 {
            for j in 0..4 {
                let v = round as f64;
                s.set_row(j, &[0, 1, 2, 3], &[v, v, v, v]);
            }
        }
        assert_eq!(s.ids.capacity(), ic, "arena regrew in steady state");
        assert_eq!(s.vals.capacity(), vc, "arena regrew in steady state");
    }

    #[test]
    fn set_from_copies_and_reuses() {
        let a = sample();
        let mut b = RowSlab::from_rows(5, &[vec![], vec![], vec![], vec![]]);
        b.set_from(&a);
        assert_eq!(a, b);
        // Mutating the copy leaves the source untouched.
        b.set_row(1, &[0], &[9.0]);
        assert_eq!(a.row(1), (&[1u32][..], &[3.0][..]));
    }

    #[test]
    fn logical_eq_ignores_physical_layout() {
        let a = sample();
        let mut b = sample();
        // Force row 0 through a relocation (longer, then back).
        b.set_row(0, &[0, 1, 2, 3, 4], &[1.0; 5]);
        b.set_row(0, &[0, 2], &[1.0, 2.0]);
        assert_eq!(a, b);
        b.set_row(0, &[0, 2], &[1.0, 2.5]);
        assert_ne!(a, b);
    }
}
