//! The three-region structured mean-inverted indexes (Section IV-A,
//! Fig. 5/6) for the ES, TA, and CS main filters.
//!
//! All three share the Region-1 `InvIndex` over terms `s < t_th` (two
//! blocks, moving first). They differ in how the high-df region
//! `t_th ≤ s < D` is organized:
//!
//! * **ES** (`EsIndex`): Region 2 keeps only tuples with `v ≥ v_th`
//!   (arranged moving-high | invariant-high); Region-3 values live in the
//!   *partial mean-inverted index* `M^p` — a full-expression
//!   `(D − t_th) × K` matrix of values `< v_th` (0 elsewhere) addressed
//!   by centroid id. Values are **scaled** by `1 / v_th` (and object
//!   values by `v_th`, Appendix A) so the Region-3 upper bound is a pure
//!   addition `ρ_j + y_(i,j)`.
//! * **TA** (`TaIndex`): the `s ≥ t_th` arrays are sorted in descending
//!   feature value (threshold-algorithm order), with an *additional*
//!   moving-only sorted copy for the ICP combination; the partial index
//!   holds **all** values (the filter threshold is per object, so nothing
//!   can be pre-split).
//! * **CS** (`CsIndex`): the `s ≥ t_th` arrays store *squared* values
//!   (for the on-the-fly partial L2 norms of Eq. 21), two-block like
//!   Region 1; the partial index holds all values.
//!
//! **Lifecycle (§Perf).** The `build` constructors here are the
//! from-scratch reference path. In the clustering loop the structured
//! indexes persist across iterations and are maintained *incrementally*
//! by [`crate::index::maintain`]: only the postings of centroids that
//! moved (or just became invariant) are spliced, and only moved
//! centroids' columns of the partial index are rewritten — byte-identical
//! to a from-scratch build by construction, with the from-scratch path
//! kept as the fallback whenever `(t_th, v_th)` change (EstParams).

use crate::index::inverted::InvIndex;
use crate::index::means::MeanSet;

/// Flat per-term arrays over the high-df region `t_th ≤ s < D`.
///
/// Offsets are `u32`, like [`InvIndex`]'s (the compact-layout argument
/// in [`crate::index::inverted`]'s module docs); construction asserts
/// the nnz bound.
///
/// Fields are `pub(crate)` so the incremental splice engine
/// ([`crate::index::maintain`]) can rebuild the flat arrays in place.
#[derive(Debug, Clone, Default)]
pub struct Region2 {
    pub t_th: usize,
    pub(crate) offsets: Vec<u32>,
    pub(crate) ids: Vec<u32>,
    pub(crate) vals: Vec<f64>,
    /// Moving-block length per term (counts only stored entries).
    pub mfm: Vec<u32>,
}

impl Region2 {
    #[inline]
    pub fn len(&self, s: usize) -> usize {
        let i = s - self.t_th;
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    #[inline]
    pub fn postings(&self, s: usize) -> (&[u32], &[f64]) {
        let i = s - self.t_th;
        let (a, b) = (self.offsets[i] as usize, self.offsets[i + 1] as usize);
        (&self.ids[a..b], &self.vals[a..b])
    }

    #[inline]
    pub fn postings_moving(&self, s: usize) -> (&[u32], &[f64]) {
        let i = s - self.t_th;
        let a = self.offsets[i] as usize;
        let b = a + self.mfm[i] as usize;
        (&self.ids[a..b], &self.vals[a..b])
    }

    pub fn nnz(&self) -> usize {
        self.ids.len()
    }

    /// The flat storage `(offsets, ids, vals, mfm)` for the bitwise
    /// incremental-vs-scratch equality suite.
    pub fn raw_parts(&self) -> (&[u32], &[u32], &[f64], &[u32]) {
        (&self.offsets, &self.ids, &self.vals, &self.mfm)
    }

    pub fn mem_bytes(&self) -> usize {
        use std::mem::size_of;
        self.offsets.len() * size_of::<u32>()
            + self.ids.len() * size_of::<u32>()
            + self.vals.len() * size_of::<f64>()
            + self.mfm.len() * size_of::<u32>()
    }
}

/// Full-expression partial mean-inverted index `M^p` (Table III): one
/// dense K-length row of values per term in `t_th ≤ s < D`, directly
/// addressable by centroid id at the verification phase.
#[derive(Debug, Clone, Default)]
pub struct PartialIndex {
    pub t_th: usize,
    pub k: usize,
    pub(crate) w: Vec<f64>,
}

impl PartialIndex {
    #[inline]
    pub fn row(&self, s: usize) -> &[f64] {
        let i = (s - self.t_th) * self.k;
        &self.w[i..i + self.k]
    }

    /// The full dense value array (row-major per term) for the bitwise
    /// incremental-vs-scratch equality suite.
    pub fn values(&self) -> &[f64] {
        &self.w
    }

    /// Memory footprint — the paper's
    /// `K · (D − t_th + 1) · sizeof(double)` accounting (Section IV-A).
    pub fn mem_bytes(&self) -> usize {
        self.w.len() * std::mem::size_of::<f64>()
    }
}

/// Structured index for the ES filter (the proposed algorithm).
///
/// **Folded representation (§Perf).** Beyond the paper's Appendix-A
/// scaling, this implementation folds the per-centroid remaining-mass
/// accumulator `y_(i,j)` into ρ itself:
///
/// * the ρ accumulator is initialized to `y_base = Σ_{s ≥ t_th} u'_s`
///   instead of 0;
/// * Region-2 entries store `v/v_th − 1`, so one multiply-add both adds
///   the exact partial similarity and retires the upper-bound mass;
/// * the ES filter is then the bare comparison `ρ_j > ρ_max` — no
///   addition, no second array (fewer instructions *and* half the
///   accumulator cache traffic than the paper's formulation);
/// * the partial index stores **deficits** `1 − v/(v_th)` (1 where the
///   mean is zero, 0 for Region-2 entries), so the verification phase
///   *subtracts* `u'·deficit` and ρ lands exactly on the similarity.
#[derive(Debug, Clone)]
pub struct EsIndex {
    /// Region 1 (`s < t_th`), two-block, values scaled by `1/v_th`.
    pub r1: InvIndex,
    /// Region 2 (`s ≥ t_th`, `v ≥ v_th` only), two-block, storing
    /// `v/v_th − 1` (folded form, see above).
    pub r2: Region2,
    /// Region-3 deficits `1 − v/v_th` (0 for Region-2 entries), full
    /// expression.
    pub partial: PartialIndex,
    pub t_th: usize,
    pub v_th: f64,
    pub moving_ids: Vec<u32>,
    pub k: usize,
    pub d: usize,
}

impl EsIndex {
    /// Build from a mean set given the structural parameters. All stored
    /// feature values are divided by `v_th` (Appendix-A scaling; pass
    /// `v_th = 1.0` to disable, e.g. for the ThT ablation).
    pub fn build(means: &MeanSet, t_th: usize, v_th: f64) -> Self {
        let d = means.m.n_cols();
        let k = means.k();
        let t_th = t_th.min(d);
        assert!(v_th > 0.0, "v_th must be positive (got {v_th})");
        let inv_scale = 1.0 / v_th;

        // Region-1 values are scaled during construction (exact partial
        // similarities in the scaled domain): each value is written
        // exactly once — no scale-in-place post-pass.
        let r1 = InvIndex::build_scaled(means, t_th, inv_scale);

        let width = d - t_th;
        // Pass 1: counts.
        let mut cnt_mov = vec![0u32; width];
        let mut cnt_inv = vec![0u32; width];
        for j in 0..k {
            let (ts, vs) = means.m.row(j);
            let moving = means.moved[j];
            for (&t, &v) in ts.iter().zip(vs) {
                let t = t as usize;
                if t >= t_th && v >= v_th {
                    if moving {
                        cnt_mov[t - t_th] += 1;
                    } else {
                        cnt_inv[t - t_th] += 1;
                    }
                }
            }
        }
        let mut offsets = vec![0u32; width + 1];
        let mut acc = 0usize;
        for i in 0..width {
            acc += (cnt_mov[i] + cnt_inv[i]) as usize;
            offsets[i + 1] = acc as u32;
        }
        assert!(
            acc <= u32::MAX as usize,
            "region-2 nnz {acc} overflows the u32 offset layout"
        );
        let nnz = acc;
        let mut ids = vec![0u32; nnz];
        let mut vals = vec![0.0f64; nnz];
        // Deficit default 1.0: a term where a mean has no value carries
        // its full upper-bound mass to be retired at verification.
        let mut w = vec![1.0f64; width * k];
        let mut cur_mov: Vec<usize> = (0..width).map(|i| offsets[i] as usize).collect();
        let mut cur_inv: Vec<usize> = (0..width)
            .map(|i| offsets[i] as usize + cnt_mov[i] as usize)
            .collect();
        for j in 0..k {
            let (ts, vs) = means.m.row(j);
            let moving = means.moved[j];
            for (&t, &v) in ts.iter().zip(vs) {
                let t = t as usize;
                if t >= t_th {
                    let i = t - t_th;
                    if v >= v_th {
                        let slot = if moving {
                            let s = cur_mov[i];
                            cur_mov[i] += 1;
                            s
                        } else {
                            let s = cur_inv[i];
                            cur_inv[i] += 1;
                            s
                        };
                        ids[slot] = j as u32;
                        // Folded form: the multiply-add u'·(v' − 1) both
                        // accumulates the exact partial similarity and
                        // retires the bound mass.
                        vals[slot] = v * inv_scale - 1.0;
                        // Region-2 entry: nothing left to retire.
                        w[i * k + j] = 0.0;
                    } else {
                        // Region 3: deficit 1 − v/v_th (Table III's w,
                        // folded).
                        w[i * k + j] = 1.0 - v * inv_scale;
                    }
                }
            }
        }

        let moving_ids = r1.moving_ids.clone();
        Self {
            r1,
            r2: Region2 {
                t_th,
                offsets,
                ids,
                vals,
                mfm: cnt_mov,
            },
            partial: PartialIndex { t_th, k, w },
            t_th,
            v_th,
            moving_ids,
            k,
            d,
        }
    }

    /// `(mfH)_s` — kept (high-value) entries at term `s ≥ t_th`.
    #[inline]
    pub fn mfh(&self, s: usize) -> usize {
        self.r2.len(s)
    }

    pub fn mem_bytes(&self) -> usize {
        self.r1.mem_bytes() + self.r2.mem_bytes() + self.partial.mem_bytes()
    }
}

/// Structured index for the TA (threshold-algorithm) filter, Appendix F-A.
#[derive(Debug, Clone)]
pub struct TaIndex {
    /// Region 1 two-block index (`s < t_th`), unscaled.
    pub r1: InvIndex,
    /// `s ≥ t_th` arrays sorted descending by value — all centroids.
    pub r2_all: Region2,
    /// Additional moving-only sorted arrays (for `G_(ta)1`).
    pub r2_moving: Region2,
    /// Full-expression partial index with **all** values (w′ in Alg 8).
    pub partial: PartialIndex,
    pub t_th: usize,
    pub moving_ids: Vec<u32>,
    pub k: usize,
    pub d: usize,
}

impl TaIndex {
    pub fn build(means: &MeanSet, t_th: usize) -> Self {
        let d = means.m.n_cols();
        let k = means.k();
        let t_th = t_th.min(d);
        let r1 = InvIndex::build(means, t_th);
        let width = d - t_th;

        // Gather per-term tuple lists for the high region, then sort each
        // descending by value (the TA posting-list order).
        let mut per_term: Vec<Vec<(u32, f64)>> = vec![Vec::new(); width];
        let mut w = vec![0.0f64; width * k];
        for j in 0..k {
            let (ts, vs) = means.m.row(j);
            for (&t, &v) in ts.iter().zip(vs) {
                let t = t as usize;
                if t >= t_th {
                    per_term[t - t_th].push((j as u32, v));
                    w[(t - t_th) * k + j] = v;
                }
            }
        }
        let sort_desc = |list: &mut Vec<(u32, f64)>| {
            list.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        };
        let flatten = |lists: &[Vec<(u32, f64)>]| -> Region2 {
            let mut offsets = vec![0u32; lists.len() + 1];
            let mut acc = 0usize;
            for (i, l) in lists.iter().enumerate() {
                acc += l.len();
                offsets[i + 1] = acc as u32;
            }
            assert!(
                acc <= u32::MAX as usize,
                "TA region nnz {acc} overflows the u32 offset layout"
            );
            let mut ids = Vec::with_capacity(acc);
            let mut vals = Vec::with_capacity(acc);
            for l in lists {
                for &(j, v) in l {
                    ids.push(j);
                    vals.push(v);
                }
            }
            Region2 {
                t_th,
                offsets,
                ids,
                vals,
                mfm: vec![0; lists.len()], // not used for TA ordering
            }
        };

        let mut all = per_term.clone();
        for l in &mut all {
            sort_desc(l);
        }
        let mut moving: Vec<Vec<(u32, f64)>> = per_term
            .into_iter()
            .map(|l| {
                l.into_iter()
                    .filter(|&(j, _)| means.moved[j as usize])
                    .collect()
            })
            .collect();
        for l in &mut moving {
            sort_desc(l);
        }

        let moving_ids = r1.moving_ids.clone();
        Self {
            r1,
            r2_all: flatten(&all),
            r2_moving: flatten(&moving),
            partial: PartialIndex { t_th, k, w },
            t_th,
            moving_ids,
            k,
            d,
        }
    }

    pub fn mem_bytes(&self) -> usize {
        self.r1.mem_bytes()
            + self.r2_all.mem_bytes()
            + self.r2_moving.mem_bytes()
            + self.partial.mem_bytes()
    }
}

/// Structured index for the CS (Cauchy–Schwarz) filter, Appendix F-B.
#[derive(Debug, Clone)]
pub struct CsIndex {
    /// Region 1 two-block index (`s < t_th`), unscaled.
    pub r1: InvIndex,
    /// `s ≥ t_th` arrays of (id, value²), two-block moving-first — the
    /// partial squared-mean-inverted index `M^p_sq` of Alg 10.
    pub r2_sq: Region2,
    /// Full-expression partial index with all values (verification).
    pub partial: PartialIndex,
    pub t_th: usize,
    pub moving_ids: Vec<u32>,
    pub k: usize,
    pub d: usize,
}

impl CsIndex {
    pub fn build(means: &MeanSet, t_th: usize) -> Self {
        let d = means.m.n_cols();
        let k = means.k();
        let t_th = t_th.min(d);
        let r1 = InvIndex::build(means, t_th);
        let width = d - t_th;

        let mut cnt_mov = vec![0u32; width];
        let mut cnt_inv = vec![0u32; width];
        for j in 0..k {
            let (ts, _) = means.m.row(j);
            let moving = means.moved[j];
            for &t in ts {
                let t = t as usize;
                if t >= t_th {
                    if moving {
                        cnt_mov[t - t_th] += 1;
                    } else {
                        cnt_inv[t - t_th] += 1;
                    }
                }
            }
        }
        let mut offsets = vec![0u32; width + 1];
        let mut acc = 0usize;
        for i in 0..width {
            acc += (cnt_mov[i] + cnt_inv[i]) as usize;
            offsets[i + 1] = acc as u32;
        }
        assert!(
            acc <= u32::MAX as usize,
            "CS region nnz {acc} overflows the u32 offset layout"
        );
        let nnz = acc;
        let mut ids = vec![0u32; nnz];
        let mut vals = vec![0.0f64; nnz];
        let mut w = vec![0.0f64; width * k];
        let mut cur_mov: Vec<usize> = (0..width).map(|i| offsets[i] as usize).collect();
        let mut cur_inv: Vec<usize> = (0..width)
            .map(|i| offsets[i] as usize + cnt_mov[i] as usize)
            .collect();
        for j in 0..k {
            let (ts, vs) = means.m.row(j);
            let moving = means.moved[j];
            for (&t, &v) in ts.iter().zip(vs) {
                let t = t as usize;
                if t >= t_th {
                    let i = t - t_th;
                    let slot = if moving {
                        let s = cur_mov[i];
                        cur_mov[i] += 1;
                        s
                    } else {
                        let s = cur_inv[i];
                        cur_inv[i] += 1;
                        s
                    };
                    ids[slot] = j as u32;
                    vals[slot] = v * v; // squared value (Eq. 21)
                    w[i * k + j] = v;
                }
            }
        }

        let moving_ids = r1.moving_ids.clone();
        Self {
            r1,
            r2_sq: Region2 {
                t_th,
                offsets,
                ids,
                vals,
                mfm: cnt_mov,
            },
            partial: PartialIndex { t_th, k, w },
            t_th,
            moving_ids,
            k,
            d,
        }
    }

    pub fn mem_bytes(&self) -> usize {
        self.r1.mem_bytes() + self.r2_sq.mem_bytes() + self.partial.mem_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::means::update_means;
    use crate::sparse::build_dataset;

    fn means_with_moves() -> (crate::sparse::Dataset, MeanSet) {
        let docs = vec![
            vec![(0, 3), (1, 1), (4, 2)],
            vec![(0, 2), (1, 2), (5, 1)],
            vec![(2, 3), (3, 1), (4, 1)],
            vec![(2, 2), (3, 2), (5, 2)],
            vec![(1, 1), (3, 1), (5, 3)],
            vec![(0, 1), (2, 1), (4, 4)],
        ];
        let ds = build_dataset("t", 6, &docs);
        let assign = vec![0, 0, 1, 1, 2, 2];
        let mut out = update_means(&ds, &assign, 3, None, None);
        out.means.moved = vec![true, false, true];
        (ds, out.means)
    }

    /// Reconstruct every mean value reachable through an EsIndex and check
    /// it matches the mean set (after unscaling).
    #[test]
    fn es_index_partition_is_complete_and_exclusive() {
        let (_, means) = means_with_moves();
        let d = means.m.n_cols();
        let k = means.k();
        for t_th in [0usize, d / 2, d] {
            let v_th = 0.2;
            let idx = EsIndex::build(&means, t_th, v_th);
            let mut seen = vec![vec![0.0f64; d]; k];
            let mut in_r2 = vec![vec![false; d]; k];
            for s in 0..t_th {
                let (ids, vals) = idx.r1.postings(s);
                for (&j, &v) in ids.iter().zip(vals) {
                    seen[j as usize][s] += v * v_th;
                }
            }
            for s in t_th..d {
                let (ids, vals) = idx.r2.postings(s);
                for (&j, &v) in ids.iter().zip(vals) {
                    // Folded storage: v = value/v_th − 1.
                    let unscaled = (v + 1.0) * v_th;
                    assert!(
                        unscaled >= v_th - 1e-12,
                        "region-2 entry below threshold"
                    );
                    seen[j as usize][s] += unscaled;
                    in_r2[j as usize][s] = true;
                }
                let row = idx.partial.row(s);
                for (j, &deficit) in row.iter().enumerate() {
                    if in_r2[j][s] {
                        assert_eq!(deficit, 0.0, "region-2 entry must have 0 deficit");
                        continue;
                    }
                    // deficit = 1 − value/v_th; 1.0 ⇔ mean is zero here.
                    let unscaled = (1.0 - deficit) * v_th;
                    assert!(unscaled < v_th + 1e-12, "region-3 entry above threshold");
                    seen[j][s] += unscaled;
                }
            }
            for j in 0..k {
                let dense = means.m.row_dense(j);
                for s in 0..d {
                    assert!(
                        (seen[j][s] - dense[s]).abs() < 1e-9,
                        "t_th={t_th} mean {j} term {s}: {} vs {}",
                        seen[j][s],
                        dense[s]
                    );
                }
            }
        }
    }

    #[test]
    fn es_region2_moving_block_first() {
        let (_, means) = means_with_moves();
        let d = means.m.n_cols();
        let idx = EsIndex::build(&means, d / 2, 0.05);
        for s in d / 2..d {
            let (ids, _) = idx.r2.postings(s);
            let mfm = idx.r2.mfm[s - d / 2] as usize;
            for (q, &j) in ids.iter().enumerate() {
                assert_eq!(q < mfm, means.moved[j as usize]);
            }
        }
    }

    #[test]
    fn ta_index_sorted_descending() {
        let (_, means) = means_with_moves();
        let d = means.m.n_cols();
        let idx = TaIndex::build(&means, d / 2);
        for s in d / 2..d {
            let (_, vals) = idx.r2_all.postings(s);
            assert!(vals.windows(2).all(|w| w[0] >= w[1]), "not sorted at {s}");
            let (mids, mvals) = idx.r2_moving.postings(s);
            assert!(mvals.windows(2).all(|w| w[0] >= w[1]));
            assert!(mids.iter().all(|&j| means.moved[j as usize]));
        }
        // partial index holds all values
        let total: usize = (d / 2..d)
            .map(|s| idx.partial.row(s).iter().filter(|&&v| v != 0.0).count())
            .sum();
        let expected: usize = (d / 2..d).map(|s| idx.r2_all.len(s)).sum();
        assert_eq!(total, expected);
    }

    #[test]
    fn cs_index_squares_values() {
        let (_, means) = means_with_moves();
        let d = means.m.n_cols();
        let idx = CsIndex::build(&means, d / 2);
        for s in d / 2..d {
            let (ids, sq) = idx.r2_sq.postings(s);
            for (&j, &vsq) in ids.iter().zip(sq) {
                let dense = means.m.row_dense(j as usize);
                assert!((vsq - dense[s] * dense[s]).abs() < 1e-12);
                assert!((idx.partial.row(s)[j as usize] - dense[s]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn memory_accounting_nonzero() {
        let (_, means) = means_with_moves();
        let d = means.m.n_cols();
        let es = EsIndex::build(&means, d / 2, 0.1);
        assert!(es.mem_bytes() > 0);
        assert_eq!(es.partial.mem_bytes(), (d - d / 2) * means.k() * 8);
    }
}
