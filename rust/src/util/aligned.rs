//! Cache-line-aligned `f64` storage for the dense Region-1 tail block.
//!
//! The SIMD gather kernels ([`crate::algo::kernel`]) read the dense
//! tail rows with 256/512-bit vector loads. Correctness never depends
//! on alignment — the kernels use unaligned-load intrinsics throughout —
//! but keeping every row on a 64-byte boundary means those loads never
//! split a cache line, which is the whole point of the dense block
//! ("frequently used data kept in cache", §Perf). [`AlignedF64Vec`]
//! guarantees the alignment after *every* rebuild: the derived dense
//! block is reconstructed from scratch on each build and each
//! incremental splice, so the buffer only needs to re-derive its
//! aligned window when it (re)allocates, never to preserve data across
//! a reallocation.
//!
//! Implementation: over-allocate a plain `Vec<f64>` by up to 7 elements
//! and slice from the first 64-byte-aligned element. No custom
//! allocator, no `unsafe` — the alignment is a perf property layered on
//! ordinary safe storage.

use std::mem::size_of;

/// Alignment target: one cache line / one AVX-512 register (64 bytes).
pub const CACHE_LINE_BYTES: usize = 64;
const ALIGN_ELEMS: usize = CACHE_LINE_BYTES / size_of::<f64>();

/// A growable `f64` buffer whose first element always sits on a
/// [`CACHE_LINE_BYTES`] boundary. Contents are only ever rebuilt whole
/// (see the module docs), so the single mutator is
/// [`AlignedF64Vec::resize_zeroed`].
#[derive(Debug, Default)]
pub struct AlignedF64Vec {
    buf: Vec<f64>,
    off: usize,
    len: usize,
}

impl AlignedF64Vec {
    pub fn new() -> Self {
        Self::default()
    }

    /// Discard all contents and resize to `n` zeros, re-deriving the
    /// aligned window (the backing `Vec` may have moved on
    /// reallocation).
    pub fn resize_zeroed(&mut self, n: usize) {
        self.buf.clear();
        if n == 0 {
            self.off = 0;
            self.len = 0;
            return;
        }
        self.buf.resize(n + ALIGN_ELEMS - 1, 0.0);
        let addr = self.buf.as_ptr() as usize;
        debug_assert_eq!(addr % size_of::<f64>(), 0, "Vec<f64> must be 8-aligned");
        self.off = (ALIGN_ELEMS - (addr / size_of::<f64>()) % ALIGN_ELEMS) % ALIGN_ELEMS;
        self.len = n;
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.buf[self.off..self.off + self.len]
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.buf[self.off..self.off + self.len]
    }

    /// Resident bytes including the alignment slack (Max MEM
    /// accounting counts what is actually allocated).
    pub fn mem_bytes(&self) -> usize {
        self.buf.len() * size_of::<f64>()
    }
}

impl Clone for AlignedF64Vec {
    fn clone(&self) -> Self {
        // The clone's backing Vec lands at a different address, so the
        // aligned window must be re-derived, not copied.
        let mut v = AlignedF64Vec::new();
        v.resize_zeroed(self.len);
        v.as_mut_slice().copy_from_slice(self.as_slice());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_aligned(v: &AlignedF64Vec) -> bool {
        v.is_empty() || (v.as_slice().as_ptr() as usize) % CACHE_LINE_BYTES == 0
    }

    #[test]
    fn aligned_after_every_resize() {
        let mut v = AlignedF64Vec::new();
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000, 3, 0, 17] {
            v.resize_zeroed(n);
            assert_eq!(v.len(), n);
            assert!(is_aligned(&v), "misaligned at n={n}");
            assert!(v.as_slice().iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn contents_survive_clone_with_alignment() {
        let mut v = AlignedF64Vec::new();
        v.resize_zeroed(37);
        for (i, x) in v.as_mut_slice().iter_mut().enumerate() {
            *x = i as f64 * 0.5 - 3.0;
        }
        let c = v.clone();
        assert!(is_aligned(&c));
        assert_eq!(v.as_slice(), c.as_slice());
    }

    #[test]
    fn mem_accounting_counts_slack() {
        let mut v = AlignedF64Vec::new();
        v.resize_zeroed(16);
        assert!(v.mem_bytes() >= 16 * std::mem::size_of::<f64>());
    }
}
