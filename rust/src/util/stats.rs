//! Small statistics helpers shared by the UCs analyzers, the seeding
//! experiments (Appendix H), and the bench harnesses.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation CV = sigma / mean (Eq. 51, Appendix H).
pub fn coefficient_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    std_dev(xs) / m
}

/// Ordinary least squares fit `y = a + b x`; returns `(a, b, r2)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return (0.0, 0.0, 0.0);
    }
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let syy: f64 = ys.iter().map(|y| y * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-300 {
        return (sy / n, 0.0, 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    let ss_tot = syy - sy * sy / n;
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

/// Fit a power law `y = c * x^slope` over points with x, y > 0 by OLS on
/// log-log coordinates; returns `(slope, log_c, r2)`. Used for the Zipf /
/// bounded-Zipf exponents in Section III.
pub fn power_law_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    let pts: Vec<(f64, f64)> = xs
        .iter()
        .zip(ys)
        .filter(|(x, y)| **x > 0.0 && **y > 0.0)
        .map(|(x, y)| (x.ln(), y.ln()))
        .collect();
    let lx: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ly: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let (a, b, r2) = linear_fit(&lx, &ly);
    (b, a, r2)
}

/// Fast approximate `exp(x)` (Schraudolph-style bit manipulation refined
/// with one polynomial correction step; relative error < 0.1% over the
/// range the EstParams estimator uses). EstParams evaluates millions of
/// exponentials per parameter sweep (Appendix C); its probability model
/// is itself an approximation, so a 1e-3-accurate exp is more than
/// enough and ~5× faster than libm.
#[inline]
pub fn fast_exp(x: f64) -> f64 {
    if x < -700.0 {
        return 0.0;
    }
    if x > 700.0 {
        return f64::INFINITY;
    }
    // exp(x) = 2^(x/ln2) = 2^i * 2^f,  i = round(x/ln2), |f| <= 0.5
    let y = x * std::f64::consts::LOG2_E;
    let i = y.round();
    let f = y - i;
    // 2^f for |f| <= 0.5 via a degree-4 minimax-ish polynomial on f·ln2.
    let z = f * std::f64::consts::LN_2;
    let p = 1.0 + z * (1.0 + z * (0.5 + z * (1.0 / 6.0 + z * (1.0 / 24.0))));
    // Assemble 2^i through the exponent bits.
    let bits = (((i as i64) + 1023) as u64) << 52;
    f64::from_bits(bits) * p
}

/// Quantile by linear interpolation over a *sorted* slice; q in [0, 1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Histogram with `bins` equal-width buckets over `[lo, hi]`; out-of-range
/// values are clamped into the edge buckets.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<u64> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0u64; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let b = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[b] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_cv() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
        assert!((coefficient_of_variation(&xs) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn power_law_fit_recovers_exponent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 5.0 * x.powf(-1.3)).collect();
        let (slope, _, r2) = power_law_fit(&xs, &ys);
        assert!((slope + 1.3).abs() < 1e-6, "slope={slope}");
        assert!(r2 > 0.999);
    }

    #[test]
    fn fast_exp_accuracy() {
        for i in -200..=200 {
            let x = i as f64 * 0.11;
            let approx = fast_exp(x);
            let exact = x.exp();
            let rel = ((approx - exact) / exact).abs();
            assert!(rel < 1e-3, "x={x}: {approx} vs {exact} (rel {rel})");
        }
        assert_eq!(fast_exp(-1000.0), 0.0);
        assert!(fast_exp(1000.0).is_infinite());
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(quantile_sorted(&xs, 0.0), 0.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 100.0);
        assert!((quantile_sorted(&xs, 0.5) - 50.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.05, 0.15, 0.15, 0.95, -3.0, 7.0];
        let h = histogram(&xs, 0.0, 1.0, 10);
        assert_eq!(h[0], 2); // 0.05 and clamped -3.0
        assert_eq!(h[1], 2);
        assert_eq!(h[9], 2); // 0.95 and clamped 7.0
        assert_eq!(h.iter().sum::<u64>(), 6);
    }
}
