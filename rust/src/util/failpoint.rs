//! Compile-time-gated fail-point injection (the `failpoints` cargo
//! feature) — the harness `rust/tests/faults.rs` uses to prove the
//! fault-containment contract.
//!
//! ## Usage
//!
//! Named sites are planted in the production code with the
//! [`crate::failpoint!`] / [`crate::failpoint_res!`] macros:
//!
//! ```ignore
//! crate::failpoint!("algo.assign_shard", lo);     // non-Result context
//! crate::failpoint_res!("loader.triple", seen);   // `?`s an injected error
//! ```
//!
//! Without `--features failpoints` both macros expand to an **empty
//! block** — zero code, zero branches, zero dependency on this module —
//! so the default build's bit-pinned hot paths are untouched (the
//! existing determinism suites run featureless and prove it).
//!
//! With the feature enabled, sites consult a process-global registry:
//!
//! * seeded once from `SKM_FAILPOINTS`, a `;`-separated list of
//!   `site=action` entries where `action` is `panic`, `error`, or
//!   `delay:<ms>`, optionally suffixed `@<arg>` to fire only when the
//!   site's argument (shard start, query index, triple number …)
//!   matches — that's how a test kills exactly one shard or one query
//!   deterministically;
//! * reconfigurable at runtime through [`set`] / [`clear`] /
//!   [`clear_all`] (tests in one process cannot rely on env-once
//!   semantics). Tests serialize around the shared registry.
//!
//! Actions: `panic` unwinds with a tagged `String` payload (exercises
//! the `catch_unwind` containment paths), `error` returns
//! [`SkmError::FaultInjected`] at `failpoint_res!` sites (and panics at
//! `failpoint!` sites, which cannot return), `delay:<ms>` sleeps —
//! for perturbing worker scheduling without changing results.
//!
//! ## Persistence sites
//!
//! The crash-safety suite (`rust/tests/persist.rs`) kills the snapshot
//! writer at every stage through four `failpoint_res!` sites in
//! [`crate::persist`]: `persist.write_block` (arg = block index, fired
//! before each data block is written), `persist.fsync` (before the
//! temp file is synced), `persist.rename` (before the atomic
//! temp→final rename), and `persist.read_block` (arg = block index, on
//! the load path). An `error` injected at any write-path site must
//! leave the previously published snapshot untouched and loadable —
//! that is the atomic-publish contract under test.

#[cfg(feature = "failpoints")]
mod imp {
    use crate::error::{SkmError, SkmResult};
    use std::collections::HashMap;
    use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

    /// What an armed fail-point does when hit.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Action {
        Panic,
        Error,
        DelayMs(u64),
    }

    /// One armed site: the action, optionally restricted to a single
    /// site-argument value.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub struct FailSpec {
        pub action: Action,
        pub only_arg: Option<u64>,
    }

    fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
        // The registry must stay usable after a *injected* panic
        // unwound through a holder — poison tolerance, same as the
        // engines under test.
        m.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn registry() -> &'static Mutex<HashMap<String, FailSpec>> {
        static REG: OnceLock<Mutex<HashMap<String, FailSpec>>> = OnceLock::new();
        REG.get_or_init(|| Mutex::new(parse_env()))
    }

    fn parse_env() -> HashMap<String, FailSpec> {
        match std::env::var("SKM_FAILPOINTS") {
            Ok(s) => parse_list(&s).unwrap_or_else(|e| {
                crate::util::log::log_once(
                    "failpoint.env",
                    &format!("ignoring invalid SKM_FAILPOINTS: {e}"),
                );
                HashMap::new()
            }),
            Err(_) => HashMap::new(),
        }
    }

    /// Parse one `action[@arg]` spec (`panic`, `error`, `delay:<ms>`).
    pub fn parse_spec(s: &str) -> Result<FailSpec, String> {
        let (action_str, only_arg) = match s.split_once('@') {
            Some((a, g)) => (
                a.trim(),
                Some(
                    g.trim()
                        .parse::<u64>()
                        .map_err(|_| format!("bad @arg in failpoint spec {s:?}"))?,
                ),
            ),
            None => (s.trim(), None),
        };
        let action = if action_str == "panic" {
            Action::Panic
        } else if action_str == "error" {
            Action::Error
        } else if let Some(ms) = action_str.strip_prefix("delay:") {
            Action::DelayMs(
                ms.parse::<u64>()
                    .map_err(|_| format!("bad delay in failpoint spec {s:?}"))?,
            )
        } else {
            return Err(format!(
                "unknown failpoint action {action_str:?} (want panic | error | delay:<ms>)"
            ));
        };
        Ok(FailSpec { action, only_arg })
    }

    fn parse_list(s: &str) -> Result<HashMap<String, FailSpec>, String> {
        let mut map = HashMap::new();
        for part in s.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (name, spec) = part
                .split_once('=')
                .ok_or_else(|| format!("missing '=' in failpoint entry {part:?}"))?;
            map.insert(name.trim().to_string(), parse_spec(spec)?);
        }
        Ok(map)
    }

    /// Arm `site` with an `action[@arg]` spec (overwrites any previous
    /// arming, including one from `SKM_FAILPOINTS`).
    pub fn set(site: &str, spec: &str) -> Result<(), String> {
        let parsed = parse_spec(spec)?;
        lock(registry()).insert(site.to_string(), parsed);
        Ok(())
    }

    /// Disarm one site.
    pub fn clear(site: &str) {
        lock(registry()).remove(site);
    }

    /// Disarm every site (test teardown).
    pub fn clear_all() {
        lock(registry()).clear();
    }

    fn active(site: &str, arg: u64) -> Option<Action> {
        let reg = lock(registry());
        let spec = reg.get(site)?;
        match spec.only_arg {
            Some(g) if g != arg => None,
            _ => Some(spec.action),
        }
    }

    fn injected_panic(site: &str, arg: u64) -> ! {
        std::panic::panic_any(format!("failpoint {site} (arg {arg}): injected panic"))
    }

    /// Fire a unit-context site (cannot return an error): `panic` and
    /// `error` both unwind (the site has no error channel), `delay`
    /// sleeps.
    pub fn fire_unit(site: &str, arg: u64) {
        match active(site, arg) {
            Some(Action::Panic) | Some(Action::Error) => injected_panic(site, arg),
            Some(Action::DelayMs(ms)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms))
            }
            None => {}
        }
    }

    /// Fire a Result-context site: `error` returns
    /// [`SkmError::FaultInjected`] for the caller's `?`.
    pub fn fire_err(site: &str, arg: u64) -> SkmResult<()> {
        match active(site, arg) {
            Some(Action::Panic) => injected_panic(site, arg),
            Some(Action::Error) => Err(SkmError::FaultInjected {
                site: format!("{site} (arg {arg})"),
            }),
            Some(Action::DelayMs(ms)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
            None => Ok(()),
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        // Module tests share the process-global registry with nothing
        // else in the lib test binary (integration fault tests live in
        // their own binary), but still serialize among themselves.
        fn test_lock() -> MutexGuard<'static, ()> {
            static L: Mutex<()> = Mutex::new(());
            L.lock().unwrap_or_else(PoisonError::into_inner)
        }

        #[test]
        fn spec_parsing() {
            assert_eq!(
                parse_spec("panic").unwrap(),
                FailSpec {
                    action: Action::Panic,
                    only_arg: None
                }
            );
            assert_eq!(
                parse_spec("error@3").unwrap(),
                FailSpec {
                    action: Action::Error,
                    only_arg: Some(3)
                }
            );
            assert_eq!(
                parse_spec("delay:25").unwrap(),
                FailSpec {
                    action: Action::DelayMs(25),
                    only_arg: None
                }
            );
            assert!(parse_spec("explode").is_err());
            assert!(parse_spec("panic@x").is_err());
            assert!(parse_spec("delay:ms").is_err());
            assert!(parse_list("a=panic;b=error@2; ;").is_ok());
            assert!(parse_list("a").is_err());
        }

        #[test]
        fn arg_filter_and_lifecycle() {
            let _g = test_lock();
            clear_all();
            set("unit.test.site", "error@5").unwrap();
            assert!(fire_err("unit.test.site", 4).is_ok());
            assert!(fire_err("unit.test.site", 5).is_err());
            assert!(fire_err("other.site", 5).is_ok());
            clear("unit.test.site");
            assert!(fire_err("unit.test.site", 5).is_ok());
            clear_all();
        }

        #[test]
        fn panic_action_unwinds_with_tagged_payload() {
            let _g = test_lock();
            clear_all();
            set("unit.test.panic", "panic").unwrap();
            let err = crate::error::contain("unit.test", || {
                fire_unit("unit.test.panic", 9);
                0u32
            })
            .unwrap_err();
            clear_all();
            let msg = err.to_string();
            assert!(msg.contains("failpoint unit.test.panic"), "{msg}");
            assert!(msg.contains("arg 9"), "{msg}");
        }
    }
}

#[cfg(feature = "failpoints")]
pub use imp::{clear, clear_all, fire_err, fire_unit, parse_spec, set, Action, FailSpec};

/// Plant a fail-point in a non-`Result` context. `$arg` is a `u64`-ish
/// site argument (shard start, query index …) used by `@arg` filters;
/// it must be cheap and side-effect free — the disabled build drops the
/// expression entirely.
#[macro_export]
macro_rules! failpoint {
    ($site:expr, $arg:expr) => {{
        #[cfg(feature = "failpoints")]
        {
            $crate::util::failpoint::fire_unit($site, ($arg) as u64);
        }
    }};
}

/// Plant a fail-point in a function returning `Result<_, SkmError>`
/// (or any error `From<SkmError>`): an armed `error` action returns
/// through the enclosing function's `?`. Same disabled-build guarantee
/// as [`crate::failpoint!`].
#[macro_export]
macro_rules! failpoint_res {
    ($site:expr, $arg:expr) => {{
        #[cfg(feature = "failpoints")]
        {
            $crate::util::failpoint::fire_err($site, ($arg) as u64)?;
        }
    }};
}
