//! Foundation utilities: RNG, statistics, CLI parsing, output writers,
//! timing. Everything here is dependency-free because the build
//! environment is offline (see DESIGN.md §3).

pub mod aligned;
pub mod cli;
pub mod failpoint;
pub mod io;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod timer;
