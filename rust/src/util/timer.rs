//! Wall-clock timing helpers and a tiny benchmarking loop (the offline
//! build has no `criterion`; the `benches/` harnesses use this instead).

use std::time::{Duration, Instant};

/// A simple stopwatch that accumulates across start/stop cycles; used to
/// split assignment-step vs update-step time as the paper's appendix
/// tables do.
#[derive(Debug, Clone, Default)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(s) = self.started.take() {
            self.total += s.elapsed();
        }
    }

    pub fn reset(&mut self) {
        self.total = Duration::ZERO;
        self.started = None;
    }

    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(s) => self.total + s.elapsed(),
            None => self.total,
        }
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure once, returning (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Statistics from a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
}

impl BenchStats {
    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: mean {:.3} ms  min {:.3} ms  max {:.3} ms  (+/- {:.3} ms, n={})",
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.std_s * 1e3,
            self.iters
        )
    }
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until either `max_iters` or `budget` seconds are spent (at least one).
pub fn bench(warmup: usize, max_iters: usize, budget_s: f64, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let budget = Duration::from_secs_f64(budget_s);
    let t0 = Instant::now();
    while samples.len() < max_iters.max(1) && (samples.is_empty() || t0.elapsed() < budget) {
        let s = Instant::now();
        f();
        samples.push(s.elapsed().as_secs_f64());
    }
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    BenchStats {
        iters: n,
        mean_s: mean,
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(0.0, f64::max),
        std_s: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let t1 = sw.secs();
        assert!(t1 >= 0.004);
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.secs() > t1);
        sw.reset();
        assert_eq!(sw.secs(), 0.0);
    }

    #[test]
    fn bench_runs_at_least_once() {
        let mut count = 0;
        let stats = bench(1, 5, 0.05, || {
            count += 1;
        });
        assert!(stats.iters >= 1);
        assert!(count >= stats.iters); // warmup + timed
        assert!(stats.min_s <= stats.mean_s && stats.mean_s <= stats.max_s + 1e-12);
    }
}
