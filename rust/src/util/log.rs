//! Deduplicated, silenceable diagnostics for library code.
//!
//! Library modules must not write raw `eprintln!` lines: a warning that
//! fires once per query (or once per checkpoint round) floods stderr,
//! and embedders need a single switch to silence the crate entirely.
//! [`log_once`] is that policy in one place — each *site* string prints
//! at most once per process, and `SKM_QUIET=1` suppresses everything.
//!
//! The message is advisory only: callers already carry the real outcome
//! through typed [`crate::error::SkmError`] values or degraded-but-exact
//! results (e.g. the router's exact-scan fallback). Nothing may branch
//! on whether a line was printed.

use std::collections::HashSet;
use std::sync::{Mutex, OnceLock};

fn seen_sites() -> &'static Mutex<HashSet<String>> {
    static SEEN: OnceLock<Mutex<HashSet<String>>> = OnceLock::new();
    SEEN.get_or_init(|| Mutex::new(HashSet::new()))
}

fn quiet() -> bool {
    std::env::var("SKM_QUIET").map(|v| v == "1").unwrap_or(false)
}

/// Print `skm: {msg}` to stderr, at most once per `site` per process.
/// Returns `true` when the line was actually emitted (first call at the
/// site with `SKM_QUIET` unset) — callers that keep their own counters
/// (e.g. the router's fallback counter) don't need the return value;
/// it exists for tests.
pub fn log_once(site: &str, msg: &str) -> bool {
    let mut seen = seen_sites()
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner);
    if !seen.insert(site.to_string()) {
        return false;
    }
    drop(seen);
    if quiet() {
        return false;
    }
    eprintln!("skm: {msg}");
    true
}

/// Forget every site (test hook: lets a suite re-arm a warning it wants
/// to observe). Not part of the stable API surface.
pub fn reset_for_tests() {
    seen_sites()
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedupes_per_site() {
        reset_for_tests();
        // Whether the first call prints depends on SKM_QUIET in the test
        // environment; the dedup contract is environment-independent:
        // after one call the site is spent.
        let _ = log_once("test.site.a", "first");
        assert!(!log_once("test.site.a", "second"));
        assert!(!log_once("test.site.a", "third"));
        // A different site is independent.
        let _ = log_once("test.site.b", "other");
        assert!(!log_once("test.site.b", "other again"));
    }
}
