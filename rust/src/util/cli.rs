//! Minimal command-line argument parser (the offline build has no `clap`).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` conventions used by the `skm` binary, the examples, and the
//! bench harnesses:
//!
//! ```no_run
//! # // no_run: doctest executables cannot resolve libxla's rpath in
//! # // this offline image; the same assertions run in #[test]s below.
//! use skm::util::cli::Args;
//! let args = Args::parse_from(["cluster", "--algo", "es-icp", "--k=100", "--verbose"]);
//! assert_eq!(args.subcommand(), Some("cluster"));
//! assert_eq!(args.get("algo"), Some("es-icp"));
//! assert_eq!(args.get_parsed::<usize>("k", 8), 100);
//! assert!(args.flag("verbose"));
//! ```

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn parse_from<I, S>(items: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Self::default();
        let items: Vec<String> = items.into_iter().map(Into::into).collect();
        let mut i = 0;
        while i < items.len() {
            let it = &items[i];
            if let Some(stripped) = it.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    out.options
                        .insert(stripped.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(it.clone());
            } else {
                out.positional.push(it.clone());
            }
            i += 1;
        }
        out
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.subcommand.as_deref()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse an option value, falling back to `default` when absent.
    /// Panics with a clear message on malformed input. Kept for the
    /// bench harnesses / examples (a backtrace is fine there); the
    /// `skm` binary routes through [`Args::try_parsed_or`] so user
    /// typos exit 2 with a one-line message instead.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Fallible parse of an option value: `Ok(None)` when absent, a
    /// typed usage error ([`crate::error::SkmError::InvalidConfig`],
    /// exit code 2) on malformed input.
    pub fn try_parsed<T: std::str::FromStr>(
        &self,
        key: &str,
    ) -> crate::error::SkmResult<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                crate::error::SkmError::invalid_config(format!("--{key}: cannot parse {v:?}"))
            }),
        }
    }

    /// [`Args::try_parsed`] with a default for the absent case.
    pub fn try_parsed_or<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> crate::error::SkmResult<T> {
        Ok(self.try_parsed(key)?.unwrap_or(default))
    }

    /// True if a bare `--name` flag was given (or `--name=true`).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.get(name) == Some("true")
    }

    /// `--threads N` — worker threads for the sharded execution engine
    /// (default 1 = serial). Consumed by the `skm` binary and examples;
    /// the engine itself lives in `algo::par`.
    pub fn threads(&self) -> usize {
        self.get_parsed::<usize>("threads", 1).max(1)
    }

    /// `--shard N` — objects per shard for the sharded engine
    /// (default 0 = one shard per thread).
    pub fn shard(&self) -> usize {
        self.get_parsed::<usize>("shard", 0)
    }

    /// `--minibatch` — run the mini-batch / streaming driver instead of
    /// full-batch Lloyd (consumed by the `skm` binary; the driver lives
    /// in `coordinator::minibatch`).
    pub fn minibatch(&self) -> bool {
        self.flag("minibatch")
    }

    /// `--batch-size N` — objects per mini-batch round (0 = the
    /// workload's default, ~1/16 of the corpus floored at 256).
    pub fn batch_size(&self) -> usize {
        self.get_parsed::<usize>("batch-size", 0)
    }

    /// `--decay F` — count-decay forgetting factor in [0, 1]:
    /// per batch `c_j ← decay·c_j + m_j`, learning rate `m_j / c_j`.
    /// 1.0 = classic count decay; 0.0 = memoryless (with
    /// `--batch-size n` this is bit-exact full-batch Lloyd).
    pub fn decay(&self) -> f64 {
        self.get_parsed::<f64>("decay", 1.0)
    }

    /// `--top-p N` — centroids the serving router returns per query
    /// (0 = the workload default, ~K/32 clamped to [1, 8]). Consumed by
    /// the `skm serve` subcommand; the router lives in `serve::router`.
    pub fn top_p(&self) -> usize {
        self.get_parsed::<usize>("top-p", 0)
    }

    /// `--top-k N` — documents the serving retrieval stage returns per
    /// query (0 = routing only).
    pub fn top_k(&self) -> usize {
        self.get_parsed::<usize>("top-k", 10)
    }

    /// `--save PATH` — persistence destination. For `skm serve`: write
    /// the frozen serving snapshot there. For `skm cluster`: write
    /// (periodic + final) run checkpoints there. The write is atomic
    /// (temp + fsync + rename); see `persist`.
    pub fn save_path(&self) -> Option<&str> {
        self.get("save")
    }

    /// `--load PATH` — serve from a persisted snapshot instead of
    /// clustering (skips dataset building entirely; `skm serve` only).
    pub fn load_path(&self) -> Option<&str> {
        self.get("load")
    }

    /// `--checkpoint-every N` — rounds between periodic checkpoints
    /// (requires `--save`; default 10 when `--save` is given).
    pub fn checkpoint_every(&self) -> crate::error::SkmResult<Option<usize>> {
        self.try_parsed::<usize>("checkpoint-every")
    }

    /// `--resume PATH` — resume a checkpointed `skm cluster` run; the
    /// checkpoint's fingerprint must match the current configuration
    /// and corpus.
    pub fn resume_path(&self) -> Option<&str> {
        self.get("resume")
    }

    /// `--compress` — write the snapshot in format v2: posting ids
    /// delta+varint chunk-encoded (`skm serve --save` only; loading
    /// auto-detects the version).
    pub fn compress(&self) -> bool {
        self.flag("compress")
    }

    /// `--mmap` — serve `--load`ed compressed snapshots straight from
    /// the file via mmap + block cache instead of decoding the corpus
    /// into RAM (v1 snapshots fall back to the full in-RAM load).
    pub fn mmap(&self) -> bool {
        self.flag("mmap")
    }

    /// `--cache-mb N` — block-cache capacity in MiB for `--mmap`
    /// serving (default 64).
    pub fn cache_mb(&self) -> crate::error::SkmResult<usize> {
        self.try_parsed_or::<usize>("cache-mb", crate::persist::mmap::DEFAULT_CACHE_MB)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_options_flags() {
        // NOTE grammar: a bare `--name` immediately followed by a
        // non-`--` token is an option (`--name value`); trailing bare
        // `--name` is a boolean flag. Use `--name=true` to force a flag
        // before positional arguments.
        let a = Args::parse_from(["run", "file.txt", "--n", "100", "--k=5", "--fast"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get_parsed::<usize>("k", 0), 5);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
        assert_eq!(a.positional(), &["file.txt".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from(Vec::<String>::new());
        assert_eq!(a.subcommand(), None);
        assert_eq!(a.get_parsed::<f64>("alpha", 1.5), 1.5);
        assert_eq!(a.get_or("algo", "mivi"), "mivi");
    }

    #[test]
    fn persistence_accessors() {
        let a = Args::parse_from([
            "cluster",
            "--save",
            "out.ckpt",
            "--checkpoint-every",
            "5",
            "--resume",
            "in.ckpt",
        ]);
        assert_eq!(a.save_path(), Some("out.ckpt"));
        assert_eq!(a.resume_path(), Some("in.ckpt"));
        assert_eq!(a.checkpoint_every().unwrap(), Some(5));
        assert_eq!(a.load_path(), None);

        let b = Args::parse_from(["serve", "--load", "snap.skm"]);
        assert_eq!(b.load_path(), Some("snap.skm"));
        assert_eq!(b.save_path(), None);
        assert_eq!(b.checkpoint_every().unwrap(), None);

        let bad = Args::parse_from(["cluster", "--checkpoint-every", "soon"]);
        assert!(bad.checkpoint_every().is_err());
    }

    #[test]
    fn flag_before_flag() {
        let a = Args::parse_from(["x", "--verbose", "--k", "3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_parsed::<u32>("k", 0), 3);
    }

    #[test]
    fn threads_and_shard_accessors() {
        let a = Args::parse_from(["cluster", "--threads", "6", "--shard=128"]);
        assert_eq!(a.threads(), 6);
        assert_eq!(a.shard(), 128);
        let b = Args::parse_from(Vec::<String>::new());
        assert_eq!(b.threads(), 1);
        assert_eq!(b.shard(), 0);
        // --threads 0 clamps to serial rather than panicking downstream.
        let c = Args::parse_from(["x", "--threads", "0"]);
        assert_eq!(c.threads(), 1);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn malformed_number_panics() {
        let a = Args::parse_from(["x", "--k", "abc"]);
        let _ = a.get_parsed::<usize>("k", 0);
    }

    #[test]
    fn try_parsed_is_typed_and_exits_2() {
        let a = Args::parse_from(["x", "--k", "abc", "--n", "7"]);
        assert_eq!(a.try_parsed_or::<usize>("n", 0).unwrap(), 7);
        assert_eq!(a.try_parsed::<usize>("missing").unwrap(), None);
        let err = a.try_parsed::<usize>("k").unwrap_err();
        assert_eq!(err.exit_code(), 2);
        assert!(err.to_string().contains("--k: cannot parse"), "{err}");
    }

    #[test]
    fn serve_accessors() {
        let a = Args::parse_from(["serve", "--top-p", "4", "--top-k=25"]);
        assert_eq!(a.top_p(), 4);
        assert_eq!(a.top_k(), 25);
        let b = Args::parse_from(Vec::<String>::new());
        assert_eq!(b.top_p(), 0); // 0 = workload default
        assert_eq!(b.top_k(), 10);
    }

    #[test]
    fn compression_and_mmap_accessors() {
        let a = Args::parse_from(["serve", "--load", "s.skm", "--mmap", "--cache-mb", "128"]);
        assert!(a.mmap());
        assert!(!a.compress());
        assert_eq!(a.cache_mb().unwrap(), 128);
        let b = Args::parse_from(["serve", "--save", "s.skm", "--compress"]);
        assert!(b.compress());
        assert!(!b.mmap());
        assert_eq!(
            b.cache_mb().unwrap(),
            crate::persist::mmap::DEFAULT_CACHE_MB
        );
        let bad = Args::parse_from(["serve", "--cache-mb", "lots"]);
        assert!(bad.cache_mb().is_err());
    }

    #[test]
    fn minibatch_accessors() {
        let a = Args::parse_from(["cluster", "--minibatch", "--batch-size", "512", "--decay=0.5"]);
        assert!(a.minibatch());
        assert_eq!(a.batch_size(), 512);
        assert_eq!(a.decay(), 0.5);
        let b = Args::parse_from(Vec::<String>::new());
        assert!(!b.minibatch());
        assert_eq!(b.batch_size(), 0);
        assert_eq!(b.decay(), 1.0);
    }
}
