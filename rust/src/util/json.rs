//! Minimal JSON value builder/serializer (the offline build has no
//! `serde`). Used by the `--bench-json` CLI flag and the hot-path
//! bench harness to emit machine-readable phase timings and counters.
//!
//! Output is deterministic: object fields serialize in insertion order,
//! floats use Rust's shortest-roundtrip `Display`, and non-finite
//! floats degrade to `null` (JSON has no NaN/Inf).

use std::fmt;

/// A JSON value. Construct with the helper constructors and serialize
/// with [`Json::render`] (compact) or [`Json::render_pretty`].
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Finite floats; NaN/Inf serialize as `null`.
    Num(f64),
    /// Unsigned integers (counters can exceed `f64`'s 2^53 precision).
    UInt(u64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Compact single-line rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Two-space-indented rendering (for committed baselines).
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    let s = format!("{v}");
                    out.push_str(&s);
                    // Keep integral floats as valid JSON numbers — they
                    // already are ("1" is a number) — nothing to fix up.
                } else {
                    out.push_str("null");
                }
            }
            Json::UInt(v) => out.push_str(&v.to_string()),
            Json::Int(v) => out.push_str(&v.to_string()),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (q, item) in items.iter().enumerate() {
                    if q > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (q, (key, value)) in fields.iter().enumerate() {
                    if q > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(key, out);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    value.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::Num(1.0).render(), "1");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::UInt(u64::MAX).render(), "18446744073709551615");
        assert_eq!(Json::Int(-3).render(), "-3");
    }

    #[test]
    fn strings_escape() {
        assert_eq!(Json::str("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::str("\u{01}").render(), "\"\\u0001\"");
    }

    #[test]
    fn containers_render_in_order() {
        let j = Json::obj(vec![
            ("b", Json::UInt(1)),
            ("a", Json::Arr(vec![Json::Num(0.5), Json::Null])),
        ]);
        assert_eq!(j.render(), "{\"b\":1,\"a\":[0.5,null]}");
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::obj(vec![]).render(), "{}");
    }

    #[test]
    fn pretty_is_parseable_shape() {
        let j = Json::obj(vec![(
            "phases",
            Json::obj(vec![("gather", Json::Num(0.25))]),
        )]);
        let p = j.render_pretty();
        assert!(p.contains("\"phases\": {"));
        assert!(p.ends_with("}\n"));
    }
}
