//! Experiment-output writers: a tiny JSON emitter and a CSV table writer.
//!
//! The offline build has no `serde`, so we emit JSON manually from a small
//! value enum. Bench harnesses write both a human-readable table to stdout
//! and machine-readable JSON/CSV under `target/experiments/`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A minimal JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn s(v: impl Into<String>) -> Self {
        Json::Str(v.into())
    }
    pub fn n(v: impl Into<f64>) -> Self {
        Json::Num(v.into())
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Write a JSON value to a file, creating parent directories.
pub fn write_json(path: impl AsRef<Path>, value: &Json) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(value.render().as_bytes())?;
    f.write_all(b"\n")
}

/// A column-aligned text table that can also be dumped as CSV; every bench
/// harness uses this to print paper-style rows.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let _ = write!(out, "{:<w$}", c, w = widths[i]);
            }
            out.push('\n');
        };
        fmt_row(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            fmt_row(&mut out, r);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            out.push_str(
                &cells
                    .iter()
                    .map(|c| esc(c))
                    .collect::<Vec<_>>()
                    .join(","),
            );
            out.push('\n');
        };
        line(&self.header, &mut out);
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format a float the way the paper's tables do (4 significant digits,
/// scientific for very large/small magnitudes).
pub fn fmt_sig(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let ax = x.abs();
    if !(1e-3..1e7).contains(&ax) {
        format!("{x:.3e}")
    } else if ax >= 1000.0 {
        format!("{x:.1}")
    } else if ax >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_shapes() {
        let v = Json::Obj(vec![
            ("name".into(), Json::s("es-icp")),
            ("k".into(), Json::n(80_000.0)),
            ("ok".into(), Json::Bool(true)),
            ("xs".into(), Json::Arr(vec![Json::n(1.5), Json::Null])),
            ("quote".into(), Json::s("a\"b\n")),
        ]);
        let s = v.render();
        assert_eq!(
            s,
            r#"{"name":"es-icp","k":80000,"ok":true,"xs":[1.5,null],"quote":"a\"b\n"}"#
        );
    }

    #[test]
    fn json_nonfinite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(vec!["Algo", "AvgMult"]);
        t.row(vec!["MIVI", "141.2"]);
        t.row(vec!["ES-ICP", "1.0"]);
        let s = t.render();
        assert!(s.contains("Algo"));
        assert!(s.lines().count() == 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "Algo,AvgMult");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["x,y\"z"]);
        assert!(t.to_csv().contains("\"x,y\"\"z\""));
    }

    #[test]
    fn sig_formatting() {
        assert_eq!(fmt_sig(0.0), "0");
        assert_eq!(fmt_sig(1.2345), "1.2345");
        assert!(fmt_sig(9.391e10).contains('e'));
        assert!(fmt_sig(1e-9).contains('e'));
    }
}
