//! Deterministic pseudo-random number generation.
//!
//! The build environment is offline (no `rand` crate), so we implement the
//! small set of generators and samplers the corpus generator and the
//! seeding logic need: SplitMix64 for seeding, PCG32 as the workhorse
//! stream, plus uniform / Zipf / symmetric-Dirichlet-ish / categorical
//! samplers. All generators are deterministic given a seed, which the
//! exactness audits (DESIGN.md §6) rely on.

/// SplitMix64: used to expand a single `u64` seed into independent streams.
///
/// Reference: Steele, Lea, Flood, "Fast splittable pseudorandom number
/// generators", OOPSLA 2014.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): small, fast, statistically solid stream generator.
///
/// Reference: O'Neill, "PCG: A family of simple fast space-efficient
/// statistically good algorithms for random number generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Construct from a seed; the stream id is derived via SplitMix64 so
    /// `Pcg32::new(s)` and `Pcg32::new(s + 1)` are independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let initstate = sm.next_u64();
        let initseq = sm.next_u64();
        let mut rng = Self {
            state: 0,
            inc: (initseq << 1) | 1,
        };
        let _ = rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        let _ = rng.next_u32();
        rng
    }

    /// The raw `(state, inc)` pair, for checkpointing. Feeding it back
    /// through [`Pcg32::from_raw_state`] reproduces the stream exactly
    /// from this point (the persistence layer relies on this for
    /// bit-identical resumed mini-batch trajectories).
    pub fn raw_state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg32::raw_state`] pair.
    pub fn from_raw_state(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 32 bits of resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4_294_967_296.0)
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift
    /// rejection method (unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut m = (self.next_u32() as u64).wrapping_mul(bound as u64);
        let mut lo = m as u32;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                m = (self.next_u32() as u64).wrapping_mul(bound as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n), order randomized.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        // Floyd's algorithm: O(k) expected insertions.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j as u32 + 1) as usize;
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        self.shuffle(&mut out);
        out
    }

    /// Standard normal via Box–Muller (we only need modest quality).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; used for Dirichlet sampling in
    /// the topic-model corpus generator.
    pub fn next_gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boosting: Gamma(a) = Gamma(a+1) * U^{1/a}
            let g = self.next_gamma(shape + 1.0);
            let u = self.next_f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_gaussian();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3;
            }
        }
    }
}

/// Samples ranks from a (truncated) Zipf distribution
/// `P(rank = r) ∝ (r + shift)^(-alpha)`, `r ∈ 1..=n`, by inverting the
/// cumulative distribution with a precomputed table (binary search).
///
/// A table-based sampler is exact for our purposes and fast enough: the
/// corpus generator draws tens of millions of term ranks.
#[derive(Clone, Debug)]
pub struct ZipfSampler {
    /// Cumulative unnormalized mass, `cdf[r-1] = sum_{r'<=r} (r'+shift)^-alpha`.
    cdf: Vec<f64>,
    total: f64,
}

impl ZipfSampler {
    pub fn new(n: usize, alpha: f64) -> Self {
        Self::with_shift(n, alpha, 0.0)
    }

    /// Zipf–Mandelbrot variant with a rank shift (flattens the head, which
    /// matches empirical document-frequency curves better — cf. paper
    /// Fig. 2 where the head of the df curve bends below the power law).
    pub fn with_shift(n: usize, alpha: f64, shift: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 1..=n {
            acc += (r as f64 + shift).powf(-alpha);
            cdf.push(acc);
        }
        Self { cdf, total: acc }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw a 1-based rank.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.next_f64() * self.total;
        // partition_point returns the first index with cdf[idx] >= u.
        self.cdf.partition_point(|&c| c < u) + 1
    }
}

/// Weighted categorical sampler over arbitrary nonnegative weights
/// (cumulative-table + binary search). Used for topic mixtures.
#[derive(Clone, Debug)]
pub struct Categorical {
    cdf: Vec<f64>,
    total: f64,
}

impl Categorical {
    pub fn new(weights: &[f64]) -> Self {
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            debug_assert!(w >= 0.0 && w.is_finite());
            acc += w;
            cdf.push(acc);
        }
        assert!(acc > 0.0, "Categorical: all weights zero");
        Self { cdf, total: acc }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.next_f64() * self.total;
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_deterministic_and_distinct_streams() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        let mut c = Pcg32::new(43);
        let xa: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let xb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        let xc: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_eq!(xa, xb);
        assert_ne!(xa, xc);
    }

    #[test]
    fn raw_state_round_trip_resumes_stream() {
        let mut a = Pcg32::new(42);
        for _ in 0..17 {
            a.next_u32();
        }
        let (s, inc) = a.raw_state();
        let mut b = Pcg32::from_raw_state(s, inc);
        let xa: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let xb: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        assert_eq!(xa, xb);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Pcg32::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Pcg32::new(3);
        let z = ZipfSampler::new(1000, 1.1);
        let mut counts = vec![0u32; 1001];
        for _ in 0..50_000 {
            let r = z.sample(&mut rng);
            assert!((1..=1000).contains(&r));
            counts[r] += 1;
        }
        // rank 1 should be much more frequent than rank 100
        assert!(counts[1] > counts[100] * 10);
        // and the tail should still be sampled
        assert!(counts[500..].iter().map(|&c| c as u64).sum::<u64>() > 0);
    }

    #[test]
    fn zipf_empirical_exponent_roughly_matches() {
        // Fit log(freq) vs log(rank) for the top ranks; slope ≈ -alpha.
        let mut rng = Pcg32::new(9);
        let alpha = 1.0;
        let z = ZipfSampler::new(5000, alpha);
        let mut counts = vec![0u32; 5001];
        for _ in 0..400_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        let pts: Vec<(f64, f64)> = (1..=50)
            .map(|r| ((r as f64).ln(), (counts[r].max(1) as f64).ln()))
            .collect();
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        assert!(
            (slope + alpha).abs() < 0.15,
            "slope={slope}, expected ~{}",
            -alpha
        );
    }

    #[test]
    fn gamma_positive_mean_matches_shape() {
        let mut rng = Pcg32::new(11);
        for &shape in &[0.3, 1.0, 4.5] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| rng.next_gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut rng = Pcg32::new(5);
        for _ in 0..100 {
            let k = 1 + rng.gen_range(50) as usize;
            let s = rng.sample_distinct(60, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < 60));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg32::new(13);
        let c = Categorical::new(&[1.0, 0.0, 3.0]);
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[c.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }
}
