//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas
//! computations (`artifacts/*.hlo.txt`) from Rust, with **no Python on
//! the execution path**.
//!
//! Build path (see `python/compile/aot.py`): JAX lowers the Layer-2
//! model (which calls the Layer-1 Pallas kernel) to StableHLO, converts
//! it to an `XlaComputation`, and dumps **HLO text** — the interchange
//! format this image's xla_extension 0.5.1 accepts (jax ≥ 0.5 protos
//! carry 64-bit ids the proto path rejects; the text parser reassigns
//! ids).
//!
//! Runtime path (this module): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. Compiled
//! executables are cached per artifact name.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Expected dense-block shapes, kept in sync with `python/compile/aot.py`
/// (`BLOCK_B`, `BLOCK_K`, `BLOCK_D` there).
pub const BLOCK_B: usize = 64;
pub const BLOCK_K: usize = 32;
pub const BLOCK_D: usize = 256;

/// A PJRT client plus a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// Default artifacts directory: `$SKM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SKM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// True if the named artifact exists on disk.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Load (and cache) an artifact by name (`name` → `name.hlo.txt`).
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on f32 inputs with the given shapes; returns
    /// the flattened outputs of the result tuple.
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let expected: i64 = shape.iter().product();
                anyhow::ensure!(
                    expected as usize == data.len(),
                    "shape {shape:?} wants {expected} elements, got {}",
                    data.len()
                );
                xla::Literal::vec1(data)
                    .reshape(shape)
                    .map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| {
                // Outputs may be f32 or i32 (argmax indices); convert to
                // f32 uniformly for a simple interface.
                let p = p
                    .convert(xla::PrimitiveType::F32)
                    .map_err(|e| anyhow!("convert: {e:?}"))?;
                p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
            })
            .collect()
    }

    /// Dense-block assignment via the AOT Pallas/JAX kernel: given a
    /// `B×D` block of objects and `K×D` means (both dense f32,
    /// row-major), returns `(argmax ids, best sims)`.
    ///
    /// Shapes must match the compiled block ([`BLOCK_B`], [`BLOCK_K`],
    /// [`BLOCK_D`]); use [`pad_to`] helpers for partial blocks.
    pub fn assign_block(&mut self, x: &[f32], m: &[f32]) -> Result<(Vec<u32>, Vec<f32>)> {
        let outs = self.execute_f32(
            "assign_block",
            &[
                (x, &[BLOCK_B as i64, BLOCK_D as i64]),
                (m, &[BLOCK_K as i64, BLOCK_D as i64]),
            ],
        )?;
        anyhow::ensure!(outs.len() == 2, "assign_block returned {} outputs", outs.len());
        let ids = outs[0].iter().map(|&v| v as u32).collect();
        Ok((ids, outs[1].clone()))
    }

    /// One dense spherical-k-means step via the AOT kernel: returns
    /// `(assignments, new unit-norm means (K×D), objective)`.
    pub fn kmeans_step(&mut self, x: &[f32], m: &[f32]) -> Result<(Vec<u32>, Vec<f32>, f32)> {
        let outs = self.execute_f32(
            "kmeans_step",
            &[
                (x, &[BLOCK_B as i64, BLOCK_D as i64]),
                (m, &[BLOCK_K as i64, BLOCK_D as i64]),
            ],
        )?;
        anyhow::ensure!(outs.len() == 3, "kmeans_step returned {} outputs", outs.len());
        let ids = outs[0].iter().map(|&v| v as u32).collect();
        Ok((ids, outs[1].clone(), outs[2][0]))
    }
}

/// Pad a dense row-major `rows×cols` matrix to `target_rows×target_cols`
/// with zeros (partial blocks → full compiled block shapes).
pub fn pad_to(data: &[f32], rows: usize, cols: usize, target_rows: usize, target_cols: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * cols);
    assert!(rows <= target_rows && cols <= target_cols);
    let mut out = vec![0.0f32; target_rows * target_cols];
    for r in 0..rows {
        out[r * target_cols..r * target_cols + cols]
            .copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
    out
}

/// Project sparse rows onto the `proj_d` highest-df terms (term ids
/// `D - proj_d ..`) as dense f32 rows — the dense cross-check subspace
/// used by the hybrid example (see DESIGN.md §2).
pub fn densify_top_terms(
    x: &crate::sparse::CsrMatrix,
    rows: &[usize],
    proj_d: usize,
) -> Vec<f32> {
    let d = x.n_cols();
    let lo = d.saturating_sub(proj_d);
    let mut out = vec![0.0f32; rows.len() * proj_d];
    for (r, &i) in rows.iter().enumerate() {
        let (ts, vs) = x.row(i);
        for (&t, &v) in ts.iter().zip(vs) {
            let t = t as usize;
            if t >= lo {
                out[r * proj_d + (t - lo)] = v as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_roundtrip() {
        let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let p = pad_to(&data, 2, 3, 4, 5);
        assert_eq!(p.len(), 20);
        assert_eq!(&p[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(p[3], 0.0);
        assert_eq!(&p[5..8], &[4.0, 5.0, 6.0]);
        assert_eq!(p[19], 0.0);
    }

    #[test]
    fn densify_top_terms_places_values() {
        use crate::sparse::CsrMatrix;
        let m = CsrMatrix::from_rows(10, &[vec![(1, 0.5), (8, 0.25), (9, 0.75)]]);
        let dense = densify_top_terms(&m, &[0], 4); // terms 6..10
        assert_eq!(dense.len(), 4);
        assert_eq!(dense, vec![0.0, 0.0, 0.25, 0.75]); // term 1 dropped
    }

    /// Full PJRT round-trip — only runs when artifacts are built
    /// (`make artifacts`); the integration test in `rust/tests`
    /// exercises it unconditionally via the Makefile flow.
    #[test]
    fn pjrt_assign_block_if_artifacts_present() {
        let dir = PjrtRuntime::default_dir();
        if !dir.join("assign_block.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = PjrtRuntime::new(&dir).expect("client");
        let mut x = vec![0.0f32; BLOCK_B * BLOCK_D];
        let mut m = vec![0.0f32; BLOCK_K * BLOCK_D];
        // object r matches mean r % K exactly.
        for r in 0..BLOCK_B {
            x[r * BLOCK_D + (r % BLOCK_K)] = 1.0;
        }
        for j in 0..BLOCK_K {
            m[j * BLOCK_D + j] = 1.0;
        }
        let (ids, sims) = rt.assign_block(&x, &m).expect("assign");
        for r in 0..BLOCK_B {
            assert_eq!(ids[r], (r % BLOCK_K) as u32, "row {r}");
            assert!((sims[r] - 1.0).abs() < 1e-5);
        }
    }
}
