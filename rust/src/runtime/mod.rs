//! PJRT runtime — executes the AOT-compiled JAX/Pallas dense-block
//! computations (`artifacts/*.hlo.txt`) from Rust, with **no Python on
//! the execution path**.
//!
//! Build path (see `python/compile/aot.py`): JAX lowers the Layer-2
//! model (which calls the Layer-1 Pallas kernel) to StableHLO, converts
//! it to an `XlaComputation`, and dumps **HLO text** — the interchange
//! format the original image's xla_extension 0.5.1 accepts.
//!
//! ## Feature gating (offline-green builds)
//!
//! The XLA PJRT toolchain (`xla_extension` + the `xla` bindings crate)
//! is not available in the offline build environment, so this module is
//! gated behind the **`pjrt`** cargo feature:
//!
//! * **default build** (no features): only the dependency-free helpers
//!   ([`pad_to`], [`densify_top_terms`], the block-shape constants) are
//!   functional; [`PjrtRuntime::new`] returns a descriptive error so
//!   call sites (the `skm info` subcommand, the hybrid examples, the
//!   integration test) compile and degrade gracefully.
//! * **`--features pjrt`**: [`PjrtRuntime`] compiles a **native CPU
//!   executor** for the two known artifacts — `assign_block` and
//!   `kmeans_step` — implementing exactly the dense math of
//!   `python/compile/model.py` (and of the pure-Rust reference in
//!   `examples/hybrid_dense.rs`), still with no Python/XLA dependency.
//!   Arbitrary HLO execution ([`PjrtRuntime::execute_f32`]) keeps a
//!   stub error path; relinking the real `xla` bindings is a drop-in
//!   replacement for the two `native_*` functions below.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Expected dense-block shapes, kept in sync with `python/compile/aot.py`
/// (`BLOCK_B`, `BLOCK_K`, `BLOCK_D` there).
pub const BLOCK_B: usize = 64;
pub const BLOCK_K: usize = 32;
pub const BLOCK_D: usize = 256;

/// A PJRT-style executor rooted at an artifacts directory. See the
/// module docs for what each feature configuration provides.
pub struct PjrtRuntime {
    artifacts_dir: PathBuf,
}

impl PjrtRuntime {
    /// Create an executor rooted at an artifacts directory. Errors when
    /// the crate was built without the `pjrt` feature.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        if !cfg!(feature = "pjrt") {
            bail!(
                "skm was built without the `pjrt` feature; rebuild with \
                 `cargo build --features pjrt` to enable the runtime module"
            );
        }
        Ok(Self {
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    /// Default artifacts directory: `$SKM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SKM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        "native-cpu (xla backend not linked)".to_string()
    }

    /// True if the named artifact exists on disk.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Path of an artifact, erroring when it is missing (the native
    /// executor still insists the AOT pipeline ran, so the cross-check
    /// examples exercise the same preconditions as the XLA-linked
    /// build).
    fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {path:?} not found (run `make artifacts`)");
        }
        Ok(path)
    }

    /// Execute an artifact on f32 inputs with the given shapes.
    ///
    /// Stub error path: executing *arbitrary* HLO requires the XLA PJRT
    /// backend, which is not linked in this build; only the two known
    /// dense-block entry points ([`PjrtRuntime::assign_block`],
    /// [`PjrtRuntime::kmeans_step`]) have native implementations.
    pub fn execute_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        for (data, shape) in inputs {
            let expected: i64 = shape.iter().product();
            anyhow::ensure!(
                expected as usize == data.len(),
                "shape {shape:?} wants {expected} elements, got {}",
                data.len()
            );
        }
        let path = self.artifact_path(name)?;
        bail!(
            "cannot execute {path:?}: the XLA PJRT backend is not linked into \
             this build (native implementations exist only for assign_block \
             and kmeans_step)"
        );
    }

    /// Dense-block assignment: given a `B×D` block of objects and `K×D`
    /// means (both dense f32, row-major), returns `(argmax ids, best
    /// sims)`.
    ///
    /// Shapes must match the compiled block ([`BLOCK_B`], [`BLOCK_K`],
    /// [`BLOCK_D`]); use [`pad_to`] helpers for partial blocks.
    pub fn assign_block(&mut self, x: &[f32], m: &[f32]) -> Result<(Vec<u32>, Vec<f32>)> {
        self.artifact_path("assign_block")
            .context("assign_block artifact")?;
        anyhow::ensure!(x.len() == BLOCK_B * BLOCK_D, "x must be BLOCK_B x BLOCK_D");
        anyhow::ensure!(m.len() == BLOCK_K * BLOCK_D, "m must be BLOCK_K x BLOCK_D");
        Ok(native_assign_block(x, m))
    }

    /// One dense spherical-k-means step: returns `(assignments, new
    /// unit-norm means (K×D), objective)`.
    pub fn kmeans_step(&mut self, x: &[f32], m: &[f32]) -> Result<(Vec<u32>, Vec<f32>, f32)> {
        self.artifact_path("kmeans_step")
            .context("kmeans_step artifact")?;
        anyhow::ensure!(x.len() == BLOCK_B * BLOCK_D, "x must be BLOCK_B x BLOCK_D");
        anyhow::ensure!(m.len() == BLOCK_K * BLOCK_D, "m must be BLOCK_K x BLOCK_D");
        Ok(native_kmeans_step(x, m))
    }
}

/// Native argmax-similarity over one dense block — the same math the
/// AOT `assign_block` artifact encodes (`python/compile/model.py`).
fn native_assign_block(x: &[f32], m: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let mut ids = vec![0u32; BLOCK_B];
    let mut sims = vec![0.0f32; BLOCK_B];
    for r in 0..BLOCK_B {
        let xr = &x[r * BLOCK_D..(r + 1) * BLOCK_D];
        let (mut best, mut bestv) = (0usize, f32::NEG_INFINITY);
        for j in 0..BLOCK_K {
            let mr = &m[j * BLOCK_D..(j + 1) * BLOCK_D];
            let s: f32 = xr.iter().zip(mr).map(|(a, b)| a * b).sum();
            if s > bestv {
                bestv = s;
                best = j;
            }
        }
        ids[r] = best as u32;
        sims[r] = bestv;
    }
    (ids, sims)
}

/// Native dense spherical-k-means step — assignment, member-sum means,
/// L2 normalization; empty/zero clusters keep their previous mean
/// (matching `python/compile/model.py::kmeans_step`).
fn native_kmeans_step(x: &[f32], m: &[f32]) -> (Vec<u32>, Vec<f32>, f32) {
    let (assign, sims) = native_assign_block(x, m);
    let obj: f32 = sims.iter().sum();
    let mut sums = vec![0.0f32; BLOCK_K * BLOCK_D];
    let mut counts = vec![0u32; BLOCK_K];
    for r in 0..BLOCK_B {
        let j = assign[r] as usize;
        counts[j] += 1;
        for t in 0..BLOCK_D {
            sums[j * BLOCK_D + t] += x[r * BLOCK_D + t];
        }
    }
    let mut new_m = m.to_vec();
    for j in 0..BLOCK_K {
        let row = &sums[j * BLOCK_D..(j + 1) * BLOCK_D];
        let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
        if counts[j] > 0 && norm > 0.0 {
            for t in 0..BLOCK_D {
                new_m[j * BLOCK_D + t] = row[t] / norm;
            }
        }
    }
    (assign, new_m, obj)
}

/// Pad a dense row-major `rows×cols` matrix to `target_rows×target_cols`
/// with zeros (partial blocks → full compiled block shapes).
pub fn pad_to(data: &[f32], rows: usize, cols: usize, target_rows: usize, target_cols: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * cols);
    assert!(rows <= target_rows && cols <= target_cols);
    let mut out = vec![0.0f32; target_rows * target_cols];
    for r in 0..rows {
        out[r * target_cols..r * target_cols + cols]
            .copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
    out
}

/// Project sparse rows onto the `proj_d` highest-df terms (term ids
/// `D - proj_d ..`) as dense f32 rows — the dense cross-check subspace
/// used by the hybrid example (see DESIGN.md §2).
pub fn densify_top_terms(
    x: &crate::sparse::CsrMatrix,
    rows: &[usize],
    proj_d: usize,
) -> Vec<f32> {
    let d = x.n_cols();
    let lo = d.saturating_sub(proj_d);
    let mut out = vec![0.0f32; rows.len() * proj_d];
    for (r, &i) in rows.iter().enumerate() {
        let (ts, vs) = x.row(i);
        for (&t, &v) in ts.iter().zip(vs) {
            let t = t as usize;
            if t >= lo {
                out[r * proj_d + (t - lo)] = v as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_roundtrip() {
        let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let p = pad_to(&data, 2, 3, 4, 5);
        assert_eq!(p.len(), 20);
        assert_eq!(&p[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(p[3], 0.0);
        assert_eq!(&p[5..8], &[4.0, 5.0, 6.0]);
        assert_eq!(p[19], 0.0);
    }

    #[test]
    fn densify_top_terms_places_values() {
        use crate::sparse::CsrMatrix;
        let m = CsrMatrix::from_rows(10, &[vec![(1, 0.5), (8, 0.25), (9, 0.75)]]);
        let dense = densify_top_terms(&m, &[0], 4); // terms 6..10
        assert_eq!(dense.len(), 4);
        assert_eq!(dense, vec![0.0, 0.0, 0.25, 0.75]); // term 1 dropped
    }

    /// Without the `pjrt` feature the runtime degrades to a clear error
    /// (the stub error path); with it, construction succeeds.
    #[test]
    fn feature_gate_behavior() {
        let r = PjrtRuntime::new("artifacts");
        if cfg!(feature = "pjrt") {
            assert!(r.is_ok());
        } else {
            let msg = format!("{:#}", r.err().expect("must error without pjrt"));
            assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
        }
    }

    /// The native executor matches a hand-rolled argmax on a block where
    /// object r matches mean r % K exactly (the original PJRT smoke
    /// test, now independent of artifacts).
    #[test]
    fn native_assign_block_identity_pattern() {
        let mut x = vec![0.0f32; BLOCK_B * BLOCK_D];
        let mut m = vec![0.0f32; BLOCK_K * BLOCK_D];
        for r in 0..BLOCK_B {
            x[r * BLOCK_D + (r % BLOCK_K)] = 1.0;
        }
        for j in 0..BLOCK_K {
            m[j * BLOCK_D + j] = 1.0;
        }
        let (ids, sims) = native_assign_block(&x, &m);
        for r in 0..BLOCK_B {
            assert_eq!(ids[r], (r % BLOCK_K) as u32, "row {r}");
            assert!((sims[r] - 1.0).abs() < 1e-5);
        }
    }

    /// The native k-means step keeps unit-norm means and a non-decreasing
    /// objective — the invariants the AOT artifact is cross-checked
    /// against in `examples/hybrid_dense.rs`.
    #[test]
    fn native_kmeans_step_invariants() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(7);
        let mut unit_rows = |rows: usize| {
            let mut x = vec![0.0f32; rows * BLOCK_D];
            for r in 0..rows {
                let mut norm = 0.0f32;
                for t in 0..BLOCK_D {
                    let v = rng.next_f64() as f32 + 1e-3;
                    x[r * BLOCK_D + t] = v;
                    norm += v * v;
                }
                let norm = norm.sqrt();
                for t in 0..BLOCK_D {
                    x[r * BLOCK_D + t] /= norm;
                }
            }
            x
        };
        let x = unit_rows(BLOCK_B);
        let mut m = unit_rows(BLOCK_K);
        let mut prev = f32::NEG_INFINITY;
        for _ in 0..6 {
            let (assign, new_m, obj) = native_kmeans_step(&x, &m);
            assert_eq!(assign.len(), BLOCK_B);
            assert!(assign.iter().all(|&a| (a as usize) < BLOCK_K));
            assert!(obj >= prev - 1e-3, "objective decreased: {prev} -> {obj}");
            for j in 0..BLOCK_K {
                let row = &new_m[j * BLOCK_D..(j + 1) * BLOCK_D];
                let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
                assert!((norm - 1.0).abs() < 1e-4, "mean {j} norm {norm}");
            }
            prev = obj;
            m = new_m;
        }
    }
}
