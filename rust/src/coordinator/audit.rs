//! Exactness audit (DESIGN.md §6): every algorithm is an *acceleration*
//! — from identical seeding it must reproduce MIVI's trajectory. The
//! audit runs a candidate algorithm and MIVI with the same configuration
//! and compares final assignments; any disagreement must be a
//! floating-point tie (the two chosen centroids have similarities equal
//! within tolerance), which we verify by recomputing exact similarities
//! against the *candidate's* final mean set.

use crate::algo::{run_clustering_with, AlgoKind, ClusterConfig, ParConfig};
use crate::index::update_means;
use crate::sparse::Dataset;

#[derive(Debug, Clone)]
pub struct AuditReport {
    pub algo: &'static str,
    pub n: usize,
    /// Objects assigned identically to MIVI.
    pub exact_matches: usize,
    /// Objects assigned differently but provably tied (|Δsim| ≤ tol).
    pub tie_matches: usize,
    /// Genuine divergences (audit failure if > 0).
    pub divergences: usize,
    pub mivi_iterations: usize,
    pub algo_iterations: usize,
    pub objective_gap: f64,
}

impl AuditReport {
    pub fn passed(&self) -> bool {
        self.divergences == 0
    }
}

/// Audit `kind` against MIVI on the given dataset/config (serial).
pub fn audit_equivalence(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    tol: f64,
) -> AuditReport {
    audit_equivalence_with(kind, ds, cfg, tol, &ParConfig::serial())
}

/// [`audit_equivalence`] running both clusterings on the sharded
/// engine. Since the engine is bit-identical to the serial path, the
/// audit verdict cannot depend on `par` — this merely makes large
/// audits faster (the `skm audit --threads N` path).
pub fn audit_equivalence_with(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    tol: f64,
    par: &ParConfig,
) -> AuditReport {
    let base = run_clustering_with(AlgoKind::Mivi, ds, cfg, par);
    let cand = run_clustering_with(kind, ds, cfg, par);

    let mut exact = 0usize;
    let mut ties = 0usize;
    let mut div = 0usize;

    // Recompute exact similarities against the candidate's converged
    // means for any disagreeing object.
    let upd = update_means(ds, &cand.assign, cfg.k, None, None);
    for i in 0..ds.n() {
        if base.assign[i] == cand.assign[i] {
            exact += 1;
            continue;
        }
        let sim_to = |j: u32| {
            let dense = upd.means.m.row_dense(j as usize);
            ds.x.row_dot_dense(i, &dense)
        };
        let a = sim_to(base.assign[i]);
        let b = sim_to(cand.assign[i]);
        if (a - b).abs() <= tol {
            ties += 1;
        } else {
            div += 1;
        }
    }

    AuditReport {
        algo: kind.name(),
        n: ds.n(),
        exact_matches: exact,
        tie_matches: ties,
        divergences: div,
        mivi_iterations: base.iterations(),
        algo_iterations: cand.iterations(),
        objective_gap: (base.objective - cand.objective).abs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny, CorpusSpec};
    use crate::sparse::build_dataset;

    #[test]
    fn audit_all_algorithms_on_tiny() {
        let c = generate(&CorpusSpec {
            n_docs: 500,
            ..tiny(202)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 10,
            seed: 30,
            ..Default::default()
        };
        for &kind in AlgoKind::all() {
            if kind == AlgoKind::Mivi {
                continue;
            }
            let rep = audit_equivalence(kind, &ds, &cfg, 1e-9);
            assert!(
                rep.passed(),
                "{}: {} divergences (exact {}, ties {})",
                rep.algo,
                rep.divergences,
                rep.exact_matches,
                rep.tie_matches
            );
            assert!(rep.objective_gap < 1e-6, "{}", rep.algo);
        }
    }
}
