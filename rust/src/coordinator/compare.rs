//! Multi-algorithm comparison harness — the machinery behind Tables II,
//! IV, VI, VIII and the Appendix-E/F/G tables: run each algorithm on the
//! same dataset/seed, collect Mult / elapsed-time / memory plus hardware
//! PMU readings (or their software proxies), and print the paper-style
//! rate tables (rates relative to a reference algorithm).

use crate::algo::{run_clustering_with, AlgoKind, ClusterConfig, ClusterOutput, ParConfig};
use crate::metrics::perf::{PerfGroup, PerfReading};
use crate::sparse::Dataset;
use crate::util::io::{fmt_sig, Table};

/// Everything the paper's tables report about one algorithm run.
#[derive(Debug, Clone)]
pub struct AlgoRunSummary {
    pub name: &'static str,
    pub iterations: usize,
    pub converged: bool,
    pub objective: f64,
    /// Average multiplications per iteration.
    pub avg_mult: f64,
    /// Average elapsed seconds per iteration (assignment + update).
    pub avg_secs: f64,
    pub avg_assign_secs: f64,
    pub avg_update_secs: f64,
    pub max_mem_gb: f64,
    /// Hardware counters over the whole run, if the PMU is accessible.
    pub perf: Option<PerfReading>,
    /// Software proxies (always available).
    pub sw_irregular_branches: u64,
    pub sw_cold_touches: u64,
    pub sw_sqrts: u64,
    pub final_cpr: f64,
}

/// Run one algorithm and summarize it, measuring hardware counters
/// around the whole clustering when the PMU is available.
///
/// Thread plumbing: the sharded engine configuration is read from the
/// `SKM_THREADS` / `SKM_SHARD` environment knobs (default: serial), so
/// every bench harness and preset runs parallel without signature
/// churn. The engine is bit-identical to the serial path, so only the
/// elapsed-time columns are affected. Use [`run_and_summarize_with`]
/// to pass an explicit [`ParConfig`] (e.g. from the `--threads` CLI
/// flag).
pub fn run_and_summarize(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
) -> (ClusterOutput, AlgoRunSummary) {
    run_and_summarize_with(kind, ds, cfg, &ParConfig::from_env())
}

/// [`run_and_summarize`] with an explicit sharded-engine configuration.
pub fn run_and_summarize_with(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    par: &ParConfig,
) -> (ClusterOutput, AlgoRunSummary) {
    let group = PerfGroup::try_new();
    if let Some(g) = &group {
        g.start();
    }
    let out = run_clustering_with(kind, ds, cfg, par);
    let perf = group.map(|g| g.stop());

    let iters = out.iterations().max(1) as f64;
    let summary = AlgoRunSummary {
        name: kind.name(),
        iterations: out.iterations(),
        converged: out.converged,
        objective: out.objective,
        avg_mult: out.avg_mult(),
        avg_secs: out.total_secs() / iters,
        avg_assign_secs: out.total_assign_secs() / iters,
        avg_update_secs: out.total_update_secs() / iters,
        max_mem_gb: out.max_mem_bytes as f64 / 1e9,
        perf,
        sw_irregular_branches: out.logs.iter().map(|l| l.counters.irregular_branches).sum(),
        sw_cold_touches: out.logs.iter().map(|l| l.counters.cold_touches).sum(),
        sw_sqrts: out.logs.iter().map(|l| l.counters.sqrts).sum(),
        final_cpr: out.logs.last().map(|l| l.cpr).unwrap_or(1.0),
    };
    (out, summary)
}

/// Build the paper-style rate table (e.g. Table IV): every column is the
/// ratio of an algorithm's value to the reference algorithm's value.
/// When the PMU was available, Inst/BM/LLCM come from hardware counters;
/// otherwise from the software proxies (suffixed `~`).
pub fn comparison_rate_table(summaries: &[AlgoRunSummary], reference: &str) -> Table {
    let rf = summaries
        .iter()
        .find(|s| s.name == reference)
        .unwrap_or_else(|| panic!("reference algorithm {reference} not in summaries"));
    let hw = summaries.iter().all(|s| s.perf.is_some());

    let mut t = Table::new(vec![
        "Algo", "AvgMult", "AvgTime", "Inst", "BM", "LLCM", "MaxMEM",
    ]);
    let rate = |x: f64, r: f64| {
        if r > 0.0 {
            fmt_sig(x / r)
        } else if x == 0.0 {
            "1.0 (0/0)".to_string()
        } else {
            // Reference count is zero (e.g. MIVI has no irregular
            // branches under the software model): show the absolute
            // count instead of a meaningless ratio.
            format!("{} (abs)", fmt_sig(x))
        }
    };
    for s in summaries {
        let (inst, bm, llcm) = if hw {
            let p = s.perf.as_ref().unwrap();
            let q = rf.perf.as_ref().unwrap();
            (
                rate(p.instructions as f64, q.instructions as f64),
                rate(p.branch_misses as f64, q.branch_misses as f64),
                rate(p.llc_load_misses as f64, q.llc_load_misses as f64),
            )
        } else {
            // Software proxies: Mult ≈ instructions driver; irregular
            // branches ≈ BM; cold touches ≈ LLCM.
            (
                rate(s.avg_mult, rf.avg_mult),
                rate(
                    s.sw_irregular_branches as f64,
                    rf.sw_irregular_branches.max(1) as f64,
                ),
                rate(s.sw_cold_touches as f64, rf.sw_cold_touches.max(1) as f64),
            )
        };
        t.row(vec![
            s.name.to_string(),
            rate(s.avg_mult, rf.avg_mult),
            rate(s.avg_secs, rf.avg_secs),
            inst,
            bm,
            llcm,
            rate(s.max_mem_gb, rf.max_mem_gb),
        ]);
    }
    t
}

/// Absolute-values table (the Appendix-E/F/G style): avg mult, avg time
/// with assignment/update split, max memory.
pub fn absolute_table(summaries: &[AlgoRunSummary]) -> Table {
    let mut t = Table::new(vec![
        "Algo",
        "Iters",
        "AvgMult/iter",
        "AvgTime/iter(s)",
        "[assign, update]",
        "MaxMEM(GB)",
        "Objective",
    ]);
    for s in summaries {
        t.row(vec![
            s.name.to_string(),
            s.iterations.to_string(),
            fmt_sig(s.avg_mult),
            fmt_sig(s.avg_secs),
            format!(
                "[{}, {}]",
                fmt_sig(s.avg_assign_secs),
                fmt_sig(s.avg_update_secs)
            ),
            fmt_sig(s.max_mem_gb),
            fmt_sig(s.objective),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;

    #[test]
    fn summarize_and_tables() {
        let c = generate(&tiny(123));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 8,
            seed: 17,
            ..Default::default()
        };
        let (_, a) = run_and_summarize(AlgoKind::Mivi, &ds, &cfg);
        let (_, b) = run_and_summarize(AlgoKind::EsIcp, &ds, &cfg);
        assert_eq!(a.iterations, b.iterations);
        let t = comparison_rate_table(&[a.clone(), b.clone()], "ES-ICP");
        let text = t.render();
        assert!(text.contains("MIVI") && text.contains("ES-ICP"));
        // Reference row rates are 1 by construction.
        let es_row = &t.rows[1];
        assert_eq!(es_row[1], "1.0000");
        let abs = absolute_table(&[a, b]);
        assert_eq!(abs.rows.len(), 2);
    }

    #[test]
    #[should_panic(expected = "not in summaries")]
    fn missing_reference_panics() {
        comparison_rate_table(&[], "ES-ICP");
    }
}
