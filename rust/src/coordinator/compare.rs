//! Multi-algorithm comparison harness — the machinery behind Tables II,
//! IV, VI, VIII and the Appendix-E/F/G tables: run each algorithm on the
//! same dataset/seed, collect Mult / elapsed-time / memory plus hardware
//! PMU readings (or their software proxies), and print the paper-style
//! rate tables (rates relative to a reference algorithm).

use crate::algo::{run_clustering_with, AlgoKind, ClusterConfig, ClusterOutput, ParConfig};
use crate::metrics::perf::{PerfGroup, PerfReading};
use crate::sparse::Dataset;
use crate::util::io::{fmt_sig, Table};
use crate::util::json::Json;

/// Everything the paper's tables report about one algorithm run.
#[derive(Debug, Clone)]
pub struct AlgoRunSummary {
    pub name: &'static str,
    pub iterations: usize,
    pub converged: bool,
    pub objective: f64,
    /// Average multiplications per iteration.
    pub avg_mult: f64,
    /// Average elapsed seconds per iteration (assignment + update).
    pub avg_secs: f64,
    pub avg_assign_secs: f64,
    /// Update step in the paper's footnote-7 sense (mean construction +
    /// index rebuild + EstParams).
    pub avg_update_secs: f64,
    /// Index-maintenance (rebuild) share of `avg_update_secs`.
    pub avg_rebuild_secs: f64,
    /// Assignment gathering-phase seconds per iteration. Summed across
    /// shard workers: CPU-seconds under `--threads N` (may exceed
    /// `avg_assign_secs`), wall time in serial runs.
    pub avg_gather_secs: f64,
    /// Assignment verification-phase seconds per iteration (same units
    /// caveat as `avg_gather_secs`).
    pub avg_verify_secs: f64,
    pub max_mem_gb: f64,
    /// Hardware counters over the whole run, if the PMU is accessible.
    pub perf: Option<PerfReading>,
    /// Software proxies (always available).
    pub sw_irregular_branches: u64,
    pub sw_cold_touches: u64,
    pub sw_sqrts: u64,
    pub final_cpr: f64,
}

/// Run one algorithm and summarize it, measuring hardware counters
/// around the whole clustering when the PMU is available.
///
/// Thread plumbing: the sharded engine configuration is read from the
/// `SKM_THREADS` / `SKM_SHARD` environment knobs (default: serial), so
/// every bench harness and preset runs parallel without signature
/// churn. The engine is bit-identical to the serial path, so only the
/// elapsed-time columns are affected. Use [`run_and_summarize_with`]
/// to pass an explicit [`ParConfig`] (e.g. from the `--threads` CLI
/// flag).
pub fn run_and_summarize(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
) -> (ClusterOutput, AlgoRunSummary) {
    run_and_summarize_with(kind, ds, cfg, &ParConfig::from_env())
}

/// [`run_and_summarize`] with an explicit sharded-engine configuration.
pub fn run_and_summarize_with(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    par: &ParConfig,
) -> (ClusterOutput, AlgoRunSummary) {
    let group = PerfGroup::try_new();
    if let Some(g) = &group {
        g.start();
    }
    let out = run_clustering_with(kind, ds, cfg, par);
    let perf = group.map(|g| g.stop());

    let iters = out.iterations().max(1) as f64;
    let summary = AlgoRunSummary {
        name: kind.name(),
        iterations: out.iterations(),
        converged: out.converged,
        objective: out.objective,
        avg_mult: out.avg_mult(),
        avg_secs: out.total_secs() / iters,
        avg_assign_secs: out.total_assign_secs() / iters,
        avg_update_secs: out.total_update_secs() / iters,
        avg_rebuild_secs: out.total_rebuild_secs() / iters,
        avg_gather_secs: out.total_gather_secs() / iters,
        avg_verify_secs: out.total_verify_secs() / iters,
        max_mem_gb: out.max_mem_bytes as f64 / 1e9,
        perf,
        sw_irregular_branches: out.logs.iter().map(|l| l.counters.irregular_branches).sum(),
        sw_cold_touches: out.logs.iter().map(|l| l.counters.cold_touches).sum(),
        sw_sqrts: out.logs.iter().map(|l| l.counters.sqrts).sum(),
        final_cpr: out.logs.last().map(|l| l.cpr).unwrap_or(1.0),
    };
    (out, summary)
}

/// Build the paper-style rate table (e.g. Table IV): every column is the
/// ratio of an algorithm's value to the reference algorithm's value.
/// When the PMU was available, Inst/BM/LLCM come from hardware counters;
/// otherwise from the software proxies (suffixed `~`).
pub fn comparison_rate_table(summaries: &[AlgoRunSummary], reference: &str) -> Table {
    let rf = summaries
        .iter()
        .find(|s| s.name == reference)
        .unwrap_or_else(|| panic!("reference algorithm {reference} not in summaries"));
    let hw = summaries.iter().all(|s| s.perf.is_some());

    let mut t = Table::new(vec![
        "Algo", "AvgMult", "AvgTime", "Inst", "BM", "LLCM", "MaxMEM",
    ]);
    let rate = |x: f64, r: f64| {
        if r > 0.0 {
            fmt_sig(x / r)
        } else if x == 0.0 {
            "1.0 (0/0)".to_string()
        } else {
            // Reference count is zero (e.g. MIVI has no irregular
            // branches under the software model): show the absolute
            // count instead of a meaningless ratio.
            format!("{} (abs)", fmt_sig(x))
        }
    };
    for s in summaries {
        let (inst, bm, llcm) = if hw {
            let p = s.perf.as_ref().unwrap();
            let q = rf.perf.as_ref().unwrap();
            (
                rate(p.instructions as f64, q.instructions as f64),
                rate(p.branch_misses as f64, q.branch_misses as f64),
                rate(p.llc_load_misses as f64, q.llc_load_misses as f64),
            )
        } else {
            // Software proxies: Mult ≈ instructions driver; irregular
            // branches ≈ BM; cold touches ≈ LLCM.
            (
                rate(s.avg_mult, rf.avg_mult),
                rate(
                    s.sw_irregular_branches as f64,
                    rf.sw_irregular_branches.max(1) as f64,
                ),
                rate(s.sw_cold_touches as f64, rf.sw_cold_touches.max(1) as f64),
            )
        };
        t.row(vec![
            s.name.to_string(),
            rate(s.avg_mult, rf.avg_mult),
            rate(s.avg_secs, rf.avg_secs),
            inst,
            bm,
            llcm,
            rate(s.max_mem_gb, rf.max_mem_gb),
        ]);
    }
    t
}

/// Absolute-values table (the Appendix-E/F/G style): avg mult, avg time
/// with assignment/update split, max memory.
pub fn absolute_table(summaries: &[AlgoRunSummary]) -> Table {
    let mut t = Table::new(vec![
        "Algo",
        "Iters",
        "AvgMult/iter",
        "AvgTime/iter(s)",
        "[assign, update]",
        "MaxMEM(GB)",
        "Objective",
    ]);
    for s in summaries {
        t.row(vec![
            s.name.to_string(),
            s.iterations.to_string(),
            fmt_sig(s.avg_mult),
            fmt_sig(s.avg_secs),
            format!(
                "[{}, {}]",
                fmt_sig(s.avg_assign_secs),
                fmt_sig(s.avg_update_secs)
            ),
            fmt_sig(s.max_mem_gb),
            fmt_sig(s.objective),
        ]);
    }
    t
}

/// Machine-readable report for one clustering run: dataset shape,
/// iteration count, phase-level timing breakdown (assign split into
/// gather/verify, update split into mean-update/rebuild), total
/// `OpCounters`, and the per-iteration trajectory. Consumed by the
/// `skm … --bench-json <path>` flag and the hot-path bench baseline.
pub fn cluster_run_json(ds: &Dataset, cfg: &ClusterConfig, out: &ClusterOutput) -> Json {
    let c = out.total_counters();
    let per_iter: Vec<Json> = out
        .logs
        .iter()
        .map(|l| {
            Json::obj(vec![
                ("iter", Json::UInt(l.iter as u64)),
                ("mult", Json::UInt(l.counters.mult)),
                ("cpr", Json::Num(l.cpr)),
                ("assign_secs", Json::Num(l.assign_secs)),
                ("gather_secs", Json::Num(l.gather_secs)),
                ("verify_secs", Json::Num(l.verify_secs)),
                ("update_secs", Json::Num(l.update_secs)),
                ("rebuild_secs", Json::Num(l.rebuild_secs)),
                ("changes", Json::UInt(l.changes as u64)),
                ("n_moving", Json::UInt(l.n_moving as u64)),
                ("mem_bytes", Json::UInt(l.mem_bytes as u64)),
                ("objective", Json::Num(l.objective)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("algo", Json::str(out.algo.name())),
        (
            "dataset",
            Json::obj(vec![
                ("name", Json::str(ds.name.clone())),
                ("n", Json::UInt(ds.n() as u64)),
                ("d", Json::UInt(ds.d() as u64)),
                ("k", Json::UInt(cfg.k as u64)),
                ("seed", Json::UInt(cfg.seed)),
            ]),
        ),
        ("iterations", Json::UInt(out.iterations() as u64)),
        ("converged", Json::Bool(out.converged)),
        ("objective", Json::Num(out.objective)),
        ("max_mem_bytes", Json::UInt(out.max_mem_bytes as u64)),
        (
            "t_th",
            out.t_th.map(|t| Json::UInt(t as u64)).unwrap_or(Json::Null),
        ),
        ("v_th", out.v_th.map(Json::Num).unwrap_or(Json::Null)),
        (
            "phase_secs",
            Json::obj(vec![
                ("assign", Json::Num(out.total_assign_secs())),
                ("gather", Json::Num(out.total_gather_secs())),
                ("verify", Json::Num(out.total_verify_secs())),
                ("update", Json::Num(out.total_update_secs() - out.total_rebuild_secs())),
                ("rebuild", Json::Num(out.total_rebuild_secs())),
                ("total", Json::Num(out.total_secs())),
            ]),
        ),
        (
            "counters",
            Json::obj(vec![
                ("mult", Json::UInt(c.mult)),
                ("irregular_branches", Json::UInt(c.irregular_branches)),
                ("cold_touches", Json::UInt(c.cold_touches)),
                ("candidates", Json::UInt(c.candidates)),
                ("exact_sims", Json::UInt(c.exact_sims)),
                ("sqrts", Json::UInt(c.sqrts)),
            ]),
        ),
        ("per_iter", Json::Arr(per_iter)),
    ])
}

/// [`cluster_run_json`] over several runs (the `compare --bench-json`
/// shape): one entry per algorithm, same dataset.
pub fn compare_runs_json(ds: &Dataset, cfg: &ClusterConfig, outs: &[ClusterOutput]) -> Json {
    Json::obj(vec![
        (
            "dataset",
            Json::obj(vec![
                ("name", Json::str(ds.name.clone())),
                ("n", Json::UInt(ds.n() as u64)),
                ("d", Json::UInt(ds.d() as u64)),
                ("k", Json::UInt(cfg.k as u64)),
                ("seed", Json::UInt(cfg.seed)),
            ]),
        ),
        (
            "runs",
            Json::Arr(outs.iter().map(|o| cluster_run_json(ds, cfg, o)).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;

    #[test]
    fn run_json_has_phases_and_counters() {
        let c = generate(&tiny(124));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 8,
            seed: 5,
            ..Default::default()
        };
        let (out, _) = run_and_summarize(AlgoKind::EsIcp, &ds, &cfg);
        let j = cluster_run_json(&ds, &cfg, &out);
        let text = j.render();
        for key in [
            "\"phase_secs\"",
            "\"gather\"",
            "\"verify\"",
            "\"rebuild\"",
            "\"per_iter\"",
            "\"counters\"",
            "\"mult\"",
        ] {
            assert!(text.contains(key), "missing {key} in {text}");
        }
        // Phase-breakdown consistency (serial run): the per-object
        // probes time subsets of the assignment loop, so their sum can
        // only fall short of the wall time, never exceed it.
        assert!(out.total_gather_secs() > 0.0, "gather never timed");
        for l in &out.logs {
            assert!(
                l.gather_secs + l.verify_secs <= l.assign_secs + 1e-6,
                "iter {}: phase sum {} + {} exceeds assign wall time {}",
                l.iter,
                l.gather_secs,
                l.verify_secs,
                l.assign_secs
            );
        }
    }

    #[test]
    fn summarize_and_tables() {
        let c = generate(&tiny(123));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 8,
            seed: 17,
            ..Default::default()
        };
        let (_, a) = run_and_summarize(AlgoKind::Mivi, &ds, &cfg);
        let (_, b) = run_and_summarize(AlgoKind::EsIcp, &ds, &cfg);
        assert_eq!(a.iterations, b.iterations);
        let t = comparison_rate_table(&[a.clone(), b.clone()], "ES-ICP");
        let text = t.render();
        assert!(text.contains("MIVI") && text.contains("ES-ICP"));
        // Reference row rates are 1 by construction.
        let es_row = &t.rows[1];
        assert_eq!(es_row[1], "1.0000");
        let abs = absolute_table(&[a, b]);
        assert_eq!(abs.rows.len(), 2);
    }

    #[test]
    #[should_panic(expected = "not in summaries")]
    fn missing_reference_panics() {
        comparison_rate_table(&[], "ES-ICP");
    }
}
