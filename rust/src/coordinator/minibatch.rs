//! Mini-batch / streaming spherical k-means on the structured index
//! (§Stream tentpole).
//!
//! The full-batch driver ([`crate::algo::run_clustering_with`]) walks
//! Lloyd iterations over all N objects. At traffic scale (the ROADMAP's
//! million-document streams) that is the wrong granularity: fresh
//! documents arrive continuously and each assignment pass over the full
//! corpus costs O(N) before a single centroid moves. The driver here
//! processes **batches**:
//!
//! 1. pick a batch (a sequential window sweeping the corpus in storage
//!    order, or a seeded random sample without replacement),
//! 2. run the assignment step for the batch only, through the existing
//!    [`Assigner`] machinery ([`Assigner::assign_span`] — the same
//!    per-object routines, sharded and bit-deterministic),
//! 3. fold the batch into the mean set **in place** with per-centroid
//!    count-decay learning rates
//!    ([`crate::index::update_means_minibatch_inplace`]: touched mean
//!    rows spliced into the [`crate::index::RowSlab`], batch-member ρ
//!    mutated in place, objective maintained as a running sum of the
//!    per-member deltas — O(batch + nnz of touched rows), never O(n)),
//! 4. let the incremental maintainers splice only the touched centroids
//!    into the structured index (`index::maintain`, the PR-2 engine:
//!    per-batch index cost scales with the moved mass, and the
//!    `SKM_SPLICE_FRAC` dirty-fraction fallback applies per batch).
//!
//! ## Determinism and the Lloyd-parity contract
//!
//! Batch selection is a pure function of `(schedule, sample_seed,
//! round)`; the batch assignment is the sharded engine (bit-identical
//! for any thread/shard count); the update is serial batch-sized work;
//! counters merge in fixed run order. Hence **same seed ⇒ identical
//! assignments, ρ, objectives, and merged [`OpCounters`] for any thread
//! count** — enforced by `rust/tests/minibatch.rs`.
//!
//! With `batch == n` and `decay == 0` every round degenerates to a full
//! Lloyd iteration, and the driver is **bit-exact** against
//! [`crate::algo::run_clustering_with`]: same assignment trajectory,
//! same per-round objective bits, same counters, same convergence round
//! (also enforced by `rust/tests/minibatch.rs`).
//!
//! ## Incremental objective accounting
//!
//! The logged objective is a running sum `obj_sum` updated with the
//! update step's per-member ρ deltas (O(batch) per round), **exactly
//! re-summed over the full ρ vector at every epoch boundary** so the
//! low-order float bits cannot drift run-to-run with the resume point.
//! Between boundaries the value is still fully deterministic (fixed
//! member order), it merely differs in low bits from what a per-round
//! full re-sum would produce. At `batch == n` every round IS an epoch
//! boundary, so the re-sum fires every round and the logged objective
//! is bit-exactly the full-batch one — Lloyd parity intact.
//!
//! ## Epoch wrap (sequential schedule)
//!
//! The sequential schedule wraps batches across the epoch boundary
//! (`[(0, rem), (lo, n)]`) instead of emitting a ragged short tail:
//! every round now feeds the count-decay update a full `b` objects, so
//! no round computes learning rates from a tiny tail `m_j`. With
//! `batch == n` the window is always exactly `[0, n)` and nothing
//! changes (Lloyd parity intact); for smaller batches the trajectory
//! differs from the pre-wrap driver **by design** — the old short tail
//! round and its skewed η are gone.
//!
//! ## What partial batches approximate
//!
//! An object outside the current batch keeps its stored ρ (similarity
//! to its centroid as of its *last* refresh). If its centroid has moved
//! since, that threshold is stale — the pruning filters may over- or
//! under-prune relative to an exact pass, which is the standard
//! mini-batch approximation (Sculley-style); results remain
//! deterministic. The ICP auxiliary filter is *never* armed from stale
//! state: the driver tracks each centroid's last-moved round and each
//! object's last-refreshed round, and clears the object's eligibility
//! flag when the centroid moved after the refresh (an invariant-centroid
//! argument from stale ρ would be unsound, not merely approximate).

use crate::algo::{
    make_assigner, seed_means, AlgoKind, Assigner, ClusterConfig, IterState, ParConfig,
};
use crate::index::{update_means_minibatch_inplace, MbUpdateScratch};
use crate::metrics::counters::OpCounters;
use crate::persist::checkpoint::{CheckpointSpec, CheckpointState, MbStateRef, RunFingerprint};
use crate::sparse::Dataset;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::util::timer::Stopwatch;

/// How each round's batch is drawn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchSchedule {
    /// Contiguous windows sweeping the corpus in storage order (the
    /// streaming mode: documents are consumed in arrival order, e.g.
    /// straight out of `corpus::loader`'s UCI reader).
    Sequential,
    /// A seeded random sample without replacement per round
    /// (Floyd-style reservoir draw via [`Pcg32::sample_distinct`]) —
    /// the classic mini-batch k-means regime.
    Reservoir,
}

impl BatchSchedule {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" | "stream" => BatchSchedule::Sequential,
            "reservoir" | "random" | "sample" => BatchSchedule::Reservoir,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            BatchSchedule::Sequential => "sequential",
            BatchSchedule::Reservoir => "reservoir",
        }
    }
}

/// Configuration of the mini-batch / streaming driver.
#[derive(Debug, Clone)]
pub struct MiniBatchConfig {
    /// Objects per round (clamped to `[1, n]`; `batch == n` with
    /// `decay == 0` is bit-exact full-batch Lloyd).
    pub batch: usize,
    pub schedule: BatchSchedule,
    /// Count-decay forgetting factor: per batch, `c_j ← decay·c_j + m_j`
    /// and the learning rate is `η_j = m_j / c_j`. `1.0` = classic
    /// count decay (Sculley-style mini-batch k-means), `< 1` forgets
    /// old batches (drifting streams), `0.0` = memoryless (`η = 1`,
    /// batch means replace centroids — the Lloyd-parity mode).
    pub decay: f64,
    /// Hard cap on rounds (one batch each).
    pub max_rounds: usize,
    /// Seed of the batch-sampling stream (Reservoir schedule). Kept
    /// separate from [`ClusterConfig::seed`] so seeding and sampling
    /// can be varied independently.
    pub sample_seed: u64,
}

/// Epoch budget of the default policy — the single source for both
/// [`MiniBatchConfig::default_for`] and the CLI's `--rounds` default
/// (which must rescale it when `--batch-size` overrides the batch).
pub const DEFAULT_EPOCH_BUDGET: usize = 64;

impl MiniBatchConfig {
    /// The one default policy for an `n`-object workload (shared by
    /// `Preset::minibatch_config` and the `skm cluster --minibatch`
    /// flag defaults — one place, so they cannot drift): ~16 sequential
    /// batches per epoch floored at 256 objects, classic count decay,
    /// and a [`DEFAULT_EPOCH_BUDGET`]-epoch round budget.
    pub fn default_for(n: usize) -> Self {
        let n = n.max(1);
        let batch = (n / 16).max(256).min(n);
        let rounds_per_epoch = (n + batch - 1) / batch;
        Self {
            batch,
            schedule: BatchSchedule::Sequential,
            decay: 1.0,
            max_rounds: DEFAULT_EPOCH_BUDGET * rounds_per_epoch,
            sample_seed: 0xba7c_4e5d,
        }
    }

    /// Typed validation, mirroring [`run_minibatch`]'s asserts (which
    /// stay in place to protect the bit path) so fallible callers can
    /// reject bad configs before anything runs.
    pub fn validate(&self) -> crate::error::SkmResult<()> {
        use crate::error::SkmError;
        if self.batch < 1 {
            return Err(SkmError::invalid_config("batch size must be >= 1"));
        }
        if !self.decay.is_finite() || !(0.0..=1.0).contains(&self.decay) {
            return Err(SkmError::invalid_config(format!(
                "decay must be in [0, 1] (got {})",
                self.decay
            )));
        }
        if self.max_rounds < 1 || self.max_rounds >= u32::MAX as usize {
            return Err(SkmError::invalid_config(format!(
                "rounds must be in [1, {}] (got {})",
                u32::MAX - 1,
                self.max_rounds
            )));
        }
        Ok(())
    }
}

/// Fallible front door to [`run_minibatch`]: validates both configs up
/// front ([`crate::error::SkmError::InvalidConfig`]) and contains a
/// panicking run — including a sharded worker fault — as a typed
/// [`crate::error::SkmError::WorkerPanic`]. On success the output is
/// bit-identical to [`run_minibatch`].
pub fn try_run_minibatch(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    mb: &MiniBatchConfig,
    par: &ParConfig,
) -> crate::error::SkmResult<MiniBatchOutput> {
    crate::algo::validate_cluster_config(cfg, ds)?;
    mb.validate()?;
    crate::error::contain("minibatch.run", || run_minibatch(kind, ds, cfg, mb, par))
}

/// Fallible front door to [`run_minibatch_resumable`]: config
/// validation up front, worker panics contained as typed errors, and
/// checkpoint/resume I/O surfaced as [`crate::error::SkmError`].
pub fn try_run_minibatch_resumable(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    mb: &MiniBatchConfig,
    par: &ParConfig,
    ckpt: Option<&CheckpointSpec>,
    resume: Option<&std::path::Path>,
) -> crate::error::SkmResult<MiniBatchOutput> {
    crate::algo::validate_cluster_config(cfg, ds)?;
    mb.validate()?;
    crate::error::contain("minibatch.run", || {
        run_minibatch_resumable(kind, ds, cfg, mb, par, ckpt, resume)
    })
    .and_then(|r| r)
}

/// Per-round record (the mini-batch analog of [`crate::algo::IterLog`]).
#[derive(Debug, Clone)]
pub struct RoundLog {
    /// 1-based round number (`IterState::iter` of this round's batch).
    pub round: usize,
    /// Objects in this round's batch.
    pub batch_len: usize,
    pub counters: OpCounters,
    pub changes: usize,
    pub assign_secs: f64,
    /// Gather/verify split of the batch assignment (CPU-seconds across
    /// shard workers, like [`crate::algo::IterLog`]).
    pub gather_secs: f64,
    pub verify_secs: f64,
    pub update_secs: f64,
    pub rebuild_secs: f64,
    pub n_moving: usize,
    /// Σ_i ρ_i over ALL objects, with objects no batch has refreshed
    /// yet counting as 0 (their −1.0 init sentinels are compensated).
    /// Entries outside the batch carry their last refreshed value, so
    /// in streaming mode this is a running estimate of the Lloyd
    /// objective; at `batch == n` the compensation is a no-op and the
    /// value is bit-exactly the full-batch objective.
    pub objective: f64,
    pub mem_bytes: usize,
}

/// Result of a complete mini-batch run.
pub struct MiniBatchOutput {
    pub algo: AlgoKind,
    pub assign: Vec<u32>,
    pub objective: f64,
    pub rounds: Vec<RoundLog>,
    /// A full epoch's worth of consecutive rounds saw zero assignment
    /// changes before the round cap.
    pub converged: bool,
    pub max_mem_bytes: usize,
    pub t_th: Option<usize>,
    pub v_th: Option<f64>,
}

impl MiniBatchOutput {
    pub fn n_rounds(&self) -> usize {
        self.rounds.len()
    }

    pub fn total_counters(&self) -> OpCounters {
        let mut c = OpCounters::new();
        for r in &self.rounds {
            c.add(&r.counters);
        }
        c
    }

    pub fn total_assign_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.assign_secs).sum()
    }

    pub fn total_update_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.update_secs + r.rebuild_secs).sum()
    }

    pub fn total_rebuild_secs(&self) -> f64 {
        self.rounds.iter().map(|r| r.rebuild_secs).sum()
    }

    /// Objects assigned across all rounds (≥ one epoch ⇒ ≥ n).
    pub fn objects_processed(&self) -> usize {
        self.rounds.iter().map(|r| r.batch_len).sum()
    }
}

/// Decompose a sorted list of distinct object ids into maximal
/// contiguous `(lo, hi)` runs — the span form the assigners consume.
fn runs_from_sorted_ids(ids: &[usize], runs: &mut Vec<(usize, usize)>) {
    runs.clear();
    let mut q = 0usize;
    while q < ids.len() {
        let lo = ids[q];
        let mut hi = lo + 1;
        q += 1;
        while q < ids.len() && ids[q] == hi {
            hi += 1;
            q += 1;
        }
        runs.push((lo, hi));
    }
}

/// Run mini-batch / streaming clustering. See module docs for the
/// determinism and Lloyd-parity contracts.
pub fn run_minibatch(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    mb: &MiniBatchConfig,
    par: &ParConfig,
) -> MiniBatchOutput {
    run_minibatch_resumable(kind, ds, cfg, mb, par, None, None)
        .expect("the driver is infallible without checkpointing")
}

/// [`run_minibatch`] plus crash-safe persistence, mirroring
/// [`crate::algo::run_clustering_resumable`]: an optional periodic
/// [`CheckpointSpec`] and an optional `resume` path. A mini-batch
/// checkpoint additionally carries the decayed per-centroid counts, the
/// ρ/ICP staleness clocks, the batch cursor, and the exact RNG stream
/// position, so a resumed run draws the *same* batch sequence and
/// computes rounds `c+1..` bit-identically to the uninterrupted run
/// (`tests/persist.rs`). `RoundLog`s cover only the resumed segment.
pub fn run_minibatch_resumable(
    kind: AlgoKind,
    ds: &Dataset,
    cfg: &ClusterConfig,
    mb: &MiniBatchConfig,
    par: &ParConfig,
    ckpt: Option<&CheckpointSpec>,
    resume: Option<&std::path::Path>,
) -> crate::error::SkmResult<MiniBatchOutput> {
    let n = ds.n();
    let k = cfg.k;
    let b = mb.batch.clamp(1, n);
    let rounds_per_epoch = (n + b - 1) / b;
    assert!(
        (0.0..=1.0).contains(&mb.decay),
        "decay must be in [0, 1] (got {})",
        mb.decay
    );
    assert!(
        mb.max_rounds < u32::MAX as usize,
        "max_rounds out of range"
    );

    let mut st = IterState {
        k,
        assign: vec![0; n],
        rho: vec![-1.0; n],
        xstate: vec![false; n],
        means: seed_means(ds, k, cfg.seed),
        iter: 1,
    };
    let mut assigner = make_assigner(kind, ds, cfg);

    // Driver state: decayed per-centroid batch mass, incrementally
    // maintained full-assignment sizes, and the ρ/ICP staleness clocks.
    let mut counts = vec![0.0f64; k];
    let mut sizes = vec![0u32; k];
    for &a in &st.assign {
        sizes[a as usize] += 1;
    }
    let mut obs_round = vec![0u32; n];
    // Objects no batch has refreshed yet: their ρ still holds the −1.0
    // init sentinel, which the logged objective compensates (each such
    // object counts as 0, not −1). Zero from the first full span on, so
    // the compensation is a no-op — bit-exact — in Lloyd-parity mode.
    let mut never_seen = n;
    let mut last_moved = vec![0u32; k];
    // The two most recent distinct rounds in which ANY centroid moved.
    // The ICP eligibility gate needs them: centroids that moved at the
    // round producing the current means are in `moving_ids` and get
    // scanned fresh, but a centroid that moved at an *earlier* round
    // since an object's last refresh is invariant now and would be
    // unsoundly skipped — so eligibility requires the object's refresh
    // to postdate every move round except the latest.
    let mut mr_latest = 0u32;
    let mut mr_prev = 0u32;
    let mut rng = Pcg32::new(mb.sample_seed ^ 0x00ba_7c4e);

    let mut cursor = 0usize;
    let mut runs: Vec<(usize, usize)> = Vec::new();
    let mut prev_b: Vec<u32> = Vec::new();
    // Post-assignment ρ of the batch members, captured just before the
    // in-place update so the ICP eligibility refresh can compare old
    // vs new without an O(n) ρ clone.
    let mut old_rho_b: Vec<f64> = Vec::new();
    let mut changed = vec![false; k];
    let mut scratch = MbUpdateScratch::new();
    // Running Σ_i ρ_i (see module docs: per-member deltas between epoch
    // boundaries, exact full re-sum at each boundary). Starts from the
    // −1.0 init sentinels; the logged objective compensates those via
    // `never_seen`.
    let mut obj_sum: f64 = st.rho.iter().sum();
    // Objects processed so far. `st.iter` advances per completed
    // *epoch* (n objects), not per round: the assigners key EstParams
    // and the TA/CS preset switches off `st.iter ∈ {2, 3}`, and those
    // must not fire while most ρ entries still carry the −1.0 init
    // sentinel (EstParams would derive garbage (t_th, v_th) from the
    // clamped sentinel slack and pin it for the whole run). With
    // `batch == n` one epoch IS one round, so `st.iter` takes exactly
    // the full-batch driver's values — Lloyd parity is unaffected.
    let mut processed = 0usize;

    let mut logs: Vec<RoundLog> = Vec::new();
    let mut quiet = 0usize;
    let mut converged = false;
    let mut max_mem = 0usize;
    let mut objective = f64::NAN;
    let mut start_round = 1usize;

    // Run identity, needed by both the save and the resume path.
    let fp = (ckpt.is_some() || resume.is_some())
        .then(|| RunFingerprint::compute(kind, ds, cfg, Some(mb)));

    if let Some(path) = resume {
        let ck = crate::persist::checkpoint::load_minibatch_checkpoint(
            path,
            fp.as_ref().expect("fingerprint exists when resuming"),
            n,
            ds.d(),
            k,
        )?;
        st.assign = ck.base.assign;
        st.rho = ck.base.rho;
        st.xstate = ck.base.xstate;
        st.means = ck.base.means;
        objective = ck.base.objective;
        max_mem = ck.base.max_mem;
        assigner.import_params_state(ds, &ck.base.params);
        counts = ck.mb.counts;
        sizes = ck.mb.sizes;
        obs_round = ck.mb.obs_round;
        never_seen = obs_round.iter().filter(|&&o| o == 0).count();
        last_moved = ck.mb.last_moved;
        mr_latest = ck.mb.mr_latest;
        mr_prev = ck.mb.mr_prev;
        rng = Pcg32::from_raw_state(ck.mb.rng_state, ck.mb.rng_inc);
        cursor = ck.mb.cursor;
        processed = ck.mb.processed;
        quiet = ck.mb.quiet;
        obj_sum = ck.mb.obj_sum;
        st.iter = 1 + processed / n;
        start_round = ck.base.round + 1;
    }

    // Initial structures — from the seed means on a fresh run, from the
    // restored post-update means on a resumed one; carried into the
    // first round's rebuild attribution exactly like the full-batch
    // driver.
    let mut rb_sw = Stopwatch::new();
    rb_sw.start();
    assigner.rebuild(ds, &st, cfg);
    rb_sw.stop();
    let mut carry_rebuild_secs = rb_sw.secs();

    let every = ckpt.map_or(0, |s| s.every);
    // Highest round whose update+rebuild completed / is on disk.
    let mut completed = start_round - 1;
    let mut last_saved = start_round - 1;

    for r in start_round..=mb.max_rounds {
        st.iter = 1 + processed / n;

        // --- batch selection → contiguous runs ---------------------------
        match mb.schedule {
            BatchSchedule::Sequential => {
                // Wrap across the epoch boundary instead of emitting a
                // ragged short tail (a tiny tail m_j skews η — see
                // module docs). The wrapped pair is ascending and
                // disjoint: `rem = lo + b − n ≤ lo` since `b ≤ n`.
                let lo = cursor;
                runs.clear();
                if lo + b <= n {
                    runs.push((lo, lo + b));
                    cursor = if lo + b == n { 0 } else { lo + b };
                } else {
                    let rem = lo + b - n;
                    runs.push((0, rem));
                    runs.push((lo, n));
                    cursor = rem;
                }
            }
            BatchSchedule::Reservoir => {
                let mut ids = rng.sample_distinct(n, b);
                ids.sort_unstable();
                runs_from_sorted_ids(&ids, &mut runs);
            }
        }
        let batch_len: usize = runs.iter().map(|&(lo, hi)| hi - lo).sum();

        // Snapshot the batch's previous assignments (O(batch)): feeds
        // the changed-cluster flags, size deltas, and ICP eligibility.
        prev_b.clear();
        for &(lo, hi) in &runs {
            prev_b.extend_from_slice(&st.assign[lo..hi]);
        }
        // Gate ICP eligibility against staleness. The carried flag is
        // valid only if (a) the object's own centroid has not moved
        // since the object's ρ was last refreshed, and (b) no *other*
        // centroid moved at a round the current moving set no longer
        // reflects: moves at the latest move round are in `moving_ids`
        // (scanned fresh by the G_1 path), but moves at any earlier
        // round since the object's last *comparison* belong to
        // centroids that are invariant now — skipping them would be
        // unsound, not merely approximate. `stale_bar` is the most
        // recent move round whose movers are NOT in the current moving
        // set; the comparison the object's eligibility rests on saw
        // means from the round BEFORE its refresh round, so the gate is
        // strict: moves at `obs_round[i]` itself postdate it.
        let stale_bar = if mr_latest as usize == r - 1 {
            mr_prev
        } else {
            mr_latest
        };
        for &(lo, hi) in &runs {
            for i in lo..hi {
                st.xstate[i] = st.xstate[i]
                    && last_moved[st.assign[i] as usize] <= obs_round[i]
                    && obs_round[i] > stale_bar;
            }
        }

        // --- batch assignment (sharded, bit-deterministic) ---------------
        let mut asg_sw = Stopwatch::new();
        asg_sw.start();
        let mut counters = OpCounters::new();
        let mut changes = 0usize;
        for &(lo, hi) in &runs {
            let (c, ch) = assigner.assign_span(ds, &mut st, lo, hi, par);
            counters.add(&c);
            changes += ch;
        }
        asg_sw.stop();
        let phases = assigner.take_phases();
        processed += batch_len;
        // Did this round's batch complete an epoch? (Triggers the
        // deterministic exact objective re-sum after the update.)
        let epoch_boundary = processed / n > (processed - batch_len) / n;

        let mem = assigner.mem_bytes();
        max_mem = max_mem.max(mem);

        if changes == 0 {
            quiet += 1;
        } else {
            quiet = 0;
        }
        if quiet >= rounds_per_epoch && r > rounds_per_epoch {
            // A full epoch of batches saw no reassignment: log the
            // final (pure-assignment) round, exactly like the
            // full-batch driver's fixed-point exit.
            logs.push(RoundLog {
                round: r,
                batch_len,
                counters,
                changes,
                assign_secs: asg_sw.secs(),
                gather_secs: phases.gather,
                verify_secs: phases.verify,
                update_secs: 0.0,
                rebuild_secs: carry_rebuild_secs,
                n_moving: st.means.n_moving(),
                objective,
                mem_bytes: mem,
            });
            converged = true;
            break;
        }

        // --- changed flags + size bookkeeping (O(batch)) ------------------
        changed.iter_mut().for_each(|c| *c = false);
        let mut off = 0usize;
        for &(lo, hi) in &runs {
            for i in lo..hi {
                let was = prev_b[off];
                off += 1;
                let now = st.assign[i];
                if was != now {
                    changed[was as usize] = true;
                    changed[now as usize] = true;
                    sizes[was as usize] -= 1;
                    sizes[now as usize] += 1;
                } else if mb.decay > 0.0 {
                    // Streaming mode: every batch member nudges its
                    // centroid, membership change or not. (Memoryless
                    // mode keeps Lloyd's invariant-reuse semantics.)
                    changed[now as usize] = true;
                }
            }
        }

        // --- count-decay update step (in place, O(batch)) -----------------
        let mut upd_sw = Stopwatch::new();
        upd_sw.start();
        // Snapshot the batch members' pre-update ρ (O(batch)): the
        // eligibility refresh below needs old-vs-new, and the update
        // mutates `st.rho` in place.
        old_rho_b.clear();
        for &(lo, hi) in &runs {
            old_rho_b.extend_from_slice(&st.rho[lo..hi]);
        }
        let delta = update_means_minibatch_inplace(
            ds, &st.assign, &runs, &mut st.means, &mut st.rho, &changed, &sizes,
            &mut counts, mb.decay, &mut scratch, par,
        );
        obj_sum += delta;
        // ICP eligibility (Eq. 5) and staleness clocks for the batch.
        // A member's ρ is genuinely current only when its cluster was
        // rebuilt this round (recomputed against the new mean) or when
        // the carried value is still in sync (refreshed before, and the
        // mean unmoved since — `last_moved` still holds pre-round
        // values here). A first-visited member of an untouched cluster
        // keeps the −1.0 sentinel: that is NOT a refresh — its clocks
        // stay put (so the objective compensation still covers it) and
        // eligibility must not be armed from the sentinel.
        let mut off = 0usize;
        for &(lo, hi) in &runs {
            for i in lo..hi {
                let a = st.assign[i] as usize;
                let recomputed = st.means.moved[a];
                let carried_current = obs_round[i] > 0 && last_moved[a] <= obs_round[i];
                if recomputed || carried_current {
                    st.xstate[i] = prev_b[off] == st.assign[i] && st.rho[i] >= old_rho_b[off];
                    if obs_round[i] == 0 {
                        never_seen -= 1;
                    }
                    obs_round[i] = r as u32;
                } else {
                    st.xstate[i] = false;
                }
                off += 1;
            }
        }
        let any_moved = st.means.moved.iter().any(|&m| m);
        for (j, m) in st.means.moved.iter().enumerate() {
            if *m {
                last_moved[j] = r as u32;
            }
        }
        if any_moved {
            mr_prev = mr_latest;
            mr_latest = r as u32;
        }
        // Epoch boundary: replace the running sum with a deterministic
        // exact re-sum (see module docs; at `batch == n` this fires
        // every round and reproduces the full-batch objective bits).
        if epoch_boundary {
            obj_sum = st.rho.iter().sum();
        }
        // Compensate the −1.0 sentinels of never-refreshed objects so
        // early-epoch objectives are a meaningful running estimate
        // (unseen objects contribute 0). `never_seen == 0` leaves the
        // sum untouched — the Lloyd-parity bit-exactness path.
        objective = if never_seen > 0 {
            obj_sum + never_seen as f64
        } else {
            obj_sum
        };
        st.iter = 1 + processed / n;
        upd_sw.stop();

        // --- incremental index maintenance (splice only dirty centroids) --
        let mut rb_sw = Stopwatch::new();
        rb_sw.start();
        assigner.rebuild(ds, &st, cfg);
        rb_sw.stop();

        logs.push(RoundLog {
            round: r,
            batch_len,
            counters,
            changes,
            assign_secs: asg_sw.secs(),
            gather_secs: phases.gather,
            verify_secs: phases.verify,
            update_secs: upd_sw.secs(),
            rebuild_secs: carry_rebuild_secs + rb_sw.secs(),
            n_moving: st.means.n_moving(),
            objective,
            mem_bytes: assigner.mem_bytes(),
        });
        carry_rebuild_secs = 0.0;
        max_mem = max_mem.max(assigner.mem_bytes());
        completed = r;

        if let Some(spec) = ckpt {
            if every > 0 && r % every == 0 {
                save_mb_ckpt(
                    spec, fp.as_ref().unwrap(), r, objective, max_mem, &st, &*assigner,
                    &counts, &sizes, &obs_round, &last_moved, mr_latest, mr_prev, &rng,
                    cursor, processed, quiet, obj_sum,
                )?;
                last_saved = r;
            }
        }
    }

    // Final checkpoint so `--resume` can extend a finished run.
    if let Some(spec) = ckpt {
        if completed > last_saved {
            save_mb_ckpt(
                spec, fp.as_ref().unwrap(), completed, objective, max_mem, &st, &*assigner,
                &counts, &sizes, &obs_round, &last_moved, mr_latest, mr_prev, &rng,
                cursor, processed, quiet, obj_sum,
            )?;
        }
    }

    let (t_th, v_th) = assigner.params();
    Ok(MiniBatchOutput {
        algo: kind,
        assign: st.assign,
        objective,
        rounds: logs,
        converged,
        max_mem_bytes: max_mem,
        t_th,
        v_th,
    })
}

#[allow(clippy::too_many_arguments)]
fn save_mb_ckpt(
    spec: &CheckpointSpec,
    fp: &RunFingerprint,
    round: usize,
    objective: f64,
    max_mem: usize,
    st: &IterState,
    assigner: &dyn Assigner,
    counts: &[f64],
    sizes: &[u32],
    obs_round: &[u32],
    last_moved: &[u32],
    mr_latest: u32,
    mr_prev: u32,
    rng: &Pcg32,
    cursor: usize,
    processed: usize,
    quiet: usize,
    obj_sum: f64,
) -> crate::error::SkmResult<()> {
    let (rng_state, rng_inc) = rng.raw_state();
    crate::persist::checkpoint::save_minibatch_checkpoint(
        &spec.path,
        fp,
        &CheckpointState {
            round,
            objective,
            max_mem,
            params: assigner.export_params_state(),
            assign: &st.assign,
            rho: &st.rho,
            xstate: &st.xstate,
            means: &st.means,
        },
        &MbStateRef {
            counts,
            sizes,
            obs_round,
            last_moved,
            mr_latest,
            mr_prev,
            rng_state,
            rng_inc,
            cursor,
            processed,
            quiet,
            obj_sum,
        },
    )?;
    Ok(())
}

/// Machine-readable report for one mini-batch run (the `--bench-json`
/// shape of the streaming mode).
pub fn minibatch_run_json(
    ds: &Dataset,
    cfg: &ClusterConfig,
    mb: &MiniBatchConfig,
    out: &MiniBatchOutput,
) -> Json {
    let c = out.total_counters();
    let per_round: Vec<Json> = out
        .rounds
        .iter()
        .map(|l| {
            Json::obj(vec![
                ("round", Json::UInt(l.round as u64)),
                ("batch_len", Json::UInt(l.batch_len as u64)),
                ("mult", Json::UInt(l.counters.mult)),
                ("changes", Json::UInt(l.changes as u64)),
                ("assign_secs", Json::Num(l.assign_secs)),
                ("update_secs", Json::Num(l.update_secs)),
                ("rebuild_secs", Json::Num(l.rebuild_secs)),
                ("n_moving", Json::UInt(l.n_moving as u64)),
                ("objective", Json::Num(l.objective)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("algo", Json::str(out.algo.name())),
        ("mode", Json::str("minibatch")),
        (
            "dataset",
            Json::obj(vec![
                ("name", Json::str(ds.name.clone())),
                ("n", Json::UInt(ds.n() as u64)),
                ("d", Json::UInt(ds.d() as u64)),
                ("k", Json::UInt(cfg.k as u64)),
                ("seed", Json::UInt(cfg.seed)),
            ]),
        ),
        (
            "minibatch",
            Json::obj(vec![
                ("batch", Json::UInt(mb.batch as u64)),
                ("schedule", Json::str(mb.schedule.name())),
                ("decay", Json::Num(mb.decay)),
                ("sample_seed", Json::UInt(mb.sample_seed)),
            ]),
        ),
        ("rounds", Json::UInt(out.n_rounds() as u64)),
        ("converged", Json::Bool(out.converged)),
        ("objective", Json::Num(out.objective)),
        ("objects_processed", Json::UInt(out.objects_processed() as u64)),
        ("max_mem_bytes", Json::UInt(out.max_mem_bytes as u64)),
        (
            "t_th",
            out.t_th.map(|t| Json::UInt(t as u64)).unwrap_or(Json::Null),
        ),
        ("v_th", out.v_th.map(Json::Num).unwrap_or(Json::Null)),
        (
            "phase_secs",
            Json::obj(vec![
                ("assign", Json::Num(out.total_assign_secs())),
                (
                    "update",
                    Json::Num(out.total_update_secs() - out.total_rebuild_secs()),
                ),
                ("rebuild", Json::Num(out.total_rebuild_secs())),
            ]),
        ),
        (
            "counters",
            Json::obj(vec![
                ("mult", Json::UInt(c.mult)),
                ("irregular_branches", Json::UInt(c.irregular_branches)),
                ("cold_touches", Json::UInt(c.cold_touches)),
                ("candidates", Json::UInt(c.candidates)),
                ("exact_sims", Json::UInt(c.exact_sims)),
                ("sqrts", Json::UInt(c.sqrts)),
            ]),
        ),
        ("per_round", Json::Arr(per_round)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny, CorpusSpec};
    use crate::sparse::build_dataset;

    fn dataset(n_docs: usize, seed: u64) -> Dataset {
        let c = generate(&CorpusSpec {
            n_docs,
            ..tiny(seed)
        });
        build_dataset("mb", c.n_terms, &c.docs)
    }

    #[test]
    fn schedule_parse_roundtrip() {
        for s in [BatchSchedule::Sequential, BatchSchedule::Reservoir] {
            assert_eq!(BatchSchedule::parse(s.name()), Some(s));
        }
        assert_eq!(BatchSchedule::parse("stream"), Some(BatchSchedule::Sequential));
        assert_eq!(BatchSchedule::parse("random"), Some(BatchSchedule::Reservoir));
        assert_eq!(BatchSchedule::parse("nope"), None);
    }

    #[test]
    fn runs_decomposition_is_maximal_and_disjoint() {
        let mut runs = Vec::new();
        runs_from_sorted_ids(&[0, 1, 2, 5, 7, 8], &mut runs);
        assert_eq!(runs, vec![(0, 3), (5, 6), (7, 9)]);
        runs_from_sorted_ids(&[], &mut runs);
        assert!(runs.is_empty());
        runs_from_sorted_ids(&[4], &mut runs);
        assert_eq!(runs, vec![(4, 5)]);
    }

    /// Unit-scope smoke of the driver itself; the epoch-coverage,
    /// thread-determinism, Lloyd-parity, and quality suites live in
    /// `rust/tests/minibatch.rs` (one place, no drifting copies).
    #[test]
    fn driver_smoke_one_epoch() {
        let ds = dataset(250, 7);
        let cfg = ClusterConfig {
            k: 8,
            seed: 3,
            ..Default::default()
        };
        let mb = MiniBatchConfig {
            batch: 64,
            schedule: BatchSchedule::Sequential,
            decay: 1.0,
            max_rounds: 4,
            sample_seed: 1,
        };
        let out = run_minibatch(AlgoKind::Mivi, &ds, &cfg, &mb, &ParConfig::serial());
        assert_eq!(out.n_rounds(), 4);
        // Every sequential batch is a full 64 objects — the 4th wraps
        // past n = 250 instead of emitting a ragged 58-object tail.
        assert_eq!(out.objects_processed(), 4 * 64);
        assert!(out.objective.is_finite());
    }

    /// The sequential schedule's wrap arithmetic: full windows while
    /// they fit, then an ascending disjoint `[(0, rem), (lo, n)]` pair
    /// across the boundary, cursor continuing at `rem`.
    #[test]
    fn sequential_wrap_emits_full_ascending_disjoint_batches() {
        let (n, b) = (250usize, 64usize);
        let mut cursor = 0usize;
        let mut seen_wrap = false;
        for _ in 0..20 {
            let lo = cursor;
            let runs: Vec<(usize, usize)> = if lo + b <= n {
                cursor = if lo + b == n { 0 } else { lo + b };
                vec![(lo, lo + b)]
            } else {
                let rem = lo + b - n;
                cursor = rem;
                seen_wrap = true;
                vec![(0, rem), (lo, n)]
            };
            let len: usize = runs.iter().map(|&(lo, hi)| hi - lo).sum();
            assert_eq!(len, b, "every batch is exactly b objects");
            for w in runs.windows(2) {
                assert!(w[0].1 <= w[1].0, "runs ascending and disjoint");
            }
            assert!(cursor < n);
        }
        assert!(seen_wrap, "20 rounds of 64/250 must wrap at least once");
    }
}
