//! Experiment coordination: workload presets, the multi-algorithm
//! comparison harness behind every table/figure bench, and the
//! exactness audit (DESIGN.md §6).

pub mod audit;
pub mod compare;
pub mod minibatch;
pub mod presets;

pub use audit::{audit_equivalence, audit_equivalence_with, AuditReport};
pub use compare::{
    cluster_run_json, compare_runs_json, comparison_rate_table, run_and_summarize,
    run_and_summarize_with, AlgoRunSummary,
};
pub use minibatch::{
    minibatch_run_json, run_minibatch, run_minibatch_resumable, try_run_minibatch,
    try_run_minibatch_resumable, BatchSchedule, MiniBatchConfig, MiniBatchOutput, RoundLog,
};
pub use presets::{preset, Preset};
