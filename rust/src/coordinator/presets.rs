//! Workload presets: named, scaled stand-ins for the paper's two
//! evaluation settings (8.2M PubMed at K = 80 000; 1.29M NYT at
//! K = 10 000). The `scale` knob shrinks N (and, via Heaps' law, D)
//! while keeping K ≈ N/100 (PubMed) and N/128 (NYT) as in the paper, so
//! the algorithmic regime — huge K, mean vectors ~30× denser than
//! objects — is preserved.

use crate::algo::ClusterConfig;
use crate::coordinator::minibatch::MiniBatchConfig;
use crate::corpus::{self, CorpusSpec};
use crate::serve::ServeDefaults;
use crate::sparse::{build_dataset, Dataset};

/// A named experimental workload.
#[derive(Debug, Clone)]
pub struct Preset {
    pub name: String,
    pub spec: CorpusSpec,
    pub k: usize,
}

impl Preset {
    /// Materialize the dataset (generate corpus + tf-idf features).
    pub fn dataset(&self) -> Dataset {
        let corpus = corpus::generate(&self.spec);
        build_dataset(&self.spec.name, corpus.n_terms, &corpus.docs)
    }

    /// Default cluster configuration for this workload.
    ///
    /// Thread plumbing note: presets deliberately carry no parallelism
    /// knob — preset runs pick up `SKM_THREADS` / `SKM_SHARD` through
    /// `coordinator::run_and_summarize` (the sharded engine is
    /// bit-identical to the serial path, so a preset's results never
    /// depend on that choice).
    pub fn config(&self, seed: u64) -> ClusterConfig {
        ClusterConfig {
            k: self.k,
            seed,
            ..Default::default()
        }
    }

    /// Default mini-batch / streaming configuration for this workload
    /// ([`MiniBatchConfig::default_for`] the corpus size), with the
    /// sampling seed following the corpus seed so a preset names one
    /// deterministic stream end to end.
    pub fn minibatch_config(&self) -> MiniBatchConfig {
        MiniBatchConfig {
            sample_seed: self.spec.seed,
            ..MiniBatchConfig::default_for(self.spec.n_docs)
        }
    }

    /// Default serving knobs for this workload's K — the preset-level
    /// convenience over [`ServeDefaults::default_for`], which is the
    /// one shared policy (the `skm serve` subcommand applies it to its
    /// own `--k`, which may differ from the preset's).
    pub fn serve_defaults(&self) -> ServeDefaults {
        ServeDefaults::default_for(self.k)
    }
}

/// Resolve a preset by name:
///
/// * `pubmed-like` — default bench scale (N ≈ 25 000, K = N/100)
/// * `pubmed-like-large` — N ≈ 80 000
/// * `nyt-like` — N ≈ 10 000 with long documents (K = N/128)
/// * `nyt-like-large` — N ≈ 40 000
/// * `tiny` — unit-test scale
///
/// `scale_override` multiplies the preset's document count.
pub fn preset(name: &str, seed: u64, scale_override: Option<f64>) -> Option<Preset> {
    let s = |base: f64| scale_override.map(|o| base * o).unwrap_or(base);
    match name {
        "pubmed-like" => {
            let spec = corpus::pubmed_like(s(3.0e-3), seed); // ~24.6k docs
            let k = (spec.n_docs / 100).max(2);
            Some(Preset {
                name: name.into(),
                spec,
                k,
            })
        }
        "pubmed-like-large" => {
            let spec = corpus::pubmed_like(s(1.0e-2), seed); // ~82k docs
            let k = (spec.n_docs / 100).max(2);
            Some(Preset {
                name: name.into(),
                spec,
                k,
            })
        }
        "nyt-like" => {
            let spec = corpus::nyt_like(s(8.0e-3), seed); // ~10.3k docs
            let k = (spec.n_docs / 128).max(2);
            Some(Preset {
                name: name.into(),
                spec,
                k,
            })
        }
        "nyt-like-large" => {
            let spec = corpus::nyt_like(s(3.0e-2), seed); // ~38.6k docs
            let k = (spec.n_docs / 128).max(2);
            Some(Preset {
                name: name.into(),
                spec,
                k,
            })
        }
        "tiny" => {
            let spec = corpus::tiny(seed);
            Some(Preset {
                name: name.into(),
                spec,
                k: 12,
            })
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for name in ["pubmed-like", "pubmed-like-large", "nyt-like", "nyt-like-large", "tiny"] {
            let p = preset(name, 1, None).unwrap();
            assert!(p.k >= 2, "{name}");
            assert!(p.spec.n_docs >= 100, "{name}");
        }
        assert!(preset("nope", 1, None).is_none());
    }

    #[test]
    fn scale_override_shrinks() {
        let a = preset("pubmed-like", 1, None).unwrap();
        let b = preset("pubmed-like", 1, Some(0.1)).unwrap();
        assert!(b.spec.n_docs < a.spec.n_docs);
    }

    #[test]
    fn minibatch_defaults_are_sane() {
        use crate::coordinator::minibatch::BatchSchedule;
        for name in ["pubmed-like", "nyt-like", "tiny"] {
            let p = preset(name, 1, None).unwrap();
            let mb = p.minibatch_config();
            assert!(mb.batch >= 1 && mb.batch <= p.spec.n_docs, "{name}");
            assert_eq!(mb.schedule, BatchSchedule::Sequential);
            assert_eq!(mb.decay, 1.0);
            // Budget covers at least one epoch.
            assert!(mb.max_rounds * mb.batch >= p.spec.n_docs, "{name}");
        }
    }

    #[test]
    fn serve_defaults_track_k() {
        let p = preset("pubmed-like", 1, None).unwrap();
        let sd = p.serve_defaults();
        assert_eq!(sd, crate::serve::ServeDefaults::default_for(p.k));
        assert!(sd.top_p >= 1 && sd.top_p <= 8);
        assert_eq!(sd.top_k, 10);
    }

    #[test]
    fn tiny_preset_materializes() {
        let p = preset("tiny", 7, None).unwrap();
        let ds = p.dataset();
        assert_eq!(ds.n(), p.spec.n_docs);
        assert!(ds.sparsity_indicator() < 0.2);
    }
}
