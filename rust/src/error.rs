//! The typed error surface of the crate (§Robustness).
//!
//! Everything that can fail for a *reason the caller can act on* —
//! malformed corpus files, hostile queries, bad configuration, an
//! unsupported kernel backend, a panicking worker — is an [`SkmError`]
//! variant, so callers (the `skm` binary, the serving layer, embedders)
//! can match on the failure class instead of parsing panic messages.
//!
//! Design rules:
//!
//! * **The success path is untouched.** Error plumbing never changes a
//!   float sequence: fallible constructors validate and then run the
//!   exact bit-pinned code the infallible paths always ran.
//! * **User errors never panic.** Bad CLI flags, bad files, and bad
//!   queries surface as `Err` and exit with a one-line message (exit
//!   code [`SkmError::exit_code`]) — no backtraces.
//! * **Worker panics are contained, not hidden.** The sharded engines
//!   ([`crate::algo::par`], [`crate::serve::batch`]) catch a panicking
//!   shard/query with [`std::panic::catch_unwind`], convert the payload
//!   through [`SkmError::from_panic`], and keep serving the unaffected
//!   work — see the module docs there for the containment contract,
//!   and `rust/tests/faults.rs` for the proof.

use std::fmt;

/// Crate-wide result alias.
pub type SkmResult<T> = Result<T, SkmError>;

/// The typed error taxonomy. Display strings are the single user-facing
/// error surface (the CLI prints `skm: {e}` and exits).
#[derive(Debug)]
pub enum SkmError {
    /// An I/O operation failed (file open/read/write).
    Io {
        /// What was being done, e.g. `"open docword.txt"`.
        context: String,
        source: std::io::Error,
    },
    /// A corpus / docword file violated the format or its own headers.
    MalformedCorpus { detail: String },
    /// A query was rejected at validation (NaN/inf/negative weights,
    /// out-of-range term ids, vocabulary mismatch).
    InvalidQuery { detail: String },
    /// Configuration (CLI flags, `ClusterConfig`, `MiniBatchConfig`,
    /// `RouterParams`) failed validation. Exits with code 2 (usage).
    InvalidConfig { detail: String },
    /// A worker thread (or contained serial computation) panicked; the
    /// panic was caught at the named site and converted.
    WorkerPanic { site: String, detail: String },
    /// A requested compute backend (e.g. `SKM_KERNEL`, the PJRT
    /// runtime) is unknown or unsupported on this host.
    BackendUnsupported { detail: String },
    /// The structured index and the snapshot disagree — an internal
    /// consistency failure. The router degrades to the exact scan on
    /// this (see `serve::router`); surfacing it means degradation was
    /// impossible.
    IndexInconsistent { detail: String },
    /// An error injected by the `failpoints` test harness
    /// ([`crate::util::failpoint`]). Only constructible with the
    /// `failpoints` cargo feature enabled.
    FaultInjected { site: String },
    /// An on-disk snapshot or checkpoint failed validation on load: bad
    /// magic/version, a checksum mismatch, a structurally inconsistent
    /// section (offsets out of bounds, ids ≥ K, broken relabeling), or a
    /// truncated file. `section` names the part of the file that failed
    /// (`"header"`, `"manifest"`, `"block 3"`, `"corpus.indptr"`, …) so
    /// corruption reports are actionable. The loader never returns a
    /// partially-decoded snapshot alongside this (see [`crate::persist`]).
    CorruptSnapshot {
        path: String,
        section: String,
        detail: String,
    },
}

impl fmt::Display for SkmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SkmError::Io { context, source } => write!(f, "{context}: {source}"),
            SkmError::MalformedCorpus { detail } => {
                write!(f, "malformed corpus: {detail}")
            }
            SkmError::InvalidQuery { detail } => write!(f, "invalid query: {detail}"),
            SkmError::InvalidConfig { detail } => {
                write!(f, "invalid configuration: {detail}")
            }
            SkmError::WorkerPanic { site, detail } => {
                write!(f, "worker panicked at {site}: {detail}")
            }
            SkmError::BackendUnsupported { detail } => {
                write!(f, "backend unsupported: {detail}")
            }
            SkmError::IndexInconsistent { detail } => {
                write!(f, "index inconsistent: {detail}")
            }
            SkmError::FaultInjected { site } => {
                write!(f, "injected fault at {site}")
            }
            SkmError::CorruptSnapshot {
                path,
                section,
                detail,
            } => {
                write!(f, "corrupt snapshot {path} [{section}]: {detail}")
            }
        }
    }
}

impl std::error::Error for SkmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SkmError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl SkmError {
    /// Wrap an I/O error with what was being attempted.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        SkmError::Io {
            context: context.into(),
            source,
        }
    }

    pub fn malformed(detail: impl Into<String>) -> Self {
        SkmError::MalformedCorpus {
            detail: detail.into(),
        }
    }

    pub fn invalid_query(detail: impl Into<String>) -> Self {
        SkmError::InvalidQuery {
            detail: detail.into(),
        }
    }

    pub fn invalid_config(detail: impl Into<String>) -> Self {
        SkmError::InvalidConfig {
            detail: detail.into(),
        }
    }

    /// A snapshot/checkpoint load failure pinned to a file section.
    pub fn corrupt_snapshot(
        path: impl Into<String>,
        section: impl Into<String>,
        detail: impl Into<String>,
    ) -> Self {
        SkmError::CorruptSnapshot {
            path: path.into(),
            section: section.into(),
            detail: detail.into(),
        }
    }

    /// CLI exit code: `2` for usage/configuration errors (the
    /// conventional "called wrong" code, matching the unknown-subcommand
    /// path), `1` for runtime failures.
    pub fn exit_code(&self) -> i32 {
        match self {
            SkmError::InvalidConfig { .. } => 2,
            _ => 1,
        }
    }

    /// Convert a caught panic payload into a typed error. A payload
    /// that already *is* an [`SkmError`] (e.g. re-thrown by
    /// [`crate::algo::par::run_sharded`]) passes through unchanged so
    /// the original variant survives nested containment; anything else
    /// becomes [`SkmError::WorkerPanic`] at `site` with the extracted
    /// panic message.
    pub fn from_panic(site: &str, payload: Box<dyn std::any::Any + Send>) -> Self {
        match payload.downcast::<SkmError>() {
            Ok(e) => *e,
            Err(payload) => SkmError::WorkerPanic {
                site: site.to_string(),
                detail: panic_message(payload.as_ref()),
            },
        }
    }
}

/// Best-effort extraction of a human-readable message from a panic
/// payload (`&str` and `String` cover `panic!`; [`SkmError`] covers the
/// engines' structured re-throws).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(e) = payload.downcast_ref::<SkmError>() {
        e.to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `f`, converting a panic into a typed error at `site` instead of
/// unwinding further. This is the boundary between panic-world (the
/// bit-pinned compute core keeps its asserts) and error-world (callers
/// that must not die): [`crate::algo::try_run_clustering_with`],
/// [`crate::coordinator::try_run_minibatch`], and the per-query slots of
/// [`crate::serve::serve_batch`] are all built on it.
///
/// `AssertUnwindSafe` is sound at these call sites because every caller
/// either owns the captured state exclusively (per-query/per-shard
/// slots) or discards it on error (the run_* wrappers return nothing on
/// failure), and the shared pools are poison-tolerant by design (see
/// [`crate::algo::par::lock_unpoisoned`]).
pub fn contain<T>(site: &str, f: impl FnOnce() -> T) -> SkmResult<T> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
        .map_err(|payload| SkmError::from_panic(site, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_exit_codes() {
        let e = SkmError::invalid_config("--k: cannot parse \"abc\"");
        assert_eq!(e.exit_code(), 2);
        assert!(e.to_string().contains("invalid configuration"));
        let e = SkmError::malformed("NNZ header says 5, file has 1 triples");
        assert_eq!(e.exit_code(), 1);
        assert!(e.to_string().contains("NNZ"));
        let e = SkmError::io(
            "open missing.txt",
            std::io::Error::new(std::io::ErrorKind::NotFound, "no such file"),
        );
        assert_eq!(e.exit_code(), 1);
        assert!(e.to_string().contains("missing.txt"));
    }

    #[test]
    fn contain_catches_and_types_panics() {
        assert_eq!(contain("t", || 41 + 1).unwrap(), 42);
        let err = contain("site-a", || -> u32 { panic!("boom {}", 7) }).unwrap_err();
        match err {
            SkmError::WorkerPanic { site, detail } => {
                assert_eq!(site, "site-a");
                assert!(detail.contains("boom 7"), "{detail}");
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn contain_preserves_typed_payloads() {
        let err = contain("outer", || -> u32 {
            std::panic::panic_any(SkmError::WorkerPanic {
                site: "inner".into(),
                detail: "original".into(),
            })
        })
        .unwrap_err();
        match err {
            SkmError::WorkerPanic { site, detail } => {
                assert_eq!(site, "inner", "typed payload must pass through");
                assert_eq!(detail, "original");
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn panic_message_extracts_common_payloads() {
        let err = contain("t", || -> u32 { std::panic::panic_any("static str") }).unwrap_err();
        assert!(err.to_string().contains("static str"));
        let err = contain("t", || -> u32 { std::panic::panic_any(3usize) }).unwrap_err();
        assert!(err.to_string().contains("non-string"));
    }
}
