//! # skm — Accelerated Spherical K-Means for Large-Scale Sparse Documents
//!
//! A production-grade reproduction of *"Accelerating Spherical K-Means
//! Clustering for Large-Scale Sparse Document Data"* (Aoyama & Saito,
//! 2024): the **ES-ICP** algorithm, every comparator it is evaluated
//! against, the structural-parameter estimator, the universal-
//! characteristics analyzers, and a complete bench harness regenerating
//! every table and figure of the paper.
//!
//! ## Layout (three-layer architecture, see DESIGN.md)
//!
//! - [`sparse`], [`corpus`] — the sparse document substrate and corpus
//!   generation/loading.
//! - [`index`] — mean-inverted indexes, including the three-region
//!   structured index driven by the structural parameters `(t_th, v_th)`.
//! - [`algo`] — the clustering algorithms (MIVI, DIVI, Ding+, ICP,
//!   ES-ICP, TA-ICP, CS-ICP, and the ablations ES/ThV/ThT/…-MIVI).
//! - [`estparams`] — the Section-V estimator for `(t_th, v_th)`.
//! - [`ucs`] — universal-characteristics analysis (Zipf, bounded Zipf,
//!   feature-value concentration, CPS).
//! - [`metrics`] — Mult counters, CPR, PMU counters, NMI/CV.
//! - [`coordinator`] — experiment orchestration, presets, equivalence
//!   audits.
//! - [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas dense
//!   cross-check kernels (`artifacts/*.hlo.txt`).
//! - [`util`] — offline-friendly RNG/CLI/IO/timing utilities.

pub mod algo;
pub mod coordinator;
pub mod corpus;
pub mod estparams;
pub mod index;
pub mod metrics;
pub mod runtime;
pub mod sparse;
pub mod ucs;
pub mod util;
