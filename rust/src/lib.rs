//! # skm — Accelerated Spherical K-Means for Large-Scale Sparse Documents
//!
//! A production-grade reproduction of *"Accelerating Spherical K-Means
//! Clustering for Large-Scale Sparse Document Data"* (Aoyama & Saito,
//! 2024): the **ES-ICP** algorithm, every comparator it is evaluated
//! against, the structural-parameter estimator, the universal-
//! characteristics analyzers, and a complete bench harness regenerating
//! every table and figure of the paper.
//!
//! ## Workspace layout
//!
//! The cargo workspace root is the repository root; this crate (`skm`)
//! lives in `rust/` and declares the repo-level `benches/` (one harness
//! per paper experiment, `harness = false`) and `examples/` directories
//! as its targets. Tier-1 verification is
//! `cargo build --release && cargo test -q` from the workspace root.
//!
//! ## Module layout (three-layer architecture, see DESIGN.md)
//!
//! - [`sparse`], [`corpus`] — the sparse document substrate and corpus
//!   generation/loading.
//! - [`index`] — mean-inverted indexes, including the three-region
//!   structured index driven by the structural parameters `(t_th, v_th)`,
//!   the (optionally cluster-parallel) update step, and
//!   [`index::maintain`] — incremental index maintenance that splices
//!   only moved centroids' postings across iterations (byte-identical
//!   to a from-scratch build, enforced by `rust/tests/incremental.rs`).
//! - [`algo`] — the clustering algorithms (MIVI, DIVI, Ding+, ICP,
//!   ES-ICP, TA-ICP, CS-ICP, and the ablations ES/ThV/ThT/…-MIVI);
//!   [`algo::kernel`] — the shared gather micro-kernels every assigner's
//!   inner loops route through (unrolled unchecked scatter-add, dense
//!   Region-1 tail gather, deduplicated argmax/filter scans), bit-
//!   identical to the naive loops by construction
//!   (`rust/tests/kernel.rs`); plus
//!   [`algo::par`] — the sharded multi-threaded assignment engine
//!   (`ParConfig { threads, shard }`), **bit-identical** to the serial
//!   path for every algorithm and enforced so by
//!   `rust/tests/parallel.rs`. Plumbed through
//!   `coordinator::run_and_summarize` (env knobs `SKM_THREADS` /
//!   `SKM_SHARD`), the `skm` binary's `--threads` / `--shard` flags,
//!   and the bench harnesses.
//! - [`estparams`] — the Section-V estimator for `(t_th, v_th)`.
//! - [`ucs`] — universal-characteristics analysis (Zipf, bounded Zipf,
//!   feature-value concentration, CPS).
//! - [`metrics`] — Mult counters, CPR, PMU counters, NMI/CV.
//! - [`coordinator`] — experiment orchestration, presets, equivalence
//!   audits, and [`coordinator::minibatch`] — the mini-batch /
//!   streaming driver (seeded-deterministic batches through
//!   `Assigner::assign_span`, per-centroid count-decay updates, and
//!   per-batch incremental index splicing; `batch == n` with
//!   `decay == 0` is bit-exact full-batch Lloyd, enforced by
//!   `rust/tests/minibatch.rs`).
//! - [`runtime`] — executor for the AOT-compiled JAX/Pallas dense
//!   cross-check kernels (`artifacts/*.hlo.txt`), gated behind the
//!   **`pjrt`** cargo feature: the default build is offline-green with
//!   a stub error path, `--features pjrt` compiles a native CPU
//!   executor for the two known dense-block artifacts (no Python/XLA
//!   toolchain required either way).
//! - [`serve`] — the online serving layer: [`serve::ClusteredCorpus`]
//!   freezes a finished clustering, [`serve::Router`] routes sparse
//!   queries to their top-p nearest centroids through the structured
//!   mean index (ES-pruned, exact scores, bit-identical to brute force
//!   — `rust/tests/serve.rs`), second-stage retrieval scans only the
//!   routed clusters' members, and [`serve::serve_batch`] shards query
//!   batches over the same scoped-thread engine as assignment.
//! - [`persist`] — crash-safe on-disk persistence: a versioned,
//!   per-block-checksummed container format for frozen serving state
//!   (atomic write-to-temp → fsync → rename publish, paranoid-by-
//!   default loading with every violation a typed
//!   [`error::SkmError::CorruptSnapshot`]), plus periodic
//!   checkpoint/resume for long clustering runs with a bit-identical
//!   resumed trajectory (`rust/tests/persist.rs`).
//! - [`util`] — offline-friendly RNG/CLI/IO/timing utilities, plus
//!   [`util::failpoint`] — the compile-time-gated fail-point harness
//!   (cargo feature `failpoints`) behind `rust/tests/faults.rs`.
//! - [`error`] — the typed failure surface ([`error::SkmError`]):
//!   malformed corpora, invalid queries/config, and contained worker
//!   panics are `Err` values with stable exit codes, never process
//!   aborts. Both sharded engines isolate a panicking shard/query with
//!   `catch_unwind` + poison-tolerant locks, and the router degrades to
//!   its exact scan when estimation or the structured index fails —
//!   without disturbing one bit of any unaffected result (see README
//!   "Robustness & failure semantics").

// The hot-path idiom here is deliberate index arithmetic over parallel
// flat arrays (CSR/CSC walks, counting sorts, scatter loops); iterator
// rewrites of these obscure the cost model the paper counts, so the
// corresponding style lints are opted out crate-wide.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_div_ceil
)]

pub mod algo;
pub mod coordinator;
pub mod corpus;
pub mod error;
pub mod estparams;
pub mod index;
pub mod metrics;
pub mod persist;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod ucs;
pub mod util;
