//! Corpus acquisition: synthetic Zipf-topic generation (the DESIGN.md §3
//! substitution for PubMed/NYT) and the UCI bag-of-words loader for the
//! real data sets when present.

pub mod loader;
pub mod synth;

pub use loader::{read_uci_bow, read_uci_bow_file};
pub use synth::{generate, nyt_like, pubmed_like, tiny, BowCorpus, CorpusSpec};
