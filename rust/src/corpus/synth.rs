//! Synthetic sparse document corpora with the paper's universal
//! characteristics (UCs).
//!
//! The paper evaluates on PubMed (8.2M docs) and NYT (1.29M docs), which
//! are not available here; per DESIGN.md §3 we substitute a generative
//! Zipf-topic corpus that reproduces the four UCs the algorithm exploits
//! (Section III):
//!
//! 1. **Zipf's law** on tf and df — tokens are drawn from a
//!    Zipf–Mandelbrot background distribution.
//! 2. **Bounded Zipf's law** on mean frequency — follows from (1) plus
//!    clustering, verified empirically by `ucs::` and the tests below.
//! 3. **Feature-value concentration** — each topic has a few *anchor*
//!    terms with a strongly skewed weight profile; cluster means inherit
//!    one or a few dominant tf-idf features.
//! 4. **Pareto-like CPS** — follows from (3); checked in `ucs::cps`.
//!
//! Documents are generated from a hard topic mixture: a document picks one
//! topic, then each token is an anchor of that topic with probability
//! `anchor_prob`, otherwise a background Zipf draw. Ground-truth topics are
//! kept (useful for sanity checks; never used by the algorithms).

use crate::util::rng::{Categorical, Pcg32, ZipfSampler};

/// Parameters of the generative corpus model.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub name: String,
    /// Number of documents (paper: N).
    pub n_docs: usize,
    /// Vocabulary size (paper: D; terms that end up unused are dropped
    /// later by `build_dataset`).
    pub n_terms: usize,
    /// Number of latent topics (ground truth granularity).
    pub n_topics: usize,
    /// Mean of the per-document *token* count (before dedup); the
    /// resulting distinct-term average `D̂` is somewhat smaller.
    pub mean_doc_len: f64,
    /// Log-normal sigma for document length.
    pub doc_len_sigma: f64,
    /// Zipf exponent for the background term distribution.
    pub zipf_alpha: f64,
    /// Zipf–Mandelbrot rank shift (flattens the head, cf. Fig 2(a)).
    pub zipf_shift: f64,
    /// Probability that a token comes from the topic's anchor set.
    pub anchor_prob: f64,
    /// Anchors per topic.
    pub anchors_per_topic: usize,
    /// Skew of anchor weights inside a topic: weight(rank a) ∝ a^-skew.
    /// Large skew → one dominant anchor → strong feature-value
    /// concentration.
    pub anchor_skew: f64,
    pub seed: u64,
}

/// A generated bag-of-words corpus.
#[derive(Debug, Clone)]
pub struct BowCorpus {
    pub n_terms: usize,
    /// Per-document `(term id, count)` lists.
    pub docs: Vec<Vec<(u32, u32)>>,
    /// Ground-truth topic of each document (diagnostics only).
    pub labels: Vec<u32>,
    pub name: String,
}

impl BowCorpus {
    pub fn n_docs(&self) -> usize {
        self.docs.len()
    }
}

/// PubMed-like preset (Section VI-A: N = 8.2e6, D = 141k, D̂ ≈ 59,
/// K ≈ N/100), scaled by `scale` ∈ (0, 1]. `scale = 1.0` would be the
/// paper size; experiments use laptop scales like 3e-3 (N ≈ 25k).
pub fn pubmed_like(scale: f64, seed: u64) -> CorpusSpec {
    let n_docs = ((8_200_000.0 * scale) as usize).max(200);
    // Vocabulary grows sublinearly with corpus size (Heaps' law, exponent
    // ~0.55 for PubMed-like text).
    let n_terms = ((141_043.0 * scale.powf(0.55)) as usize).max(800);
    CorpusSpec {
        name: format!("pubmed-like-{:.0e}", scale),
        n_docs,
        n_terms,
        n_topics: (n_docs / 100).max(8),
        mean_doc_len: 90.0, // distinct ≈ 59 after dedup of Zipf draws
        doc_len_sigma: 0.45,
        zipf_alpha: 1.05,
        zipf_shift: 2.7,
        anchor_prob: 0.32,
        anchors_per_topic: 12,
        anchor_skew: 1.6,
        seed,
    }
}

/// NYT-like preset (Section VI-A: N = 1.29e6, D = 495k, D̂ ≈ 226,
/// K ≈ N/128).
pub fn nyt_like(scale: f64, seed: u64) -> CorpusSpec {
    let n_docs = ((1_285_944.0 * scale) as usize).max(200);
    let n_terms = ((495_126.0 * scale.powf(0.55)) as usize).max(1_500);
    CorpusSpec {
        name: format!("nyt-like-{:.0e}", scale),
        n_docs,
        n_terms,
        n_topics: (n_docs / 128).max(8),
        mean_doc_len: 380.0, // distinct ≈ 226
        doc_len_sigma: 0.5,
        zipf_alpha: 1.1,
        zipf_shift: 3.5,
        anchor_prob: 0.28,
        anchors_per_topic: 16,
        anchor_skew: 1.45,
        seed,
    }
}

/// Tiny preset for unit tests.
pub fn tiny(seed: u64) -> CorpusSpec {
    CorpusSpec {
        name: "tiny".into(),
        n_docs: 400,
        n_terms: 600,
        n_topics: 12,
        mean_doc_len: 30.0,
        doc_len_sigma: 0.4,
        zipf_alpha: 1.0,
        zipf_shift: 2.0,
        anchor_prob: 0.35,
        anchors_per_topic: 6,
        anchor_skew: 1.6,
        seed,
    }
}

/// Generate a corpus from a spec. Deterministic given `spec.seed`.
pub fn generate(spec: &CorpusSpec) -> BowCorpus {
    let mut rng = Pcg32::new(spec.seed);
    let background = ZipfSampler::with_shift(spec.n_terms, spec.zipf_alpha, spec.zipf_shift);

    // Anchor terms are drawn from the mid-frequency band: ranks in
    // [n/50, n/2). Head terms are stop-word-like (shared across topics);
    // deep-tail terms would make topics trivially separable and would not
    // produce the high-df/high-mf Region-2 structure of Fig. 3(a).
    let lo = (spec.n_terms / 50).max(1);
    let hi = (spec.n_terms / 2).max(lo + spec.anchors_per_topic);
    let band = hi - lo;

    let anchor_weights: Vec<f64> = (1..=spec.anchors_per_topic)
        .map(|a| (a as f64).powf(-spec.anchor_skew))
        .collect();
    let anchor_cat = Categorical::new(&anchor_weights);

    // Each topic's anchors: distinct ranks within the band. Topics may
    // share anchors (realistic: clusters sharing vocabulary).
    let topics: Vec<Vec<u32>> = (0..spec.n_topics)
        .map(|_| {
            rng.sample_distinct(band, spec.anchors_per_topic)
                .into_iter()
                .map(|r| (lo + r) as u32)
                .collect()
        })
        .collect();

    // Documents.
    let mut docs = Vec::with_capacity(spec.n_docs);
    let mut labels = Vec::with_capacity(spec.n_docs);
    let mut counts: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
    let log_mean = spec.mean_doc_len.ln() - 0.5 * spec.doc_len_sigma * spec.doc_len_sigma;
    for _ in 0..spec.n_docs {
        let z = rng.gen_range(spec.n_topics as u32) as usize;
        labels.push(z as u32);
        let len = (log_mean + spec.doc_len_sigma * rng.next_gaussian()).exp();
        let len = (len.round() as usize).clamp(4, spec.n_terms);
        counts.clear();
        for _ in 0..len {
            let term = if rng.next_f64() < spec.anchor_prob {
                topics[z][anchor_cat.sample(&mut rng)]
            } else {
                // ZipfSampler returns 1-based rank; rank r → term id r-1
                // so low term ids are the *most* frequent in the original
                // labeling (build_dataset relabels by df anyway).
                (background.sample(&mut rng) - 1) as u32
            };
            *counts.entry(term).or_insert(0) += 1;
        }
        let mut doc: Vec<(u32, u32)> = counts.iter().map(|(&t, &c)| (t, c)).collect();
        doc.sort_unstable_by_key(|&(t, _)| t);
        docs.push(doc);
    }

    BowCorpus {
        n_terms: spec.n_terms,
        docs,
        labels,
        name: spec.name.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::build_dataset;
    use crate::util::stats::power_law_fit;

    #[test]
    fn deterministic_given_seed() {
        let spec = tiny(7);
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.docs, b.docs);
        let spec2 = tiny(8);
        let c = generate(&spec2);
        assert_ne!(a.docs, c.docs);
    }

    #[test]
    fn doc_shape_sane() {
        let c = generate(&tiny(1));
        assert_eq!(c.n_docs(), 400);
        for doc in &c.docs {
            assert!(!doc.is_empty());
            assert!(doc.windows(2).all(|w| w[0].0 < w[1].0));
            assert!(doc.iter().all(|&(t, cnt)| (t as usize) < c.n_terms && cnt > 0));
        }
    }

    #[test]
    fn df_follows_power_law() {
        // Zipf UC (paper Fig. 2a): rank-frequency of df is a power law
        // over the head/mid ranks.
        let spec = CorpusSpec {
            n_docs: 3000,
            ..tiny(3)
        };
        let c = generate(&spec);
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let mut df: Vec<f64> = ds.df.iter().map(|&d| d as f64).collect();
        df.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
        let top = 60.min(df.len());
        let ranks: Vec<f64> = (1..=top).map(|r| r as f64).collect();
        let (slope, _, r2) = power_law_fit(&ranks, &df[..top]);
        assert!(slope < -0.4, "slope={slope} not a decaying power law");
        assert!(r2 > 0.8, "r2={r2}");
    }

    #[test]
    fn avg_terms_in_expected_range() {
        let spec = pubmed_like(3e-4, 5); // ~2460 docs
        let c = generate(&spec);
        let ds = build_dataset("p", c.n_terms, &c.docs);
        let avg = ds.avg_terms();
        // target D̂ ≈ 59; generous band since dedup depends on vocab size
        assert!((30.0..110.0).contains(&avg), "avg distinct terms = {avg}");
        assert!(ds.sparsity_indicator() < 0.05);
    }

    #[test]
    fn topics_have_signal() {
        // Two docs of the same topic should on average be more similar
        // than docs of different topics (clusterability sanity check).
        let c = generate(&tiny(11));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let mut same = (0.0, 0);
        let mut diff = (0.0, 0);
        for i in 0..120 {
            for j in (i + 1)..120 {
                let s = ds.x.row_dot(i, j);
                if c.labels[i] == c.labels[j] {
                    same = (same.0 + s, same.1 + 1);
                } else {
                    diff = (diff.0 + s, diff.1 + 1);
                }
            }
        }
        let same_avg = same.0 / same.1.max(1) as f64;
        let diff_avg = diff.0 / diff.1.max(1) as f64;
        assert!(
            same_avg > diff_avg * 1.5,
            "same={same_avg} diff={diff_avg}: no topic signal"
        );
    }
}
