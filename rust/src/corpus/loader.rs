//! Loader for the UCI "bag of words" format used by the paper's PubMed
//! data set (docword.* files), so the real corpora drop in when available.
//!
//! Format:
//! ```text
//! N        <- number of documents
//! D        <- vocabulary size
//! NNZ      <- number of (doc, term, count) triples
//! docID termID count
//! ...
//! ```
//! IDs in the file are 1-based; we convert to 0-based. Blank lines and
//! comment lines (starting with `#` or `%`, as hand-annotated dumps and
//! MatrixMarket-adjacent tools produce) are skipped anywhere in the
//! file, including before the three headers.

use crate::corpus::synth::BowCorpus;
use anyhow::{bail, Context, Result};
use std::io::BufRead;

/// Next non-blank, non-comment line, or `None` at EOF. Returns the
/// line as read (callers trim) — no copy beyond the one `lines()`
/// already made, which matters at real-corpus scale (~10⁸ triples).
fn next_data_line<B: BufRead>(lines: &mut std::io::Lines<B>) -> Result<Option<String>> {
    for line in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        return Ok(Some(line));
    }
    Ok(None)
}

/// Parse a UCI bag-of-words stream. `max_docs` optionally truncates the
/// corpus (useful for scaled-down runs of the real data).
pub fn read_uci_bow(reader: impl std::io::Read, max_docs: Option<usize>) -> Result<BowCorpus> {
    let mut lines = std::io::BufReader::new(reader).lines();
    let mut header = |what: &str| -> Result<usize> {
        let line = next_data_line(&mut lines)?
            .with_context(|| format!("missing {what} header"))?;
        line.trim()
            .parse::<usize>()
            .with_context(|| format!("bad {what} header: {line:?}"))
    };
    let n = header("N")?;
    let d = header("D")?;
    let nnz = header("NNZ")?;
    let keep = max_docs.unwrap_or(n).min(n);

    let mut docs: Vec<Vec<(u32, u32)>> = vec![Vec::new(); keep];
    let mut seen = 0usize;
    while let Some(line) = next_data_line(&mut lines)? {
        let t = line.trim();
        let mut it = t.split_whitespace();
        let (a, b, c) = (
            it.next().context("triple: doc")?,
            it.next().context("triple: term")?,
            it.next().context("triple: count")?,
        );
        let doc: usize = a.parse().context("doc id")?;
        let term: usize = b.parse().context("term id")?;
        let count: u32 = c.parse().context("count")?;
        if doc == 0 || doc > n || term == 0 || term > d {
            bail!("triple out of range: {t:?} (N={n}, D={d})");
        }
        seen += 1;
        if doc <= keep {
            docs[doc - 1].push((term as u32 - 1, count));
        }
    }
    if max_docs.is_none() && seen != nnz {
        bail!("NNZ header says {nnz}, file has {seen} triples");
    }
    for doc in &mut docs {
        doc.sort_unstable_by_key(|&(t, _)| t);
    }
    Ok(BowCorpus {
        n_terms: d,
        docs,
        labels: vec![0; keep],
        name: "uci-bow".into(),
    })
}

/// Read from a file path (plain text; the UCI archives are gzipped — gunzip
/// first, we have no flate2 on the runtime path by policy).
pub fn read_uci_bow_file(path: &str, max_docs: Option<usize>) -> Result<BowCorpus> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path}"))?;
    read_uci_bow(f, max_docs)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "3\n5\n6\n1 1 2\n1 3 1\n2 2 4\n2 5 1\n3 1 1\n3 4 2\n";

    #[test]
    fn parses_sample() {
        let c = read_uci_bow(SAMPLE.as_bytes(), None).unwrap();
        assert_eq!(c.n_docs(), 3);
        assert_eq!(c.n_terms, 5);
        assert_eq!(c.docs[0], vec![(0, 2), (2, 1)]);
        assert_eq!(c.docs[1], vec![(1, 4), (4, 1)]);
        assert_eq!(c.docs[2], vec![(0, 1), (3, 2)]);
    }

    #[test]
    fn truncates_with_max_docs() {
        let c = read_uci_bow(SAMPLE.as_bytes(), Some(2)).unwrap();
        assert_eq!(c.n_docs(), 2);
        assert_eq!(c.docs[1], vec![(1, 4), (4, 1)]);
    }

    #[test]
    fn skips_comment_and_blank_lines() {
        let annotated = "# hand-annotated dump\n% matrixmarket-style too\n3\n\n5\n6\n# triples follow\n1 1 2\n1 3 1\n2 2 4\n2 5 1\n\n3 1 1\n3 4 2\n";
        let c = read_uci_bow(annotated.as_bytes(), None).unwrap();
        let plain = read_uci_bow(SAMPLE.as_bytes(), None).unwrap();
        assert_eq!(c.docs, plain.docs);
        assert_eq!(c.n_terms, plain.n_terms);
    }

    #[test]
    fn rejects_out_of_range() {
        let bad = "1\n2\n1\n1 3 1\n";
        assert!(read_uci_bow(bad.as_bytes(), None).is_err());
    }

    #[test]
    fn rejects_nnz_mismatch() {
        let bad = "1\n2\n5\n1 1 1\n";
        assert!(read_uci_bow(bad.as_bytes(), None).is_err());
    }
}
