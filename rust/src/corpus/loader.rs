//! Loader for the UCI "bag of words" format used by the paper's PubMed
//! data set (docword.* files), so the real corpora drop in when available.
//!
//! Format:
//! ```text
//! N        <- number of documents
//! D        <- vocabulary size
//! NNZ      <- number of (doc, term, count) triples
//! docID termID count
//! ...
//! ```
//! IDs in the file are 1-based; we convert to 0-based. Blank lines and
//! comment lines (starting with `#` or `%`, as hand-annotated dumps and
//! MatrixMarket-adjacent tools produce) are skipped anywhere in the
//! file, including before the three headers.
//!
//! ## Hardening (§Robustness)
//!
//! The headers are **untrusted input**: a hostile or corrupted file
//! must not be able to panic the process or exhaust memory before a
//! single triple is read. Errors are typed
//! ([`SkmError::MalformedCorpus`] / [`SkmError::Io`]), declared sizes
//! are capped ([`MAX_DECLARED_DOCS`], [`MAX_DECLARED_TERMS`],
//! [`MAX_DECLARED_NNZ`], and `NNZ ≤ N·D` by checked arithmetic), and
//! allocation follows the *observed* document ids — preallocation from
//! the N header is bounded by [`PREALLOC_DOC_CAP`] — so memory grows
//! with actual file content, never with a forged header. A file with
//! more triples than its NNZ header declares is rejected at the first
//! excess triple, before it can grow anything. Hostile-input cases
//! live in `rust/tests/loader.rs`.

use crate::corpus::synth::BowCorpus;
use crate::error::{SkmError, SkmResult};
use std::io::BufRead;

/// Hard cap on the declared document count N. Covers the paper's
/// corpora with ~8× headroom (PubMed is 8.2M documents) while bounding
/// what a forged header can make the final `resize_with` allocate
/// (~1.6 GiB of empty row headers at the cap). Corpora beyond this
/// belong to the ROADMAP's streaming-ingest item.
pub const MAX_DECLARED_DOCS: usize = 1 << 26;

/// Hard cap on the declared vocabulary size D: term ids are stored as
/// `u32` throughout the pipeline.
pub const MAX_DECLARED_TERMS: usize = u32::MAX as usize;

/// Hard cap on the declared triple count NNZ (10¹²-ish; the paper's
/// largest corpus has ~7.3×10⁸). NNZ is additionally checked against
/// N·D, the structural maximum.
pub const MAX_DECLARED_NNZ: usize = 1 << 40;

/// Preallocation bound for the document table: up to this many row
/// headers (~24 MiB) are reserved up front from the N header; beyond
/// it, growth follows observed doc ids.
pub const PREALLOC_DOC_CAP: usize = 1 << 20;

fn malformed(detail: String) -> SkmError {
    SkmError::malformed(detail)
}

/// Next non-blank, non-comment line, or `None` at EOF. Returns the
/// line as read (callers trim) — no copy beyond the one `lines()`
/// already made, which matters at real-corpus scale (~10⁸ triples).
fn next_data_line<B: BufRead>(lines: &mut std::io::Lines<B>) -> SkmResult<Option<String>> {
    for line in lines.by_ref() {
        let line = line.map_err(|e| SkmError::io("read corpus line", e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        return Ok(Some(line));
    }
    Ok(None)
}

/// Parse a UCI bag-of-words stream. `max_docs` optionally truncates the
/// corpus (useful for scaled-down runs of the real data). Never panics
/// on malformed input — every violation is a typed
/// [`SkmError::MalformedCorpus`] (module docs).
pub fn read_uci_bow(reader: impl std::io::Read, max_docs: Option<usize>) -> SkmResult<BowCorpus> {
    let mut lines = std::io::BufReader::new(reader).lines();
    let mut header = |what: &str| -> SkmResult<usize> {
        let line = next_data_line(&mut lines)?
            .ok_or_else(|| malformed(format!("missing {what} header")))?;
        line.trim()
            .parse::<usize>()
            .map_err(|e| malformed(format!("bad {what} header: {line:?} ({e})")))
    };
    let n = header("N")?;
    let d = header("D")?;
    let nnz = header("NNZ")?;
    crate::failpoint_res!("loader.after_header", 0u64);
    if n > MAX_DECLARED_DOCS {
        return Err(malformed(format!(
            "N header {n} exceeds the {MAX_DECLARED_DOCS}-document cap"
        )));
    }
    if d > MAX_DECLARED_TERMS {
        return Err(malformed(format!(
            "D header {d} exceeds the {MAX_DECLARED_TERMS}-term cap"
        )));
    }
    if nnz > MAX_DECLARED_NNZ {
        return Err(malformed(format!(
            "NNZ header {nnz} exceeds the {MAX_DECLARED_NNZ}-triple cap"
        )));
    }
    // Structural maximum: a (doc, term) grid holds at most N·D triples.
    match n.checked_mul(d) {
        Some(grid) if nnz <= grid => {}
        Some(grid) => {
            return Err(malformed(format!(
                "NNZ header {nnz} exceeds N·D = {grid}"
            )))
        }
        // n·d overflowing usize is unreachable under the caps above,
        // but reject rather than assume.
        None => return Err(malformed(format!("N·D overflows ({n} × {d})"))),
    }
    let keep = max_docs.unwrap_or(n).min(n);

    // Grow toward `keep` as doc ids are actually observed: the header
    // alone reserves at most PREALLOC_DOC_CAP row headers.
    let mut docs: Vec<Vec<(u32, u32)>> = Vec::with_capacity(keep.min(PREALLOC_DOC_CAP));
    let mut seen = 0usize;
    while let Some(line) = next_data_line(&mut lines)? {
        let t = line.trim();
        let mut it = t.split_whitespace();
        let (a, b, c) = match (it.next(), it.next(), it.next()) {
            (Some(a), Some(b), Some(c)) => (a, b, c),
            _ => return Err(malformed(format!("triple too short: {t:?}"))),
        };
        let doc: usize = a
            .parse()
            .map_err(|e| malformed(format!("bad doc id in triple {t:?} ({e})")))?;
        let term: usize = b
            .parse()
            .map_err(|e| malformed(format!("bad term id in triple {t:?} ({e})")))?;
        let count: u32 = c
            .parse()
            .map_err(|e| malformed(format!("bad count in triple {t:?} ({e})")))?;
        if doc == 0 || doc > n || term == 0 || term > d {
            return Err(malformed(format!(
                "triple out of range: {t:?} (N={n}, D={d})"
            )));
        }
        if seen >= nnz {
            // Reject the first excess triple instead of buffering an
            // undeclared tail of unbounded length.
            return Err(malformed(format!(
                "more than NNZ={nnz} triples in file (at {t:?})"
            )));
        }
        crate::failpoint_res!("loader.triple", seen as u64);
        seen += 1;
        if doc <= keep {
            if docs.len() < doc {
                docs.resize_with(doc, Vec::new);
            }
            docs[doc - 1].push((term as u32 - 1, count));
        }
    }
    if max_docs.is_none() && seen != nnz {
        return Err(malformed(format!(
            "NNZ header says {nnz}, file has {seen} triples"
        )));
    }
    // Trailing documents with no triples still exist as empty rows.
    docs.resize_with(keep, Vec::new);
    for doc in &mut docs {
        doc.sort_unstable_by_key(|&(t, _)| t);
    }
    Ok(BowCorpus {
        n_terms: d,
        docs,
        labels: vec![0; keep],
        name: "uci-bow".into(),
    })
}

/// Read from a file path (plain text; the UCI archives are gzipped — gunzip
/// first, we have no flate2 on the runtime path by policy).
pub fn read_uci_bow_file(path: &str, max_docs: Option<usize>) -> SkmResult<BowCorpus> {
    let f = std::fs::File::open(path).map_err(|e| SkmError::io(format!("open {path}"), e))?;
    read_uci_bow(f, max_docs)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "3\n5\n6\n1 1 2\n1 3 1\n2 2 4\n2 5 1\n3 1 1\n3 4 2\n";

    #[test]
    fn parses_sample() {
        let c = read_uci_bow(SAMPLE.as_bytes(), None).unwrap();
        assert_eq!(c.n_docs(), 3);
        assert_eq!(c.n_terms, 5);
        assert_eq!(c.docs[0], vec![(0, 2), (2, 1)]);
        assert_eq!(c.docs[1], vec![(1, 4), (4, 1)]);
        assert_eq!(c.docs[2], vec![(0, 1), (3, 2)]);
    }

    #[test]
    fn truncates_with_max_docs() {
        let c = read_uci_bow(SAMPLE.as_bytes(), Some(2)).unwrap();
        assert_eq!(c.n_docs(), 2);
        assert_eq!(c.docs[1], vec![(1, 4), (4, 1)]);
    }

    #[test]
    fn skips_comment_and_blank_lines() {
        let annotated = "# hand-annotated dump\n% matrixmarket-style too\n3\n\n5\n6\n# triples follow\n1 1 2\n1 3 1\n2 2 4\n2 5 1\n\n3 1 1\n3 4 2\n";
        let c = read_uci_bow(annotated.as_bytes(), None).unwrap();
        let plain = read_uci_bow(SAMPLE.as_bytes(), None).unwrap();
        assert_eq!(c.docs, plain.docs);
        assert_eq!(c.n_terms, plain.n_terms);
    }

    #[test]
    fn rejects_out_of_range() {
        let bad = "1\n2\n1\n1 3 1\n";
        assert!(read_uci_bow(bad.as_bytes(), None).is_err());
    }

    #[test]
    fn rejects_nnz_mismatch() {
        let bad = "1\n2\n5\n1 1 1\n";
        assert!(read_uci_bow(bad.as_bytes(), None).is_err());
    }

    #[test]
    fn rejects_excess_triples_immediately() {
        // NNZ says 1, file carries 2 — rejected at the second triple
        // even under max_docs truncation (which previously tolerated
        // undeclared tails).
        let bad = "2\n2\n1\n1 1 1\n2 2 1\n";
        let err = read_uci_bow(bad.as_bytes(), Some(1)).unwrap_err();
        assert!(err.to_string().contains("more than NNZ"), "{err}");
    }

    #[test]
    fn trailing_empty_docs_are_materialized() {
        // Doc 3 of 3 has no triples; it must still exist as an empty row.
        let s = "3\n2\n1\n1 1 1\n";
        let c = read_uci_bow(s.as_bytes(), None).unwrap();
        assert_eq!(c.n_docs(), 3);
        assert!(c.docs[1].is_empty() && c.docs[2].is_empty());
    }
}
