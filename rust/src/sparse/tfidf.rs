//! Feature extraction: raw term counts → tf-idf → L2 normalization →
//! df-ascending term relabeling — producing the `Dataset` every algorithm
//! consumes.
//!
//! Matches Section VI-A of the paper:
//!   tf-idf(s, i) = tf(s, i) * log(N / df_s)                      (Eq. 15)
//! followed by L2 normalization (objects live on the unit hypersphere),
//! with term IDs relabeled so that **ascending term ID == ascending
//! document frequency** (Section IV-A) — the ES filter's Region-1/2 split
//! on term IDs depends on this ordering.

use crate::sparse::csr::CsrMatrix;

/// A prepared clustering dataset: unit-norm tf-idf feature vectors with
/// df-ascending term IDs, plus the per-term document frequencies.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// N × D unit-norm feature matrix, term IDs ascending in df.
    pub x: CsrMatrix,
    /// Document frequency per (relabeled) term; nondecreasing in term id.
    pub df: Vec<u32>,
    /// Maps relabeled term id → original term id (for interpretability).
    pub orig_term: Vec<u32>,
    /// Human-readable dataset label ("pubmed-like", "nyt-like", ...).
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.n_rows()
    }

    pub fn d(&self) -> usize {
        self.x.n_cols()
    }

    /// Average number of distinct terms per document — the paper's `D̂`.
    pub fn avg_terms(&self) -> f64 {
        self.x.avg_row_nnz()
    }

    /// Sparsity indicator `D̂ / D` (Section I).
    pub fn sparsity_indicator(&self) -> f64 {
        self.avg_terms() / self.d() as f64
    }
}

/// Build a `Dataset` from bag-of-words counts.
///
/// `docs[i]` lists `(term id, count)` pairs (any order, duplicates summed);
/// `n_terms` is the vocabulary size. Terms that appear in **no** document
/// are dropped during relabeling (the paper's D counts only distinct terms
/// present in the data set).
pub fn build_dataset(name: &str, n_terms: usize, docs: &[Vec<(u32, u32)>]) -> Dataset {
    let n = docs.len();
    assert!(n > 0, "empty corpus");

    // Pass 1: document frequencies over the original vocabulary.
    let mut df_orig = vec![0u32; n_terms];
    for doc in docs {
        // Dedup within doc for df counting.
        let mut terms: Vec<u32> = doc.iter().map(|&(t, _)| t).collect();
        terms.sort_unstable();
        terms.dedup();
        for t in terms {
            df_orig[t as usize] += 1;
        }
    }

    // Relabel: sort original terms by (df ascending, original id) — the
    // deterministic tiebreak keeps runs reproducible.
    let mut present: Vec<u32> = (0..n_terms as u32).filter(|&t| df_orig[t as usize] > 0).collect();
    present.sort_unstable_by_key(|&t| (df_orig[t as usize], t));
    let d_eff = present.len();
    let mut relabel = vec![u32::MAX; n_terms];
    for (new_id, &old_id) in present.iter().enumerate() {
        relabel[old_id as usize] = new_id as u32;
    }
    let df: Vec<u32> = present.iter().map(|&t| df_orig[t as usize]).collect();

    // Pass 2: tf-idf rows in the relabeled vocabulary.
    let n_f = n as f64;
    let rows: Vec<Vec<(u32, f64)>> = docs
        .iter()
        .map(|doc| {
            doc.iter()
                .filter(|&&(_, c)| c > 0)
                .map(|&(t, c)| {
                    let nt = relabel[t as usize];
                    debug_assert!(nt != u32::MAX);
                    let idf = (n_f / df_orig[t as usize] as f64).ln();
                    (nt, c as f64 * idf)
                })
                .collect()
        })
        .collect();

    let mut x = CsrMatrix::from_rows(d_eff, &rows);
    x.l2_normalize_rows();

    Dataset {
        x,
        df,
        orig_term: present,
        name: name.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_docs() -> (usize, Vec<Vec<(u32, u32)>>) {
        // vocab 6; term 5 never used; term 0 in all docs (df=4, idf=0!),
        // term 1 in 2 docs, terms 2..4 in 1 doc each.
        let docs = vec![
            vec![(0, 2), (1, 1), (2, 3)],
            vec![(0, 1), (1, 2)],
            vec![(0, 5), (3, 1)],
            vec![(0, 1), (4, 2)],
        ];
        (6, docs)
    }

    #[test]
    fn df_ascending_after_relabel() {
        let (nt, docs) = toy_docs();
        let ds = build_dataset("toy", nt, &docs);
        assert_eq!(ds.d(), 5); // term 5 dropped
        assert!(ds.df.windows(2).all(|w| w[0] <= w[1]), "df not ascending");
        assert_eq!(*ds.df.last().unwrap(), 4); // term 0 has df=4
        assert_eq!(*ds.orig_term.last().unwrap(), 0);
    }

    #[test]
    fn rows_are_unit_norm_where_possible() {
        let (nt, docs) = toy_docs();
        let ds = build_dataset("toy", nt, &docs);
        for i in 0..ds.n() {
            let norm = ds.x.row_norm(i);
            // doc 2 = {0 (idf 0), 3}: still nonzero because of term 3.
            assert!((norm - 1.0).abs() < 1e-12, "row {i} norm {norm}");
        }
    }

    #[test]
    fn idf_zero_terms_vanish_in_weight_but_norm_is_fine() {
        let (nt, docs) = toy_docs();
        let ds = build_dataset("toy", nt, &docs);
        // the ubiquitous term (df = N) has idf = ln(1) = 0 → zero weight
        let ubiquitous_new_id = ds.d() as u32 - 1;
        for i in 0..ds.n() {
            let (ts, vs) = ds.x.row(i);
            for (&t, &v) in ts.iter().zip(vs) {
                if t == ubiquitous_new_id {
                    assert_eq!(v, 0.0);
                }
            }
        }
    }

    #[test]
    fn tfidf_values_match_formula() {
        let docs = vec![vec![(0, 2), (1, 1)], vec![(1, 3)]];
        let ds = build_dataset("t", 2, &docs);
        // df: term0 = 1, term1 = 2 → relabeled term0 → id0, term1 → id1
        // doc0 raw: tfidf(term0) = 2 ln 2, tfidf(term1) = 1 ln 1 = 0
        let (ts, vs) = ds.x.row(0);
        assert_eq!(ts, &[0, 1]);
        assert!((vs[0] - 1.0).abs() < 1e-12); // normalized: only nonzero entry
        assert_eq!(vs[1], 0.0);
    }

    #[test]
    fn sparsity_indicator() {
        let (nt, docs) = toy_docs();
        let ds = build_dataset("toy", nt, &docs);
        let expected = (3.0 + 2.0 + 2.0 + 2.0) / 4.0 / 5.0;
        assert!((ds.sparsity_indicator() - expected).abs() < 1e-12);
    }
}
