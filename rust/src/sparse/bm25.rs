//! BM25 feature extraction — an alternative weighting to the paper's
//! tf-idf (Eq. 15), addressing the paper's future-work item "(1) various
//! data sets and features" (Section IX).
//!
//! Okapi BM25 weight of term s in document i:
//!
//! ```text
//! w(s,i) = idf(s) · tf(s,i)·(k1 + 1) / (tf(s,i) + k1·(1 − b + b·len_i/avg_len))
//! idf(s) = ln( (N − df_s + 0.5) / (df_s + 0.5) + 1 )
//! ```
//!
//! followed by L2 normalization, so the resulting vectors live on the
//! unit hypersphere exactly like the tf-idf ones — every algorithm and
//! every UC analysis applies unchanged. The df-ascending term relabeling
//! is shared with [`super::tfidf::build_dataset`].

use crate::sparse::csr::CsrMatrix;
use crate::sparse::tfidf::Dataset;

/// BM25 hyperparameters (standard defaults).
#[derive(Debug, Clone, Copy)]
pub struct Bm25Params {
    pub k1: f64,
    pub b: f64,
}

impl Default for Bm25Params {
    fn default() -> Self {
        Self { k1: 1.2, b: 0.75 }
    }
}

/// Build a clustering dataset with BM25 weights instead of tf-idf.
pub fn build_dataset_bm25(
    name: &str,
    n_terms: usize,
    docs: &[Vec<(u32, u32)>],
    params: Bm25Params,
) -> Dataset {
    let n = docs.len();
    assert!(n > 0, "empty corpus");

    // Document frequencies and lengths.
    let mut df_orig = vec![0u32; n_terms];
    let mut doc_len = vec![0u64; n];
    for (i, doc) in docs.iter().enumerate() {
        let mut terms: Vec<u32> = doc.iter().map(|&(t, _)| t).collect();
        terms.sort_unstable();
        terms.dedup();
        for t in terms {
            df_orig[t as usize] += 1;
        }
        doc_len[i] = doc.iter().map(|&(_, c)| c as u64).sum();
    }
    let avg_len = doc_len.iter().sum::<u64>() as f64 / n as f64;

    // df-ascending relabeling (same contract as tf-idf's build_dataset —
    // the ES filter's Region split depends on it).
    let mut present: Vec<u32> = (0..n_terms as u32)
        .filter(|&t| df_orig[t as usize] > 0)
        .collect();
    present.sort_unstable_by_key(|&t| (df_orig[t as usize], t));
    let d_eff = present.len();
    let mut relabel = vec![u32::MAX; n_terms];
    for (new_id, &old_id) in present.iter().enumerate() {
        relabel[old_id as usize] = new_id as u32;
    }
    let df: Vec<u32> = present.iter().map(|&t| df_orig[t as usize]).collect();

    let n_f = n as f64;
    let rows: Vec<Vec<(u32, f64)>> = docs
        .iter()
        .enumerate()
        .map(|(i, doc)| {
            let len_norm = 1.0 - params.b + params.b * doc_len[i] as f64 / avg_len;
            doc.iter()
                .filter(|&&(_, c)| c > 0)
                .map(|&(t, c)| {
                    let dfs = df_orig[t as usize] as f64;
                    let idf = ((n_f - dfs + 0.5) / (dfs + 0.5) + 1.0).ln();
                    let tf = c as f64;
                    let w = idf * tf * (params.k1 + 1.0) / (tf + params.k1 * len_norm);
                    (relabel[t as usize], w)
                })
                .collect()
        })
        .collect();

    let mut x = CsrMatrix::from_rows(d_eff, &rows);
    x.l2_normalize_rows();
    Dataset {
        x,
        df,
        orig_term: present,
        name: format!("{name}-bm25"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{run_clustering, AlgoKind, ClusterConfig};
    use crate::corpus::{generate, tiny};
    use crate::metrics::nmi;

    fn corpus() -> crate::corpus::BowCorpus {
        generate(&tiny(404))
    }

    #[test]
    fn unit_norm_and_df_ascending() {
        let c = corpus();
        let ds = build_dataset_bm25("t", c.n_terms, &c.docs, Bm25Params::default());
        assert!(ds.df.windows(2).all(|w| w[0] <= w[1]));
        for i in 0..ds.n() {
            let norm = ds.x.row_norm(i);
            assert!((norm - 1.0).abs() < 1e-12, "row {i}: {norm}");
        }
    }

    #[test]
    fn weights_positive_and_idf_monotone() {
        let c = corpus();
        let ds = build_dataset_bm25("t", c.n_terms, &c.docs, Bm25Params::default());
        // BM25 idf(+1 variant) is strictly positive, so all weights > 0.
        for i in 0..ds.n() {
            let (_, vs) = ds.x.row(i);
            assert!(vs.iter().all(|&v| v > 0.0), "row {i} has nonpositive weight");
        }
    }

    #[test]
    fn saturation_with_k1() {
        // With k1 -> 0, term frequency saturates immediately: weights for
        // tf=1 and tf=10 of the same term should coincide (up to idf).
        let docs = vec![vec![(0, 1), (1, 1)], vec![(0, 10), (1, 1)]];
        let ds = build_dataset_bm25(
            "t",
            2,
            &docs,
            Bm25Params { k1: 1e-9, b: 0.0 },
        );
        // After normalization both docs should have identical vectors.
        let a = ds.x.row_dense(0);
        let b = ds.x.row_dense(1);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn algorithms_stay_exact_on_bm25_features() {
        // The exactness guarantees are weighting-agnostic: ES-ICP must
        // match MIVI on BM25 features too.
        let c = corpus();
        let ds = build_dataset_bm25("t", c.n_terms, &c.docs, Bm25Params::default());
        let cfg = ClusterConfig {
            k: 10,
            seed: 5,
            ..Default::default()
        };
        let a = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let b = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        let t = run_clustering(AlgoKind::TaIcp, &ds, &cfg);
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.assign, t.assign);
    }

    #[test]
    fn bm25_clusters_similarly_to_tfidf() {
        let c = corpus();
        let tfidf = crate::sparse::build_dataset("t", c.n_terms, &c.docs);
        let bm25 = build_dataset_bm25("t", c.n_terms, &c.docs, Bm25Params::default());
        let cfg = ClusterConfig {
            k: 12,
            seed: 9,
            ..Default::default()
        };
        let a = run_clustering(AlgoKind::EsIcp, &tfidf, &cfg);
        let b = run_clustering(AlgoKind::EsIcp, &bm25, &cfg);
        let agreement = nmi(&a.assign, &b.assign);
        assert!(
            agreement > 0.4,
            "tf-idf and BM25 clusterings unrelated: NMI={agreement}"
        );
    }
}
