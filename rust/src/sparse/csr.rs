//! Compressed sparse row (CSR) storage for document-feature matrices.
//!
//! A row is the paper's "sparse expression" of an object: a tuple array
//! `[(term id, feature value)]` with term IDs stored in ascending order.
//! The clustering engine requires the *global* term-ID order to be
//! ascending document frequency (df); that relabeling is done by
//! `sparse::tfidf::build_dataset`, not here.

/// CSR sparse matrix with `u32` column indices and `f64` values.
///
/// `f64` matches the paper's `sizeof(double)` memory accounting for the
/// partial mean-inverted index (Section IV-A).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    n_cols: usize,
    /// Row start offsets; `indptr.len() == n_rows + 1`.
    indptr: Vec<usize>,
    /// Column (term) ids, ascending within each row.
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from per-row tuple lists. Each row's tuples are sorted by
    /// column id; duplicate columns within a row are summed.
    pub fn from_rows(n_cols: usize, rows: &[Vec<(u32, f64)>]) -> Self {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        let mut scratch: Vec<(u32, f64)> = Vec::new();
        for row in rows {
            // Fast path: already strictly sorted (the common case for
            // rows produced by the update step) — no copy, no sort, no
            // dedup scan (§Perf).
            if row.windows(2).all(|w| w[0].0 < w[1].0) {
                for &(c, v) in row {
                    debug_assert!((c as usize) < n_cols);
                    indices.push(c);
                    values.push(v);
                }
                indptr.push(indices.len());
                continue;
            }
            scratch.clear();
            scratch.extend_from_slice(row);
            scratch.sort_unstable_by_key(|t| t.0);
            let mut i = 0;
            while i < scratch.len() {
                let (c, mut v) = scratch[i];
                assert!((c as usize) < n_cols, "column {c} out of range {n_cols}");
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                indices.push(c);
                values.push(v);
                i = j;
            }
            indptr.push(indices.len());
        }
        Self {
            n_cols,
            indptr,
            indices,
            values,
        }
    }

    /// Build directly from raw CSR arrays (caller guarantees validity;
    /// checked in debug builds).
    pub fn from_raw(n_cols: usize, indptr: Vec<usize>, indices: Vec<u32>, values: Vec<f64>) -> Self {
        debug_assert!(!indptr.is_empty());
        debug_assert_eq!(*indptr.last().unwrap(), indices.len());
        debug_assert_eq!(indices.len(), values.len());
        #[cfg(debug_assertions)]
        for r in 0..indptr.len() - 1 {
            let seg = &indices[indptr[r]..indptr[r + 1]];
            debug_assert!(seg.windows(2).all(|w| w[0] < w[1]), "row {r} not sorted");
            debug_assert!(seg.iter().all(|&c| (c as usize) < n_cols));
        }
        Self {
            n_cols,
            indptr,
            indices,
            values,
        }
    }

    /// The raw CSR arrays `(n_cols, indptr, indices, values)`, for the
    /// persistence layer's serializer. Read-only: mutating entry points
    /// stay [`CsrMatrix::from_rows`] / [`CsrMatrix::from_raw`] so the
    /// sortedness invariant has exactly two producers.
    pub fn raw_parts(&self) -> (usize, &[usize], &[u32], &[f64]) {
        (self.n_cols, &self.indptr, &self.indices, &self.values)
    }

    pub fn n_rows(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Number of non-zeros in row `i` — the paper's `(nt)_i`.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Row `i` as parallel slices `(term ids, values)`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    pub fn row_mut(&mut self, i: usize) -> (&[u32], &mut [f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &mut self.values[s..e])
    }

    /// Row `i` split at the structural term threshold: `(low, high)`
    /// where `low` covers terms `< t_split` and `high` terms
    /// `≥ t_split` (term ids ascend within a row, so this is one binary
    /// search). The shared accessor behind every assigner's Region-1 /
    /// Region-2+3 partition of an object (§Perf: previously each
    /// assigner re-derived the split point by hand).
    #[inline]
    pub fn row_split(&self, i: usize, t_split: usize) -> ((&[u32], &[f64]), (&[u32], &[f64])) {
        let (ts, vs) = self.row(i);
        let p0 = ts.partition_point(|&t| (t as usize) < t_split);
        ((&ts[..p0], &vs[..p0]), (&ts[p0..], &vs[p0..]))
    }

    /// Iterate `(row, term, value)` over all non-zeros.
    pub fn iter(&self) -> impl Iterator<Item = (usize, u32, f64)> + '_ {
        (0..self.n_rows()).flat_map(move |r| {
            let (ts, vs) = self.row(r);
            ts.iter().zip(vs.iter()).map(move |(&t, &v)| (r, t, v))
        })
    }

    /// L2 norm of row `i`.
    pub fn row_norm(&self, i: usize) -> f64 {
        let (_, vs) = self.row(i);
        vs.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// L1 norm of row `i` — `||x_i||_1` used by the TA filter (Eq. 16).
    pub fn row_l1(&self, i: usize) -> f64 {
        let (_, vs) = self.row(i);
        vs.iter().map(|v| v.abs()).sum::<f64>()
    }

    /// Scale every row to unit L2 norm (rows with zero norm are left
    /// untouched). Returns the number of zero rows encountered.
    pub fn l2_normalize_rows(&mut self) -> usize {
        let mut zeros = 0;
        for i in 0..self.n_rows() {
            let n = self.row_norm(i);
            if n > 0.0 {
                let (s, e) = (self.indptr[i], self.indptr[i + 1]);
                for v in &mut self.values[s..e] {
                    *v /= n;
                }
            } else {
                zeros += 1;
            }
        }
        zeros
    }

    /// Dot product of two rows (sorted-merge set intersection).
    pub fn row_dot(&self, a: usize, b: usize) -> f64 {
        let (ta, va) = self.row(a);
        let (tb, vb) = self.row(b);
        dot_sorted(ta, va, tb, vb)
    }

    /// Dot product of row `i` against a dense vector.
    pub fn row_dot_dense(&self, i: usize, dense: &[f64]) -> f64 {
        let (ts, vs) = self.row(i);
        ts.iter()
            .zip(vs.iter())
            .map(|(&t, &v)| v * dense[t as usize])
            .sum()
    }

    /// Document frequency per column: in how many rows each column occurs.
    pub fn column_df(&self) -> Vec<u32> {
        let mut df = vec![0u32; self.n_cols];
        for &c in &self.indices {
            df[c as usize] += 1;
        }
        df
    }

    /// Sum of values per column (term frequency when values are counts).
    pub fn column_sum(&self) -> Vec<f64> {
        let mut s = vec![0.0; self.n_cols];
        for (_, c, v) in self.iter() {
            s[c as usize] += v;
        }
        s
    }

    /// Remap column ids: `new_id = perm[old_id]`. `perm` must be a
    /// permutation of `0..n_cols`. Rows are re-sorted afterwards.
    pub fn permute_columns(&mut self, perm: &[u32]) {
        assert_eq!(perm.len(), self.n_cols);
        for c in &mut self.indices {
            *c = perm[*c as usize];
        }
        // Re-sort each row by the new ids.
        for r in 0..self.n_rows() {
            let (s, e) = (self.indptr[r], self.indptr[r + 1]);
            let mut pairs: Vec<(u32, f64)> = self.indices[s..e]
                .iter()
                .cloned()
                .zip(self.values[s..e].iter().cloned())
                .collect();
            pairs.sort_unstable_by_key(|t| t.0);
            for (k, (c, v)) in pairs.into_iter().enumerate() {
                self.indices[s + k] = c;
                self.values[s + k] = v;
            }
        }
    }

    /// Densify row `i` into a `n_cols`-length vector (test/oracle helper).
    pub fn row_dense(&self, i: usize) -> Vec<f64> {
        let mut d = vec![0.0; self.n_cols];
        let (ts, vs) = self.row(i);
        for (&t, &v) in ts.iter().zip(vs) {
            d[t as usize] = v;
        }
        d
    }

    /// Average row nnz — the paper's `D̂`.
    pub fn avg_row_nnz(&self) -> f64 {
        if self.n_rows() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_rows() as f64
        }
    }
}

/// Sparse·sparse dot product over sorted (ids, values) pairs.
#[inline]
pub fn dot_sorted(ta: &[u32], va: &[f64], tb: &[u32], vb: &[f64]) -> f64 {
    let (mut i, mut j, mut acc) = (0usize, 0usize, 0.0f64);
    while i < ta.len() && j < tb.len() {
        match ta[i].cmp(&tb[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                acc += va[i] * vb[j];
                i += 1;
                j += 1;
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        CsrMatrix::from_rows(
            5,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![],
                vec![(2, 1.0), (4, 1.0), (0, 4.0)],
            ],
        )
    }

    #[test]
    fn shape_and_rows() {
        let m = sample();
        assert_eq!(m.n_rows(), 4);
        assert_eq!(m.n_cols(), 5);
        assert_eq!(m.nnz(), 6);
        assert_eq!(m.row(0), (&[0u32, 2][..], &[1.0, 2.0][..]));
        assert_eq!(m.row(2).0.len(), 0);
        // unsorted input row 3 got sorted
        assert_eq!(m.row(3).0, &[0, 2, 4]);
        assert_eq!(m.row_nnz(3), 3);
    }

    #[test]
    fn duplicate_columns_are_summed() {
        let m = CsrMatrix::from_rows(3, &[vec![(1, 1.0), (1, 2.0), (0, 1.0)]]);
        assert_eq!(m.row(0), (&[0u32, 1][..], &[1.0, 3.0][..]));
    }

    #[test]
    fn norms_and_normalize() {
        let mut m = sample();
        assert!((m.row_norm(0) - 5.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(m.row_l1(3), 6.0);
        let zeros = m.l2_normalize_rows();
        assert_eq!(zeros, 1); // the empty row
        for i in [0usize, 1, 3] {
            assert!((m.row_norm(i) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn row_split_partitions_at_threshold() {
        let m = sample();
        let ((lts, lvs), (hts, hvs)) = m.row_split(3, 2);
        assert_eq!(lts, &[0]);
        assert_eq!(lvs, &[4.0]);
        assert_eq!(hts, &[2, 4]);
        assert_eq!(hvs, &[1.0, 1.0]);
        // Degenerate thresholds: everything low / everything high.
        assert_eq!(m.row_split(3, 5).0 .0.len(), 3);
        assert_eq!(m.row_split(3, 0).1 .0.len(), 3);
        assert_eq!(m.row_split(2, 3).0 .0.len(), 0); // empty row
    }

    #[test]
    fn dots() {
        let m = sample();
        // rows 0 and 3 share terms {0, 2}: 1*4 + 2*1 = 6
        assert_eq!(m.row_dot(0, 3), 6.0);
        assert_eq!(m.row_dot(0, 1), 0.0);
        let dense = [1.0, 1.0, 1.0, 1.0, 1.0];
        assert_eq!(m.row_dot_dense(3, &dense), 6.0);
    }

    #[test]
    fn df_and_colsum() {
        let m = sample();
        assert_eq!(m.column_df(), vec![2, 1, 2, 0, 1]);
        assert_eq!(m.column_sum(), vec![5.0, 3.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn permute_columns_preserves_data() {
        let mut m = sample();
        let before = m.row_dense(3);
        // reverse permutation
        let perm: Vec<u32> = (0..5).rev().collect();
        m.permute_columns(&perm);
        let after = m.row_dense(3);
        for c in 0..5 {
            assert_eq!(before[c], after[4 - c]);
        }
        // rows stay sorted
        let (ts, _) = m.row(3);
        assert!(ts.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn dot_sorted_edge_cases() {
        assert_eq!(dot_sorted(&[], &[], &[1], &[2.0]), 0.0);
        assert_eq!(dot_sorted(&[0, 5], &[1.0, 2.0], &[5], &[3.0]), 6.0);
    }
}
