//! Sparse document substrate: CSR matrices, sparse dot products, tf-idf
//! feature extraction, and the `Dataset` type consumed by every
//! clustering algorithm.

pub mod bm25;
pub mod csr;
pub mod tfidf;

pub use bm25::{build_dataset_bm25, Bm25Params};
pub use csr::{dot_sorted, CsrMatrix};
pub use tfidf::{build_dataset, Dataset};
