//! Universal-characteristics (UCs) analysis — Section III and
//! Appendix I.
//!
//! Four skewed-form phenomena that the ES-ICP design exploits, each with
//! an analyzer that regenerates the corresponding paper figure:
//!
//! 1. **Zipf's law** on term frequency (tf) and document frequency (df)
//!    — Fig. 2(a): [`rank_frequency`], [`zipf_exponent`].
//! 2. **Bounded Zipf's law** on mean frequency (mf) — Fig. 2(b):
//!    [`rank_frequency`] over a mean set's column df.
//! 3. **df–mf correlation** and the multiplication-volume diagram —
//!    Fig. 3: [`df_mf_profile`], [`mult_volume`].
//! 4. **Feature-value concentration** — Figs. 4(a)/9/11:
//!    [`value_skew`], [`order_value_cdf`]; and the **Pareto-like CPS** —
//!    Figs. 4(b)/21/22: [`cps_curve`].

pub mod cps;

pub use cps::{cps_curve, CpsCurve};

use crate::index::MeanSet;
use crate::sparse::Dataset;
use crate::util::stats::power_law_fit;

/// Rank–frequency series: frequencies sorted descending, paired with
/// 1-based ranks. Input is any per-item frequency vector (tf, df or mf).
pub fn rank_frequency(freqs: &[f64]) -> Vec<(f64, f64)> {
    let mut f: Vec<f64> = freqs.iter().cloned().filter(|&x| x > 0.0).collect();
    f.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    f.into_iter()
        .enumerate()
        .map(|(i, v)| ((i + 1) as f64, v))
        .collect()
}

/// Fit the Zipf exponent α over the top `head` ranks of a rank–frequency
/// series (Eq. 2): returns `(alpha, r2)` with `freq ∝ rank^-alpha`.
pub fn zipf_exponent(rank_freq: &[(f64, f64)], head: usize) -> (f64, f64) {
    let head = head.min(rank_freq.len());
    let xs: Vec<f64> = rank_freq[..head].iter().map(|p| p.0).collect();
    let ys: Vec<f64> = rank_freq[..head].iter().map(|p| p.1).collect();
    let (slope, _, r2) = power_law_fit(&xs, &ys);
    (-slope, r2)
}

/// Per-df average mean frequency `mf̄` (Eq. 3) — the Fig. 3(a) scatter
/// reduced to its trend: returns `(df, mf̄)` pairs sorted by df.
pub fn df_mf_profile(ds: &Dataset, means: &MeanSet) -> Vec<(f64, f64)> {
    let mf = means.m.column_df();
    let mut by_df: std::collections::BTreeMap<u32, (f64, u32)> = std::collections::BTreeMap::new();
    for s in 0..ds.d() {
        let e = by_df.entry(ds.df[s]).or_insert((0.0, 0));
        e.0 += mf[s] as f64;
        e.1 += 1;
    }
    by_df
        .into_iter()
        .map(|(df, (sum, cnt))| (df as f64, sum / cnt as f64))
        .collect()
}

/// The Fig. 3(b) quantity: per-term `df_s · mf_s` (the MIVI
/// multiplication volume), in term-id order (ascending df), plus the
/// cumulative fraction contributed by the top-df tail. Returns
/// `(total, frac_in_top_10pct_terms)`.
pub fn mult_volume(ds: &Dataset, means: &MeanSet) -> (f64, f64) {
    let mf = means.m.column_df();
    let d = ds.d();
    let per_term: Vec<f64> = (0..d)
        .map(|s| ds.df[s] as f64 * mf[s] as f64)
        .collect();
    let total: f64 = per_term.iter().sum();
    let top = per_term[d - d / 10..].iter().sum::<f64>();
    (total, if total > 0.0 { top / total } else { 0.0 })
}

/// Feature-value skew (Fig. 4(a)/11(a)): all non-zero mean-feature
/// values sorted descending, with ranks normalized by K. Returns
/// `(rank/K, value)` pairs, subsampled to at most `max_points`.
pub fn value_skew(means: &MeanSet, max_points: usize) -> Vec<(f64, f64)> {
    let k = means.k() as f64;
    let mut vals: Vec<f64> = Vec::with_capacity(means.m.nnz());
    for j in 0..means.k() {
        let (_, vs) = means.m.row(j);
        vals.extend_from_slice(vs);
    }
    vals.retain(|&v| v > 0.0);
    vals.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    let n = vals.len();
    let step = (n / max_points.max(1)).max(1);
    (0..n)
        .step_by(step)
        .map(|i| ((i + 1) as f64 / k, vals[i]))
        .collect()
}

/// Number of mean-feature values above `1/√2` — since no unit vector can
/// have two such components, this counts centroids exhibiting the
/// feature-value-concentration phenomenon (Section III).
pub fn concentration_count(means: &MeanSet) -> usize {
    let th = std::f64::consts::FRAC_1_SQRT_2;
    (0..means.k())
        .map(|j| {
            let (_, vs) = means.m.row(j);
            vs.iter().filter(|&&v| v > th).count()
        })
        .sum()
}

/// Fig. 9 / 11(b): for each requested order q (1-based position in a
/// mean-inverted-index array sorted descending by value), the empirical
/// CDF of the q-th largest value across all arrays with term id
/// `s ≥ t_th`. Returns, per order, sorted samples (value ascending) from
/// which `P(value ≤ x)` can be read directly.
pub fn order_value_cdf(
    means: &MeanSet,
    t_th: usize,
    orders: &[usize],
) -> Vec<(usize, Vec<f64>)> {
    let d = means.m.n_cols();
    let mut per_term: Vec<Vec<f64>> = vec![Vec::new(); d - t_th];
    for j in 0..means.k() {
        let (ts, vs) = means.m.row(j);
        for (&t, &v) in ts.iter().zip(vs) {
            let t = t as usize;
            if t >= t_th && v > 0.0 {
                per_term[t - t_th].push(v);
            }
        }
    }
    for l in &mut per_term {
        l.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    }
    orders
        .iter()
        .map(|&q| {
            let mut samples: Vec<f64> = per_term
                .iter()
                .filter(|l| l.len() >= q)
                .map(|l| l[q - 1])
                .collect();
            samples.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            (q, samples)
        })
        .collect()
}

/// Max / average array length over the high-df region (the paper quotes
/// max 75 042 and average 10 341 for PubMed at K = 80 000).
pub fn array_length_stats(means: &MeanSet, t_th: usize) -> (usize, f64) {
    let mf = means.m.column_df();
    let d = means.m.n_cols();
    let lens: Vec<usize> = (t_th..d).map(|s| mf[s] as usize).collect();
    let max = lens.iter().cloned().max().unwrap_or(0);
    let nonempty: Vec<usize> = lens.into_iter().filter(|&l| l > 0).collect();
    let avg = if nonempty.is_empty() {
        0.0
    } else {
        nonempty.iter().sum::<usize>() as f64 / nonempty.len() as f64
    };
    (max, avg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{run_clustering, AlgoKind, ClusterConfig};
    use crate::corpus::{generate, tiny, CorpusSpec};
    use crate::index::update_means;
    use crate::sparse::build_dataset;

    fn clustered() -> (Dataset, MeanSet) {
        let c = generate(&CorpusSpec {
            n_docs: 800,
            ..tiny(55)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 20,
            seed: 20,
            ..Default::default()
        };
        let out = run_clustering(AlgoKind::EsIcp, &ds, &cfg);
        let upd = update_means(&ds, &out.assign, 20, None, None);
        (ds, upd.means)
    }

    #[test]
    fn rank_frequency_sorted_and_positive() {
        let rf = rank_frequency(&[3.0, 0.0, 7.0, 1.0]);
        assert_eq!(rf, vec![(1.0, 7.0), (2.0, 3.0), (3.0, 1.0)]);
    }

    #[test]
    fn zipf_holds_on_synthetic_df() {
        let (ds, _) = clustered();
        let df: Vec<f64> = ds.df.iter().map(|&x| x as f64).collect();
        let rf = rank_frequency(&df);
        let (alpha, r2) = zipf_exponent(&rf, 80);
        assert!(alpha > 0.3, "df not Zipf-like: alpha={alpha}");
        assert!(r2 > 0.75, "poor power-law fit: r2={r2}");
    }

    #[test]
    fn bounded_zipf_on_mf() {
        let (_, means) = clustered();
        let mf: Vec<f64> = means.m.column_df().iter().map(|&x| x as f64).collect();
        let rf = rank_frequency(&mf);
        // Bounded: max mf cannot exceed K.
        assert!(rf[0].1 <= means.k() as f64);
        let (alpha, _) = zipf_exponent(&rf, 60);
        assert!(alpha > 0.1, "mf not skewed: alpha={alpha}");
    }

    #[test]
    fn df_mf_positively_correlated() {
        let (ds, means) = clustered();
        let prof = df_mf_profile(&ds, &means);
        // Compare average mf̄ in the low-df third vs the high-df third.
        let third = prof.len() / 3;
        let low: f64 = prof[..third].iter().map(|p| p.1).sum::<f64>() / third as f64;
        let high: f64 = prof[prof.len() - third..].iter().map(|p| p.1).sum::<f64>() / third as f64;
        // At unit-test scale (K = 20) mf saturates quickly, so the ratio
        // is modest; at bench scale it is ≫ 2 (see exp_ucs).
        assert!(
            high > low * 1.4,
            "df–mf correlation missing: low={low} high={high}"
        );
    }

    #[test]
    fn mult_volume_concentrated_in_high_df() {
        let (ds, means) = clustered();
        let (total, top_frac) = mult_volume(&ds, &means);
        assert!(total > 0.0);
        // Fig. 3(b): the top 10% of term ids carry a disproportionate
        // share of the multiplication volume.
        assert!(
            top_frac > 0.3,
            "multiplications not concentrated: top 10% carries {top_frac}"
        );
    }

    #[test]
    fn value_skew_is_decreasing_and_concentrated() {
        let (_, means) = clustered();
        let skew = value_skew(&means, 200);
        assert!(!skew.is_empty());
        assert!(skew.windows(2).all(|w| w[0].1 >= w[1].1));
        // Feature-value concentration: some centroid has a dominant term.
        assert!(
            concentration_count(&means) > 0,
            "no dominant features found"
        );
    }

    #[test]
    fn order_value_cdf_shapes() {
        let (_, means) = clustered();
        let d = means.m.n_cols();
        let cdfs = order_value_cdf(&means, d / 2, &[1, 2, 10]);
        assert_eq!(cdfs.len(), 3);
        // First-order values dominate higher orders stochastically:
        // compare medians where both defined.
        let med = |v: &Vec<f64>| v[v.len() / 2];
        let (q1, s1) = &cdfs[0];
        let (q10, s10) = &cdfs[2];
        assert_eq!((*q1, *q10), (1, 10));
        if !s1.is_empty() && !s10.is_empty() {
            assert!(med(s1) >= med(s10));
        }
    }

    #[test]
    fn array_length_stats_sane() {
        let (_, means) = clustered();
        let (max, avg) = array_length_stats(&means, 0);
        assert!(max >= 1);
        assert!(avg > 0.0 && avg <= max as f64);
        assert!(max <= means.k());
    }
}
