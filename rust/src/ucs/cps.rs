//! Cumulative partial similarity (CPS) — the Pareto-principle-like
//! phenomenon of Section III / Appendix I (Figs. 4(b), 21, 22).
//!
//! For each object, the partial similarities
//! `δρ(p) = u_(i,p) · μ_(a(i), t_(i,p))` to its own centroid are sorted
//! descending and accumulated; `CPS(i, h)` is the fraction of the total
//! similarity reached after the top `h` products and `NR = h / nt_i` the
//! normalized rank (Eqs. 52–54). Binned averaging over all objects
//! (Eqs. 55–56) yields the `CPS̄(NR)` curve with its standard deviation.

use crate::index::MeanSet;
use crate::sparse::Dataset;

/// The averaged CPS curve over all objects.
#[derive(Debug, Clone)]
pub struct CpsCurve {
    /// Normalized ranks (bin centers), 0 ..= 1.
    pub nr: Vec<f64>,
    /// Average CPS per bin.
    pub mean: Vec<f64>,
    /// Standard deviation per bin.
    pub std: Vec<f64>,
}

impl CpsCurve {
    /// CPS̄ at a given normalized rank (nearest bin) — e.g.
    /// `value_at(0.1)` reproduces the paper's "10% of multiplications →
    /// 92% of the similarity" headline.
    pub fn value_at(&self, nr: f64) -> f64 {
        let idx = ((nr.clamp(0.0, 1.0)) * (self.nr.len() - 1) as f64).round() as usize;
        self.mean[idx]
    }
}

/// Compute the averaged CPS curve with `bins + 1` points (δb = 1/bins;
/// the paper uses δb = 0.01). Objects with zero similarity to their
/// centroid are skipped (no curve is defined for them).
pub fn cps_curve(ds: &Dataset, means: &MeanSet, assign: &[u32], bins: usize) -> CpsCurve {
    assert!(bins >= 1);
    let nb = bins + 1;
    let mut sum = vec![0.0f64; nb];
    let mut sumsq = vec![0.0f64; nb];
    let mut count = 0u64;

    let mut partials: Vec<f64> = Vec::new();
    // Dense scratch per centroid would be K×D; instead densify each mean
    // on demand per *cluster* by grouping objects (cheaper: sort object
    // ids by assignment).
    let k = means.k();
    let mut by_cluster: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (i, &a) in assign.iter().enumerate() {
        by_cluster[a as usize].push(i as u32);
    }
    let mut dense = vec![0.0f64; means.m.n_cols()];
    for j in 0..k {
        if by_cluster[j].is_empty() {
            continue;
        }
        let (ts, vs) = means.m.row(j);
        for (&t, &v) in ts.iter().zip(vs) {
            dense[t as usize] = v;
        }
        for &i in &by_cluster[j] {
            let (ots, ovs) = ds.x.row(i as usize);
            partials.clear();
            let mut total = 0.0;
            for (&t, &u) in ots.iter().zip(ovs) {
                let p = u * dense[t as usize];
                if p > 0.0 {
                    partials.push(p);
                    total += p;
                }
            }
            if total <= 0.0 || partials.is_empty() {
                continue;
            }
            partials.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
            // Cumulative curve, linearly interpolated onto the bins.
            // NR(i, h) = h / nt_i uses the object's distinct-term count
            // (Eq. 53) — products that are zero contribute no mass but
            // do occupy rank positions.
            let nt = ots.len() as f64;
            let mut cum = 0.0;
            let mut h = 0usize;
            for b in 0..nb {
                let target_h = (b as f64 / bins as f64) * nt;
                while (h as f64) < target_h && h < partials.len() {
                    cum += partials[h];
                    h += 1;
                }
                // Fractional part via linear interpolation.
                let frac = target_h - target_h.floor();
                let extra = if h < partials.len() && frac > 0.0 && (h as f64) <= target_h {
                    partials[h] * frac
                } else {
                    0.0
                };
                let cps = ((cum + extra) / total).min(1.0);
                sum[b] += cps;
                sumsq[b] += cps * cps;
            }
            count += 1;
        }
        for &t in ts {
            dense[t as usize] = 0.0;
        }
    }

    let n = count.max(1) as f64;
    let mean: Vec<f64> = sum.iter().map(|s| s / n).collect();
    let std: Vec<f64> = sum
        .iter()
        .zip(&sumsq)
        .map(|(s, sq)| {
            let m = s / n;
            (sq / n - m * m).max(0.0).sqrt()
        })
        .collect();
    CpsCurve {
        nr: (0..nb).map(|b| b as f64 / bins as f64).collect(),
        mean,
        std,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{run_clustering, AlgoKind, ClusterConfig};
    use crate::corpus::{generate, tiny, CorpusSpec};
    use crate::index::update_means;
    use crate::sparse::build_dataset;

    #[test]
    fn cps_curve_is_monotone_and_ends_at_one() {
        let c = generate(&CorpusSpec {
            n_docs: 600,
            ..tiny(66)
        });
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 15,
            seed: 8,
            ..Default::default()
        };
        let out = run_clustering(AlgoKind::Mivi, &ds, &cfg);
        let upd = update_means(&ds, &out.assign, 15, None, None);
        let curve = cps_curve(&ds, &upd.means, &out.assign, 100);
        assert_eq!(curve.nr.len(), 101);
        assert!((curve.mean[0]).abs() < 1e-9);
        assert!((curve.mean[100] - 1.0).abs() < 1e-9);
        for w in curve.mean.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "CPS not monotone");
        }
        // Pareto-like: the curve is strongly concave — a small NR already
        // captures most of the similarity (paper: CPS(0.1) ≈ 0.92 on
        // PubMed; synthetic corpora are less extreme but clearly super-
        // linear).
        assert!(
            curve.value_at(0.1) > 0.3,
            "CPS(0.1) = {} — no Pareto concentration",
            curve.value_at(0.1)
        );
        assert!(curve.value_at(0.5) > 0.8);
        // STD is small at the endpoints by construction.
        assert!(curve.std[0] < 1e-9 && curve.std[100] < 1e-9);
    }
}
