//! Clustering-comparison measures for the initial-state-independence study
//! (Appendix H): normalized mutual information (Eqs. 49–50), entropy, and
//! the pairwise-NMI average over seed ensembles.

use std::collections::HashMap;

/// Entropy (nats) of a labeling.
pub fn entropy(labels: &[u32]) -> f64 {
    let n = labels.len() as f64;
    if labels.is_empty() {
        return 0.0;
    }
    let mut counts: HashMap<u32, u64> = HashMap::new();
    for &l in labels {
        *counts.entry(l).or_insert(0) += 1;
    }
    counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        })
        .sum()
}

/// Mutual information (nats) between two labelings of the same objects.
pub fn mutual_information(a: &[u32], b: &[u32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if a.is_empty() {
        return 0.0;
    }
    let mut joint: HashMap<(u32, u32), u64> = HashMap::new();
    let mut ca: HashMap<u32, u64> = HashMap::new();
    let mut cb: HashMap<u32, u64> = HashMap::new();
    for (&x, &y) in a.iter().zip(b) {
        *joint.entry((x, y)).or_insert(0) += 1;
        *ca.entry(x).or_insert(0) += 1;
        *cb.entry(y).or_insert(0) += 1;
    }
    joint
        .iter()
        .map(|(&(x, y), &c)| {
            let pxy = c as f64 / n;
            let px = ca[&x] as f64 / n;
            let py = cb[&y] as f64 / n;
            pxy * (pxy / (px * py)).ln()
        })
        .sum()
}

/// NMI(C_a, C_b) = I / sqrt(H_a · H_b) (Eq. 49). Returns 1.0 when both
/// labelings are single-cluster (degenerate but identical).
pub fn nmi(a: &[u32], b: &[u32]) -> f64 {
    let ha = entropy(a);
    let hb = entropy(b);
    if ha == 0.0 && hb == 0.0 {
        return 1.0;
    }
    if ha == 0.0 || hb == 0.0 {
        return 0.0;
    }
    (mutual_information(a, b) / (ha * hb).sqrt()).clamp(0.0, 1.0)
}

/// Average pairwise NMI over an ensemble of labelings (Eq. 50), plus the
/// standard deviation across pairs. Requires at least 2 labelings.
pub fn pairwise_nmi(ensemble: &[Vec<u32>]) -> (f64, f64) {
    assert!(ensemble.len() >= 2);
    let mut vals = Vec::new();
    for i in 0..ensemble.len() {
        for j in (i + 1)..ensemble.len() {
            vals.push(nmi(&ensemble[i], &ensemble[j]));
        }
    }
    let m = crate::util::stats::mean(&vals);
    let s = crate::util::stats::std_dev(&vals);
    (m, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_uniform() {
        let labels = [0, 0, 1, 1, 2, 2, 3, 3];
        assert!((entropy(&labels) - (4f64).ln()).abs() < 1e-12);
        assert_eq!(entropy(&[5, 5, 5]), 0.0);
    }

    #[test]
    fn nmi_identical_is_one() {
        let a = [0, 1, 2, 0, 1, 2, 1, 1];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        // NMI is invariant to label renaming
        let b: Vec<u32> = a.iter().map(|&x| 10 - x).collect();
        assert!((nmi(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nmi_independent_is_near_zero() {
        // a splits first/second half; b splits even/odd — independent.
        let n = 1000;
        let a: Vec<u32> = (0..n).map(|i| (i < n / 2) as u32).collect();
        let b: Vec<u32> = (0..n).map(|i| (i % 2) as u32).collect();
        assert!(nmi(&a, &b) < 0.01);
    }

    #[test]
    fn nmi_symmetric() {
        let a = [0, 0, 1, 1, 2, 2];
        let b = [0, 1, 1, 2, 2, 2];
        assert!((nmi(&a, &b) - nmi(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn pairwise_over_ensemble() {
        let e = vec![vec![0, 0, 1, 1], vec![1, 1, 0, 0], vec![0, 1, 0, 1]];
        let (m, s) = pairwise_nmi(&e);
        // pairs (0,1) identical → 1.0; (0,2) and (1,2) independent → 0.0
        assert!((m - 1.0 / 3.0).abs() < 1e-9, "m={m}");
        assert!(s > 0.0);
    }
}
