//! Software cost counters — the paper's primary efficiency metric is the
//! number of multiplications for similarity calculations (Section I,
//! footnote 2), plus proxies for the other two performance-degradation
//! factors when hardware counters are unavailable (see `metrics::perf`).
//!
//! Counters are incremented at *loop granularity* (e.g. "this object
//! touched an inverted array of length mf_s → mf_s multiply-adds"), never
//! per scalar operation, so instrumentation does not distort the timings
//! it accompanies.

/// Per-iteration cost counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounters {
    /// Multiply-add operations for similarity and upper-bound
    /// calculations (the paper's "Mult"; upper-bound multiplications are
    /// included, Section VI-D).
    pub mult: u64,
    /// Data-dependent conditional branches whose outcome is irregular
    /// (value comparisons inside inner loops) — the BM proxy.
    pub irregular_branches: u64,
    /// Touches of arrays that are cold / too large for cache (full-
    /// expression mean vectors, partial indexes) — the LLCM proxy.
    pub cold_touches: u64,
    /// Centroids that passed the pruning filters and reached the
    /// verification phase: Σ_i |Z_i| (numerator of the CPR, Eq. 22).
    pub candidates: u64,
    /// Exact similarities fully computed.
    pub exact_sims: u64,
    /// Square-root operations (the CS filter's per-candidate cost,
    /// Appendix F-B).
    pub sqrts: u64,
}

impl OpCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, other: &OpCounters) {
        self.mult += other.mult;
        self.irregular_branches += other.irregular_branches;
        self.cold_touches += other.cold_touches;
        self.candidates += other.candidates;
        self.exact_sims += other.exact_sims;
        self.sqrts += other.sqrts;
    }

    /// Complementary pruning rate for one iteration (Eq. 22):
    /// CPR = (1/N) Σ |Z_i| / K. Lower is a better filter.
    pub fn cpr(&self, n: usize, k: usize) -> f64 {
        if n == 0 || k == 0 {
            return 0.0;
        }
        self.candidates as f64 / (n as f64 * k as f64)
    }
}

/// Accumulates per-iteration snapshots for a whole clustering run.
#[derive(Debug, Clone, Default)]
pub struct RunCounters {
    pub per_iter: Vec<OpCounters>,
}

impl RunCounters {
    pub fn push(&mut self, c: OpCounters) {
        self.per_iter.push(c);
    }

    pub fn total(&self) -> OpCounters {
        let mut t = OpCounters::default();
        for c in &self.per_iter {
            t.add(c);
        }
        t
    }

    pub fn avg_mult(&self) -> f64 {
        if self.per_iter.is_empty() {
            return 0.0;
        }
        self.total().mult as f64 / self.per_iter.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_total() {
        let mut run = RunCounters::default();
        run.push(OpCounters {
            mult: 10,
            candidates: 4,
            ..Default::default()
        });
        run.push(OpCounters {
            mult: 30,
            irregular_branches: 5,
            ..Default::default()
        });
        let t = run.total();
        assert_eq!(t.mult, 40);
        assert_eq!(t.irregular_branches, 5);
        assert_eq!(run.avg_mult(), 20.0);
    }

    #[test]
    fn cpr_matches_definition() {
        let c = OpCounters {
            candidates: 50,
            ..Default::default()
        };
        // N=10 objects, K=10 centroids, 50 candidates → CPR = 0.5
        assert!((c.cpr(10, 10) - 0.5).abs() < 1e-12);
        assert_eq!(c.cpr(0, 10), 0.0);
    }
}
