//! Hardware performance counters via `perf_event_open(2)`.
//!
//! The paper measures completed instructions, branch mispredictions, and
//! last-level-cache load misses with the Linux `perf` CLI (Table II et
//! seq.). We read the same PMU events directly through the syscall (no
//! `perf` binary needed). Containers frequently disable PMU access
//! (`perf_event_paranoid`, seccomp, or missing PMU virtualization); in
//! that case `PerfGroup::try_new` returns `None` and the harnesses fall
//! back to the software cost model in `metrics::counters` — the
//! substitution is documented in DESIGN.md §3.

use std::mem;

const PERF_TYPE_HARDWARE: u32 = 0;
const PERF_TYPE_HW_CACHE: u32 = 3;

const PERF_COUNT_HW_INSTRUCTIONS: u64 = 1;
const PERF_COUNT_HW_CACHE_MISSES: u64 = 3;
const PERF_COUNT_HW_BRANCH_INSTRUCTIONS: u64 = 4;
const PERF_COUNT_HW_BRANCH_MISSES: u64 = 5;
const PERF_COUNT_HW_CACHE_LL: u64 = 2;
const PERF_COUNT_HW_CACHE_OP_READ: u64 = 0;
const PERF_COUNT_HW_CACHE_RESULT_ACCESS: u64 = 0;
const PERF_COUNT_HW_CACHE_RESULT_MISS: u64 = 1;

/// Subset of `struct perf_event_attr` we need (layout-compatible prefix;
/// the kernel accepts any size ≥ PERF_ATTR_SIZE_VER0 = 64).
#[repr(C)]
#[derive(Clone, Copy)]
struct PerfEventAttr {
    type_: u32,
    size: u32,
    config: u64,
    sample_period_or_freq: u64,
    sample_type: u64,
    read_format: u64,
    flags: u64,
    wakeup: u32,
    bp_type: u32,
    config1: u64,
    config2: u64,
    branch_sample_type: u64,
    sample_regs_user: u64,
    sample_stack_user: u32,
    clockid: i32,
    sample_regs_intr: u64,
    aux_watermark: u32,
    sample_max_stack: u16,
    _reserved: u16,
}

const ATTR_FLAG_DISABLED: u64 = 1;
const ATTR_FLAG_EXCLUDE_KERNEL: u64 = 1 << 5;
const ATTR_FLAG_EXCLUDE_HV: u64 = 1 << 6;

fn perf_event_open(attr: &PerfEventAttr, group_fd: i64) -> i64 {
    unsafe {
        libc::syscall(
            libc::SYS_perf_event_open,
            attr as *const PerfEventAttr,
            0i32,  // pid = self
            -1i32, // any cpu
            group_fd as i32,
            0u64, // flags
        )
    }
}

/// One measured quantity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Event {
    Instructions,
    Branches,
    BranchMisses,
    /// Last-level-cache load misses (falls back to generic cache-misses
    /// if the LL cache event is not supported).
    LlcLoadMisses,
    LlcLoads,
}

impl Event {
    fn attr(self) -> PerfEventAttr {
        let (type_, config) = match self {
            Event::Instructions => (PERF_TYPE_HARDWARE, PERF_COUNT_HW_INSTRUCTIONS),
            Event::Branches => (PERF_TYPE_HARDWARE, PERF_COUNT_HW_BRANCH_INSTRUCTIONS),
            Event::BranchMisses => (PERF_TYPE_HARDWARE, PERF_COUNT_HW_BRANCH_MISSES),
            Event::LlcLoadMisses => (
                PERF_TYPE_HW_CACHE,
                PERF_COUNT_HW_CACHE_LL
                    | (PERF_COUNT_HW_CACHE_OP_READ << 8)
                    | (PERF_COUNT_HW_CACHE_RESULT_MISS << 16),
            ),
            Event::LlcLoads => (
                PERF_TYPE_HW_CACHE,
                PERF_COUNT_HW_CACHE_LL
                    | (PERF_COUNT_HW_CACHE_OP_READ << 8)
                    | (PERF_COUNT_HW_CACHE_RESULT_ACCESS << 16),
            ),
        };
        PerfEventAttr {
            type_,
            size: mem::size_of::<PerfEventAttr>() as u32,
            config,
            sample_period_or_freq: 0,
            sample_type: 0,
            read_format: 0,
            flags: ATTR_FLAG_DISABLED | ATTR_FLAG_EXCLUDE_KERNEL | ATTR_FLAG_EXCLUDE_HV,
            wakeup: 0,
            bp_type: 0,
            config1: 0,
            config2: 0,
            branch_sample_type: 0,
            sample_regs_user: 0,
            sample_stack_user: 0,
            clockid: 0,
            sample_regs_intr: 0,
            aux_watermark: 0,
            sample_max_stack: 0,
            _reserved: 0,
        }
    }

}

/// Owned perf-event file descriptor: closed exactly once, on drop.
///
/// Every fd returned by `perf_event_open` is wrapped in one of these
/// *immediately*, so there is no code path — partial group setup, an
/// early `return None`, a panic between opens — on which an opened fd
/// can outlive its owner. Long-running `skm serve` processes retry
/// counter setup; before this type, each failed retry relied on a
/// hand-written close loop that any new early return would bypass.
struct PerfFd(i32);

impl Drop for PerfFd {
    fn drop(&mut self) {
        unsafe { libc::close(self.0) };
    }
}

/// A group of hardware counters enabled/disabled together. Dropping
/// the group closes every fd (via [`PerfFd`]); a partially-opened
/// group that fails mid-setup closes the already-opened fds the same
/// way when the local `Vec` unwinds.
pub struct PerfGroup {
    fds: Vec<(Event, PerfFd)>,
}

impl PerfGroup {
    /// Try to open the paper's counter set. Returns `None` when the
    /// kernel refuses PMU access (typical in containers); any fds
    /// opened before the refusal are closed by their owners as the
    /// partial `fds` vector drops.
    pub fn try_new() -> Option<Self> {
        let wanted = [
            Event::Instructions,
            Event::Branches,
            Event::BranchMisses,
            Event::LlcLoads,
            Event::LlcLoadMisses,
        ];
        let mut fds: Vec<(Event, PerfFd)> = Vec::new();
        for ev in wanted {
            let fd = perf_event_open(&ev.attr(), -1);
            if fd >= 0 {
                fds.push((ev, PerfFd(fd as i32)));
                continue;
            }
            // LLC events may be unsupported even when the basic ones
            // work; try the generic cache events for those.
            if matches!(ev, Event::LlcLoads | Event::LlcLoadMisses) {
                let mut attr = ev.attr();
                attr.type_ = PERF_TYPE_HARDWARE;
                // cache-references = 2, cache-misses = 3 (generic HW events)
                attr.config = if ev == Event::LlcLoads {
                    2
                } else {
                    PERF_COUNT_HW_CACHE_MISSES
                };
                let fd2 = perf_event_open(&attr, -1);
                if fd2 >= 0 {
                    fds.push((ev, PerfFd(fd2 as i32)));
                    continue;
                }
            }
            // Dropping `fds` here closes every fd opened so far.
            return None;
        }
        Some(Self { fds })
    }

    pub fn start(&self) {
        for (_, fd) in &self.fds {
            unsafe {
                libc::ioctl(fd.0, 0x2403 /* PERF_EVENT_IOC_RESET */, 0);
                libc::ioctl(fd.0, 0x2400 /* PERF_EVENT_IOC_ENABLE */, 0);
            }
        }
    }

    pub fn stop(&self) -> PerfReading {
        let mut out = PerfReading::default();
        for (ev, fd) in &self.fds {
            unsafe {
                libc::ioctl(fd.0, 0x2401 /* PERF_EVENT_IOC_DISABLE */, 0);
            }
            let mut value: u64 = 0;
            let n = unsafe {
                libc::read(
                    fd.0,
                    &mut value as *mut u64 as *mut libc::c_void,
                    mem::size_of::<u64>(),
                )
            };
            if n == mem::size_of::<u64>() as isize {
                match ev {
                    Event::Instructions => out.instructions = value,
                    Event::Branches => out.branches = value,
                    Event::BranchMisses => out.branch_misses = value,
                    Event::LlcLoads => out.llc_loads = value,
                    Event::LlcLoadMisses => out.llc_load_misses = value,
                }
            }
        }
        out
    }
}

/// Wall-clock seconds spent in each phase of one clustering iteration
/// (§Perf instrumentation): index **rebuild** (incremental splice or
/// from-scratch build, plus EstParams), assignment **gather**
/// (region-1/2 accumulation + pruning filters), assignment **verify**
/// (partial-index exact pass + argmax), and mean **update** (centroid
/// construction + ρ/ICP bookkeeping).
///
/// Assigners accumulate gather/verify per shard and the coordinator
/// fills rebuild/update; the merged breakdown lands in
/// `algo::IterLog` and the `--bench-json` report. Timing never affects
/// results — the sharded engine stays bit-identical to the serial path.
///
/// **Units caveat:** `gather`/`verify` are summed across shard workers,
/// so under `--threads N` they are *CPU-seconds* and can exceed the
/// assignment *wall* time by up to N×; they equal wall time only in
/// serial runs. `rebuild`/`update` are wall-clock (the coordinator
/// times those phases on one thread).
///
/// The per-object gather/verify probes cost two `Instant::now()` calls
/// per object (~50 ns); set `SKM_PHASE_TIMING=0` to disable them for
/// maximum-fidelity timing runs (the phases then read 0).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTimes {
    pub rebuild: f64,
    pub gather: f64,
    pub verify: f64,
    pub update: f64,
}

/// Whether the per-object gather/verify probes are enabled
/// (`SKM_PHASE_TIMING`, default on; `0` disables). Read once per
/// assigner at construction.
pub fn phase_timing_enabled() -> bool {
    std::env::var("SKM_PHASE_TIMING")
        .map(|v| v != "0")
        .unwrap_or(true)
}

impl PhaseTimes {
    pub fn add(&mut self, o: &PhaseTimes) {
        self.rebuild += o.rebuild;
        self.gather += o.gather;
        self.verify += o.verify;
        self.update += o.update;
    }

    /// Total seconds across all four phases.
    pub fn total(&self) -> f64 {
        self.rebuild + self.gather + self.verify + self.update
    }
}

/// Counter values from one measurement window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PerfReading {
    pub instructions: u64,
    pub branches: u64,
    pub branch_misses: u64,
    pub llc_loads: u64,
    pub llc_load_misses: u64,
}

impl PerfReading {
    pub fn add(&mut self, o: &PerfReading) {
        self.instructions += o.instructions;
        self.branches += o.branches;
        self.branch_misses += o.branch_misses;
        self.llc_loads += o.llc_loads;
        self.llc_load_misses += o.llc_load_misses;
    }
}

/// Measure a closure with hardware counters when available.
/// Returns `(result, Some(reading))` or `(result, None)` if PMU access is
/// denied.
pub fn measure<T>(f: impl FnOnce() -> T) -> (T, Option<PerfReading>) {
    match PerfGroup::try_new() {
        Some(g) => {
            g.start();
            let out = f();
            let r = g.stop();
            (out, Some(r))
        }
        None => (f(), None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_is_safe_either_way() {
        // Works whether or not the container allows PMU access.
        let (sum, reading) = measure(|| {
            let mut s = 0u64;
            for i in 0..100_000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(sum > 0);
        if let Some(r) = reading {
            // If counters worked at all, the loop must have retired a
            // nontrivial number of instructions.
            assert!(r.instructions > 10_000, "instructions={}", r.instructions);
            println!("perf available: {r:?}");
        } else {
            println!("perf unavailable in this environment (fallback path)");
        }
    }

    /// Number of open file descriptors for this process.
    fn open_fd_count() -> Option<usize> {
        Some(std::fs::read_dir("/proc/self/fd").ok()?.count())
    }

    #[test]
    fn group_setup_never_leaks_fds() {
        // Whether try_new succeeds, fails outright, or fails after
        // opening a few events, repeated setup/teardown must leave the
        // process fd table where it started. This is the `skm serve`
        // retry loop in miniature.
        let Some(before) = open_fd_count() else {
            println!("/proc/self/fd unavailable; skipping");
            return;
        };
        for _ in 0..32 {
            drop(PerfGroup::try_new());
        }
        let after = open_fd_count().unwrap();
        assert_eq!(
            before, after,
            "perf group setup leaked {} fds over 32 retries",
            after as isize - before as isize
        );
    }

    #[test]
    fn phase_times_accumulate() {
        let mut p = PhaseTimes::default();
        p.add(&PhaseTimes {
            rebuild: 1.0,
            gather: 2.0,
            verify: 3.0,
            update: 4.0,
        });
        p.add(&PhaseTimes {
            rebuild: 0.5,
            ..Default::default()
        });
        assert_eq!(p.rebuild, 1.5);
        assert_eq!(p.gather, 2.0);
        assert_eq!(p.total(), 10.5);
    }

    #[test]
    fn reading_add() {
        let mut a = PerfReading {
            instructions: 1,
            branches: 2,
            branch_misses: 3,
            llc_loads: 4,
            llc_load_misses: 5,
        };
        a.add(&a.clone());
        assert_eq!(a.instructions, 2);
        assert_eq!(a.llc_load_misses, 10);
    }
}
