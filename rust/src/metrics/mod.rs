//! Instrumentation and evaluation: software cost counters (Mult, CPR),
//! hardware PMU counters (Inst/BM/LLCM via perf_event_open), and
//! clustering-quality measures (objective J, NMI, CV) used by the
//! Appendix-H study.

pub mod counters;
pub mod nmi;
pub mod perf;

pub use counters::{OpCounters, RunCounters};
pub use nmi::{entropy, mutual_information, nmi, pairwise_nmi};
pub use perf::{measure, PerfGroup, PerfReading, PhaseTimes};
