//! Periodic checkpoint / resume for long clustering runs.
//!
//! A checkpoint captures the *complete* mid-run state of a driver —
//! assignment, ρ, ICP invariance flags, the mean set (values, moved
//! flags, sizes), the estimator's structural-parameter state, and (for
//! mini-batch runs) the decay counters, observation rounds, RNG stream
//! position, and batch cursor — so that a resumed run continues on a
//! trajectory **bit-identical** to the uninterrupted one
//! (`rust/tests/persist.rs` enforces this per algorithm).
//!
//! Each checkpoint file also embeds a [`RunFingerprint`] of the run
//! configuration and the corpus content. `--resume` against a
//! checkpoint from a different corpus, algorithm, K, seed, or sampling
//! configuration is a typed [`SkmError::InvalidConfig`] (exit 2), not a
//! silently-diverging run. The iteration/round *cap* is deliberately
//! excluded from the fingerprint: resuming with a larger
//! `--max-iters` / `--rounds` is the supported way to extend a finished
//! run, and the trajectory through the already-computed rounds is
//! unchanged by the cap.
//!
//! Files use the same block format, atomic publish, and paranoid
//! validation as serving snapshots (see [`crate::persist`]).

use crate::algo::{AlgoKind, ClusterConfig, ParamsState};
use crate::coordinator::{BatchSchedule, MiniBatchConfig};
use crate::error::{SkmError, SkmResult};
use crate::index::{MeanSet, RowSlab};
use crate::persist::format::{
    ByteReader, ByteWriter, KIND_CLUSTER_CKPT, KIND_MINIBATCH_CKPT,
};
use crate::persist::reader::read_blocks_file;
use crate::persist::{
    sec, section_bools, section_f64s, section_u32s, section_usizes, validated_csr, writer,
};
use crate::sparse::Dataset;
use std::path::{Path, PathBuf};

/// Where and how often a driver writes checkpoints.
#[derive(Debug, Clone)]
pub struct CheckpointSpec {
    /// Write a checkpoint every `every` completed rounds (0 = only the
    /// final checkpoint at run completion).
    pub every: usize,
    /// Destination path; each checkpoint atomically replaces the last.
    pub path: PathBuf,
}

// ---------------------------------------------------------------------
// Run fingerprint

/// Identity of a clustering run: everything that determines the
/// bit-exact trajectory. Threading (`ParConfig`) is excluded — the
/// sharded engine is bit-identical to serial — and so are the
/// iteration/round caps (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunFingerprint {
    pub algo: String,
    pub k: u64,
    pub seed: u64,
    pub t_th_frac_bits: u64,
    pub s_min_frac_bits: u64,
    pub n_vth_candidates: u64,
    pub n: u64,
    pub d: u64,
    pub nnz: u64,
    /// FNV-1a 64 digest over the corpus arrays (CSR + df + relabeling).
    pub corpus_digest: u64,
    /// Mini-batch configuration; all-zero for full-batch runs.
    pub mb_batch: u64,
    /// 0 = full-batch, 1 = sequential, 2 = reservoir.
    pub mb_schedule: u32,
    pub mb_decay_bits: u64,
    pub mb_sample_seed: u64,
}

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

fn corpus_digest(ds: &Dataset) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let (_, indptr, indices, values) = ds.x.raw_parts();
    for &p in indptr {
        fnv1a(&mut h, &(p as u64).to_le_bytes());
    }
    for &t in indices {
        fnv1a(&mut h, &t.to_le_bytes());
    }
    for &v in values {
        fnv1a(&mut h, &v.to_bits().to_le_bytes());
    }
    for &f in &ds.df {
        fnv1a(&mut h, &f.to_le_bytes());
    }
    for &t in &ds.orig_term {
        fnv1a(&mut h, &t.to_le_bytes());
    }
    h
}

impl RunFingerprint {
    pub fn compute(
        kind: AlgoKind,
        ds: &Dataset,
        cfg: &ClusterConfig,
        mb: Option<&MiniBatchConfig>,
    ) -> Self {
        Self {
            algo: kind.name().to_string(),
            k: cfg.k as u64,
            seed: cfg.seed,
            t_th_frac_bits: cfg.t_th_frac.to_bits(),
            s_min_frac_bits: cfg.s_min_frac.to_bits(),
            n_vth_candidates: cfg.n_vth_candidates as u64,
            n: ds.n() as u64,
            d: ds.d() as u64,
            nnz: ds.x.nnz() as u64,
            corpus_digest: corpus_digest(ds),
            mb_batch: mb.map_or(0, |m| m.batch as u64),
            mb_schedule: mb.map_or(0, |m| match m.schedule {
                BatchSchedule::Sequential => 1,
                BatchSchedule::Reservoir => 2,
            }),
            mb_decay_bits: mb.map_or(0, |m| m.decay.to_bits()),
            mb_sample_seed: mb.map_or(0, |m| m.sample_seed),
        }
    }

    /// Error (typed `InvalidConfig`, exit 2) unless `stored` matches
    /// this run exactly, naming the first differing field.
    pub fn verify_matches(&self, stored: &RunFingerprint) -> SkmResult<()> {
        let mismatch = |field: &str, want: String, got: String| {
            Err(SkmError::invalid_config(format!(
                "--resume checkpoint does not belong to this run: {field} differs \
                 (checkpoint {got}, current run {want})"
            )))
        };
        if stored.algo != self.algo {
            return mismatch("algorithm", self.algo.clone(), stored.algo.clone());
        }
        macro_rules! check {
            ($field:ident, $label:expr) => {
                if stored.$field != self.$field {
                    return mismatch(
                        $label,
                        format!("{:?}", self.$field),
                        format!("{:?}", stored.$field),
                    );
                }
            };
        }
        check!(k, "K");
        check!(seed, "seed");
        check!(t_th_frac_bits, "t_th_frac");
        check!(s_min_frac_bits, "s_min_frac");
        check!(n_vth_candidates, "n_vth_candidates");
        check!(n, "corpus size N");
        check!(d, "vocabulary size D");
        check!(nnz, "corpus nnz");
        check!(corpus_digest, "corpus content digest");
        check!(mb_batch, "mini-batch size");
        check!(mb_schedule, "mini-batch schedule");
        check!(mb_decay_bits, "mini-batch decay");
        check!(mb_sample_seed, "mini-batch sample seed");
        Ok(())
    }

    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_str(&self.algo);
        for v in [
            self.k,
            self.seed,
            self.t_th_frac_bits,
            self.s_min_frac_bits,
            self.n_vth_candidates,
            self.n,
            self.d,
            self.nnz,
            self.corpus_digest,
            self.mb_batch,
        ] {
            w.put_u64(v);
        }
        w.put_u32(self.mb_schedule);
        w.put_u64(self.mb_decay_bits);
        w.put_u64(self.mb_sample_seed);
        w.into_bytes()
    }

    fn decode(r: &mut ByteReader) -> Result<Self, String> {
        let algo = r.get_str()?;
        let mut u = || r.get_u64();
        let k = u()?;
        let seed = u()?;
        let t_th_frac_bits = u()?;
        let s_min_frac_bits = u()?;
        let n_vth_candidates = u()?;
        let n = u()?;
        let d = u()?;
        let nnz = u()?;
        let corpus_digest = u()?;
        let mb_batch = u()?;
        let mb_schedule = r.get_u32()?;
        let mb_decay_bits = r.get_u64()?;
        let mb_sample_seed = r.get_u64()?;
        Ok(Self {
            algo,
            k,
            seed,
            t_th_frac_bits,
            s_min_frac_bits,
            n_vth_candidates,
            n,
            d,
            nnz,
            corpus_digest,
            mb_batch,
            mb_schedule,
            mb_decay_bits,
            mb_sample_seed,
        })
    }
}

// ---------------------------------------------------------------------
// Checkpoint payloads

/// Borrowed full-batch driver state for serialization (the save path
/// never clones the big arrays).
#[derive(Debug, Clone, Copy)]
pub struct CheckpointState<'a> {
    /// 1-based round whose update + rebuild this state reflects.
    pub round: usize,
    pub objective: f64,
    pub max_mem: usize,
    pub params: ParamsState,
    pub assign: &'a [u32],
    pub rho: &'a [f64],
    pub xstate: &'a [bool],
    pub means: &'a MeanSet,
}

/// Borrowed mini-batch driver extras for serialization.
#[derive(Debug, Clone, Copy)]
pub struct MbStateRef<'a> {
    pub counts: &'a [f64],
    pub sizes: &'a [u32],
    pub obs_round: &'a [u32],
    pub last_moved: &'a [u32],
    pub mr_latest: u32,
    pub mr_prev: u32,
    pub rng_state: u64,
    pub rng_inc: u64,
    pub cursor: usize,
    pub processed: usize,
    pub quiet: usize,
    /// Running Σ_i ρ_i the driver maintains incrementally between
    /// epoch-boundary re-sums; must survive resume bit-exactly or the
    /// resumed objective trajectory diverges in the low bits.
    pub obj_sum: f64,
}

/// A loaded, fully-validated full-batch checkpoint.
#[derive(Debug, Clone)]
pub struct ClusterCheckpoint {
    pub round: usize,
    pub objective: f64,
    pub max_mem: usize,
    pub params: ParamsState,
    pub assign: Vec<u32>,
    pub rho: Vec<f64>,
    pub xstate: Vec<bool>,
    pub means: MeanSet,
}

/// Loaded mini-batch driver extras.
#[derive(Debug, Clone)]
pub struct MbDriverState {
    pub counts: Vec<f64>,
    pub sizes: Vec<u32>,
    pub obs_round: Vec<u32>,
    pub last_moved: Vec<u32>,
    pub mr_latest: u32,
    pub mr_prev: u32,
    pub rng_state: u64,
    pub rng_inc: u64,
    pub cursor: usize,
    pub processed: usize,
    pub quiet: usize,
    pub obj_sum: f64,
}

/// A loaded, fully-validated mini-batch checkpoint.
#[derive(Debug, Clone)]
pub struct MinibatchCheckpoint {
    pub base: ClusterCheckpoint,
    pub mb: MbDriverState,
}

fn encode_driver(st: &CheckpointState) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_u64(st.round as u64);
    w.put_f64_bits(st.objective);
    w.put_u64(st.max_mem as u64);
    w.put_u32(u32::from(st.params.t_th.is_some()));
    w.put_u64(st.params.t_th.unwrap_or(0) as u64);
    w.put_u32(u32::from(st.params.v_th.is_some()));
    w.put_f64_bits(st.params.v_th.unwrap_or(0.0));
    w.put_u64(st.params.estimations_done as u64);
    w.into_bytes()
}

fn encode_mb_driver(mb: &MbStateRef) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.put_f64s(mb.counts);
    w.put_u32s(mb.sizes);
    w.put_u32s(mb.obs_round);
    w.put_u32s(mb.last_moved);
    w.put_u32(mb.mr_latest);
    w.put_u32(mb.mr_prev);
    w.put_u64(mb.rng_state);
    w.put_u64(mb.rng_inc);
    w.put_u64(mb.cursor as u64);
    w.put_u64(mb.processed as u64);
    w.put_u64(mb.quiet as u64);
    w.put_f64_bits(mb.obj_sum);
    w.into_bytes()
}

fn common_sections(fp: &RunFingerprint, st: &CheckpointState) -> Vec<(u32, Vec<u8>)> {
    // The slab's physical layout is an in-memory detail; checkpoints
    // keep the CSR on-disk format (also canonicalizes the byte stream
    // regardless of splice history).
    let mcsr = st.means.m.to_csr();
    let (m_cols, m_indptr, m_indices, m_values) = mcsr.raw_parts();
    let _ = m_cols;
    let enc_u32s = |v: &[u32]| {
        let mut w = ByteWriter::new();
        w.put_u32s(v);
        w.into_bytes()
    };
    let enc_usizes = |v: &[usize]| {
        let mut w = ByteWriter::new();
        w.put_usizes(v);
        w.into_bytes()
    };
    let enc_f64s = |v: &[f64]| {
        let mut w = ByteWriter::new();
        w.put_f64s(v);
        w.into_bytes()
    };
    let enc_bools = |v: &[bool]| {
        let mut w = ByteWriter::new();
        w.put_bools(v);
        w.into_bytes()
    };
    vec![
        (sec::FINGERPRINT, fp.encode()),
        (sec::DRIVER, encode_driver(st)),
        (sec::ASSIGN, enc_u32s(st.assign)),
        (sec::RHO, enc_f64s(st.rho)),
        (sec::XSTATE, enc_bools(st.xstate)),
        (sec::MEANS_INDPTR, enc_usizes(m_indptr)),
        (sec::MEANS_INDICES, enc_u32s(m_indices)),
        (sec::MEANS_VALUES, enc_f64s(m_values)),
        (sec::MEAN_SIZES, enc_u32s(&st.means.sizes)),
        (sec::MEANS_MOVED, enc_bools(&st.means.moved)),
    ]
}

/// Atomically write a full-batch checkpoint. Returns file bytes.
pub fn save_cluster_checkpoint(
    path: &Path,
    fp: &RunFingerprint,
    st: &CheckpointState,
) -> SkmResult<u64> {
    writer::write_blocks_file(path, KIND_CLUSTER_CKPT, &common_sections(fp, st))
}

/// Atomically write a mini-batch checkpoint. Returns file bytes.
pub fn save_minibatch_checkpoint(
    path: &Path,
    fp: &RunFingerprint,
    st: &CheckpointState,
    mb: &MbStateRef,
) -> SkmResult<u64> {
    let mut sections = common_sections(fp, st);
    sections.push((sec::MB_DRIVER, encode_mb_driver(mb)));
    writer::write_blocks_file(path, KIND_MINIBATCH_CKPT, &sections)
}

fn corrupt(path: &Path, section: &str, detail: impl Into<String>) -> SkmError {
    SkmError::corrupt_snapshot(path.display().to_string(), section, detail)
}

/// Decode + validate the sections shared by both checkpoint kinds.
fn load_common(
    path: &Path,
    raw: &crate::persist::reader::RawFile,
    expect_fp: &RunFingerprint,
    n: usize,
    d: usize,
    k: usize,
) -> SkmResult<ClusterCheckpoint> {
    let c = |section: &str, detail: String| corrupt(path, section, detail);

    // Fingerprint first: a mismatched run is InvalidConfig, and no
    // further state is trusted before the match is proven.
    let mut fr = ByteReader::new(raw.section(sec::FINGERPRINT, "fingerprint", path)?);
    let stored_fp = RunFingerprint::decode(&mut fr).map_err(|detail| c("fingerprint", detail))?;
    fr.finish().map_err(|detail| c("fingerprint", detail))?;
    expect_fp.verify_matches(&stored_fp)?;

    // Driver scalars.
    let mut dr = ByteReader::new(raw.section(sec::DRIVER, "driver", path)?);
    let de = |r: Result<u64, String>| r.map_err(|detail| c("driver", detail));
    let round = usize::try_from(de(dr.get_u64())?)
        .map_err(|_| c("driver", "round exceeds host usize".to_string()))?;
    let objective = f64::from_bits(de(dr.get_u64())?);
    let max_mem = usize::try_from(de(dr.get_u64())?)
        .map_err(|_| c("driver", "max_mem exceeds host usize".to_string()))?;
    let t_th_present = dr.get_u32().map_err(|detail| c("driver", detail))?;
    let t_th_val = de(dr.get_u64())?;
    let v_th_present = dr.get_u32().map_err(|detail| c("driver", detail))?;
    let v_th_val = f64::from_bits(de(dr.get_u64())?);
    let estimations_done = usize::try_from(de(dr.get_u64())?)
        .map_err(|_| c("driver", "estimations_done exceeds host usize".to_string()))?;
    dr.finish().map_err(|detail| c("driver", detail))?;

    if round == 0 || round >= u32::MAX as usize {
        return Err(c("driver", format!("round {round} out of range")));
    }
    if !objective.is_finite() {
        return Err(c("driver", format!("non-finite objective {objective}")));
    }
    for (present, label) in [(t_th_present, "t_th"), (v_th_present, "v_th")] {
        if present > 1 {
            return Err(c("driver", format!("{label} presence flag {present} (want 0 or 1)")));
        }
    }
    let t_th = if t_th_present == 1 {
        let t = usize::try_from(t_th_val)
            .map_err(|_| c("driver", "t_th exceeds host usize".to_string()))?;
        if t > d {
            return Err(c("driver", format!("t_th = {t} > D = {d}")));
        }
        Some(t)
    } else {
        None
    };
    let v_th = if v_th_present == 1 {
        if !v_th_val.is_finite() || v_th_val <= 0.0 {
            return Err(c("driver", format!("v_th = {v_th_val} (want positive finite)")));
        }
        Some(v_th_val)
    } else {
        None
    };
    if estimations_done > 8 {
        return Err(c("driver", format!("estimations_done = {estimations_done} (sanity cap 8)")));
    }
    let params = ParamsState {
        t_th,
        v_th,
        estimations_done,
    };

    // Arrays.
    let assign = section_u32s(raw, sec::ASSIGN, "assign", path)?;
    if assign.len() != n {
        return Err(c("assign", format!("{} entries for N = {n}", assign.len())));
    }
    if let Some(&bad) = assign.iter().find(|&&a| a as usize >= k) {
        return Err(c("assign", format!("cluster id {bad} >= K = {k}")));
    }
    let rho = section_f64s(raw, sec::RHO, "rho", path)?;
    if rho.len() != n {
        return Err(c("rho", format!("{} entries for N = {n}", rho.len())));
    }
    if let Some(&bad) = rho.iter().find(|v| !v.is_finite()) {
        return Err(c("rho", format!("non-finite rho value {bad}")));
    }
    let xstate = section_bools(raw, sec::XSTATE, "xstate", path)?;
    if xstate.len() != n {
        return Err(c("xstate", format!("{} entries for N = {n}", xstate.len())));
    }
    let m = RowSlab::from_csr(&validated_csr(
        path,
        "means",
        k,
        d,
        section_usizes(raw, sec::MEANS_INDPTR, "means", path)?,
        section_u32s(raw, sec::MEANS_INDICES, "means", path)?,
        section_f64s(raw, sec::MEANS_VALUES, "means", path)?,
    )?);
    let sizes = section_u32s(raw, sec::MEAN_SIZES, "mean_sizes", path)?;
    if sizes.len() != k {
        return Err(c("mean_sizes", format!("{} entries for K = {k}", sizes.len())));
    }
    let moved = section_bools(raw, sec::MEANS_MOVED, "means_moved", path)?;
    if moved.len() != k {
        return Err(c("means_moved", format!("{} entries for K = {k}", moved.len())));
    }

    Ok(ClusterCheckpoint {
        round,
        objective,
        max_mem,
        params,
        assign,
        rho,
        xstate,
        means: MeanSet { m, moved, sizes },
    })
}

/// Load and validate a full-batch checkpoint, proving it belongs to
/// the run described by `expect_fp` (n, d, k are the current run's
/// dimensions — already pinned by the fingerprint, re-checked against
/// every array).
pub fn load_cluster_checkpoint(
    path: &Path,
    expect_fp: &RunFingerprint,
    n: usize,
    d: usize,
    k: usize,
) -> SkmResult<ClusterCheckpoint> {
    let raw = read_blocks_file(path, KIND_CLUSTER_CKPT)?;
    load_common(path, &raw, expect_fp, n, d, k)
}

/// Load and validate a mini-batch checkpoint.
pub fn load_minibatch_checkpoint(
    path: &Path,
    expect_fp: &RunFingerprint,
    n: usize,
    d: usize,
    k: usize,
) -> SkmResult<MinibatchCheckpoint> {
    let raw = read_blocks_file(path, KIND_MINIBATCH_CKPT)?;
    let base = load_common(path, &raw, expect_fp, n, d, k)?;
    let c = |detail: String| corrupt(path, "mb_driver", detail);

    let mut r = ByteReader::new(raw.section(sec::MB_DRIVER, "mb_driver", path)?);
    let counts = r.get_f64s().map_err(&c)?;
    let sizes = r.get_u32s().map_err(&c)?;
    let obs_round = r.get_u32s().map_err(&c)?;
    let last_moved = r.get_u32s().map_err(&c)?;
    let mr_latest = r.get_u32().map_err(&c)?;
    let mr_prev = r.get_u32().map_err(&c)?;
    let rng_state = r.get_u64().map_err(&c)?;
    let rng_inc = r.get_u64().map_err(&c)?;
    let cursor = r.get_usize().map_err(&c)?;
    let processed = r.get_usize().map_err(&c)?;
    let quiet = r.get_usize().map_err(&c)?;
    let obj_sum = f64::from_bits(r.get_u64().map_err(&c)?);
    r.finish().map_err(&c)?;

    let round = base.round as u32;
    if counts.len() != k {
        return Err(c(format!("{} decay counts for K = {k}", counts.len())));
    }
    if let Some(&bad) = counts.iter().find(|v| !v.is_finite() || **v < 0.0) {
        return Err(c(format!("decay count {bad} (want finite nonnegative)")));
    }
    if sizes.len() != k {
        return Err(c(format!("{} cluster sizes for K = {k}", sizes.len())));
    }
    if obs_round.len() != n {
        return Err(c(format!("{} observation rounds for N = {n}", obs_round.len())));
    }
    if let Some(&bad) = obs_round.iter().find(|&&o| o > round) {
        return Err(c(format!("observation round {bad} > checkpoint round {round}")));
    }
    if last_moved.len() != k {
        return Err(c(format!("{} last-moved rounds for K = {k}", last_moved.len())));
    }
    if let Some(&bad) = last_moved.iter().find(|&&o| o > round) {
        return Err(c(format!("last-moved round {bad} > checkpoint round {round}")));
    }
    if mr_prev > mr_latest || mr_latest > round {
        return Err(c(format!(
            "mover-round markers ({mr_prev}, {mr_latest}) inconsistent with round {round}"
        )));
    }
    if cursor >= n {
        return Err(c(format!("batch cursor {cursor} >= N = {n}")));
    }
    if quiet > base.round {
        return Err(c(format!("quiet-round count {quiet} > round {}", base.round)));
    }
    if !obj_sum.is_finite() {
        return Err(c(format!("non-finite running objective sum {obj_sum}")));
    }

    Ok(MinibatchCheckpoint {
        base,
        mb: MbDriverState {
            counts,
            sizes,
            obs_round,
            last_moved,
            mr_latest,
            mr_prev,
            rng_state,
            rng_inc,
            cursor,
            processed,
            quiet,
            obj_sum,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;

    fn setup() -> (Dataset, ClusterConfig) {
        let c = generate(&tiny(9));
        let ds = build_dataset("t", c.n_terms, &c.docs);
        let cfg = ClusterConfig {
            k: 6,
            seed: 3,
            max_iters: 4,
            ..Default::default()
        };
        (ds, cfg)
    }

    #[test]
    fn fingerprint_is_content_sensitive() {
        let (ds, cfg) = setup();
        let a = RunFingerprint::compute(AlgoKind::EsIcp, &ds, &cfg, None);
        let b = RunFingerprint::compute(AlgoKind::EsIcp, &ds, &cfg, None);
        assert_eq!(a, b);
        a.verify_matches(&b).unwrap();
        // Different seed → typed InvalidConfig naming the field.
        let cfg2 = ClusterConfig {
            seed: 4,
            ..cfg.clone()
        };
        let c2 = RunFingerprint::compute(AlgoKind::EsIcp, &ds, &cfg2, None);
        let err = a.verify_matches(&c2).unwrap_err();
        assert_eq!(err.exit_code(), 2);
        assert!(err.to_string().contains("seed"), "{err}");
        // Different corpus content → digest mismatch.
        let c3 = generate(&tiny(10));
        let ds3 = build_dataset("t", c3.n_terms, &c3.docs);
        let f3 = RunFingerprint::compute(AlgoKind::EsIcp, &ds3, &cfg, None);
        assert!(a.verify_matches(&f3).is_err());
        // Mini-batch config participates.
        let mb = MiniBatchConfig {
            batch: 64,
            schedule: BatchSchedule::Reservoir,
            decay: 0.5,
            max_rounds: 10,
            sample_seed: 7,
        };
        let f4 = RunFingerprint::compute(AlgoKind::EsIcp, &ds, &cfg, Some(&mb));
        assert!(a.verify_matches(&f4).is_err());
        // …but the round cap does not.
        let mb2 = MiniBatchConfig {
            max_rounds: 99,
            ..mb.clone()
        };
        let f5 = RunFingerprint::compute(AlgoKind::EsIcp, &ds, &cfg, Some(&mb2));
        f4.verify_matches(&f5).unwrap();
    }

    #[test]
    fn fingerprint_codec_round_trips() {
        let (ds, cfg) = setup();
        let fp = RunFingerprint::compute(AlgoKind::TaIcp, &ds, &cfg, None);
        let bytes = fp.encode();
        let mut r = ByteReader::new(&bytes);
        let back = RunFingerprint::decode(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(fp, back);
    }
}
