//! On-disk format primitives for the persistence layer: the CRC32
//! checksum, the fixed-size file header/footer, the section manifest,
//! and a bounded little-endian byte codec.
//!
//! ## File layout (versions 1 and 2)
//!
//! Both versions share this container byte-for-byte; they differ only
//! in how the big posting sections encode their payloads (v2
//! chunk-compresses them, see [`crate::persist::chunk`]). The header's
//! version field tells the loader which section codec to use.
//!
//! ```text
//! ┌──────────────────────┐ offset 0
//! │ header (40 B)        │ magic "SKMPERS1", version, endianness
//! │                      │ marker, file kind, block size, block
//! │                      │ count, header CRC32
//! ├──────────────────────┤ offset 40
//! │ data block 0 (64 KiB)│ [payload_len u32][crc32 u32][payload…0-pad]
//! │ data block 1         │ each section starts on a block boundary
//! │ …                    │
//! ├──────────────────────┤ offset 40 + n_blocks·65536
//! │ manifest             │ count + {id, first_block, n_blocks,
//! │                      │ byte_len} per section
//! ├──────────────────────┤ EOF − 32
//! │ footer (32 B)        │ magic "SKMFOOT1", manifest offset/len/CRC,
//! │                      │ footer CRC32
//! └──────────────────────┘
//! ```
//!
//! All integers are little-endian; an explicit endianness marker in the
//! header rejects byte-swapped files instead of misreading them. `f64`
//! values are stored as raw IEEE-754 bits (`to_bits`/`from_bits`) — the
//! round-trip contract is **bit** equality, not approximate equality.
//!
//! Every decode function here returns `Result<_, String>`: a plain
//! detail message the caller wraps into
//! [`crate::error::SkmError::CorruptSnapshot`] together with the file
//! path and section name. Nothing in this module panics on malformed
//! bytes, and no allocation is sized from an unvalidated length field —
//! [`ByteReader`] bounds every element count by the bytes actually
//! remaining, so a flipped length cannot request terabytes.

/// File magic, first 8 bytes of every persisted file.
pub const MAGIC: [u8; 8] = *b"SKMPERS1";
/// Footer magic, first 8 bytes of the fixed-size footer.
pub const FOOTER_MAGIC: [u8; 8] = *b"SKMFOOT1";
/// Format version 1: every section payload is the raw `ByteWriter`
/// encoding (uncompressed). Checkpoints and `skm serve --save` without
/// `--compress` still write this version, byte-identical to PR 8 files.
pub const VERSION: u32 = 1;
/// Format version 2: the big posting sections (corpus CSR, means CSR,
/// member id lists) are delta+varint chunk-compressed (see
/// [`crate::persist::chunk`]); everything else is unchanged. Written by
/// `skm serve --save --compress`.
pub const VERSION_COMPRESSED: u32 = 2;
/// Highest format version this reader understands.
pub const MAX_VERSION: u32 = VERSION_COMPRESSED;
/// Endianness marker: reads back as itself only on a little-endian
/// decode of bytes written little-endian.
pub const ENDIAN_MARK: u32 = 0x0A0B_0C0D;
/// Fixed data block size (header + payload + zero padding).
pub const BLOCK_SIZE: usize = 64 * 1024;
/// Per-block header: `payload_len: u32` + `crc32: u32`.
pub const BLOCK_HDR: usize = 8;
/// Payload capacity of one block.
pub const BLOCK_CAP: usize = BLOCK_SIZE - BLOCK_HDR;
/// Encoded header length in bytes.
pub const HEADER_LEN: usize = 40;
/// Encoded footer length in bytes.
pub const FOOTER_LEN: usize = 32;
/// Encoded manifest entry length in bytes.
pub const MANIFEST_ENTRY_LEN: usize = 28;

/// File kind: a frozen serving snapshot
/// ([`crate::serve::ClusteredCorpus`] + router parameters).
pub const KIND_SNAPSHOT: u32 = 1;
/// File kind: a full-batch clustering checkpoint.
pub const KIND_CLUSTER_CKPT: u32 = 2;
/// File kind: a mini-batch / streaming clustering checkpoint.
pub const KIND_MINIBATCH_CKPT: u32 = 3;

// ---------------------------------------------------------------------
// CRC32 (IEEE 802.3, polynomial 0xEDB88320) — table built at compile
// time; no external crate in the offline image.

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut b = 0;
        while b < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            b += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC32 (IEEE) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Header / footer / manifest

/// Decoded file header (the validated subset; constants are checked,
/// not stored). `version` is carried so the loader can dispatch between
/// the raw (v1) and chunk-compressed (v2) section encodings; a v1
/// header encodes byte-identically to the PR 8 format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub version: u32,
    pub kind: u32,
    pub n_blocks: u64,
}

impl Header {
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut b = [0u8; HEADER_LEN];
        b[0..8].copy_from_slice(&MAGIC);
        b[8..12].copy_from_slice(&self.version.to_le_bytes());
        b[12..16].copy_from_slice(&ENDIAN_MARK.to_le_bytes());
        b[16..20].copy_from_slice(&self.kind.to_le_bytes());
        b[20..24].copy_from_slice(&(BLOCK_SIZE as u32).to_le_bytes());
        b[24..32].copy_from_slice(&self.n_blocks.to_le_bytes());
        // bytes 32..36 reserved (zero), covered by the CRC
        let crc = crc32(&b[0..36]);
        b[36..40].copy_from_slice(&crc.to_le_bytes());
        b
    }

    /// Decode and validate a header from exactly [`HEADER_LEN`] bytes.
    pub fn decode(b: &[u8]) -> Result<Self, String> {
        if b.len() != HEADER_LEN {
            return Err(format!("header is {} bytes, want {HEADER_LEN}", b.len()));
        }
        let crc_stored = u32::from_le_bytes(b[36..40].try_into().unwrap());
        if crc32(&b[0..36]) != crc_stored {
            return Err("header checksum mismatch".to_string());
        }
        if b[0..8] != MAGIC {
            return Err(format!("bad magic {:02x?}", &b[0..8]));
        }
        let version = u32::from_le_bytes(b[8..12].try_into().unwrap());
        if !(VERSION..=MAX_VERSION).contains(&version) {
            return Err(format!(
                "unsupported format version {version} (reader understands {VERSION}..={MAX_VERSION})"
            ));
        }
        let endian = u32::from_le_bytes(b[12..16].try_into().unwrap());
        if endian != ENDIAN_MARK {
            return Err(format!(
                "endianness marker {endian:#010x} != {ENDIAN_MARK:#010x} (byte-swapped file?)"
            ));
        }
        let kind = u32::from_le_bytes(b[16..20].try_into().unwrap());
        let block_size = u32::from_le_bytes(b[20..24].try_into().unwrap());
        if block_size as usize != BLOCK_SIZE {
            return Err(format!("block size {block_size} != {BLOCK_SIZE}"));
        }
        let n_blocks = u64::from_le_bytes(b[24..32].try_into().unwrap());
        Ok(Self {
            version,
            kind,
            n_blocks,
        })
    }
}

/// Decoded file footer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Footer {
    pub manifest_off: u64,
    pub manifest_len: u64,
    pub manifest_crc: u32,
}

impl Footer {
    pub fn encode(&self) -> [u8; FOOTER_LEN] {
        let mut b = [0u8; FOOTER_LEN];
        b[0..8].copy_from_slice(&FOOTER_MAGIC);
        b[8..16].copy_from_slice(&self.manifest_off.to_le_bytes());
        b[16..24].copy_from_slice(&self.manifest_len.to_le_bytes());
        b[24..28].copy_from_slice(&self.manifest_crc.to_le_bytes());
        let crc = crc32(&b[0..28]);
        b[28..32].copy_from_slice(&crc.to_le_bytes());
        b
    }

    /// Decode and validate a footer from exactly [`FOOTER_LEN`] bytes.
    pub fn decode(b: &[u8]) -> Result<Self, String> {
        if b.len() != FOOTER_LEN {
            return Err(format!("footer is {} bytes, want {FOOTER_LEN}", b.len()));
        }
        let crc_stored = u32::from_le_bytes(b[28..32].try_into().unwrap());
        if crc32(&b[0..28]) != crc_stored {
            return Err("footer checksum mismatch".to_string());
        }
        if b[0..8] != FOOTER_MAGIC {
            return Err(format!("bad footer magic {:02x?}", &b[0..8]));
        }
        Ok(Self {
            manifest_off: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            manifest_len: u64::from_le_bytes(b[16..24].try_into().unwrap()),
            manifest_crc: u32::from_le_bytes(b[24..28].try_into().unwrap()),
        })
    }
}

/// One manifest entry: where a section's chunked payload lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SectionEntry {
    pub id: u32,
    pub first_block: u64,
    pub n_blocks: u64,
    pub byte_len: u64,
}

pub fn encode_manifest(entries: &[SectionEntry]) -> Vec<u8> {
    let mut b = Vec::with_capacity(4 + entries.len() * MANIFEST_ENTRY_LEN);
    b.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for e in entries {
        b.extend_from_slice(&e.id.to_le_bytes());
        b.extend_from_slice(&e.first_block.to_le_bytes());
        b.extend_from_slice(&e.n_blocks.to_le_bytes());
        b.extend_from_slice(&e.byte_len.to_le_bytes());
    }
    b
}

/// Decode a manifest whose CRC the caller has already verified.
pub fn decode_manifest(b: &[u8]) -> Result<Vec<SectionEntry>, String> {
    if b.len() < 4 {
        return Err(format!("manifest is {} bytes, want at least 4", b.len()));
    }
    let count = u32::from_le_bytes(b[0..4].try_into().unwrap()) as usize;
    let want = 4 + count
        .checked_mul(MANIFEST_ENTRY_LEN)
        .ok_or_else(|| format!("manifest entry count {count} overflows"))?;
    if b.len() != want {
        return Err(format!(
            "manifest length {} != {want} for {count} entries",
            b.len()
        ));
    }
    let mut entries = Vec::with_capacity(count);
    for i in 0..count {
        let o = 4 + i * MANIFEST_ENTRY_LEN;
        entries.push(SectionEntry {
            id: u32::from_le_bytes(b[o..o + 4].try_into().unwrap()),
            first_block: u64::from_le_bytes(b[o + 4..o + 12].try_into().unwrap()),
            n_blocks: u64::from_le_bytes(b[o + 12..o + 20].try_into().unwrap()),
            byte_len: u64::from_le_bytes(b[o + 20..o + 28].try_into().unwrap()),
        });
    }
    Ok(entries)
}

// ---------------------------------------------------------------------
// Bounded byte codec for section payloads

/// Little-endian section-payload encoder. Length-prefixed arrays use a
/// `u64` element count so the reader can bound its allocation.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `f64` as raw IEEE-754 bits (bit-exact round trip, NaNs included).
    pub fn put_f64_bits(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn put_u32s(&mut self, v: &[u32]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// `usize` values as `u64` (the format is 64-bit regardless of host).
    pub fn put_usizes(&mut self, v: &[usize]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&(x as u64).to_le_bytes());
        }
    }

    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    /// Booleans as one byte each (0 or 1).
    pub fn put_bools(&mut self, v: &[bool]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.buf.push(u8::from(x));
        }
    }
}

/// Little-endian section-payload decoder over a borrowed buffer.
///
/// Every array read first checks `count · elem_size ≤ remaining bytes`
/// **before** allocating — a corrupted count field produces a typed
/// error, never an abort-on-OOM allocation.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if n > self.remaining() {
            return Err(format!(
                "truncated payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64_bits(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// A `u64` the host must be able to index with.
    pub fn get_usize(&mut self) -> Result<usize, String> {
        usize::try_from(self.get_u64()?).map_err(|_| "64-bit value exceeds host usize".to_string())
    }

    /// Read a `u64` element count and bound it by the remaining bytes.
    fn get_count(&mut self, elem_size: usize) -> Result<usize, String> {
        let count = self.get_usize()?;
        match count.checked_mul(elem_size) {
            Some(total) if total <= self.remaining() => Ok(count),
            _ => Err(format!(
                "array count {count} (x{elem_size} B) exceeds the {} bytes remaining",
                self.remaining()
            )),
        }
    }

    pub fn get_str(&mut self) -> Result<String, String> {
        let len = self.get_count(1)?;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| "string is not valid UTF-8".to_string())
    }

    pub fn get_u32s(&mut self) -> Result<Vec<u32>, String> {
        let count = self.get_count(4)?;
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(u32::from_le_bytes(self.take(4)?.try_into().unwrap()));
        }
        Ok(out)
    }

    pub fn get_usizes(&mut self) -> Result<Vec<usize>, String> {
        let count = self.get_count(8)?;
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let v = u64::from_le_bytes(self.take(8)?.try_into().unwrap());
            out.push(
                usize::try_from(v).map_err(|_| "64-bit value exceeds host usize".to_string())?,
            );
        }
        Ok(out)
    }

    pub fn get_f64s(&mut self) -> Result<Vec<f64>, String> {
        let count = self.get_count(8)?;
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(f64::from_bits(u64::from_le_bytes(
                self.take(8)?.try_into().unwrap(),
            )));
        }
        Ok(out)
    }

    pub fn get_bools(&mut self) -> Result<Vec<bool>, String> {
        let count = self.get_count(1)?;
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            match self.take(1)?[0] {
                0 => out.push(false),
                1 => out.push(true),
                b => return Err(format!("bool byte {b} (want 0 or 1)")),
            }
        }
        Ok(out)
    }

    /// Error unless the payload was consumed exactly.
    pub fn finish(&self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!("{} trailing bytes after payload", self.remaining()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // The IEEE check value for the nine ASCII digits.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn header_round_trip_and_detects_flips() {
        for version in [VERSION, VERSION_COMPRESSED] {
            let h = Header {
                version,
                kind: KIND_SNAPSHOT,
                n_blocks: 17,
            };
            let enc = h.encode();
            assert_eq!(Header::decode(&enc).unwrap(), h);
            for i in 0..HEADER_LEN {
                let mut bad = enc;
                bad[i] ^= 0xFF;
                assert!(Header::decode(&bad).is_err(), "flip at byte {i} accepted");
            }
            assert!(Header::decode(&enc[..HEADER_LEN - 1]).is_err());
        }
    }

    #[test]
    fn header_rejects_future_versions_with_typed_message() {
        let h = Header {
            version: VERSION,
            kind: KIND_SNAPSHOT,
            n_blocks: 3,
        };
        let mut enc = h.encode();
        // Claim version MAX_VERSION + 1 and re-seal the CRC so only the
        // version check can reject it.
        enc[8..12].copy_from_slice(&(MAX_VERSION + 1).to_le_bytes());
        let crc = crc32(&enc[0..36]);
        enc[36..40].copy_from_slice(&crc.to_le_bytes());
        let err = Header::decode(&enc).unwrap_err();
        assert!(err.contains("unsupported format version"), "{err}");
        // Version 0 (below the floor) is likewise rejected.
        enc[8..12].copy_from_slice(&0u32.to_le_bytes());
        let crc = crc32(&enc[0..36]);
        enc[36..40].copy_from_slice(&crc.to_le_bytes());
        assert!(Header::decode(&enc).is_err());
    }

    #[test]
    fn footer_round_trip_and_detects_flips() {
        let f = Footer {
            manifest_off: 40 + 3 * BLOCK_SIZE as u64,
            manifest_len: 60,
            manifest_crc: 0xDEAD_BEEF,
        };
        let enc = f.encode();
        assert_eq!(Footer::decode(&enc).unwrap(), f);
        for i in 0..FOOTER_LEN {
            let mut bad = enc;
            bad[i] ^= 0xFF;
            assert!(Footer::decode(&bad).is_err(), "flip at byte {i} accepted");
        }
    }

    #[test]
    fn manifest_round_trip() {
        let entries = vec![
            SectionEntry {
                id: 1,
                first_block: 0,
                n_blocks: 1,
                byte_len: 100,
            },
            SectionEntry {
                id: 2,
                first_block: 1,
                n_blocks: 2,
                byte_len: BLOCK_CAP as u64 + 5,
            },
        ];
        let enc = encode_manifest(&entries);
        assert_eq!(decode_manifest(&enc).unwrap(), entries);
        // Truncated and padded manifests are rejected.
        assert!(decode_manifest(&enc[..enc.len() - 1]).is_err());
        let mut padded = enc.clone();
        padded.push(0);
        assert!(decode_manifest(&padded).is_err());
        assert!(decode_manifest(&[]).is_err());
    }

    #[test]
    fn byte_codec_round_trip() {
        let mut w = ByteWriter::new();
        w.put_u32(7);
        w.put_u64(u64::MAX);
        w.put_f64_bits(-0.0);
        w.put_str("pubmed-like");
        w.put_u32s(&[3, 1, 4]);
        w.put_usizes(&[0, 10, usize::MAX]);
        w.put_f64s(&[1.5, f64::NAN]);
        w.put_bools(&[true, false]);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u32().unwrap(), 7);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_f64_bits().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.get_str().unwrap(), "pubmed-like");
        assert_eq!(r.get_u32s().unwrap(), vec![3, 1, 4]);
        assert_eq!(r.get_usizes().unwrap(), vec![0, 10, usize::MAX]);
        let f = r.get_f64s().unwrap();
        assert_eq!(f[0], 1.5);
        assert!(f[1].is_nan()); // NaN bits survive
        assert_eq!(r.get_bools().unwrap(), vec![true, false]);
        r.finish().unwrap();
    }

    #[test]
    fn corrupt_counts_cannot_oversize_allocations() {
        // A huge count must be rejected *before* allocation.
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX); // claims u64::MAX f64 elements
        let bytes = w.into_bytes();
        assert!(ByteReader::new(&bytes).get_f64s().is_err());
        assert!(ByteReader::new(&bytes).get_u32s().is_err());
        assert!(ByteReader::new(&bytes).get_str().is_err());
        // Non-0/1 bool bytes are rejected.
        let mut w = ByteWriter::new();
        w.put_u64(1);
        let mut bytes = w.into_bytes();
        bytes.push(7);
        assert!(ByteReader::new(&bytes).get_bools().is_err());
        // Trailing garbage is rejected by finish().
        let mut w = ByteWriter::new();
        w.put_u32(1);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let _ = r.get_u32().unwrap();
        r.finish().unwrap();
        let r2 = ByteReader::new(&bytes);
        assert!(r2.finish().is_err());
    }
}
