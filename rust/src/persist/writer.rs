//! Atomic block-file writer: write-to-temp → fsync → rename.
//!
//! [`write_blocks_file`] never touches the destination path until the
//! complete, checksummed temp file is durable: the payload is chunked
//! into fixed 64 KiB blocks (per-block CRC32), followed by the section
//! manifest and the fixed footer, all written to a hidden sibling temp
//! file; the file is `fsync`ed, then atomically `rename`d over the
//! destination, then the parent directory is fsynced (best effort) so
//! the rename itself is durable. A crash — or an injected fault — at
//! *any* stage leaves the previously published file untouched, and the
//! temp file is removed on every error path.
//!
//! Fail-point sites (cargo feature `failpoints`, see
//! [`crate::util::failpoint`]): `persist.write_block` (arg = global
//! block index), `persist.fsync`, `persist.rename`.

use crate::error::{SkmError, SkmResult};
use crate::persist::format::{
    crc32, encode_manifest, Footer, Header, SectionEntry, BLOCK_CAP, BLOCK_SIZE, HEADER_LEN,
    MAX_VERSION, VERSION,
};
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Removes the temp file on drop unless disarmed — the error-path
/// cleanup for every failure between `create` and `rename`.
struct TempGuard {
    path: PathBuf,
    armed: bool,
}

impl Drop for TempGuard {
    fn drop(&mut self) {
        if self.armed {
            let _ = fs::remove_file(&self.path);
        }
    }
}

/// Best-effort parent-directory fsync after the rename (makes the new
/// directory entry durable on unix; silently a no-op elsewhere and on
/// filesystems that reject directory fsync).
fn sync_parent_dir(path: &Path) {
    #[cfg(unix)]
    {
        let dir = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    #[cfg(not(unix))]
    {
        let _ = path;
    }
}

/// The hidden sibling temp path: same directory (rename must not cross
/// filesystems), name tagged with the pid so concurrent writers of
/// *different* files never collide.
fn temp_path_for(path: &Path) -> SkmResult<PathBuf> {
    let file_name = path.file_name().ok_or_else(|| {
        SkmError::invalid_config(format!(
            "snapshot path {} has no file name component",
            path.display()
        ))
    })?;
    let tmp_name = format!(
        ".{}.tmp.{}",
        file_name.to_string_lossy(),
        std::process::id()
    );
    Ok(match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.join(tmp_name),
        _ => PathBuf::from(tmp_name),
    })
}

/// Write `sections` (id, payload) as a version-1 block file at `path`,
/// atomically. Returns the total file size in bytes. On any error the
/// destination is untouched and the temp file is removed.
pub fn write_blocks_file(path: &Path, kind: u32, sections: &[(u32, Vec<u8>)]) -> SkmResult<u64> {
    write_blocks_file_versioned(path, kind, VERSION, sections)
}

/// [`write_blocks_file`] with an explicit format version in the header
/// (the container layout is version-independent; the version tells the
/// loader which section codec the payloads use). Version 1 output is
/// byte-identical to [`write_blocks_file`]. The fail-point sites are
/// shared, so the crash kill matrix covers every version's write path.
pub fn write_blocks_file_versioned(
    path: &Path,
    kind: u32,
    version: u32,
    sections: &[(u32, Vec<u8>)],
) -> SkmResult<u64> {
    debug_assert!((VERSION..=MAX_VERSION).contains(&version));
    let tmp = temp_path_for(path)?;
    let mut guard = TempGuard {
        path: tmp.clone(),
        armed: true,
    };
    let bytes = write_temp(&tmp, kind, version, sections)?;
    crate::failpoint_res!("persist.rename", 0u64);
    fs::rename(&tmp, path).map_err(|e| {
        SkmError::io(
            format!("rename snapshot temp over {}", path.display()),
            e,
        )
    })?;
    guard.armed = false; // published — the temp path no longer exists
    sync_parent_dir(path);
    Ok(bytes)
}

/// Write and fsync the complete temp file (header, blocks, manifest,
/// footer). The caller owns cleanup-on-error via [`TempGuard`].
fn write_temp(tmp: &Path, kind: u32, version: u32, sections: &[(u32, Vec<u8>)]) -> SkmResult<u64> {
    let ioe = |what: &str, e: std::io::Error| {
        SkmError::io(format!("{what} {}", tmp.display()), e)
    };

    // Lay the sections out first: each starts on a fresh block boundary.
    let mut entries = Vec::with_capacity(sections.len());
    let mut cursor = 0u64;
    for (id, payload) in sections {
        let nb = payload.len().div_ceil(BLOCK_CAP) as u64;
        entries.push(SectionEntry {
            id: *id,
            first_block: cursor,
            n_blocks: nb,
            byte_len: payload.len() as u64,
        });
        cursor += nb;
    }
    let n_blocks = cursor;
    let manifest = encode_manifest(&entries);
    let manifest_off = (HEADER_LEN + n_blocks as usize * BLOCK_SIZE) as u64;

    let f = File::create(tmp).map_err(|e| ioe("create snapshot temp", e))?;
    let mut w = std::io::BufWriter::new(f);
    let header = Header {
        version,
        kind,
        n_blocks,
    };
    w.write_all(&header.encode())
        .map_err(|e| ioe("write snapshot header to", e))?;

    let zeros = [0u8; BLOCK_CAP];
    let mut block_idx = 0u64;
    for (_, payload) in sections {
        let mut off = 0usize;
        // One iteration per block; empty sections occupy zero blocks.
        while off < payload.len() {
            crate::failpoint_res!("persist.write_block", block_idx);
            let chunk = &payload[off..(off + BLOCK_CAP).min(payload.len())];
            let mut hdr = [0u8; 8];
            hdr[0..4].copy_from_slice(&(chunk.len() as u32).to_le_bytes());
            hdr[4..8].copy_from_slice(&crc32(chunk).to_le_bytes());
            w.write_all(&hdr)
                .map_err(|e| ioe("write snapshot block to", e))?;
            w.write_all(chunk)
                .map_err(|e| ioe("write snapshot block to", e))?;
            if chunk.len() < BLOCK_CAP {
                w.write_all(&zeros[..BLOCK_CAP - chunk.len()])
                    .map_err(|e| ioe("write snapshot block to", e))?;
            }
            off += chunk.len();
            block_idx += 1;
        }
    }
    debug_assert_eq!(block_idx, n_blocks);

    w.write_all(&manifest)
        .map_err(|e| ioe("write snapshot manifest to", e))?;
    let footer = Footer {
        manifest_off,
        manifest_len: manifest.len() as u64,
        manifest_crc: crc32(&manifest),
    };
    w.write_all(&footer.encode())
        .map_err(|e| ioe("write snapshot footer to", e))?;
    w.flush().map_err(|e| ioe("flush snapshot temp", e))?;
    let f = w
        .into_inner()
        .map_err(|e| ioe("flush snapshot temp", e.into_error()))?;
    crate::failpoint_res!("persist.fsync", 0u64);
    f.sync_all().map_err(|e| ioe("fsync snapshot temp", e))?;
    Ok(manifest_off + manifest.len() as u64 + crate::persist::format::FOOTER_LEN as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("skm_writer_{}_{tag}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_atomically_and_cleans_temp() {
        let dir = tmp_dir("basic");
        let path = dir.join("a.skm");
        let sections = vec![
            (1u32, vec![1u8, 2, 3]),
            (2u32, vec![9u8; BLOCK_CAP + 10]), // spans two blocks
            (3u32, Vec::new()),                // zero blocks
        ];
        let bytes = write_blocks_file(&path, 1, &sections).unwrap();
        assert_eq!(fs::metadata(&path).unwrap().len(), bytes);
        // 1 + 2 + 0 = 3 data blocks
        let expect = (HEADER_LEN + 3 * BLOCK_SIZE) as u64
            + (4 + 3 * crate::persist::format::MANIFEST_ENTRY_LEN) as u64
            + crate::persist::format::FOOTER_LEN as u64;
        assert_eq!(bytes, expect);
        // No temp litter.
        let litter: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(litter.is_empty(), "temp files left behind: {litter:?}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_pathless_destination() {
        assert!(write_blocks_file(Path::new("/"), 1, &[]).is_err());
    }

    #[test]
    fn versioned_writer_stamps_header_and_v1_bytes_are_unchanged() {
        use crate::persist::format::{Header, HEADER_LEN, MAX_VERSION};
        let dir = tmp_dir("versioned");
        let sections = vec![(1u32, vec![5u8; 100])];
        let p1 = dir.join("v1.skm");
        let p1b = dir.join("v1b.skm");
        let p2 = dir.join("v2.skm");
        write_blocks_file(&p1, 1, &sections).unwrap();
        write_blocks_file_versioned(&p1b, 1, 1, &sections).unwrap();
        write_blocks_file_versioned(&p2, 1, MAX_VERSION, &sections).unwrap();
        let b1 = fs::read(&p1).unwrap();
        let b1b = fs::read(&p1b).unwrap();
        let b2 = fs::read(&p2).unwrap();
        // The default entry point IS version 1, bit for bit.
        assert_eq!(b1, b1b);
        assert_eq!(Header::decode(&b1[..HEADER_LEN]).unwrap().version, 1);
        assert_eq!(
            Header::decode(&b2[..HEADER_LEN]).unwrap().version,
            MAX_VERSION
        );
        // Only the header (version field + its CRC) differs.
        assert_eq!(b1[HEADER_LEN..], b2[HEADER_LEN..]);
        let _ = fs::remove_dir_all(&dir);
    }
}
