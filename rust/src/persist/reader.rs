//! Paranoid block-file reader: every byte of a persisted file is
//! validated before any of it is trusted.
//!
//! [`read_blocks_file`] checks, in order: file length bounds, the
//! header (magic, CRC, version, endianness marker, block size, file
//! kind), the footer (magic, CRC, manifest offset/length consistency),
//! the manifest CRC and entry geometry (contiguous blocks, block count
//! consistent with byte length), and finally every data block's payload
//! length and CRC32. All offset arithmetic is overflow-checked. Every
//! violation is a typed [`SkmError::CorruptSnapshot`] naming the file,
//! the section, and the defect — never a panic, never undefined
//! behavior, and never a partially-decoded result.
//!
//! Fail-point site (cargo feature `failpoints`):
//! `persist.read_block` (arg = global block index).

use crate::error::{SkmError, SkmResult};
use crate::persist::format::{
    crc32, decode_manifest, Footer, Header, SectionEntry, BLOCK_CAP, BLOCK_HDR, BLOCK_SIZE,
    FOOTER_LEN, HEADER_LEN,
};
use std::path::Path;

/// A fully checksum-verified file: the header's kind and format
/// version, and each section's reassembled payload, in manifest order.
/// Structural validation of the *decoded* values is the caller's job.
#[derive(Debug)]
pub struct RawFile {
    pub kind: u32,
    pub version: u32,
    sections: Vec<(u32, Vec<u8>)>,
}

impl RawFile {
    /// The payload of section `id`, or a typed error naming `name`.
    pub fn section(&self, id: u32, name: &str, path: &Path) -> SkmResult<&[u8]> {
        self.sections
            .iter()
            .find(|(sid, _)| *sid == id)
            .map(|(_, payload)| payload.as_slice())
            .ok_or_else(|| {
                SkmError::corrupt_snapshot(
                    path.display().to_string(),
                    name,
                    format!("section {id} missing from manifest"),
                )
            })
    }
}

/// Validate everything about a block file *except* the per-block
/// payload CRCs: length bounds, header (magic, CRC, version,
/// endianness, block size, kind), footer, manifest CRC, and manifest
/// geometry. Shared by the eager reader below (which then verifies
/// every block) and the mmap-backed opener in [`crate::persist::mmap`]
/// (which defers corpus-block CRCs to cache-fill time).
pub(crate) fn check_structure(
    buf: &[u8],
    path: &Path,
    expect_kind: u32,
) -> SkmResult<(Header, Vec<SectionEntry>)> {
    let corrupt = |section: &str, detail: String| {
        SkmError::corrupt_snapshot(path.display().to_string(), section, detail)
    };
    let len = buf.len();
    if len < HEADER_LEN + 4 + FOOTER_LEN {
        return Err(corrupt("file", format!("{len} bytes is too short to be a snapshot")));
    }

    // Header.
    let header = Header::decode(&buf[..HEADER_LEN]).map_err(|d| corrupt("header", d))?;
    if header.kind != expect_kind {
        return Err(corrupt(
            "header",
            format!("file kind {} but this loader expects kind {expect_kind}", header.kind),
        ));
    }
    let blocks_bytes = header
        .n_blocks
        .checked_mul(BLOCK_SIZE as u64)
        .and_then(|b| usize::try_from(b).ok())
        .ok_or_else(|| corrupt("header", format!("block count {} overflows", header.n_blocks)))?;
    let data_end = HEADER_LEN
        .checked_add(blocks_bytes)
        .ok_or_else(|| corrupt("header", format!("block count {} overflows", header.n_blocks)))?;
    if data_end.checked_add(4 + FOOTER_LEN).is_none_or(|min| min > len) {
        return Err(corrupt(
            "header",
            format!(
                "{} data blocks need {data_end} bytes before the manifest, file has {len}",
                header.n_blocks
            ),
        ));
    }

    // Footer and manifest.
    let footer = Footer::decode(&buf[len - FOOTER_LEN..]).map_err(|d| corrupt("footer", d))?;
    if footer.manifest_off != data_end as u64 {
        return Err(corrupt(
            "footer",
            format!(
                "manifest offset {} but data blocks end at {data_end}",
                footer.manifest_off
            ),
        ));
    }
    let manifest_end = (len - FOOTER_LEN) as u64;
    if footer
        .manifest_off
        .checked_add(footer.manifest_len)
        != Some(manifest_end)
    {
        return Err(corrupt(
            "footer",
            format!(
                "manifest [{}, +{}) does not end at the footer ({manifest_end})",
                footer.manifest_off, footer.manifest_len
            ),
        ));
    }
    let manifest_bytes = &buf[data_end..len - FOOTER_LEN];
    if crc32(manifest_bytes) != footer.manifest_crc {
        return Err(corrupt("manifest", "manifest checksum mismatch".to_string()));
    }
    let entries = decode_manifest(manifest_bytes).map_err(|d| corrupt("manifest", d))?;

    // Manifest geometry: contiguous, within the data region, block
    // count consistent with byte length, ids unique.
    let mut cursor = 0u64;
    for e in &entries {
        if entries.iter().filter(|o| o.id == e.id).count() != 1 {
            return Err(corrupt("manifest", format!("duplicate section id {}", e.id)));
        }
        if e.first_block != cursor {
            return Err(corrupt(
                "manifest",
                format!(
                    "section {} starts at block {} but the previous section ends at {cursor}",
                    e.id, e.first_block
                ),
            ));
        }
        let nb_expected = e.byte_len.div_ceil(BLOCK_CAP as u64);
        if e.n_blocks != nb_expected {
            return Err(corrupt(
                "manifest",
                format!(
                    "section {}: {} bytes need {nb_expected} blocks, manifest claims {}",
                    e.id, e.byte_len, e.n_blocks
                ),
            ));
        }
        cursor = cursor
            .checked_add(e.n_blocks)
            .ok_or_else(|| corrupt("manifest", format!("section {} block range overflows", e.id)))?;
    }
    if cursor != header.n_blocks {
        return Err(corrupt(
            "manifest",
            format!(
                "sections cover {cursor} blocks, header declares {}",
                header.n_blocks
            ),
        ));
    }
    Ok((header, entries))
}

/// Read and fully verify a block file (any understood format version).
/// `expect_kind` rejects e.g. loading a checkpoint where a serving
/// snapshot is required.
pub fn read_blocks_file(path: &Path, expect_kind: u32) -> SkmResult<RawFile> {
    let buf = fs_read(path)?;
    let (header, entries) = check_structure(&buf, path, expect_kind)?;
    assemble_sections(&buf, path, &header, &entries, &[])
}

/// Reassemble (and CRC-verify, block by block) every section except the
/// ids in `skip` from an already structure-checked buffer. The mmap
/// opener uses `skip` to leave the big corpus posting sections on disk —
/// their blocks are CRC-verified lazily at block-cache fill time.
pub(crate) fn assemble_sections(
    buf: &[u8],
    path: &Path,
    header: &Header,
    entries: &[SectionEntry],
    skip: &[u32],
) -> SkmResult<RawFile> {
    let corrupt = |section: &str, detail: String| {
        SkmError::corrupt_snapshot(path.display().to_string(), section, detail)
    };

    // Data blocks: verify each block's declared payload length and CRC,
    // then reassemble the section payload. `byte_len` is bounded by
    // `n_blocks · BLOCK_CAP` (checked above) which is bounded by the
    // file size, so the allocation below cannot exceed the input.
    let mut sections = Vec::with_capacity(entries.len());
    for e in entries {
        if skip.contains(&e.id) {
            continue;
        }
        let byte_len = usize::try_from(e.byte_len)
            .map_err(|_| corrupt("manifest", format!("section {} length overflows", e.id)))?;
        let mut payload = Vec::with_capacity(byte_len);
        let mut remaining = byte_len;
        for b in 0..e.n_blocks {
            let gb = e.first_block + b;
            crate::failpoint_res!("persist.read_block", gb);
            let boff = HEADER_LEN + gb as usize * BLOCK_SIZE;
            let hdr = &buf[boff..boff + BLOCK_HDR];
            let payload_len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
            let crc_stored = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
            let expected = remaining.min(BLOCK_CAP);
            if payload_len != expected {
                return Err(corrupt(
                    "block",
                    format!(
                        "block {gb} (section {}): payload length {payload_len}, expected {expected}",
                        e.id
                    ),
                ));
            }
            let chunk = &buf[boff + BLOCK_HDR..boff + BLOCK_HDR + payload_len];
            if crc32(chunk) != crc_stored {
                return Err(corrupt(
                    "block",
                    format!("block {gb} (section {}): checksum mismatch", e.id),
                ));
            }
            payload.extend_from_slice(chunk);
            remaining -= payload_len;
        }
        debug_assert_eq!(remaining, 0);
        sections.push((e.id, payload));
    }

    Ok(RawFile {
        kind: header.kind,
        version: header.version,
        sections,
    })
}

fn fs_read(path: &Path) -> SkmResult<Vec<u8>> {
    std::fs::read(path).map_err(|e| SkmError::io(format!("read snapshot {}", path.display()), e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::format::KIND_SNAPSHOT;
    use crate::persist::writer::write_blocks_file;
    use std::path::PathBuf;

    fn tmp_file(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("skm_reader_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join("f.skm")
    }

    fn sections() -> Vec<(u32, Vec<u8>)> {
        let big: Vec<u8> = (0..BLOCK_CAP + 100).map(|i| (i % 251) as u8).collect();
        vec![(1, b"hello".to_vec()), (2, big), (3, Vec::new())]
    }

    #[test]
    fn round_trips_sections() {
        let path = tmp_file("rt");
        let s = sections();
        write_blocks_file(&path, KIND_SNAPSHOT, &s).unwrap();
        let raw = read_blocks_file(&path, KIND_SNAPSHOT).unwrap();
        assert_eq!(raw.version, crate::persist::format::VERSION);
        for (id, payload) in &s {
            assert_eq!(raw.section(*id, "x", &path).unwrap(), payload.as_slice());
        }
        assert!(raw.section(99, "meta", &path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wrong_kind_is_typed() {
        let path = tmp_file("kind");
        write_blocks_file(&path, KIND_SNAPSHOT, &sections()).unwrap();
        let err = read_blocks_file(&path, 2).unwrap_err();
        match err {
            SkmError::CorruptSnapshot { section, .. } => assert_eq!(section, "header"),
            other => panic!("wrong variant: {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_io_not_corrupt() {
        let err = read_blocks_file(Path::new("/nonexistent/skm.snap"), 1).unwrap_err();
        assert!(matches!(err, SkmError::Io { .. }), "{err:?}");
    }
}
