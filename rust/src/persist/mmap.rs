//! Memory-mapped serving of compressed (v2) snapshots: corpus posting
//! blocks stay on disk and are decoded on demand through a small LRU
//! cache of CRC-verified block payloads, so `skm serve --load --mmap`
//! can serve corpora larger than RAM straight from the file.
//!
//! ## Architecture
//!
//! * [`SnapshotMap`] — a read-only `mmap(2)` of the whole snapshot file
//!   (via `libc`, the only FFI dependency the image bakes in). On
//!   non-unix hosts, or when the kernel refuses the mapping, it degrades
//!   to an ordinary heap read — same API, no behavior difference beyond
//!   residency.
//! * [`BlockCache`] — an exact LRU over **decoded block payloads**,
//!   keyed by global block index. A miss copies the 64 KiB payload out
//!   of the mapping *after* verifying the block's CRC32; a hit returns
//!   the shared [`Arc`] without touching the file. Capacity is the
//!   `--cache-mb` knob (default 64 MiB ≈ 1024 blocks).
//! * [`DiskRows`] — the random-access corpus row reader: per-chunk
//!   metadata and the row pointer live in RAM (they are small); a row
//!   fetch reads the chunk id/value byte spans through the cache and
//!   delta-decodes into caller scratch. Ids and values live in separate
//!   streams, so id-only consumers never fault value blocks.
//!
//! ## Bit-exactness and failure semantics
//!
//! [`DiskRows::validate_all`] streams every row once at open time with
//! the same decode path serving uses, checking the full corpus contract
//! (strictly ascending ids `< D`, finite nonnegative values, chunk
//! metadata consistent) — so a corrupt file is a typed
//! [`SkmError::CorruptSnapshot`] at load, never a panic. After a clean
//! open, decoded bits equal the saved bits, and since the router's
//! exact merges are unchanged, every served id and score bit matches
//! the in-RAM router (pinned by `rust/tests/persist.rs`). The only
//! panic left is a block whose CRC changes *after* validation (the file
//! was mutated under a live server); it carries a clear message and is
//! contained per-query by `serve_batch`'s worker isolation.

use crate::error::{SkmError, SkmResult};
use crate::persist::chunk::{self, ChunkMeta};
use crate::persist::format::{crc32, BLOCK_CAP, BLOCK_HDR, BLOCK_SIZE, HEADER_LEN};
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default block-cache capacity in MiB for `--mmap` serving.
pub const DEFAULT_CACHE_MB: usize = 64;

// ---------------------------------------------------------------------
// Read-only file mapping

enum MapBuf {
    /// A live `mmap(2)` region (unix only). Read-only and private.
    #[cfg(unix)]
    Mapped { ptr: *const u8, len: usize },
    /// Heap fallback: the whole file read into memory (non-unix hosts,
    /// or when the kernel refuses the mapping).
    Heap(Vec<u8>),
}

/// A read-only view of the snapshot file. See the module docs.
pub struct SnapshotMap {
    buf: MapBuf,
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE and never mutated or
// remapped after construction; sharing immutable bytes across threads
// is sound. The heap variant is a plain Vec.
unsafe impl Send for SnapshotMap {}
unsafe impl Sync for SnapshotMap {}

impl SnapshotMap {
    /// Map `path` read-only, falling back to a heap read when mapping
    /// is unavailable.
    pub fn open(path: &Path) -> SkmResult<Self> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let ioe =
                |e: std::io::Error| SkmError::io(format!("mmap snapshot {}", path.display()), e);
            let f = std::fs::File::open(path).map_err(ioe)?;
            let len = f.metadata().map_err(ioe)?.len();
            let len = usize::try_from(len).map_err(|_| {
                SkmError::corrupt_snapshot(
                    path.display().to_string(),
                    "file",
                    "file length exceeds host usize",
                )
            })?;
            if len > 0 {
                // SAFETY: fd is a valid open file, len is its size;
                // PROT_READ + MAP_PRIVATE cannot alias writable memory.
                let ptr = unsafe {
                    libc::mmap(
                        std::ptr::null_mut(),
                        len,
                        libc::PROT_READ,
                        libc::MAP_PRIVATE,
                        f.as_raw_fd(),
                        0,
                    )
                };
                if ptr != libc::MAP_FAILED {
                    return Ok(Self {
                        buf: MapBuf::Mapped {
                            ptr: ptr as *const u8,
                            len,
                        },
                    });
                }
                // fall through to the heap read on mapping failure
            }
        }
        let bytes = std::fs::read(path)
            .map_err(|e| SkmError::io(format!("read snapshot {}", path.display()), e))?;
        Ok(Self {
            buf: MapBuf::Heap(bytes),
        })
    }

    /// The mapped file bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.buf {
            #[cfg(unix)]
            // SAFETY: ptr/len come from a successful mmap that lives
            // until Drop; the region is never unmapped early.
            MapBuf::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            MapBuf::Heap(v) => v,
        }
    }

    /// True when backed by a real mapping (false = heap fallback).
    pub fn is_mapped(&self) -> bool {
        match &self.buf {
            #[cfg(unix)]
            MapBuf::Mapped { .. } => true,
            MapBuf::Heap(_) => false,
        }
    }
}

impl Drop for SnapshotMap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let MapBuf::Mapped { ptr, len } = self.buf {
            // SAFETY: exactly the region returned by mmap in open().
            unsafe {
                libc::munmap(ptr as *mut libc::c_void, len);
            }
        }
    }
}

impl std::fmt::Debug for SnapshotMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SnapshotMap")
            .field("len", &self.bytes().len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

// ---------------------------------------------------------------------
// LRU cache of CRC-verified block payloads

/// Exact LRU keyed by global block index. Recency is a monotone stamp;
/// a `BTreeMap` stamp index makes eviction `O(log n)` per miss.
#[derive(Debug)]
pub struct BlockCache {
    cap_blocks: usize,
    tick: u64,
    by_block: HashMap<u64, (u64, Arc<Vec<u8>>)>,
    by_stamp: BTreeMap<u64, u64>,
}

impl BlockCache {
    pub fn new(cap_blocks: usize) -> Self {
        Self {
            // At least 4 so one row's worst case (2 id + 2 value
            // blocks) never self-evicts mid-fetch.
            cap_blocks: cap_blocks.max(4),
            tick: 0,
            by_block: HashMap::new(),
            by_stamp: BTreeMap::new(),
        }
    }

    fn get(&mut self, gb: u64) -> Option<Arc<Vec<u8>>> {
        let (stamp, payload) = self.by_block.get(&gb)?;
        let (old, payload) = (*stamp, Arc::clone(payload));
        self.by_stamp.remove(&old);
        self.tick += 1;
        self.by_stamp.insert(self.tick, gb);
        self.by_block.insert(gb, (self.tick, Arc::clone(&payload)));
        Some(payload)
    }

    fn insert(&mut self, gb: u64, payload: Arc<Vec<u8>>) {
        while self.by_block.len() >= self.cap_blocks {
            let Some((_, victim)) = self.by_stamp.pop_first() else {
                break;
            };
            self.by_block.remove(&victim);
        }
        self.tick += 1;
        self.by_stamp.insert(self.tick, gb);
        self.by_block.insert(gb, (self.tick, payload));
    }

    pub fn len(&self) -> usize {
        self.by_block.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_block.is_empty()
    }
}

// ---------------------------------------------------------------------
// Disk-backed corpus rows

/// Block range of one lazy section inside the file.
#[derive(Debug, Clone, Copy)]
pub struct SectionGeom {
    pub first_block: u64,
    pub byte_len: u64,
}

/// Random-access reader over the compressed corpus posting sections of
/// an open snapshot. See the module docs.
pub struct DiskRows {
    map: SnapshotMap,
    path: PathBuf,
    cache: Mutex<BlockCache>,
    cache_blocks: usize,
    metas: Vec<ChunkMeta>,
    /// First chunk of each row; `len == n_rows + 1`.
    row_chunk_start: Vec<u32>,
    /// The real corpus row pointer (the in-RAM stub matrix carries an
    /// all-zero one; see `ClusteredCorpus::row_view`).
    indptr: Vec<usize>,
    n_cols: usize,
    ids_sec: SectionGeom,
    vals_sec: SectionGeom,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl std::fmt::Debug for DiskRows {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DiskRows")
            .field("path", &self.path)
            .field("n_rows", &(self.indptr.len() - 1))
            .field("n_chunks", &self.metas.len())
            .field("cache_blocks", &self.cache_blocks)
            .finish()
    }
}

impl DiskRows {
    /// Assemble the reader from decoded chunk metadata and the lazy
    /// sections' geometry, then validate the metadata layout against
    /// the stream lengths. `validate_all` (the full streaming decode
    /// check) is a separate call so the loader can report it as its own
    /// phase.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        map: SnapshotMap,
        path: &Path,
        metas: Vec<ChunkMeta>,
        indptr: Vec<usize>,
        n_cols: usize,
        ids_sec: SectionGeom,
        vals_sec: SectionGeom,
        cache_blocks: usize,
    ) -> SkmResult<Self> {
        chunk::validate_layout(
            &metas,
            &indptr,
            ids_sec.byte_len as usize,
            vals_sec.byte_len as usize,
            true,
        )
        .map_err(|d| {
            SkmError::corrupt_snapshot(path.display().to_string(), "corpus_chunks", d)
        })?;
        let n = indptr.len() - 1;
        let mut row_chunk_start = Vec::with_capacity(n + 1);
        let mut acc = 0u32;
        row_chunk_start.push(0);
        for w in indptr.windows(2) {
            acc += chunk::chunks_for_row(w[1] - w[0]) as u32;
            row_chunk_start.push(acc);
        }
        debug_assert_eq!(acc as usize, metas.len());
        Ok(Self {
            map,
            path: path.to_path_buf(),
            cache: Mutex::new(BlockCache::new(cache_blocks)),
            cache_blocks,
            metas,
            row_chunk_start,
            indptr,
            n_cols,
            ids_sec,
            vals_sec,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    pub fn n_rows(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// `(cache hits, cache misses)` since open — the bench harness uses
    /// this to separate cold and warm throughput.
    pub fn cache_counters(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// RAM actually resident for this reader: chunk metadata, row
    /// mapping, and the block cache at full capacity (the mapping
    /// itself is page cache, not anonymous memory).
    pub fn resident_bytes(&self) -> usize {
        use std::mem::size_of;
        self.metas.len() * size_of::<ChunkMeta>()
            + self.row_chunk_start.len() * size_of::<u32>()
            + self.indptr.len() * size_of::<usize>()
            + self.cache_blocks * BLOCK_CAP
    }

    /// Fetch one block payload through the cache, verifying its CRC on
    /// miss. Returns a plain error message on any defect.
    fn block(&self, sec: &SectionGeom, local: u64) -> Result<Arc<Vec<u8>>, String> {
        let gb = sec.first_block + local;
        {
            let mut cache = lock_cache(&self.cache);
            if let Some(p) = cache.get(gb) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(p);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let buf = self.map.bytes();
        let boff = HEADER_LEN + gb as usize * BLOCK_SIZE;
        // In bounds: check_structure proved n_blocks · BLOCK_SIZE fits
        // the file, and validate_layout bounds local by the section.
        let hdr = &buf[boff..boff + BLOCK_HDR];
        let payload_len = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let crc_stored = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let expected = (sec.byte_len - local * BLOCK_CAP as u64).min(BLOCK_CAP as u64) as usize;
        if payload_len != expected {
            return Err(format!(
                "block {gb}: payload length {payload_len}, expected {expected}"
            ));
        }
        let payload = buf[boff + BLOCK_HDR..boff + BLOCK_HDR + payload_len].to_vec();
        if crc32(&payload) != crc_stored {
            return Err(format!("block {gb}: checksum mismatch"));
        }
        let payload = Arc::new(payload);
        lock_cache(&self.cache).insert(gb, Arc::clone(&payload));
        Ok(payload)
    }

    /// Copy `len` bytes at logical offset `off` of a lazy section into
    /// `out` (cleared first), walking blocks through the cache.
    fn read_span(
        &self,
        sec: &SectionGeom,
        off: u64,
        len: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), String> {
        debug_assert!(off + len as u64 <= sec.byte_len);
        out.clear();
        out.reserve(len);
        let mut cur = off;
        let end = off + len as u64;
        while cur < end {
            let local = cur / BLOCK_CAP as u64;
            let boff = (cur % BLOCK_CAP as u64) as usize;
            let payload = self.block(sec, local)?;
            let take = ((end - cur) as usize).min(payload.len() - boff);
            out.extend_from_slice(&payload[boff..boff + take]);
            cur += take as u64;
        }
        Ok(())
    }

    /// Decode corpus row `i` into `ids`/`vals` (cleared first), using
    /// `bytes` as byte scratch. Validates the row contract the in-RAM
    /// loader enforces: strictly ascending ids `< D` (across chunk
    /// boundaries too) and finite nonnegative values.
    pub(crate) fn try_fill_row(
        &self,
        i: usize,
        bytes: &mut Vec<u8>,
        ids: &mut Vec<u32>,
        vals: &mut Vec<f64>,
    ) -> Result<(), String> {
        ids.clear();
        vals.clear();
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        if lo == hi {
            return Ok(());
        }
        let (c0, c1) = (
            self.row_chunk_start[i] as usize,
            self.row_chunk_start[i + 1] as usize,
        );
        // A row's chunks are contiguous in both streams.
        let id_off = self.metas[c0].id_off;
        let last = &self.metas[c1 - 1];
        let id_len = (last.id_off + last.id_len as u64 - id_off) as usize;
        self.read_span(&self.ids_sec, id_off, id_len, bytes)?;
        for m in &self.metas[c0..c1] {
            let rel = (m.id_off - id_off) as usize;
            chunk::decode_chunk_ids(&bytes[rel..rel + m.id_len as usize], m, ids)?;
        }
        if !ids.windows(2).all(|w| w[0] < w[1]) {
            return Err(format!("row {i}: ids not strictly ascending across chunks"));
        }
        if let Some(&bad) = ids.iter().find(|&&t| t as usize >= self.n_cols) {
            return Err(format!("row {i}: term id {bad} >= D={}", self.n_cols));
        }

        self.read_span(&self.vals_sec, (lo * 8) as u64, (hi - lo) * 8, bytes)?;
        for p in 0..hi - lo {
            let b = &bytes[p * 8..p * 8 + 8];
            let v = f64::from_bits(u64::from_le_bytes(b.try_into().unwrap()));
            if !v.is_finite() || v < 0.0 {
                return Err(format!("row {i}: non-finite or negative value {v}"));
            }
            vals.push(v);
        }
        Ok(())
    }

    /// Serve-path row fetch. Panics only if the file's bytes changed
    /// after [`DiskRows::validate_all`] passed (CRC or contract
    /// violation under a live server); `serve_batch` contains that
    /// per-query.
    pub(crate) fn fill_row(
        &self,
        i: usize,
        bytes: &mut Vec<u8>,
        ids: &mut Vec<u32>,
        vals: &mut Vec<f64>,
    ) {
        if let Err(d) = self.try_fill_row(i, bytes, ids, vals) {
            panic!(
                "snapshot {} corrupted after load (row {i}): {d}",
                self.path.display()
            );
        }
    }

    /// Stream every row once with the serving decode path, surfacing
    /// any defect as a typed error. After this passes, serving cannot
    /// hit a decode error unless the file mutates on disk.
    pub(crate) fn validate_all(&self) -> SkmResult<()> {
        let mut bytes = Vec::new();
        let mut ids = Vec::new();
        let mut vals = Vec::new();
        for i in 0..self.n_rows() {
            self.try_fill_row(i, &mut bytes, &mut ids, &mut vals)
                .map_err(|d| {
                    SkmError::corrupt_snapshot(
                        self.path.display().to_string(),
                        "corpus_chunks",
                        d,
                    )
                })?;
        }
        Ok(())
    }
}

/// Poison-tolerant lock (same policy as the serve/assign pools): a
/// panic while holding the cache lock must not poison every later
/// query — the cache holds only verified immutable payloads, so the
/// inner state is always valid.
fn lock_cache(m: &Mutex<BlockCache>) -> std::sync::MutexGuard<'_, BlockCache> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = BlockCache::new(4);
        for gb in 0..4u64 {
            c.insert(gb, Arc::new(vec![gb as u8]));
        }
        assert_eq!(c.len(), 4);
        // Touch 0 so 1 becomes the eviction victim.
        assert!(c.get(0).is_some());
        c.insert(9, Arc::new(vec![9]));
        assert_eq!(c.len(), 4);
        assert!(c.get(1).is_none(), "LRU victim survived");
        assert!(c.get(0).is_some());
        assert!(c.get(9).is_some());
        // Capacity floor: tiny requests still hold a row's worth.
        assert_eq!(BlockCache::new(0).cap_blocks, 4);
    }

    #[test]
    fn snapshot_map_reads_file_bytes() {
        let dir = std::env::temp_dir().join(format!("skm_mmap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bin");
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let map = SnapshotMap::open(&path).unwrap();
        assert_eq!(map.bytes(), &data[..]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(SnapshotMap::open(Path::new("/nonexistent/skm.map")).is_err());
    }
}
