//! Crash-safe persistence: a versioned, checksummed on-disk format for
//! frozen serving state and clustering checkpoints.
//!
//! ## What is stored
//!
//! * **Serving snapshots** ([`save_snapshot`] / [`load_snapshot`]) — a
//!   complete [`crate::serve::ClusteredCorpus`] (corpus CSR, document
//!   frequencies, term relabeling, assignment, frozen means, ρ, member
//!   posting lists) plus the router's structural parameters
//!   [`crate::serve::RouterParams`]. A loaded snapshot answers every
//!   query **bit-identical** to the in-RAM snapshot it was saved from:
//!   all floats round-trip as raw IEEE-754 bits, and the member
//!   lists / relabeling are stored verbatim rather than recomputed.
//! * **Clustering checkpoints** ([`checkpoint`]) — the full mid-run
//!   state of the full-batch and mini-batch drivers (assignment, ρ,
//!   invariance flags, means, RNG stream, decay counters, estimator
//!   state), so an interrupted run resumes on a **bit-identical
//!   trajectory** to the uninterrupted one.
//!
//! ## Format and crash safety
//!
//! One file layout serves all three kinds (see [`format`] for the byte
//! layout): a 40-byte header (magic, version, endianness marker, kind),
//! fixed 64 KiB data blocks each carrying its own CRC32, a section
//! manifest, and a fixed 32-byte footer. Publication is atomic:
//! write-to-temp → fsync → rename ([`writer`]), so a crash at any stage
//! leaves the previously published file untouched. Loading is paranoid
//! by default ([`reader`]): every checksum is verified and every
//! decoded value is structurally validated (offsets in bounds, ids
//! `< K`, member lists a partition consistent with the assignment,
//! df-ascending relabeling inverse-consistent) **before** any value
//! reaches an `unsafe`-using kernel — a corrupt or truncated file is a
//! typed [`SkmError::CorruptSnapshot`], never a panic, never UB, never
//! a partially-built snapshot.
//!
//! ## Compressed snapshots (format version 2)
//!
//! [`save_snapshot_with`] with `compress = true` writes the same
//! container stamped format version 2: the three posting families
//! (corpus rows, mean rows, member lists) are chunk-encoded by
//! [`chunk`] — ≤128 postings per chunk, ids as delta + LEB128 varints,
//! values as raw `f64` bits in a separate stream, plus a fixed 28-byte
//! per-chunk metadata record — so ids decode without touching values
//! and any row is decodable from its chunks alone. Decoding is
//! bit-exact: a v2 load (or an mmap-served query) returns the same id
//! and score bits as the v1 / in-RAM path. [`load_snapshot`] reads both
//! versions transparently; [`load_snapshot_mmap`] additionally leaves
//! the (dominant) corpus posting sections on disk behind an mmap + LRU
//! block cache ([`mmap`]) so serving does not need the corpus in RAM.
//!
//! Fail-point sites for the crash harness (`rust/tests/persist.rs`,
//! cargo feature `failpoints`): `persist.write_block`, `persist.fsync`,
//! `persist.rename`, `persist.read_block`. The sites are shared by the
//! v1 and v2 writers, so the kill matrix covers the compressed path.

pub mod checkpoint;
pub mod chunk;
pub mod format;
pub mod mmap;
pub mod reader;
pub mod writer;

use crate::error::{SkmError, SkmResult};
use crate::index::MeanSet;
use crate::persist::format::{
    ByteReader, ByteWriter, KIND_SNAPSHOT, VERSION, VERSION_COMPRESSED,
};
use crate::persist::mmap::{DiskRows, SectionGeom, SnapshotMap};
use crate::persist::reader::{read_blocks_file, RawFile};
use crate::serve::{ClusteredCorpus, RouterParams};
use crate::sparse::{CsrMatrix, Dataset};
use std::path::Path;
use std::sync::Arc;

/// Section ids shared by the snapshot and checkpoint codecs.
///
/// Public so integration tests (and external tooling) can locate a
/// section inside the container via the manifest without hardcoding
/// magic numbers.
pub mod sec {
    pub const META: u32 = 1;
    pub const CORPUS_INDPTR: u32 = 2;
    pub const CORPUS_INDICES: u32 = 3;
    pub const CORPUS_VALUES: u32 = 4;
    pub const DF: u32 = 5;
    pub const ORIG_TERM: u32 = 6;
    pub const ASSIGN: u32 = 7;
    pub const MEANS_INDPTR: u32 = 8;
    pub const MEANS_INDICES: u32 = 9;
    pub const MEANS_VALUES: u32 = 10;
    pub const MEAN_SIZES: u32 = 11;
    pub const RHO: u32 = 12;
    pub const MEMBER_OFFSETS: u32 = 13;
    pub const MEMBER_IDS: u32 = 14;
    pub const ORIG_TO_TERM: u32 = 15;
    pub const XSTATE: u32 = 16;
    pub const MEANS_MOVED: u32 = 17;
    pub const DRIVER: u32 = 18;
    pub const FINGERPRINT: u32 = 19;
    pub const MB_DRIVER: u32 = 20;
    // Format v2 (compressed) replacements for CORPUS_INDICES/VALUES,
    // MEANS_INDICES/VALUES, and MEMBER_IDS; the indptr/offset sections
    // above are shared by both versions.
    pub const CORPUS_CHUNK_META: u32 = 21;
    pub const CORPUS_CHUNK_IDS: u32 = 22;
    pub const CORPUS_CHUNK_VALS: u32 = 23;
    pub const MEANS_CHUNK_META: u32 = 24;
    pub const MEANS_CHUNK_IDS: u32 = 25;
    pub const MEANS_CHUNK_VALS: u32 = 26;
    pub const MEMBER_CHUNK_META: u32 = 27;
    pub const MEMBER_CHUNK_IDS: u32 = 28;
}

fn corrupt(path: &Path, section: &str, detail: impl Into<String>) -> SkmError {
    SkmError::corrupt_snapshot(path.display().to_string(), section, detail)
}

/// Decode one section as a `u32` array (exact payload).
pub(crate) fn section_u32s(
    raw: &RawFile,
    id: u32,
    name: &str,
    path: &Path,
) -> SkmResult<Vec<u32>> {
    let mut r = ByteReader::new(raw.section(id, name, path)?);
    let v = r.get_u32s().map_err(|d| corrupt(path, name, d))?;
    r.finish().map_err(|d| corrupt(path, name, d))?;
    Ok(v)
}

/// Decode one section as a `usize` (stored `u64`) array.
pub(crate) fn section_usizes(
    raw: &RawFile,
    id: u32,
    name: &str,
    path: &Path,
) -> SkmResult<Vec<usize>> {
    let mut r = ByteReader::new(raw.section(id, name, path)?);
    let v = r.get_usizes().map_err(|d| corrupt(path, name, d))?;
    r.finish().map_err(|d| corrupt(path, name, d))?;
    Ok(v)
}

/// Decode one section as an `f64` array (raw bits).
pub(crate) fn section_f64s(
    raw: &RawFile,
    id: u32,
    name: &str,
    path: &Path,
) -> SkmResult<Vec<f64>> {
    let mut r = ByteReader::new(raw.section(id, name, path)?);
    let v = r.get_f64s().map_err(|d| corrupt(path, name, d))?;
    r.finish().map_err(|d| corrupt(path, name, d))?;
    Ok(v)
}

/// Decode one section as a `bool` array.
pub(crate) fn section_bools(
    raw: &RawFile,
    id: u32,
    name: &str,
    path: &Path,
) -> SkmResult<Vec<bool>> {
    let mut r = ByteReader::new(raw.section(id, name, path)?);
    let v = r.get_bools().map_err(|d| corrupt(path, name, d))?;
    r.finish().map_err(|d| corrupt(path, name, d))?;
    Ok(v)
}

/// Validate raw CSR arrays and assemble the matrix. This is the
/// soundness gate: [`CsrMatrix::from_raw`] only debug-asserts, and the
/// unchecked gather kernels downstream rely on `indices < n_cols` and
/// monotone `indptr` — so every invariant is release-checked here with
/// a typed error before the matrix exists.
pub(crate) fn validated_csr(
    path: &Path,
    name: &str,
    n_rows: usize,
    n_cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
) -> SkmResult<CsrMatrix> {
    let c = |d: String| corrupt(path, name, d);
    check_indptr(path, name, n_rows, &indptr)?;
    if *indptr.last().unwrap() != indices.len() || indices.len() != values.len() {
        return Err(c(format!(
            "nnz mismatch: indptr ends at {}, {} indices, {} values",
            indptr.last().unwrap(),
            indices.len(),
            values.len()
        )));
    }
    for r in 0..n_rows {
        let seg = &indices[indptr[r]..indptr[r + 1]];
        if !seg.windows(2).all(|w| w[0] < w[1]) {
            return Err(c(format!("row {r} term ids not strictly ascending")));
        }
        if let Some(&bad) = seg.iter().find(|&&t| t as usize >= n_cols) {
            return Err(c(format!("row {r} term id {bad} >= D={n_cols}")));
        }
    }
    // The feature space is nonnegative (tf-idf weights, means of
    // nonnegative unit vectors); the router's Region-3 upper bound
    // relies on it, so enforce it on load.
    if let Some(&bad) = values.iter().find(|v| !v.is_finite() || **v < 0.0) {
        return Err(c(format!("non-finite or negative feature value {bad}")));
    }
    Ok(CsrMatrix::from_raw(n_cols, indptr, indices, values))
}

/// Release-checked row-pointer shape: `n_rows + 1` entries, starts at
/// zero, monotone. Factored out of [`validated_csr`] because the chunk
/// layout math (`chunk::total_chunks`) derives row sizes from `indptr`
/// and must never see a decreasing pointer.
pub(crate) fn check_indptr(
    path: &Path,
    name: &str,
    n_rows: usize,
    indptr: &[usize],
) -> SkmResult<()> {
    let c = |d: String| corrupt(path, name, d);
    if indptr.len() != n_rows + 1 {
        return Err(c(format!(
            "indptr has {} entries for {n_rows} rows (want {})",
            indptr.len(),
            n_rows + 1
        )));
    }
    if indptr[0] != 0 {
        return Err(c(format!("indptr[0] = {} (want 0)", indptr[0])));
    }
    if let Some(r) = indptr.windows(2).position(|w| w[0] > w[1]) {
        return Err(c(format!("indptr decreases at row {r}")));
    }
    Ok(())
}

/// Serialize a frozen serving snapshot and its router parameters,
/// publishing atomically at `path` (uncompressed, format version 1).
/// Returns the file size in bytes.
///
/// Takes `params` by reference: every external caller holds the params
/// it is about to keep serving with, and the by-value signature this
/// module originally shipped forced a copy at each of them — worse, the
/// callers in `main.rs`, `tests/persist.rs`, and `benches/serve.rs`
/// were already written against the by-reference form, so the by-value
/// signature did not compile against its own users.
pub fn save_snapshot(
    path: &Path,
    snap: &ClusteredCorpus,
    params: &RouterParams,
) -> SkmResult<u64> {
    save_snapshot_with(path, snap, params, false)
}

/// [`save_snapshot`] with an explicit choice of payload codec:
/// `compress = false` writes format v1 (byte-identical to
/// [`save_snapshot`]), `compress = true` writes format v2 with the
/// posting families chunk-encoded (see [`chunk`]). Both publish
/// atomically through the same fail-point-instrumented writer.
pub fn save_snapshot_with(
    path: &Path,
    snap: &ClusteredCorpus,
    params: &RouterParams,
    compress: bool,
) -> SkmResult<u64> {
    // A disk-backed snapshot's in-RAM corpus is an empty stub — writing
    // it out would silently persist a corpus of zeros.
    if snap.is_disk_backed() {
        return Err(SkmError::invalid_config(
            "cannot re-serialize a snapshot served from disk (mmap): its corpus \
             rows are not resident — load it without mmap first",
        ));
    }
    let (n_cols, x_indptr, x_indices, x_values) = snap.ds.x.raw_parts();
    debug_assert_eq!(n_cols, snap.ds.d());
    // The mean slab's arena layout depends on splice history; serialize
    // through the canonical CSR form so the on-disk bytes stay stable.
    let mcsr = snap.means.m.to_csr();
    let (m_cols, m_indptr, m_indices, m_values) = mcsr.raw_parts();
    debug_assert_eq!(m_cols, snap.ds.d());
    let (member_offsets, member_ids, orig_to_term) = snap.persisted_parts();

    let mut meta = ByteWriter::new();
    meta.put_u64(snap.ds.n() as u64);
    meta.put_u64(snap.ds.d() as u64);
    meta.put_u64(snap.k as u64);
    meta.put_f64_bits(snap.objective);
    // usize::MAX (the exact-routing sentinel) maps to u64::MAX so the
    // encoding is host-width independent.
    meta.put_u64(if params.t_th == usize::MAX {
        u64::MAX
    } else {
        params.t_th as u64
    });
    meta.put_f64_bits(params.v_th);
    meta.put_str(&snap.ds.name);

    let enc_u32s = |v: &[u32]| {
        let mut w = ByteWriter::new();
        w.put_u32s(v);
        w.into_bytes()
    };
    let enc_usizes = |v: &[usize]| {
        let mut w = ByteWriter::new();
        w.put_usizes(v);
        w.into_bytes()
    };
    let enc_f64s = |v: &[f64]| {
        let mut w = ByteWriter::new();
        w.put_f64s(v);
        w.into_bytes()
    };

    if compress {
        // v2: the posting families ride as chunk streams; the id-keyed
        // sections they replace are simply absent from the manifest.
        let corpus = chunk::encode_postings(x_indptr, x_indices, x_values);
        let means = chunk::encode_postings(m_indptr, m_indices, m_values);
        let members = chunk::encode_postings(member_offsets, member_ids, &[]);
        let sections = vec![
            (sec::META, meta.into_bytes()),
            (sec::CORPUS_INDPTR, enc_usizes(x_indptr)),
            (sec::CORPUS_CHUNK_META, corpus.meta),
            (sec::CORPUS_CHUNK_IDS, corpus.ids),
            (sec::CORPUS_CHUNK_VALS, corpus.vals),
            (sec::DF, enc_u32s(&snap.ds.df)),
            (sec::ORIG_TERM, enc_u32s(&snap.ds.orig_term)),
            (sec::ASSIGN, enc_u32s(&snap.assign)),
            (sec::MEANS_INDPTR, enc_usizes(m_indptr)),
            (sec::MEANS_CHUNK_META, means.meta),
            (sec::MEANS_CHUNK_IDS, means.ids),
            (sec::MEANS_CHUNK_VALS, means.vals),
            (sec::MEAN_SIZES, enc_u32s(&snap.means.sizes)),
            (sec::RHO, enc_f64s(&snap.rho)),
            (sec::MEMBER_OFFSETS, enc_usizes(member_offsets)),
            (sec::MEMBER_CHUNK_META, members.meta),
            (sec::MEMBER_CHUNK_IDS, members.ids),
            (sec::ORIG_TO_TERM, enc_u32s(orig_to_term)),
        ];
        writer::write_blocks_file_versioned(path, KIND_SNAPSHOT, VERSION_COMPRESSED, &sections)
    } else {
        // v1: exactly the layout every snapshot before the version bump
        // used — section order (and therefore every byte) is unchanged.
        let sections = vec![
            (sec::META, meta.into_bytes()),
            (sec::CORPUS_INDPTR, enc_usizes(x_indptr)),
            (sec::CORPUS_INDICES, enc_u32s(x_indices)),
            (sec::CORPUS_VALUES, enc_f64s(x_values)),
            (sec::DF, enc_u32s(&snap.ds.df)),
            (sec::ORIG_TERM, enc_u32s(&snap.ds.orig_term)),
            (sec::ASSIGN, enc_u32s(&snap.assign)),
            (sec::MEANS_INDPTR, enc_usizes(m_indptr)),
            (sec::MEANS_INDICES, enc_u32s(m_indices)),
            (sec::MEANS_VALUES, enc_f64s(m_values)),
            (sec::MEAN_SIZES, enc_u32s(&snap.means.sizes)),
            (sec::RHO, enc_f64s(&snap.rho)),
            (sec::MEMBER_OFFSETS, enc_usizes(member_offsets)),
            (sec::MEMBER_IDS, enc_u32s(member_ids)),
            (sec::ORIG_TO_TERM, enc_u32s(orig_to_term)),
        ];
        writer::write_blocks_file(path, KIND_SNAPSHOT, &sections)
    }
}

/// Load, checksum-verify, and structurally validate a serving snapshot.
/// On success the returned snapshot serves every query bit-identical to
/// the one that was saved; on any defect the result is a typed
/// [`SkmError::CorruptSnapshot`] and no partial snapshot escapes.
pub fn load_snapshot(path: &Path) -> SkmResult<(ClusteredCorpus, RouterParams)> {
    let raw = read_blocks_file(path, KIND_SNAPSHOT)?;
    build_snapshot(path, &raw, None)
}

/// Open a snapshot with the corpus posting sections left **on disk**
/// behind an mmap + LRU block cache (see [`mmap`]), so serving does not
/// need the corpus resident in RAM. Everything else — metadata, means,
/// ρ, member lists, relabeling — is decoded and validated eagerly, and
/// the corpus chunks are streamed once through the serving decode path
/// at open time, so any defect is a typed error here, not a panic
/// later. Queries served through the returned snapshot are bit-identical
/// to the in-RAM router.
///
/// `cache_blocks` caps the LRU at that many 64 KiB payload blocks
/// (clamped to at least 4). Version-1 files carry no chunk sections, so
/// they fall back to the ordinary full in-RAM load.
pub fn load_snapshot_mmap(
    path: &Path,
    cache_blocks: usize,
) -> SkmResult<(ClusteredCorpus, RouterParams)> {
    let map = SnapshotMap::open(path)?;
    let (header, entries) = reader::check_structure(map.bytes(), path, KIND_SNAPSHOT)?;
    if header.version == VERSION {
        drop(map);
        return load_snapshot(path);
    }
    let skip = [sec::CORPUS_CHUNK_IDS, sec::CORPUS_CHUNK_VALS];
    let raw = reader::assemble_sections(map.bytes(), path, &header, &entries, &skip)?;
    let geom = |id: u32| {
        entries
            .iter()
            .find(|e| e.id == id)
            .map(|e| SectionGeom {
                first_block: e.first_block,
                byte_len: e.byte_len,
            })
            .ok_or_else(|| {
                corrupt(
                    path,
                    "corpus_chunks",
                    format!("section {id} missing from manifest"),
                )
            })
    };
    let ids_sec = geom(sec::CORPUS_CHUNK_IDS)?;
    let vals_sec = geom(sec::CORPUS_CHUNK_VALS)?;
    build_snapshot(
        path,
        &raw,
        Some(DiskParts {
            map,
            ids_sec,
            vals_sec,
            cache_blocks,
        }),
    )
}

/// Corpus sections the mmap loader leaves on disk, handed through to
/// [`DiskRows`].
struct DiskParts {
    map: SnapshotMap,
    ids_sec: SectionGeom,
    vals_sec: SectionGeom,
    cache_blocks: usize,
}

/// Decode one posting family according to the file's format version:
/// v1 reads the raw id/value sections verbatim, v2 chunk-decodes (bit-
/// exactly). A `0` in the values slot of either triple marks an
/// ids-only family (member lists). For v2 the row pointer's monotone
/// shape is enforced first — the chunk layout derives row sizes from it.
fn decoded_postings(
    raw: &RawFile,
    path: &Path,
    name: &str,
    indptr: &[usize],
    v1: (u32, u32),
    v2: (u32, u32, u32),
) -> SkmResult<(Vec<u32>, Vec<f64>)> {
    if raw.version == VERSION {
        let ids = section_u32s(raw, v1.0, name, path)?;
        let vals = if v1.1 == 0 {
            Vec::new()
        } else {
            section_f64s(raw, v1.1, name, path)?
        };
        return Ok((ids, vals));
    }
    let c = |d: String| corrupt(path, name, d);
    if indptr.is_empty() {
        return Err(c("empty row pointer".to_string()));
    }
    if indptr[0] != 0 || indptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(c("row pointer not monotone from 0".to_string()));
    }
    let meta = raw.section(v2.0, name, path)?;
    let ids = raw.section(v2.1, name, path)?;
    let has_vals = v2.2 != 0;
    let vals: &[u8] = if has_vals {
        raw.section(v2.2, name, path)?
    } else {
        &[]
    };
    chunk::decode_postings(indptr, meta, ids, vals, has_vals).map_err(c)
}

fn build_snapshot(
    path: &Path,
    raw: &RawFile,
    disk: Option<DiskParts>,
) -> SkmResult<(ClusteredCorpus, RouterParams)> {
    let c = |section: &str, d: String| corrupt(path, section, d);

    // META.
    let mut meta = ByteReader::new(raw.section(sec::META, "meta", path)?);
    let meta_field = |what: &str, r: Result<u64, String>| -> SkmResult<u64> {
        r.map_err(|d| c("meta", format!("{what}: {d}")))
    };
    let n = usize::try_from(meta_field("n", meta.get_u64())?)
        .map_err(|_| c("meta", "corpus size exceeds host usize".to_string()))?;
    let d = usize::try_from(meta_field("d", meta.get_u64())?)
        .map_err(|_| c("meta", "vocabulary size exceeds host usize".to_string()))?;
    let k = usize::try_from(meta_field("k", meta.get_u64())?)
        .map_err(|_| c("meta", "cluster count exceeds host usize".to_string()))?;
    let objective = f64::from_bits(meta_field("objective", meta.get_u64())?);
    let t_th_raw = meta_field("t_th", meta.get_u64())?;
    let v_th = f64::from_bits(meta_field("v_th", meta.get_u64())?);
    let name = meta.get_str().map_err(|d| c("meta", d))?;
    meta.finish().map_err(|d| c("meta", d))?;
    if k == 0 {
        return Err(c("meta", "K = 0".to_string()));
    }
    if n == 0 {
        return Err(c("meta", "empty corpus".to_string()));
    }
    if !objective.is_finite() {
        return Err(c("meta", format!("non-finite objective {objective}")));
    }
    let t_th = if t_th_raw == u64::MAX {
        usize::MAX
    } else {
        let t = usize::try_from(t_th_raw)
            .map_err(|_| c("meta", "t_th exceeds host usize".to_string()))?;
        if t > d {
            return Err(c("meta", format!("t_th = {t} > D = {d}")));
        }
        t
    };
    if !v_th.is_finite() || v_th <= 0.0 {
        return Err(c("meta", format!("v_th = {v_th} (want positive finite)")));
    }

    // Corpus CSR + relabeling. With a [`DiskParts`] the corpus postings
    // stay on disk: chunk metadata is decoded and every row is streamed
    // once through the serving decode path (full validation), then the
    // in-RAM matrix is an empty stub of the right shape — all corpus
    // row access goes through [`DiskRows`] (`ClusteredCorpus::row_view`).
    let x_indptr = section_usizes(raw, sec::CORPUS_INDPTR, "corpus", path)?;
    let mut disk_rows: Option<Arc<DiskRows>> = None;
    let x = match disk {
        None => {
            let (xi, xv) = decoded_postings(
                raw,
                path,
                "corpus",
                &x_indptr,
                (sec::CORPUS_INDICES, sec::CORPUS_VALUES),
                (
                    sec::CORPUS_CHUNK_META,
                    sec::CORPUS_CHUNK_IDS,
                    sec::CORPUS_CHUNK_VALS,
                ),
            )?;
            validated_csr(path, "corpus", n, d, x_indptr, xi, xv)?
        }
        Some(dp) => {
            check_indptr(path, "corpus", n, &x_indptr)?;
            let metas = chunk::decode_metas(
                raw.section(sec::CORPUS_CHUNK_META, "corpus_chunks", path)?,
                &x_indptr,
            )
            .map_err(|d| c("corpus_chunks", d))?;
            let rows = DiskRows::new(
                dp.map,
                path,
                metas,
                x_indptr,
                d,
                dp.ids_sec,
                dp.vals_sec,
                dp.cache_blocks,
            )?;
            rows.validate_all()?;
            disk_rows = Some(Arc::new(rows));
            CsrMatrix::from_raw(d, vec![0; n + 1], Vec::new(), Vec::new())
        }
    };
    let df = section_u32s(raw, sec::DF, "df", path)?;
    if df.len() != d {
        return Err(c("df", format!("{} entries for D = {d}", df.len())));
    }
    if df.windows(2).any(|w| w[0] > w[1]) {
        let detail = "document frequencies not ascending in term id \
                      (the df-ascending relabeling is broken)";
        return Err(c("df", detail.to_string()));
    }
    if let Some(&bad) = df.iter().find(|&&f| f == 0 || f as usize > n) {
        return Err(c("df", format!("df value {bad} outside [1, N={n}]")));
    }
    let orig_term = section_u32s(raw, sec::ORIG_TERM, "orig_term", path)?;
    if orig_term.len() != d {
        return Err(c("orig_term", format!("{} entries for D = {d}", orig_term.len())));
    }

    // Assignment.
    let assign = section_u32s(raw, sec::ASSIGN, "assign", path)?;
    if assign.len() != n {
        return Err(c("assign", format!("{} entries for N = {n}", assign.len())));
    }
    if let Some(&bad) = assign.iter().find(|&&a| a as usize >= k) {
        return Err(c("assign", format!("cluster id {bad} >= K = {k}")));
    }

    // Frozen means (always decoded to RAM — they are small and hot).
    let m_indptr = section_usizes(raw, sec::MEANS_INDPTR, "means", path)?;
    let (mi, mv) = decoded_postings(
        raw,
        path,
        "means",
        &m_indptr,
        (sec::MEANS_INDICES, sec::MEANS_VALUES),
        (
            sec::MEANS_CHUNK_META,
            sec::MEANS_CHUNK_IDS,
            sec::MEANS_CHUNK_VALS,
        ),
    )?;
    let m = crate::index::RowSlab::from_csr(&validated_csr(path, "means", k, d, m_indptr, mi, mv)?);
    let sizes = section_u32s(raw, sec::MEAN_SIZES, "mean_sizes", path)?;
    if sizes.len() != k {
        return Err(c("mean_sizes", format!("{} entries for K = {k}", sizes.len())));
    }

    // ρ.
    let rho = section_f64s(raw, sec::RHO, "rho", path)?;
    if rho.len() != n {
        return Err(c("rho", format!("{} entries for N = {n}", rho.len())));
    }
    if let Some(&bad) = rho.iter().find(|v| !v.is_finite()) {
        return Err(c("rho", format!("non-finite rho value {bad}")));
    }

    // Member posting lists: an ascending partition of [0, N) that is
    // exactly consistent with `assign` and `sizes`.
    let member_offsets = section_usizes(raw, sec::MEMBER_OFFSETS, "members", path)?;
    if member_offsets.len() != k + 1 {
        return Err(c("members", format!("{} offsets for K = {k}", member_offsets.len())));
    }
    if member_offsets[0] != 0 || *member_offsets.last().unwrap() != n {
        return Err(c("members", format!(
            "offsets span [{}, {}] (want [0, {n}])",
            member_offsets[0],
            member_offsets.last().unwrap()
        )));
    }
    if member_offsets.windows(2).any(|w| w[0] > w[1]) {
        return Err(c("members", "offsets decrease".to_string()));
    }
    // Ids-only family: offsets are its row pointer (validated above,
    // which is why the ids are decoded only now).
    let (member_ids, _) = decoded_postings(
        raw,
        path,
        "members",
        &member_offsets,
        (sec::MEMBER_IDS, 0),
        (sec::MEMBER_CHUNK_META, sec::MEMBER_CHUNK_IDS, 0),
    )?;
    if member_ids.len() != n {
        return Err(c("members", format!("{} member ids for N = {n}", member_ids.len())));
    }
    for j in 0..k {
        let seg = &member_ids[member_offsets[j]..member_offsets[j + 1]];
        if sizes[j] as usize != seg.len() {
            return Err(c("members", format!(
                "cluster {j}: size {} but {} members listed",
                sizes[j],
                seg.len()
            )));
        }
        if !seg.windows(2).all(|w| w[0] < w[1]) {
            return Err(c("members", format!("cluster {j}: member ids not strictly ascending")));
        }
        for &i in seg {
            if i as usize >= n {
                return Err(c("members", format!("cluster {j}: member id {i} >= N = {n}")));
            }
            if assign[i as usize] as usize != j {
                return Err(c("members", format!(
                    "doc {i} listed in cluster {j} but assigned to {}",
                    assign[i as usize]
                )));
            }
        }
    }

    // Inverse relabeling: orig_to_term must invert orig_term exactly,
    // in both directions, and cover exactly [0, max original id].
    let orig_to_term = section_u32s(raw, sec::ORIG_TO_TERM, "orig_to_term", path)?;
    let want_len = orig_term.iter().max().map(|&t| t as usize + 1).unwrap_or(0);
    if orig_to_term.len() != want_len {
        return Err(c("orig_to_term", format!(
            "{} entries, want {want_len} (max original term id + 1)",
            orig_to_term.len()
        )));
    }
    for (t, &o) in orig_term.iter().enumerate() {
        if orig_to_term[o as usize] != t as u32 {
            return Err(c("orig_to_term", format!(
                "original term {o} maps to {} but orig_term[{t}] = {o}",
                orig_to_term[o as usize]
            )));
        }
    }
    for (o, &t) in orig_to_term.iter().enumerate() {
        if t != u32::MAX && (t as usize >= d || orig_term[t as usize] as usize != o) {
            return Err(c("orig_to_term", format!(
                "entry {o} -> {t} is not the inverse of orig_term"
            )));
        }
    }

    let ds = Dataset {
        x,
        df,
        orig_term,
        name,
    };
    let means = MeanSet {
        m,
        moved: vec![false; k], // frozen by construction
        sizes,
    };
    let mut snap = ClusteredCorpus::from_validated_parts(
        ds,
        assign,
        k,
        means,
        rho,
        objective,
        member_offsets,
        member_ids,
        orig_to_term,
    );
    if let Some(rows) = disk_rows {
        snap.attach_disk(rows);
    }
    Ok((snap, RouterParams { t_th, v_th }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, tiny};
    use crate::sparse::build_dataset;
    use std::path::PathBuf;

    fn tmp_file(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("skm_persist_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("snap.skm")
    }

    fn snapshot() -> ClusteredCorpus {
        let c = generate(&tiny(41));
        let ds = build_dataset("tiny", c.n_terms, &c.docs);
        let n = ds.n();
        let assign: Vec<u32> = (0..n).map(|i| (i % 5) as u32).collect();
        ClusteredCorpus::from_assignment(ds, assign, 5)
    }

    #[test]
    fn snapshot_round_trip_is_bit_exact() {
        let snap = snapshot();
        let params = RouterParams {
            t_th: snap.ds.d() / 2,
            v_th: 0.25,
        };
        let path = tmp_file("rt");
        let bytes = save_snapshot(&path, &snap, &params).unwrap();
        assert!(bytes > 0);
        let (loaded, p2) = load_snapshot(&path).unwrap();
        assert_eq!(p2.t_th, params.t_th);
        assert_eq!(p2.v_th.to_bits(), params.v_th.to_bits());
        assert_eq!(loaded.k, snap.k);
        assert_eq!(loaded.assign, snap.assign);
        assert_eq!(loaded.objective.to_bits(), snap.objective.to_bits());
        assert_eq!(loaded.ds.x, snap.ds.x);
        assert_eq!(loaded.ds.df, snap.ds.df);
        assert_eq!(loaded.ds.orig_term, snap.ds.orig_term);
        assert_eq!(loaded.ds.name, snap.ds.name);
        assert_eq!(loaded.means.m, snap.means.m);
        assert_eq!(loaded.means.sizes, snap.means.sizes);
        assert_eq!(loaded.means.n_moving(), 0);
        assert_eq!(
            loaded.rho.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            snap.rho.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        for j in 0..snap.k {
            assert_eq!(loaded.members(j), snap.members(j));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn exact_params_sentinel_round_trips() {
        let snap = snapshot();
        let path = tmp_file("exact");
        save_snapshot(&path, &snap, &RouterParams::exact()).unwrap();
        let (_, p) = load_snapshot(&path).unwrap();
        assert_eq!(p.t_th, usize::MAX);
        assert_eq!(p.v_th, 1.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compressed_snapshot_round_trip_is_bit_exact() {
        let snap = snapshot();
        let params = RouterParams {
            t_th: snap.ds.d() / 2,
            v_th: 0.25,
        };
        let path = tmp_file("v2rt");
        save_snapshot_with(&path, &snap, &params, true).unwrap();
        let (loaded, p2) = load_snapshot(&path).unwrap();
        assert_eq!(p2.t_th, params.t_th);
        assert_eq!(p2.v_th.to_bits(), params.v_th.to_bits());
        assert_eq!(loaded.ds.x, snap.ds.x);
        assert_eq!(loaded.ds.df, snap.ds.df);
        assert_eq!(loaded.assign, snap.assign);
        assert_eq!(loaded.means.m, snap.means.m);
        assert_eq!(
            loaded.rho.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            snap.rho.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        for j in 0..snap.k {
            assert_eq!(loaded.members(j), snap.members(j));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mmap_load_serves_corpus_rows_bit_exact() {
        let snap = snapshot();
        let path = tmp_file("mmap");
        save_snapshot_with(&path, &snap, &RouterParams::exact(), true).unwrap();
        let (loaded, _) = load_snapshot_mmap(&path, 8).unwrap();
        // Corpus postings live on disk; means/members are in RAM.
        assert_eq!(loaded.means.m, snap.means.m);
        for j in 0..snap.k {
            assert_eq!(loaded.members(j), snap.members(j));
        }
        let (mut b, mut ids, mut vals) = (Vec::new(), Vec::new(), Vec::new());
        for i in 0..snap.ds.n() {
            let (ti, tv) = snap.ds.x.row(i);
            let (li, lv) = loaded.row_view(i, &mut b, &mut ids, &mut vals);
            assert_eq!(li, ti, "row {i} ids");
            assert_eq!(
                lv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                tv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "row {i} value bits"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mmap_load_of_v1_file_falls_back_to_full_ram() {
        let snap = snapshot();
        let path = tmp_file("mmapv1");
        save_snapshot(&path, &snap, &RouterParams::exact()).unwrap();
        let (loaded, p) = load_snapshot_mmap(&path, 8).unwrap();
        assert_eq!(p.t_th, usize::MAX);
        // v1 has no chunk sections: the whole corpus is in RAM.
        assert_eq!(loaded.ds.x, snap.ds.x);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_rejects_checkpoint_kind() {
        let path = tmp_file("kind");
        writer::write_blocks_file(&path, format::KIND_CLUSTER_CKPT, &[(1, vec![0u8; 8])])
            .unwrap();
        match load_snapshot(&path).unwrap_err() {
            SkmError::CorruptSnapshot { section, .. } => assert_eq!(section, "header"),
            other => panic!("wrong variant: {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }
}
