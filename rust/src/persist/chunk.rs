//! Delta+varint chunk compression for posting sections (format v2).
//!
//! ## Encoding
//!
//! A posting list family (the corpus CSR, the means CSR, or the
//! per-cluster member id lists) is split into **chunks of at most
//! [`CHUNK_CAP`] postings that never span a row boundary** — row `r`
//! owns `ceil(nnz_r / CHUNK_CAP)` consecutive chunks, so the row → chunk
//! mapping is derived from `indptr` and never stored. Three byte
//! streams are produced:
//!
//! * **meta** — one fixed-size [`ChunkMeta`] record per chunk:
//!   `{count, max_id, id_off, id_len, val_off}`. `max_id` is the last
//!   (largest) id of the chunk; `id_off`/`id_len` locate the chunk's id
//!   bytes; `val_off` locates its values. Because ids and values live in
//!   separate streams, **ids decode without touching a single value
//!   byte** — the disk reader fetches value blocks only for rows it
//!   actually scores.
//! * **ids** — per chunk: the first id as an absolute LEB128 varint,
//!   then `count − 1` strictly-positive deltas as LEB128 varints (ids
//!   are strictly ascending within a row, so every delta ≥ 1; a zero
//!   delta is a typed corruption error). Each chunk restarts from an
//!   absolute id, so a chunk decodes independently of its predecessors.
//! * **vals** — raw IEEE-754 `f64` bits, little-endian, in posting
//!   order (8 bytes per posting; `val_off = 8 × postings before the
//!   chunk`). Values round-trip **bit**-exactly, NaNs included — the
//!   same contract as the v1 `ByteWriter` encoding.
//!
//! Ids-only families (member lists) simply have an empty `vals` stream.
//!
//! ## Validation
//!
//! [`decode_postings`] re-derives the chunk layout from `indptr` and
//! checks every metadata field against it: chunk counts and sizes,
//! contiguous `id_off`/`val_off`, `id_len` equal to the bytes actually
//! consumed, `max_id` equal to the decoded last id, deltas nonzero, ids
//! representable in `u32`, and both streams consumed exactly. Every
//! defect is a `Result::Err` with a plain detail string the caller
//! wraps into [`crate::error::SkmError::CorruptSnapshot`] — never a
//! panic, and no allocation is sized from unvalidated input (decoded
//! vectors are bounded by `indptr`-derived counts, which the snapshot
//! loader has already validated against the file size).

use crate::persist::format::{ByteReader, ByteWriter};

/// Maximum postings per chunk. 128 ids ≈ ≤640 varint bytes and exactly
/// 1 KiB of values — a chunk always spans at most two 64 KiB blocks,
/// so a random row touch faults at most four cache blocks.
pub const CHUNK_CAP: usize = 128;

/// Fixed per-chunk metadata record (28 bytes encoded).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkMeta {
    /// Postings in this chunk (1 ..= CHUNK_CAP).
    pub count: u32,
    /// Largest (= last) id in the chunk.
    pub max_id: u32,
    /// Byte offset of the chunk's ids in the id stream.
    pub id_off: u64,
    /// Byte length of the chunk's ids in the id stream.
    pub id_len: u32,
    /// Byte offset of the chunk's values in the value stream
    /// (`8 × postings before this chunk`; 0 for ids-only families).
    pub val_off: u64,
}

/// Encoded size of one [`ChunkMeta`] record.
pub const CHUNK_META_LEN: usize = 28;

/// The three encoded streams of one posting family.
#[derive(Debug, Default)]
pub struct ChunkedPostings {
    /// `u64` chunk count, then `CHUNK_META_LEN` bytes per chunk.
    pub meta: Vec<u8>,
    /// Concatenated per-chunk varint id bytes.
    pub ids: Vec<u8>,
    /// Concatenated raw-bit values (empty for ids-only families).
    pub vals: Vec<u8>,
}

// ---------------------------------------------------------------------
// LEB128 varints

/// Append `v` as an unsigned LEB128 varint (1–5 bytes for `u32` range).
#[inline]
pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Decode one LEB128 varint at `pos`, returning `(value, bytes read)`.
/// Rejects truncation and values that overflow `u64` (> 10 bytes or
/// overlong final byte).
#[inline]
pub fn get_varint(buf: &[u8], pos: usize) -> Result<(u64, usize), String> {
    let mut v = 0u64;
    let mut shift = 0u32;
    let mut n = 0usize;
    loop {
        let byte = *buf
            .get(pos + n)
            .ok_or_else(|| format!("truncated varint at byte {pos}"))?;
        n += 1;
        let low = (byte & 0x7F) as u64;
        if shift >= 64 || (shift == 63 && low > 1) {
            return Err(format!("varint at byte {pos} overflows u64"));
        }
        v |= low << shift;
        if byte & 0x80 == 0 {
            return Ok((v, n));
        }
        shift += 7;
    }
}

// ---------------------------------------------------------------------
// Chunk layout derived from indptr

/// Chunks owned by row `r`: `ceil(nnz_r / CHUNK_CAP)`.
#[inline]
pub fn chunks_for_row(nnz: usize) -> usize {
    nnz.div_ceil(CHUNK_CAP)
}

/// Total chunk count for a family with row pointer `indptr`.
pub fn total_chunks(indptr: &[usize]) -> usize {
    indptr
        .windows(2)
        .map(|w| chunks_for_row(w[1] - w[0]))
        .sum()
}

// ---------------------------------------------------------------------
// Encode

/// Chunk-encode a posting family. `values` must be parallel to `ids`
/// (same length), or empty for an ids-only family (member lists).
/// `indptr` partitions `ids` into rows with strictly ascending ids —
/// the CSR invariant every caller has already established.
pub fn encode_postings(indptr: &[usize], ids: &[u32], values: &[f64]) -> ChunkedPostings {
    debug_assert!(!indptr.is_empty());
    debug_assert_eq!(*indptr.last().unwrap(), ids.len());
    debug_assert!(values.is_empty() || values.len() == ids.len());
    let n_chunks = total_chunks(indptr);
    let mut metas = ByteWriter::new();
    metas.put_u64(n_chunks as u64);
    let mut id_bytes: Vec<u8> = Vec::with_capacity(ids.len()); // ≥1 B/posting
    let mut val_bytes: Vec<u8> = Vec::with_capacity(values.len() * 8);
    let has_vals = !values.is_empty();

    for w in indptr.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        let mut c = lo;
        while c < hi {
            let end = (c + CHUNK_CAP).min(hi);
            let chunk_ids = &ids[c..end];
            let id_off = id_bytes.len() as u64;
            put_varint(&mut id_bytes, chunk_ids[0] as u64);
            for pair in chunk_ids.windows(2) {
                debug_assert!(pair[0] < pair[1], "posting ids not strictly ascending");
                put_varint(&mut id_bytes, (pair[1] - pair[0]) as u64);
            }
            let val_off = if has_vals { (c * 8) as u64 } else { 0 };
            if has_vals {
                for &v in &values[c..end] {
                    val_bytes.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            metas.put_u32((end - c) as u32);
            metas.put_u32(*chunk_ids.last().unwrap());
            metas.put_u64(id_off);
            metas.put_u32((id_bytes.len() as u64 - id_off) as u32);
            metas.put_u64(val_off);
            c = end;
        }
    }
    ChunkedPostings {
        meta: metas.into_bytes(),
        ids: id_bytes,
        vals: val_bytes,
    }
}

// ---------------------------------------------------------------------
// Decode

/// Decode the metadata stream into records, validating the chunk count
/// against the layout `indptr` implies.
pub fn decode_metas(meta: &[u8], indptr: &[usize]) -> Result<Vec<ChunkMeta>, String> {
    let want = total_chunks(indptr);
    let mut r = ByteReader::new(meta);
    let count = r.get_usize()?;
    if count != want {
        return Err(format!(
            "chunk count {count} but indptr implies {want} chunks"
        ));
    }
    if r.remaining() != count * CHUNK_META_LEN {
        return Err(format!(
            "chunk metadata is {} bytes for {count} chunks (want {})",
            r.remaining(),
            count * CHUNK_META_LEN
        ));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        out.push(ChunkMeta {
            count: r.get_u32()?,
            max_id: r.get_u32()?,
            id_off: r.get_u64()?,
            id_len: r.get_u32()?,
            val_off: r.get_u64()?,
        });
    }
    r.finish()?;
    Ok(out)
}

/// Decode one chunk's ids from its byte span into `out`, validating
/// every field of `m` along the way. Returns an error message on any
/// defect; on success exactly `m.count` strictly-ascending ids were
/// appended and `m.id_len` bytes consumed.
pub fn decode_chunk_ids(bytes: &[u8], m: &ChunkMeta, out: &mut Vec<u32>) -> Result<(), String> {
    if m.count == 0 || m.count as usize > CHUNK_CAP {
        return Err(format!("chunk posting count {} outside [1, {CHUNK_CAP}]", m.count));
    }
    let mut pos = 0usize;
    let mut prev = 0u64;
    for i in 0..m.count {
        let (v, n) = get_varint(bytes, pos)?;
        pos += n;
        let id = if i == 0 {
            v
        } else {
            if v == 0 {
                return Err("zero id delta (ids must be strictly ascending)".to_string());
            }
            // checked: a hostile delta must not overflow-panic in debug
            // builds — it is a typed corruption error like everything else.
            prev.checked_add(v)
                .ok_or_else(|| format!("id delta {v} overflows from {prev}"))?
        };
        if id > u32::MAX as u64 {
            return Err(format!("posting id {id} overflows u32"));
        }
        out.push(id as u32);
        prev = id;
    }
    if pos != m.id_len as usize {
        return Err(format!(
            "chunk id bytes: consumed {pos}, metadata claims {}",
            m.id_len
        ));
    }
    if prev != m.max_id as u64 {
        return Err(format!(
            "chunk max_id {} but last decoded id is {prev}",
            m.max_id
        ));
    }
    Ok(())
}

/// Validate the pure-metadata layout of a decoded chunk table against
/// `indptr` and the stream lengths: per-row chunk sizes, contiguous
/// `id_off` spans covering exactly `ids_len` bytes, `val_off` equal to
/// `8 × postings before the chunk`, and the value stream exactly
/// `8 × nnz` bytes (empty for ids-only families). After this passes,
/// every chunk's byte span is in bounds and chunks can be decoded
/// independently (the mmap reader relies on that for random row access).
pub fn validate_layout(
    metas: &[ChunkMeta],
    indptr: &[usize],
    ids_len: usize,
    vals_len: usize,
    has_vals: bool,
) -> Result<(), String> {
    let nnz = *indptr.last().unwrap();
    if has_vals {
        if vals_len != nnz * 8 {
            return Err(format!(
                "value stream is {vals_len} bytes for {nnz} postings (want {})",
                nnz * 8
            ));
        }
    } else if vals_len != 0 {
        return Err(format!("ids-only family has a {vals_len}-byte value stream"));
    }
    if metas.len() != total_chunks(indptr) {
        return Err(format!(
            "{} chunk records but indptr implies {}",
            metas.len(),
            total_chunks(indptr)
        ));
    }
    let mut chunk = 0usize;
    let mut id_cursor = 0u64;
    for (r, w) in indptr.windows(2).enumerate() {
        let mut c = w[0];
        while c < w[1] {
            let take = (w[1] - c).min(CHUNK_CAP);
            let m = &metas[chunk];
            if m.count as usize != take {
                return Err(format!(
                    "row {r}: chunk {chunk} holds {} postings, layout implies {take}",
                    m.count
                ));
            }
            if m.id_off != id_cursor {
                return Err(format!(
                    "chunk {chunk}: id offset {} but stream cursor is {id_cursor}",
                    m.id_off
                ));
            }
            let in_bounds = (m.id_off as usize)
                .checked_add(m.id_len as usize)
                .is_some_and(|e| e <= ids_len);
            if !in_bounds {
                return Err(format!(
                    "chunk {chunk}: id span [{}, +{}) exceeds the {ids_len}-byte stream",
                    m.id_off, m.id_len
                ));
            }
            let want_val_off = if has_vals { (c * 8) as u64 } else { 0 };
            if m.val_off != want_val_off {
                return Err(format!(
                    "chunk {chunk}: value offset {} (want {want_val_off})",
                    m.val_off
                ));
            }
            id_cursor += m.id_len as u64;
            c += take;
            chunk += 1;
        }
    }
    if id_cursor != ids_len as u64 {
        return Err(format!(
            "{} trailing bytes in the id stream",
            ids_len as u64 - id_cursor
        ));
    }
    Ok(())
}

/// Fully decode a chunk-encoded family back into `(ids, values)`,
/// validating all metadata against `indptr`. `has_vals = false` for
/// ids-only families (the value stream must then be empty). The decoded
/// arrays are **bit-identical** to what [`encode_postings`] was given.
pub fn decode_postings(
    indptr: &[usize],
    meta: &[u8],
    id_bytes: &[u8],
    val_bytes: &[u8],
    has_vals: bool,
) -> Result<(Vec<u32>, Vec<f64>), String> {
    let metas = decode_metas(meta, indptr)?;
    validate_layout(&metas, indptr, id_bytes.len(), val_bytes.len(), has_vals)?;
    let nnz = *indptr.last().unwrap();

    let mut ids = Vec::with_capacity(nnz);
    let mut vals = Vec::with_capacity(if has_vals { nnz } else { 0 });
    let mut chunk = 0usize;
    for (r, w) in indptr.windows(2).enumerate() {
        let mut c = w[0];
        while c < w[1] {
            let take = (w[1] - c).min(CHUNK_CAP);
            let m = &metas[chunk];
            let span = &id_bytes[m.id_off as usize..m.id_off as usize + m.id_len as usize];
            decode_chunk_ids(span, m, &mut ids)
                .map_err(|d| format!("chunk {chunk} (row {r}): {d}"))?;
            if has_vals {
                for p in c..c + take {
                    let b = &val_bytes[p * 8..p * 8 + 8];
                    vals.push(f64::from_bits(u64::from_le_bytes(b.try_into().unwrap())));
                }
            }
            c += take;
            chunk += 1;
        }
    }
    Ok((ids, vals))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(indptr: &[usize], ids: &[u32], vals: &[f64]) {
        let enc = encode_postings(indptr, ids, vals);
        let (di, dv) =
            decode_postings(indptr, &enc.meta, &enc.ids, &enc.vals, !vals.is_empty()).unwrap();
        assert_eq!(di, ids);
        assert_eq!(
            dv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn varint_round_trips_edge_values() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut b = Vec::new();
            put_varint(&mut b, v);
            let (d, n) = get_varint(&b, 0).unwrap();
            assert_eq!(d, v);
            assert_eq!(n, b.len());
        }
        // Truncation and u64 overflow are rejected.
        assert!(get_varint(&[0x80], 0).is_err());
        assert!(get_varint(&[0xFF; 11], 0).is_err());
    }

    #[test]
    fn empty_rows_and_boundary_sizes_round_trip() {
        // 0, 1, CHUNK_CAP, CHUNK_CAP+1, 2*CHUNK_CAP postings per row.
        let sizes = [0usize, 1, CHUNK_CAP, CHUNK_CAP + 1, 2 * CHUNK_CAP];
        let mut indptr = vec![0usize];
        let mut ids = Vec::new();
        let mut vals = Vec::new();
        for (r, &s) in sizes.iter().enumerate() {
            for i in 0..s {
                ids.push((i * 3 + r) as u32); // strictly ascending per row
                vals.push((r as f64 + 0.5) * (i as f64 + 1.0));
            }
            indptr.push(ids.len());
        }
        roundtrip(&indptr, &ids, &vals);
        // Chunk layout: 0 + 1 + 1 + 2 + 2 chunks.
        assert_eq!(total_chunks(&indptr), 6);
        // An all-empty family works too.
        roundtrip(&[0, 0, 0], &[], &[]);
    }

    #[test]
    fn extreme_ids_and_value_bits_round_trip() {
        let indptr = [0usize, 3, 5];
        let ids = [0u32, u32::MAX - 1, u32::MAX, 7, 1_000_000];
        let vals = [0.0, -0.0, f64::NAN, f64::MIN_POSITIVE, 1.0e300];
        roundtrip(&indptr, &ids, &vals);
    }

    #[test]
    fn ids_only_families_have_no_value_stream() {
        let indptr = [0usize, 2, 2, 5];
        let ids = [4u32, 9, 0, 1, 2];
        let enc = encode_postings(&indptr, &ids, &[]);
        assert!(enc.vals.is_empty());
        let (di, dv) = decode_postings(&indptr, &enc.meta, &enc.ids, &enc.vals, false).unwrap();
        assert_eq!(di, ids);
        assert!(dv.is_empty());
        // A stray value stream on an ids-only family is a defect.
        assert!(decode_postings(&indptr, &enc.meta, &enc.ids, &[0u8; 8], false).is_err());
    }

    #[test]
    fn metadata_defects_are_typed() {
        let indptr = [0usize, 200]; // 2 chunks: 128 + 72
        let ids: Vec<u32> = (0..200u32).map(|i| i * 2).collect();
        let vals: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let enc = encode_postings(&indptr, &ids, &vals);
        let metas = decode_metas(&enc.meta, &indptr).unwrap();
        assert_eq!(metas.len(), 2);
        assert_eq!(metas[0].count, 128);
        assert_eq!(metas[1].count, 72);
        assert_eq!(metas[0].max_id, 254);
        assert_eq!(metas[1].val_off, 128 * 8);

        // Each corrupted field is caught with an error, not a panic.
        let corrupt_field = |off: usize, val: u64, len: usize| {
            let mut bad = enc.meta.clone();
            bad[off..off + len].copy_from_slice(&val.to_le_bytes()[..len]);
            decode_postings(&indptr, &bad, &enc.ids, &enc.vals, true)
        };
        // Record 0 starts at byte 8: count, max_id, id_off, id_len, val_off.
        assert!(corrupt_field(8, 127, 4).is_err(), "count");
        assert!(corrupt_field(12, 999, 4).is_err(), "max_id");
        assert!(corrupt_field(16, 3, 8).is_err(), "id_off");
        assert!(corrupt_field(24, 1, 4).is_err(), "id_len");
        assert!(corrupt_field(28, 8, 8).is_err(), "val_off");
        // Wrong chunk count.
        let mut bad = enc.meta.clone();
        bad[0..8].copy_from_slice(&9u64.to_le_bytes());
        assert!(decode_postings(&indptr, &bad, &enc.ids, &enc.vals, true).is_err());
        // Truncated metadata.
        assert!(decode_metas(&enc.meta[..enc.meta.len() - 1], &indptr).is_err());

        // Corrupted id payload: a zero delta breaks strict ascent.
        let mut bad_ids = enc.ids.clone();
        bad_ids[metas[0].id_off as usize + 1] = 0; // first delta byte → 0
        assert!(decode_postings(&indptr, &enc.meta, &bad_ids, &enc.vals, true).is_err());
        // Truncated id stream.
        assert!(decode_postings(&indptr, &enc.meta, &enc.ids[..enc.ids.len() - 1], &enc.vals, true)
            .is_err());
        // Truncated value stream.
        assert!(decode_postings(&indptr, &enc.meta, &enc.ids, &enc.vals[..enc.vals.len() - 8], true)
            .is_err());
    }

    #[test]
    fn compression_wins_on_dense_ascending_ids() {
        // tf-idf-like rows: clustered ascending ids → mostly 1-byte
        // varints vs 4 raw bytes per id.
        let indptr = [0usize, 1000];
        let ids: Vec<u32> = (0..1000u32).map(|i| 10_000 + i * 3).collect();
        let enc = encode_postings(&indptr, &ids, &[]);
        assert!(
            enc.ids.len() < 1000 * 4 / 2,
            "{} id bytes for 1000 postings",
            enc.ids.len()
        );
    }
}
